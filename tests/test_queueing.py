"""Unit + property tests for the paper's closed-form queueing primitives."""

import math

import numpy as np
import pytest

from _prop import assume, example, given, settings, st

from repro.core import queueing as Q

# strategy: stable queue operating points
stable = st.tuples(
    st.floats(0.01, 50.0),  # lam
    st.floats(0.1, 200.0),  # mu
).filter(lambda t: t[0] < 0.95 * t[1])


class TestClosedForms:
    def test_mm1_known_value(self):
        # rho=0.5: E[w] = rho/(mu(1-rho)) = 0.5/(10*0.5) = 0.1
        assert Q.mm1_wait(5.0, 10.0) == pytest.approx(0.1)

    def test_md1_is_half_mm1(self):
        # P-K: deterministic halves the exponential wait
        assert Q.md1_wait(5.0, 10.0) == pytest.approx(0.5 * Q.mm1_wait(5.0, 10.0))

    def test_mg1_reduces_to_md1_at_zero_variance(self):
        lam, mu = 4.0, 9.0
        assert Q.mg1_wait(lam, mu, 0.0) == pytest.approx(Q.md1_wait(lam, mu))

    def test_mg1_reduces_to_mm1_at_exponential_variance(self):
        lam, mu = 4.0, 9.0
        assert Q.mg1_wait(lam, mu, 1.0 / mu**2) == pytest.approx(Q.mm1_wait(lam, mu))

    def test_unstable_is_inf(self):
        assert Q.mm1_wait(10.0, 10.0) == math.inf
        assert Q.md1_wait(11.0, 10.0) == math.inf
        assert Q.mg1_wait(10.0, 10.0, 0.1) == math.inf
        assert Q.gg1_wait_upper_bound(12.0, 10.0, 0.1, 0.1) == math.inf

    def test_zero_arrivals_zero_wait(self):
        assert Q.mm1_wait(0.0, 10.0) == 0.0
        assert Q.md1_wait(0.0, 10.0) == 0.0

    def test_aggregated_rate_forms(self):
        # Eq. 6 / Lemma 3.3 building blocks: k folds into mu
        assert Q.md1_wait_aggregated(5.0, 2.0, 4.0) == pytest.approx(Q.md1_wait(5.0, 8.0))
        assert Q.mm1_wait_aggregated(5.0, 2.0, 4.0) == pytest.approx(Q.mm1_wait(5.0, 8.0))

    def test_erlang_c_k1_equals_mm1(self):
        assert Q.mmk_wait_erlang(5.0, 10.0, 1) == pytest.approx(Q.mm1_wait(5.0, 10.0))

    def test_erlang_c_vs_aggregated_same_ballpark(self):
        # the paper's aggregated-rate reduction vs the exact Erlang-C M/M/k:
        # at rho=0.75 the approximation overestimates the wait by ~47% —
        # quantified (not assumed) here; both vanish as rho -> 0.
        lam, mu, k = 6.0, 2.0, 4
        exact = Q.mmk_wait_erlang(lam, mu, k)
        approx = Q.mm1_wait(lam, k * mu)
        assert 0.3 < exact / approx < 3.0
        assert Q.mmk_wait_erlang(0.1, mu, k) == pytest.approx(0.0, abs=1e-3)

    def test_gg1_bound_dominates_mm1(self):
        # with exponential interarrival+service variances, Marshall's bound
        # must upper-bound the exact M/M/1 wait
        lam, mu = 4.0, 10.0
        bound = Q.gg1_wait_upper_bound(lam, mu, 1 / lam**2, 1 / mu**2)
        assert bound >= Q.mm1_wait(lam, mu) - 1e-12


class TestProperties:
    @given(stable, st.floats(0.0, 1.0))
    @settings(max_examples=200, deadline=None)
    def test_wait_monotone_in_lambda(self, lm, frac):
        lam, mu = lm
        lam2 = lam * frac
        assert Q.mm1_wait(lam2, mu) <= Q.mm1_wait(lam, mu) + 1e-12
        assert Q.md1_wait(lam2, mu) <= Q.md1_wait(lam, mu) + 1e-12

    @given(stable, st.floats(1.01, 10.0))
    @settings(max_examples=200, deadline=None)
    def test_wait_monotone_in_mu(self, lm, boost):
        lam, mu = lm
        assert Q.mm1_wait(lam, mu * boost) <= Q.mm1_wait(lam, mu) + 1e-12

    @given(stable, st.floats(0.0, 5.0), st.floats(0.0, 5.0))
    @settings(max_examples=200, deadline=None)
    def test_mg1_monotone_in_variance(self, lm, v1, v2):
        lam, mu = lm
        lo, hi = sorted((v1, v2))
        assert Q.mg1_wait(lam, mu, lo) <= Q.mg1_wait(lam, mu, hi) + 1e-12

    @given(stable)
    @settings(max_examples=200, deadline=None)
    def test_waits_nonnegative(self, lm):
        lam, mu = lm
        for f in (Q.mm1_wait, Q.md1_wait):
            assert f(lam, mu) >= 0

    @given(stable, st.floats(0.0, 2.0))
    @settings(max_examples=100, deadline=None)
    def test_md1_lower_bounds_mg1(self, lm, var):
        # deterministic service is the minimum-variance service
        lam, mu = lm
        assert Q.md1_wait(lam, mu) <= Q.mg1_wait(lam, mu, var) + 1e-12

    @given(st.floats(0.01, 50.0), st.floats(0.01, 50.0), st.floats(0.1, 200.0))
    @example(4.0, 8.0, 10.0)  # textbook pin: rho 0.4 vs 0.8 on Eq. 7
    @settings(max_examples=200, deadline=None)
    def test_wait_strictly_increasing_between_distinct_loads(self, lam_a, lam_b, mu):
        # assume() runs identically under hypothesis and the seeded fallback:
        # rejected draws are resampled, not failed
        assume(abs(lam_a - lam_b) > 1e-3)
        assume(max(lam_a, lam_b) < 0.95 * mu)
        lo, hi = sorted((lam_a, lam_b))
        assert Q.mm1_wait(lo, mu) < Q.mm1_wait(hi, mu)
        assert Q.md1_wait(lo, mu) < Q.md1_wait(hi, mu)
