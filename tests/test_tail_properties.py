"""Distributional contracts for the tail layer, swept over random tandems.

Property sweep over random stable station tandems (device-style single
stations and NIC->proc->NIC offload chains alike, drawn as raw
``proc_station`` mixtures): the quantile inversion must behave like an
inverse CDF — monotone in q, consistent under round-trip through
``sojourn_cdf``, continuous (within the documented inversion noise) across
the ``EULER_Q_MAX`` handoff to the asymptote, and bounded by the
mean-derived Markov envelope.

Runs under both property engines: real hypothesis when installed, and the
seeded fallback (`tests/_prop.py`) that the hermetic container uses — CI
forces the fallback explicitly via ``REPRO_FORCE_HYPOTHESIS_FALLBACK=1``.

Tolerances are empirical but principled:

  * round-trip |F(t_q) - q| <= 1e-6 holds for *continuous* (exponential /
    gamma) mixtures, where the Euler inversion's error floor is ~1e-8;
    deterministic services put atoms in the sojourn law, where a CDF
    round-trip is ill-posed at the jump (the quantile is exact but F steps
    over q) — those draw from the monotonicity/envelope sweeps instead;
  * at the ``EULER_Q_MAX`` = 1 - 1e-6 handoff the ~1e-8 CDF noise floor is
    ~1% of the surviving mass, so the euler quantile can only promise
    CDF-consistency to within a couple of survival widths, and t-space
    agreement with the asymptote to a few percent (noise floor x the local
    log-slope, plus the asymptote's own subdominant-pole error).
"""

import math

import numpy as np
import pytest

from _prop import given, settings, st
from repro.core import tail as T

# ---------------------------------------------------------------------------
# strategies: random stable tandems
# ---------------------------------------------------------------------------

# one station: (mu, rho, kind, cv2). Service mean is 1/mu, arrival rho*mu.
_STATION = st.tuples(
    st.floats(0.5, 50.0),  # service rate mu
    st.floats(0.05, 0.9),  # utilisation rho (strictly stable)
    st.sampled_from([T.KIND_DET, T.KIND_EXP, T.KIND_GAMMA]),
    st.floats(0.05, 1.5),  # cv^2 for GAMMA kinds
)
_TANDEM = st.lists(_STATION, min_size=1, max_size=3)
# continuous-law tandems: no deterministic atoms, so the sojourn CDF is
# strictly increasing and round-trip/density checks are well-posed
_SMOOTH_STATION = st.tuples(
    st.floats(0.5, 50.0),
    st.floats(0.05, 0.9),
    st.sampled_from([T.KIND_EXP, T.KIND_GAMMA]),
    st.floats(0.05, 1.5),
)
_SMOOTH_TANDEM = st.lists(_SMOOTH_STATION, min_size=1, max_size=3)
_Q = st.floats(0.5, 0.995)


def _stations(params):
    out = []
    for mu, rho, kind, cv2 in params:
        mean = 1.0 / mu
        var = cv2 * mean * mean if kind == T.KIND_GAMMA else 0.0
        out.append(T.proc_station(rho * mu, kind, mean, var, 1.0))
    return out


# ---------------------------------------------------------------------------
# monotonicity
# ---------------------------------------------------------------------------


class TestMonotoneInQ:
    @given(_TANDEM, st.tuples(st.floats(0.5, 0.999), st.floats(0.5, 0.999)))
    @settings(max_examples=40, deadline=None)
    def test_quantile_monotone_in_q_both_methods(self, params, qs):
        sts = _stations(params)
        q0, q1 = sorted(qs)
        for method in ("euler", "asymptote"):
            t0 = T.sojourn_quantile(sts, q0, method=method)
            t1 = T.sojourn_quantile(sts, q1, method=method)
            # non-strict: deterministic atoms legitimately pin neighbouring
            # quantiles to the same t; a tiny slack absorbs inversion noise
            assert t0 <= t1 * (1.0 + 1e-9), (method, q0, q1, t0, t1)

    @given(_SMOOTH_TANDEM)
    @settings(max_examples=25, deadline=None)
    def test_cdf_monotone_in_t(self, params):
        sts = _stations(params)
        mean = T.sojourn_mean(sts)
        t = np.linspace(0.1 * mean, 8.0 * mean, 24)
        cdf = np.asarray(T.sojourn_cdf(sts, t))
        assert np.all(np.diff(cdf) >= -1e-9)
        assert np.all((cdf >= 0.0) & (cdf <= 1.0))


# ---------------------------------------------------------------------------
# round-trip: quantile is the inverse of the CDF it was solved against
# ---------------------------------------------------------------------------


class TestRoundTrip:
    @given(_SMOOTH_TANDEM, _Q)
    @settings(max_examples=40, deadline=None)
    def test_cdf_of_quantile_recovers_q(self, params, q):
        sts = _stations(params)
        t = T.sojourn_quantile(sts, q, method="euler")
        assert math.isfinite(t) and t > 0.0
        assert abs(float(T.sojourn_cdf(sts, t)) - q) <= 1e-6, (q, t)

    @given(_SMOOTH_TANDEM, _Q)
    @settings(max_examples=25, deadline=None)
    def test_pdf_is_cdf_derivative(self, params, q):
        """The free density the Newton phase steers by must actually be the
        CDF's derivative — central difference to ~1e-3, far tighter than
        anything the safeguarded step needs."""
        sts = _stations(params)
        t = T.sojourn_quantile(sts, q, method="euler")
        pdf = float(T.sojourn_pdf(sts, t))
        h = 1e-5 * t
        fd = float((T.sojourn_cdf(sts, t + h) - T.sojourn_cdf(sts, t - h)) / (2 * h))
        assert pdf >= 0.0
        assert pdf == pytest.approx(fd, rel=1e-3, abs=1e-9)


# ---------------------------------------------------------------------------
# the EULER_Q_MAX handoff
# ---------------------------------------------------------------------------


class TestEulerAsymptoteHandoff:
    def test_resolution_flips_exactly_past_q_max(self):
        qmax = T.EULER_Q_MAX
        assert T.resolve_tail_method(qmax, "euler") == "euler"
        assert T.resolve_tail_method(math.nextafter(qmax, 1.0), "euler") == \
            "asymptote"
        # asymptote never re-routes
        assert T.resolve_tail_method(0.5, "asymptote") == "asymptote"

    @given(_SMOOTH_TANDEM)
    @settings(max_examples=25, deadline=None)
    def test_handoff_is_cdf_consistent(self, params):
        """At the boundary quantile the euler answer must still sit within a
        couple of survival widths of q in CDF space — the noise floor is ~1%
        of the surviving mass there, which is exactly why EULER_Q_MAX is
        where it is."""
        sts = _stations(params)
        qmax = T.EULER_Q_MAX
        t = T.sojourn_quantile(sts, qmax, method="euler")
        assert abs(float(T.sojourn_cdf(sts, t)) - qmax) <= 2.0 * (1.0 - qmax)

    @given(st.lists(st.tuples(st.floats(0.5, 50.0), st.floats(0.05, 0.9)),
                    min_size=2, max_size=3))
    @settings(max_examples=25, deadline=None)
    def test_handoff_jump_small_for_exponential_tandems(self, pairs):
        """Crossing EULER_Q_MAX swaps engines mid-curve; for exponential
        tandems (no atoms, asymptote near-exact) the jump is bounded by the
        inversion noise x log-slope — a few percent, empirically <= 6%."""
        sts = [T.proc_station(rho * mu, T.KIND_EXP, 1.0 / mu, 0.0, 1.0)
               for mu, rho in pairs]
        qmax = T.EULER_Q_MAX
        e = T.sojourn_quantile(sts, qmax, method="euler")
        a = T.sojourn_quantile(sts, qmax, method="asymptote")
        assert abs(e - a) / a <= 0.10, (e, a)


# ---------------------------------------------------------------------------
# mean-derived envelope
# ---------------------------------------------------------------------------


class TestMeanEnvelope:
    @given(_TANDEM)
    @settings(max_examples=40, deadline=None)
    def test_p99_p50_mean_chain(self, params):
        """0 < p50 <= p99, both under the Markov bound t_q <= mean/(1-q),
        and p99 above the deterministic service floor — every piece derived
        from the same mean the closed forms report."""
        sts = _stations(params)
        mean = T.sojourn_mean(sts)
        assert math.isfinite(mean) and mean > 0.0
        p50 = T.sojourn_quantile(sts, 0.5, method="euler")
        p99 = T.sojourn_quantile(sts, 0.99, method="euler")
        assert 0.0 < p50 <= p99 * (1.0 + 1e-9)
        assert p50 <= mean / 0.5 * (1.0 + 1e-6)
        assert p99 <= mean / 0.01 * (1.0 + 1e-6)
        floor = sum(1.0 / mu for mu, _, kind, _ in params if kind == T.KIND_DET)
        assert p99 >= floor * (1.0 - 1e-6)

    @given(_TANDEM)
    @settings(max_examples=25, deadline=None)
    def test_asymptote_obeys_same_envelope(self, params):
        sts = _stations(params)
        mean = T.sojourn_mean(sts)
        p50 = T.sojourn_quantile(sts, 0.5, method="asymptote")
        p99 = T.sojourn_quantile(sts, 0.99, method="asymptote")
        assert 0.0 < p50 <= p99 * (1.0 + 1e-9)
        assert p99 <= mean / 0.01 * (1.0 + 1e-6)
