"""Tests for the repro.validate subsystem: golden corpus integrity, metric
math, the tier-1 smoke differential gate, and the tier-2 full MAPE gate.

Tier-1 tests here are fast (analytic-only checks over the whole corpus, short
simulations over the smoke subset). The full paper-style gate — analytic vs
long-run simulation MAPE <= 5% over every gated corpus scenario — carries the
``validate`` marker and runs via ``python -m pytest -m validate``.
"""

import json
import os
from pathlib import Path

import numpy as np
import pytest

from repro.core.scenario import Scenario
from repro.launch import validate as validate_cli
from repro.validate import (
    BAND_ORDER,
    CorpusEntry,
    bootstrap_mean_ci,
    bottleneck_rho,
    corpus_to_dict,
    default_fixture_path,
    error_stats,
    error_table,
    generate_corpus,
    load_corpus,
    mape,
    meanfield_gate_specs,
    rho_band,
    run_differential,
    run_meanfield_gate,
    smoke_subset,
)

FIXTURE = default_fixture_path()


@pytest.fixture(scope="module")
def corpus():
    entries, meta = load_corpus(FIXTURE)
    return entries, meta


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------


class TestMetrics:
    def test_mape_scalar_and_array(self):
        assert mape(1.05, 1.0) == pytest.approx(5.0)
        out = mape(np.array([1.1, 0.9]), np.array([1.0, 1.0]))
        assert out == pytest.approx([10.0, 10.0])

    def test_mape_inf_prediction_is_loud(self):
        assert np.isinf(mape(np.inf, 1.0))

    def test_error_stats_paper_style_fractions(self):
        s = error_stats([1.0, 4.0, 6.0, 12.0])
        assert s.n == 4
        assert s.mean_pct == pytest.approx(5.75)
        assert s.within_5_frac == pytest.approx(0.5)
        assert s.within_10_frac == pytest.approx(0.75)
        assert s.max_pct == pytest.approx(12.0)

    def test_error_table_respects_band_order(self):
        table = error_table(
            [("stress", 1.0), ("low", 2.0), ("mid", 3.0), ("low", 4.0)],
            order=BAND_ORDER,
        )
        assert list(table) == ["low", "mid", "stress"]
        assert table["low"].n == 2

    def test_bootstrap_ci_covers_iid_mean(self):
        rng = np.random.default_rng(0)
        x = rng.normal(10.0, 2.0, size=5_000)
        ci = bootstrap_mean_ci(x, n_boot=300, seed=1)
        assert ci.lo < 10.0 < ci.hi
        assert ci.half_width_pct < 2.0
        assert ci.mean == pytest.approx(x.mean())

    def test_rel_err_one_sided_inf_is_loud(self):
        # regression: a one-sided inf produced inf/inf = NaN, which max()
        # silently drops — exactly the scalar-vs-vec bug class the gate exists
        # to catch would have passed
        from repro.validate.differential import _rel_err
        assert _rel_err(np.inf, np.inf) == 0.0
        assert _rel_err(np.inf, 1.0) == np.inf
        assert _rel_err(1.0, np.inf) == np.inf
        assert _rel_err(np.nan, 1.0) == np.inf
        assert _rel_err(2.0, 1.0) == pytest.approx(0.5)

    def test_parse_strategy_is_the_single_label_parser(self):
        from repro.core.scenario import ScenarioError, parse_strategy
        assert parse_strategy("on_device") == -1
        assert parse_strategy("edge[2]") == 2
        assert parse_strategy("edge[0]", n_edges=1) == 0
        for bad in ("edge[1]", "edge[x]", "edgy", ""):
            with pytest.raises(ScenarioError):
                parse_strategy(bad, n_edges=1)

    def test_bootstrap_ci_blocks_widen_for_autocorrelated_series(self):
        # a strongly autocorrelated series must NOT get an iid-narrow CI
        rng = np.random.default_rng(2)
        ar = np.empty(20_000)
        ar[0] = 0.0
        eps = rng.normal(size=20_000)
        for i in range(1, len(ar)):
            ar[i] = 0.99 * ar[i - 1] + eps[i]
        blocked = bootstrap_mean_ci(ar, n_boot=200, seed=3)
        iid = bootstrap_mean_ci(ar, n_boot=200, block_len=1, seed=3)
        assert (blocked.hi - blocked.lo) > 3.0 * (iid.hi - iid.lo)


# ---------------------------------------------------------------------------
# corpus integrity
# ---------------------------------------------------------------------------


class TestCorpus:
    def test_fixture_exists_and_matches_regeneration(self, corpus):
        """tests/golden/corpus_v1.json is exactly generate_corpus(seed)."""
        _, meta = corpus
        regenerated = corpus_to_dict(generate_corpus(meta["seed"]), seed=meta["seed"])
        on_disk = json.loads(FIXTURE.read_text())
        assert regenerated == on_disk

    def test_corpus_spans_the_paper_axes(self, corpus):
        entries, _ = corpus
        bands = {e.band for e in entries}
        assert bands == set(BAND_ORDER), "corpus must span every utilization band"
        regimes = {e.regime for e in entries}
        assert {"device-md1", "device-mm1", "device-mg1", "multitenant",
                "cluster-equilibrium", "meanfield-equilibrium"} <= regimes
        assert any("aggregated-k" in r for r in regimes)
        assert any(e.scenario.edges and e.scenario.edges[0].background
                   for e in entries), "corpus needs multi-tenant scenarios"
        assert any(not e.scenario.edges for e in entries)
        assert max(e.rho for e in entries) <= 0.96
        assert len({e.name for e in entries}) == len(entries)

    def test_every_entry_round_trips_and_validates(self, corpus):
        entries, _ = corpus
        for e in entries:
            # construction already ran eager validation; JSON round-trip exact
            assert Scenario.from_dict(e.scenario.to_dict()) == e.scenario
            d = e.to_dict()
            again = CorpusEntry.from_dict(d)
            assert again.scenario == e.scenario
            assert again.rho == pytest.approx(e.rho)

    def test_golden_totals_pin(self, corpus):
        """Recomputed scalar analytic must match the checked-in totals: any
        closed-form change that moves a prediction fails HERE, by name."""
        entries, meta = corpus
        expected = meta["expected_totals"]
        for e in entries:
            tot = e.scenario.analytic().totals()
            exp = expected[e.name]
            assert tot.keys() == exp.keys()
            for k, v in tot.items():
                assert v == pytest.approx(exp[k], rel=1e-9), (e.name, k)

    def test_gated_entries_stay_inside_the_gateable_region(self, corpus):
        entries, _ = corpus
        for e in entries:
            if e.sim_gate:
                assert e.rho <= 0.9 + 1e-9, e.name
                assert "aggregated" not in e.regime, e.name
            assert e.rho == pytest.approx(bottleneck_rho(e.scenario, e.strategy))

    def test_rho_band_boundaries(self):
        assert rho_band(0.1) == "low"
        assert rho_band(0.3) == "low"  # upper-inclusive
        assert rho_band(0.45) == "mid"
        assert rho_band(0.75) == "high"
        assert rho_band(0.9) == "peak"
        assert rho_band(0.95) == "stress"

    def test_different_seed_different_corpus(self):
        a = generate_corpus(0)
        b = generate_corpus(1)
        assert [e.name for e in a] == [e.name for e in b]  # same structure
        assert any(x.scenario != y.scenario for x, y in zip(a, b))  # jittered

    def test_meanfield_regime_entries(self, corpus):
        """The integerized mean-field fixed points land as gated multitenant-
        style entries: the representative offloads, the other offloaded
        clients are its per-stream background, and the cellular class keeps
        some of the fleet on-device (class structure survived)."""
        entries, _ = corpus
        mf = [e for e in entries if e.regime == "meanfield-equilibrium"]
        assert len(mf) >= 2
        for e in mf:
            assert e.strategy.startswith("edge[")
            assert e.sim_gate and e.rho <= 0.9
            j = int(e.strategy[5:-1])
            bg = e.scenario.edges[j].background
            assert len(bg) >= 2  # one stream per other offloaded client
            # the cellular class stayed on-device: fewer background streams
            # than fleet-members-minus-one
            assert len(bg) < 11


# ---------------------------------------------------------------------------
# differential harness — tier-1: analytic paths over the FULL corpus,
# simulation over the smoke subset only
# ---------------------------------------------------------------------------


class TestDifferentialSmoke:
    def test_analytic_paths_agree_on_full_corpus(self, corpus):
        """Scalar vs vectorized closed forms and golden pins, no simulation."""
        entries, meta = corpus
        rep = run_differential(entries, expected_totals=meta["expected_totals"],
                               simulate=False)
        assert rep.vec_max_rel_err <= 1e-6
        assert rep.golden_max_rel_err <= 1e-9
        assert rep.passed  # MAPE gate is vacuous without simulation
        assert all(r.vec_rel_err <= 1e-6 for r in rep.entries)
        # the batched exact euler inversion agrees with the scalar one to
        # 1e-8 on every rho <= 0.95 entry even in the analytic-only run
        assert rep.euler_vec_n >= 30
        assert rep.euler_vec_max_rel_err <= 1e-8, rep.euler_vec_max_rel_err

    def test_smoke_gate(self, corpus):
        """The fast subset meets the paper-style budget with short runs."""
        entries, meta = corpus
        sub = smoke_subset(entries)
        assert 5 <= len(sub) <= 12
        rep = run_differential(sub, expected_totals=meta["expected_totals"],
                               base_n=20_000, max_n_factor=2.0, bootstrap=100,
                               sim_cross_count=2)
        assert rep.passed
        assert rep.gate.n == len(sub)
        assert rep.gate.mean_pct <= 5.0
        # the fast tail smoke: analytic p99 vs simulated percentile(99) over
        # the exact-transform members, scalar-vs-vectorized tail everywhere
        assert rep.tail.n >= 5
        assert rep.tail_passed and rep.tail.mean_pct <= 10.0
        assert rep.tail_vec_max_rel_err <= 1e-6
        for r in rep.entries:
            assert r.sim_backend in ("fleet", "scalar")
            assert r.sim_ci is not None and r.sim_ci.lo <= r.sim_mean_s <= r.sim_ci.hi
        # the two simulators estimated the same queues
        assert rep.sim_cross["max_mape_pct"] < 10.0

    def test_report_serialises_to_json(self, corpus):
        entries, meta = corpus
        rep = run_differential(entries[:3], simulate=False)
        d = rep.to_dict()
        blob = json.dumps(d)  # must be JSON-clean
        back = json.loads(blob)
        assert back["passed"] is True
        assert back["scalar_vs_vec"]["max_rel_err"] <= 1e-6
        assert len(back["entries"]) == 3
        # the meanfield gate runs even without simulation (analytic-only)
        assert back["meanfield_gate"]["passed"] is True

    def test_meanfield_gate_is_optional(self, corpus):
        entries, _ = corpus
        rep = run_differential(entries[:2], simulate=False, meanfield=False)
        assert rep.meanfield is None and rep.meanfield_passed
        assert rep.to_dict()["meanfield_gate"] is None
        assert rep.passed


class TestMeanFieldGate:
    def test_gate_passes_within_budget(self):
        """Acceptance: the class-aggregated solver reproduces the exact
        per-client equilibrium to <= 5% gated MAPE on the fixed fleets."""
        rep = run_meanfield_gate()
        assert rep["n_specs"] == len(meanfield_gate_specs()) == 2
        assert rep["converged"]
        assert rep["gated_max_mape_pct"] is not None
        assert rep["gated_max_mape_pct"] <= 5.0, rep
        assert rep["passed"]
        json.dumps(rep)  # report must be JSON-clean for VALIDATION.json

    def test_gate_specs_are_deterministic(self):
        a, b = meanfield_gate_specs(), meanfield_gate_specs()
        assert [s.to_dict() for s in a] == [s.to_dict() for s in b]

    def test_budget_is_enforced(self):
        rep = run_meanfield_gate(budget_pct=1e-9)
        assert not rep["passed"]  # real solvers always disagree by > 1e-9 %


class TestCLI:
    def test_no_sim_run_writes_report(self, tmp_path):
        out = tmp_path / "VALIDATION.json"
        rc = validate_cli.main(["--no-sim", "--out", str(out)])
        assert rc == 0
        d = json.loads(out.read_text())
        assert d["passed"] is True
        assert d["golden"]["passed"] is True
        assert d["mape_gate"]["n"] == 0  # not exercised without sim

    def test_regenerate_round_trips_fixture(self, tmp_path):
        out = tmp_path / "corpus.json"
        rc = validate_cli.main(["--regenerate", "--corpus", str(out)])
        assert rc == 0
        assert json.loads(out.read_text()) == json.loads(FIXTURE.read_text())


# ---------------------------------------------------------------------------
# tier-2: the full paper-style gate (slow; `python -m pytest -m validate`)
# ---------------------------------------------------------------------------


@pytest.mark.validate
class TestFullGate:
    def test_full_corpus_mape_gate(self, corpus):
        """Acceptance gate: analytic-vs-simulated MAPE <= 5% over every gated
        corpus scenario (rho <= 0.9), scalar-vs-vectorized <= 1e-6 everywhere,
        golden pins intact — the repo's §4.3 table, enforced."""
        entries, meta = corpus
        rep = run_differential(entries, expected_totals=meta["expected_totals"],
                               base_n=120_000, max_n_factor=6.0)
        # CI reuses this run as the build artifact instead of paying for a
        # second identical full differential via the CLI
        out = os.environ.get("REPRO_VALIDATION_OUT")
        if out:
            Path(out).parent.mkdir(parents=True, exist_ok=True)
            Path(out).write_text(json.dumps(rep.to_dict(), indent=2))
        assert rep.vec_max_rel_err <= 1e-6
        assert all(r.vec_rel_err <= 1e-6 for r in rep.entries)
        assert rep.golden_max_rel_err <= 1e-9
        assert rep.gate.n >= 30
        assert rep.gate.mean_pct <= 5.0, rep.gate
        assert rep.gate.within_10_frac == 1.0, rep.gate
        # tail-percentile gate (ISSUE 5 acceptance): analytic p99 within 10%
        # MAPE of simulated percentile(99) over the tail-gated entries, and
        # fleet_tail matching scalar analytic_tail to <= 1e-6 everywhere
        assert rep.tail.n >= 20
        assert rep.tail.mean_pct <= 10.0, rep.tail
        assert rep.tail_vec_max_rel_err <= 1e-6
        # tail-euler-vec gate (ISSUE 8): the batched exact p99 reproduces the
        # scalar euler inversion to <= 1e-8 on every entry at rho <= 0.95
        assert rep.euler_vec_n >= 30
        assert rep.euler_vec_max_rel_err <= 1e-8, rep.euler_vec_max_rel_err
        assert rep.euler_vec_passed
        assert rep.passed
        # every simulated entry got a CI; gated entries resolve their own error
        for r in rep.entries:
            if r.sim_mape_pct is None:
                continue
            assert r.sim_ci is not None
            assert r.sim_n >= 120_000
        # per-band tables cover the whole ladder including stress (reported,
        # not gated)
        assert set(rep.bands) == set(BAND_ORDER)
