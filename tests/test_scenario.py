"""The unified `Scenario` spec: round-trip, eager named-field validation, and
exact agreement with the kernel-layer functions it wraps (plus sim MAPE at
the tolerance test_simulation_validation already enforces)."""

import json

import numpy as np
import pytest

from repro.core.crossover import bandwidth_crossover
from repro.core.latency import (
    NetworkPath,
    ServiceModel,
    Tier,
    Workload,
    edge_offload_latency,
    on_device_latency,
)
from repro.core.manager import ON_DEVICE, AdaptiveOffloadManager
from repro.core.multitenant import TenantStream, multitenant_edge_latency
from repro.core.scenario import (
    EdgeSpec,
    Scenario,
    ScenarioError,
    analytic,
    crossovers,
    simulate,
)
from repro.serving.gateway import OffloadGateway


def make_scenario(**kw) -> Scenario:
    defaults = dict(
        workload=Workload(10.0, 25_000, 2_000, name="camera"),
        device=Tier("jetson", 0.035, service_model=ServiceModel.DETERMINISTIC),
        network=NetworkPath(20e6 / 8),
        edges=(
            EdgeSpec(Tier("edge-gpu", 0.005, parallelism_k=2)),
            EdgeSpec(
                Tier("edge-llm", 0.008, service_model=ServiceModel.EXPONENTIAL),
                background=(TenantStream(3.0, 0.012, 1e-6, name="bg"),),
                bandwidth_Bps=5e6,
            ),
        ),
        name="fixture",
    )
    defaults.update(kw)
    return Scenario(**defaults)


class TestRoundTrip:
    def test_from_dict_to_dict_roundtrips_exactly(self):
        scn = make_scenario()
        assert Scenario.from_dict(scn.to_dict()) == scn

    def test_dict_is_plain_json(self):
        scn = make_scenario()
        assert Scenario.from_dict(json.loads(json.dumps(scn.to_dict()))) == scn

    def test_roundtrip_preserves_flags_and_models(self):
        scn = make_scenario(return_results=False, allow_unstable=True)
        back = Scenario.from_dict(scn.to_dict())
        assert back == scn
        assert back.edges[1].tier.service_model is ServiceModel.EXPONENTIAL
        assert back.edges[1].bandwidth_Bps == 5e6
        assert back.edges[0].bandwidth_Bps is None

    def test_service_model_accepts_value_strings(self):
        # a spec written by hand with "mm1" strings coerces to the enum
        scn = make_scenario(device=Tier("d", 0.01, service_model="mm1"))
        assert scn.device.service_model is ServiceModel.EXPONENTIAL


class TestValidation:
    @pytest.mark.parametrize(
        "kw,field",
        [
            (dict(workload=Workload(-1.0, 1e4, 1e3)), "workload.arrival_rate"),
            (dict(workload=Workload(1.0, -5.0, 1e3)), "workload.req_bytes"),
            (dict(workload=Workload(1.0, 1e4, -1.0)), "workload.res_bytes"),
            (dict(network=NetworkPath(0.0)), "network.bandwidth_Bps"),
            (dict(device=Tier("d", -0.1)), "device.service_time_s"),
            (dict(device=Tier("d", 0.01, parallelism_k=0)), "device.parallelism_k"),
            (dict(edges=(EdgeSpec(Tier("e", 0.005), bandwidth_Bps=0.0),)),
             "edges[0].bandwidth_Bps"),
            (dict(edges=(EdgeSpec(Tier("e", 0.005),
                                  background=(TenantStream(-2.0, 0.01),)),)),
             "edges[0].background[0].arrival_rate"),
        ],
    )
    def test_invalid_specs_name_the_field(self, kw, field):
        with pytest.raises(ScenarioError) as ei:
            make_scenario(**kw)
        assert ei.value.field == field
        assert field in str(ei.value)

    def test_unstable_device_rejected_eagerly(self):
        # lam >= k*mu: 100 rps into a 20 rps device
        with pytest.raises(ScenarioError) as ei:
            make_scenario(workload=Workload(100.0, 1e4, 1e3))
        assert ei.value.field == "device"
        assert "unstable" in str(ei.value)

    def test_unstable_edge_aggregate_rejected_eagerly(self):
        heavy = (TenantStream(200.0, 0.02),)
        with pytest.raises(ScenarioError) as ei:
            make_scenario(edges=(EdgeSpec(Tier("e", 0.005), background=heavy),))
        assert ei.value.field == "edges[0]"

    def test_allow_unstable_permits_saturation_studies(self):
        scn = make_scenario(workload=Workload(100.0, 1e4, 1e3), allow_unstable=True)
        assert float(analytic(scn)["on_device"].total) == np.inf

    def test_unknown_service_model_string(self):
        d = make_scenario().to_dict()
        d["device"]["service_model"] = "g/g/1"
        with pytest.raises(ScenarioError) as ei:
            Scenario.from_dict(d)
        assert ei.value.field == "device.service_model"

    def test_from_dict_missing_nested_field_names_the_path(self):
        d = make_scenario().to_dict()
        del d["workload"]["arrival_rate"]
        with pytest.raises(ScenarioError) as ei:
            Scenario.from_dict(d)
        assert ei.value.field == "workload.arrival_rate"

    def test_crossovers_tenancy_rejects_unknown_kwargs(self):
        with pytest.raises(TypeError):
            crossovers(make_scenario(), "tenancy", max_tenant=64)  # typo

    def test_direct_construction_with_bad_model_string(self):
        with pytest.raises(ScenarioError) as ei:
            make_scenario(device=Tier("d", 0.01, service_model="bogus"))
        assert ei.value.field == "device.service_model"


class TestAnalyticEqualsKernelLayer:
    def test_on_device_matches_direct_call(self):
        scn = make_scenario()
        assert float(analytic(scn)["on_device"].total) == float(
            on_device_latency(scn.workload, scn.device)
        )

    def test_dedicated_edge_matches_direct_call(self):
        scn = make_scenario()
        direct = float(
            edge_offload_latency(scn.workload, scn.edges[0].tier, scn.network)
        )
        assert float(analytic(scn)["edge[0]"].total) == direct

    def test_multitenant_edge_matches_direct_call(self):
        scn = make_scenario()
        e = scn.edges[1]
        streams = (e.own_stream(scn.workload),) + e.background
        direct = float(
            multitenant_edge_latency(
                scn.workload, e.tier, NetworkPath(e.bandwidth_Bps), streams
            )
        )
        assert float(analytic(scn)["edge[1]"].total) == pytest.approx(direct, rel=1e-12)

    def test_epsilon_background_is_continuous_for_exponential_edge(self):
        # regression: the own stream's mixture variance must be the one the
        # service model implies (s^2 for M/M/1), or an epsilon-rate background
        # tenant discontinuously downgrades the prediction to the M/D/1 form
        exp_edge = Tier("e", 0.02, service_model=ServiceModel.EXPONENTIAL)
        fast_dev = Tier("d", 0.015)  # keeps the 40 rps device queue stable
        dedicated = make_scenario(
            workload=Workload(40.0, 25_000, 2_000),
            device=fast_dev,
            edges=(EdgeSpec(exp_edge),),
        )
        eps = make_scenario(
            workload=Workload(40.0, 25_000, 2_000),
            device=fast_dev,
            edges=(EdgeSpec(exp_edge, background=(TenantStream(1e-6, 0.02, 0.02**2),)),),
        )
        t_ded = float(analytic(dedicated)["edge[0]"].total)
        t_eps = float(analytic(eps)["edge[0]"].total)
        assert t_eps == pytest.approx(t_ded, rel=1e-3)

    def test_best_strategy_is_argmin(self):
        pred = analytic(make_scenario())
        totals = pred.totals()
        assert totals[pred.best_strategy] == min(totals.values())

    def test_return_results_flag_propagates(self):
        with_ret = analytic(make_scenario())
        without = analytic(make_scenario(return_results=False))
        assert float(without["edge[0]"].total) < float(with_ret["edge[0]"].total)


class TestSimulateAgreesWithAnalytic:
    # tolerances mirror tests/test_simulation_validation.py
    def test_offload_pipeline_mape(self):
        scn = make_scenario()
        pred = float(analytic(scn)["edge[0]"].total)
        sim = simulate(scn, "edge[0]", n=120_000, seed=5)
        assert abs(pred - sim.mean) / sim.mean * 100 < 3.0

    def test_on_device_mape(self):
        scn = make_scenario()
        pred = float(analytic(scn)["on_device"].total)
        sim = simulate(scn, "on_device", n=120_000, seed=1)
        assert abs(pred - sim.mean) / sim.mean * 100 < 2.5

    def test_multitenant_mape(self):
        scn = make_scenario()
        pred = float(analytic(scn)["edge[1]"].total)
        sim = simulate(scn, "edge[1]", n=180_000, seed=6)
        assert abs(pred - sim.stream_mean(0)) / sim.stream_mean(0) * 100 < 8.0

    def test_multitenant_mape_heterogeneous_rates(self):
        # regression: a fast background stream (30 rps vs own 8) must span the
        # same horizon as the own stream, or the own tail sees a drained edge
        scn = Scenario(
            workload=Workload(8.0, 50_000, 2_000),
            device=Tier("d", 0.05),
            network=NetworkPath(20e6 / 8),
            edges=(EdgeSpec(Tier("e", 0.02),
                            background=(TenantStream(30.0, 0.02),)),),
        )
        pred = float(analytic(scn)["edge[0]"].total)
        sim = simulate(scn, "edge[0]", n=200_000, seed=7)
        assert abs(pred - sim.stream_mean(0)) / sim.stream_mean(0) * 100 < 8.0

    def test_default_strategy_is_first_edge(self):
        scn = make_scenario()
        a = simulate(scn, n=4_000, seed=3)
        b = simulate(scn, "edge[0]", n=4_000, seed=3)
        np.testing.assert_array_equal(a.latencies, b.latencies)

    def test_unknown_strategy_raises(self):
        with pytest.raises(ScenarioError) as ei:
            simulate(make_scenario(), "edge[9]", n=1000)
        assert ei.value.field == "strategy"

    def test_fractional_parallelism_refused_not_rounded(self):
        # analytic() folds k=2.5 into k*mu; simulating 2 servers would be a
        # structurally different system, so simulate() must refuse
        scn = make_scenario(edges=(EdgeSpec(Tier("e", 0.005, parallelism_k=2.5)),))
        with pytest.raises(ScenarioError) as ei:
            simulate(scn, "edge[0]", n=1000)
        assert ei.value.field == "edges[0].tier.parallelism_k"
        assert np.isfinite(float(analytic(scn)["edge[0]"].total))  # analytic fine


class TestSweepAndCrossovers:
    def test_sweep_sets_field_and_allows_instability(self):
        scn = make_scenario()
        lams = [1.0, 10.0, 1000.0]  # 1000 rps saturates everything
        fam = scn.sweep("workload.arrival_rate", lams)
        assert [s.workload.arrival_rate for s in fam] == lams
        assert all(s.allow_unstable for s in fam)
        assert float(analytic(fam[-1])["on_device"].total) == np.inf

    def test_sweep_nested_edge_field(self):
        scn = make_scenario()
        fam = scn.sweep("edges[0].tier.service_time_s", [0.001, 0.002])
        assert [s.edges[0].tier.service_time_s for s in fam] == [0.001, 0.002]
        # untouched fields intact
        assert fam[0].edges[1] == scn.edges[1]

    def test_replaced_unknown_field_raises(self):
        with pytest.raises(ScenarioError):
            make_scenario().replaced("edges[0].tier.nonsense", 1.0)

    def test_bandwidth_crossover_matches_kernel_solver(self):
        scn = make_scenario(edges=(EdgeSpec(Tier("e", 0.005, parallelism_k=2)),))
        c = crossovers(scn, "bandwidth")
        direct = bandwidth_crossover(scn.workload, scn.device, scn.edges[0].tier)
        assert c.value == direct.value
        assert c.offload_wins_above is True

    def test_bandwidth_crossover_respects_background_tenants(self):
        # the crossover must agree with analytic() on the SAME spec: a loaded
        # edge needs more bandwidth before offloading pays than a dedicated one
        dedicated = make_scenario(edges=(EdgeSpec(Tier("e", 0.018)),))
        loaded = make_scenario(
            edges=(EdgeSpec(Tier("e", 0.018),
                            background=(TenantStream(40.0, 0.018),)),),
            allow_unstable=True,
        )
        c_ded = crossovers(dedicated, "bandwidth")
        c_load = crossovers(loaded, "bandwidth")
        if c_load.value is not None:
            assert c_load.value > c_ded.value
            # and on either side of ITS crossover, analytic agrees
            hi = loaded.replaced("network.bandwidth_Bps", c_load.value * 2)
            assert analytic(hi).best_strategy == "edge[0]"
        lo = loaded.replaced("network.bandwidth_Bps",
                             (c_load.value or c_ded.value) * 0.5)
        assert analytic(lo).best_strategy == "on_device"

    def test_tenancy_crossover_returns_tenant_count(self):
        scn = Scenario(
            workload=Workload(2.0, 40_000, 4_000),
            device=Tier("d", 0.060),
            network=NetworkPath(1.25e6),
            edges=(EdgeSpec(Tier("e", 0.012)),),
        )
        c = crossovers(scn, "tenancy")
        assert c.value is not None and c.value > 1
        # homogeneous case matches the kernel solver's [template]*m exactly
        from repro.core.crossover import tenancy_crossover

        m_kernel = tenancy_crossover(
            scn.workload, scn.device, scn.edges[0].tier, scn.network,
            scn.edges[0].own_stream(scn.workload),
        )
        assert c.value == float(m_kernel)

    def test_tenancy_crossover_keeps_own_stream_with_background(self):
        # regression: with a light background template, the own 10 rps stream
        # must stay in the mixture — m* is far smaller than template-only math
        own_only = Scenario(
            workload=Workload(10.0, 40_000, 4_000),
            device=Tier("d", 0.060),
            network=NetworkPath(2.5e6),
            edges=(EdgeSpec(Tier("e", 0.012)),),
        )
        light_bg = own_only.replaced(
            "edges[0].background", (TenantStream(0.5, 0.012),)
        )
        m_own = crossovers(own_only, "tenancy").value
        m_bg = crossovers(light_bg, "tenancy").value
        assert m_bg is not None
        # a 0.5 rps template on top of the own 10 rps stream means MORE
        # copies fit than 10 rps copies, but nowhere near template-only math
        assert m_own < m_bg < 40 * m_own

    def test_unknown_axis_raises(self):
        with pytest.raises(ScenarioError):
            crossovers(make_scenario(), "altitude")


class TestManagerAndGatewayFromSpec:
    def test_manager_decides_from_spec_derived_inputs(self):
        scn = make_scenario()
        mgr = scn.manager()
        d = mgr.decide(scn.workload, scn.snapshot(), scn.edge_states())
        # offloading clearly wins at 20 Mbps; edge[1] has the faster override
        assert d.strategy == "offload" and d.edge_index == 1
        # the manager's dedicated-edge prediction agrees with analytic() exactly
        assert d.t_edges[0] == float(analytic(scn)["edge[0]"].total)

    def test_edge_states_aggregate_background(self):
        scn = make_scenario()
        st = scn.edge_states()[1]
        # aggregate = own 10 rps + background 3 rps
        assert st.arrival_rate == pytest.approx(13.0)
        assert st.service_var > 0  # mixture variance, not own variance
        assert st.bandwidth_Bps == 5e6

    def test_manager_falls_back_to_device_when_saturated(self):
        scn = make_scenario(
            edges=(EdgeSpec(Tier("e", 0.005),
                            background=(TenantStream(500.0, 0.005),)),),
            allow_unstable=True,
        )
        d = scn.manager().decide(scn.workload, scn.snapshot(), scn.edge_states())
        assert d.edge_index == ON_DEVICE

    def test_manager_general_device_uses_variance(self):
        # regression: GENERAL device tiers must use the M/G/1 form (variance
        # raises the wait above the M/D/1 prediction)
        lam = 10.0
        base = Tier("d", 0.035, service_model=ServiceModel.DETERMINISTIC)
        gen = Tier("d", 0.035, service_model=ServiceModel.GENERAL, service_var=0.002)
        t_det = AdaptiveOffloadManager(base)._predict_device(lam)
        t_gen = AdaptiveOffloadManager(gen)._predict_device(lam)
        assert t_gen > t_det

    def test_manager_survives_link_outage_snapshot(self):
        # a MEASURED bandwidth of 0 (outage) is not a config error: Algorithm 1
        # must fall back to on-device, not crash the serving loop
        from repro.core.telemetry import TelemetrySnapshot

        scn = make_scenario(edges=(EdgeSpec(Tier("e", 0.005, parallelism_k=2)),))
        dead = TelemetrySnapshot(time_s=0.0, lam_dev=10.0, bandwidth_Bps=0.0)
        d = scn.manager().decide(scn.workload, dead, scn.edge_states())
        assert d.edge_index == ON_DEVICE
        assert d.t_edges == (np.inf,)

    def test_manager_handles_zero_res_bytes(self):
        # res_bytes=0 passes Scenario validation and analytic(); the manager
        # must not ZeroDivisionError on the degenerate return leg
        scn = make_scenario(
            workload=Workload(10.0, 25_000, 0.0),
            edges=(EdgeSpec(Tier("e", 0.005, parallelism_k=2)),),
            return_results=False,
        )
        d = scn.manager().decide(scn.workload, scn.snapshot(), scn.edge_states())
        assert np.isfinite(d.predicted_latency_s)

    def test_manager_honours_return_results(self):
        # regression: results-consumed-at-edge specs (big res_bytes, tiny
        # req_bytes) must not make Algorithm 1 model the dropped return leg
        scn = Scenario(
            workload=Workload(10.0, 5_000, 400_000),
            device=Tier("dev", 0.030),
            network=NetworkPath(20e6 / 8),
            edges=(EdgeSpec(Tier("edge", 0.004, parallelism_k=2)),),
            return_results=False,
        )
        d = scn.manager().decide(scn.workload, scn.snapshot(), scn.edge_states())
        assert d.edge_index == 0  # agrees with analytic()
        assert analytic(scn).best_strategy == "edge[0]"
        gw = OffloadGateway.from_scenario(scn)
        assert gw.manager.return_results is False

    def test_manager_zero_bandwidth_override_rejected(self):
        # regression: a 0.0 per-edge bandwidth must error, not silently fall
        # back to the device-level estimate (the old `or` treated 0 as unset)
        from repro.core.manager import EdgeServerState

        mgr = AdaptiveOffloadManager(Tier("d", 0.035))
        bad = EdgeServerState("e", 200.0, 10.0, 0.005, bandwidth_Bps=0.0)
        with pytest.raises(ValueError):
            mgr._predict_edge(bad, Workload(10.0, 1e4, 1e3), 10.0, 2.5e6)

    def test_gateway_carries_implied_service_variance(self):
        # regression: an EXPONENTIAL edge tier must reach the gateway's M/G/1
        # inputs as var=s^2, not 0 — otherwise the gateway halves the edge
        # wait near saturation relative to analytic() on the same spec
        scn = make_scenario(
            edges=(EdgeSpec(Tier("e", 0.02, service_model=ServiceModel.EXPONENTIAL)),),
        )
        gw = OffloadGateway.from_scenario(scn)
        assert gw.edges[0].service_var_s == pytest.approx(0.02**2)
        assert gw.edges[0].state().service_var == pytest.approx(0.02**2)

    def test_gateway_from_scenario(self):
        scn = make_scenario()
        gw = OffloadGateway.from_scenario(scn, epoch_s=1.0)
        assert [h.name for h in gw.edges] == ["edge-gpu", "edge-llm"]
        assert gw.edges[1].background_rate == pytest.approx(3.0)
        assert gw.edges[1].bandwidth_Bps == 5e6
        for _ in range(3):
            gw.observe_bandwidth(20e6 / 8)
        for t in np.arange(0.0, 1.0, 0.1):
            gw.observe_arrival(float(t))
        d = gw.decide(now=1.0)
        assert d.strategy in ("offload", "on_device")
