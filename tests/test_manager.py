"""Algorithm 1 (adaptive offloading manager) + crossover solvers + telemetry."""

import numpy as np
import pytest

from repro.core.crossover import (
    arrival_rate_crossovers,
    bandwidth_crossover,
    tenancy_crossover,
)
from repro.core.latency import NetworkPath, ServiceModel, Tier, Workload
from repro.core.manager import ON_DEVICE, AdaptiveOffloadManager, EdgeServerState
from repro.core.multitenant import TenantStream
from repro.core.service_time import fit_parallelism, from_profile, from_roofline
from repro.core.telemetry import (
    EwmaEstimator,
    SlidingRateEstimator,
    TelemetrySnapshot,
    WindowedMoments,
)

WL = Workload(arrival_rate=10.0, req_bytes=25_000, res_bytes=2_000)
DEV = Tier("dev", 0.035, service_model=ServiceModel.DETERMINISTIC)


def snap(lam=10.0, bw=2.5e6):
    return TelemetrySnapshot(time_s=0.0, lam_dev=lam, bandwidth_Bps=bw)


def edge_state(name="e0", s=0.005, lam=10.0, var=0.0):
    return EdgeServerState(
        name=name, service_rate=1.0 / s, arrival_rate=lam, service_time_s=s, service_var=var
    )


class TestAlgorithm1:
    def test_offloads_on_fast_network(self):
        mgr = AdaptiveOffloadManager(DEV)
        d = mgr.decide(WL, snap(bw=2.5e6), [edge_state()])  # 20 Mbps
        assert d.strategy == "offload"

    def test_local_on_slow_network(self):
        """Paper Fig. 6: at 2 Mbps offloading loses to local processing."""
        mgr = AdaptiveOffloadManager(DEV)
        d = mgr.decide(WL, snap(bw=2e6 / 8), [edge_state()])
        assert d.strategy == "on_device"
        assert d.t_dev < min(d.t_edges)

    def test_network_dynamics_case_study(self):
        """Fig. 6 sequence: 20 -> 10 -> 2 -> 20 Mbps."""
        mgr = AdaptiveOffloadManager(DEV)
        seq = [2.5e6, 1.25e6, 0.25e6, 2.5e6]
        decisions = [mgr.decide(WL, snap(bw=b), [edge_state()]).strategy for b in seq]
        assert decisions == ["offload", "offload", "on_device", "offload"]

    def test_multitenant_case_study(self):
        """Fig. 7: route to least-loaded edge, then to device when both load up."""
        mgr = AdaptiveOffloadManager(Tier("dev", 0.04))
        wl = Workload(10.0, 50_000, 5_000)
        e1 = lambda lam: edge_state("E1", 0.015, lam)
        e2 = lambda lam: edge_state("E2", 0.015, lam)
        d0 = mgr.decide(wl, snap(bw=2.5e6), [e1(10 + 10), e2(30 + 0)])
        assert d0.edge_index == 0  # E1 less loaded
        d1 = mgr.decide(wl, snap(bw=2.5e6), [e1(50 + 10), e2(30 + 0)])
        assert d1.edge_index == 1  # load shifted -> E2
        d2 = mgr.decide(wl, snap(bw=2.5e6), [e1(60), e2(62)])
        assert d2.edge_index == ON_DEVICE  # both saturated -> local

    def test_saturated_edges_never_chosen(self):
        mgr = AdaptiveOffloadManager(DEV)
        d = mgr.decide(WL, snap(), [edge_state(lam=1000.0)])  # rho >> 1
        assert d.strategy == "on_device"

    def test_hysteresis_damps_flapping(self):
        # operating point right at the crossover: without hysteresis the
        # manager flips with tiny bandwidth noise; with it, it holds.
        rng = np.random.default_rng(0)
        bws = 0.45e6 + rng.normal(0, 3e4, size=50)

        def run(h):
            mgr = AdaptiveOffloadManager(DEV, hysteresis=h)
            for b in bws:
                mgr.decide(WL, snap(bw=float(b)), [edge_state()])
            return mgr.switches

        assert run(0.15) <= run(0.0)

    def test_history_and_epochs(self):
        mgr = AdaptiveOffloadManager(DEV)
        for i in range(5):
            mgr.decide(WL, snap(), [edge_state()])
        assert len(mgr.history) == 5
        assert [d.epoch for d in mgr.history] == list(range(5))


class TestCrossovers:
    def test_bandwidth_crossover_direction(self):
        c = bandwidth_crossover(WL, DEV, Tier("e", 0.005), lo_Bps=1e4, hi_Bps=1e9)
        assert c.value is not None
        assert c.offload_wins_above is True
        # verify by evaluation on both sides
        from repro.core.latency import edge_offload_latency, on_device_latency

        lo = NetworkPath(c.value * 0.5)
        hi = NetworkPath(c.value * 2.0)
        assert float(edge_offload_latency(WL, Tier("e", 0.005), hi)) < float(
            on_device_latency(WL, DEV)
        )

    def test_rate_crossover_exists_for_paper_like_setup(self):
        """Fig. 5b: at high enough bandwidth, device wins at low RPS and
        edge wins past a crossover."""
        wl = Workload(1.0, 30_000, 3_000)
        dev = Tier("d", 0.015)
        edge = Tier("e", 0.004, parallelism_k=4)
        net = NetworkPath(2.5e6)  # 20 Mbps
        xs = arrival_rate_crossovers(wl, dev, edge, net)
        assert len(xs) >= 1

    def test_tenancy_crossover(self):
        """Fig. 5c-style: enough co-located tenants push offloading above local."""
        wl = Workload(2.0, 40_000, 4_000)
        dev = Tier("d", 0.060)
        edge = Tier("e", 0.012)
        net = NetworkPath(1.25e6)  # 10 Mbps
        m = tenancy_crossover(wl, dev, edge, net, TenantStream(2.0, 0.012))
        assert m is not None and m > 1


class TestServiceTime:
    def test_from_profile(self):
        est = from_profile([0.01, 0.012, 0.011, 0.013])
        assert est.mean_s == pytest.approx(0.0115)
        assert est.var_s > 0

    def test_from_roofline_takes_binding_term(self):
        est = from_roofline(1e12, 1e9, peak_flops=197e12, hbm_bw=819e9)
        assert est.mean_s == pytest.approx(max(1e12 / 197e12, 1e9 / 819e9))

    def test_fit_parallelism_recovers_k(self):
        """Generate response times from a known k, recover it (paper §4.1)."""
        from repro.core.latency import Tier as T, proc_wait

        k_true, s = 4.0, 0.02
        tier = T("t", s, parallelism_k=k_true)
        lam = np.linspace(1.0, 150.0, 24)
        obs = np.asarray(proc_wait(tier, lam)) + s
        k_hat = fit_parallelism(lam, obs, s)
        assert k_hat == pytest.approx(k_true, rel=0.05)


class TestTelemetry:
    def test_sliding_rate(self):
        est = SlidingRateEstimator(window_s=10.0)
        for t in np.arange(0, 10, 0.1):
            est.record(float(t))
        assert est.rate() == pytest.approx(10.0, rel=0.1)

    def test_rate_evicts_old(self):
        est = SlidingRateEstimator(window_s=1.0)
        est.record(0.0)
        est.record(100.0)
        assert est.rate(100.0) == pytest.approx(1.0, rel=0.01)

    def test_ewma(self):
        est = EwmaEstimator(alpha=0.5, initial=10.0)
        est.update(20.0)
        assert est.value == pytest.approx(15.0)

    def test_windowed_moments(self):
        m = WindowedMoments(maxlen=4)
        for x in (1.0, 2.0, 3.0, 4.0, 5.0):
            m.record(x)
        assert m.mean == pytest.approx(3.5)  # last 4
        assert m.var > 0
