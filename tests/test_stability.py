"""Edge-of-stability contracts for the queueing closed forms.

The paper's models are only meaningful strictly inside the stability region;
these tests pin the behaviour AT the boundary: waits blow up finitely and
monotonically as rho -> 1-, every path (scalar math, numpy-broadcast, jitted
vectorized) reports inf at rho >= 1 for permissive specs, and eager Scenario
validation raises ScenarioError naming the offending field identically
whether the spec is later consumed by the scalar or the vectorized engine.
"""

import math

import jax.experimental
import numpy as np
import pytest

from repro.core import latency as L
from repro.core import queueing as Q
from repro.core.latency import NetworkPath, ServiceModel, Tier, Workload
from repro.core.scenario import EdgeSpec, Scenario, ScenarioError
from repro.fleet import ScenarioBatch, fleet_analytic
from repro.fleet.analytic_vec import (
    md1_wait_vec,
    mg1_wait_vec,
    mm1_wait_vec,
    mmk_wait_erlang_vec,
)

MU = 10.0
# rho ladder approaching 1 from below; float64 still resolves mu - lam here
RHOS = 1.0 - np.geomspace(1e-1, 1e-9, 17)


class TestBlowupFiniteAndMonotone:
    @pytest.mark.parametrize("wait", [Q.mm1_wait, Q.md1_wait,
                                      lambda lam, mu: Q.mg1_wait(lam, mu, 0.02)])
    def test_scalar_forms(self, wait):
        vals = [wait(rho * MU, MU) for rho in RHOS]
        assert all(math.isfinite(v) for v in vals), "rho < 1 must stay finite"
        assert all(b > a for a, b in zip(vals, vals[1:])), "blowup must be monotone"
        assert vals[-1] > 1e6  # genuinely blowing up, not saturating

    def test_numpy_broadcast_forms(self):
        lam = RHOS * MU
        for w in (L.mm1_wait(lam, MU), L.md1_wait(lam, MU),
                  L.mg1_wait(lam, MU, 0.02)):
            w = np.asarray(w)
            assert np.all(np.isfinite(w))
            assert np.all(np.diff(w) > 0)

    def test_vectorized_jax_forms(self):
        # the vec primitives are documented to run inside a scoped x64
        # context (fleet_analytic provides it); replicate that here
        lam = RHOS * MU
        with jax.experimental.enable_x64():
            waits = [np.asarray(w) for w in (
                mm1_wait_vec(lam, MU), md1_wait_vec(lam, MU),
                mg1_wait_vec(lam, MU, 0.02))]
        for w in waits:
            assert np.all(np.isfinite(w))
            assert np.all(np.diff(w) > 0)

    def test_erlang_c_exact_and_vectorized(self):
        k = 4
        lam = RHOS * k * MU
        exact = np.array([Q.mmk_wait_erlang(la, MU, k) for la in lam])
        vec = np.asarray(mmk_wait_erlang_vec(lam, MU, float(k)))
        assert np.all(np.isfinite(exact)) and np.all(np.diff(exact) > 0)
        np.testing.assert_allclose(vec, exact, rtol=1e-9)

    def test_scalar_and_vectorized_blowups_match_pointwise(self):
        lam = RHOS * MU
        scalar = np.array([Q.mm1_wait(la, MU) for la in lam])
        with jax.experimental.enable_x64():
            vec = np.asarray(mm1_wait_vec(lam, MU))
        np.testing.assert_allclose(vec, scalar, rtol=1e-12)


class TestAtAndPastSaturation:
    @pytest.mark.parametrize("rho", [1.0, 1.0 + 1e-12, 1.5, 10.0])
    def test_every_path_reports_inf(self, rho):
        lam = rho * MU
        assert Q.mm1_wait(lam, MU) == math.inf
        assert Q.md1_wait(lam, MU) == math.inf
        assert Q.mg1_wait(lam, MU, 0.02) == math.inf
        assert Q.mmk_wait_erlang(lam * 4, MU, 4) == math.inf  # lam >= k*mu
        assert np.asarray(L.mm1_wait(lam, MU)) == np.inf
        with jax.experimental.enable_x64():
            assert np.asarray(mm1_wait_vec(np.array([lam]), MU))[0] == np.inf
            assert np.asarray(md1_wait_vec(np.array([lam]), MU))[0] == np.inf
            assert np.asarray(mg1_wait_vec(np.array([lam]), MU, 0.02))[0] == np.inf

    def test_negative_arrival_is_inf_not_negative_wait(self):
        assert Q.mm1_wait(-1.0, MU) == math.inf
        with jax.experimental.enable_x64():
            assert np.asarray(mm1_wait_vec(np.array([-1.0]), MU))[0] == np.inf


def _spec(lam: float, *, allow_unstable: bool = False, **kw) -> Scenario:
    defaults = dict(
        workload=Workload(arrival_rate=lam, req_bytes=30_000, res_bytes=1_000),
        device=Tier("dev", 0.150),
        edges=(EdgeSpec(Tier("edge", 0.028)),),
        network=NetworkPath(2.5e6),
        allow_unstable=allow_unstable,
    )
    defaults.update(kw)
    return Scenario(**defaults)


class TestScenarioValidationConsistency:
    def test_device_saturation_raises_named_field(self):
        # device k*mu = 1/0.15 = 6.67: rho >= 1 must raise, not return inf
        with pytest.raises(ScenarioError) as ei:
            _spec(7.0)
        assert ei.value.field == "device"
        # just inside the boundary constructs fine
        _spec(6.6)

    def test_edge_saturation_raises_named_field(self):
        with pytest.raises(ScenarioError) as ei:
            _spec(40.0, device=Tier("dev", 0.01), network=NetworkPath(2.5e7))
        assert ei.value.field == "edges[0]"

    def test_nic_saturation_raises_named_field(self):
        with pytest.raises(ScenarioError) as ei:
            _spec(5.0, network=NetworkPath(30_000 * 4.0))  # lam >= B/D_req
        assert ei.value.field == "network.bandwidth_Bps"

    def test_scalar_and_vectorized_consume_the_same_validation(self):
        """rho >= 1 raises identically regardless of downstream engine: the
        vectorized packers take validated Scenarios, so the SAME ScenarioError
        fires before either path can run."""
        with pytest.raises(ScenarioError):
            ScenarioBatch.from_scenarios([_spec(7.0)])
        with pytest.raises(ScenarioError):
            ScenarioBatch.from_sweep(_spec(7.0), {"workload.arrival_rate": [1.0]})

    def test_allow_unstable_yields_inf_consistently_across_paths(self):
        """With allow_unstable=True both engines agree: inf exactly where the
        spec saturates, finite elsewhere — no NaNs, no negatives."""
        base = _spec(1.0, allow_unstable=True)
        lams = [1.0, 6.0, 6.67, 7.5, 40.0, 120.0]
        scns = base.sweep("workload.arrival_rate", lams)
        batch = ScenarioBatch.from_scenarios(scns)
        pred = fleet_analytic(batch)
        for i, scn in enumerate(scns):
            scalar = scn.analytic().totals()
            vec = pred.totals(i)
            for key, v in scalar.items():
                vv = vec[key]
                assert not (np.isnan(v) or np.isnan(vv)), (key, v, vv)
                if np.isinf(v):
                    assert np.isinf(vv), (key, v, vv)
                else:
                    assert v >= 0 and vv == pytest.approx(v, rel=1e-9)
        # the sweep genuinely crossed saturation on both paths
        assert np.isinf(pred.t_dev).any() and np.isfinite(pred.t_dev).any()

    def test_fractional_k_refused_by_both_simulators(self):
        scn = _spec(1.0, device=Tier("dev", 0.15, parallelism_k=1.5))
        with pytest.raises(ScenarioError, match="parallelism"):
            scn.simulate("on_device", n=100)
        from repro.fleet import simulate_fleet
        with pytest.raises(ValueError, match="fractional"):
            simulate_fleet(ScenarioBatch.from_scenarios([scn]), "on_device", n=100)


class TestServiceModelBoundary:
    def test_general_tier_with_zero_var_matches_deterministic(self):
        # GENERAL with Var[s]=0 must equal the M/D/1 prediction exactly
        det = _spec(3.0, device=Tier("d", 0.15)).analytic().totals()["on_device"]
        gen = _spec(3.0, device=Tier(
            "d", 0.15, service_model=ServiceModel.GENERAL, service_var=0.0,
        )).analytic().totals()["on_device"]
        assert gen == pytest.approx(det, rel=1e-12)

    def test_general_tier_with_exponential_var_matches_mm1(self):
        s = 0.15
        exp = _spec(3.0, device=Tier(
            "d", s, service_model=ServiceModel.EXPONENTIAL,
        )).analytic().totals()["on_device"]
        gen = _spec(3.0, device=Tier(
            "d", s, service_model=ServiceModel.GENERAL, service_var=s * s,
        )).analytic().totals()["on_device"]
        assert gen == pytest.approx(exp, rel=1e-12)
