"""Contracts for the SLO-constrained provisioning solver.

Small fleets on purpose: every feasibility probe is a full equilibrium
solve, and each distinct (n_clients, n_edges) shape JIT-compiles once — the
tests below stay inside N in {4, 8}, E in {1..3} so the whole module reuses
a handful of compilations. The asymptote tail engine drives most cases (the
solver logic under test is identical); one case runs the exact euler engine
end to end.
"""

import json

import pytest

from repro.core.latency import NetworkPath, Tier, Workload
from repro.core.scenario import EdgeSpec, Scenario, ScenarioError
from repro.fleet import solve_equilibrium
from repro.plan import ProvisionPlan, ProvisionSpace, provision

BASE = Scenario(
    workload=Workload(arrival_rate=4.0, req_bytes=30_000, res_bytes=1_000,
                      name="plan-wl"),
    device=Tier("cpu-only", 0.08),
    edges=(EdgeSpec(Tier("edge", 0.04)),),
    network=NetworkPath(2.0e6),
    name="plan-base",
)
SPACE = ProvisionSpace(
    base=BASE,
    tiers=(Tier("slow", 0.040), Tier("fast", 0.015)),
    max_edges=3,
    bandwidths_Bps=(1.0e6, 2.0e6),
    name="plan-space",
)
Q = 0.99


def _feasible(space, n_edges, ti, bi, n_clients, slo_s, tail_method="asymptote"):
    eq = solve_equilibrium(space.cluster_spec(n_edges, ti, bi, n_clients),
                           slo_quantile=Q, tail_method=tail_method)
    return eq.meets_slo(slo_s)


def _grid_min(space, n_clients, slo_s):
    """Exhaustive lexicographic minimum over the whole (E, tier, bw) grid."""
    for e in range(1, space.max_edges + 1):
        for ti in range(len(space.tiers)):
            for bi in range(len(space.bandwidths_Bps)):
                if _feasible(space, e, ti, bi, n_clients, slo_s):
                    return (e, ti, bi)
    return None


class TestSolver:
    def test_agrees_with_brute_force_grid(self):
        """The bisection search must land on the exhaustive grid's
        lexicographic minimum. This slo admits (2, slow, hi) — two slow
        edges — so a non-lexicographic 'cheapest' notion would diverge."""
        plan = provision(SPACE, 4, 0.16, q=Q, tail_method="asymptote")
        assert plan is not None
        got = (plan.n_edges, plan.tier_index, plan.bandwidth_index)
        assert got == _grid_min(SPACE, 4, 0.16)
        assert plan.max_latency_s <= 0.16
        assert plan.evaluations <= 2 * 3 * 3  # bisection, not the full grid

    def test_plan_is_component_wise_minimal(self):
        plan = provision(SPACE, 8, 0.10, q=Q, tail_method="asymptote")
        assert plan is not None
        e, ti, bi = plan.n_edges, plan.tier_index, plan.bandwidth_index
        best_t = len(SPACE.tiers) - 1
        best_b = len(SPACE.bandwidths_Bps) - 1
        if e > 1:
            assert not _feasible(SPACE, e - 1, best_t, best_b, 8, 0.10)
        if ti > 0:
            assert not _feasible(SPACE, e, ti - 1, best_b, 8, 0.10)
        if bi > 0:
            assert not _feasible(SPACE, e, ti, bi - 1, 8, 0.10)
        # and it genuinely needed more than the floor somewhere
        assert (e, ti, bi) != (1, 0, 0)

    def test_monotone_in_n_clients(self):
        small = provision(SPACE, 4, 0.10, q=Q, tail_method="asymptote")
        large = provision(SPACE, 8, 0.10, q=Q, tail_method="asymptote")
        assert small is not None and large is not None
        assert (small.n_edges, small.tier_index, small.bandwidth_index) <= \
            (large.n_edges, large.tier_index, large.bandwidth_index)
        assert large.n_edges > small.n_edges  # sized to actually scale

    def test_monotone_in_budget(self):
        loose = provision(SPACE, 8, 0.16, q=Q, tail_method="asymptote")
        tight = provision(SPACE, 8, 0.10, q=Q, tail_method="asymptote")
        assert loose is not None and tight is not None
        assert (loose.n_edges, loose.tier_index, loose.bandwidth_index) <= \
            (tight.n_edges, tight.tier_index, tight.bandwidth_index)
        assert tight.n_edges > loose.n_edges

    def test_trivial_budget_returns_cheapest_corner(self):
        plan = provision(SPACE, 4, 10.0, q=Q, tail_method="asymptote")
        assert plan is not None
        assert (plan.n_edges, plan.tier_index, plan.bandwidth_index) == (1, 0, 0)
        assert plan.evaluations <= 4

    def test_impossible_budget_returns_none(self):
        # below the fast tier's bare service time: no deployment can win
        assert provision(SPACE, 4, 1e-3, q=Q, tail_method="asymptote") is None

    def test_euler_engine_end_to_end(self):
        plan = provision(SPACE, 4, 0.16, q=Q, tail_method="euler")
        assert plan is not None
        assert plan.tail_method == "euler"
        assert plan.max_latency_s <= 0.16
        assert _feasible(SPACE, plan.n_edges, plan.tier_index,
                         plan.bandwidth_index, 4, 0.16, tail_method="euler")

    def test_slack_and_diagnostics(self):
        plan = provision(SPACE, 8, 0.10, q=Q, tail_method="asymptote")
        assert plan.slack_s == pytest.approx(0.10 - plan.max_latency_s)
        assert plan.slack_s >= 0.0
        assert sum(plan.counts.values()) == 8
        assert len(plan.rho_edges) == plan.n_edges
        assert all(0.0 <= r < 1.0 for r in plan.rho_edges)
        assert plan.mean_latency_s <= plan.max_latency_s * (1.0 + 1e-12)


class TestSerialisation:
    def test_plan_round_trips_through_json(self):
        plan = provision(SPACE, 4, 0.16, q=Q, tail_method="asymptote")
        rt = ProvisionPlan.from_dict(json.loads(json.dumps(plan.to_dict())))
        assert rt == plan

    def test_space_round_trips_through_json(self):
        rt = ProvisionSpace.from_dict(json.loads(json.dumps(SPACE.to_dict())))
        assert rt == SPACE
        # and the round-tripped space instantiates identical candidates
        assert rt.cluster_spec(2, 1, 0, 4) == SPACE.cluster_spec(2, 1, 0, 4)

    def test_plan_from_dict_missing_field_raises(self):
        plan = provision(SPACE, 4, 0.16, q=Q, tail_method="asymptote")
        d = plan.to_dict()
        del d["n_edges"]
        with pytest.raises(ScenarioError):
            ProvisionPlan.from_dict(d)


class TestValidation:
    def test_tiers_must_be_ordered_slow_to_fast(self):
        with pytest.raises(ScenarioError, match="slowest to fastest"):
            ProvisionSpace(base=BASE, tiers=(Tier("fast", 0.015),
                                             Tier("slow", 0.040)),
                           max_edges=2, bandwidths_Bps=(1e6,))

    def test_bandwidths_must_ascend(self):
        with pytest.raises(ScenarioError, match="ascending"):
            ProvisionSpace(base=BASE, tiers=(Tier("t", 0.02),),
                           max_edges=2, bandwidths_Bps=(2e6, 1e6))

    def test_template_must_have_one_edge(self):
        with pytest.raises(ScenarioError, match="exactly one edge"):
            ProvisionSpace(base=Scenario(workload=BASE.workload,
                                         device=BASE.device,
                                         network=BASE.network, edges=()),
                           tiers=(Tier("t", 0.02),), max_edges=2,
                           bandwidths_Bps=(1e6,))

    def test_bad_solver_inputs_rejected(self):
        with pytest.raises(ScenarioError, match="n_clients"):
            provision(SPACE, 0, 0.1)
        with pytest.raises(ScenarioError, match="slo_s"):
            provision(SPACE, 4, 0.0)
        with pytest.raises(ScenarioError, match="quantile"):
            provision(SPACE, 4, 0.1, q=1.5)

    def test_parallelism_breaks_service_time_ties(self):
        # s/k ordering: a 2-wide slow tier can outrank a narrower faster one
        sp = ProvisionSpace(base=BASE,
                            tiers=(Tier("one-wide", 0.030),
                                   Tier("two-wide", 0.040, parallelism_k=2.0)),
                            max_edges=2, bandwidths_Bps=(1e6,))
        assert sp.tiers[1].parallelism_k == 2.0
