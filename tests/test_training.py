"""Optimizers, gradient compression, checkpointing, trainer resume."""

import numpy as np

import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint.checkpointer import Checkpointer, load_pytree, save_pytree
from repro.configs import get_config
from repro.training import optimizer as opt
from repro.training.train_loop import TrainConfig, Trainer

KEY = jax.random.PRNGKey(0)


class TestAdamW:
    def test_converges_on_quadratic(self):
        params = {"w": jnp.asarray([4.0, -3.0])}
        state = opt.adamw_init(params)
        cfg = opt.AdamWConfig(lr=0.1, weight_decay=0.0)
        for _ in range(150):
            grads = {"w": 2 * params["w"]}
            params, state, _ = opt.adamw_update(cfg, grads, state, params)
        assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2

    def test_clip_by_global_norm(self):
        grads = {"a": jnp.asarray([3.0, 4.0])}  # norm 5
        clipped, gn = opt.clip_by_global_norm(grads, 1.0)
        assert float(gn) == pytest.approx(5.0)
        assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0)

    def test_cosine_schedule(self):
        lr = opt.cosine_schedule(1.0, warmup=10, total=100)
        assert float(lr(0)) == pytest.approx(0.0)
        assert float(lr(10)) == pytest.approx(1.0)
        assert float(lr(100)) == pytest.approx(0.1, rel=0.01)


class TestAdafactor:
    def test_converges_on_quadratic(self):
        params = {"w": jnp.full((256, 256), 2.0)}  # factored leaf
        state = opt.adafactor_init(params)
        cfg = opt.AdafactorConfig(lr=0.3)
        for _ in range(120):
            grads = {"w": 2 * params["w"]}
            params, state, _ = opt.adafactor_update(cfg, grads, state, params)
        assert float(jnp.mean(jnp.abs(params["w"]))) < 0.05

    def test_state_is_factored(self):
        params = {"big": jnp.zeros((256, 512)), "small": jnp.zeros((8,))}
        state = opt.adafactor_init(params)
        assert state["factors"]["big"]["vr"].shape == (256,)
        assert state["factors"]["big"]["vc"].shape == (512,)
        assert state["factors"]["small"]["v"].shape == (8,)

    def test_memory_footprint_tiny_vs_adamw(self):
        from repro.models.params import tree_bytes

        params = {"w": jnp.zeros((1024, 1024), jnp.bfloat16)}
        af = opt.adafactor_init(params)
        aw = opt.adamw_init(params)
        assert tree_bytes(af) < tree_bytes(aw) / 100


class TestGradCompression:
    def test_roundtrip_error_bounded(self):
        g = {"w": jax.random.normal(KEY, (512,))}
        q, scales, err = opt.compress_grads(g, None)
        deq = opt.decompress_grads(q, scales)
        rel = float(jnp.linalg.norm(deq["w"] - g["w"]) / jnp.linalg.norm(g["w"]))
        assert rel < 0.02  # int8 quantisation noise
        assert q["w"].dtype == jnp.int8

    def test_error_feedback_accumulates(self):
        """EF: repeated compression of a constant gradient must average out —
        the error residual makes the quantised sum track the true sum."""
        g = {"w": jnp.full((64,), 0.001)}
        err = None
        total = jnp.zeros((64,))
        for _ in range(100):
            q, s, err = opt.compress_grads(g, err)
            total = total + opt.decompress_grads(q, s)["w"]
        np.testing.assert_allclose(total, 0.1 * jnp.ones(64), rtol=0.05)

    def test_compressed_training_converges(self):
        params = {"w": jnp.asarray([4.0, -3.0])}
        state = opt.adamw_init(params)
        state["ef"] = None
        cfg = opt.AdamWConfig(lr=0.1, weight_decay=0.0, compress=True)
        err = None
        for _ in range(150):
            grads = {"w": 2 * params["w"]}
            q, s, err = opt.compress_grads(grads, err)
            grads = opt.decompress_grads(q, s)
            params, state, _ = opt.adamw_update(cfg, grads, state, params)
        assert float(jnp.max(jnp.abs(params["w"]))) < 5e-2


class TestCheckpointer:
    def test_roundtrip_structure(self, tmp_path):
        tree = {
            "a": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3),
            "b": (jnp.zeros(3), [jnp.int32(4), None]),
            "c": {"count": jnp.zeros((), jnp.int32)},
        }
        p = tmp_path / "x.ckpt"
        save_pytree(p, tree)
        back = load_pytree(p)
        assert np.asarray(back["a"]).dtype == np.dtype("bfloat16")
        assert isinstance(back["b"], tuple) and isinstance(back["b"][1], list)
        assert back["b"][1][1] is None

    def test_restore_with_target_dtypes(self, tmp_path):
        tree = {"w": jnp.ones((4, 4), jnp.float32)}
        p = tmp_path / "x.ckpt"
        save_pytree(p, tree)
        target = {"w": jax.ShapeDtypeStruct((4, 4), jnp.bfloat16)}
        back = load_pytree(p, target=target)
        assert back["w"].dtype == jnp.bfloat16

    def test_retention_and_latest(self, tmp_path):
        ck = Checkpointer(tmp_path, keep=2)
        for s in (10, 20, 30):
            ck.save(s, {"x": jnp.asarray(s)})
        assert ck.steps() == [20, 30]
        step, tree = ck.restore(target={"x": jax.ShapeDtypeStruct((), jnp.int32)})
        assert step == 30 and int(tree["x"]) == 30

    def test_no_tmp_residue(self, tmp_path):
        ck = Checkpointer(tmp_path)
        ck.save(1, {"x": jnp.zeros(4)})
        assert not list(tmp_path.glob("*.tmp"))


class TestTrainerResume:
    def test_bit_exact_resume(self, tmp_path):
        cfg = get_config("starcoder2_3b").reduced()
        tc = TrainConfig(
            steps=6, batch=2, seq_len=32, checkpoint_every=3,
            checkpoint_dir=str(tmp_path), log_every=1, lr=1e-3,
        )
        t1 = Trainer(cfg, tc)
        p_full, s_full, _ = t1.run()

        # fresh trainer resumes from step 3 and must land on identical params
        t2 = Trainer(cfg, tc)
        params, state, step = t2.resume()
        assert step in (3, 6)
        if step == 6:
            # restore the intermediate checkpoint explicitly
            step, tree = t2.ckpt.restore(
                3,
                target={
                    "params": __import__("repro.models", fromlist=["lm"]).lm.abstract_model(cfg),
                    "opt": opt.abstract_adamw_state(
                        __import__("repro.models", fromlist=["lm"]).lm.abstract_model(cfg)
                    ),
                },
            )
            params, state = tree["params"], tree["opt"]
            step = 3
        p2, s2, _ = t2.run(params, state, start_step=step)
        for a, b in zip(jax.tree.leaves(p_full), jax.tree.leaves(p2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_straggler_watchdog_records(self):
        cfg = get_config("starcoder2_3b").reduced()
        tc = TrainConfig(steps=3, batch=2, seq_len=16, deadline_factor=0.0)
        t = Trainer(cfg, tc)
        t.run()
        # with a zero deadline every post-warmup step is a "straggler";
        # only 3 steps -> none recorded (needs 8), but the path executed
        assert isinstance(t.straggler_events, list)


class TestElasticMesh:
    def test_remesh_shrinks(self):
        from repro.launch.mesh import elastic_mesh

        # cannot build >1-device meshes on CPU here; just validate arithmetic
        with pytest.raises(ValueError):
            elastic_mesh(7, model_parallel=16)

    def test_data_pipeline_stateless_resume(self):
        from repro.data.pipeline import DataConfig, SyntheticLM

        cfg = get_config("starcoder2_3b").reduced()
        src = SyntheticLM(cfg, DataConfig(batch=2, seq_len=16, seed=3))
        a = src[5]
        b = src[5]
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        c = src[6]
        assert not np.array_equal(a["tokens"], c["tokens"])
