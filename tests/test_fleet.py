"""Tests for the repro.fleet subsystem: vectorized-vs-scalar coherence,
batched simulation, trace generators, and the §5 adaptive-replay result."""

import numpy as np
import pytest

from _prop import given, settings, st

from repro.core import (
    EdgeSpec,
    NetworkPath,
    Scenario,
    ScenarioError,
    ServiceModel,
    Tier,
    Workload,
    analytic,
    crossovers,
    simulate,
)
from repro.core.multitenant import TenantStream
from repro.core.queueing import mmk_wait_erlang
from repro.core.simulation import station_pass
from repro.fleet import (
    ScenarioBatch,
    Trace,
    drift_signal,
    fleet_analytic,
    fleet_crossover,
    lindley_station,
    make_trace,
    mmk_wait_erlang_vec,
    mmpp_signal,
    replay,
    simulate_fleet,
    step_signal,
)

REL_TOL = 1e-9


def _assert_matches_scalar(pred, i, scn):
    tot = analytic(scn).totals()
    vec = pred.totals(i)
    for key, v in tot.items():
        vv = vec[key]
        if np.isinf(v):
            assert np.isinf(vv), (key, v, vv)
        else:
            assert abs(v - vv) <= REL_TOL * abs(v), (key, v, vv)
    assert pred.strategy_names()[i] == analytic(scn).best_strategy


def _paper_point(**kw) -> Scenario:
    defaults = dict(
        workload=Workload(2.0, 30_000, 1_000, name="inceptionv4"),
        device=Tier("tx2", 0.150),
        edges=(EdgeSpec(Tier("a2", 0.028)),),
        network=NetworkPath(5e6 / 8),
    )
    defaults.update(kw)
    return Scenario(**defaults)


# strategy space for property-style coherence: service model x rates x sizes
_models = st.sampled_from(list(ServiceModel))
_point = st.tuples(
    st.floats(0.1, 20.0),     # lam
    st.floats(0.005, 0.5),    # dev service s
    st.floats(0.002, 0.1),    # edge service s
    st.floats(1.0, 4.0),      # edge k
    st.floats(0.2, 50.0),     # bandwidth Mbps
    _models,                  # device model
    _models,                  # edge model
    st.integers(0, 2),        # background tenants
)


class TestBatchPacking:
    def test_from_scenarios_round_numbers(self):
        scn = _paper_point()
        batch = ScenarioBatch.from_scenarios([scn, scn])
        assert batch.size == len(batch) == 2
        assert batch.max_edges == 1
        assert np.all(batch.n_edges == 1)
        assert batch.lam[0] == 2.0 and batch.edge_s[0, 0] == 0.028
        assert np.isnan(batch.edge_bw[0, 0])  # unset override

    def test_edge_padding_and_no_edge_rows(self):
        two_edges = _paper_point(edges=(
            EdgeSpec(Tier("a", 0.03)), EdgeSpec(Tier("b", 0.02), bandwidth_Bps=1e6)))
        no_edges = _paper_point(edges=())
        batch = ScenarioBatch.from_scenarios([two_edges, no_edges])
        assert batch.max_edges == 2
        assert list(batch.n_edges) == [2, 0]
        pred = fleet_analytic(batch)
        assert np.all(np.isinf(pred.t_edge[1]))  # padding never wins
        assert pred.strategy_names()[1] == "on_device"
        _assert_matches_scalar(pred, 0, two_edges)

    def test_from_sweep_matches_grid_rows(self):
        base = _paper_point()
        axes = {
            "network.bandwidth_Bps": np.geomspace(2e5, 2e7, 3),
            "workload.arrival_rate": np.linspace(0.5, 6.0, 4),
        }
        grid = base.grid(axes)
        batch = ScenarioBatch.from_sweep(base, axes)
        assert batch.size == len(grid) == 12
        pred = fleet_analytic(batch)
        for i, scn in enumerate(grid):
            _assert_matches_scalar(pred, i, scn)

    def test_from_sweep_descending_axis_on_stable_base_matches_grid(self):
        # regression: the fail-fast probe must allow unstable values exactly
        # like grid()/sweep() do, regardless of axis value ORDER
        base = _paper_point()  # allow_unstable=False, device cap ~6.67 rps
        axes = {"workload.arrival_rate": np.linspace(30.0, 0.5, 4)}
        grid = base.grid(axes)
        batch = ScenarioBatch.from_sweep(base, axes)
        pred = fleet_analytic(batch)
        for i, scn in enumerate(grid):
            _assert_matches_scalar(pred, i, scn)

    def test_from_sweep_rejects_unknown_paths(self):
        base = _paper_point()
        with pytest.raises(ScenarioError):
            ScenarioBatch.from_sweep(base, {"device.name": [1.0]})
        with pytest.raises(ScenarioError):
            ScenarioBatch.from_sweep(base, {"edges[3].tier.service_time_s": [0.1]})

    def test_grid_row_order_contract_pinned_column_exact(self):
        """THE row-matching contract: packing ``base.grid(axes)`` row by row
        is COLUMN-IDENTICAL to ``from_sweep(base, axes)`` — same C order
        (last axis fastest), same values, bit-for-bit. Previously this was
        asserted only via latency agreement; pin the packed arrays directly
        so a silent reordering in either constructor fails loudly here."""
        base = _paper_point()
        axes = {
            "workload.arrival_rate": np.linspace(0.5, 6.0, 3),
            "edges[0].tier.service_time_s": np.array([0.01, 0.03]),
            "network.bandwidth_Bps": np.geomspace(2e5, 2e7, 4),
        }
        via_grid = ScenarioBatch.from_scenarios(base.grid(axes))
        via_sweep = ScenarioBatch.from_sweep(base, axes)
        assert via_grid.size == via_sweep.size == 3 * 2 * 4
        for name, col in via_grid.arrays().items():
            np.testing.assert_array_equal(
                col, via_sweep.arrays()[name], err_msg=name, strict=True)
        # and the C-order invariant itself: the LAST axis varies fastest
        bw = via_sweep.bandwidth_Bps
        assert np.array_equal(bw[:4], np.geomspace(2e5, 2e7, 4))
        assert np.array_equal(bw, np.tile(np.geomspace(2e5, 2e7, 4), 6))
        lam = via_sweep.lam
        assert np.array_equal(lam, np.repeat(np.linspace(0.5, 6.0, 3), 8))

    def test_from_sweep_rejects_invalid_later_values_like_grid(self):
        # regression: only the FIRST axis value used to be probed, so a zero
        # rate in position 2 was silently packed while grid() raised — the
        # two constructors must reject exactly the same axes
        base = _paper_point()
        for axes in (
            {"workload.arrival_rate": [5.0, 0.0]},
            {"workload.arrival_rate": [5.0, -1.0]},
            {"network.bandwidth_Bps": [1e6, float("nan")]},
            {"workload.res_bytes": [1000.0, -5.0]},
            {"edges[0].tier.service_time_s": [0.01, 0.0]},
        ):
            with pytest.raises(ScenarioError):
                base.grid(axes)
            with pytest.raises(ScenarioError):
                ScenarioBatch.from_sweep(base, axes)


class TestSweepErgonomics:
    def test_sweep_accepts_numpy_arrays_and_iterables(self):
        base = _paper_point()
        swept = base.sweep("workload.arrival_rate", np.linspace(1, 5, 3))
        assert [s.workload.arrival_rate for s in swept] == [1.0, 3.0, 5.0]
        # numpy scalars are coerced: the spec stays exactly JSON-round-trippable
        assert all(isinstance(s.workload.arrival_rate, float) for s in swept)
        assert all(Scenario.from_dict(s.to_dict()) == s for s in swept)
        gen = (x for x in (2.0, 4.0))
        assert len(base.sweep("workload.arrival_rate", gen)) == 2

    def test_grid_is_c_ordered(self):
        base = _paper_point()
        grid = base.grid({"workload.arrival_rate": [1.0, 2.0],
                          "network.bandwidth_Bps": [1e5, 1e6, 1e7]})
        assert len(grid) == 6
        # last axis fastest
        assert [s.workload.arrival_rate for s in grid[:3]] == [1.0, 1.0, 1.0]
        assert [float(np.asarray(s.network.bandwidth_Bps)) for s in grid[:3]] == [1e5, 1e6, 1e7]


class TestAnalyticVecCoherence:
    @settings(max_examples=25)
    @given(_point)
    def test_matches_scalar_analytic(self, p):
        lam, s_dev, s_edge, k_edge, mbps, m_dev, m_edge, n_bg = p
        bg = tuple(
            TenantStream(1.0 + i, s_edge * (1 + i), (s_edge / 4) ** 2)
            for i in range(n_bg)
        )
        scn = Scenario(
            workload=Workload(lam, 20_000, 2_000),
            device=Tier("dev", s_dev, service_model=m_dev,
                        service_var=(s_dev / 3) ** 2),
            edges=(EdgeSpec(Tier("edge", s_edge, parallelism_k=k_edge,
                                 service_model=m_edge,
                                 service_var=(s_edge / 3) ** 2),
                            background=bg),),
            network=NetworkPath(mbps * 1e6 / 8),
            allow_unstable=True,
        )
        pred = fleet_analytic(ScenarioBatch.from_scenarios([scn]))
        _assert_matches_scalar(pred, 0, scn)

    def test_100k_batch_single_jitted_call(self):
        # acceptance criterion: >= 100k scenarios in one jitted evaluation,
        # per-scenario results matching the scalar path
        base = _paper_point()
        axes = {
            "network.bandwidth_Bps": np.geomspace(1e5, 1e8, 512),
            "workload.arrival_rate": np.linspace(0.5, 30.0, 256),
        }
        batch = ScenarioBatch.from_sweep(base, axes)
        assert batch.size == 131072 >= 100_000
        pred = fleet_analytic(batch)
        assert pred.t_dev.shape == (131072,)
        assert pred.t_edge.shape == (131072, 1)
        # spot-check random rows against the scalar closed forms
        rng = np.random.default_rng(7)
        bw, lam = axes["network.bandwidth_Bps"], axes["workload.arrival_rate"]
        for idx in rng.integers(0, batch.size, 12):
            i, j = divmod(int(idx), lam.size)
            scn = base.grid({"network.bandwidth_Bps": [bw[i]],
                             "workload.arrival_rate": [lam[j]]})[0]
            _assert_matches_scalar(pred, int(idx), scn)

    def test_return_results_false_drops_return_path(self):
        scn = _paper_point(return_results=False)
        pred = fleet_analytic(ScenarioBatch.from_scenarios([scn]))
        _assert_matches_scalar(pred, 0, scn)

    def test_mmk_erlang_vec_matches_scalar_oracle(self):
        lams = np.array([3.0, 0.5, 10.0, 0.0, 4.9])
        mus = np.array([1.0, 2.0, 1.5, 1.0, 1.0])
        ks = np.array([5.0, 1.0, 8.0, 3.0, 5.0])
        vec = np.asarray(mmk_wait_erlang_vec(lams, mus, ks))
        for i in range(len(lams)):
            ref = mmk_wait_erlang(float(lams[i]), float(mus[i]), int(ks[i]))
            assert vec[i] == pytest.approx(ref, rel=1e-9, abs=1e-12)

    def test_mmk_erlang_vec_refuses_truncated_k(self):
        # regression: k beyond the masked-sum width must fail loudly
        with pytest.raises(ValueError, match="max_k"):
            mmk_wait_erlang_vec(60.0, 1.0, 80.0)
        big = np.asarray(mmk_wait_erlang_vec(60.0, 1.0, 80.0, max_k=128))
        assert float(big) == pytest.approx(mmk_wait_erlang(60.0, 1.0, 80), rel=1e-9)


class TestCrossoverVec:
    def test_bandwidth_crossover_matches_scalar(self):
        scns = [
            _paper_point(allow_unstable=True),
            _paper_point(device=Tier("orin", 0.085), allow_unstable=True),
        ]
        fc = fleet_crossover(ScenarioBatch.from_scenarios(scns), "bandwidth")
        for i, scn in enumerate(scns):
            c = crossovers(scn, "bandwidth")
            assert c.value is not None and fc.found[i]
            assert fc.value[i] == pytest.approx(c.value, rel=1e-6)
            assert bool(fc.offload_wins_above[i]) == c.offload_wins_above

    def test_arrival_rate_crossover_matches_scalar(self):
        scn = Scenario(
            workload=Workload(1.0, 50_000, 2_000),
            device=Tier("dev", 0.010),
            edges=(EdgeSpec(Tier("edge", 0.008, parallelism_k=8.0)),),
            network=NetworkPath(100e6 / 8), allow_unstable=True)
        c = crossovers(scn, "arrival_rate")
        fc = fleet_crossover(ScenarioBatch.from_scenarios([scn]), "arrival_rate")
        assert c.value is not None and fc.found[0]
        assert fc.value[0] == pytest.approx(c.value, rel=1e-6)

    def test_no_crossover_reports_nan(self):
        # offloading wins across the whole default bandwidth range? no — the
        # device here beats the edge everywhere (tiny payload, fast device)
        scn = Scenario(
            workload=Workload(1.0, 1_000, 100),
            device=Tier("fast", 0.001),
            edges=(EdgeSpec(Tier("slow-edge", 0.05)),),
            network=NetworkPath(1e7), allow_unstable=True)
        assert crossovers(scn, "bandwidth").value is None
        fc = fleet_crossover(ScenarioBatch.from_scenarios([scn]), "bandwidth")
        assert not fc.found[0] and np.isnan(fc.value[0])


class TestSimVec:
    def test_lindley_station_exact_vs_station_pass(self):
        rng = np.random.default_rng(3)
        for k in (1, 2, 4):
            arr = np.cumsum(rng.exponential(0.1, size=400))
            svc = rng.exponential(0.05, size=400)
            ref = station_pass(arr, svc, k)
            vec = np.asarray(lindley_station(arr[None, :], svc[None, :], k))[0]
            assert np.max(np.abs(ref - vec)) < 1e-9

    def test_k_max_smaller_than_k_is_refused(self):
        # regression: an undersized server pool must not silently simulate
        # a different station
        arr = np.cumsum(np.full((1, 10), 0.1), axis=1)
        svc = np.full((1, 10), 0.05)
        with pytest.raises(ValueError, match="k_max"):
            lindley_station(arr, svc, 4, k_max=2)

    def test_heterogeneous_k_rows(self):
        rng = np.random.default_rng(4)
        arr = np.cumsum(rng.exponential(0.1, size=(2, 300)), axis=1)
        svc = rng.exponential(0.08, size=(2, 300))
        vec = np.asarray(lindley_station(arr, svc, np.array([1, 3])))
        for i, k in enumerate((1, 3)):
            ref = station_pass(arr[i], svc[i], k)
            assert np.max(np.abs(ref - vec[i])) < 1e-9

    def test_edge_sim_matches_scalar_means(self):
        # shared seeds: deterministic run-to-run, compared within CI bounds
        scn = _paper_point(
            device=Tier("tx2", 0.15, service_model=ServiceModel.EXPONENTIAL),
            edges=(EdgeSpec(Tier("a2", 0.028, parallelism_k=2.0)),),
            workload=Workload(4.0, 30_000, 1_000),
            network=NetworkPath(20e6 / 8))
        batch = ScenarioBatch.from_scenarios([scn] * 3)
        res = simulate_fleet(batch, "edge[0]", n=30_000, seed=5)
        ref = simulate(scn, "edge[0]", n=30_000, seed=5).mean
        pred = float(np.asarray(analytic(scn)["edge[0]"].total))
        assert res.latencies.shape == (3, 30_000)
        for mu in res.mean:
            assert abs(mu - ref) / ref < 0.06
            assert abs(mu - pred) / pred < 0.10

    def test_on_device_sim_matches_scalar_means(self):
        scn = _paper_point()
        batch = ScenarioBatch.from_scenarios([scn] * 2)
        res = simulate_fleet(batch, "on_device", n=30_000, seed=6)
        ref = simulate(scn, "on_device", n=30_000, seed=6).mean
        for mu in res.mean:
            assert abs(mu - ref) / ref < 0.08

    def test_background_edges_are_refused(self):
        scn = _paper_point(edges=(
            EdgeSpec(Tier("a2", 0.028), background=(TenantStream(2.0, 0.028),)),))
        batch = ScenarioBatch.from_scenarios([scn])
        with pytest.raises(ValueError, match="shared-station"):
            simulate_fleet(batch, "edge[0]", n=100)

    def test_fractional_k_is_refused(self):
        scn = _paper_point(edges=(EdgeSpec(Tier("a2", 0.028, parallelism_k=2.5)),))
        batch = ScenarioBatch.from_scenarios([scn])
        with pytest.raises(ValueError, match="fractional"):
            simulate_fleet(batch, "edge[0]", n=100)


class TestTraces:
    def test_step_signal_breakpoints(self):
        t = np.arange(0.0, 10.0, 1.0)
        v = step_signal(t, [(0, 5.0), (4, 1.0), (8, 5.0)])
        assert list(v[:4]) == [5.0] * 4 and list(v[4:8]) == [1.0] * 4
        assert list(v[8:]) == [5.0] * 2

    def test_drift_and_mmpp_are_seeded(self):
        t = np.arange(0.0, 50.0, 1.0)
        a = drift_signal(t, 10.0, 20.0, jitter=0.1, seed=3)
        b = drift_signal(t, 10.0, 20.0, jitter=0.1, seed=3)
        assert np.array_equal(a, b)
        assert np.all(a > 0)
        m1 = mmpp_signal(t, 1.0, 9.0, p_up=0.3, p_down=0.3, seed=1)
        assert np.array_equal(m1, mmpp_signal(t, 1.0, 9.0, p_up=0.3, p_down=0.3, seed=1))
        assert set(np.unique(m1)) <= {1.0, 9.0}
        assert (m1 == 9.0).any()  # bursts actually occur

    def test_trace_validation(self):
        with pytest.raises(ValueError):
            Trace(times=np.array([0.0, 1.0, 3.0]),  # non-uniform
                  bandwidth_Bps=np.ones(3), arrival_rate=np.ones(3),
                  edge_bg_rate=np.zeros((3, 1)))
        with pytest.raises(ValueError):
            make_trace(10.0, 1.0, bandwidth_Bps=0.0, arrival_rate=1.0)

    def test_make_trace_composition(self):
        tr = make_trace(
            60.0, 1.0,
            bandwidth_Bps=lambda t: step_signal(t, [(0, 2.5e6), (30, 2.5e5)]),
            arrival_rate=10.0,
            edge_bg_rate=[lambda t: mmpp_signal(t, 0.0, 30.0, seed=7)],
        )
        assert tr.n_epochs == 60 and tr.n_edges == 1 and tr.epoch_s == 1.0


class TestReplay:
    @staticmethod
    def _trace():
        # bandwidth step (Fig. 6 shape) + tenant churn (Fig. 7 shape)
        return make_trace(
            120.0, 1.0,
            bandwidth_Bps=lambda t: step_signal(
                t, [(0, 20e6 / 8), (40, 0.8e6 / 8), (80, 20e6 / 8)]),
            arrival_rate=2.0,
            edge_bg_rate=[lambda t: step_signal(
                t, [(0, 0.0), (20, 33.0), (35, 0.0)])],
        )

    def test_adaptive_beats_both_statics(self):
        # acceptance criterion: the §5 qualitative result on a bandwidth-step
        # + tenant-churn trace — adaptive mean <= both static policies
        res = replay(_paper_point(network=NetworkPath(20e6 / 8)), self._trace(), seed=1)
        a = res.policies["adaptive"].mean_latency_s
        assert a <= res.policies["on_device"].mean_latency_s
        assert a <= res.policies["edge[0]"].mean_latency_s
        assert res.adaptive_wins
        assert res.policies["adaptive"].switches >= 2  # it actually adapted

    def test_replay_goes_through_estimators_not_raw_values(self):
        res = replay(_paper_point(network=NetworkPath(20e6 / 8)), self._trace(), seed=1)
        step_idx = 40  # bandwidth drops 20 -> 0.8 Mbps here
        true_bw = res.trace.bandwidth_Bps[step_idx]
        # EWMA lag: the manager's view at the step is NOT the raw new value...
        assert res.est_bandwidth_Bps[step_idx] > 2 * true_bw
        # ...but converges within a few epochs
        assert res.est_bandwidth_Bps[step_idx + 8] == pytest.approx(true_bw, rel=0.1)
        # arrival estimates come from the sliding-window estimator (noisy,
        # not the exact trace constant)
        assert not np.allclose(res.est_arrival_rate, res.trace.arrival_rate)

    def test_manager_step_is_the_gateway_decision_path(self):
        # the same metrics through manager.step() and through the gateway
        # must produce the same decision (no duplicated dispatch logic)
        from repro.serving.gateway import OffloadGateway

        scn = _paper_point(network=NetworkPath(20e6 / 8))
        gw = OffloadGateway.from_scenario(scn)
        for dt in np.arange(0.0, 1.0, 0.1):
            gw.observe_arrival(float(dt))
        d_gw = gw.decide(now=1.0)

        mgr = scn.manager()
        d_step = mgr.step(1.0, {
            "workload": scn.workload,
            "lam_dev": gw.arrivals.rate(1.0),
            "bandwidth_Bps": gw.bandwidth.value,
            "edges": [e.state() for e in gw.edges],
        })
        assert d_step.edge_index == d_gw.edge_index
        assert d_step.predicted_latency_s == pytest.approx(d_gw.predicted_latency_s)

    def test_manager_step_missing_metric_raises(self):
        mgr = _paper_point().manager()
        with pytest.raises(KeyError):
            mgr.step(0.0, {"lam_dev": 1.0})

    def test_bg_less_trace_keeps_spec_background(self):
        # regression: a trace without edge columns means "no churn", not
        # "no tenants" — scoring must reflect the spec's declared background
        scn = _paper_point(
            edges=(EdgeSpec(Tier("a2", 0.028),
                            background=(TenantStream(30.0, 0.028),)),),
            network=NetworkPath(20e6 / 8))
        tr = make_trace(20.0, 1.0, bandwidth_Bps=20e6 / 8, arrival_rate=2.0)
        res = replay(scn, tr, seed=0)
        expected = float(np.asarray(analytic(scn)["edge[0]"].total))
        got = res.policies["edge[0]"].mean_latency_s
        assert got == pytest.approx(expected, rel=1e-9)

    def test_trace_edge_count_mismatch_raises(self):
        scn = _paper_point()
        tr = make_trace(20.0, 1.0, bandwidth_Bps=1e6, arrival_rate=2.0,
                        edge_bg_rate=[0.0, 0.0])  # two columns, one edge
        with pytest.raises(ScenarioError):
            replay(scn, tr)


class TestFleetSweepCLI:
    def test_main_writes_report(self, tmp_path, capsys):
        from repro.launch.fleet_sweep import main

        out = tmp_path / "sweep.json"
        rc = main([
            "--axis", "network.bandwidth_Bps=1e5:1e7:8:geom",
            "--axis", "workload.arrival_rate=0.5:6:4",
            "--crossover", "bandwidth",
            "--out", str(out),
        ])
        assert rc == 0
        import json

        report = json.loads(out.read_text())
        assert report["batch_size"] == 32
        assert set(report["strategy_counts"]) <= {"on_device", "edge[0]"}
        assert "crossover" in report
        assert "scenarios/s" in capsys.readouterr().out
