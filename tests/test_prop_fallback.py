"""The hypothesis fallback itself is load-bearing in the hermetic container —
test its contract directly (independent of whether real hypothesis is
installed), so both property-test engines keep running the same cases."""

import pytest

import _hypothesis_fallback as fb
from _prop import USING_FALLBACK, given, settings, st


def test_shim_reports_engine():
    assert isinstance(USING_FALLBACK, bool)
    assert callable(given) and callable(settings)
    assert hasattr(st, "floats") and hasattr(st, "integers")


def test_fallback_is_deterministic():
    runs = []
    for _ in range(2):
        seen = []

        @fb.given(fb.floats(0.0, 1.0), fb.integers(0, 9))
        def inner(x, n):
            seen.append((x, n))

        inner()
        runs.append(seen)
    assert runs[0] == runs[1]
    assert len(runs[0]) == fb._MAX_EXAMPLES


def test_assume_resamples_instead_of_failing():
    seen = []

    @fb.given(fb.floats(0.0, 1.0))
    @fb.settings(max_examples=10)
    def inner(x):
        fb.assume(x > 0.5)
        seen.append(x)

    inner()
    assert len(seen) == 10
    assert all(x > 0.5 for x in seen)


def test_assume_exhaustion_is_loud():
    @fb.given(fb.floats(0.0, 1.0))
    @fb.settings(max_examples=5)
    def inner(x):
        fb.assume(False)

    with pytest.raises(ValueError, match="assume"):
        inner()


def test_examples_run_first_in_declaration_order():
    seen = []

    @fb.given(fb.integers(0, 100))
    @fb.example(7)
    @fb.example(9)
    @fb.settings(max_examples=4)
    def inner(x):
        seen.append(x)

    inner()
    assert seen[:2] == [7, 9]  # topmost @example first, like hypothesis
    assert len(seen) == 2 + 4  # explicit cases don't consume the random budget


def test_example_failure_propagates():
    @fb.given(fb.integers(0, 100))
    @fb.example(101)
    def inner(x):
        assert x <= 100

    with pytest.raises(AssertionError):
        inner()


def test_filter_chaining_still_applies():
    seen = []

    @fb.given(fb.integers(0, 20).filter(lambda v: v % 2 == 0).filter(lambda v: v > 4))
    @fb.settings(max_examples=8)
    def inner(v):
        seen.append(v)

    inner()
    assert all(v % 2 == 0 and v > 4 for v in seen)
