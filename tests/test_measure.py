"""Tier-1 tests for the hardware-in-the-loop measurement subsystem:
engine timing discipline, workload determinism/validation, the profiling
harness, the fit layer (known-distribution round-trips), the MeasuredProfile
artifact, Tier.from_measured, and the measured validation gate."""

import json

import numpy as np
import pytest

import jax

from repro.core.latency import ServiceModel, Tier
from repro.core.scenario import Scenario, analytic, analytic_tail
from repro.measure import (
    HarnessConfig,
    MeasuredTrace,
    build_profile,
    classify_service_model,
    fit_samples,
    fit_trace,
    load_profile,
    run_harness,
)
from repro.measure.profile import MeasuredProfile, PROFILE_VERSION
from repro.serving.workload import PoissonWorkload, WorkloadConfig
from repro.validate.measured import measured_scenario, run_measured_gate

# the smoke profile: the ISSUE acceptance run (deterministic simulated clock)
SMOKE = HarnessConfig(arch="starcoder2_3b", n_requests=240, seed=0)


@pytest.fixture(scope="module")
def smoke_trace():
    return run_harness(SMOKE)


@pytest.fixture(scope="module")
def smoke_profile(smoke_trace):
    return build_profile(smoke_trace)


class TestWorkload:
    def test_same_seed_identical_stream(self):
        wc = WorkloadConfig(arrival_rate=50.0, prompt_len=16, prompt_len_jitter=4,
                            max_new_tokens=8, new_tokens_geometric_p=0.4, seed=7)
        a = PoissonWorkload(wc).take(40)
        b = PoissonWorkload(wc).take(40)
        assert [r.arrival_s for r in a] == [r.arrival_s for r in b]
        assert [r.max_new_tokens for r in a] == [r.max_new_tokens for r in b]
        assert all(np.array_equal(x.prompt, y.prompt) for x, y in zip(a, b))

    def test_different_seed_differs(self):
        wc = lambda s: WorkloadConfig(arrival_rate=50.0, prompt_len=16,
                                      prompt_len_jitter=4, seed=s)
        a = PoissonWorkload(wc(0)).take(20)
        b = PoissonWorkload(wc(1)).take(20)
        assert [r.arrival_s for r in a] != [r.arrival_s for r in b]

    def test_jitter_cannot_truncate(self):
        # jitter >= prompt_len (could go non-positive) and jitter that dips
        # below the min-length floor both fail eagerly, not silently clamp
        with pytest.raises(ValueError, match="prompt_len_jitter"):
            WorkloadConfig(arrival_rate=1.0, prompt_len=8, prompt_len_jitter=8)
        with pytest.raises(ValueError, match="prompt_len_jitter"):
            WorkloadConfig(arrival_rate=1.0, prompt_len=6, prompt_len_jitter=3)
        ok = WorkloadConfig(arrival_rate=1.0, prompt_len=8, prompt_len_jitter=4)
        assert ok.prompt_len_range == (4, 12)

    def test_validation_errors(self):
        with pytest.raises(ValueError, match="arrival_rate"):
            WorkloadConfig(arrival_rate=0.0)
        with pytest.raises(ValueError, match="geometric"):
            WorkloadConfig(arrival_rate=1.0, new_tokens_geometric_p=1.0)
        with pytest.raises(ValueError, match="max_new_tokens"):
            WorkloadConfig(arrival_rate=1.0, max_new_tokens=0)

    def test_lengths_span_configured_range(self):
        wc = WorkloadConfig(arrival_rate=50.0, prompt_len=8, prompt_len_jitter=4,
                            seed=0)
        lens = {len(r.prompt) for r in PoissonWorkload(wc).take(200)}
        assert min(lens) == 4 and max(lens) == 12


class TestEngineTiming:
    @pytest.fixture(scope="class")
    def engine_run(self):
        from repro.configs import get_config
        from repro.models import lm
        from repro.serving.engine import Engine, Request, ServeConfig

        cfg = get_config("starcoder2_3b").reduced(seq_chunk=8)
        params = lm.init_model(cfg, jax.random.PRNGKey(0))
        eng = Engine(cfg, params, ServeConfig(slots=1, max_seq=64))
        rng = np.random.default_rng(0)
        reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, size=8)
                        .astype(np.int32), max_new_tokens=3) for i in range(3)]
        for r in reqs:
            eng.submit(r)
        eng.drain()
        return eng, reqs

    def test_cold_calls_flagged_and_excluded(self, engine_run):
        eng, _ = engine_run
        # no warmup() was called: the first prefill at each shape and the
        # first decode carry JIT compile and must be flagged
        cold = [ev for ev in eng.service_log if ev.compile]
        warm = [ev for ev in eng.service_log if not ev.compile]
        assert cold and warm
        mean, var = eng.observed_service_stats()
        durs = np.array([ev.duration_s for ev in warm])
        assert mean == pytest.approx(float(durs.mean()))
        # compile time is seconds; steady-state ops are far faster — if cold
        # calls leaked into the stats the mean would be >> the warm mean
        assert mean < min(ev.duration_s for ev in cold)

    def test_warmup_precompiles(self):
        from repro.configs import get_config
        from repro.models import lm
        from repro.serving.engine import Engine, Request, ServeConfig

        cfg = get_config("starcoder2_3b").reduced(seq_chunk=8)
        params = lm.init_model(cfg, jax.random.PRNGKey(0))
        eng = Engine(cfg, params, ServeConfig(slots=1, max_seq=64))
        eng.warmup([8])
        rng = np.random.default_rng(0)
        eng.submit(Request(rid=0, prompt=rng.integers(0, cfg.vocab_size, size=8)
                           .astype(np.int32), max_new_tokens=3))
        eng.drain()
        assert not any(ev.compile for ev in eng.service_log)

    def test_event_time_stamps_consistent(self, engine_run):
        eng, reqs = engine_run
        for r in reqs:
            assert r.arrival_s <= r.t_admit <= r.t_first_token <= r.t_done
            assert r.queue_wait_s >= 0
            assert len(r.tokens_out) == r.max_new_tokens
        # service log is a serialised schedule: events don't overlap
        for a, b in zip(eng.service_log, eng.service_log[1:]):
            assert b.t >= a.t

    def test_single_token_request_completes_at_prefill(self):
        from repro.configs import get_config
        from repro.models import lm
        from repro.serving.engine import Engine, Request, ServeConfig

        cfg = get_config("starcoder2_3b").reduced(seq_chunk=8)
        params = lm.init_model(cfg, jax.random.PRNGKey(0))
        eng = Engine(cfg, params, ServeConfig(slots=1, max_seq=64))
        req = Request(rid=0, prompt=np.arange(1, 9, dtype=np.int32),
                      max_new_tokens=1)
        eng.submit(req)
        eng.drain()
        assert len(req.tokens_out) == 1
        assert req.t_done == req.t_first_token


class TestHarness:
    def test_deterministic_per_seed(self):
        hc = HarnessConfig(arch="starcoder2_3b", n_requests=30, seed=3)
        a = run_harness(hc)
        b = run_harness(hc)
        assert a.to_dict() == b.to_dict()

    def test_trace_roundtrip(self, smoke_trace, tmp_path):
        p = smoke_trace.save(tmp_path / "trace.json")
        back = MeasuredTrace.load(p)
        assert back.to_dict() == smoke_trace.to_dict()

    def test_records_consistent(self, smoke_trace):
        assert len(smoke_trace.requests) == SMOKE.n_requests
        for r in smoke_trace.requests:
            assert r.n_decode == r.n_tokens - 1
            assert r.latency_s == pytest.approx(r.queue_wait_s + r.service_s)
            # slots=1: in-service time is exactly prefill + own decode steps
            assert r.service_s == pytest.approx(r.prefill_s + r.decode_s)
            assert r.occupancy == 1

    def test_lands_near_target_rho(self, smoke_profile):
        rho = smoke_profile.observed_stat("rho_hat")
        assert abs(rho - SMOKE.target_rho) < 0.1


class TestFit:
    def test_deterministic_roundtrip(self):
        f = fit_samples(np.full(200, 0.02), phase="prefill", occupancy=1)
        assert f.model is ServiceModel.DETERMINISTIC
        assert f.mean_s == pytest.approx(0.02)
        assert f.var_s == pytest.approx(0.0)

    def test_exponential_roundtrip(self):
        rng = np.random.default_rng(0)
        x = rng.exponential(0.05, 4000)
        f = fit_samples(x, phase="request", occupancy=1)
        assert f.model is ServiceModel.EXPONENTIAL
        assert f.mean_s == pytest.approx(0.05, rel=0.1)
        assert f.var_s == pytest.approx(0.05**2, rel=0.2)

    def test_gamma_roundtrip_two_moment_match(self):
        # gamma with SCV = 1/k = 0.25: too variable for DETERMINISTIC, too
        # regular for EXPONENTIAL -> GENERAL with an exact two-moment match
        rng = np.random.default_rng(1)
        k, theta = 4.0, 0.01
        x = rng.gamma(k, theta, 4000)
        f = fit_samples(x, phase="request", occupancy=1)
        assert f.model is ServiceModel.GENERAL
        assert f.mean_s == pytest.approx(k * theta, rel=0.05)
        assert f.var_s == pytest.approx(k * theta**2, rel=0.15)
        assert f.scv == pytest.approx(1.0 / k, rel=0.15)

    def test_classify_edges(self):
        assert classify_service_model(1.0, 0.0) is ServiceModel.DETERMINISTIC
        assert classify_service_model(1.0, 1.0) is ServiceModel.EXPONENTIAL
        assert classify_service_model(1.0, 0.25) is ServiceModel.GENERAL
        with pytest.raises(ValueError):
            classify_service_model(0.0, 1.0)
        with pytest.raises(ValueError):
            classify_service_model(1.0, -1.0)

    def test_fit_trace_groups(self, smoke_trace):
        fits = fit_trace(smoke_trace)
        keys = {(f.phase, f.occupancy) for f in fits}
        assert ("prefill", 1) in keys
        assert ("decode", 1) in keys
        assert ("request", 1) in keys
        for f in fits:
            assert f.n >= 8 and f.mean_s > 0
            assert f.ci_lo_s <= f.mean_s <= f.ci_hi_s
            assert f.percentile(50) <= f.percentile(99)


class TestProfile:
    def test_json_byte_stability(self, smoke_profile, tmp_path):
        path = smoke_profile.save(tmp_path / "p.json")
        raw = path.read_bytes()
        back = load_profile(path)
        assert back.dumps().encode() == raw  # byte-for-byte round-trip
        assert back.service_moments(1) == smoke_profile.service_moments(1)

    def test_version_gate(self, smoke_profile):
        d = smoke_profile.to_dict()
        d["version"] = PROFILE_VERSION + 1
        with pytest.raises(ValueError, match="version"):
            MeasuredProfile.from_dict(d)

    def test_missing_fit_is_loud(self, smoke_profile):
        with pytest.raises(KeyError, match="occupancy=7"):
            smoke_profile.fit_for("request", 7)
        with pytest.raises(KeyError):
            smoke_profile.observed_stat("nope")


class TestTierFromMeasured:
    def test_flows_through_all_analytic_paths(self, smoke_profile):
        tier = Tier.from_measured(smoke_profile, 1)
        assert tier.service_time_s > 0
        assert tier.parallelism_k == 1.0
        assert tier.meta["measured"] is True

        scn = measured_scenario(smoke_profile)
        assert isinstance(scn, Scenario)
        pred = analytic(scn)
        mean = float(np.asarray(pred["on_device"].total))
        assert np.isfinite(mean) and mean > tier.service_time_s

        q99 = analytic_tail(scn, 0.99)["on_device"]
        assert np.isfinite(q99) and q99 > mean

        from repro.fleet import ScenarioBatch, fleet_analytic

        fp = fleet_analytic(ScenarioBatch.from_scenarios([scn]))
        assert float(fp.t_dev[0]) == pytest.approx(mean, rel=1e-9)

    def test_duck_typed_protocol(self):
        class Stub:
            arch = "stub"

            def service_moments(self, occupancy):
                return 0.01, 0.0001, ServiceModel.EXPONENTIAL

        t = Tier.from_measured(Stub(), 2)
        assert t.service_model is ServiceModel.EXPONENTIAL
        assert t.parallelism_k == 2.0
        assert t.service_var == 0.0  # only GENERAL carries Var[s]

    def test_invalid_occupancy(self, smoke_profile):
        with pytest.raises(ValueError, match="occupancy"):
            Tier.from_measured(smoke_profile, 0)


class TestMeasuredGate:
    def test_smoke_gate_passes_within_budget(self, smoke_profile):
        rep = run_measured_gate(smoke_profile)
        assert rep.mean_mape_pct <= 15.0, (
            f"analytic mean {rep.analytic_mean_s} vs observed "
            f"{rep.observed_mean_s}: MAPE {rep.mean_mape_pct:.2f}%")
        assert rep.tail_passed and rep.vec_passed
        assert rep.passed

    def test_report_carries_observed_numbers(self, smoke_profile):
        d = run_measured_gate(smoke_profile).to_dict()
        assert d["regime"] == "measured"
        assert d["mean"]["observed_s"] > 0
        assert d["tail"]["observed_s"] > d["mean"]["observed_s"]
        assert json.loads(json.dumps(d)) == d  # JSON-clean

    def test_budget_configurable(self, smoke_profile):
        rep = run_measured_gate(smoke_profile, budget_pct=0.001)
        assert not rep.mean_passed and not rep.passed


class TestCLI:
    def test_profile_validate_roundtrip(self, tmp_path):
        from repro.launch.measure import main

        out = tmp_path / "PROFILE.json"
        rc = main(["profile", "--config", "starcoder2_3b", "--requests", "40",
                   "--seed", "1", "--out", str(out)])
        assert rc == 0 and out.exists()

        report = tmp_path / "GATE.json"
        rc = main(["validate", "--profile", str(out),
                   "--report-out", str(report)])
        assert rc == 0
        d = json.loads(report.read_text())
        assert d["regime"] == "measured" and d["passed"]

    def test_profile_replayable(self, tmp_path):
        from repro.launch.measure import main

        a, b = tmp_path / "a.json", tmp_path / "b.json"
        argv = ["profile", "--config", "starcoder2_3b", "--requests", "25",
                "--seed", "5"]
        assert main(argv + ["--out", str(a)]) == 0
        assert main(argv + ["--out", str(b)]) == 0
        assert a.read_bytes() == b.read_bytes()
