"""Per-architecture smoke tests (required deliverable): reduced config of the
same family, one forward (+ one train step for representatives), asserting
output shapes and no NaNs on CPU."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.data.pipeline import make_batch
from repro.launch.steps import make_train_step
from repro.models import lm
from repro.training import optimizer as opt

KEY = jax.random.PRNGKey(0)


def _batch_for(cfg, B=2, S=32):
    return make_batch(cfg, B, S, step=0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    params = lm.init_model(cfg, KEY)
    batch = _batch_for(cfg)
    loss, metrics = jax.jit(lambda p, b: lm.loss_fn(p, cfg, b))(params, batch)
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"
    logits = lm.forward(
        params, cfg, batch["tokens"],
        prefix_embeds=batch.get("prefix_embeds"),
        enc_embeds=batch.get("enc_embeds"),
    )
    S_total = batch["tokens"].shape[1] + (
        batch["prefix_embeds"].shape[1] if "prefix_embeds" in batch else 0
    )
    assert logits.shape == (2, S_total, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ["starcoder2_3b", "jamba_v0_1_52b", "xlstm_1_3b"])
def test_train_step_no_nans(arch):
    cfg = get_config(arch).reduced()
    params = lm.init_model(cfg, KEY)
    state = (
        opt.adafactor_init(params)
        if cfg.optimizer == "adafactor"
        else opt.adamw_init(params)
    )
    step = jax.jit(make_train_step(cfg))
    batch = _batch_for(cfg)
    p2, s2, m = step(params, state, batch)
    assert jnp.isfinite(m["loss"])
    leaves = jax.tree.leaves(p2)
    assert all(bool(jnp.all(jnp.isfinite(l.astype(jnp.float32)))) for l in leaves)


@pytest.mark.parametrize("arch", ["starcoder2_3b", "gemma2_9b"])
def test_loss_decreases_over_steps(arch):
    cfg = get_config(arch).reduced()
    params = lm.init_model(cfg, KEY)
    state = opt.adamw_init(params)
    step = jax.jit(make_train_step(cfg, opt.AdamWConfig(lr=3e-3, weight_decay=0.0)))
    batch = _batch_for(cfg, B=4, S=32)
    losses = []
    for _ in range(8):
        params, state, m = step(params, state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3, losses


def test_param_counts_match_full_configs():
    """Full-config parameter counts should be in the advertised ballpark."""
    expect = {
        "starcoder2_15b": (13e9, 18e9),
        "starcoder2_3b": (2.5e9, 4e9),
        "deepseek_7b": (6e9, 8e9),
        "gemma2_9b": (8e9, 11e9),
        "arctic_480b": (420e9, 520e9),
        "dbrx_132b": (115e9, 145e9),
        "jamba_v0_1_52b": (45e9, 60e9),
        "xlstm_1_3b": (0.9e9, 1.8e9),
    }
    for arch, (lo, hi) in expect.items():
        n = lm.num_params(get_config(arch))
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B params outside [{lo/1e9}, {hi/1e9}]"
