"""repro.obs: tracer determinism/export, decision-audit coherence (the term
re-sum invariant and term-for-term agreement with ``Scenario.analytic()``),
metrics primitives, run manifests, and the cluster audit reconstruction."""

import json
import math

import numpy as np
import pytest
from _prop import given, settings, st

from repro.core import (
    ClusterSpec,
    EdgeSpec,
    NetworkPath,
    Scenario,
    ServiceModel,
    Tier,
    Workload,
)
from repro.core.manager import ON_DEVICE
from repro.core.telemetry import EwmaEstimator, SlidingRateEstimator, WindowedMoments
from repro.fleet import Trace, predict_decisions, predict_terms, replay, simulate_cluster
from repro.obs import (
    AuditLog,
    DecisionAudit,
    Histogram,
    MetricsRegistry,
    ResumError,
    Tracer,
    audit_cluster,
    explain_flip,
    format_decision,
    manifest_delta,
    merge,
    render_report,
    run_manifest,
)
from repro.obs.manifest import config_hash
from repro.serving.gateway import OffloadGateway

WL = Workload(arrival_rate=10.0, req_bytes=25_000, res_bytes=2_000)

# regimes where the manager's aggregate-M/G/1 edge wait COINCIDES with the
# per-model dispatch in analytic() (no background tenants): exponential and
# deterministic at k=1 (P-K with CV^2=1 resp. 0), and GENERAL at any k
# (both sides call the same mg1 form). Audit-vs-analytic coherence is only
# claimed there; the re-sum invariant holds everywhere.
COINCIDING = [
    pytest.param(ServiceModel.EXPONENTIAL, 1.0, 0.0, id="exp-k1"),
    pytest.param(ServiceModel.DETERMINISTIC, 1.0, 0.0, id="det-k1"),
    pytest.param(ServiceModel.GENERAL, 4.0, 2.5e-5, id="general-k4"),
]


def _scn(model, k, var, *, bw=2.5e6, return_results=True):
    return Scenario(
        workload=WL,
        device=Tier("dev", 0.035, service_model=ServiceModel.DETERMINISTIC),
        edges=(
            EdgeSpec(Tier("e0", 0.008, parallelism_k=k, service_model=model,
                          service_var=var)),
            EdgeSpec(Tier("e1", 0.012, parallelism_k=k, service_model=model,
                          service_var=var)),
        ),
        network=NetworkPath(bandwidth_Bps=bw),
        return_results=return_results,
        name="obs-test",
    )


def _step_metrics(scn, *, bandwidth_Bps=None):
    return {
        "workload": scn.workload,
        "lam_dev": scn.workload.arrival_rate,
        "bandwidth_Bps": (scn.network.bandwidth_Bps
                          if bandwidth_Bps is None else bandwidth_Bps),
        "edges": [e.to_state(scn.workload) for e in scn.edges],
    }


class TestAuditAnalyticCoherence:
    @pytest.mark.parametrize("model,k,var", COINCIDING)
    @pytest.mark.parametrize("return_results", [True, False])
    def test_audited_terms_equal_analytic_breakdowns(self, model, k, var,
                                                     return_results):
        """The audit row IS the closed form: every logged term equals the
        matching ``Scenario.analytic()`` breakdown term, and the logged
        totals equal the analytic totals — on both network-leg strategies."""
        scn = _scn(model, k, var, return_results=return_results)
        auditor = AuditLog()
        mgr = scn.manager(auditor=auditor)
        mgr.step(0.0, _step_metrics(scn))
        assert len(auditor) == 1
        row = auditor.rows[0]
        pred = scn.analytic()
        for strat, breakdown in pred.items():
            assert row.totals[strat] == pytest.approx(
                float(np.asarray(breakdown.total)), rel=1e-12, abs=1e-15)
            audited = row.terms[strat]
            assert set(audited) == set(breakdown.terms)
            for term, v in breakdown.terms.items():
                assert audited[term] == pytest.approx(
                    float(np.asarray(v)), rel=1e-12, abs=1e-15), \
                    f"{strat}.{term} diverged from analytic()"
        assert auditor.verify() <= 1e-9

    @pytest.mark.parametrize("model,k,var", COINCIDING)
    def test_bandwidth_sweep_stays_coherent(self, model, k, var):
        """Across a bandwidth sweep through the crossover the audited chosen
        total always equals the analytic total of the same strategy."""
        auditor = AuditLog()
        # floor above the NIC-stability bound (lam * D_req = 0.25e6)
        for i, bw in enumerate(np.geomspace(0.3e6, 5e6, 24)):
            scn = _scn(model, k, var, bw=float(bw))
            mgr = scn.manager(auditor=auditor)
            mgr.step(float(i), _step_metrics(scn))
            row = auditor.rows[-1]
            totals = scn.analytic().totals()
            assert row.predicted_latency_s == pytest.approx(
                totals[row.chosen], rel=1e-12)
        assert auditor.verify() <= 1e-9
        chosen = {r.chosen for r in auditor.rows}
        assert "on_device" in chosen  # the sweep actually crosses over
        assert any(c.startswith("edge[") for c in chosen)


class TestResumInvariant:
    def test_manager_sweep(self):
        scn = _scn(ServiceModel.EXPONENTIAL, 1.0, 0.0)
        auditor = AuditLog()
        mgr = scn.manager(auditor=auditor, hysteresis=0.1)
        for i in range(60):
            bw = 2.5e6 * (0.1 + 1.9 * (i % 20) / 19.0)
            mgr.step(float(i), _step_metrics(scn, bandwidth_Bps=bw))
        assert len(auditor) == 60
        assert auditor.verify() <= 1e-9

    def test_gateway_path(self):
        scn = _scn(ServiceModel.EXPONENTIAL, 1.0, 0.0)
        auditor = AuditLog()
        metrics = MetricsRegistry()
        gw = OffloadGateway.from_scenario(scn, epoch_s=1.0, auditor=auditor,
                                          metrics=metrics)
        t = 0.0
        for epoch in range(8):
            gw.observe_bandwidth(2.5e6 if epoch < 4 else 0.25e6)
            for _ in range(10):
                t += 0.1
                gw.observe_arrival(t)
            gw.decide(now=float(epoch + 1))
        assert len(auditor) == 8
        assert all(r.source == "gateway" for r in auditor)
        assert auditor.verify() <= 1e-9
        snap = metrics.snapshot()
        assert snap["counters"]["gateway.decisions"] == 8

    def test_replay_path(self):
        scn = _scn(ServiceModel.EXPONENTIAL, 1.0, 0.0)
        times = np.arange(12, dtype=float)
        trace = Trace(
            times=times,
            bandwidth_Bps=np.where(times < 6, 2.5e6, 0.25e6),
            arrival_rate=np.full(12, WL.arrival_rate),
            edge_bg_rate=np.zeros((12, 2)),
        )
        auditor = AuditLog()
        res = replay(scn, trace, auditor=auditor)
        assert len(auditor) == trace.n_epochs
        assert all(r.source == "replay" for r in auditor)
        assert auditor.verify() <= 1e-9
        # the audited choices are the replay's own adaptive targets
        targets = res.policies["adaptive"].targets
        assert [r.edge_index for r in auditor] == list(targets)

    def test_slo_quantile_mode(self):
        """In SLO mode totals are q-quantiles, so the re-sum invariant binds
        terms to the mean ``term_totals`` only — and still verifies."""
        scn = _scn(ServiceModel.EXPONENTIAL, 1.0, 0.0)
        auditor = AuditLog()
        mgr = scn.manager(auditor=auditor, slo_quantile=0.99)
        mgr.step(0.0, _step_metrics(scn))
        row = auditor.rows[0]
        assert row.decision_metric == "p99"
        assert row.slo_quantile == 0.99
        # quantile totals exceed the mean decomposition on every finite path
        for strat, t in row.totals.items():
            if math.isfinite(t):
                assert t > row.term_totals[strat]
        assert auditor.verify() <= 1e-9

    def test_dead_link_audits_inf_and_verifies(self):
        scn = _scn(ServiceModel.EXPONENTIAL, 1.0, 0.0)
        auditor = AuditLog()
        mgr = scn.manager(auditor=auditor)
        d = mgr.step(0.0, _step_metrics(scn, bandwidth_Bps=0.0))
        assert d.edge_index == ON_DEVICE
        row = auditor.rows[0]
        for j in range(2):
            assert math.isinf(row.totals[f"edge[{j}]"])
            assert math.isinf(row.terms[f"edge[{j}]"]["w_net_dev"])
        assert not math.isnan(row.margin_s)  # inf alt - finite chosen = +inf
        assert auditor.verify() <= 1e-9

    def test_hysteresis_engaged_flag(self):
        """When hysteresis holds the previous target against a raw-rule flip,
        the audit row says so."""
        scn = _scn(ServiceModel.EXPONENTIAL, 1.0, 0.0)
        auditor = AuditLog()
        mgr = scn.manager(auditor=auditor, hysteresis=0.5)
        mgr.step(0.0, _step_metrics(scn, bandwidth_Bps=2.5e6))  # offload
        first = auditor.rows[0]
        assert not first.hysteresis["engaged"]
        # drop bandwidth just past the crossover: the raw rule flips to
        # on_device but a 50% improvement bar keeps the edge target
        mgr.step(1.0, _step_metrics(scn, bandwidth_Bps=0.9e6))
        row = auditor.rows[1]
        assert row.hysteresis["hysteresis"] == 0.5
        assert row.hysteresis["engaged"]
        assert row.edge_index == first.edge_index
        assert row.margin_s < 0  # held against a better raw alternative
        assert auditor.verify() <= 1e-9

    def test_verify_raises_on_cooked_books(self):
        scn = _scn(ServiceModel.EXPONENTIAL, 1.0, 0.0)
        auditor = AuditLog()
        scn.manager(auditor=auditor).step(0.0, _step_metrics(scn))
        row = auditor.rows[0]
        bad = DecisionAudit(**{**row.__dict__,
                               "term_totals": {k: v + 1e-6
                                               for k, v in row.term_totals.items()}})
        log = AuditLog()
        log.rows.append(bad)
        with pytest.raises(ResumError):
            log.verify()

    def test_audit_jsonl_round_trip_preserves_inf(self):
        scn = _scn(ServiceModel.EXPONENTIAL, 1.0, 0.0)
        auditor = AuditLog()
        mgr = scn.manager(auditor=auditor)
        mgr.step(0.0, _step_metrics(scn, bandwidth_Bps=0.0))
        mgr.step(1.0, _step_metrics(scn))
        text = auditor.to_jsonl()
        back = AuditLog.from_jsonl(text)
        assert back.to_jsonl() == text
        assert math.isinf(back.rows[0].totals["edge[0]"])
        assert back.verify() <= 1e-9


def _small_cluster_spec():
    return ClusterSpec(
        base=Scenario(
            workload=Workload(2.0, 30_000, 1_000, name="inceptionv4"),
            device=Tier("orin", 0.045),
            edges=(
                EdgeSpec(Tier("a2", 0.028)),
                EdgeSpec(Tier("t4", 0.020, service_model=ServiceModel.EXPONENTIAL)),
            ),
            network=NetworkPath(20e6 / 8),
        ),
        n_clients=4,
        name="obs-small",
    )


class TestClusterAudit:
    def test_predict_terms_matches_predict_decisions_bitwise(self):
        spec = _small_cluster_spec()
        rng = np.random.default_rng(7)
        n, e = spec.n_clients, spec.n_edges
        lam = rng.uniform(0.5, 4.0, size=n)
        bw = rng.uniform(0.5e6, 4e6, size=n)
        endo = rng.uniform(0.0, 3.0, size=(n, e))
        exo = rng.uniform(0.0, 2.0, size=e)
        _, t_dev, t_edge = predict_decisions(spec, lam, bw, endo, exo)
        terms = predict_terms(spec, lam, bw, endo, exo)
        np.testing.assert_array_equal(terms["t_dev"], t_dev)
        np.testing.assert_array_equal(terms["t_edge"], t_edge)
        # and the term arrays re-sum to those totals
        dev_sum = terms["w_proc_dev"] + terms["s_dev"]
        edge_sum = (terms["w_net_dev"] + terms["n_req"] + terms["w_proc_edge"]
                    + terms["s_edge"] + terms["w_net_edge"] + terms["n_res"])
        np.testing.assert_allclose(dev_sum, t_dev, rtol=0, atol=1e-12)
        fin = np.isfinite(t_edge)
        np.testing.assert_allclose(edge_sum[fin], t_edge[fin], rtol=0, atol=1e-12)

    def test_audit_cluster_agrees_with_scan(self):
        spec = _small_cluster_spec()
        times = np.arange(10, dtype=float)
        trace = Trace(
            times=times,
            bandwidth_Bps=np.where(times < 5, 2.5e6, 0.3e6),
            arrival_rate=np.full(10, 2.0),
            edge_bg_rate=np.zeros((10, 2)),
        )
        res = simulate_cluster(spec, trace, policies=("adaptive",), stagger=1,
                               hysteresis=0.0)
        log = audit_cluster(res)
        choices = res.policies["adaptive"].choices
        assert len(log) == choices.size
        assert log.verify() <= 1e-9
        by_key = {(r.epoch, r.source): r for r in log}
        for t in range(choices.shape[0]):
            for i in range(choices.shape[1]):
                row = by_key[(t, f"cluster[{i}]")]
                assert row.edge_index == int(choices[t, i])

    def test_audit_cluster_subsetting(self):
        spec = _small_cluster_spec()
        trace = Trace(
            times=np.arange(6, dtype=float),
            bandwidth_Bps=np.full(6, 2.5e6),
            arrival_rate=np.full(6, 2.0),
            edge_bg_rate=np.zeros((6, 2)),
        )
        res = simulate_cluster(spec, trace, policies=("adaptive",))
        log = audit_cluster(res, epochs=[1, 3], clients=[0])
        assert len(log) == 2
        assert {r.source for r in log} == {"cluster[0]"}


class TestTracer:
    def _populate(self, tr):
        tr.span(name="prefill", cat="prefill", t=0.10, dur=0.02,
                track="engine", rid=1)
        tr.span(name="decode", cat="decode", t=0.12, dur=0.30,
                track="engine", rid=1)
        tr.instant(name="respond", cat="respond", t=0.42, track="engine", rid=1)
        tr.span(name="req", cat="transfer", t=0.0, dur=0.05,
                track="edge[0]", bytes=25_000)

    def test_disabled_records_nothing(self):
        tr = Tracer(enabled=False)
        self._populate(tr)
        assert len(tr.spans) == 0
        assert tr.to_jsonl() == ""

    def test_jsonl_round_trip_byte_stable(self):
        tr = Tracer()
        self._populate(tr)
        text = tr.to_jsonl()
        back = Tracer.from_jsonl(text)
        assert back.to_jsonl() == text
        assert [s.name for s in back.spans] == [s.name for s in tr.spans]

    def test_chrome_export_structure(self):
        tr = Tracer()
        self._populate(tr)
        doc = tr.to_chrome()
        assert doc["displayTimeUnit"] == "ms"
        events = doc["traceEvents"]
        meta = [e for e in events if e["ph"] == "M"]
        assert {m["args"]["name"] for m in meta} == {"engine", "edge[0]"}
        xs = [e for e in events if e["ph"] == "X"]
        instants = [e for e in events if e["ph"] == "i"]
        assert len(xs) == 3 and len(instants) == 1
        assert all(e["pid"] == 1 for e in events)
        assert instants[0]["s"] == "t"
        decode = next(e for e in xs if e["name"] == "decode")
        assert decode["ts"] == pytest.approx(0.12e6)
        assert decode["dur"] == pytest.approx(0.30e6)
        json.dumps(doc)  # must be serialisable as-is

    def test_merge_sorts_by_start_time(self):
        a, b = Tracer(), Tracer()
        a.span(name="late", cat="c", t=1.0, dur=0.1, track="a")
        b.span(name="early", cat="c", t=0.5, dur=0.1, track="b")
        m = merge([a, b])
        assert [s.name for s in m.spans] == ["early", "late"]

    def test_nonfinite_attrs_canonicalised(self):
        """inf/nan attrs are coerced to canonical strings at record time, so
        the JSONL never emits non-standard JSON and round-trips exactly."""
        tr = Tracer()
        tr.instant(name="x", cat="c", t=0.0, track="t",
                   val=float("inf"), n=np.int64(3))
        assert dict(tr.spans[0].attrs) == {"val": "inf", "n": 3}
        back = Tracer.from_jsonl(tr.to_jsonl())
        assert back.to_jsonl() == tr.to_jsonl()

    def test_engine_run_byte_stable_across_reruns(self):
        """Same seed + simulated clock => byte-identical trace stream from a
        real engine run (the enabled-tracer determinism acceptance)."""
        from repro.measure.harness import HarnessConfig, run_harness

        hc = HarnessConfig(arch="starcoder2_3b", slots=1, seed=0, n_requests=6,
                           clock="simulated")
        streams = []
        for _ in range(2):
            tr = Tracer()
            run_harness(hc, tracer=tr)
            streams.append(tr.to_jsonl())
        assert streams[0] == streams[1]
        assert streams[0]  # and it actually traced something
        cats = {s.cat for s in Tracer.from_jsonl(streams[0]).spans}
        assert {"queue", "prefill", "respond"} <= cats


class TestMetrics:
    def test_counter_and_gauge(self):
        reg = MetricsRegistry()
        c = reg.counter("reqs")
        c.inc()
        c.inc(4)
        with pytest.raises(ValueError):
            c.inc(-1)
        g = reg.gauge("bw")
        g.set(2.5e6)
        with pytest.raises(ValueError):
            g.set(float("nan"))
        assert reg.counter("reqs") is c  # get-or-create
        snap = reg.snapshot()
        assert snap["counters"]["reqs"] == 5
        assert snap["gauges"]["bw"] == 2.5e6

    def test_histogram_percentiles_bracket_samples(self):
        h = Histogram()
        vals = np.geomspace(1e-3, 1.0, 500)
        for v in vals:
            h.record(float(v))
        assert h.count == 500
        assert h.min == pytest.approx(1e-3)
        assert h.max == pytest.approx(1.0)
        # log-bucketed percentile is within one bucket's relative growth
        assert h.p50 == pytest.approx(np.percentile(vals, 50), rel=0.10)
        assert h.p99 == pytest.approx(np.percentile(vals, 99), rel=0.10)
        with pytest.raises(ValueError):
            h.record(float("inf"))

    def test_render_is_line_oriented(self):
        reg = MetricsRegistry()
        reg.counter("a").inc()
        reg.gauge("b").set(1.0)
        reg.histogram("c").record(0.5)
        lines = reg.render(prefix="x.").splitlines()
        assert len(lines) == 3
        assert all(line.startswith("x.") for line in lines)


class TestManifest:
    def test_keys_and_determinism(self):
        m = run_manifest(seed=3, config={"a": 1})
        assert m["seed"] == 3
        for key in ("manifest_version", "git", "python", "platform",
                    "packages", "config_sha256"):
            assert key in m
        assert m == run_manifest(seed=3, config={"a": 1})
        assert "timestamp" not in json.dumps(m)  # replayable: no wall clock

    def test_config_hash_is_order_insensitive(self):
        assert config_hash({"a": 1, "b": 2}) == config_hash({"b": 2, "a": 1})
        assert config_hash({"a": 1}) != config_hash({"a": 2})
        assert config_hash(None) is None

    def test_manifest_delta(self):
        a = run_manifest(seed=0)
        assert manifest_delta(a, a) == []
        b = json.loads(json.dumps(a))
        b["packages"]["jax"] = "0.0.0"
        notes = manifest_delta(a, b)
        assert any("jax" in n for n in notes)
        assert manifest_delta(None, a) == []  # absent side: nothing to say


class TestReport:
    def _two_rows(self):
        auditor = AuditLog()
        scn = _scn(ServiceModel.EXPONENTIAL, 1.0, 0.0)
        mgr = scn.manager(auditor=auditor)
        mgr.step(0.0, _step_metrics(scn, bandwidth_Bps=2.5e6))
        mgr.step(1.0, _step_metrics(scn, bandwidth_Bps=0.2e6))
        return auditor

    def test_format_decision_and_flips(self):
        auditor = self._two_rows()
        line = format_decision(auditor.rows[0])
        assert auditor.rows[0].chosen in line
        flips = auditor.flips()
        assert len(flips) == 1
        text = explain_flip(*flips[0])
        assert "w_net_dev" in text and "on_device" in text

    def test_render_report_smoke(self):
        tr = Tracer()
        tr.span(name="prefill", cat="prefill", t=0.0, dur=0.01, track="engine")
        reg = MetricsRegistry()
        reg.counter("n").inc()
        md = render_report(tracer=tr, audit=self._two_rows(), metrics=reg,
                           title="T")
        assert md.startswith("# T")
        assert "prefill" in md and "flip" in md


class TestTelemetryGuards:
    @pytest.mark.parametrize("bad", [float("nan"), float("inf"), -float("inf")])
    def test_sliding_rate_rejects_nonfinite(self, bad):
        est = SlidingRateEstimator(window_s=10.0)
        with pytest.raises(ValueError):
            est.record(bad)

    @pytest.mark.parametrize("bad", [float("nan"), float("inf")])
    def test_ewma_rejects_nonfinite(self, bad):
        with pytest.raises(ValueError):
            EwmaEstimator(alpha=0.5, initial=bad)
        est = EwmaEstimator(alpha=0.5, initial=1.0)
        with pytest.raises(ValueError):
            est.update(bad)

    def test_windowed_moments_rejects_nonfinite(self):
        wm = WindowedMoments()
        with pytest.raises(ValueError):
            wm.record(float("nan"))

    @settings(max_examples=60, deadline=None)
    @given(st.lists(st.floats(0.0, 5.0), min_size=1, max_size=40),
           st.floats(0.5, 20.0))
    def test_sliding_rate_eviction_boundary(self, dts, window):
        """The estimator's rate always equals count-in-window / window, with
        the boundary convention that an event exactly ``window_s`` old is
        still inside (strict-< eviction)."""
        est = SlidingRateEstimator(window_s=window)
        t = 0.0
        times = []
        for dt in dts:
            t += dt
            times.append(t)
            est.record(t)
        now = times[-1]
        expected = sum(1 for u in times if u >= now - window) / window
        assert est.rate(now) == pytest.approx(expected, rel=1e-12)

    def test_sliding_rate_exact_window_edge_included(self):
        est = SlidingRateEstimator(window_s=10.0)
        est.record(0.0)
        est.record(5.0)
        assert est.rate(10.0) == pytest.approx(2 / 10.0)  # 0.0 is exactly 10s old
        assert est.rate(10.0 + 1e-9) == pytest.approx(1 / 10.0)
