"""One import point for property testing: real hypothesis when installed,
the seeded fallback otherwise — and a switch to force the fallback.

Every property-test module imports from here::

    from _prop import USING_FALLBACK, assume, example, given, settings, st

CI runs the suite twice: once with hypothesis installed (the default
``.[test]`` environment) and once with ``REPRO_FORCE_HYPOTHESIS_FALLBACK=1``,
so the fallback — the only engine available inside the hermetic container —
keeps exercising exactly the same strategy definitions as the real library.
"""

from __future__ import annotations

import os

_FORCE = os.environ.get("REPRO_FORCE_HYPOTHESIS_FALLBACK", "") not in ("", "0")

try:
    if _FORCE:
        raise ModuleNotFoundError("fallback forced via REPRO_FORCE_HYPOTHESIS_FALLBACK")
    from hypothesis import assume, example, given, settings
    from hypothesis import strategies as st

    USING_FALLBACK = False
except ModuleNotFoundError:
    import _hypothesis_fallback as st
    from _hypothesis_fallback import assume, example, given, settings

    USING_FALLBACK = True

__all__ = ["USING_FALLBACK", "assume", "example", "given", "settings", "st"]
