"""Paper §3.5 "Model limitations": bursty (non-Poisson) arrivals and the
G/G/1 Marshall bound — validated against simulation, which the paper itself
does not do. Also covers the gateway's behaviour under burstiness (the
adaptive manager consumes a windowed rate estimate, so bursts inflate its
lambda-hat exactly as they should)."""

import heapq

import numpy as np
import pytest

from repro.core import queueing as Q
from repro.core import simulation as S


def bursty_arrivals(lam: float, n: int, rng, *, burst: int = 4, cv2: float = 4.0):
    """Batched-Poisson arrivals: bursts of `burst` jobs at Poisson epochs —
    interarrival variance far above exponential (squared CV ~= cv2)."""
    epochs = np.cumsum(rng.exponential(burst / lam, size=n // burst + 1))
    times = np.repeat(epochs, burst)[:n]
    return times


class TestGG1Bound:
    @pytest.mark.parametrize("rho", [0.3, 0.6])
    def test_marshall_bound_holds_for_bursty_arrivals(self, rho):
        lam, n = 5.0, 120_000
        mu = lam / rho
        rng = np.random.default_rng(0)
        arr = bursty_arrivals(lam, n, rng)
        services = rng.exponential(1 / mu, size=n)
        dep = S.station_pass(arr, services, 1)
        waits = dep - arr - services
        obs_wait = float(np.mean(waits[n // 10 :]))
        # empirical interarrival variance feeds the bound
        ia = np.diff(arr)
        bound = Q.gg1_wait_upper_bound(lam, mu, float(np.var(ia)), 1 / mu**2)
        assert obs_wait <= bound * 1.02  # bound holds (2% sim tolerance)

    def test_poisson_case_bound_is_tight_ish(self):
        """For M/M/1 the Marshall bound equals the exact wait at rho->1 and
        stays within ~2x at moderate loads."""
        lam, mu = 6.0, 10.0
        exact = Q.mm1_wait(lam, mu)
        bound = Q.gg1_wait_upper_bound(lam, mu, 1 / lam**2, 1 / mu**2)
        assert exact <= bound <= 2.0 * exact

    def test_burstiness_raises_latency_vs_poisson(self):
        """The paper's motivation for §3.5: same lambda, burstier arrivals,
        strictly worse latency — the closed Poisson forms would be optimistic."""
        lam, mu, n = 5.0, 10.0, 120_000
        rng = np.random.default_rng(1)
        services = rng.exponential(1 / mu, size=n)
        arr_p = S.poisson_arrivals(lam, n, np.random.default_rng(2))
        arr_b = bursty_arrivals(lam, n, np.random.default_rng(3))
        w_p = float(np.mean((S.station_pass(arr_p, services, 1) - arr_p)[n // 10 :]))
        w_b = float(np.mean((S.station_pass(arr_b, services, 1) - arr_b)[n // 10 :]))
        assert w_b > w_p * 1.3


class TestFiniteBufferNote:
    def test_saturated_queue_latency_grows_unboundedly_without_buffer(self):
        """Documents the infinite-buffer assumption (paper §3.5): above
        saturation the simulated mean grows with horizon, it does not settle."""
        lam, mu = 12.0, 10.0  # rho = 1.2
        short = S.simulate_on_device(lam, S.Exponential(1 / mu), n=5_000, seed=0)
        long = S.simulate_on_device(lam, S.Exponential(1 / mu), n=40_000, seed=0)
        assert long.mean > 2.0 * short.mean
        assert Q.mm1_wait(lam, mu) == float("inf")
