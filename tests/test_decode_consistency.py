"""Integration: prefill + decode must reproduce full-forward logits for every
architecture family (KV caches, ring buffers, SSM states, cross-attention)."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import lm

KEY = jax.random.PRNGKey(1)


def _pad_kv(caches, total):
    """Grow seq-capacity caches by one slot for the decode write."""

    def f(path, x):
        if x.ndim == 5 and x.shape[2] == total:
            return jnp.pad(x, ((0, 0), (0, 0), (0, 1), (0, 0), (0, 0)))
        return x

    return jax.tree_util.tree_map_with_path(f, caches)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch):
    cfg = get_config(arch).reduced(seq_chunk=8)
    params = lm.init_model(cfg, KEY)
    B, S = 2, 24
    kw = {}
    if cfg.is_encdec:
        kw["enc_embeds"] = jax.random.normal(KEY, (B, S, cfg.d_model), jnp.float32)
    P = int(S * cfg.prefix_len_fraction) if (cfg.prefix_embed and not cfg.is_encdec) else 0
    if P:
        kw["prefix_embeds"] = jax.random.normal(KEY, (B, P, cfg.d_model), jnp.float32)
    tokens = jax.random.randint(KEY, (B, S - P), 0, cfg.vocab_size)

    logits_full = lm.forward(params, cfg, tokens, **kw)
    lg, caches = lm.prefill(params, cfg, tokens[:, :-1], **kw)

    # prefill last-position logits == forward on the short sequence
    logits_short = lm.forward(params, cfg, tokens[:, :-1], **kw)
    assert float(jnp.max(jnp.abs(lg[:, 0] - logits_short[:, -1]))) < 2e-3

    total = S - 1
    caches = _pad_kv(caches, total)
    logits_dec, new_caches = lm.decode_step(
        params, cfg, tokens[:, -1:], jnp.int32(total), caches
    )
    err = float(jnp.max(jnp.abs(logits_dec[:, 0] - logits_full[:, -1])))
    assert err < 2e-3, f"{arch}: decode/forward mismatch {err}"
    # caches keep their structure
    assert jax.tree.structure(new_caches) == jax.tree.structure(caches)


def test_multi_token_greedy_decode_matches_teacher_forcing():
    """Decode 4 tokens autoregressively; teacher-forcing the same tokens
    through forward() must predict the identical next tokens."""
    cfg = get_config("starcoder2_3b").reduced(seq_chunk=8)
    params = lm.init_model(cfg, KEY)
    B, S0, steps = 1, 12, 4
    prompt = jax.random.randint(KEY, (B, S0), 0, cfg.vocab_size)
    lg, caches = lm.prefill(params, cfg, prompt)
    caches = jax.tree_util.tree_map_with_path(
        lambda p, x: jnp.pad(x, ((0, 0), (0, 0), (0, steps), (0, 0), (0, 0)))
        if x.ndim == 5 and x.shape[2] == S0
        else x,
        caches,
    )
    toks = [int(jnp.argmax(lg[0, 0]))]
    for i in range(steps - 1):
        lg_i, caches = lm.decode_step(
            params, cfg, jnp.asarray([[toks[-1]]], jnp.int32), jnp.int32(S0 + i), caches
        )
        toks.append(int(jnp.argmax(lg_i[0, 0])))
    # teacher forcing
    seq = jnp.concatenate([prompt, jnp.asarray([toks[:-1]], jnp.int32)], axis=1)
    full = lm.forward(params, cfg, seq)
    expected = [int(jnp.argmax(full[0, S0 - 1 + i])) for i in range(steps)]
    assert toks == expected
