"""Tests for the end-to-end latency models (Eq. 1/2) and Lemmas 3.1-3.3."""

import numpy as np
import pytest

from _prop import given, settings, st

from repro.core.latency import (
    NetworkPath,
    ServiceModel,
    Tier,
    Workload,
    edge_offload_latency,
    lemma31_rhs,
    lemma32_rhs,
    lemma33_rhs,
    offload_wins,
    on_device_latency,
)
from repro.core.multitenant import TenantStream, aggregate_streams, multitenant_edge_latency
from repro.core.split import LayerProfile, SplitPlanner, SplitPoint, split_latency

WL = Workload(arrival_rate=2.0, req_bytes=200_000, res_bytes=10_000)
NET = NetworkPath(bandwidth_Bps=5e6 / 8)  # 5 Mbps
DEV = Tier("dev", 0.050, parallelism_k=1, service_model=ServiceModel.DETERMINISTIC)
EDGE = Tier("edge", 0.010, parallelism_k=2, service_model=ServiceModel.DETERMINISTIC)


class TestEndToEnd:
    def test_on_device_decomposition(self):
        b = on_device_latency(WL, DEV, breakdown=True)
        assert b.total == pytest.approx(b["w_proc_dev"] + b["s_dev"])

    def test_edge_decomposition_matches_eq1(self):
        b = edge_offload_latency(WL, EDGE, NET, breakdown=True)
        total = sum(
            np.asarray(b[k])
            for k in ("w_net_dev", "n_req", "w_proc_edge", "s_edge", "w_net_edge", "n_res")
        )
        assert float(b.total) == pytest.approx(float(total))

    def test_results_consumed_at_edge_drops_return_path(self):
        t_with = float(edge_offload_latency(WL, EDGE, NET))
        t_without = float(edge_offload_latency(WL, EDGE, NET, return_results=False))
        assert t_without < t_with

    def test_broadcasting_bandwidth_sweep(self):
        nets = NetworkPath(bandwidth_Bps=np.logspace(5, 8, 16))
        t = edge_offload_latency(WL, EDGE, nets)
        assert t.shape == (16,)
        # latency decreases with bandwidth
        finite = np.isfinite(t)
        assert np.all(np.diff(t[finite]) <= 1e-12)

    def test_saturated_network_is_inf(self):
        slow = NetworkPath(bandwidth_Bps=WL.req_bytes * WL.arrival_rate * 0.9)
        assert float(edge_offload_latency(WL, EDGE, slow)) == np.inf


class TestLemmas:
    """Each lemma states: on-device wins  <=>  s_dev - s_edge < RHS.
    Verify the inequality agrees with the direct Eq.1-vs-Eq.2 comparison."""

    @given(
        st.floats(0.001, 0.2),  # s_dev
        st.floats(0.001, 0.2),  # s_edge
        st.floats(0.1, 20.0),  # lam
        st.floats(1e5, 1e8),  # bandwidth
    )
    @settings(max_examples=300, deadline=None)
    def test_lemma31_consistency(self, s_dev, s_edge, lam, bw):
        wl = Workload(lam, 100_000, 5_000)
        net = NetworkPath(bw)
        dev = Tier("d", s_dev, service_model=ServiceModel.DETERMINISTIC)
        edge = Tier("e", s_edge, service_model=ServiceModel.DETERMINISTIC)
        t_dev = float(on_device_latency(wl, dev))
        t_edge = float(edge_offload_latency(wl, edge, net))
        if not (np.isfinite(t_dev) and np.isfinite(t_edge)):
            return
        rhs = float(lemma31_rhs(wl, dev, edge, net))
        device_wins = t_dev < t_edge
        assert device_wins == ((s_dev - s_edge) < rhs)

    @given(
        st.floats(0.001, 0.2),
        st.floats(0.001, 0.2),
        st.floats(0.1, 20.0),
        st.floats(1e5, 1e8),
    )
    @settings(max_examples=300, deadline=None)
    def test_lemma33_consistency(self, s_dev, s_edge, lam, bw):
        wl = Workload(lam, 100_000, 5_000)
        net = NetworkPath(bw)
        dev = Tier("d", s_dev, service_model=ServiceModel.EXPONENTIAL)
        edge = Tier("e", s_edge, service_model=ServiceModel.EXPONENTIAL)
        t_dev = float(on_device_latency(wl, dev))
        t_edge = float(edge_offload_latency(wl, edge, net))
        if not (np.isfinite(t_dev) and np.isfinite(t_edge)):
            return
        rhs = float(lemma33_rhs(wl, dev, edge, net))
        assert (t_dev < t_edge) == ((s_dev - s_edge) < rhs)

    def test_lemma32_multitenant_consistency(self):
        streams = [
            TenantStream(2.0, 0.02, 0.0),
            TenantStream(3.0, 0.05, 0.001),
            TenantStream(1.0, 0.01, 0.0),
        ]
        agg = aggregate_streams(streams)
        wl = Workload(2.0, 200_000, 10_000)
        dev = Tier("d", 0.05, service_model=ServiceModel.DETERMINISTIC)
        edge = Tier("e", agg.service_mean_s, service_model=ServiceModel.GENERAL,
                    service_var=agg.service_var)
        t_dev = float(on_device_latency(wl, dev))
        t_edge = float(multitenant_edge_latency(wl, edge, NET, streams))
        rhs = float(
            lemma32_rhs(
                wl, dev, edge, NET,
                edge_arrival_rate=agg.arrival_rate,
                edge_service_var=agg.service_var,
            )
        )
        assert (t_dev < t_edge) == ((dev.service_time_s - edge.service_time_s) < rhs)

    def test_remark31_light_workloads_prefer_device(self):
        """Remark 3.1: scale compute demand down -> device advantage grows."""
        def gap(scale):
            dev = DEV.with_service(DEV.service_time_s * scale)
            edge = EDGE.with_service(EDGE.service_time_s * scale)
            return float(edge_offload_latency(WL, edge, NET)) - float(
                on_device_latency(WL, dev)
            )
        # edge advantage (negative gap) shrinks as demand shrinks
        assert gap(0.01) > gap(1.0) or gap(0.01) > 0

    def test_remark32_slow_network_prefers_device(self):
        fast = NetworkPath(1e8)
        slow = NetworkPath(1e4)
        adv_fast = float(edge_offload_latency(WL, EDGE, fast)) - float(on_device_latency(WL, DEV))
        adv_slow = float(edge_offload_latency(WL, EDGE, slow)) - float(on_device_latency(WL, DEV))
        assert adv_slow > adv_fast


class TestMultitenant:
    def test_poisson_superposition(self):
        agg = aggregate_streams([TenantStream(1.0, 0.01), TenantStream(2.5, 0.02)])
        assert agg.arrival_rate == pytest.approx(3.5)

    def test_weighted_mean_service(self):
        agg = aggregate_streams([TenantStream(1.0, 0.010), TenantStream(3.0, 0.030)])
        assert agg.service_mean_s == pytest.approx((1 * 0.01 + 3 * 0.03) / 4)

    @given(st.lists(st.tuples(st.floats(0.1, 10), st.floats(0.001, 0.1)), min_size=1, max_size=6))
    @settings(max_examples=100, deadline=None)
    def test_mixture_variance_nonnegative_and_zero_for_identical(self, items):
        streams = [TenantStream(l, s) for l, s in items]
        agg = aggregate_streams(streams)
        assert agg.service_var >= 0
        same = [TenantStream(l, 0.02) for l, _ in items]
        assert aggregate_streams(same).service_var == pytest.approx(0.0, abs=1e-12)

    def test_latency_increases_with_tenants(self):
        wl = Workload(2.0, 200_000, 10_000)
        t = [
            float(
                multitenant_edge_latency(
                    wl, EDGE, NET, [TenantStream(2.0, EDGE.service_time_s)] * m
                )
            )
            for m in (1, 4, 8)
        ]
        finite = [x for x in t if np.isfinite(x)]
        assert all(a <= b + 1e-12 for a, b in zip(finite, finite[1:]))


class TestSplit:
    def test_full_offload_degenerates_to_edge(self):
        sp = SplitPoint(dev_service_s=0.0, edge_service_s=EDGE.service_time_s,
                        inter_bytes=WL.req_bytes)
        t_split = float(split_latency(WL, DEV, EDGE, NET, sp))
        t_edge = float(edge_offload_latency(WL, EDGE, NET))
        assert t_split == pytest.approx(t_edge, rel=1e-9)

    def test_full_local_degenerates_to_device(self):
        sp = SplitPoint(dev_service_s=DEV.service_time_s, edge_service_s=0.0, inter_bytes=0.0)
        assert float(split_latency(WL, DEV, EDGE, NET, sp)) == pytest.approx(
            float(on_device_latency(WL, DEV))
        )

    def test_planner_picks_argmin(self):
        layers = [
            LayerProfile(dev_service_s=0.004, edge_service_s=0.001, out_bytes=80_000)
            for _ in range(6)
        ]
        planner = SplitPlanner(layers, WL)
        plan = planner.plan(DEV, EDGE, NET)
        sweep = planner.sweep(DEV, EDGE, NET)
        assert plan.latency_s == pytest.approx(float(np.min(sweep)))
        assert plan.index == int(np.argmin(sweep))

    def test_growing_intermediate_disfavours_late_splits(self):
        """Paper §4.6: later split points ship larger activations."""
        layers = [
            LayerProfile(0.002, 0.0005, out_bytes=50_000 * (i + 1)) for i in range(5)
        ]
        planner = SplitPlanner(layers, WL)
        sweep = planner.sweep(DEV, EDGE, NET)
        interior = sweep[1:-1]
        finite = interior[np.isfinite(interior)]
        assert np.all(np.diff(finite) >= -1e-9)
