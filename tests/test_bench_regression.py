"""The bench-regression gate itself: a synthetic slowdown must exit nonzero,
tolerance math must hold in both directions, missing metrics are loud, and
``benchmarks.run --only`` rejects unknown families."""

import copy
import json

import pytest

from benchmarks.check_regression import (
    DEFAULT_TOLERANCE,
    HEADLINES,
    compare,
    main as check_main,
    resolve,
    resolve_artifact,
    update_baselines,
)
from benchmarks.run import BENCHES, main as run_main

BASE_CLUSTER = {
    "closed_loop": {
        "client_epochs_per_sec": 4.0e5,
        "adaptive_mean_latency_s": 0.041,
    },
    "equilibrium": {"iterations": 5},
}


def _write(d, name, doc):
    (d / name).write_text(json.dumps(doc))


@pytest.fixture
def dirs(tmp_path):
    fresh = tmp_path / "fresh"
    base = tmp_path / "base"
    fresh.mkdir()
    base.mkdir()
    return fresh, base


class TestCheckRegression:
    def test_synthetic_2x_slowdown_exits_nonzero(self, dirs, capsys):
        """Acceptance criterion: the tolerance check is demonstrably wired —
        a 2x throughput drop fails the gate (machine-matched mode, where
        wall-clock baselines are comparable)."""
        fresh, base = dirs
        _write(base, "BENCH_cluster.json", BASE_CLUSTER)
        slow = copy.deepcopy(BASE_CLUSTER)
        slow["closed_loop"]["client_epochs_per_sec"] /= 2.0
        _write(fresh, "BENCH_cluster.json", slow)
        rc = check_main(["--fresh", str(fresh), "--baselines", str(base),
                         "--machine-matched"])
        assert rc != 0
        out = capsys.readouterr().out
        assert "REGRESSED" in out
        assert "client_epochs_per_sec" in out

    def test_machine_bound_metrics_informational_on_foreign_machines(self, dirs):
        """Without --machine-matched a slower machine must not fail the gate
        on absolute throughputs — but the row still shows up as info."""
        fresh, base = dirs
        _write(base, "BENCH_cluster.json", BASE_CLUSTER)
        slow = copy.deepcopy(BASE_CLUSTER)
        slow["closed_loop"]["client_epochs_per_sec"] /= 3.0  # slow CI runner
        _write(fresh, "BENCH_cluster.json", slow)
        rows, regressions = compare(fresh, base)
        assert regressions == 0
        tp = next(r for r in rows
                  if r["metric"] == "closed_loop.client_epochs_per_sec")
        assert tp["status"] == "info(slower)"
        # a MODEL regression on the same slow machine still fails
        slow["equilibrium"]["iterations"] = 15
        _write(fresh, "BENCH_cluster.json", slow)
        _rows, regressions = compare(fresh, base)
        assert regressions == 1

    def test_within_tolerance_passes(self, dirs):
        fresh, base = dirs
        _write(base, "BENCH_cluster.json", BASE_CLUSTER)
        near = copy.deepcopy(BASE_CLUSTER)
        near["closed_loop"]["client_epochs_per_sec"] *= 0.8  # -20% < 45% tol
        near["equilibrium"]["iterations"] = 6  # +20% < 30% tol
        _write(fresh, "BENCH_cluster.json", near)
        rc = check_main(["--fresh", str(fresh), "--baselines", str(base),
                         "--machine-matched"])
        assert rc == 0

    def test_missing_baseline_file_is_loud(self, dirs):
        """A family produced fresh but absent from the committed baselines is
        MISSING for every headline — never a silent skip."""
        fresh, base = dirs
        _write(fresh, "BENCH_cluster.json", BASE_CLUSTER)
        rows, regressions = compare(fresh, base)
        assert regressions == len(HEADLINES["BENCH_cluster.json"])
        assert all(r["status"] == "MISSING" for r in rows)

    def test_missing_fresh_file_is_loud(self, dirs):
        """The symmetric hole: a baselined family whose fresh artifact never
        got produced (renamed file, family dropped from the CI --only list)
        must fail, not shrink the gate silently."""
        fresh, base = dirs
        _write(base, "BENCH_cluster.json", BASE_CLUSTER)
        rows, regressions = compare(fresh, base)
        assert regressions == len(HEADLINES["BENCH_cluster.json"])
        assert all(r["status"] == "MISSING" and r["fresh"] is None for r in rows)

    def test_lower_is_better_direction(self, dirs):
        fresh, base = dirs
        _write(base, "BENCH_cluster.json", BASE_CLUSTER)
        worse = copy.deepcopy(BASE_CLUSTER)
        worse["equilibrium"]["iterations"] = 12  # 2.4x the baseline
        _write(fresh, "BENCH_cluster.json", worse)
        rows, regressions = compare(fresh, base)
        bad = [r for r in rows if r["status"] == "REGRESSED"]
        assert regressions == 1
        assert bad[0]["metric"] == "equilibrium.iterations"

    def test_improvement_never_fails(self, dirs):
        fresh, base = dirs
        _write(base, "BENCH_cluster.json", BASE_CLUSTER)
        better = copy.deepcopy(BASE_CLUSTER)
        better["closed_loop"]["client_epochs_per_sec"] *= 10.0
        better["equilibrium"]["iterations"] = 2
        _write(fresh, "BENCH_cluster.json", better)
        _rows, regressions = compare(fresh, base)
        assert regressions == 0

    def test_missing_metric_is_a_regression(self, dirs):
        fresh, base = dirs
        _write(base, "BENCH_cluster.json", BASE_CLUSTER)
        shrunk = copy.deepcopy(BASE_CLUSTER)
        del shrunk["equilibrium"]
        _write(fresh, "BENCH_cluster.json", shrunk)
        rows, regressions = compare(fresh, base)
        assert regressions >= 1
        assert any(r["status"] == "MISSING" for r in rows)

    def test_nothing_compared_is_an_error(self, dirs):
        fresh, base = dirs  # both empty
        rc = check_main(["--fresh", str(fresh), "--baselines", str(base)])
        assert rc == 2

    def test_update_baselines_copies_known_families(self, dirs):
        fresh, base = dirs
        _write(fresh, "BENCH_cluster.json", BASE_CLUSTER)
        _write(fresh, "UNRELATED.json", {"x": 1})
        copied = update_baselines(fresh, base)
        assert copied == ["BENCH_cluster.json"]
        assert json.loads((base / "BENCH_cluster.json").read_text()) == BASE_CLUSTER
        assert not (base / "UNRELATED.json").exists()

    def test_headline_registry_resolves_against_committed_baselines(self):
        """Every headline metric must exist in the committed baselines —
        otherwise the gate silently shrinks as artifacts evolve."""
        from benchmarks.check_regression import default_baseline_dir

        base_dir = default_baseline_dir()
        for fname, metrics in HEADLINES.items():
            doc = json.loads((base_dir / fname).read_text())
            for metric in metrics:
                assert resolve(doc, metric) is not None, (fname, metric)

    def test_default_tolerance_is_thirty_percent(self):
        assert DEFAULT_TOLERANCE == pytest.approx(0.30)


class TestResultsTreeSupport:
    """check_regression reads reproduce-style results/ trees, restricts to
    declared partial runs via ``families``, and commits portable baselines."""

    def test_artifact_found_in_nested_results_tree(self, dirs):
        fresh, base = dirs
        nested = fresh / "bench-cluster" / "run-abc123" / "seed-0"
        nested.mkdir(parents=True)
        _write(nested, "BENCH_cluster.json", BASE_CLUSTER)
        assert resolve_artifact(fresh, "BENCH_cluster.json") == \
            nested / "BENCH_cluster.json"
        _write(base, "BENCH_cluster.json", BASE_CLUSTER)
        rows, regressions = compare(fresh, base)
        assert regressions == 0
        assert all(r["status"] in ("ok", "info") for r in rows)

    def test_flat_layout_wins_over_nested(self, dirs):
        fresh, _ = dirs
        nested = fresh / "deep"
        nested.mkdir()
        _write(nested, "BENCH_cluster.json", {"x": 1})
        _write(fresh, "BENCH_cluster.json", BASE_CLUSTER)
        found = resolve_artifact(fresh, "BENCH_cluster.json")
        assert found == fresh / "BENCH_cluster.json"

    def test_families_filter_restricts_comparison(self, dirs):
        """A declared partial run (reproduce --only) compares only what it
        produced — absent families stay out instead of going MISSING."""
        fresh, base = dirs
        _write(fresh, "BENCH_cluster.json", BASE_CLUSTER)
        _write(base, "BENCH_cluster.json", BASE_CLUSTER)
        _write(base, "BENCH_plan.json", {"solver": {"wall_s": 1.0}})
        rows, regressions = compare(fresh, base,
                                    families=["BENCH_cluster.json"])
        assert regressions == 0
        assert {r["family"] for r in rows} == {"BENCH_cluster.json"}

    def test_update_baselines_strips_machine_bound_manifest(self, dirs):
        fresh, base = dirs
        doc = dict(BASE_CLUSTER)
        doc["manifest"] = {
            "manifest_version": 1, "seed": 0, "config_sha256": "cafe",
            "git": {"sha": "deadbeef", "dirty": False},
            "python": "3.12.0", "platform": "Linux-x86",
            "packages": {"jax": "0.4.0"},
        }
        _write(fresh, "BENCH_cluster.json", doc)
        copied = update_baselines(fresh, base)
        assert copied == ["BENCH_cluster.json"]
        committed = json.loads((base / "BENCH_cluster.json").read_text())
        assert committed["manifest"] == {
            "manifest_version": 1, "seed": 0, "config_sha256": "cafe"}
        # headline payload untouched
        assert committed["equilibrium"] == BASE_CLUSTER["equilibrium"]

    def test_stripped_baseline_emits_no_drift_notes(self, dirs):
        """The satellite bug: stripped baselines vs a full fresh manifest
        used to report every provenance key as perpetual drift."""
        from benchmarks.check_regression import manifest_notes
        from repro.obs import manifest_delta, run_manifest

        fresh, base = dirs
        full = dict(BASE_CLUSTER)
        full["manifest"] = run_manifest(seed=0, config={"x": 1})
        _write(fresh, "BENCH_cluster.json", full)
        update_baselines(fresh, base)
        assert manifest_notes(fresh, base) == []
        stripped = {"manifest_version": 1, "seed": 0}
        assert manifest_delta(stripped, full["manifest"]) == []
        # genuine drift on a shared key still reported
        other = dict(full["manifest"], git={"sha": "other", "dirty": False})
        assert manifest_delta(full["manifest"], other)


class TestRunOnlyValidation:
    def test_unknown_family_exits_nonzero_listing_known(self, capsys, tmp_path):
        rc = run_main(["--only", "definitely-not-a-family",
                       "--out", str(tmp_path)])
        assert rc != 0
        err = capsys.readouterr().err
        for family in BENCHES:
            assert family in err
        assert "definitely-not-a-family" in err

    def test_known_families_accepted_mixed_with_unknown_still_fail(self, capsys, tmp_path):
        rc = run_main(["--only", "fleet", "--only", "nope", "--out", str(tmp_path)])
        assert rc != 0  # nothing ran: the registry check precedes execution
        assert "nope" in capsys.readouterr().err

    def test_comma_separated_families_split_before_validation(self, capsys, tmp_path):
        # "--only a,b" must mean the families a and b, not one family "a,b";
        # an unknown name inside the comma list still fails the whole run
        rc = run_main(["--only", "fleet,nope", "--out", str(tmp_path)])
        assert rc == 2
        err = capsys.readouterr().err
        assert "'nope'" in err and "fleet,nope" not in err

    def test_comma_separated_known_families_run(self, capsys, tmp_path):
        rc = run_main(["--only", "plan,obs", "--out", str(tmp_path)])
        assert rc == 0
        produced = {p.name for p in tmp_path.glob("BENCH_*.json")}
        assert produced == {"BENCH_plan.json", "BENCH_obs.json"}

    def test_only_with_no_parseable_names_is_rejected(self, capsys, tmp_path):
        # a stray "--only ," must not silently fall back to running ALL
        # families — that's the silently-wrong-artifact failure mode
        rc = run_main(["--only", ",", "--out", str(tmp_path)])
        assert rc == 2
        assert "no family names parsed" in capsys.readouterr().err
        assert not list(tmp_path.glob("BENCH_*.json"))
