"""Tail-latency layer (ISSUE 5): closed-form sojourn quantiles, the
vectorized twin, SLO-aware decisions, and the decision/crossover correctness
satellites (tail_z symmetry, instability-pocket crossovers, vectorized
station_pass, Mixture validation, tenancy bracketing)."""

import math
import warnings

import numpy as np
import pytest

from repro.core import simulation as S
from repro.core import tail as T
from repro.core.crossover import (
    Crossover,
    smallest_true,
    solve_crossover,
    tenancy_crossover,
)
from repro.core.latency import NetworkPath, ServiceModel, Tier, Workload
from repro.core.manager import ON_DEVICE, AdaptiveOffloadManager, EdgeServerState
from repro.core.multitenant import TenantStream, multitenant_edge_latency
from repro.core.scenario import EdgeSpec, Scenario, analytic_tail, tail_stations
from repro.core.simulation import Mixture, _station_pass_k1_loop, station_pass
from repro.core.telemetry import TelemetrySnapshot
from repro.fleet import ScenarioBatch, fleet_tail


def _mm1_station(lam, mu):
    return T.proc_station(lam, T.KIND_EXP, 1.0 / mu, 0.0, 1.0)


SCN = Scenario(
    workload=Workload(8.0, 50_000, 4_000),
    device=Tier("dev", 0.05, service_model=ServiceModel.DETERMINISTIC),
    network=NetworkPath(2.5e6),
    edges=(EdgeSpec(Tier("edge", 0.018, service_model=ServiceModel.EXPONENTIAL)),),
)


# ---------------------------------------------------------------------------
# the tentpole: closed-form sojourn distributions
# ---------------------------------------------------------------------------


class TestSojournQuantiles:
    def test_mm1_exact_closed_form(self):
        """Acceptance: single-station M/M/1 quantiles exact to <= 1e-9 vs the
        closed form t_q = -ln(1-q)/(mu - lam), under BOTH methods."""
        lam, mu = 8.0, 10.0
        st = _mm1_station(lam, mu)
        for q in (0.5, 0.9, 0.95, 0.99, 0.999):
            exact = -math.log1p(-q) / (mu - lam)
            for method in ("euler", "asymptote"):
                got = T.sojourn_quantile([st], q, method=method)
                assert abs(got - exact) / exact <= 1e-9, (q, method)

    def test_mm1_cdf_matches_exponential(self):
        lam, mu = 5.0, 8.0
        st = _mm1_station(lam, mu)
        t = np.linspace(0.05, 3.0, 20)
        np.testing.assert_allclose(
            T.sojourn_cdf([st], t), 1.0 - np.exp(-(mu - lam) * t), atol=2e-8)

    def test_md1_quantile_vs_simulation(self):
        lam, s = 8.0, 0.1  # rho = 0.8
        st = T.proc_station(lam, T.KIND_DET, s, 0.0, 1.0)
        res = S.simulate_on_device(lam, S.Deterministic(s), n=400_000, seed=1)
        for q in (0.9, 0.99):
            pred = T.sojourn_quantile([st], q)
            obs = res.percentile(q * 100)
            assert abs(pred - obs) / obs < 0.10, (q, pred, obs)

    def test_low_rho_md1_quantile_below_atom_is_service_time(self):
        # rho = 0.05: P(W = 0) = 0.95 > q=0.5, so the q-quantile is the
        # (deterministic) service time itself. The Euler inversion converges
        # to the jump midpoint AT the atom, so the bisection lands within a
        # Gibbs ripple of s — sub-percent, documented in sojourn_cdf.
        st = T.proc_station(0.5, T.KIND_DET, 0.1, 0.0, 1.0)
        assert T.sojourn_quantile([st], 0.5) == pytest.approx(0.1, rel=1e-2)

    def test_mg1_gamma_match_vs_lognormal_sim(self):
        # cv^2 = 0.25 GENERAL tier: gamma transform vs lognormal draws is a
        # quantified approximation — a few percent at p99, not gated
        lam, s, var = 5.0, 0.1, 0.0025
        st = T.proc_station(lam, T.KIND_GAMMA, s, var, 1.0)
        res = S.simulate_on_device(lam, S.LogNormal(s, var), n=400_000, seed=3)
        pred = T.sojourn_quantile([st], 0.99)
        obs = res.percentile(99)
        assert abs(pred - obs) / obs < 0.10

    def test_tandem_offload_p99_vs_simulation(self):
        lam, s, bw, req, res_b = 8.0, 0.05, 2.5e6, 50_000, 5_000
        stations = [
            T.nic_station(lam, req, bw),
            T.proc_station(lam, T.KIND_DET, s, 0.0, 1.0),
            T.nic_station(lam, res_b, bw),
        ]
        sim = S.simulate_offload(lam, S.Deterministic(s), 1, bandwidth_Bps=bw,
                                 req_bytes=req, res_bytes=res_b, n=400_000, seed=2)
        for q in (0.9, 0.95, 0.99):
            pred = T.sojourn_quantile(stations, q)
            obs = sim.percentile(q * 100)
            assert abs(pred - obs) / obs < 0.10, (q, pred, obs)

    def test_composed_mean_matches_analytic_total(self):
        """E[sum of per-station sojourns] == the Eq. 1/2 closed-form total."""
        for strategy in ("on_device", "edge[0]"):
            total = float(np.asarray(SCN.analytic().totals()[strategy]))
            assert T.sojourn_mean(tail_stations(SCN, strategy)) == \
                pytest.approx(total, rel=1e-12)

    def test_quantile_monotone_in_q(self):
        st = tail_stations(SCN, "edge[0]")
        qs = [0.5, 0.9, 0.95, 0.99, 0.999]
        vals = [T.sojourn_quantile(st, q) for q in qs]
        assert all(a < b for a, b in zip(vals, vals[1:]))

    def test_asymptote_close_to_euler_at_p99(self):
        st = tail_stations(SCN, "edge[0]")
        e = T.sojourn_quantile(st, 0.99, method="euler")
        a = T.sojourn_quantile(st, 0.99, method="asymptote")
        assert abs(a - e) / e < 0.10

    def test_extreme_quantile_hands_off_to_asymptote(self):
        """Regression (review): beyond the Euler CDF's ~1e-8 accuracy floor,
        the numeric bisection converges against inversion noise and silently
        underestimates — such q must route to the asymptote, which is
        asymptotically exact precisely as q -> 1."""
        st = T.proc_station(0.5, T.KIND_DET, 1.0, 0.0)
        q = 1.0 - 1e-12
        asym = T.sojourn_quantile([st], q, method="asymptote")
        assert T.sojourn_quantile([st], q) == asym  # euler resolved away
        assert T.resolve_tail_method(q, "euler") == "asymptote"
        assert T.resolve_tail_method(0.99, "euler") == "euler"
        # the batch twin applies the same resolution
        batch = ScenarioBatch.from_scenarios([SCN])
        np.testing.assert_allclose(
            fleet_tail(batch, q).t_dev, fleet_tail(batch, q, method="asymptote").t_dev)

    def test_unstable_station_is_inf(self):
        st = _mm1_station(10.0, 8.0)
        assert T.sojourn_quantile([st], 0.99) == math.inf
        assert T.sojourn_quantile([st], 0.99, method="asymptote") == math.inf

    def test_bad_quantile_rejected(self):
        st = _mm1_station(1.0, 2.0)
        for q in (0.0, 1.0, -0.1, 1.5):
            with pytest.raises(ValueError, match="quantile"):
                T.sojourn_quantile([st], q)
        with pytest.raises(ValueError, match="method"):
            T.sojourn_quantile([st], 0.9, method="bogus")

    def test_kind_codes_match_model_codes(self):
        # scenario._TAIL_KINDS / tail.KIND_* must stay aligned with the
        # batched columns' MODEL_CODES — fleet_tail reuses them unmapped
        from repro.fleet.batch import MODEL_CODES
        assert MODEL_CODES[ServiceModel.DETERMINISTIC] == T.KIND_DET
        assert MODEL_CODES[ServiceModel.EXPONENTIAL] == T.KIND_EXP
        assert MODEL_CODES[ServiceModel.GENERAL] == T.KIND_GAMMA


class TestAnalyticTail:
    def test_strategy_keys_match_analytic(self):
        tails = SCN.analytic_tail(0.99)
        assert set(tails) == set(SCN.analytic().totals())

    def test_p99_above_mean(self):
        tails = SCN.analytic_tail(0.99)
        totals = SCN.analytic().totals()
        for k in tails:
            assert tails[k] > float(np.asarray(totals[k]))

    def test_fleet_tail_matches_scalar_on_sweep(self):
        """Acceptance: tail_vec matches scalar tail.py to <= 1e-6 relative
        (the full-corpus version is gated in the validate harness)."""
        scns = SCN.sweep("workload.arrival_rate", np.linspace(2.0, 14.0, 7))
        batch = ScenarioBatch.from_scenarios(scns)
        for method in ("euler", "asymptote"):
            pred = fleet_tail(batch, 0.99, method=method)
            for i, s in enumerate(scns):
                sc = analytic_tail(s, 0.99, method=method)
                vt = pred.totals(i)
                for k, v in sc.items():
                    if math.isinf(v):
                        assert math.isinf(vt[k])
                        continue
                    assert abs(v - vt[k]) / v <= 1e-6, (method, i, k)

    def test_fleet_tail_euler_is_exact_batched_kernel(self):
        """Regression: ``method="euler"`` on a batch must run the batched
        exact inversion — matching scalar euler to <= 1e-8 — not silently
        fall back to the asymptote, which is what the documented-but-unrouted
        batch path did before the euler_vec kernel landed. Rows mix det/exp
        devices, a GENERAL edge, and background tenants so both the
        kind-hinted and runtime-dispatch paths are exercised."""
        from repro.core.multitenant import TenantStream

        scns = [
            SCN,
            Scenario(
                workload=Workload(6.0, 40_000, 2_000),
                device=Tier("dev-exp", 0.06, service_model=ServiceModel.EXPONENTIAL),
                network=NetworkPath(4e6),
                edges=(EdgeSpec(Tier("edge-gen", 0.02,
                                     service_model=ServiceModel.GENERAL,
                                     service_var=0.3 * 0.02**2),
                                background=(TenantStream(5.0, 0.015, 0.015**2),)),),
            ),
        ]
        batch = ScenarioBatch.from_scenarios(scns)
        for q in (0.9, 0.99):
            pred = fleet_tail(batch, q, method="euler")
            asym = fleet_tail(batch, q, method="asymptote")
            saw_gap = False
            for i, s in enumerate(scns):
                sc = analytic_tail(s, q, method="euler")
                vt, at = pred.totals(i), asym.totals(i)
                for k, v in sc.items():
                    assert abs(v - vt[k]) <= 1e-8 * max(abs(v), 1.0), (q, i, k)
                    saw_gap |= abs(at[k] - vt[k]) > 1e-6 * abs(v)
            # the euler result is genuinely distinct from the asymptote's —
            # a silent fallback would make the 1e-8 agreement above vacuous
            assert saw_gap, q

    def test_fleet_tail_best_edge_convention(self):
        batch = ScenarioBatch.from_scenarios([SCN])
        pred = fleet_tail(batch, 0.99)
        tails = SCN.analytic_tail(0.99)
        best = min(tails, key=tails.get)
        assert pred.strategy_names()[0] == best

    def test_fleet_tail_rejects_bad_inputs(self):
        batch = ScenarioBatch.from_scenarios([SCN])
        with pytest.raises(ValueError, match="quantile"):
            fleet_tail(batch, 1.2)
        with pytest.raises(ValueError, match="method"):
            fleet_tail(batch, 0.9, method="nope")


# ---------------------------------------------------------------------------
# percentile crossovers: the new result class
# ---------------------------------------------------------------------------


class TestQuantileCrossovers:
    def test_p99_bandwidth_crossover_shifts_up(self):
        """Offload paths stack three queues, so their tails are heavier than
        the single device queue's: the p99 crossover needs MORE bandwidth
        than the mean crossover — a statement the paper's mean forms cannot
        express."""
        cm = SCN.crossovers("bandwidth")
        cq = SCN.crossovers("bandwidth", quantile=0.99)
        assert cm.value is not None and cq.value is not None
        assert cq.value > cm.value
        assert cq.offload_wins_above is True

    def test_p99_crossover_consistent_with_tail_evaluation(self):
        cq = SCN.crossovers("bandwidth", quantile=0.99)
        lo = SCN.replaced("network.bandwidth_Bps", cq.value * 0.8)
        hi = SCN.replaced("network.bandwidth_Bps", cq.value * 1.25)
        tl, th = lo.analytic_tail(0.99), hi.analytic_tail(0.99)
        assert tl["on_device"] < tl["edge[0]"]
        assert th["edge[0]"] < th["on_device"]

    def test_quantile_tenancy_crossover(self):
        scn = Scenario(
            workload=Workload(2.0, 50_000, 4_000),
            device=Tier("dev", 0.06),
            network=NetworkPath(12.5e6),
            edges=(EdgeSpec(Tier("edge", 0.02)),),
        )
        cm = scn.crossovers("tenancy", max_tenants=256)
        cq = scn.crossovers("tenancy", quantile=0.99, max_tenants=256)
        assert cm.value is not None and cq.value is not None
        # heavier tails at the shared edge: on-device wins at no MORE tenants
        assert cq.value <= cm.value
        # the bracketed search equals an exhaustive scan of the same quantile
        tails_dev = scn.analytic_tail(0.99)["on_device"]
        template = scn.edges[0].own_stream(scn.workload)
        for m in range(1, int(cq.value) + 1):
            bg = (template,) * (m - 1)
            scn_m = Scenario(workload=scn.workload, device=scn.device,
                             network=scn.network, allow_unstable=True,
                             edges=(EdgeSpec(scn.edges[0].tier, background=bg),))
            te = scn_m.analytic_tail(0.99)["edge[0]"]
            assert (te > tails_dev) == (m == int(cq.value)), m

    def test_quantile_tenancy_rejects_unknown_kwargs(self):
        # regression (review): the quantile branch used to swallow typos the
        # mean branch rejects
        with pytest.raises(TypeError, match="unexpected keyword"):
            SCN.crossovers("tenancy", quantile=0.99, tenant_templates=None)


# ---------------------------------------------------------------------------
# SLO-aware manager (satellite 1: tail_z symmetry; tentpole: slo_quantile)
# ---------------------------------------------------------------------------


def _snap(lam=10.0, bw=2.5e6):
    return TelemetrySnapshot(time_s=0.0, lam_dev=lam, bandwidth_Bps=bw)


class TestManagerSLO:
    def test_tail_z_is_symmetric_now(self):
        """Regression (ISSUE 5 satellite): with identical device and edge
        queues and no network legs, any tail_z must leave the comparison a
        tie — the old code inflated only the edge wait, biasing every
        decision toward on-device."""
        wl = Workload(10.0, 0.0, 0.0)
        dev = Tier("dev", 0.05, service_model=ServiceModel.EXPONENTIAL)
        edge = EdgeServerState(name="e", service_rate=20.0, arrival_rate=10.0,
                               service_time_s=0.05, service_var=0.0025)
        for z in (0.0, 0.5, 2.0):
            with warnings.catch_warnings():
                warnings.simplefilter("ignore", DeprecationWarning)
                mgr = AdaptiveOffloadManager(dev, tail_z=z, return_results=False)
            d = mgr.decide(wl, _snap(), [edge])
            assert d.t_dev == pytest.approx(d.t_edges[0], rel=1e-12), z

    def test_tail_z_warns_deprecated(self):
        with pytest.warns(DeprecationWarning, match="slo_quantile"):
            AdaptiveOffloadManager(Tier("d", 0.05), tail_z=1.0)

    def test_tail_z_and_slo_quantile_exclusive(self):
        with pytest.raises(ValueError, match="mutually exclusive"):
            AdaptiveOffloadManager(Tier("d", 0.05), tail_z=1.0, slo_quantile=0.99)

    def test_slo_quantile_validated(self):
        for bad in (0.0, 1.0, 1.5):
            with pytest.raises(ValueError, match="slo_quantile"):
                AdaptiveOffloadManager(Tier("d", 0.05), slo_quantile=bad)

    def test_slo_decision_matches_analytic_tail(self):
        """Manager SLO predictions == Scenario.analytic_tail on dedicated
        k=1 edges (the same coherence the mean paths are pinned to)."""
        mgr = SCN.manager(slo_quantile=0.99)
        d = mgr.decide(SCN.workload, SCN.snapshot(), SCN.edge_states())
        tails = SCN.analytic_tail(0.99)
        assert d.t_dev == pytest.approx(tails["on_device"], rel=1e-12)
        assert d.t_edges[0] == pytest.approx(tails["edge[0]"], rel=1e-12)

    def test_slo_mode_flips_decision_on_tail_heavy_edge(self):
        """An edge that wins on the mean but loses at p99 (high service
        variance) must flip once the SLO objective is active."""
        wl = Workload(6.0, 0.0, 0.0)
        dev = Tier("dev", 0.11, service_model=ServiceModel.DETERMINISTIC)
        s_e, cv2 = 0.05, 8.0
        edge = EdgeServerState(name="e", service_rate=1.0 / s_e, arrival_rate=6.0,
                               service_time_s=s_e, service_var=cv2 * s_e * s_e)
        mean_mgr = AdaptiveOffloadManager(dev, return_results=False)
        slo_mgr = AdaptiveOffloadManager(dev, slo_quantile=0.99,
                                         return_results=False)
        d_mean = mean_mgr.decide(wl, _snap(lam=6.0), [edge])
        d_slo = slo_mgr.decide(wl, _snap(lam=6.0), [edge])
        assert d_mean.edge_index == 0  # edge wins the mean comparison
        assert d_slo.edge_index == ON_DEVICE  # p99 prefers the det device

    def test_slo_mode_in_replay_scores_quantiles(self):
        from repro.fleet import make_trace, replay
        from repro.fleet.traces import step_signal

        tr = make_trace(40.0, 1.0,
                        bandwidth_Bps=lambda t: step_signal(
                            t, [(0.0, 2.5e6), (20.0, 2.5e5)]),
                        arrival_rate=8.0)
        rr = replay(SCN, tr, slo_quantile=0.99, seed=0)
        rm = replay(SCN, tr, seed=0)
        assert rr.adaptive_wins
        # quantile scores dominate the mean scores epoch for epoch
        assert rr.policies["on_device"].mean_latency_s > \
            rm.policies["on_device"].mean_latency_s


class TestClusterSLO:
    def test_predict_decisions_coheres_with_slo_manager(self):
        from repro.core.scenario import ClusterSpec
        from repro.fleet import predict_decisions

        spec = ClusterSpec(base=Scenario(
            workload=Workload(2.0, 40_000, 2_000),
            device=Tier("cpu", 0.4),
            network=NetworkPath(12.5e6),
            edges=(EdgeSpec(Tier("fast", 0.03)), EdgeSpec(Tier("slow", 0.18))),
        ), n_clients=4)
        lam_hat = spec.arrival_rates()
        bw = 12.5e6
        choices, t_dev, t_edge = predict_decisions(
            spec, lam_hat, bw, np.zeros((4, 2)), np.zeros(2), slo_quantile=0.99)
        mgr = AdaptiveOffloadManager(spec.base.device, slo_quantile=0.99,
                                     tail_method="asymptote")
        d = mgr.decide(spec.base.workload, spec.base.snapshot(),
                       spec.base.edge_states())
        assert choices[0] == d.edge_index
        assert t_dev[0] == pytest.approx(d.t_dev, rel=1e-9)
        for j in range(2):
            assert t_edge[0][j] == pytest.approx(d.t_edges[j], rel=1e-9)

    def test_equilibrium_slo_converges_and_reports_quantiles(self):
        from repro.core.scenario import ClusterSpec
        from repro.fleet import solve_equilibrium

        spec = ClusterSpec(base=Scenario(
            workload=Workload(2.0, 40_000, 2_000),
            device=Tier("cpu", 0.4),
            network=NetworkPath(12.5e6),
            edges=(EdgeSpec(Tier("fast", 0.03)), EdgeSpec(Tier("slow", 0.18))),
        ), n_clients=8)
        eq_mean = solve_equilibrium(spec)
        eq_slo = solve_equilibrium(spec, slo_quantile=0.99)
        assert eq_slo.converged
        # quantile latencies dominate the means at the same fixed point shape
        assert eq_slo.mean_latency_s > eq_mean.mean_latency_s


# ---------------------------------------------------------------------------
# satellite 2: instability-pocket crossovers
# ---------------------------------------------------------------------------


class TestCrossoverAdjacency:
    def test_inf_pocket_is_not_a_crossover(self):
        """Regression: a sign change ACROSS an instability pocket used to be
        bisected into the inf region and reported as a bogus crossover."""

        def diff(x):
            if x < 0.3:
                return -1.0
            if x < 0.6:
                return math.inf
            return 1.0

        c = solve_crossover(diff, 0.0, 1.0, samples=101)
        assert c.value is None and c.offload_wins_above is None

    def test_nan_pocket_is_not_a_crossover(self):
        def diff(x):
            if x < 0.3:
                return 1.0
            if x < 0.6:
                return math.nan
            return -1.0

        assert solve_crossover(diff, 0.0, 1.0, samples=101).value is None

    def test_adjacent_sign_change_still_found(self):
        c = solve_crossover(lambda x: x - 0.37, 0.0, 1.0, samples=101)
        assert c.value == pytest.approx(0.37, abs=1e-9)
        assert c.offload_wins_above is False  # diff < 0 above the root

    def test_crossover_after_inf_prefix_still_found(self):
        # the common real shape: edge NIC unstable at low bandwidth (inf
        # prefix), then finite with a genuine crossover
        def diff(x):
            if x < 0.2:
                return math.inf
            return 0.5 - x

        c = solve_crossover(diff, 0.0, 1.0, samples=201)
        assert c.value == pytest.approx(0.5, abs=1e-8)

    def test_fleet_crossover_agrees_on_inf_pocket_scenario(self):
        """The vectorized scan must apply the same adjacency rule: a spec
        whose diff has an instability pocket between opposite-sign regions
        reports no crossover on BOTH paths."""
        from repro.fleet import fleet_crossover

        # device much faster than the edge: offload never wins at any
        # bandwidth, but low-bandwidth samples are inf (NIC unstable), so a
        # pocket-pairing bug would fabricate a crossover at the boundary
        scn = Scenario(
            workload=Workload(9.0, 120_000, 4_000),
            device=Tier("dev", 0.01),
            network=NetworkPath(2.5e6),
            edges=(EdgeSpec(Tier("edge", 0.09)),),
            allow_unstable=True,
        )
        c = scn.crossovers("bandwidth")
        fc = fleet_crossover(ScenarioBatch.from_scenarios([scn]), "bandwidth")
        assert c.value is None
        assert not fc.found[0]


# ---------------------------------------------------------------------------
# satellite 3: vectorized k=1 station_pass
# ---------------------------------------------------------------------------


class TestStationPassVectorized:
    def test_matches_sequential_loop(self):
        rng = np.random.default_rng(7)
        for n, lam_s in ((400, 0.1), (50_000, 0.02)):
            arr = np.cumsum(rng.exponential(lam_s, size=n))
            svc = rng.exponential(lam_s * 0.8, size=n)
            ref = _station_pass_k1_loop(arr, svc)
            vec = station_pass(arr, svc, 1)
            # same recursion, different float association order: equal to
            # float64 roundoff on the departure times
            assert np.max(np.abs(ref - vec) / ref) < 1e-12

    def test_empty_input_returns_empty(self):
        # regression (review): the old loop returned an empty array; the
        # vectorized path must not IndexError on zero jobs
        out = station_pass(np.empty(0), np.empty(0), 1)
        assert out.shape == (0,)

    def test_deterministic_saturated_and_idle_extremes(self):
        # idle: every job starts at its arrival
        arr = np.array([0.0, 10.0, 20.0])
        svc = np.array([1.0, 1.0, 1.0])
        np.testing.assert_allclose(station_pass(arr, svc, 1), [1.0, 11.0, 21.0])
        # saturated: one long busy period
        arr = np.zeros(4)
        np.testing.assert_allclose(station_pass(arr, np.ones(4), 1),
                                   [1.0, 2.0, 3.0, 4.0])

    def test_k1_meaningfully_faster_than_loop(self):
        """Acceptance: the vectorized k=1 path is measurably faster on the
        100k-job validate runs (>= 5x here; ~100x typical)."""
        import time

        rng = np.random.default_rng(0)
        n = 100_000
        arr = np.cumsum(rng.exponential(0.1, size=n))
        svc = rng.exponential(0.08, size=n)
        t0 = time.perf_counter()
        _station_pass_k1_loop(arr, svc)
        t_loop = time.perf_counter() - t0
        station_pass(arr, svc, 1)  # warm
        t0 = time.perf_counter()
        station_pass(arr, svc, 1)
        t_vec = time.perf_counter() - t0
        assert t_loop / t_vec > 5.0, (t_loop, t_vec)


# ---------------------------------------------------------------------------
# satellite 4: Mixture input validation
# ---------------------------------------------------------------------------


class TestMixtureValidation:
    def test_empty_components_raise_value_error(self):
        # used to be a ZeroDivisionError out of the weight normalization
        with pytest.raises(ValueError, match="at least one component"):
            Mixture(components=(), weights=())

    def test_negative_weight_raises(self):
        with pytest.raises(ValueError, match=">= 0"):
            Mixture(components=(S.Deterministic(0.1), S.Exponential(0.2)),
                    weights=(1.5, -0.5))

    def test_nan_weight_raises_at_construction(self):
        # regression (review): NaN slipped past `w < 0` and failed later
        # inside rng.choice with a cryptic sampling error
        with pytest.raises(ValueError, match="finite"):
            Mixture(components=(S.Deterministic(0.1), S.Exponential(0.2)),
                    weights=(float("nan"), 1.0))
        with pytest.raises(ValueError, match="finite"):
            Mixture(components=(S.Deterministic(0.1),), weights=(float("inf"),))

    def test_zero_total_weight_raises(self):
        with pytest.raises(ValueError, match="positive"):
            Mixture(components=(S.Deterministic(0.1),), weights=(0.0,))

    def test_length_mismatch_raises(self):
        with pytest.raises(ValueError, match="weights"):
            Mixture(components=(S.Deterministic(0.1),), weights=(0.5, 0.5))

    def test_valid_mixture_still_normalizes_and_samples(self):
        m = Mixture(components=(S.Deterministic(0.1), S.Exponential(0.2)),
                    weights=(3.0, 1.0))
        assert m.weights == pytest.approx((0.75, 0.25))
        rng = np.random.default_rng(0)
        x = m.sample(1000, rng)
        assert x.shape == (1000,) and np.all(x > 0)


# ---------------------------------------------------------------------------
# satellite 5: tenancy crossover bracketing
# ---------------------------------------------------------------------------


class TestTenancyBracketing:
    WL = Workload(arrival_rate=10.0, req_bytes=25_000, res_bytes=2_000)
    DEV = Tier("dev", 0.035)
    NET = NetworkPath(2.5e6)

    def _linear_scan(self, wl, dev, edge, net, template, max_tenants):
        from repro.core.latency import on_device_latency

        td = float(np.asarray(on_device_latency(wl, dev)))
        for m in range(1, max_tenants + 1):
            te = float(np.asarray(
                multitenant_edge_latency(wl, edge, net, [template] * m)))
            if te > td:
                return m
        return None

    @pytest.mark.parametrize("edge_s,tpl_rate,max_tenants", [
        (0.005, 2.0, 1024),   # crossover in the middle
        (0.005, 2.0, 3),      # max_tenants below the crossover -> None
        (0.030, 2.0, 1024),   # heavy edge: crossover at m=1 or tiny
        (0.001, 0.1, 64),     # light tenants: offload may win everywhere
    ])
    def test_equals_linear_scan(self, edge_s, tpl_rate, max_tenants):
        edge = Tier("e", edge_s)
        template = TenantStream(arrival_rate=tpl_rate, service_mean_s=edge_s,
                                service_var=0.0)
        got = tenancy_crossover(self.WL, self.DEV, edge, self.NET, template,
                                max_tenants=max_tenants)
        want = self._linear_scan(self.WL, self.DEV, edge, self.NET, template,
                                 max_tenants)
        assert got == want

    def test_smallest_true_generic(self):
        for threshold in (1, 2, 3, 7, 64, 100):
            calls = []

            def pred(m, t=threshold):
                calls.append(m)
                return m >= t

            assert smallest_true(pred, 100) == threshold
            assert len(calls) <= 2 * math.ceil(math.log2(100)) + 2
        assert smallest_true(lambda m: False, 100) is None
        assert smallest_true(lambda m: True, 0) is None
