"""Closed-loop cluster tests: spec/trace validation, scalar-manager decision
coherence, the 64-client/4-edge acceptance criteria (equilibrium convergence,
analytic-vs-event-driven MAPE, adaptive <= best static), and the open-loop
bridge (induced scenarios)."""

import numpy as np
import pytest

from repro.core import (
    ClusterSpec,
    EdgeSpec,
    NetworkPath,
    Scenario,
    ScenarioError,
    ServiceModel,
    TenantStream,
    Tier,
    Workload,
    analytic,
)
from repro.core.manager import ON_DEVICE
from repro.core.scenario import implied_service_var
from repro.fleet import (
    Trace,
    TraceBatch,
    cross_check_equilibrium,
    induced_scenario,
    make_trace,
    predict_decisions,
    replay,
    simulate_cluster,
    solve_equilibrium,
    step_signal,
)
from repro.fleet.policy import bg_template
from repro.launch.cluster_sim import default_cluster


def _small_spec(n_clients: int = 5, **base_kw) -> ClusterSpec:
    defaults = dict(
        workload=Workload(2.0, 30_000, 1_000, name="inceptionv4"),
        device=Tier("orin", 0.045),
        edges=(
            EdgeSpec(Tier("a2", 0.028)),
            EdgeSpec(Tier("t4", 0.020, service_model=ServiceModel.EXPONENTIAL)),
        ),
        network=NetworkPath(20e6 / 8),
    )
    defaults.update(base_kw)
    return ClusterSpec(base=Scenario(**defaults), n_clients=n_clients, name="small")


class TestClusterSpec:
    def test_round_trip(self):
        spec = ClusterSpec(base=_small_spec().base, n_clients=3,
                           arrival_scale=(1.0, 0.5, 2.0), name="rt")
        assert ClusterSpec.from_dict(spec.to_dict()) == spec

    def test_validation_named_fields(self):
        base = _small_spec().base
        with pytest.raises(ScenarioError, match="n_clients"):
            ClusterSpec(base=base, n_clients=0)
        with pytest.raises(ScenarioError, match="arrival_scale"):
            ClusterSpec(base=base, n_clients=3, arrival_scale=(1.0, 2.0))
        with pytest.raises(ScenarioError, match=r"arrival_scale\[1\]"):
            ClusterSpec(base=base, n_clients=2, arrival_scale=(1.0, -1.0))
        no_edges = Scenario(workload=base.workload, device=base.device,
                            network=base.network, edges=())
        with pytest.raises(ScenarioError, match="base.edges"):
            ClusterSpec(base=no_edges, n_clients=2)

    def test_from_dict_missing_field_named(self):
        with pytest.raises(ScenarioError, match="n_clients"):
            ClusterSpec.from_dict({"base": _small_spec().base.to_dict()})

    def test_client_views(self):
        spec = ClusterSpec(base=_small_spec().base, n_clients=3,
                           arrival_scale=(1.0, 0.5, 2.0))
        assert np.allclose(spec.arrival_rates(), [2.0, 1.0, 4.0])
        c2 = spec.client(2)
        assert c2.workload.arrival_rate == pytest.approx(4.0)
        assert c2.allow_unstable  # the closed loop may cross saturation
        with pytest.raises(ScenarioError):
            spec.client(3)


class TestTraceBatch:
    def test_from_trace_broadcasts(self):
        tr = make_trace(20.0, 1.0, bandwidth_Bps=1e6, arrival_rate=2.0,
                        edge_bg_rate=[3.0])
        tb = TraceBatch.from_trace(tr, 4)
        assert tb.n_clients == 4 and tb.n_epochs == tr.n_epochs
        assert np.all(tb.bandwidth_Bps == 1e6)
        assert tb.edge_bg_rate.shape == (tr.n_epochs, 1)

    def test_from_traces_stacks_and_validates(self):
        t1 = make_trace(20.0, 1.0, bandwidth_Bps=1e6, arrival_rate=2.0)
        t2 = make_trace(20.0, 1.0, bandwidth_Bps=2e6, arrival_rate=3.0)
        tb = TraceBatch.from_traces([t1, t2])
        assert tb.n_clients == 2
        assert np.all(tb.arrival_rate[:, 1] == 3.0)
        t3 = make_trace(30.0, 1.0, bandwidth_Bps=1e6, arrival_rate=2.0)
        with pytest.raises(ValueError, match="epoch grid"):
            TraceBatch.from_traces([t1, t3])
        t4 = make_trace(20.0, 1.0, bandwidth_Bps=1e6, arrival_rate=2.0,
                        edge_bg_rate=[5.0])
        with pytest.raises(ValueError, match="exogenous"):
            TraceBatch.from_traces([t1, t4])

    def test_domain_validation(self):
        times = np.arange(0.0, 10.0)
        with pytest.raises(ValueError, match="bandwidth"):
            TraceBatch(times=times, bandwidth_Bps=np.zeros((10, 2)),
                       arrival_rate=np.ones((10, 2)), edge_bg_rate=np.zeros((10, 1)))

    def test_client_edge_count_mismatches_raise(self):
        spec = _small_spec(3)
        tr = make_trace(20.0, 1.0, bandwidth_Bps=1e6, arrival_rate=2.0)
        with pytest.raises(ScenarioError, match="traces"):
            simulate_cluster(spec, TraceBatch.from_trace(tr, 2))
        bad_edges = make_trace(20.0, 1.0, bandwidth_Bps=1e6, arrival_rate=2.0,
                               edge_bg_rate=[0.0, 0.0, 0.0])
        with pytest.raises(ScenarioError, match="traces"):
            simulate_cluster(spec, bad_edges)


class TestDecisionCoherence:
    def test_closed_loop_decisions_match_manager_step(self):
        """Every (epoch, client) decision of the vectorized closed loop must
        equal AdaptiveOffloadManager.step() fed the same recorded estimates —
        the one-decision-path guarantee, closed-loop edition."""
        from dataclasses import replace

        spec = _small_spec(4, edges=(
            EdgeSpec(Tier("a2", 0.028)),
            EdgeSpec(Tier("t4", 0.020, service_model=ServiceModel.EXPONENTIAL)),
            EdgeSpec(Tier("mt", 0.015),
                     background=(TenantStream(6.0, 0.015),)),
        ))
        tr = make_trace(
            25.0, 1.0,
            bandwidth_Bps=lambda t: step_signal(t, [(0, 2.5e6), (12, 4e5)]),
            arrival_rate=2.0,
            edge_bg_rate=[0.0, 0.0,
                          lambda t: step_signal(t, [(0, 6.0), (15, 20.0)])],
        )
        res = simulate_cluster(spec, tr, policies=("adaptive",), seed=3)
        base = spec.base
        templates = [bg_template(base, j) for j in range(spec.n_edges)]
        mgr = base.manager()  # hysteresis 0: history cannot change decisions
        choices = res.policies["adaptive"].choices
        checked = 0
        for t in range(tr.n_epochs):
            for i in range(spec.n_clients):
                wl_hat = replace(base.workload,
                                 arrival_rate=float(res.est_arrival_rate[t, i]))
                states = []
                for j, e in enumerate(base.edges):
                    bg = []
                    endo = float(res.est_endo_rate[t, i, j])
                    if endo > 0:
                        bg.append(TenantStream(endo, e.tier.service_time_s,
                                               implied_service_var(e.tier)))
                    exo = float(res.est_exo_rate[t, j])
                    if exo > 0:
                        bg.append(TenantStream(exo, templates[j][1], templates[j][2]))
                    states.append(replace(e, background=tuple(bg)).to_state(wl_hat))
                d = mgr.step(float(t), {
                    "workload": base.workload,
                    "lam_dev": float(res.est_arrival_rate[t, i]),
                    "bandwidth_Bps": float(res.est_bandwidth_Bps[t, i]),
                    "edges": states,
                })
                assert d.edge_index == choices[t, i], (t, i)
                checked += 1
        assert checked == tr.n_epochs * spec.n_clients

    def test_predict_decisions_matches_manager(self):
        """The single-epoch prediction helper agrees with the scalar manager
        on explicit estimates (the gateway coherence building block)."""
        from dataclasses import replace

        spec = _small_spec(1)
        base = spec.base
        for endo in ([0.0, 0.0], [20.0, 0.0], [25.0, 30.0], [60.0, 55.0]):
            choice, t_dev, t_edge = predict_decisions(
                spec, [2.0], [2.5e6], [endo], [0.0, 0.0])
            mgr = base.manager()
            states = []
            for j, e in enumerate(base.edges):
                bg = ((TenantStream(endo[j], e.tier.service_time_s,
                                    implied_service_var(e.tier)),)
                      if endo[j] > 0 else ())
                states.append(replace(e, background=bg).to_state(base.workload))
            d = mgr.step(0.0, {"workload": base.workload, "lam_dev": 2.0,
                               "bandwidth_Bps": 2.5e6, "edges": states})
            assert d.edge_index == choice[0], endo
            assert d.t_dev == pytest.approx(float(t_dev[0]), rel=1e-9)
            for j in range(spec.n_edges):
                assert d.t_edges[j] == pytest.approx(float(t_edge[0, j]), rel=1e-9)


class TestEquilibrium:
    def test_acceptance_64x4_converges_within_budget(self):
        spec = default_cluster(64)
        eq = solve_equilibrium(spec, max_iter=20)
        assert eq.converged
        assert eq.iterations <= 20
        # the fleet actually spreads: more than one target in use
        assert len([c for c in eq.counts().values() if c > 0]) >= 2
        # utilization stays inside the gateable region
        assert np.all(eq.rho_edges <= 0.9)
        assert np.all(np.isfinite(eq.latency_s))

    def test_deterministic(self):
        spec = default_cluster(16)
        a, b = solve_equilibrium(spec), solve_equilibrium(spec)
        assert np.array_equal(a.choices, b.choices)
        assert a.iterations == b.iterations
        assert np.allclose(a.latency_s, b.latency_s)

    def test_no_oscillation_on_uncontended_cluster(self):
        # plenty of capacity for 4 clients: plain best response suffices
        eq = solve_equilibrium(_small_spec(4))
        assert eq.converged and not eq.oscillation

    def test_max_iter_respected(self):
        eq = solve_equilibrium(default_cluster(64), max_iter=1)
        assert eq.iterations == 1
        assert not eq.converged

    def test_fixed_point_is_self_consistent(self):
        """At the fixed point, no client can improve by deviating — checked
        against the full response table."""
        spec = default_cluster(32)
        eq = solve_equilibrium(spec)
        assert eq.converged
        lam = spec.arrival_rates()
        for i in range(spec.n_clients):
            chosen = eq.latency_s[i]
            scn = induced_scenario(spec, eq.choices, i, allow_unstable=True)
            totals = analytic(scn).totals()
            best = min(totals.values())
            assert chosen <= best * (1 + 1e-9), (i, chosen, totals)
        assert np.allclose(eq.edge_loads.sum(), lam[eq.choices >= 0].sum())


class TestInducedScenario:
    def test_per_client_background_streams(self):
        spec = default_cluster(16)
        eq = solve_equilibrium(spec)
        offloaders = np.nonzero(eq.choices >= 0)[0]
        rep = int(offloaders[0])
        j = int(eq.choices[rep])
        scn = induced_scenario(spec, eq.choices, rep)
        same_edge = [c for c in offloaders if int(eq.choices[c]) == j and c != rep]
        assert len(scn.edges[j].background) == len(same_edge)
        # own stream excluded, everyone else's present once
        names = {t.name for t in scn.edges[j].background}
        assert f"cluster-client[{rep}]" not in names

    def test_open_loop_bridge_matches_equilibrium_latency(self):
        """analytic() on the induced scenario reproduces the closed-loop
        latency at the fixed point — the scalar and vectorized closed forms
        meet across the loop boundary."""
        spec = default_cluster(24)
        eq = solve_equilibrium(spec)
        for i in (0, spec.n_clients // 2, spec.n_clients - 1):
            scn = induced_scenario(spec, eq.choices, i, allow_unstable=True)
            tgt = int(eq.choices[i])
            key = "on_device" if tgt == ON_DEVICE else f"edge[{tgt}]"
            total = float(np.asarray(analytic(scn).totals()[key]))
            assert total == pytest.approx(float(eq.latency_s[i]), rel=1e-9)


class TestCrossCheck:
    def test_solver_overrides_flow_into_the_cross_check(self):
        """cross_check must evaluate the system the fixed point was solved
        for: rate/bandwidth overrides ride on the Equilibrium itself."""
        spec = _small_spec(4)
        lam = 1.5 * spec.arrival_rates()
        eq = solve_equilibrium(spec, arrival_rates=lam, bandwidth_Bps=1.5e6)
        assert np.allclose(eq.arrival_rates, lam)
        assert np.allclose(eq.bandwidth_Bps, 1.5e6)
        cc = cross_check_equilibrium(spec, eq, n=8_000, seed=0)
        for g in cc["groups"]:
            assert g["arrival_rate"] == pytest.approx(3.0)

    def test_predict_decisions_idle_estimate_falls_back_to_spec_rate(self):
        spec = _small_spec(2)
        choice, t_dev, t_edge = predict_decisions(
            spec, [0.0, 2.0], [2.5e6, 2.5e6],
            np.zeros((2, 2)), [0.0, 0.0])
        assert np.all(np.isfinite(t_dev))
        assert np.all(np.isfinite(t_edge))
        assert choice[0] == choice[1]  # idle client priced at the spec rate
        with pytest.raises(ScenarioError, match="n_clients"):
            predict_decisions(spec, [2.0], [2.5e6], [[0.0, 0.0]], [0.0, 0.0])

    def test_acceptance_analytic_vs_event_driven(self):
        """Acceptance criterion: closed-loop analytic means within 5% MAPE of
        the event-driven simulators at rho <= 0.9, on the seeded 64x4 spec."""
        spec = default_cluster(64)
        eq = solve_equilibrium(spec)
        assert eq.converged
        cc = cross_check_equilibrium(spec, eq, n=60_000, seed=0)
        assert cc["n_groups"] >= 2
        gated = [g for g in cc["groups"] if g["gated"]]
        assert gated, "the 64x4 spec must produce gated (rho<=0.9) groups"
        assert cc["gated_max_mape_pct"] <= 5.0, cc["groups"]


class TestClosedLoop:
    @staticmethod
    def _step_trace(duration=120.0, bw0=20e6 / 8, drop=0.15):
        third = duration / 3
        return make_trace(
            duration, 1.0,
            bandwidth_Bps=lambda t: step_signal(
                t, [(0, bw0), (third, bw0 * drop), (2 * third, bw0)]),
            arrival_rate=2.0,
        )

    def test_acceptance_adaptive_beats_every_static(self):
        spec = default_cluster(64)
        policies = ("adaptive", "on_device") + tuple(
            f"edge[{j}]" for j in range(spec.n_edges))
        res = simulate_cluster(spec, self._step_trace(), policies=policies,
                               stagger=8, seed=1)
        a = res.policies["adaptive"].mean_latency_s
        for name, p in res.policies.items():
            if name != "adaptive":
                assert a <= p.mean_latency_s, (name, a, p.mean_latency_s)
        assert res.adaptive_wins
        assert res.policies["adaptive"].saturated_epochs == 0

    def test_adapts_to_bandwidth_dip(self):
        """During the dip offloading is not worth 0.08 s of transfer: the
        whole fleet should be back on-device mid-trace, and offloading again
        at the end."""
        spec = default_cluster(64)
        res = simulate_cluster(spec, self._step_trace(), policies=("adaptive",),
                               stagger=8, seed=1)
        choices = res.policies["adaptive"].choices
        assert np.all(choices[60] == ON_DEVICE)  # mid-dip
        assert np.mean(choices[-1] >= 0) > 0.5  # recovered

    def test_statics_saturate_shared_edges(self):
        # 128 rps on any single edge exceeds every edge's capacity: the
        # all-on-one-edge statics saturate every client-epoch
        spec = default_cluster(64)
        tr = make_trace(30.0, 1.0, bandwidth_Bps=20e6 / 8, arrival_rate=2.0)
        res = simulate_cluster(spec, tr, policies=("edge[1]",))
        p = res.policies["edge[1]"]
        assert p.saturated_epochs == p.latencies_s.size

    def test_endogenous_loads_account_for_every_offloader(self):
        spec = default_cluster(32)
        res = simulate_cluster(spec, self._step_trace(60.0), policies=("adaptive",),
                               stagger=4, seed=2)
        p = res.policies["adaptive"]
        lam = res.traces.arrival_rate
        for t in (0, 20, 40, 59):
            offloaded = lam[t][p.choices[t] >= 0].sum()
            assert p.edge_loads[t].sum() == pytest.approx(offloaded)

    def test_single_client_cluster_matches_scalar_replay_statics(self):
        """With N=1 and no endogenous contention, the cluster scorer must
        reproduce the scalar replay's closed-form policy scores exactly."""
        spec = _small_spec(1)
        tr = self._step_trace(60.0)
        res = simulate_cluster(spec, tr, policies=("on_device", "edge[0]", "edge[1]"))
        rep = replay(spec.client(0), tr,
                     policies=("on_device", "edge[0]", "edge[1]"), seed=0)
        for name in ("on_device", "edge[0]", "edge[1]"):
            a = res.policies[name].latencies_s[:, 0]
            b = rep.policies[name].latencies_s
            np.testing.assert_allclose(a, b, rtol=1e-9)

    def test_same_seed_same_run(self):
        spec = _small_spec(6)
        tr = self._step_trace(40.0)
        r1 = simulate_cluster(spec, tr, seed=7, stagger=3)
        r2 = simulate_cluster(spec, tr, seed=7, stagger=3)
        assert np.array_equal(r1.policies["adaptive"].choices,
                              r2.policies["adaptive"].choices)
        np.testing.assert_array_equal(r1.est_arrival_rate, r2.est_arrival_rate)

    def test_stagger_bounds_validated(self):
        spec = _small_spec(4)
        tr = self._step_trace(30.0)
        with pytest.raises(ValueError, match="stagger"):
            simulate_cluster(spec, tr, stagger=0)
        with pytest.raises(ValueError, match="stagger"):
            simulate_cluster(spec, tr, stagger=5)

    def test_throughput_sanity(self):
        """The jitted loop must stay in vectorized territory (the bench
        asserts the real >=100k/s headline; this is a generous CI floor)."""
        import time

        spec = default_cluster(64)
        tr = make_trace(500.0, 1.0, bandwidth_Bps=20e6 / 8, arrival_rate=2.0)
        simulate_cluster(spec, tr, policies=("adaptive",), stagger=8)  # compile
        t0 = time.perf_counter()
        res = simulate_cluster(spec, tr, policies=("adaptive",), stagger=8, seed=1)
        rate = res.client_epochs / (time.perf_counter() - t0)
        assert rate >= 30_000, f"{rate:.0f} client-epochs/s"


class TestShardedScan:
    """``shards=k`` must reproduce ``shards=1`` exactly: decisions within an
    epoch depend only on lagged load reports, the Poisson chain is drawn once
    before blocking, and the endogenous total is restored by a psum — so
    blocking re-associates one float sum and changes nothing else."""

    @staticmethod
    def _run(shards, n=12):
        spec = default_cluster(n)
        tr = make_trace(
            60.0, 1.0,
            bandwidth_Bps=lambda t: step_signal(t, [(0, 2.5e6), (30, 6e5)]),
            arrival_rate=2.0,
        )
        return simulate_cluster(spec, tr, policies=("adaptive",), stagger=3,
                                hysteresis=0.05, seed=7, shards=shards)

    def _assert_exact(self, ref, res):
        a, b = ref.policies["adaptive"], res.policies["adaptive"]
        assert np.array_equal(a.choices, b.choices)
        assert np.allclose(a.latencies_s, b.latencies_s, rtol=1e-12, atol=0)
        assert np.allclose(a.edge_loads, b.edge_loads, rtol=1e-12, atol=1e-12)
        assert np.allclose(ref.est_endo_rate, res.est_endo_rate,
                           rtol=1e-12, atol=1e-15)
        assert np.allclose(ref.est_arrival_rate, res.est_arrival_rate,
                           rtol=1e-12, atol=0)

    def test_blocked_matches_flat(self):
        ref = self._run(1)
        # a meaningless comparison unless the loop actually couples clients
        assert ref.policies["adaptive"].offload_frac > 0
        self._assert_exact(ref, self._run(4))

    def test_padding_is_exact(self):
        # 5 does not divide 12: two blocks carry inert zero-rate dummies
        self._assert_exact(self._run(1), self._run(5))

    def test_shards_validated(self):
        spec = default_cluster(4)
        tr = make_trace(10.0, 1.0, bandwidth_Bps=1e6, arrival_rate=2.0)
        with pytest.raises(ValueError, match="shards"):
            simulate_cluster(spec, tr, shards=0)
        with pytest.raises(ValueError, match="shards"):
            simulate_cluster(spec, tr, shards=5)

    def test_shard_map_on_forced_multidevice(self):
        """The true multi-device path (shard_map over a 4-CPU mesh) agrees
        with the flat scan — run in a subprocess because device count is
        fixed at jax import."""
        import os
        import subprocess
        import sys

        import repro

        # repro is a namespace package (no __init__.py): locate via __path__
        src = os.path.dirname(os.path.abspath(list(repro.__path__)[0]))
        script = (
            "import jax, numpy as np\n"
            "assert len(jax.devices()) == 4, jax.devices()\n"
            "from repro.fleet import make_trace, simulate_cluster, step_signal\n"
            "from repro.launch.cluster_sim import default_cluster\n"
            "spec = default_cluster(8)\n"
            "tr = make_trace(30.0, 1.0,\n"
            "    bandwidth_Bps=lambda t: step_signal(t, [(0, 2.5e6), (15, 6e5)]),\n"
            "    arrival_rate=2.0)\n"
            "kw = dict(policies=('adaptive',), stagger=2, seed=7)\n"
            "a = simulate_cluster(spec, tr, **kw).policies['adaptive']\n"
            "b = simulate_cluster(spec, tr, shards=4, **kw).policies['adaptive']\n"
            "assert np.array_equal(a.choices, b.choices)\n"
            "assert np.allclose(a.latencies_s, b.latencies_s, rtol=1e-12)\n"
            "print('SHARDMAP_OK')\n"
        )
        env = dict(os.environ,
                   XLA_FLAGS="--xla_force_host_platform_device_count=4",
                   PYTHONPATH=src)
        proc = subprocess.run([sys.executable, "-c", script], env=env,
                              capture_output=True, text=True, timeout=300)
        assert proc.returncode == 0, proc.stderr
        assert "SHARDMAP_OK" in proc.stdout


class TestClusterCLI:
    def test_main_writes_report(self, tmp_path, capsys):
        from repro.launch.cluster_sim import main

        out = tmp_path / "cluster.json"
        rc = main(["--clients", "16", "--duration", "45", "--out", str(out)])
        assert rc == 0
        import json

        report = json.loads(out.read_text())
        assert report["equilibrium"]["converged"]
        assert report["replay"]["adaptive_wins"]
        assert report["replay"]["client_epochs"] == 16 * 45
        assert "client-epochs/s" in capsys.readouterr().out
