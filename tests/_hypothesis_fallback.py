"""Deterministic stand-in for the tiny slice of `hypothesis` these tests use.

The container image does not ship hypothesis; rather than skip the property
tests entirely we run each one over a fixed pseudo-random sample of the same
strategy space (seeded, so failures reproduce). When hypothesis IS installed
the real library is used instead — see ``tests/_prop.py``, which also lets CI
force this fallback (``REPRO_FORCE_HYPOTHESIS_FALLBACK=1``) so both paths
exercise the same cases.

Supported API surface: ``strategies.floats/integers/sampled_from/tuples/
lists`` with ``.filter()`` chaining, ``@given``, ``@settings(max_examples=)``,
``assume()`` (rejected draws are resampled, like the real library), and
``@example(...)`` (explicit cases run before the random sweep).
"""

from __future__ import annotations

import random

_MAX_EXAMPLES = 25  # fallback cap; the real library honours the caller's value
_MAX_REJECTIONS = 10_000  # combined assume()/filter() rejection budget per test


class UnsatisfiedAssumption(Exception):
    """Raised by assume(False); the current draw is discarded and resampled."""


def assume(condition) -> bool:
    """hypothesis.assume: reject the current example when ``condition`` is
    falsy. The wrapper resamples instead of failing the test."""
    if not condition:
        raise UnsatisfiedAssumption()
    return True


class _Strategy:
    """A value generator with hypothesis-style `.filter()` chaining."""

    def __init__(self, gen):
        self._gen = gen
        self._filters = []

    def filter(self, pred):
        s = _Strategy(self._gen)
        s._filters = self._filters + [pred]
        return s

    def example(self, rng: random.Random):
        for _ in range(_MAX_REJECTIONS):
            v = self._gen(rng)
            if all(f(v) for f in self._filters):
                return v
        raise ValueError("strategy filter rejected every sample")


def floats(lo, hi):
    return _Strategy(lambda r: r.uniform(lo, hi))


def integers(lo, hi):
    return _Strategy(lambda r: r.randint(lo, hi))


def sampled_from(seq):
    options = list(seq)
    return _Strategy(lambda r: options[r.randrange(len(options))])


def tuples(*strategies):
    return _Strategy(lambda r: tuple(s.example(r) for s in strategies))


def lists(strategy, min_size=0, max_size=10):
    return _Strategy(
        lambda r: [strategy.example(r) for _ in range(r.randint(min_size, max_size))]
    )


def settings(max_examples=_MAX_EXAMPLES, **_ignored):
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn

    return deco


def example(*args, **kwargs):
    """hypothesis.example: pin an explicit case; runs before the random sweep
    (applied below @given, exactly like the real decorator)."""

    def deco(fn):
        cases = list(getattr(fn, "_fallback_examples", ()))
        # decorators apply bottom-up; prepend so the topmost @example runs first
        fn._fallback_examples = [(args, kwargs)] + cases
        return fn

    return deco


def given(*strategies):
    def deco(fn):
        def wrapper(*args, **kwargs):
            n = min(getattr(fn, "_fallback_max_examples", _MAX_EXAMPLES), _MAX_EXAMPLES)
            # explicit @example cases first — these are regression pins, so an
            # assume() rejection inside one is a test bug worth surfacing
            for ex_args, ex_kwargs in getattr(fn, "_fallback_examples", ()):
                fn(*args, *ex_args, **{**kwargs, **ex_kwargs})
            rng = random.Random(0)
            runs = rejected = 0
            while runs < n:
                vals = tuple(s.example(rng) for s in strategies)
                try:
                    fn(*args, *vals, **kwargs)
                except UnsatisfiedAssumption:
                    rejected += 1
                    if rejected > _MAX_REJECTIONS:
                        raise ValueError(
                            "assume() rejected every sample "
                            f"({_MAX_REJECTIONS} draws)") from None
                    continue
                runs += 1

        # deliberately NOT functools.wraps: pytest must see the wrapper's
        # (self)-only signature, or it treats strategy params as fixtures
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper

    return deco
