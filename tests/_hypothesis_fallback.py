"""Deterministic stand-in for the tiny slice of `hypothesis` these tests use.

The container image does not ship hypothesis; rather than skip the property
tests entirely we run each one over a fixed pseudo-random sample of the same
strategy space (seeded, so failures reproduce). When hypothesis IS installed
the real library is used instead — see the try/except import in each test
module.
"""

from __future__ import annotations

import random

_MAX_EXAMPLES = 25  # fallback cap; the real library honours the caller's value


class _Strategy:
    """A value generator with hypothesis-style `.filter()` chaining."""

    def __init__(self, gen):
        self._gen = gen
        self._filters = []

    def filter(self, pred):
        s = _Strategy(self._gen)
        s._filters = self._filters + [pred]
        return s

    def example(self, rng: random.Random):
        for _ in range(10_000):
            v = self._gen(rng)
            if all(f(v) for f in self._filters):
                return v
        raise ValueError("strategy filter rejected every sample")


def floats(lo, hi):
    return _Strategy(lambda r: r.uniform(lo, hi))


def integers(lo, hi):
    return _Strategy(lambda r: r.randint(lo, hi))


def sampled_from(seq):
    options = list(seq)
    return _Strategy(lambda r: options[r.randrange(len(options))])


def tuples(*strategies):
    return _Strategy(lambda r: tuple(s.example(r) for s in strategies))


def lists(strategy, min_size=0, max_size=10):
    return _Strategy(
        lambda r: [strategy.example(r) for _ in range(r.randint(min_size, max_size))]
    )


def settings(max_examples=_MAX_EXAMPLES, **_ignored):
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn

    return deco


def given(*strategies):
    def deco(fn):
        def wrapper(*args, **kwargs):
            n = min(getattr(fn, "_fallback_max_examples", _MAX_EXAMPLES), _MAX_EXAMPLES)
            rng = random.Random(0)
            for _ in range(n):
                fn(*args, *(s.example(rng) for s in strategies), **kwargs)

        # deliberately NOT functools.wraps: pytest must see the wrapper's
        # (self)-only signature, or it treats strategy params as fixtures
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper

    return deco
