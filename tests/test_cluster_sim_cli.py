"""CLI-path coverage for ``repro.launch.cluster_sim``: trace-spec
validation is loud (exit 2 before any solve), divergence exits nonzero,
and the JSON report round-trips through plain JSON."""

import json

import numpy as np
import pytest

from repro.core.manager import ON_DEVICE
from repro.core.scenario import MeanFieldSpec, ScenarioError
from repro.fleet import static_fractions
from repro.launch.cluster_sim import (
    TraceSpecError,
    load_trace_spec,
    main,
    trace_signals,
)

GOOD = {"duration_s": 30.0, "epoch_s": 1.0,
        "bandwidth_Bps": [[0, 2.5e6], [10, 5e5], [20, 2.5e6]]}


def _write(tmp_path, doc, name="trace.json"):
    p = tmp_path / name
    p.write_text(doc if isinstance(doc, str) else json.dumps(doc))
    return p


class TestTraceSpec:
    @pytest.mark.parametrize("doc,msg", [
        ({**GOOD, "bogus": 1}, "unknown trace spec key"),
        ([1, 2, 3], "must be a JSON object"),
        ({"epoch_s": 1.0, "bandwidth_Bps": [[0, 1e6]]}, "duration_s"),
        ({**GOOD, "epoch_s": True}, "epoch_s"),
        ({**GOOD, "duration_s": 0.5}, "two"),
        ({"duration_s": 30.0, "epoch_s": 1.0}, "bandwidth_Bps .* required"),
        ({**GOOD, "bandwidth_Bps": []}, "non-empty list"),
        ({**GOOD, "bandwidth_Bps": [[0, 1e6, 2]]}, "number pair"),
        ({**GOOD, "bandwidth_Bps": [[0, "fast"]]}, "number pair"),
        ({**GOOD, "bandwidth_Bps": [[-5, 1e6]]}, "non-negative"),
        ({**GOOD, "bandwidth_Bps": [[0, -1e6]]}, "positive"),
        ({**GOOD, "bandwidth_Bps": [[10, 1e6], [0, 2e6]]}, "sorted"),
        ({**GOOD, "arrival_rate": [[0, 0.0]]}, "positive"),
        ({**GOOD, "edge_bg_rate": [[0, 1.0]]}, "object mapping"),
        ({**GOOD, "edge_bg_rate": {"x": [[0, 1.0]]}}, "not an edge index"),
        ({**GOOD, "edge_bg_rate": {"0": [[0, -1.0]]}}, "non-negative"),
    ])
    def test_malformed_specs_fail_loudly(self, tmp_path, doc, msg):
        with pytest.raises(TraceSpecError, match=msg):
            load_trace_spec(_write(tmp_path, doc))

    def test_not_json_fails_loudly(self, tmp_path):
        with pytest.raises(TraceSpecError, match="not valid JSON"):
            load_trace_spec(_write(tmp_path, "{nope"))
        with pytest.raises(TraceSpecError, match="cannot read"):
            load_trace_spec(tmp_path / "missing.json")

    def test_edge_index_out_of_range(self, tmp_path):
        ts = load_trace_spec(_write(
            tmp_path, {**GOOD, "edge_bg_rate": {"7": [[0, 5.0]]}}))
        with pytest.raises(TraceSpecError, match="out of range"):
            trace_signals(ts, 3, 2.0)

    def test_good_spec_signals(self, tmp_path):
        ts = load_trace_spec(_write(
            tmp_path, {**GOOD, "edge_bg_rate": {"1": [[0, 0.0], [10, 50.0]]}}))
        times, bw, lam, exo = trace_signals(ts, 2, 2.0)
        assert len(times) == 30
        assert bw[0] == 2.5e6 and bw[15] == 5e5 and bw[25] == 2.5e6
        assert np.all(lam == 2.0)  # defaulted to the spec's base rate
        assert exo.shape == (30, 2)
        assert exo[0, 1] == 0.0 and exo[15, 1] == 50.0 and np.all(exo[:, 0] == 0)

    def test_cli_rejects_bad_spec_with_exit_2(self, tmp_path, capsys):
        rc = main(["--trace", str(_write(tmp_path, {**GOOD, "bogus": 1}))])
        assert rc == 2
        assert "bad trace spec" in capsys.readouterr().err

    def test_cli_rejects_out_of_range_edge_with_exit_2(self, tmp_path, capsys):
        # the range check needs the spec's pool, so it trips inside the run
        bad = _write(tmp_path, {**GOOD, "edge_bg_rate": {"9": [[0, 5.0]]}})
        rc = main(["--meanfield", "--clients", "40", "--trace", str(bad)])
        assert rc == 2
        assert "out of range" in capsys.readouterr().err


class TestStaticFractions:
    def test_one_hot_layout(self):
        f = static_fractions("on_device", 3, 4)
        assert f.shape == (3, 5)
        assert np.array_equal(f[:, 0], np.ones(3)) and f[:, 1:].sum() == 0
        g = static_fractions("edge[2]", 2, 4)
        assert np.array_equal(g.sum(axis=1), np.ones(2)) and np.all(g[:, 3] == 1)
        assert ON_DEVICE == -1  # column 0 is the ON_DEVICE sentinel's slot

    def test_bad_labels_fail_like_policies(self):
        with pytest.raises(ScenarioError, match="policies"):
            static_fractions("edge[9]", 2, 2)
        with pytest.raises(ValueError, match="n_classes"):
            static_fractions("on_device", 0, 2)


class TestMeanFieldCLI:
    def test_divergence_exits_nonzero(self, capsys):
        # one damped iteration cannot reach the fixed point from the
        # all-on-device start; the CLI must say so and fail
        rc = main(["--meanfield", "--clients", "2000", "--duration", "30",
                   "--max-iter", "1"])
        assert rc == 1
        assert "NOT CONVERGED" in capsys.readouterr().out

    def test_report_round_trips(self, tmp_path, capsys):
        ts = _write(tmp_path, {
            **GOOD, "arrival_rate": [[0, 0.05]],
            "edge_bg_rate": {"0": [[0, 0.0], [10, 40.0]]}})
        out = tmp_path / "mf.json"
        rc = main(["--meanfield", "--clients", "2000", "--trace", str(ts),
                   "--cross-check", "--out", str(out)])
        assert rc == 0
        rep = json.loads(out.read_text())
        # everything in the report is JSON-native (no numpy scalars survive)
        assert json.loads(json.dumps(rep)) == rep
        assert rep["mode"] == "meanfield"
        assert rep["equilibrium"]["converged"] is True
        assert rep["adaptive_wins"] is True
        assert rep["replay"]["client_epochs"] == 2000 * 30
        assert 0.0 <= rep["replay"]["offload_frac_min"] <= \
            rep["replay"]["offload_frac_max"] <= 1.0
        # the spec block reconstructs the fleet that actually ran
        spec = MeanFieldSpec.from_dict(rep["spec"])
        assert spec.n_total == 2000 and spec.n_classes == 3
        # mean-field vs exact solver agreement, gated like the tier-2 gate
        assert rep["cross_check"]["converged"] is True
        assert rep["cross_check"]["gated_max_mape_pct"] <= 5.0
        assert "client-epochs/s" in capsys.readouterr().out
