"""Mean-field cluster tests: ClientClass/MeanFieldSpec validation and
expansion, Wardrop fixed-point convergence and self-consistency, the
mean-field-vs-exact cross-check gate (<=5% MAPE), and the diurnal
class-fraction replay (convergence to the static fixed point, adaptation
to bandwidth dips, determinism)."""

import numpy as np
import pytest

from repro.core import (
    ClientClass,
    EdgeSpec,
    MeanFieldSpec,
    NetworkPath,
    Scenario,
    ScenarioError,
    ServiceModel,
    Tier,
    Workload,
)
from repro.fleet import (
    TraceBatch,
    cross_check_meanfield,
    simulate_meanfield,
    solve_equilibrium,
    solve_meanfield_equilibrium,
    step_signal,
)


def _base(**kw) -> Scenario:
    defaults = dict(
        workload=Workload(2.0, 30_000, 1_000, name="inceptionv4"),
        device=Tier("orin", 0.045),
        edges=(
            EdgeSpec(Tier("a2", 0.028)),
            EdgeSpec(Tier("t4", 0.020, service_model=ServiceModel.EXPONENTIAL)),
        ),
        network=NetworkPath(20e6 / 8),
    )
    defaults.update(kw)
    return Scenario(**defaults)


def _spec(**kw) -> MeanFieldSpec:
    defaults = dict(
        base=_base(),
        classes=(
            ClientClass(n_clients=16, arrival_scale=1.0, name="steady"),
            ClientClass(n_clients=16, arrival_scale=0.5, name="light"),
            ClientClass(n_clients=8, arrival_scale=2.0, bandwidth_scale=0.5,
                        name="heavy"),
        ),
        name="mf-test",
    )
    defaults.update(kw)
    return MeanFieldSpec(**defaults)


class TestMeanFieldSpec:
    def test_round_trip(self):
        spec = _spec(classes=(
            ClientClass(n_clients=4, arrival_scale=0.5, bandwidth_scale=2.0,
                        device=Tier("nano", 0.120), name="slow"),
            ClientClass(n_clients=8, name="plain"),
        ))
        assert MeanFieldSpec.from_dict(spec.to_dict()) == spec

    def test_validation_named_fields(self):
        with pytest.raises(ScenarioError, match="n_clients"):
            ClientClass(n_clients=0)
        with pytest.raises(ScenarioError, match="arrival_scale"):
            ClientClass(n_clients=2, arrival_scale=-1.0)
        with pytest.raises(ScenarioError, match="bandwidth_scale"):
            ClientClass(n_clients=2, bandwidth_scale=0.0)
        with pytest.raises(ScenarioError, match="classes"):
            MeanFieldSpec(base=_base(), classes=())
        no_edges = Scenario(workload=_base().workload, device=_base().device,
                            network=_base().network, edges=())
        with pytest.raises(ScenarioError, match="base.edges"):
            MeanFieldSpec(base=no_edges, classes=(ClientClass(n_clients=2),))

    def test_from_dict_missing_field_named(self):
        with pytest.raises(ScenarioError, match="classes"):
            MeanFieldSpec.from_dict({"base": _base().to_dict()})
        with pytest.raises(ScenarioError, match=r"classes\[0\].n_clients"):
            MeanFieldSpec.from_dict(
                {"base": _base().to_dict(), "classes": [{"arrival_scale": 1.0}]})

    def test_class_views(self):
        spec = _spec()
        assert spec.n_total == 40
        assert spec.n_classes == 3
        np.testing.assert_allclose(spec.arrival_rates(), [2.0, 1.0, 4.0])
        np.testing.assert_allclose(spec.class_counts(), [16, 16, 8])
        np.testing.assert_allclose(
            spec.bandwidth_Bps(), [2.5e6, 2.5e6, 1.25e6])
        np.testing.assert_allclose(
            spec.bandwidth_Bps(1e6), [1e6, 1e6, 0.5e6])
        idx = spec.class_index()
        assert idx.shape == (40,)
        assert list(idx[:16]) == [0] * 16 and list(idx[-8:]) == [2] * 8

    def test_to_cluster_expansion(self):
        spec = _spec()
        cluster = spec.to_cluster()
        assert cluster.n_clients == 40
        lam = cluster.arrival_rates()
        np.testing.assert_allclose(lam[:16], 2.0)
        np.testing.assert_allclose(lam[16:32], 1.0)
        np.testing.assert_allclose(lam[32:], 4.0)
        assert cluster.base == spec.base

    def test_to_cluster_refuses_device_overrides(self):
        spec = _spec(classes=(
            ClientClass(n_clients=4, device=Tier("nano", 0.120)),))
        with pytest.raises(ScenarioError, match=r"classes\[0\].device"):
            spec.to_cluster()
        # an override equal to the base device is the base device: allowed
        same = _spec(classes=(ClientClass(n_clients=4, device=_base().device),))
        assert same.to_cluster().n_clients == 4

    def test_device_tier_override(self):
        spec = _spec(classes=(
            ClientClass(n_clients=4, device=Tier("nano", 0.120), name="slow"),
            ClientClass(n_clients=4, name="plain"),
        ))
        assert spec.device_tier(0).name == "nano"
        assert spec.device_tier(1).name == "orin"


class TestMeanFieldEquilibrium:
    def test_converges_and_fractions_are_a_distribution(self):
        mf = solve_meanfield_equilibrium(_spec())
        assert mf.converged
        assert mf.regret_pct <= 1e-3
        np.testing.assert_allclose(mf.fractions.sum(axis=1), 1.0, atol=1e-12)
        assert np.all(mf.fractions >= 0)
        assert np.all(np.isfinite(mf.latency_s))

    def test_fixed_point_is_self_consistent(self):
        """Wardrop condition: every occupied sub-cohort's staying cost is
        within the regret tolerance of the best move available TO IT (its own
        cost row — self-exclusion makes ``cost[c, m, j] != cost[c, j, j]`` by
        one marginal client, so rows are not comparable across cohorts)."""
        mf = solve_meanfield_equilibrium(_spec())
        c_n, e1 = mf.fractions.shape
        assert mf.cost_s.shape == (c_n, e1, e1)
        np.testing.assert_allclose(
            mf.class_latency_s, mf.cost_s[:, np.arange(e1), np.arange(e1)])
        for c in range(c_n):
            for m in range(e1):
                if mf.fractions[c, m] > 1e-6:
                    stay = mf.cost_s[c, m, m]
                    best = mf.cost_s[c, m].min()
                    assert stay <= best * (1 + 1e-4)

    def test_loads_are_rate_weighted_fractions(self):
        mf = solve_meanfield_equilibrium(_spec())
        expect = np.sum(
            (mf.counts * mf.arrival_rates)[:, None] * mf.fractions[:, 1:],
            axis=0)
        np.testing.assert_allclose(mf.edge_loads, expect, rtol=1e-12)

    def test_acceptance_cross_check_within_5pct(self):
        """The PR acceptance gate: mean-field matches the exact small-N
        solver within 5% MAPE on per-class latencies and edge utilizations."""
        rep = cross_check_meanfield(_spec())
        assert rep["meanfield_converged"] and rep["exact_converged"]
        assert rep["gated_max_mape_pct"] is not None
        assert rep["gated_max_mape_pct"] <= 5.0

    def test_expected_counts_track_exact_counts(self):
        spec = _spec()
        mf = solve_meanfield_equilibrium(spec)
        eq = solve_equilibrium(spec.to_cluster(),
                               bandwidth_Bps=np.repeat(
                                   spec.bandwidth_Bps(),
                                   [c.n_clients for c in spec.classes]))
        mf_counts = mf.expected_counts()
        for target, exact_n in eq.counts().items():
            assert abs(mf_counts[target] - exact_n) <= max(4, 0.2 * spec.n_total)

    def test_slower_device_class_offloads_more(self):
        spec = _spec(classes=(
            ClientClass(n_clients=8, device=Tier("nano", 0.200), name="slow"),
            ClientClass(n_clients=8, name="fast"),
        ))
        mf = solve_meanfield_equilibrium(spec)
        assert mf.converged
        off = mf.fractions[:, 1:].sum(axis=1)
        assert off[0] > off[1]

    def test_uncontended_class_goes_all_edge(self):
        """One light client-class, a fast idle edge: everyone offloads —
        the mean-field twin of the exact solver's uncontended case."""
        spec = _spec(classes=(ClientClass(n_clients=2, arrival_scale=0.25),))
        mf = solve_meanfield_equilibrium(spec)
        assert mf.converged
        assert mf.fractions[0, 0] < 1e-9
        assert mf.offload_frac == pytest.approx(1.0)

    def test_slo_quantile_mode(self):
        mf = solve_meanfield_equilibrium(_spec(), slo_quantile=0.99)
        assert mf.converged
        mean = solve_meanfield_equilibrium(_spec())
        # q-quantile costs dominate the means everywhere
        assert np.all(mf.latency_s >= mean.latency_s - 1e-12)

    def test_bandwidth_override_shapes(self):
        spec = _spec()
        with pytest.raises(ScenarioError, match="bandwidth_Bps"):
            solve_meanfield_equilibrium(spec, bandwidth_Bps=np.ones(2))
        mf = solve_meanfield_equilibrium(spec, bandwidth_Bps=1e6)
        np.testing.assert_allclose(mf.bandwidth_Bps, [1e6, 1e6, 0.5e6])

    def test_damping_validated(self):
        with pytest.raises(ValueError, match="damping"):
            solve_meanfield_equilibrium(_spec(), damping=0.0)
        with pytest.raises(ValueError, match="slo_quantile"):
            solve_meanfield_equilibrium(_spec(), slo_quantile=1.5)


class TestSimulateMeanField:
    def _traces(self, spec, drop_frac=None, duration=240.0, epoch=2.0):
        times = np.arange(0.0, duration, epoch)
        bw0 = spec.bandwidth_Bps()
        sig = np.ones_like(times) if drop_frac is None else step_signal(
            times, [(0.0, 1.0), (duration / 3, drop_frac),
                    (2 * duration / 3, 1.0)])
        bw = np.stack([bw0[c] * sig for c in range(spec.n_classes)], axis=1)
        lam = np.broadcast_to(spec.arrival_rates(),
                              (len(times), spec.n_classes)).copy()
        exo = np.zeros((len(times), spec.n_edges))
        return TraceBatch(times=times, bandwidth_Bps=bw, arrival_rate=lam,
                          edge_bg_rate=exo)

    def test_trace_class_count_mismatch_raises(self):
        spec = _spec()
        bad = self._traces(_spec(classes=(ClientClass(n_clients=4),)))
        with pytest.raises(ScenarioError, match="traces"):
            simulate_meanfield(spec, bad)

    def test_switch_fraction_validated(self):
        spec = _spec()
        with pytest.raises(ValueError, match="switch_fraction"):
            simulate_meanfield(spec, self._traces(spec), switch_fraction=0.0)

    def test_constant_conditions_converge_to_fixed_point(self):
        spec = _spec()
        res = simulate_meanfield(spec, self._traces(spec))
        mf = solve_meanfield_equilibrium(spec)
        # the replay's terminal per-class latency matches the static fixed
        # point (the fractions themselves may sit anywhere on the equal-cost
        # plateau, so compare prices, not masses)
        np.testing.assert_allclose(res.latency_s[-1], mf.latency_s, rtol=0.02)
        np.testing.assert_allclose(
            res.rho_edges[-1], mf.rho_edges, atol=0.05)

    def test_adapts_to_bandwidth_dip(self):
        spec = _spec()
        res = simulate_meanfield(spec, self._traces(spec, drop_frac=0.08))
        t_n = res.n_epochs
        mid = slice(t_n // 3 + 5, 2 * t_n // 3)
        # offloading retreats while the shared path is degraded
        assert res.offload_frac[mid].mean() < res.offload_frac[:t_n // 3].mean()

    def test_deterministic(self):
        spec = _spec()
        tr = self._traces(spec)
        a = simulate_meanfield(spec, tr)
        b = simulate_meanfield(spec, tr)
        np.testing.assert_array_equal(a.fractions, b.fractions)
        np.testing.assert_array_equal(a.latency_s, b.latency_s)

    def test_shapes_and_throughput_accounting(self):
        spec = _spec()
        tr = self._traces(spec)
        res = simulate_meanfield(spec, tr)
        t_n, c_n, e_n = tr.n_epochs, spec.n_classes, spec.n_edges
        assert res.fractions.shape == (t_n, c_n, e_n + 1)
        assert res.edge_loads.shape == (t_n, e_n)
        assert res.latency_s.shape == (t_n, c_n)
        assert res.client_epochs == spec.n_total * t_n
        assert res.saturated_epochs == 0
