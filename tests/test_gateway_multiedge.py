"""OffloadGateway/EdgeHandle multi-edge selection: the deployable gateway and
the closed-loop cluster decision path must agree on identical inputs, and a
fully saturated pool degrades to on-device instead of raising."""

import numpy as np
import pytest

from repro.core import (
    ClusterSpec,
    EdgeSpec,
    NetworkPath,
    Scenario,
    ServiceModel,
    Tier,
    Workload,
)
from repro.core.manager import ON_DEVICE
from repro.core.scenario import implied_service_var
from repro.fleet import predict_decisions
from repro.serving.gateway import EdgeHandle, OffloadGateway


def _scn(**kw) -> Scenario:
    defaults = dict(
        workload=Workload(2.0, 30_000, 1_000, name="inceptionv4"),
        device=Tier("orin", 0.045),
        edges=(
            EdgeSpec(Tier("a2", 0.028)),
            EdgeSpec(Tier("a100", 0.008)),
            EdgeSpec(Tier("t4", 0.020, service_model=ServiceModel.EXPONENTIAL)),
        ),
        network=NetworkPath(20e6 / 8),
    )
    defaults.update(kw)
    return Scenario(**defaults)


def _report_cluster_loads(gw: OffloadGateway, scn: Scenario, endo) -> None:
    """Feed the gateway the same per-edge view the cluster decision path
    uses: the edge reports its full aggregate (other clients + a stream
    statistically identical to ours already counted in), with the
    homogeneous-cluster mixture template."""
    lam = scn.workload.arrival_rate
    for j, h in enumerate(gw.edges):
        tier = scn.edges[j].tier
        h.observe_load(endo[j] + lam, tier.service_time_s,
                       implied_service_var(tier))


class TestMultiEdgeSelection:
    @pytest.mark.parametrize("endo", [
        (0.0, 0.0, 0.0),       # empty pool: fastest edge wins
        (0.0, 80.0, 0.0),      # crowd on a100: next-best edge wins
        (20.0, 80.0, 30.0),    # load everywhere: argmin over loaded forms
        (30.0, 100.0, 40.0),   # heavy but stable: may fall back on-device
    ])
    def test_gateway_picks_the_cluster_edge(self, endo):
        scn = _scn()
        spec = ClusterSpec(base=scn, n_clients=1, name="gw-coherence")
        choice, t_dev, t_edge = predict_decisions(
            spec, [scn.workload.arrival_rate],
            [float(np.asarray(scn.network.bandwidth_Bps))],
            [list(endo)], [0.0, 0.0, 0.0])

        gw = OffloadGateway.from_scenario(scn)
        _report_cluster_loads(gw, scn, endo)
        # no arrivals observed -> the gateway falls back to the spec rate,
        # matching the cluster's lam_hat above
        d = gw.decide(now=1.0)
        assert d.edge_index == choice[0], (endo, d.t_edges, t_edge)
        assert d.t_dev == pytest.approx(float(t_dev[0]), rel=1e-9)
        for j in range(len(scn.edges)):
            assert d.t_edges[j] == pytest.approx(float(t_edge[0, j]), rel=1e-9)

    def test_rate_only_report_prices_load_at_own_service_moments(self):
        """A load report WITHOUT moments must still price the reported rate
        with this workload's service moments (the bg_template convention),
        never at zero service time — an 80 rps report makes a 125 rps edge
        visibly busy."""
        scn = _scn()
        spec = ClusterSpec(base=scn, n_clients=1, name="rate-only")
        endo = (0.0, 80.0, 0.0)
        gw = OffloadGateway.from_scenario(scn)
        lam = scn.workload.arrival_rate
        for j, h in enumerate(gw.edges):
            h.observe_load(endo[j] + lam)  # rate only, no moments
        d = gw.decide(now=1.0)
        choice, _t_dev, t_edge = predict_decisions(
            spec, [lam], [float(np.asarray(scn.network.bandwidth_Bps))],
            [list(endo)], [0.0, 0.0, 0.0])
        assert d.edge_index == choice[0]
        for j in range(len(scn.edges)):
            assert d.t_edges[j] == pytest.approx(float(t_edge[0, j]), rel=1e-9)

    def test_all_edges_saturated_degrades_to_on_device(self):
        """rho >= 1 on every edge: the gateway must place on-device, not
        raise — saturation is a routine operating point of a shared pool."""
        scn = _scn()
        gw = OffloadGateway.from_scenario(scn)
        # aggregate rates beyond every edge's k*mu AND the return NIC
        _report_cluster_loads(gw, scn, (60.0, 140.0, 80.0))
        d = gw.decide(now=1.0)
        assert d.edge_index == ON_DEVICE
        assert d.strategy == "on_device"
        assert np.isfinite(d.t_dev)
        assert all(not np.isfinite(t) for t in d.t_edges)
        # and it keeps serving epochs without accumulating errors
        for epoch in range(2, 5):
            assert gw.decide(now=float(epoch)).edge_index == ON_DEVICE


class TestEdgeHandleLoadReports:
    def test_observe_load_ewma_and_template_refresh(self):
        h = EdgeHandle(name="e", service_mean_s=0.02)
        h.observe_load(10.0, 0.02, 0.0)
        assert h.background_rate == pytest.approx(10.0)  # first report is raw
        h.observe_load(20.0)
        assert h.background_rate == pytest.approx(15.0)  # alpha = 0.5 EWMA
        assert h.background_service_s == pytest.approx(0.02)  # template kept
        h.observe_load(15.0, 0.03, 1e-4)
        assert h.background_service_s == pytest.approx(0.03)
        assert h.background_service_var == pytest.approx(1e-4)

    def test_negative_report_rejected(self):
        h = EdgeHandle(name="e", service_mean_s=0.02)
        with pytest.raises(ValueError):
            h.observe_load(-1.0)

    def test_degenerate_moment_reports_rejected(self):
        # a zero/negative mean would price reported load at zero service time
        h = EdgeHandle(name="e", service_mean_s=0.02)
        with pytest.raises(ValueError):
            h.observe_load(5.0, service_mean_s=0.0)
        with pytest.raises(ValueError):
            h.observe_load(5.0, service_var=-1e-3)
        assert h.background_rate == 0.0  # nothing was recorded

    def test_hand_built_handle_rate_only_report_uses_own_moments(self):
        h = EdgeHandle(name="e", service_mean_s=0.02, service_var_s=4e-4)
        h.observe_load(5.0)
        assert h.background_service_s == pytest.approx(0.02)
        assert h.background_service_var == pytest.approx(4e-4)

    def test_state_reflects_reported_background(self):
        scn = _scn()
        h = EdgeHandle.from_spec(scn.edges[0])
        h.observe_load(12.0, 0.028, 0.0)
        st = h.state()
        assert st.arrival_rate == pytest.approx(12.0)
        assert st.service_time_s == pytest.approx(h.service_mean_s)
