"""The experiment layer: spec validation + round-trip, registry completeness
(every bench family and validate regime exactly once, payloads resolve),
runner semantics (resume-skip, output contract, multi-seed bootstrap CIs),
two-run byte-stability of results/ artifacts, and the reproduce CLI."""

import itertools
import json
from dataclasses import replace
from pathlib import Path

import pytest

from repro.exp import (
    ExperimentError,
    ExperimentSpec,
    bench_family_specs,
    diff_results,
    registry,
    resolve_payload,
    run_experiment,
    run_id_for,
    strip_volatile,
)
from repro.launch import reproduce

# -- a controllable payload the runner resolves by dotted name ---------------
# (tests/ is on sys.path under pytest, so "test_exp:fake_payload" resolves)

_CALLS = itertools.count()


def fake_payload(out_dir, seed, config):
    doc = {
        "value": 10.0 * (seed + 1) + float(config.get("offset", 0)),
        "elapsed_s": 0.25 + next(_CALLS),  # wall-clock stand-in: never stable
        "stable": "constant",
    }
    (Path(out_dir) / "OUT.json").write_text(json.dumps(doc, indent=2))
    return {"value": doc["value"], "gate": {"passed": config.get("ok", True)}}


def fake_spec(**over) -> ExperimentSpec:
    kw = dict(
        exp_id="fake-exp",
        kind="bench-family",
        payload="test_exp:fake_payload",
        seeds=(0,),
        seed_sensitive=True,
        outputs=("OUT.json",),
        volatile={"OUT.json": ("elapsed_s",)},
    )
    kw.update(over)
    return ExperimentSpec(**kw)


class TestSpec:
    def test_round_trip_is_exact(self):
        spec = fake_spec(config={"offset": 3}, gates={"budget_pct": 5.0},
                         seeds=(0, 1))
        assert ExperimentSpec.from_dict(spec.to_dict()) == spec
        # and the dict itself survives a JSON round trip unchanged
        d = spec.to_dict()
        assert json.loads(json.dumps(d)) == d

    def test_from_dict_rejects_unknown_fields(self):
        d = fake_spec().to_dict()
        d["surprise"] = 1
        with pytest.raises(ExperimentError, match="surprise"):
            ExperimentSpec.from_dict(d)

    def test_frozen(self):
        with pytest.raises(AttributeError):
            fake_spec().exp_id = "other"

    @pytest.mark.parametrize("over,msg", [
        ({"exp_id": "Bad Id"}, "exp_id"),
        ({"kind": "bench"}, "kind"),
        ({"payload": "no_colon"}, "payload"),
        ({"seeds": ()}, "non-empty"),
        ({"seeds": (1, 1)}, "duplicate seeds"),
        ({"seeds": (-1,)}, ">= 0"),
        ({"outputs": ("a.json", "a.json")}, "duplicate outputs"),
        ({"volatile": {"other.json": ("x",)}}, "undeclared output"),
    ])
    def test_validation_is_loud(self, over, msg):
        with pytest.raises(ExperimentError, match=msg):
            fake_spec(**over)


class TestRegistry:
    def test_every_bench_module_registered_exactly_once(self):
        """Registry completeness: each benchmarks/*_bench.py rows module
        backs exactly one experiment — a new bench family that isn't
        registered (or a stale registration) fails here."""
        bench_dir = Path(__file__).resolve().parent.parent / "benchmarks"
        modules = {f"benchmarks.{p.stem}" for p in bench_dir.glob("*_bench.py")}
        payload_mods = [s.payload.split(":")[0] for s in registry().values()]
        assert modules, "no bench modules found?"
        for mod in sorted(modules):
            assert payload_mods.count(mod) == 1, mod

    def test_validate_regimes_present_exactly_once_each(self):
        reg = registry()
        regimes = [e for e, s in reg.items() if s.kind == "validate-regime"]
        assert sorted(regimes) == ["validate-full", "validate-smoke"]
        assert reg["validate-smoke"].config["smoke"] is True
        assert reg["validate-full"].config["smoke"] is False

    def test_benches_cli_derives_from_registry(self):
        from benchmarks.run import BENCHES

        assert set(BENCHES) == set(bench_family_specs())

    def test_all_payloads_resolve(self):
        for spec in registry().values():
            assert callable(resolve_payload(spec.payload)), spec.exp_id

    def test_kinds_cover_taxonomy(self):
        kinds = {s.kind for s in registry().values()}
        assert kinds == {"bench-family", "validate-regime", "figure",
                         "measured-profile", "cluster-sim"}


class TestStripVolatile:
    def test_dotted_and_wildcard_paths(self):
        doc = {"a": {"wall_s": 1.0, "keep": 2}, "b": {"wall_s": 3.0},
               "top": 4}
        out = strip_volatile(doc, ("*.wall_s", "top"))
        assert out == {"a": {"keep": 2}, "b": {}}
        assert doc["top"] == 4  # original untouched

    def test_missing_paths_are_fine(self):
        assert strip_volatile({"x": 1}, ("nope.deep",)) == {"x": 1}


class TestRunner:
    def test_run_layout_and_resume_skip(self, tmp_path):
        spec = fake_spec()
        res = run_experiment(spec, results_root=tmp_path)
        assert not res.skipped and res.passed
        assert res.run_dir == tmp_path / "fake-exp" / res.run_id
        for fname in ("manifest.json", "metrics.json", "summary.md"):
            assert (res.run_dir / fname).exists(), fname
        assert (res.run_dir / "seed-0" / "OUT.json").exists()
        manifest = json.loads((res.run_dir / "manifest.json").read_text())
        assert manifest["experiment"]["spec"] == spec.to_dict()
        assert manifest["experiment"]["seeds"] == [0]
        # identical rerun: skipped, same dir, verdict preserved
        again = run_experiment(spec, results_root=tmp_path)
        assert again.skipped and again.passed
        assert again.run_dir == res.run_dir
        # force reruns in place
        forced = run_experiment(spec, results_root=tmp_path, force=True)
        assert not forced.skipped

    def test_config_change_is_a_new_run(self, tmp_path):
        a = run_experiment(fake_spec(), results_root=tmp_path)
        b = run_experiment(fake_spec(config={"offset": 7}),
                           results_root=tmp_path)
        assert not b.skipped
        assert a.run_id != b.run_id

    def test_seeds_override_only_when_seed_sensitive(self, tmp_path):
        res = run_experiment(fake_spec(), results_root=tmp_path,
                             seeds=(0, 1, 2))
        assert res.seeds == (0, 1, 2)
        pinned = run_experiment(fake_spec(seed_sensitive=False),
                                results_root=tmp_path, seeds=(0, 1, 2))
        assert pinned.seeds == (0,)

    def test_multi_seed_bootstrap_ci(self, tmp_path):
        res = run_experiment(fake_spec(), results_root=tmp_path,
                             seeds=(0, 1, 2))
        agg = res.metrics["aggregate"]["value"]
        assert agg["n_seeds"] == 3
        assert agg["mean"] == pytest.approx(20.0)  # mean of 10, 20, 30
        assert agg["ci95_lo"] <= agg["mean"] <= agg["ci95_hi"]
        assert agg["seed_stable"] is False
        assert (res.run_dir / "seed-2" / "OUT.json").exists()

    def test_gate_failure_fails_the_run(self, tmp_path):
        res = run_experiment(fake_spec(config={"ok": False}),
                             results_root=tmp_path)
        assert not res.passed
        assert "FAIL" in (res.run_dir / "summary.md").read_text()

    def test_missing_declared_output_is_loud(self, tmp_path):
        spec = fake_spec(outputs=("OUT.json", "NEVER.json"),
                         volatile={"OUT.json": ("elapsed_s",)})
        with pytest.raises(ExperimentError, match="NEVER.json"):
            run_experiment(spec, results_root=tmp_path)

    def test_partial_run_is_not_resumed(self, tmp_path):
        res = run_experiment(fake_spec(), results_root=tmp_path)
        (res.run_dir / "summary.md").unlink()  # simulate a crash mid-write
        again = run_experiment(fake_spec(), results_root=tmp_path)
        assert not again.skipped


class TestByteStability:
    def test_two_runs_stable_with_volatile_masked(self, tmp_path):
        spec = fake_spec()
        run_experiment(spec, results_root=tmp_path / "a")
        run_experiment(spec, results_root=tmp_path / "b")
        reg = {spec.exp_id: spec}
        assert diff_results(tmp_path / "a", tmp_path / "b", reg) == []

    def test_undeclared_drift_is_caught(self, tmp_path):
        spec = fake_spec()
        run_experiment(spec, results_root=tmp_path / "a")
        run_experiment(spec, results_root=tmp_path / "b")
        # same trees, but pretend the spec never declared elapsed_s volatile
        bare = {spec.exp_id: replace(spec, volatile={})}
        diffs = diff_results(tmp_path / "a", tmp_path / "b", bare)
        assert diffs and any("OUT.json" in d for d in diffs)

    def test_missing_file_is_a_difference(self, tmp_path):
        spec = fake_spec()
        ra = run_experiment(spec, results_root=tmp_path / "a")
        run_experiment(spec, results_root=tmp_path / "b")
        (ra.run_dir / "seed-0" / "OUT.json").unlink()
        diffs = diff_results(tmp_path / "a", tmp_path / "b",
                             {spec.exp_id: spec})
        assert any("only in" in d for d in diffs)


class TestReproduceCLI:
    def test_list_exits_zero(self, capsys):
        assert reproduce.main(["--list"]) == 0
        out = capsys.readouterr().out
        for exp_id in registry():
            assert exp_id in out

    def test_unknown_only_exits_2_listing_registry(self, capsys):
        rc = reproduce.main(["--only", "not-an-exp"])
        assert rc == 2
        err = capsys.readouterr().err
        assert "not-an-exp" in err and "validate-smoke" in err

    def test_no_selection_exits_2(self, capsys):
        assert reproduce.main([]) == 2

    def test_run_report_and_skip(self, tmp_path, monkeypatch, capsys):
        spec = fake_spec()
        monkeypatch.setattr(reproduce, "registry",
                            lambda: {spec.exp_id: spec})
        argv = ["--only", "fake-exp", "--seeds", "2",
                "--results", str(tmp_path / "results"),
                "--report", str(tmp_path / "REPRODUCTION.md")]
        assert reproduce.main(argv) == 0
        report = (tmp_path / "REPRODUCTION.md").read_text()
        assert "fake-exp" in report and "PASS" in report
        assert "| ran |" in report
        # immediate rerun skips the completed run and still passes
        assert reproduce.main(argv) == 0
        assert "skipped" in capsys.readouterr().out
        assert "skipped (complete)" in (tmp_path / "REPRODUCTION.md").read_text()

    def test_gate_failure_exits_nonzero(self, tmp_path, monkeypatch):
        spec = fake_spec(config={"ok": False})
        monkeypatch.setattr(reproduce, "registry",
                            lambda: {spec.exp_id: spec})
        rc = reproduce.main(["--only", "fake-exp",
                             "--results", str(tmp_path / "results"),
                             "--report", str(tmp_path / "R.md")])
        assert rc == 1
        assert "FAIL" in (tmp_path / "R.md").read_text()

    def test_diff_mode(self, tmp_path, monkeypatch, capsys):
        spec = fake_spec()
        run_experiment(spec, results_root=tmp_path / "a")
        run_experiment(spec, results_root=tmp_path / "b")
        monkeypatch.setattr(reproduce, "registry",
                            lambda: {spec.exp_id: spec})
        assert reproduce.main(["--diff", str(tmp_path / "a"),
                               str(tmp_path / "b")]) == 0
        assert "byte-stable" in capsys.readouterr().out


class TestRealRegistryEndToEnd:
    def test_validate_smoke_no_sim_through_runner(self, tmp_path):
        """One real registry experiment end to end (analytic-only smoke
        regime for speed): artifacts land under results/, the gate passes,
        and VALIDATION.json carries its provenance manifest."""
        base = registry()["validate-smoke"]
        spec = replace(base, config={**base.config, "no_sim": True})
        res = run_experiment(spec, results_root=tmp_path)
        assert res.passed and not res.skipped
        doc = json.loads(
            (res.run_dir / "seed-0" / "VALIDATION.json").read_text())
        assert doc["passed"] is True
        assert doc["manifest"]["seed"] == 0
        assert "elapsed_s" in doc["corpus"]
