"""Determinism contracts: a seed fully determines every stochastic artifact.

Traces are data (same seed -> byte-identical arrays), replays are exact
functions of (scenario, trace, seed), and the batched simulator must produce
the same departures whether the Lindley scan is jitted or interpreted —
otherwise "reproduce the paper's Fig. 6" would silently depend on the JAX
execution mode of the machine running it.
"""

import jax
import numpy as np

from repro.core.latency import NetworkPath, Tier, Workload
from repro.core.scenario import EdgeSpec, Scenario
from repro.fleet import (
    ScenarioBatch,
    drift_signal,
    fleet_analytic,
    make_trace,
    mmpp_signal,
    replay,
    simulate_fleet,
    step_signal,
)


def _scenario() -> Scenario:
    return Scenario(
        workload=Workload(arrival_rate=2.0, req_bytes=30_000, res_bytes=1_000),
        device=Tier("dev", 0.150),
        edges=(EdgeSpec(Tier("edge", 0.028)),),
        network=NetworkPath(2.5e6),
    )


def _trace(seed: int = 7):
    return make_trace(
        120.0, 1.0,
        bandwidth_Bps=lambda t: step_signal(t, [(0, 2.5e6), (40, 2.5e5), (80, 2.5e6)]),
        arrival_rate=lambda t: drift_signal(t, 2.0, 6.0, jitter=0.1, seed=seed),
        edge_bg_rate=[lambda t: mmpp_signal(t, 0.0, 20.0, seed=seed)],
    )


class TestTraceDeterminism:
    def test_signal_generators_reproduce_from_seed(self):
        t = np.arange(0.0, 200.0, 1.0)
        for gen in (
            lambda s: drift_signal(t, 1.0, 5.0, jitter=0.2, seed=s),
            lambda s: mmpp_signal(t, 2.0, 30.0, seed=s),
        ):
            a, b, c = gen(3), gen(3), gen(4)
            np.testing.assert_array_equal(a, b)
            assert not np.array_equal(a, c), "different seeds must differ"

    def test_step_signal_has_no_randomness(self):
        t = np.arange(0.0, 100.0, 0.5)
        pts = [(0, 20.0), (40, 2.0), (60, 20.0)]
        np.testing.assert_array_equal(step_signal(t, pts), step_signal(t, pts))

    def test_make_trace_reproduces_exactly(self):
        a, b = _trace(7), _trace(7)
        np.testing.assert_array_equal(a.times, b.times)
        np.testing.assert_array_equal(a.bandwidth_Bps, b.bandwidth_Bps)
        np.testing.assert_array_equal(a.arrival_rate, b.arrival_rate)
        np.testing.assert_array_equal(a.edge_bg_rate, b.edge_bg_rate)


class TestReplayDeterminism:
    def test_same_seed_identical_scores_and_decisions(self):
        scn, trace = _scenario(), _trace()
        a = replay(scn, trace, seed=11)
        b = replay(scn, trace, seed=11)
        assert set(a.policies) == set(b.policies)
        for name in a.policies:
            np.testing.assert_array_equal(
                a.policies[name].latencies_s, b.policies[name].latencies_s)
            assert a.policies[name].targets == b.policies[name].targets
        np.testing.assert_array_equal(a.est_bandwidth_Bps, b.est_bandwidth_Bps)
        np.testing.assert_array_equal(a.est_arrival_rate, b.est_arrival_rate)
        assert [d.edge_index for d in a.decisions] == [d.edge_index for d in b.decisions]

    def test_different_seed_different_estimator_path(self):
        # the telemetry sampling is the only stochastic input; a different
        # seed must change the estimated-arrival trajectory
        scn, trace = _scenario(), _trace()
        a = replay(scn, trace, seed=11)
        b = replay(scn, trace, seed=12)
        assert not np.array_equal(a.est_arrival_rate, b.est_arrival_rate)

    def test_policy_scores_identical_with_and_without_jit(self):
        # replay scores via the numpy closed forms, but must also be immune
        # to the global JAX mode of the process running it
        scn, trace = _scenario(), _trace()
        a = replay(scn, trace, seed=5)
        with jax.disable_jit():
            b = replay(scn, trace, seed=5)
        for name in a.policies:
            np.testing.assert_array_equal(
                a.policies[name].latencies_s, b.policies[name].latencies_s)
            assert a.policies[name].targets == b.policies[name].targets


class TestFleetSimDeterminism:
    def test_same_seed_identical_latencies(self):
        batch = ScenarioBatch.from_scenarios(
            _scenario().sweep("workload.arrival_rate", [1.0, 2.0, 3.0]))
        a = simulate_fleet(batch, "edge[0]", n=4_000, seed=9)
        b = simulate_fleet(batch, "edge[0]", n=4_000, seed=9)
        np.testing.assert_array_equal(a.latencies, b.latencies)
        np.testing.assert_array_equal(a.arrivals, b.arrivals)
        c = simulate_fleet(batch, "edge[0]", n=4_000, seed=10)
        assert not np.array_equal(a.latencies, c.latencies)

    def test_jit_and_nojit_agree(self):
        # n stays small: with jit disabled the Lindley scan runs interpreted
        # (~50ms/step), and numerical identity doesn't need scale
        batch = ScenarioBatch.from_scenarios(
            _scenario().sweep("workload.arrival_rate", [1.5, 4.0]))
        jitted = simulate_fleet(batch, "on_device", n=192, seed=3)
        with jax.disable_jit():
            eager = simulate_fleet(batch, "on_device", n=192, seed=3)
        np.testing.assert_allclose(jitted.latencies, eager.latencies,
                                   rtol=1e-12, atol=1e-12)
        np.testing.assert_allclose(jitted.mean, eager.mean, rtol=1e-12)

    def test_analytic_vec_jit_and_nojit_agree(self):
        batch = ScenarioBatch.from_scenarios(
            _scenario().sweep("network.bandwidth_Bps", [2.5e5, 2.5e6, 2.5e7]))
        jitted = fleet_analytic(batch)
        with jax.disable_jit():
            eager = fleet_analytic(batch)
        np.testing.assert_allclose(jitted.t_dev, eager.t_dev, rtol=1e-12)
        np.testing.assert_allclose(jitted.t_edge, eager.t_edge, rtol=1e-12)
        np.testing.assert_array_equal(jitted.best_edge, eager.best_edge)
