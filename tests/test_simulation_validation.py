"""The paper's core experimental claim, reproduced: closed-form predictions
match observed (simulated) latencies within a small MAPE (paper: 2.2% mean,
91.5% within +/-5%, 100% within +/-10%).
"""

import numpy as np
import pytest

from repro.core import queueing as Q
from repro.core import simulation as S
from repro.core.latency import (
    NetworkPath,
    ServiceModel,
    Tier,
    Workload,
    edge_offload_latency,
    on_device_latency,
)
from repro.core.multitenant import TenantStream, multitenant_edge_latency

N = 120_000


def mape(pred, obs):
    return abs(pred - obs) / obs * 100.0


class TestStationLevel:
    @pytest.mark.parametrize("rho", [0.2, 0.5, 0.7])
    def test_md1(self, rho):
        mu = 10.0
        lam = rho * mu
        pred = Q.md1_wait(lam, mu) + 1 / mu
        sim = S.simulate_on_device(lam, S.Deterministic(1 / mu), n=N, seed=1)
        assert mape(pred, sim.mean) < 2.5

    @pytest.mark.parametrize("rho", [0.2, 0.5, 0.7])
    def test_mm1(self, rho):
        mu = 10.0
        lam = rho * mu
        pred = Q.mm1_wait(lam, mu) + 1 / mu
        sim = S.simulate_on_device(lam, S.Exponential(1 / mu), n=N, seed=2)
        assert mape(pred, sim.mean) < 2.5

    def test_mg1_lognormal(self):
        lam, mean, var = 4.0, 0.1, 0.02
        pred = Q.mg1_wait(lam, 1 / mean, var) + mean
        sim = S.simulate_on_device(lam, S.LogNormal(mean, var), n=2 * N, seed=3)
        assert mape(pred, sim.mean) < 3.0

    def test_mdk_aggregation_approximation_quality(self):
        """The paper's M/D/k -> M/D/1 reduction: quantify, don't just trust."""
        lam, mu, k = 6.0, 2.0, 4
        approx = Q.md1_wait_aggregated(lam, mu, k) + 1 / mu
        sim = S.simulate_on_device(lam, S.Deterministic(1 / mu), k=k, n=N, seed=4)
        # at rho=0.75 the fat-server reduction overestimates by ~9% — bounded
        assert mape(approx, sim.mean) < 30.0


class TestEndToEnd:
    def test_offload_pipeline(self):
        wl = Workload(2.0, 200_000, 10_000)
        net = NetworkPath(5e6 / 8)
        edge = Tier("e", 0.02, service_model=ServiceModel.DETERMINISTIC)
        pred = float(edge_offload_latency(wl, edge, net))
        sim = S.simulate_offload(
            wl.arrival_rate, S.Deterministic(0.02), 1,
            bandwidth_Bps=net.bandwidth_Bps, req_bytes=wl.req_bytes,
            res_bytes=wl.res_bytes, n=N, seed=5,
        )
        assert mape(pred, sim.mean) < 3.0

    def test_multitenant_pipeline(self):
        wl = Workload(2.0, 200_000, 10_000)
        net = NetworkPath(5e6 / 8)
        edge = Tier("e", 0.02, service_model=ServiceModel.GENERAL)
        streams = [
            TenantStream(2.0, 0.02, 0.0),
            TenantStream(3.0, 0.05, 0.001),
            TenantStream(1.0, 0.01, 0.0),
        ]
        pred = float(multitenant_edge_latency(wl, edge, net, streams))
        sim = S.simulate_multitenant_offload(
            [(2.0, S.Deterministic(0.02)), (3.0, S.LogNormal(0.05, 0.001)),
             (1.0, S.Deterministic(0.01))],
            1, bandwidth_Bps=net.bandwidth_Bps, req_bytes=wl.req_bytes,
            res_bytes=wl.res_bytes, n_per_stream=60_000, seed=6,
        )
        # departure-process (non-Poisson) approximations at the shared
        # stations cost ~5% here; paper's own bound is +/-10%
        assert mape(pred, sim.stream_mean(0)) < 8.0

    def test_paper_grade_accuracy_suite(self):
        """Aggregate MAPE over a grid of scenarios (paper reports 2.2%)."""
        errors = []
        net = NetworkPath(2e6)
        for lam in (1.0, 3.0):
            for s_edge in (0.01, 0.05):
                wl = Workload(lam, 100_000, 8_000)
                edge = Tier("e", s_edge, service_model=ServiceModel.DETERMINISTIC)
                pred = float(edge_offload_latency(wl, edge, net))
                sim = S.simulate_offload(
                    lam, S.Deterministic(s_edge), 1,
                    bandwidth_Bps=2e6, req_bytes=1e5, res_bytes=8e3,
                    n=80_000, seed=int(lam * 100 + s_edge * 1000),
                )
                errors.append(mape(pred, sim.mean))
        assert np.mean(errors) < 3.0
        assert np.max(errors) < 10.0
