"""End-to-end behaviour tests for the paper's system: serving engine +
offload gateway (Algorithm 1 in the serving stack), predictor, HLO parsing,
sharding rules."""

import numpy as np

import jax
import jax.numpy as jnp
import pytest

from repro.configs import SHAPES, get_config
from repro.core.latency import ServiceModel, Tier, Workload
from repro.core.predictor import LatencyPredictor, workload_features
from repro.models import lm
from repro.perf.hlo import parse_collectives
from repro.serving.engine import Engine, Request, ServeConfig
from repro.serving.gateway import EdgeHandle, OffloadGateway
from repro.serving.workload import PoissonWorkload, WorkloadConfig

KEY = jax.random.PRNGKey(0)


class TestEngine:
    @pytest.fixture(scope="class")
    def engine(self):
        cfg = get_config("starcoder2_3b").reduced(seq_chunk=8)
        params = lm.init_model(cfg, KEY)
        return cfg, Engine(cfg, params, ServeConfig(slots=2, max_seq=64))

    def test_serves_requests_to_completion(self, engine):
        cfg, eng = engine
        wl = PoissonWorkload(WorkloadConfig(arrival_rate=100.0, prompt_len=8,
                                            max_new_tokens=4, vocab=cfg.vocab_size))
        for r in wl.take(5):
            eng.submit(r)
        eng.drain()
        assert len(eng.completed) == 5
        for r in eng.completed:
            assert len(r.tokens_out) == r.max_new_tokens
            assert all(0 <= t < cfg.padded_vocab for t in r.tokens_out)

    def test_greedy_decode_matches_reference(self, engine):
        """The engine's slot-cache path must reproduce a straight greedy
        decode of the same prompt."""
        cfg, _ = engine
        params = lm.init_model(cfg, KEY)
        eng = Engine(cfg, params, ServeConfig(slots=1, max_seq=64))
        prompt = np.arange(1, 9, dtype=np.int32)
        req = Request(rid=0, prompt=prompt, max_new_tokens=4)
        eng.submit(req)
        eng.drain()
        # reference greedy
        seq = jnp.asarray(prompt[None], jnp.int32)
        out = []
        for _ in range(4):
            logits = lm.forward(params, cfg, seq)
            nxt = int(jnp.argmax(logits[0, -1]))
            out.append(nxt)
            seq = jnp.concatenate([seq, jnp.asarray([[nxt]], jnp.int32)], axis=1)
        assert req.tokens_out == out

    def test_service_stats_collected(self, engine):
        cfg, eng = engine
        mean, var = eng.observed_service_stats()
        assert mean > 0


class TestGateway:
    def test_epoch_decisions_follow_bandwidth(self):
        dev = Tier("dev", 0.035, service_model=ServiceModel.DETERMINISTIC)
        wl = Workload(10.0, 25_000, 2_000)
        gw = OffloadGateway(
            dev, [EdgeHandle("edge0", service_mean_s=0.005)], wl, bandwidth_Bps=2.5e6
        )
        for t in np.arange(0.0, 2.0, 0.1):
            gw.observe_arrival(float(t))
        d_fast = gw.decide(now=2.0)
        assert d_fast.strategy == "offload"
        gw.observe_bandwidth(0.25e6)
        gw.observe_bandwidth(0.25e6)
        gw.observe_bandwidth(0.25e6)
        d_slow = gw.decide(now=2.1)
        assert d_slow.strategy == "on_device"
        assert gw.switches >= 1

    def test_deadline_redispatch(self):
        dev = Tier("dev", 0.02)
        gw = OffloadGateway(dev, [], Workload(1.0, 1e4, 1e3), bandwidth_Bps=1e6)
        assert not gw.check_deadline(predicted_s=0.1, elapsed_s=0.2)
        assert gw.check_deadline(predicted_s=0.1, elapsed_s=0.6)
        assert gw.redispatches == 1


class TestPredictor:
    def test_learns_roofline_like_latency(self):
        """Train on synthetic (features -> latency) data from a known law;
        MAPE on held-out points should be paper-grade (<10%)."""
        rng = np.random.default_rng(0)
        n = 512
        flops = 10 ** rng.uniform(9, 13, n)
        pbytes = 10 ** rng.uniform(6, 10, n)
        abytes = 10 ** rng.uniform(6, 9, n)
        batch = rng.integers(1, 64, n)
        seq = rng.integers(64, 4096, n)
        lat = np.maximum(flops / 197e12, pbytes / 819e9) * (1 + 0.05 * rng.normal(size=n))
        lat = np.abs(lat) + 1e-6
        X = np.stack([workload_features(f, p, a, b, s)
                      for f, p, a, b, s in zip(flops, pbytes, abytes, batch, seq)])
        pred = LatencyPredictor(seed=0)
        pred.fit(X[:448], lat[:448], steps=2500, lr=3e-3)
        # Kang-style predictors (paper refs) land in the 10-25% band
        # on held-out configs; the 5% injected noise adds a floor
        assert pred.mape(X[448:], lat[448:]) < 25.0


class TestHloParsing:
    def test_parses_synthetic_hlo(self):
        text = """
  %ar = f32[8,128]{1,0} all-reduce(f32[8,128]{1,0} %add), replica_groups={}
  %ag = bf16[16,256]{1,0} all-gather(bf16[2,256]{1,0} %slice), dimensions={0}
  %rs = f32[2,64]{1,0} reduce-scatter(f32[16,64]{1,0} %x), dimensions={0}
  %a2a = f32[4,32]{1,0} all-to-all(f32[4,32]{1,0} %y), dimensions={0}
  %cp = f32[4]{0} collective-permute(f32[4]{0} %z), source_target_pairs={{0,1}}
"""
        st = parse_collectives(text)
        assert st.counts == {
            "all-reduce": 1, "all-gather": 1, "reduce-scatter": 1,
            "all-to-all": 1, "collective-permute": 1,
        }
        assert st.operand_bytes["all-reduce"] == 8 * 128 * 4
        assert st.output_bytes["all-gather"] == 16 * 256 * 2
        # wire model: 2x operand for AR, output for AG, operand for RS/A2A/CP
        expect = 2 * 8 * 128 * 4 + 16 * 256 * 2 + 16 * 64 * 4 + 4 * 32 * 4 + 4 * 4
        assert st.wire_bytes == pytest.approx(expect)

    def test_async_pairs_counted_once(self):
        text = """
  %s = f32[8]{0} all-gather-start(f32[2]{0} %x), dimensions={0}
  %d = f32[8]{0} all-gather-done(f32[8]{0} %s)
"""
        st = parse_collectives(text)
        assert st.counts["all-gather"] == 1


class TestShardingRules:
    def test_rules_for_cell_divisibility(self):
        """Pure-logic checks of the cell rules (no multi-device mesh on CPU):
        verify via the rules dict of a fake mesh-like namespace."""
        from repro.sharding.partition import ShardingRules

        # single CPU device mesh: every divisibility gate must fall back safely
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        from repro.configs.base import SHAPES
        from repro.sharding.partition import rules_for_cell

        cfg = get_config("starcoder2_3b")
        r = rules_for_cell(cfg, SHAPES["train_4k"], mesh)
        assert r.rules["batch"] == ("data",)
        r2 = rules_for_cell(cfg, SHAPES["long_500k"], mesh)
        assert r2.rules["cache_seq"] is not None or r2.rules["batch"] is None

    def test_padded_vocab_shards(self):
        for arch in ("internvl2_1b", "seamless_m4t_large_v2"):
            cfg = get_config(arch)
            assert cfg.padded_vocab % 256 == 0
            assert cfg.padded_vocab >= cfg.vocab_size

    def test_opt_axes_no_duplicate_data(self):
        from repro.models.params import is_axes_leaf
        from repro.training import optimizer as opt

        cfg = get_config("dbrx_132b")
        p_abs = lm.abstract_model(cfg)
        p_axes = lm.model_param_axes(cfg)
        oaxes = opt.opt_axes(
            p_axes, p_abs, zero_size=16,
            replicated_names=frozenset({"embed"}),
            data_resident_names=frozenset({"expert_ff", "zero"}),
        )
        leaves = jax.tree.leaves(oaxes["master"], is_leaf=is_axes_leaf)
        for axes in leaves:
            data_like = [a for a in axes if a in ("zero", "expert_ff")]
            assert len(data_like) <= 1, axes
