"""Pallas kernel validation: interpret-mode execution vs pure-jnp oracles,
swept over shapes/dtypes (+ hypothesis for the pointwise kernels; a seeded
local fallback sweep keeps coverage when hypothesis is not installed)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _prop import given, settings, st

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_reference
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_reference
from repro.kernels.rmsnorm.ops import rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_reference
from repro.kernels.ssm_scan.ops import ssm_scan
from repro.kernels.ssm_scan.ref import ssm_scan_reference

KEY = jax.random.PRNGKey(7)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)


class TestFlashAttention:
    @pytest.mark.parametrize(
        "B,S,H,K,hd,causal,window,cap",
        [
            (2, 256, 4, 2, 64, True, 0, 0.0),  # GQA causal
            (1, 256, 4, 4, 128, True, 128, 0.0),  # MHA sliding window
            (2, 128, 8, 2, 64, True, 0, 50.0),  # softcap (gemma2)
            (1, 256, 2, 1, 64, False, 0, 0.0),  # bidirectional MQA
            (1, 192, 6, 3, 32, True, 64, 30.0),  # window + softcap, odd dims
        ],
    )
    def test_against_reference(self, B, S, H, K, hd, causal, window, cap):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
        k = jax.random.normal(ks[1], (B, S, K, hd), jnp.float32)
        v = jax.random.normal(ks[2], (B, S, K, hd), jnp.float32)
        out_k = flash_attention(
            q, k, v, causal=causal, window=window, softcap=cap,
            impl="interpret", blk_q=64, blk_k=64,
        )
        out_r = flash_attention(q, k, v, causal=causal, window=window, softcap=cap, impl="xla")
        np.testing.assert_allclose(out_k, out_r, rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (1, 128, 4, 64)).astype(dtype)
        k = jax.random.normal(ks[1], (1, 128, 2, 64)).astype(dtype)
        v = jax.random.normal(ks[2], (1, 128, 2, 64)).astype(dtype)
        out_k = flash_attention(q, k, v, impl="interpret", blk_q=64, blk_k=64)
        out_r = flash_attention(q, k, v, impl="xla")
        assert out_k.dtype == dtype
        np.testing.assert_allclose(
            out_k.astype(jnp.float32), out_r.astype(jnp.float32), **tol(dtype)
        )

    def test_block_shape_invariance(self):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (1, 256, 4, 64), jnp.float32)
        k = jax.random.normal(ks[1], (1, 256, 2, 64), jnp.float32)
        v = jax.random.normal(ks[2], (1, 256, 2, 64), jnp.float32)
        outs = [
            flash_attention(q, k, v, impl="interpret", blk_q=bq, blk_k=bk)
            for bq, bk in [(32, 32), (64, 128), (128, 64), (256, 256)]
        ]
        for o in outs[1:]:
            np.testing.assert_allclose(o, outs[0], rtol=1e-5, atol=1e-5)


class TestDecodeAttention:
    @pytest.mark.parametrize(
        "B,S,H,K,hd,pos,cap",
        [
            (2, 512, 8, 2, 64, 511, 0.0),
            (1, 1024, 4, 4, 128, 700, 0.0),  # partially filled cache
            (2, 512, 6, 2, 64, 40, 50.0),  # softcap, short valid region
            (1, 256, 16, 8, 32, 255, 0.0),
        ],
    )
    def test_against_reference(self, B, S, H, K, hd, pos, cap):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (B, 1, H, hd), jnp.float32)
        kc = jax.random.normal(ks[1], (B, S, K, hd), jnp.float32)
        vc = jax.random.normal(ks[2], (B, S, K, hd), jnp.float32)
        o1 = decode_attention(q, kc, vc, jnp.int32(pos), softcap=cap, impl="interpret", blk_k=128)
        o2 = decode_attention(q, kc, vc, jnp.int32(pos), softcap=cap, impl="xla")
        np.testing.assert_allclose(o1, o2, rtol=1e-5, atol=1e-5)

    def test_garbage_past_pos_is_ignored(self):
        """Cache slots beyond `pos` must not affect the output."""
        ks = jax.random.split(KEY, 3)
        B, S, H, K, hd, pos = 1, 256, 4, 2, 64, 100
        q = jax.random.normal(ks[0], (B, 1, H, hd), jnp.float32)
        kc = jax.random.normal(ks[1], (B, S, K, hd), jnp.float32)
        vc = jax.random.normal(ks[2], (B, S, K, hd), jnp.float32)
        o1 = decode_attention(q, kc, vc, jnp.int32(pos), impl="interpret", blk_k=64)
        kc2 = kc.at[:, pos + 1 :].set(1e6)
        vc2 = vc.at[:, pos + 1 :].set(-1e6)
        o2 = decode_attention(q, kc2, vc2, jnp.int32(pos), impl="interpret", blk_k=64)
        np.testing.assert_allclose(o1, o2, rtol=1e-6, atol=1e-6)


class TestSsmScan:
    @pytest.mark.parametrize("B,T,D,N,bt,bd", [(2, 64, 128, 8, 16, 64), (1, 128, 256, 16, 32, 128)])
    def test_against_reference(self, B, T, D, N, bt, bd):
        ks = jax.random.split(KEY, 5)
        dt = jax.nn.softplus(jax.random.normal(ks[0], (B, T, D))) * 0.1
        Bc = jax.random.normal(ks[1], (B, T, N))
        Cc = jax.random.normal(ks[2], (B, T, N))
        u = jax.random.normal(ks[3], (B, T, D))
        A = -jnp.exp(jax.random.normal(ks[4], (D, N)) * 0.5)
        y1 = ssm_scan(dt, Bc, Cc, u, A, impl="interpret", blk_t=bt, blk_d=bd)
        y2, _ = ssm_scan_reference(dt, Bc, Cc, u, A)
        np.testing.assert_allclose(y1, y2, rtol=1e-5, atol=1e-5)

    def test_state_continuity_across_time_blocks(self):
        """The VMEM-resident state must carry across t-block grid steps:
        compare one big block vs many small blocks."""
        ks = jax.random.split(KEY, 5)
        B, T, D, N = 1, 64, 64, 4
        dt = jax.nn.softplus(jax.random.normal(ks[0], (B, T, D))) * 0.2
        Bc = jax.random.normal(ks[1], (B, T, N))
        Cc = jax.random.normal(ks[2], (B, T, N))
        u = jax.random.normal(ks[3], (B, T, D))
        A = -jnp.exp(jax.random.normal(ks[4], (D, N)) * 0.5)
        y_one = ssm_scan(dt, Bc, Cc, u, A, impl="interpret", blk_t=64, blk_d=64)
        y_many = ssm_scan(dt, Bc, Cc, u, A, impl="interpret", blk_t=8, blk_d=32)
        np.testing.assert_allclose(y_one, y_many, rtol=1e-5, atol=1e-5)


class TestDecisionScan:
    @staticmethod
    def _costs(T, N, E1, seed=4):
        rng = np.random.default_rng(seed)
        c = jnp.asarray(rng.exponential(0.05, (T, N, E1)), jnp.float32)
        # saturated columns and exact ties must survive the kernel path
        c = c.at[3, :, E1 - 1].set(jnp.inf)
        c = c.at[5, 1 % N, :].set(0.07)
        return c

    @pytest.mark.parametrize("stagger,hysteresis", [(1, 0.0), (3, 0.0),
                                                    (3, 0.15), (2, 0.4)])
    def test_against_reference(self, stagger, hysteresis):
        from repro.kernels.decision_scan.ops import decision_scan

        T, N, E1 = 37, 13, 4
        costs = self._costs(T, N, E1)
        cohort = jnp.asarray(np.arange(N) % stagger, jnp.int32)
        ref = decision_scan(costs, cohort, hysteresis=hysteresis,
                            stagger=stagger, impl="xla")
        out = decision_scan(costs, cohort, hysteresis=hysteresis,
                            stagger=stagger, impl="interpret",
                            blk_n=8, blk_t=16)
        assert jnp.array_equal(ref, out)

    def test_choice_carry_across_time_blocks(self):
        """The VMEM-resident previous choice must persist across t-block grid
        steps — hysteresis makes any drop in the carry visible."""
        from repro.kernels.decision_scan.ops import decision_scan

        costs = self._costs(64, 8, 3, seed=9)
        cohort = jnp.asarray(np.arange(8) % 4, jnp.int32)
        one = decision_scan(costs, cohort, hysteresis=0.3, stagger=4,
                            impl="interpret", blk_n=8, blk_t=64)
        many = decision_scan(costs, cohort, hysteresis=0.3, stagger=4,
                             impl="interpret", blk_n=4, blk_t=8)
        assert jnp.array_equal(one, many)

    def test_reference_matches_cluster_decide_rule(self):
        """The oracle is pinned to the production decision rule: iterate
        ``repro.fleet.cluster._decide_vec`` by hand over the same tables."""
        import jax.experimental

        from repro.fleet.cluster import _decide_vec
        from repro.kernels.decision_scan.ref import decision_scan_reference

        T, N = 25, 6
        with jax.experimental.enable_x64():
            costs = jnp.asarray(np.asarray(self._costs(T, N, 4)), jnp.float64)
            h, prev, manual = 0.15, jnp.full(N, -1, jnp.int32), []
            for t in range(T):
                prev = _decide_vec(costs[t, :, 0], costs[t, :, 1:], prev,
                                   jnp.float64(h), jnp.bool_(t >= 1))
                manual.append(np.asarray(prev))
            ref = decision_scan_reference(costs, jnp.zeros(N, jnp.int32),
                                          hysteresis=h, stagger=1)
        assert np.array_equal(np.stack(manual), np.asarray(ref))


class TestRmsNorm:
    @given(
        st.integers(1, 5),
        st.integers(1, 97),
        st.sampled_from([64, 128, 256]),
        st.sampled_from(["float32", "bfloat16"]),
    )
    @settings(max_examples=25, deadline=None)
    def test_hypothesis_sweep(self, b, rows, d, dtype):
        dt = jnp.dtype(dtype)
        x = (jax.random.normal(KEY, (b, rows, d)) * 3).astype(dt)
        sc = (jax.random.normal(jax.random.PRNGKey(9), (d,)) * 0.2).astype(dt)
        o1 = rmsnorm(x, sc, impl="interpret", blk_rows=32)
        o2 = rmsnorm_reference(x, sc)
        np.testing.assert_allclose(
            o1.astype(jnp.float32), o2.astype(jnp.float32), **tol(dt)
        )

    def test_matches_model_layer(self):
        from repro.models.layers import rms_norm

        x = jax.random.normal(KEY, (4, 16, 128), jnp.float32)
        sc = jax.random.normal(jax.random.PRNGKey(2), (128,)) * 0.1
        np.testing.assert_allclose(
            rmsnorm(x, sc, impl="interpret"), rms_norm(x, sc, 1e-6), rtol=1e-5, atol=1e-5
        )


class TestKernelsInsideModel:
    def test_flash_attention_agrees_with_model_attention(self):
        """The kernel path must agree with models.attention's chunked XLA path."""
        from repro.configs import get_config
        from repro.models import attention as A
        from repro.models.params import init_params

        cfg = get_config("gemma2_9b").reduced(
            seq_chunk=16, num_heads=4, num_kv_heads=2, head_dim=32, attn_softcap=50.0
        )
        p = init_params(A.attn_template(cfg), KEY, jnp.float32)
        B, S = 2, 64
        x = jax.random.normal(KEY, (B, S, cfg.d_model), jnp.float32) * 0.3
        y_model = A.attn_forward(p, x, cfg, causal=True, local=True)
        # reproduce with the kernel: project, rope, call flash, project out
        from repro.models.layers import rope_apply

        K, hd, H = cfg.num_kv_heads, cfg.resolved_head_dim, cfg.num_heads
        q = (x @ p["wq"]).reshape(B, S, H, hd)
        k = (x @ p["wk"]).reshape(B, S, K, hd)
        v = (x @ p["wv"]).reshape(B, S, K, hd)
        pos = jnp.arange(S, dtype=jnp.int32)
        q = rope_apply(q, pos, cfg.rope_theta)
        k = rope_apply(k, pos, cfg.rope_theta)
        o = flash_attention(
            q, k, v, causal=True, window=cfg.window_size, softcap=cfg.attn_softcap,
            impl="interpret", blk_q=32, blk_k=32,
        )
        y_kernel = o.reshape(B, S, H * hd) @ p["wo"]
        np.testing.assert_allclose(y_kernel, y_model, rtol=2e-4, atol=2e-4)
