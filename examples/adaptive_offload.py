"""The paper end-to-end: serve a real (reduced) LM on a device-tier engine
while an adaptive gateway decides, per epoch, whether requests should run
locally or be offloaded to an edge pod — under the paper's Fig. 6 bandwidth
schedule and a Fig. 7-style edge-load surge.

The device tier is the actual JAX serving engine (repro.serving.engine); the
edge tiers are modelled by their profiled service times (exactly the paper's
two-level methodology). The whole deployment is declared once as a
`Scenario`; the gateway is built straight from it. Watch it switch strategies
as conditions change, driven purely by the closed-form predictions.

Run: PYTHONPATH=src python examples/adaptive_offload.py
"""

import jax
import numpy as np

from repro.configs import get_config
from repro.core import EdgeSpec, NetworkPath, Scenario, ServiceModel, Tier, Workload
from repro.models import lm
from repro.obs import AuditLog, MetricsRegistry, format_decision
from repro.serving.engine import Engine, ServeConfig
from repro.serving.gateway import OffloadGateway
from repro.serving.workload import PoissonWorkload, WorkloadConfig

# --- device tier: a real engine over a reduced LM ---------------------------
cfg = get_config("starcoder2_3b").reduced(seq_chunk=8)
params = lm.init_model(cfg, jax.random.PRNGKey(0))
engine = Engine(cfg, params, ServeConfig(slots=2, max_seq=64))

# profile the device by serving a short burst (paper §4.2). warmup() first:
# JIT compilation would otherwise dominate a 6-request burst and inflate the
# profiled service time by orders of magnitude.
engine.warmup([12])
wl_gen = PoissonWorkload(WorkloadConfig(arrival_rate=50.0, prompt_len=12,
                                        max_new_tokens=4, vocab=cfg.vocab_size))
for r in wl_gen.take(6):
    engine.submit(r)
engine.drain()
s_dev, var_dev = engine.observed_service_stats()
print(f"profiled device service: {s_dev*1e3:.1f} ms/tick (var {var_dev:.2e})")

# --- the deployment, declared once ------------------------------------------
# The request/response payloads are placed relative to the profiled service
# so the Fig. 6 bandwidth crossover lands near 5 Mbps regardless of how fast
# this machine runs the reduced engine: offloading must win at 10/20 Mbps
# and lose at 2 Mbps. (The edges are 8x-faster 4-wide pods, so the decision
# is dominated by the transfer time vs the on-device service.)
req_bytes = max(1, int(0.8 * s_dev * 0.625e6))  # crossover ~5 Mbps
res_bytes = max(1, req_bytes // 5)
#
# allow_unstable: the Fig. 6 schedule deliberately drives the 2 Mbps phase
# (and possibly the engine itself) past saturation — the models report inf
# there and Algorithm 1 falls back to the stable strategy.
scn = Scenario(
    workload=Workload(arrival_rate=10.0, req_bytes=req_bytes, res_bytes=res_bytes),
    device=Tier("device-engine", s_dev, service_model=ServiceModel.EXPONENTIAL),
    edges=(
        EdgeSpec(Tier("edge-pod-A", s_dev / 8, parallelism_k=4.0,
                      service_model=ServiceModel.EXPONENTIAL)),
        EdgeSpec(Tier("edge-pod-B", s_dev / 8, parallelism_k=4.0,
                      service_model=ServiceModel.EXPONENTIAL)),
    ),
    network=NetworkPath(bandwidth_Bps=2.5e6),
    allow_unstable=True,
    name="lm-serving",
)
# observability: every decision below is audited (full closed-form term
# decomposition) and counted; the printed lines are rendered FROM the audit
# rows, so console output and the machine-readable trail cannot disagree
auditor = AuditLog()
metrics = MetricsRegistry()
gw = OffloadGateway.from_scenario(scn, epoch_s=1.0, auditor=auditor,
                                  metrics=metrics)

print("\n--- Fig. 6 replay: bandwidth 20 -> 10 -> 2 -> 20 Mbps ---")
for t, mbps in [(0, 20), (20, 10), (40, 2), (60, 20)]:
    for _ in range(3):
        gw.observe_bandwidth(mbps * 1e6 / 8)
    for dt in np.arange(0.0, 1.0, 0.1):
        gw.observe_arrival(t + dt)
    gw.decide(now=t + 1.0)
    print(format_decision(auditor.rows[-1]))

print("\n--- Fig. 7 replay: edge load surge ---")
# background load expressed as a fraction of each pod's M/M/4 capacity (the
# pods' absolute capacity scales with the profiled service time): a mild
# imbalance picks pod A, a surge on A shifts traffic to pod B, and when both
# pods saturate the gateway retreats on-device — the paper's Fig. 7 arc.
edge_cap = 4.0 / gw.edges[0].service_mean_s  # per-pod capacity, rps
for t, (f_a, f_b) in [(80, (0.10, 0.60)), (160, (0.95, 0.60)), (240, (0.98, 0.97))]:
    lam_a, lam_b = int(f_a * edge_cap), int(f_b * edge_cap)
    gw.edges[0].background_rate = lam_a
    gw.edges[0].background_service_s = gw.edges[0].service_mean_s
    gw.edges[1].background_rate = lam_b
    gw.edges[1].background_service_s = gw.edges[1].service_mean_s
    for _ in range(3):
        gw.observe_bandwidth(20e6 / 8)
    for dt in np.arange(0.0, 1.0, 0.1):
        gw.observe_arrival(t + dt)
    gw.decide(now=t + 1.0)
    print(f"edge loads ({lam_a},{lam_b}) rps | {format_decision(auditor.rows[-1])}")

auditor.verify()  # audited terms must re-sum to the decision totals
print(f"\nstrategy switches: {gw.switches}; redispatches: {gw.redispatches}")
for line in metrics.render().splitlines():
    print(f"[metrics] {line}")
