"""End-to-end training driver: train a ~100M-parameter starcoder2-family
model for a few hundred steps on the synthetic pipeline, with checkpointing
and (optionally) a mid-run restart to demonstrate fault-tolerant resume.

Run:   PYTHONPATH=src python examples/train_lm.py [--steps 300] [--resume]
Small: PYTHONPATH=src python examples/train_lm.py --tiny --steps 40
"""

import argparse
import math

from repro.configs import get_config
from repro.training.train_loop import TrainConfig, Trainer


def build_cfg(tiny: bool):
    base = get_config("starcoder2_3b")
    if tiny:
        return base.reduced()
    # ~100M-parameter member of the starcoder2 family
    return base.reduced(
        d_model=512, num_heads=8, num_kv_heads=4, head_dim=64,
        d_ff=2048, num_superblocks=8, vocab_size=32_000,
        seq_chunk=128, name="starcoder2_100m",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = build_cfg(args.tiny)
    from repro.models.lm import num_params

    print(f"model: {cfg.name}  params={num_params(cfg)/1e6:.1f}M")
    tc = TrainConfig(
        steps=args.steps,
        batch=8 if not args.tiny else 4,
        seq_len=256 if not args.tiny else 64,
        checkpoint_every=100,
        checkpoint_dir=args.ckpt_dir,
        log_every=10,
        lr=3e-4,
        warmup=30,
    )
    trainer = Trainer(cfg, tc)
    if args.resume:
        params, state, step = trainer.resume()
        print(f"resumed from step {step}")
        trainer.run(params, state, start_step=step)
    else:
        trainer.run()

    first, last = trainer.metrics_log[0], trainer.metrics_log[-1]
    uniform = math.log(cfg.vocab_size)
    print(f"\nloss: {first['loss']:.3f} -> {last['loss']:.3f} "
          f"(uniform entropy floor {uniform:.2f})")
    print(f"final step time: {last['step_time_s']*1e3:.0f} ms; "
          f"straggler events: {len(trainer.straggler_events)}")
    assert last["loss"] < first["loss"], "training must reduce loss"


if __name__ == "__main__":
    main()
