"""Fleet quickstart: sweep a million-point scenario grid, then replay a
dynamic trace against the adaptive manager — the two things `repro.fleet`
adds on top of the scalar `Scenario` API.

Run: PYTHONPATH=src python examples/fleet_sweep.py
"""

import time

import numpy as np

from repro.core import EdgeSpec, NetworkPath, Scenario, Tier, Workload
from repro.fleet import (
    ScenarioBatch,
    fleet_analytic,
    fleet_crossover,
    make_trace,
    replay,
    step_signal,
)

# one validated spec, as in examples/quickstart.py — the fleet layer scales
# it out rather than re-describing it
scn = Scenario(
    workload=Workload(arrival_rate=2.0, req_bytes=30_000, res_bytes=1_000,
                      name="inceptionv4"),
    device=Tier("tx2", 0.150),
    edges=(EdgeSpec(Tier("a2", 0.028)),),
    network=NetworkPath(5e6 / 8),
    allow_unstable=True,  # sweeps cross saturation on purpose
)

# --- 1M-scenario sweep: bandwidth x arrival rate, one jitted call -----------
batch = ScenarioBatch.from_sweep(scn, {
    "network.bandwidth_Bps": np.geomspace(1e5, 1e8, 1024),
    "workload.arrival_rate": np.linspace(0.5, 30.0, 1024),
})
pred = fleet_analytic(batch)  # (compiles on first call)
t0 = time.perf_counter()
pred = fleet_analytic(batch)
dt = time.perf_counter() - t0
wins = np.array([n == "on_device" for n in pred.strategy_names()])
print(f"swept {batch.size:,} scenarios in {dt*1e3:.1f} ms "
      f"({batch.size/dt/1e6:.1f}M scenarios/s)")
print(f"on-device wins {wins.mean():.1%} of the grid; "
      f"offloading wins {1-wins.mean():.1%}")

# --- batched crossovers: B* per arrival rate, bisection over the fleet ------
cx_batch = ScenarioBatch.from_sweep(scn, {
    "workload.arrival_rate": np.linspace(0.5, 6.0, 8),
})
cx = fleet_crossover(cx_batch, "bandwidth")
for lam, b_star in zip(cx_batch.lam, cx.value):
    label = f"{b_star*8/1e6:6.2f} Mbps" if np.isfinite(b_star) else "   (none)"
    print(f"  lambda={lam:4.1f} rps -> offloading pays above {label}")

# --- trace replay: the paper's §5 experiment shape ---------------------------
trace = make_trace(
    120.0, 1.0,
    bandwidth_Bps=lambda t: step_signal(
        t, [(0, 20e6 / 8), (40, 0.8e6 / 8), (80, 20e6 / 8)]),
    arrival_rate=2.0,
    edge_bg_rate=[lambda t: step_signal(t, [(0, 0.0), (20, 33.0), (35, 0.0)])],
)
res = replay(scn.replaced("network.bandwidth_Bps", 20e6 / 8), trace, seed=1)
print("\nbandwidth-step + tenant-churn replay (120 epochs):")
for name, p in sorted(res.policies.items(), key=lambda kv: kv[1].mean_latency_s):
    print(f"  {name:10s} mean {p.mean_latency_s*1e3:7.2f} ms  "
          f"switches={p.switches}  saturated_epochs={p.saturated_epochs}")
print("adaptive beats both statics:", res.adaptive_wins)
