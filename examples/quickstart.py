"""Quickstart: the paper's models in five minutes.

1. Closed-form latency prediction for on-device vs edge offloading.
2. Validation against the discrete-event simulator.
3. A crossover query ("at what bandwidth should I offload?").
4. One adaptive-manager decision (Algorithm 1).

Run: PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import simulation as S
from repro.core.crossover import bandwidth_crossover
from repro.core.latency import (
    NetworkPath,
    ServiceModel,
    Tier,
    Workload,
    edge_offload_latency,
    on_device_latency,
)
from repro.core.manager import AdaptiveOffloadManager, EdgeServerState
from repro.core.telemetry import TelemetrySnapshot

# --- 1. describe the system ------------------------------------------------
# A camera app: 10 inference requests/s, 25 KB frames in, 2 KB results back.
wl = Workload(arrival_rate=10.0, req_bytes=25_000, res_bytes=2_000)
device = Tier("jetson", service_time_s=0.035, service_model=ServiceModel.DETERMINISTIC)
edge = Tier("edge-gpu", service_time_s=0.005, parallelism_k=2,
            service_model=ServiceModel.DETERMINISTIC)
net = NetworkPath(bandwidth_Bps=20e6 / 8)  # 20 Mbps

t_dev = float(on_device_latency(wl, device))
t_edge = edge_offload_latency(wl, edge, net, breakdown=True)
print(f"on-device : {t_dev*1e3:7.2f} ms")
print(f"offloading: {float(t_edge.total)*1e3:7.2f} ms  breakdown:")
for k, v in t_edge.terms.items():
    print(f"   {k:12s} {float(np.asarray(v))*1e3:7.2f} ms")

# --- 2. validate against simulation -----------------------------------------
sim = S.simulate_offload(
    wl.arrival_rate, S.Deterministic(edge.service_time_s), int(edge.parallelism_k),
    bandwidth_Bps=net.bandwidth_Bps, req_bytes=wl.req_bytes, res_bytes=wl.res_bytes,
    n=100_000, seed=0,
)
err = abs(float(t_edge.total) - sim.mean) / sim.mean * 100
print(f"\nsimulated : {sim.mean*1e3:7.2f} ms   (closed-form error {err:.2f}% — paper reports 2.2% MAPE)")

# --- 3. quantitative crossover ----------------------------------------------
c = bandwidth_crossover(wl, device, edge)
print(f"\noffloading pays above {c.value*8/1e6:.2f} Mbps")

# --- 4. one Algorithm-1 decision ---------------------------------------------
mgr = AdaptiveOffloadManager(device)
snap = TelemetrySnapshot(time_s=0.0, lam_dev=wl.arrival_rate, bandwidth_Bps=net.bandwidth_Bps)
est = EdgeServerState("edge0", 1.0 / edge.service_time_s, wl.arrival_rate,
                      edge.service_time_s, parallelism_k=2.0)
d = mgr.decide(wl, snap, [est])
print(f"manager decision: {d.target_name} (predicted {d.predicted_latency_s*1e3:.2f} ms)")
