"""Quickstart: the paper's models in five minutes, via one Scenario spec.

1. Describe the operating point once as a validated `Scenario`.
2. Closed-form latency prediction for every strategy (`analytic`).
3. Validation against the discrete-event simulator (`simulate`).
4. A crossover query ("at what bandwidth should I offload?").
5. One adaptive-manager decision (Algorithm 1) from the same spec.

Run: PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import (
    EdgeSpec,
    NetworkPath,
    Scenario,
    ServiceModel,
    Tier,
    Workload,
    analytic,
    crossovers,
    simulate,
)

# --- 1. describe the system ONCE ---------------------------------------------
# A camera app: 10 inference requests/s, 25 KB frames in, 2 KB results back,
# a Jetson-class device, one 2-way edge GPU, a 20 Mbps link.
scn = Scenario(
    workload=Workload(arrival_rate=10.0, req_bytes=25_000, res_bytes=2_000),
    device=Tier("jetson", service_time_s=0.035, service_model=ServiceModel.DETERMINISTIC),
    edges=(
        EdgeSpec(Tier("edge-gpu", service_time_s=0.005, parallelism_k=2,
                      service_model=ServiceModel.DETERMINISTIC)),
    ),
    network=NetworkPath(bandwidth_Bps=20e6 / 8),  # 20 Mbps
    name="camera-app",
)

# --- 2. closed-form prediction per strategy -----------------------------------
pred = analytic(scn)
print(f"on-device : {float(pred['on_device'].total)*1e3:7.2f} ms")
print(f"offloading: {float(pred['edge[0]'].total)*1e3:7.2f} ms  breakdown:")
for k, v in pred["edge[0]"].terms.items():
    print(f"   {k:12s} {float(np.asarray(v))*1e3:7.2f} ms")
print(f"analytic argmin: {pred.best_strategy}")

# --- 3. validate against simulation (same spec, no re-assembly) ----------------
sim = simulate(scn, "edge[0]", n=100_000, seed=0)
err = abs(float(pred["edge[0]"].total) - sim.mean) / sim.mean * 100
print(f"\nsimulated : {sim.mean*1e3:7.2f} ms   (closed-form error {err:.2f}% — paper reports 2.2% MAPE)")

# --- 4. quantitative crossover ------------------------------------------------
c = crossovers(scn, "bandwidth")
print(f"\noffloading pays above {c.value*8/1e6:.2f} Mbps")

# --- 5. one Algorithm-1 decision, built from the same spec ---------------------
mgr = scn.manager()
d = mgr.decide(scn.workload, scn.snapshot(), scn.edge_states())
print(f"manager decision: {d.target_name} (predicted {d.predicted_latency_s*1e3:.2f} ms)")

# the spec round-trips through plain JSON — sweepable, storable, shareable
assert Scenario.from_dict(scn.to_dict()) == scn
