"""Docs gate: every link, file ref, and worked example in the docs is live.

Three checks over ``README.md`` + ``docs/*.md``:

  1. **Links** — every relative markdown link target exists (resolved
     against the containing file's directory, falling back to the repo
     root), and every ``#anchor`` resolves to a heading slug in the target
     file (GitHub slug rules).
  2. **File refs** — every backtick or bare reference to a repo path
     (``src/``, ``tests/``, ``docs/``, ``benchmarks/``, ``tools/``,
     ``examples/``, ``.github/``) exists, and every ``path.py:123`` line
     anchor is within the file's current length — so the equation-to-code
     map in docs/MODELS.md goes stale loudly, not silently.
  3. **Worked examples** (skipped with ``--no-exec``) — the README's
     ``python`` fences are executed top to bottom in one shared namespace
     (they build on each other the way a reader runs them), the "Sizing
     the fleet" console example is run through the real provision CLI, and
     the "Reproduce every number" example runs one registry experiment
     through ``repro.launch.reproduce`` including the resume-skip rerun.
     A fence preceded by ``<!-- check_docs: skip -->`` is not run.

Usage:
  PYTHONPATH=src python -m tools.check_docs            # full gate (CI)
  PYTHONPATH=src python -m tools.check_docs --no-exec  # links/refs only
"""

from __future__ import annotations

import argparse
import re
import sys
import time
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
DOC_FILES = [REPO / "README.md", *sorted((REPO / "docs").glob("*.md"))]
REF_PREFIXES = ("src/", "tests/", "docs/", "benchmarks/", "tools/",
                "examples/", ".github/")

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
# path.py:123 line anchors (optionally backticked)
LINE_REF_RE = re.compile(
    r"`?((?:src|tests|benchmarks|tools|examples)/[\w./-]+\.py):(\d+)`?")
# backticked repo paths: `src/.../x.py`, `docs/CLI.md`, `benchmarks/baselines/`
TICK_REF_RE = re.compile(
    r"`((?:src|tests|docs|benchmarks|tools|examples|\.github)/[\w./-]+)`")
FENCE_RE = re.compile(r"(<!--\s*check_docs:\s*skip\s*-->\s*\n)?```(\w+)\n(.*?)```",
                      re.S)
SKIP_EXTERNAL = ("http://", "https://", "mailto:")


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: lowercase, strip punctuation, spaces->hyphens."""
    h = re.sub(r"`([^`]*)`", r"\1", heading.strip())
    h = h.lower()
    h = re.sub(r"[^\w\- ]", "", h, flags=re.UNICODE)
    return h.replace(" ", "-")


def heading_slugs(text: str) -> set[str]:
    slugs: set[str] = set()
    for m in re.finditer(r"^#{1,6}\s+(.+)$", text, re.M):
        slugs.add(github_slug(m.group(1)))
    return slugs


def check_links(doc: Path, text: str, errors: list[str]) -> None:
    for m in LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(SKIP_EXTERNAL):
            continue
        path_part, _, anchor = target.partition("#")
        if path_part:
            cand = (doc.parent / path_part, REPO / path_part)
            resolved = next((c for c in cand if c.exists()), None)
            if resolved is None:
                errors.append(f"{doc.name}: broken link target {target!r}")
                continue
        else:
            resolved = doc
        if anchor and resolved.suffix == ".md":
            if anchor not in heading_slugs(resolved.read_text()):
                errors.append(f"{doc.name}: anchor #{anchor} not found "
                              f"in {resolved.name}")


def check_file_refs(doc: Path, text: str, errors: list[str]) -> None:
    for m in LINE_REF_RE.finditer(text):
        rel, line = m.group(1), int(m.group(2))
        p = REPO / rel
        if not p.exists():
            errors.append(f"{doc.name}: line ref to missing file {rel}")
        elif line > len(p.read_text().splitlines()):
            errors.append(f"{doc.name}: stale line ref {rel}:{line} "
                          f"(file has {len(p.read_text().splitlines())} lines)")
    for m in TICK_REF_RE.finditer(text):
        rel = m.group(1)
        if not rel.startswith(REF_PREFIXES):
            continue
        # strip a :line suffix already validated above
        rel = rel.split(":")[0]
        if not (REPO / rel).exists():
            errors.append(f"{doc.name}: reference to missing path {rel}")


def run_readme_examples(errors: list[str]) -> None:
    """Execute the README's python fences in one shared namespace."""
    text = (REPO / "README.md").read_text()
    ns: dict = {}
    for m in FENCE_RE.finditer(text):
        skip, lang, body = m.group(1), m.group(2), m.group(3)
        if lang != "python" or skip:
            continue
        line = text[: m.start()].count("\n") + 1
        t0 = time.time()
        try:
            exec(compile(body, f"README.md:block@{line}", "exec"), ns)
        except Exception as err:  # noqa: BLE001 - report, don't crash the gate
            errors.append(f"README.md python block at line {line} failed: "
                          f"{type(err).__name__}: {err}")
            return  # later blocks may depend on this one's names
        print(f"  README.md python block @ line {line}: "
              f"OK ({time.time() - t0:.1f}s)")


def run_provision_example(errors: list[str]) -> None:
    """The 'Sizing the fleet' console example, run for real."""
    from repro.launch.provision import main as provision_main

    t0 = time.time()
    rc = provision_main(["--clients", "48", "--slo-ms", "120",
                         "--check-minimal"])
    if rc != 0:
        errors.append(f"'Sizing the fleet' worked example exited {rc}")
    else:
        print(f"  provision worked example: OK ({time.time() - t0:.1f}s)")


def run_reproduce_example(errors: list[str]) -> None:
    """The 'Reproduce every number' console example, run for real: one
    registry experiment through the manifest runner, then the resume-skip
    contract (an immediate rerun must skip the completed run)."""
    import tempfile

    from repro.launch.reproduce import main as reproduce_main

    t0 = time.time()
    with tempfile.TemporaryDirectory() as tmp:
        argv = ["--only", "validate-smoke", "--seeds", "1",
                "--results", f"{tmp}/results",
                "--report", f"{tmp}/results/REPRODUCTION.md"]
        rc = reproduce_main(argv)
        if rc != 0:
            errors.append(f"'Reproduce every number' worked example exited {rc}")
            return
        if not (Path(tmp) / "results" / "REPRODUCTION.md").exists():
            errors.append("reproduce example wrote no REPRODUCTION.md")
            return
        runs = list((Path(tmp) / "results" / "validate-smoke").glob("run-*"))
        if len(runs) != 1 or not (runs[0] / "summary.md").exists():
            errors.append("reproduce example left no completed run directory")
            return
        if reproduce_main(argv) != 0:
            errors.append("reproduce example rerun (resume-skip) failed")
            return
        if len(list((Path(tmp) / "results" / "validate-smoke").glob("run-*"))) != 1:
            errors.append("reproduce rerun did not resume-skip (new run dir)")
            return
    print(f"  reproduce worked example: OK ({time.time() - t0:.1f}s)")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--no-exec", action="store_true",
                    help="check links and file refs only; skip running the "
                         "worked examples")
    args = ap.parse_args(argv)

    errors: list[str] = []
    for doc in DOC_FILES:
        text = doc.read_text()
        check_links(doc, text, errors)
        check_file_refs(doc, text, errors)
    print(f"checked links + file refs in {len(DOC_FILES)} docs")

    if not args.no_exec:
        print("running worked examples:")
        run_readme_examples(errors)
        run_provision_example(errors)
        run_reproduce_example(errors)

    if errors:
        print(f"\n{len(errors)} docs failures:", file=sys.stderr)
        for e in errors:
            print(f"  {e}", file=sys.stderr)
        return 1
    print("docs gate: PASS")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
