"""Training loop: jit'd train_step + checkpoint/restart + straggler policy.

Fault-tolerance contract (DESIGN.md §7):
  * state = (params, opt_state, step); checkpoints are atomic and
    mesh-agnostic — ``resume()`` re-shards onto whatever mesh is active, so a
    job that lost hosts restarts on ``elastic_mesh(n_remaining)`` unchanged;
  * the data pipeline is stateless-by-step, so restoring ``step`` resumes the
    exact token stream;
  * a per-step deadline watchdog implements the synchronous-SGD straggler
    policy: steps that exceed ``deadline_factor x`` the median step time are
    logged and (optionally, ``skip_stragglers``) their host is flagged for
    the elastic controller. On a single-host dry-run this is a no-op that
    still exercises the code path.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable

import jax
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch.steps import make_train_step
from repro.models import lm
from repro.training import optimizer as opt

__all__ = ["TrainConfig", "Trainer"]


@dataclass
class TrainConfig:
    steps: int = 100
    batch: int = 8
    seq_len: int = 256
    checkpoint_every: int = 50
    checkpoint_dir: str | None = None
    keep: int = 3
    log_every: int = 10
    seed: int = 0
    deadline_factor: float = 3.0  # straggler threshold vs median step time
    lr: float = 3e-4
    warmup: int = 20


class Trainer:
    """Single-controller training driver (works on CPU and under pjit)."""

    def __init__(self, cfg: ModelConfig, tc: TrainConfig, *, ocfg=None):
        self.cfg = cfg
        self.tc = tc
        if ocfg is None:
            if cfg.optimizer == "adafactor":
                ocfg = opt.AdafactorConfig(lr=tc.lr)
            else:
                ocfg = opt.AdamWConfig(
                    lr=opt.cosine_schedule(tc.lr, tc.warmup, tc.steps)
                )
        self.ocfg = ocfg
        self.data = SyntheticLM(cfg, DataConfig(batch=tc.batch, seq_len=tc.seq_len, seed=tc.seed))
        self.step_fn = jax.jit(make_train_step(cfg, ocfg), donate_argnums=(0, 1))
        self.ckpt = Checkpointer(tc.checkpoint_dir, keep=tc.keep) if tc.checkpoint_dir else None
        self.metrics_log: list[dict] = []
        self.straggler_events: list[dict] = []

    # ------------------------------------------------------------------
    def init_state(self, seed: int = 0):
        params = lm.init_model(self.cfg, jax.random.PRNGKey(seed))
        if self.cfg.optimizer == "adafactor":
            state = opt.adafactor_init(params)
        else:
            state = opt.adamw_init(params)
        return params, state, 0

    def resume(self, *, shardings: Any = None):
        """Restore the latest checkpoint (possibly onto a different mesh)."""
        assert self.ckpt is not None, "no checkpoint dir configured"
        params_t = lm.abstract_model(self.cfg)
        if self.cfg.optimizer == "adafactor":
            state_t = opt.abstract_adafactor_state(params_t)
        else:
            state_t = opt.abstract_adamw_state(params_t)
        step, tree = self.ckpt.restore(
            target={"params": params_t, "opt": state_t}, shardings=shardings
        )
        return tree["params"], tree["opt"], step

    # ------------------------------------------------------------------
    def run(self, params=None, state=None, start_step: int = 0):
        if params is None:
            params, state, start_step = self.init_state(self.tc.seed)
        durations: list[float] = []
        for step in range(start_step, self.tc.steps):
            batch = self.data[step]
            t0 = time.time()
            params, state, metrics = self.step_fn(params, state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.time() - t0
            durations.append(dt)
            # straggler watchdog
            if len(durations) >= 8:
                median = float(np.median(durations[-32:]))
                if dt > self.tc.deadline_factor * median:
                    self.straggler_events.append(
                        {"step": step, "duration": dt, "median": median}
                    )
            if step % self.tc.log_every == 0 or step == self.tc.steps - 1:
                rec = {k: float(v) for k, v in metrics.items()}
                rec["step"] = step
                rec["step_time_s"] = dt
                self.metrics_log.append(rec)
            if self.ckpt and (step + 1) % self.tc.checkpoint_every == 0:
                self.ckpt.save(step + 1, {"params": params, "opt": state})
        if self.ckpt:
            self.ckpt.save(self.tc.steps, {"params": params, "opt": state})
        return params, state, self.metrics_log
