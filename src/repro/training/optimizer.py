"""AdamW optimizer, LR schedules, gradient clipping, gradient compression.

Built from scratch (no optax in this environment). Design points for scale:

  * Mixed precision: model params are bf16; the optimizer holds an fp32
    master copy + fp32 moments. ``opt_axes`` shards all three over the
    logical "zero" axis on top of the param's own axes (ZeRO-1): each data
    rank updates a slice and GSPMD's sharding propagation turns the gradient
    sum into reduce-scatter + all-gather instead of all-reduce.
  * Optional int8 gradient compression with error feedback (EF21-style
    residual accumulation): quantise g + e to int8 per-tensor scale before
    the cross-replica reduction path, de-quantise after, keep the residual.
    Convergence validated in tests/test_training.py.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "opt_axes", "cosine_schedule", "clip_by_global_norm", "compress_grads", "decompress_grads"]


@dataclass(frozen=True)
class AdamWConfig:
    lr: float | Callable[[jax.Array], jax.Array] = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    compress: bool = False  # int8 error-feedback gradient compression

    def lr_at(self, step):
        return self.lr(step) if callable(self.lr) else jnp.asarray(self.lr)


def cosine_schedule(peak: float, warmup: int, total: int, floor_frac: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak * step / jnp.maximum(warmup, 1)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = peak * (floor_frac + (1 - floor_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)

    return lr


def adamw_init(params: Any) -> dict:
    """fp32 master + moments; ``count`` is the step."""
    # jnp.array(..., copy=True): astype would alias fp32 params, and aliased
    # buffers break donation (params and master are both donated in train_step)
    master = jax.tree.map(lambda p: jnp.array(p, jnp.float32, copy=True), params)
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    state = {
        "master": master,
        "m": zeros,
        "v": jax.tree.map(jnp.copy, zeros),
        "count": jnp.zeros((), jnp.int32),
    }
    return state


def abstract_adamw_state(abstract_params: Any) -> dict:
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return {
        "master": jax.tree.map(f32, abstract_params),
        "m": jax.tree.map(f32, abstract_params),
        "v": jax.tree.map(f32, abstract_params),
        "count": jax.ShapeDtypeStruct((), jnp.int32),
    }


def opt_axes(
    axes_tree: Any,
    abstract_params: Any,
    *,
    zero_size: int = 0,
    replicated_names: frozenset | set = frozenset(),
    data_resident_names: frozenset | set = frozenset({"expert_ff", "zero"}),
) -> dict:
    """Logical axes for the optimizer state.

    With ``zero_size > 0`` the largest *effectively unsharded*, divisible dim
    of each leaf is additionally mapped to the "zero" logical axis (resolved
    to the data mesh axis by the sharding rules) — ZeRO-1 optimizer-state
    partitioning. "Effectively unsharded" = logical axis None OR a name in
    ``replicated_names`` (names the active rules resolve to no mesh axis,
    e.g. "embed"). Leaves with no eligible dim stay replicated over data —
    correct, just less memory-optimal.
    """

    def shard_leaf(axes, aval):
        axes = tuple(axes)
        if zero_size <= 0:
            return axes
        # leaves already sharded over the data axis (e.g. expert_ff) cannot
        # also take the zero axis — a mesh axis may appear only once per spec
        if any(a in data_resident_names for a in axes if a is not None):
            return axes
        best = -1
        for i, a in enumerate(axes):
            eligible = a is None or a in replicated_names
            if eligible and aval.shape[i] % zero_size == 0 and aval.shape[i] > 0:
                if best < 0 or aval.shape[i] > aval.shape[best]:
                    best = i
        if best < 0:
            return axes
        return axes[:best] + ("zero",) + axes[best + 1 :]

    from repro.models.params import is_axes_leaf

    mapped = jax.tree.map(shard_leaf, axes_tree, abstract_params, is_leaf=is_axes_leaf)
    return {"master": mapped, "m": mapped, "v": mapped, "count": ()}


def clip_by_global_norm(grads: Any, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gn


# ---------------------------------------------------------------------------
# int8 error-feedback gradient compression
# ---------------------------------------------------------------------------


def compress_grads(grads: Any, error: Any | None):
    """Quantise (g + e) to int8 with per-tensor scale; return (q, scales, new_error)."""
    if error is None:
        error = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def q(g, e):
        gf = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
        qi = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        new_e = gf - qi.astype(jnp.float32) * scale
        return qi, scale, new_e

    flat, treedef = jax.tree.flatten(grads)
    eflat = jax.tree.leaves(error)
    qs, scales, errs = zip(*(q(g, e) for g, e in zip(flat, eflat)))
    return (
        jax.tree.unflatten(treedef, qs),
        jax.tree.unflatten(treedef, scales),
        jax.tree.unflatten(treedef, errs),
    )


def decompress_grads(q: Any, scales: Any):
    return jax.tree.map(lambda qi, s: qi.astype(jnp.float32) * s, q, scales)


# ---------------------------------------------------------------------------
# Adafactor (Shazeer & Stern, arXiv:1804.04235) — factored second moment,
# no momentum, no fp32 master copy. The optimizer that makes 480B-class
# models trainable on a 256-chip 16 GB/chip pod: state is O(rows + cols)
# per matrix instead of 3x params fp32 (arctic-480b with fp32 AdamW needs
# 5.6 TB of optimizer state; the pod has 4 TB of HBM).
# ---------------------------------------------------------------------------

_FACTOR_MIN = 128  # factor only dims >= this (as in T5X)


@dataclass(frozen=True)
class AdafactorConfig:
    lr: float | Callable[[jax.Array], jax.Array] = 1e-2
    decay_exponent: float = 0.8  # beta2_t = 1 - t^-0.8
    eps1: float = 1e-30
    eps2: float = 1e-3
    clip_threshold: float = 1.0
    weight_decay: float = 0.0

    def lr_at(self, step):
        return self.lr(step) if callable(self.lr) else jnp.asarray(self.lr)


def _factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] >= _FACTOR_MIN and shape[-2] >= _FACTOR_MIN


def adafactor_init(params: Any) -> dict:
    def leaf(p):
        if _factored(p.shape):
            return {
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return {
        "factors": jax.tree.map(leaf, params),
        "count": jnp.zeros((), jnp.int32),
    }


def abstract_adafactor_state(abstract_params: Any) -> dict:
    def leaf(p):
        if _factored(p.shape):
            return {
                "vr": jax.ShapeDtypeStruct(p.shape[:-1], jnp.float32),
                "vc": jax.ShapeDtypeStruct(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return {"v": jax.ShapeDtypeStruct(p.shape, jnp.float32)}

    return {
        "factors": jax.tree.map(leaf, abstract_params),
        "count": jax.ShapeDtypeStruct((), jnp.int32),
    }


def adafactor_axes(axes_tree: Any, abstract_params: Any) -> dict:
    """Factor axes follow the param's own axes with the dropped dim removed."""
    from repro.models.params import is_axes_leaf

    def leaf(axes, p):
        axes = tuple(axes)
        if _factored(p.shape):
            return {"vr": axes[:-1], "vc": axes[:-2] + axes[-1:]}
        return {"v": axes}

    return {
        "factors": jax.tree.map(leaf, axes_tree, abstract_params, is_leaf=is_axes_leaf),
        "count": (),
    }


def adafactor_update(cfg: AdafactorConfig, grads: Any, state: dict, params: Any):
    count = state["count"] + 1
    t = count.astype(jnp.float32)
    beta2 = 1.0 - t ** (-cfg.decay_exponent)
    lr = cfg.lr_at(count)

    def upd(g, fac, p):
        gf = g.astype(jnp.float32)
        g2 = gf * gf + cfg.eps1
        if "vr" in fac:
            vr = beta2 * fac["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
            vc = beta2 * fac["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
            # v-hat = vr vc^T / mean(vr)
            denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), cfg.eps1)
            vhat = (vr / denom)[..., None] * vc[..., None, :]
            new_fac = {"vr": vr, "vc": vc}
        else:
            vhat = beta2 * fac["v"] + (1 - beta2) * g2
            new_fac = {"v": vhat}
        u = gf * jax.lax.rsqrt(vhat + cfg.eps1)
        # RMS clip
        rms_u = jnp.sqrt(jnp.mean(u * u) + cfg.eps1)
        u = u / jnp.maximum(1.0, rms_u / cfg.clip_threshold)
        # relative step: scale by max(eps2, RMS(param))
        pf = p.astype(jnp.float32)
        scale = jnp.maximum(cfg.eps2, jnp.sqrt(jnp.mean(pf * pf)))
        new_p = pf - lr * scale * u
        if cfg.weight_decay:
            new_p = new_p - lr * cfg.weight_decay * pf
        return new_p.astype(p.dtype), new_fac

    flat_g, treedef = jax.tree.flatten(grads)
    flat_f = jax.tree.leaves(
        state["factors"], is_leaf=lambda x: isinstance(x, dict) and ("v" in x or "vr" in x)
    )
    flat_p = jax.tree.leaves(params)
    new_p, new_f = [], []
    for g, fc, p in zip(flat_g, flat_f, flat_p):
        np_, nf = upd(g, fc, p)
        new_p.append(np_)
        new_f.append(nf)
    new_params = jax.tree.unflatten(treedef, new_p)
    new_state = {"factors": jax.tree.unflatten(treedef, new_f), "count": count}
    return new_params, new_state, {"lr": lr}


# ---------------------------------------------------------------------------
# Update
# ---------------------------------------------------------------------------


def adamw_update(cfg: AdamWConfig, grads: Any, state: dict, params: Any):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    if cfg.grad_clip > 0:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gnorm = jnp.asarray(0.0)
    count = state["count"] + 1
    lr = cfg.lr_at(count)
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(g, m, v, master):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        update = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        master = master - lr * (update + cfg.weight_decay * master)
        return m, v, master

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    flat_ma = jax.tree.leaves(state["master"])
    ms, vs, masters = [], [], []
    for g, m, v, ma in zip(flat_g, flat_m, flat_v, flat_ma):
        m2, v2, ma2 = upd(g, m, v, ma)
        ms.append(m2)
        vs.append(v2)
        masters.append(ma2)
    new_state = {
        "master": jax.tree.unflatten(treedef, masters),
        "m": jax.tree.unflatten(treedef, ms),
        "v": jax.tree.unflatten(treedef, vs),
        "count": count,
    }
    param_dtype = jax.tree.leaves(params)[0].dtype
    new_params = jax.tree.map(lambda ma: ma.astype(param_dtype), new_state["master"])
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
