"""Mixture-of-Experts: GShard-style top-k token-choice routing with capacity.

Dense one-hot dispatch/combine einsums ([arXiv:2006.16668]); experts shard
over the "expert" logical axis (expert parallelism -> all-to-all under GSPMD)
and each expert's hidden dim over "expert_ff" (so 480B-class expert stacks fit
per-device HBM; DESIGN.md §6). Tokens are split into dispatch groups of
``moe_group_size`` so the (group, E, capacity) one-hot stays bounded.

Variants:
  "moe"       — routed experts only (dbrx, jamba)
  "moe_dense" — routed experts + parallel dense residual MLP (arctic)
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding.partition import hint

from .layers import _act, mlp_apply, mlp_template
from .params import TSpec

__all__ = ["moe_template", "moe_apply", "capacity"]


def moe_template(cfg: ModelConfig) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    t = {
        "router": TSpec((d, e), ("embed", "expert"), init="fan_in"),
        "wi": TSpec((e, d, f), ("expert", "embed", "expert_ff"), init="fan_in"),
        "wg": TSpec((e, d, f), ("expert", "embed", "expert_ff"), init="fan_in"),
        "wo": TSpec((e, f, d), ("expert", "expert_ff", "embed"), init="fan_in"),
    }
    return t


def _largest_divisor(n: int, upper: int) -> int:
    """Largest divisor of n that is <= upper (group tokens exactly)."""
    for s in range(upper, 0, -1):
        if n % s == 0:
            return s
    return 1


def capacity(cfg: ModelConfig, group_tokens: int) -> int:
    """Per-group per-expert capacity C = ceil(k * s * cf / E), MXU-aligned."""
    c = math.ceil(
        cfg.num_experts_per_tok * group_tokens * cfg.capacity_factor / cfg.num_experts
    )
    return max(4, ((c + 3) // 4) * 4)


def moe_apply(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """x: (B, S, d) -> (B, S, d). Routed top-k with capacity dropping."""
    B, S, d = x.shape
    E, topk = cfg.num_experts, cfg.num_experts_per_tok
    n = B * S
    s = _largest_divisor(n, min(cfg.moe_group_size, n))
    g = n // s
    C = capacity(cfg, s)

    xt = x.reshape(g, s, d)
    logits = jnp.einsum("gsd,de->gse", xt, p["router"], preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, topk)  # (g, s, topk)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )  # renormalise over the chosen k

    # position of each (token, slot) inside its expert's buffer
    onehot_e = jax.nn.one_hot(expert_idx, E, dtype=jnp.float32)  # (g, s, topk, E)
    flat = onehot_e.reshape(g, s * topk, E)
    pos_in_expert = jnp.cumsum(flat, axis=1) - flat  # (g, s*topk, E)
    pos = jnp.sum(pos_in_expert * flat, axis=-1)  # (g, s*topk)
    keep = (pos < C).reshape(g, s, topk)
    pos = pos.reshape(g, s, topk)
    # Build dispatch/combine per k-slot, accumulating in the model dtype: the
    # (g, s, E, C) one-hot products are the layer's biggest tensors and fp32
    # materialisation of the (g, s*topk, E, C) variant costs 4x the memory.
    disp = jnp.zeros((g, s, E, C), x.dtype)
    comb = jnp.zeros((g, s, E, C), x.dtype)
    for kk in range(topk):
        oe = (onehot_e[:, :, kk] * keep[:, :, kk, None]).astype(x.dtype)  # (g,s,E)
        oc = jax.nn.one_hot(pos[:, :, kk].astype(jnp.int32), C, dtype=x.dtype)
        slot = jnp.einsum("gse,gsc->gsec", oe, oc)
        disp = disp + slot
        comb = comb + slot * gate_vals[:, :, kk, None, None].astype(x.dtype)
    disp = hint(disp, "batch", None, "expert", None)
    comb = hint(comb, "batch", None, "expert", None)

    expert_in = jnp.einsum("gsec,gsd->egcd", disp, xt)
    expert_in = hint(expert_in, "expert", "batch", None, None)
    act = _act(cfg.mlp_act)
    h = jnp.einsum("egcd,edf->egcf", expert_in, p["wi"])
    h = act(jnp.einsum("egcd,edf->egcf", expert_in, p["wg"])) * h
    h = hint(h, "expert", "batch", None, None)
    expert_out = jnp.einsum("egcf,efd->egcd", h, p["wo"])
    out = jnp.einsum("gsec,egcd->gsd", comb, expert_out)
    return out.reshape(B, S, d)


def router_aux_loss(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Load-balancing auxiliary loss (Switch [arXiv:2101.03961] style)."""
    B, S, d = x.shape
    logits = jnp.einsum("bsd,de->bse", x, p["router"], preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    _, idx = jax.lax.top_k(probs, cfg.num_experts_per_tok)
    counts = jax.nn.one_hot(idx, cfg.num_experts, dtype=jnp.float32).sum(axis=(0, 1, 2))
    frac_tokens = counts / jnp.maximum(counts.sum(), 1.0)
    frac_probs = probs.mean(axis=(0, 1))
    return cfg.num_experts * jnp.sum(frac_tokens * frac_probs)
