"""Mamba (S6) selective-state-space mixer [arXiv:2312.00752], TPU-adapted.

The recurrence h_t = exp(Δ_t A) h_{t-1} + Δ_t B_t x_t is evaluated with a
``lax.scan`` over time carrying h (B, d_inner, d_state); all projections
(in/x/dt/out) are batched matmuls outside the scan, so MXU work dominates and
the scan body is elementwise. The Pallas kernel in repro.kernels.ssm_scan is
the TPU hot path (keeps h resident in VMEM across the sequence — DESIGN.md §5).

Decode carries (conv_state, h) as the layer's cache: O(1) per token, which is
why jamba runs the long_500k cell.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding.partition import hint

from .params import TSpec

__all__ = ["mamba_template", "mamba_cache_template", "mamba_forward", "mamba_decode"]


MAMBA_CHUNK = 128  # outer-scan chunk (state checkpointed at boundaries)


def _dt_rank(cfg: ModelConfig) -> int:
    return math.ceil(cfg.d_model / 16)


def mamba_template(cfg: ModelConfig) -> dict:
    d, di, n = cfg.d_model, cfg.mamba_d_inner, cfg.mamba_d_state
    dtr, dc = _dt_rank(cfg), cfg.mamba_d_conv
    return {
        "in_proj": TSpec((d, 2 * di), ("embed", "ff"), init="fan_in"),
        "conv_w": TSpec((dc, di), (None, "ff"), init="normal", std=0.1),
        "conv_b": TSpec((di,), ("ff",), init="zeros"),
        "x_proj": TSpec((di, dtr + 2 * n), ("ff", None), init="fan_in"),
        "dt_proj": TSpec((dtr, di), (None, "ff"), init="fan_in"),
        "dt_bias": TSpec((di,), ("ff",), init="zeros"),
        "A_log": TSpec((di, n), ("ff", None), init="ones"),
        "D": TSpec((di,), ("ff",), init="ones"),
        "out_proj": TSpec((di, d), ("ff", "embed"), init="fan_in"),
    }


def mamba_cache_template(cfg: ModelConfig, batch: int) -> dict:
    di, n, dc = cfg.mamba_d_inner, cfg.mamba_d_state, cfg.mamba_d_conv
    return {
        "conv": TSpec((batch, dc - 1, di), ("cache_batch", None, "ff"), init="zeros"),
        "h": TSpec((batch, di, n), ("cache_batch", "ff", None), init="zeros", dtype="float32"),
    }


def _ssm_inputs(p: dict, x: jax.Array, cfg: ModelConfig):
    """Shared projections: returns (u, z, dt, Bc, Cc, A) with u post-conv-input."""
    di, n = cfg.mamba_d_inner, cfg.mamba_d_state
    dtr = _dt_rank(cfg)
    xz = x @ p["in_proj"]
    xz = hint(xz, "batch", "seq_inner", "ff")
    u, z = jnp.split(xz, 2, axis=-1)  # (B, S, di)
    return u, z


def _ssm_core(p: dict, u_conv: jax.Array, cfg: ModelConfig, h0: jax.Array):
    """Run the selective scan over u_conv (B, S, di) from initial state h0.
    Returns (y (B,S,di), h_final (B,di,n) fp32)."""
    di, n = cfg.mamba_d_inner, cfg.mamba_d_state
    dtr = _dt_rank(cfg)
    dbc = u_conv @ p["x_proj"]  # (B, S, dtr + 2n)
    dt_in, Bc, Cc = jnp.split(dbc, [dtr, dtr + n], axis=-1)
    dt = jax.nn.softplus(dt_in @ p["dt_proj"] + p["dt_bias"])  # (B, S, di)
    dt = hint(dt, "batch", "seq_inner", "ff")
    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # (di, n), negative real

    def step(h, xs_t):
        dt_t, B_t, C_t, u_t = xs_t  # (B, di), (B, n), (B, n), (B, di)
        dtf = dt_t.astype(jnp.float32)
        decay = jnp.exp(dtf[..., None] * A[None])  # (B, di, n)
        inp = (dtf * u_t.astype(jnp.float32))[..., None] * B_t.astype(jnp.float32)[:, None, :]
        h = decay * h + inp
        y_t = jnp.einsum("bdn,bn->bd", h, C_t.astype(jnp.float32))
        return h, y_t.astype(u_t.dtype)

    # Two-level scan: outer over chunks (h saved at chunk boundaries only),
    # inner per-step scan rematerialised in the backward pass. A flat
    # 4096-step scan would checkpoint the (B, di, n) state at EVERY step —
    # tens of GB per layer; this bounds it to S/chunk boundaries + one
    # chunk's transient (the same trick our Pallas kernel plays with VMEM).
    S = u_conv.shape[1]
    tc = min(MAMBA_CHUNK, S)
    while S % tc:
        tc -= 1
    nc = S // tc

    def to_chunks(t):  # (B, S, f) -> (nc, tc, B, f)
        return jnp.swapaxes(t.reshape(t.shape[0], nc, tc, -1), 0, 1).swapaxes(1, 2)

    xs = tuple(to_chunks(t) for t in (dt, Bc, Cc, u_conv))

    def chunk_body(h, xs_chunk):
        return jax.lax.scan(step, h, xs_chunk)

    if cfg.remat != "none" and S > 1:
        chunk_body = jax.checkpoint(chunk_body)
    h_final, y_cm = jax.lax.scan(chunk_body, h0, xs)  # y_cm: (nc, tc, B, di)
    y = jnp.moveaxis(y_cm.reshape(nc * tc, *y_cm.shape[2:]), 0, 1)
    y = hint(y, "batch", "seq_inner", "ff") + u_conv * p["D"]
    return y, h_final


def mamba_forward(
    p: dict, x: jax.Array, cfg: ModelConfig, *, return_cache: bool = False
):
    """x: (B, S, d) -> (B, S, d) [, cache]."""
    B, S, _ = x.shape
    di, dc = cfg.mamba_d_inner, cfg.mamba_d_conv
    u, z = _ssm_inputs(p, x, cfg)
    # causal depthwise conv along seq (kernel dc)
    u_pad = jnp.pad(u, ((0, 0), (dc - 1, 0), (0, 0)))
    u_conv = sum(
        u_pad[:, i : i + S] * p["conv_w"][i][None, None, :] for i in range(dc)
    ) + p["conv_b"]
    u_conv = hint(jax.nn.silu(u_conv), "batch", "seq_inner", "ff")
    h0 = jnp.zeros((B, di, cfg.mamba_d_state), jnp.float32)
    y, h_final = _ssm_core(p, u_conv, cfg, h0)
    out = (y * jax.nn.silu(z)) @ p["out_proj"]
    out = hint(out, "batch", "seq", None)
    if not return_cache:
        return out
    # conv cache = last (dc-1) raw conv inputs (pre-activation), as in decode
    cache = {"conv": u_pad[:, S : S + dc - 1, :], "h": h_final}
    return out, cache


def mamba_decode(p: dict, x: jax.Array, cache: dict, cfg: ModelConfig):
    """x: (B, 1, d); cache {conv (B, dc-1, di), h (B, di, n)} -> (y, cache)."""
    B = x.shape[0]
    dc = cfg.mamba_d_conv
    u, z = _ssm_inputs(p, x, cfg)  # (B, 1, di)
    window = jnp.concatenate([cache["conv"], u], axis=1)  # (B, dc, di)
    u_conv = jnp.einsum("bcd,cd->bd", window, p["conv_w"]) + p["conv_b"]
    u_conv = jax.nn.silu(u_conv)[:, None, :]  # (B, 1, di)
    y, h = _ssm_core(p, u_conv, cfg, cache["h"])
    out = (y * jax.nn.silu(z)) @ p["out_proj"]
    return out, {"conv": window[:, 1:, :], "h": h}
