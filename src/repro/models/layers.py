"""Shared layer primitives: RMSNorm, RoPE, MLP, embeddings."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding.partition import hint

from .params import TSpec

__all__ = [
    "rms_norm",
    "rope_apply",
    "mlp_template",
    "mlp_apply",
    "norm_template",
    "embed_template",
    "softcap",
]


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    """RMSNorm in fp32 ([arXiv:1910.07467]); (1+scale) parameterisation
    (gemma-style, zero-init-friendly)."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


def norm_template(d: int) -> TSpec:
    return TSpec((d,), ("embed",), init="zeros")


def softcap(x: jax.Array, cap: float) -> jax.Array:
    """Gemma2 logit soft-capping: cap * tanh(x / cap)."""
    if cap <= 0:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_apply(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding ([arXiv:2104.09864], llama rotate-half convention).

    x: (B, S, H, hd); positions: (S,) or (B, S) absolute token positions.
    """
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)  # (half,)
    if positions.ndim == 1:
        ang = positions.astype(jnp.float32)[:, None] * freqs[None, :]  # (S, half)
        ang = ang[None, :, None, :]  # (1, S, 1, half)
    else:
        ang = positions.astype(jnp.float32)[..., None] * freqs  # (B, S, half)
        ang = ang[:, :, None, :]
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (plain or gated GLU)
# ---------------------------------------------------------------------------


def mlp_template(cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    t = {
        "wi": TSpec((d, f), ("embed", "ff"), init="fan_in"),
        "wo": TSpec((f, d), ("ff", "embed"), init="fan_in"),
    }
    if cfg.gated_mlp:
        t["wg"] = TSpec((d, f), ("embed", "ff"), init="fan_in")
    return t


def _act(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return lambda x: jax.nn.gelu(x, approximate=True)
    raise ValueError(name)


def mlp_apply(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    act = _act(cfg.mlp_act)
    h = x @ p["wi"]
    h = hint(h, "batch", "seq_inner", "ff")
    if cfg.gated_mlp:
        h = act(x @ p["wg"]) * h
    else:
        h = act(h)
    out = h @ p["wo"]
    return hint(out, "batch", "seq", None)


# ---------------------------------------------------------------------------
# Embeddings / head
# ---------------------------------------------------------------------------


def embed_template(cfg: ModelConfig) -> dict:
    v = cfg.padded_vocab  # shard-friendly padding; ids stay < vocab_size
    t = {"embedding": TSpec((v, cfg.d_model), ("vocab", "embed"), std=0.02)}
    if not cfg.tie_embeddings:
        t["unembed"] = TSpec((cfg.d_model, v), ("embed", "vocab"), init="fan_in")
    return t
