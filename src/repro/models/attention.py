"""Attention: GQA with RoPE, optional sliding window + softcap, KV caches.

Three execution paths:
  * full-sequence (train / prefill): query-chunked online attention — the
    XLA analogue of flash attention (bounded score memory at 32k+); the
    Pallas kernel in repro.kernels.flash_attention is the TPU hot path.
  * decode: one query token against a cache. Global layers use an append
    cache; local (sliding-window) layers use a ring buffer of size W whose
    slot->absolute-position mapping is computed analytically (no stored
    position tensor). Split-KV decode maps to sequence-sharded caches.
  * cross-attention (enc-dec): queries against cached encoder K/V.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding.partition import hint

from .layers import rope_apply, softcap
from .params import TSpec

__all__ = [
    "attn_template",
    "kv_cache_template",
    "attn_forward",
    "attn_decode",
    "cross_attn_forward",
    "mha_reference",
]

NEG_INF = -2.0e38  # fp32-safe mask value


def attn_template(cfg: ModelConfig) -> dict:
    d, q, kv = cfg.d_model, cfg.q_dim, cfg.kv_dim
    return {
        "wq": TSpec((d, q), ("embed", "qkv"), init="fan_in"),
        "wk": TSpec((d, kv), ("embed", "kv"), init="fan_in"),
        "wv": TSpec((d, kv), ("embed", "kv"), init="fan_in"),
        "wo": TSpec((q, d), ("qkv", "embed"), init="fan_in"),
    }


def kv_cache_template(cfg: ModelConfig, batch: int, cache_len: int, *, local: bool) -> dict:
    s = min(cache_len, cfg.window_size) if local else cache_len
    shape = (batch, s, cfg.num_kv_heads, cfg.resolved_head_dim)
    axes = ("cache_batch", "cache_seq", None, None)
    return {
        "k": TSpec(shape, axes, init="zeros"),
        "v": TSpec(shape, axes, init="zeros"),
    }


# ---------------------------------------------------------------------------
# Core scaled-dot-product with GQA + masks (single q block)
# ---------------------------------------------------------------------------


def _sdpa_block(q, k, v, *, mask, cap, scale):
    """q: (B, Sq, K, G, hd); k/v: (B, Sk, K, hd); mask: broadcastable to
    (B, K, G, Sq, Sk) bool (True = attend). Returns (B, Sq, K, G, hd)."""
    scores = jnp.einsum(
        "bqkgh,bskh->bkgqs", q, k, preferred_element_type=jnp.float32
    ) * scale
    scores = softcap(scores, cap)
    scores = jnp.where(mask, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum(
        "bkgqs,bskh->bqkgh", p.astype(v.dtype), v, preferred_element_type=jnp.float32
    )
    return out.astype(v.dtype)


def _mask_block(q_pos, k_pos, *, causal: bool, window: int, k_valid=None):
    """(Sq, Sk) bool mask from absolute positions."""
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        m &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        m &= k_pos[None, :] > q_pos[:, None] - window
    if k_valid is not None:
        m &= k_valid[None, :]
    return m


# ---------------------------------------------------------------------------
# Full-sequence attention (train / prefill)
# ---------------------------------------------------------------------------


def attn_forward(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    causal: bool = True,
    local: bool = False,
    return_kv: bool = False,
    positions: jax.Array | None = None,
    external_kv: tuple[jax.Array, jax.Array] | None = None,
):
    """x: (B, S, d). Query-chunked attention over the full sequence.

    ``external_kv`` supplies precomputed (k, v) — the cross-attention path —
    in which case the k/v projections, rope-on-k, and causality are skipped.
    """
    B, S, _ = x.shape
    K, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    H = cfg.num_heads
    G = H // K
    q = hint(x @ p["wq"], "batch", "seq_inner", "qkv").reshape(B, S, K, G, hd)
    if external_kv is None:
        k = hint(x @ p["wk"], "batch", "seq_inner", "kv").reshape(B, S, K, hd)
        v = hint(x @ p["wv"], "batch", "seq_inner", "kv").reshape(B, S, K, hd)
    else:
        k, v = external_kv
        causal = False

    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)
    key_positions = jnp.arange(k.shape[1], dtype=jnp.int32)
    if cfg.rope and external_kv is None:  # cross-attention carries no rotary
        q = rope_apply(q.reshape(B, S, K * G, hd), positions, cfg.rope_theta).reshape(
            B, S, K, G, hd
        )
        k = rope_apply(k, positions, cfg.rope_theta)

    window = cfg.window_size if local else 0
    scale = hd**-0.5
    chunk = min(cfg.seq_chunk, S)
    # pad the query side to a chunk multiple (keys untouched -> exact);
    # padded rows are sliced off below.
    S_pad = ((S + chunk - 1) // chunk) * chunk
    if S_pad != S:
        q = jnp.pad(q, ((0, 0), (0, S_pad - S), (0, 0), (0, 0), (0, 0)))
    n_chunks = S_pad // chunk

    # Banded keys for sliding-window layers (§Perf iteration "local-band"):
    # a q-chunk at offset o only attends keys in (o - W, o + chunk), so slice
    # that band instead of scoring all S keys and masking — at 32k prefill
    # this cuts the local layers' attention FLOPs/bytes by ~7x.
    band = window + chunk if window > 0 else 0
    use_band = 0 < band < k.shape[1] and external_kv is None

    def one_chunk(qc, offset):
        q_pos = offset + jnp.arange(chunk, dtype=jnp.int32)
        if use_band:
            start = jnp.clip(offset - window, 0, k.shape[1] - band)
            kb = jax.lax.dynamic_slice_in_dim(k, start, band, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(v, start, band, axis=1)
            k_pos = start + jnp.arange(band, dtype=jnp.int32)
            mask = _mask_block(q_pos, k_pos, causal=causal, window=window)
            return _sdpa_block(qc, kb, vb, mask=mask[None, None, None],
                               cap=cfg.attn_softcap, scale=scale)
        mask = _mask_block(q_pos, key_positions, causal=causal, window=window)
        return _sdpa_block(qc, k, v, mask=mask[None, None, None], cap=cfg.attn_softcap, scale=scale)

    if cfg.remat != "none":
        # flash-style backward: recompute chunk scores instead of saving the
        # (chunk x S) probability tensor per chunk across the scan
        one_chunk = jax.checkpoint(one_chunk)

    if n_chunks == 1:
        out = one_chunk(q, jnp.int32(0))
    elif cfg.unroll_attn_chunks:
        outs = [
            one_chunk(q[:, i * chunk : (i + 1) * chunk], jnp.int32(i * chunk))
            for i in range(n_chunks)
        ]
        out = jnp.concatenate(outs, axis=1)
    else:
        qs = q.reshape(B, n_chunks, chunk, K, G, hd).transpose(1, 0, 2, 3, 4, 5)
        offs = jnp.arange(n_chunks, dtype=jnp.int32) * chunk

        def body(_, xs):
            qc, off = xs
            return None, one_chunk(qc, off)

        _, outs = jax.lax.scan(body, None, (qs, offs))
        out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, S_pad, K, G, hd)
    out = out[:, :S]

    y = out.reshape(B, S, H * hd) @ p["wo"]
    y = hint(y, "batch", "seq", None)
    if return_kv:
        return y, (k, v)
    return y


def prefill_cache_from_kv(k, v, cfg: ModelConfig, *, local: bool):
    """Convert full-sequence K/V into the decode cache layout.

    Global: identity (append cache, full S slots).
    Local: ring buffer of the last W positions; slot = pos % W, realised as a
    cyclic roll of the tail (see attn_decode for the inverse mapping).
    """
    if not local:
        return {"k": k, "v": v}
    W = cfg.window_size
    S = k.shape[1]
    if S <= W:
        pad = [(0, 0), (0, W - S), (0, 0), (0, 0)]
        return {"k": jnp.pad(k, pad), "v": jnp.pad(v, pad)}
    shift = (S - W) % W
    return {
        "k": jnp.roll(k[:, -W:], shift, axis=1),
        "v": jnp.roll(v[:, -W:], shift, axis=1),
    }


# ---------------------------------------------------------------------------
# Decode (single token, cached KV)
# ---------------------------------------------------------------------------


def attn_decode(
    p: dict,
    x: jax.Array,
    cache: dict,
    pos: jax.Array,
    cfg: ModelConfig,
    *,
    local: bool = False,
):
    """x: (B, 1, d); pos: scalar int32 — the absolute position of this token.
    Returns (y, new_cache)."""
    B = x.shape[0]
    K, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    H = cfg.num_heads
    G = H // K
    q = (x @ p["wq"]).reshape(B, 1, K, G, hd)
    k_new = (x @ p["wk"]).reshape(B, 1, K, hd)
    v_new = (x @ p["wv"]).reshape(B, 1, K, hd)
    if cfg.rope:
        pos_arr = pos[None].astype(jnp.int32)
        q = rope_apply(q.reshape(B, 1, H, hd), pos_arr, cfg.rope_theta).reshape(B, 1, K, G, hd)
        k_new = rope_apply(k_new, pos_arr, cfg.rope_theta)

    S_c = cache["k"].shape[1]
    if local:
        slot = jnp.mod(pos, cfg.window_size)
        k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, slot, axis=1)
        v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, slot, axis=1)
        # slot i holds the latest position <= pos congruent to i (mod W);
        # negative -> never written.
        i = jnp.arange(S_c, dtype=jnp.int32)
        slot_pos = pos - jnp.mod(pos - i, cfg.window_size)
        valid = slot_pos >= 0
    else:
        k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, pos, axis=1)
        v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, pos, axis=1)
        valid = jnp.arange(S_c, dtype=jnp.int32) <= pos

    scale = hd**-0.5
    mask = valid[None, None, None, None, :]  # (1,1,1,1,Sk)
    out = _sdpa_block(q, k, v, mask=mask, cap=cfg.attn_softcap, scale=scale)
    y = out.reshape(B, 1, H * hd) @ p["wo"]
    return y, {"k": k, "v": v}


# ---------------------------------------------------------------------------
# Cross-attention (encoder-decoder)
# ---------------------------------------------------------------------------


def cross_attn_forward(p: dict, x: jax.Array, enc_k: jax.Array, enc_v: jax.Array, cfg: ModelConfig):
    """x: (B, Sq, d); enc_k/enc_v: (B, Se, K, hd) — precomputed encoder KV.
    Routed through the query-chunked path (a 4k x 4k cross-score tensor per
    layer does not fit; chunking bounds it exactly like self-attention)."""
    return attn_forward(p, x, cfg, external_kv=(enc_k, enc_v))


def cross_kv(p: dict, enc_out: jax.Array, cfg: ModelConfig):
    B, Se, _ = enc_out.shape
    K, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    k = (enc_out @ p["wk"]).reshape(B, Se, K, hd)
    v = (enc_out @ p["wv"]).reshape(B, Se, K, hd)
    return k, v


# ---------------------------------------------------------------------------
# Dense reference (oracle for tests / kernels)
# ---------------------------------------------------------------------------


def mha_reference(q, k, v, *, causal=True, window=0, cap=0.0, k_valid=None):
    """Unchunked reference: q (B,Sq,H,hd), k/v (B,Sk,K,hd), GQA by repeat."""
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    qr = q.reshape(B, Sq, K, G, hd)
    q_pos = jnp.arange(Sq, dtype=jnp.int32) + (k.shape[1] - Sq)
    k_pos = jnp.arange(k.shape[1], dtype=jnp.int32)
    mask = _mask_block(q_pos, k_pos, causal=causal, window=window, k_valid=k_valid)
    out = _sdpa_block(qr, k, v, mask=mask[None, None, None], cap=cap, scale=hd**-0.5)
    return out.reshape(B, Sq, H, hd)
