"""Model assembly: decoder-only / encoder-decoder LMs over superblock stacks.

One code path serves all 10 assigned architectures; the superblock pattern in
the config decides which mixers/FFNs appear. The stack is scanned over
superblocks (HLO O(1) in depth); ``cfg.scan_layers=False`` unrolls it for the
roofline-accounting compiles (EXPERIMENTS.md §Roofline: XLA cost analysis
counts while-loop bodies once — verified empirically — so totals are
extrapolated from unrolled 1- and 2-superblock compiles).

Modes:
  forward  — full-sequence logits (training)
  prefill  — full-sequence + build decode caches
  decode   — one token, consume/update caches
"""

from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig
from repro.sharding.partition import hint

from . import attention as A
from . import moe as M
from . import ssm as SSM
from . import xlstm as XL
from .layers import embed_template, mlp_apply, mlp_template, norm_template, rms_norm, softcap
from .params import TSpec, abstract_params, count_params, init_params, param_axes, stack

__all__ = [
    "model_template",
    "cache_template",
    "init_model",
    "abstract_model",
    "model_param_axes",
    "forward",
    "prefill",
    "decode_step",
    "loss_fn",
    "encode",
]


# ---------------------------------------------------------------------------
# Templates
# ---------------------------------------------------------------------------


def _block_template(cfg: ModelConfig, spec: LayerSpec, *, cross: bool) -> dict:
    d = cfg.d_model
    t: dict[str, Any] = {"norm1": norm_template(d)}
    if spec.mixer in ("attn", "attn_local"):
        t["attn"] = A.attn_template(cfg)
    elif spec.mixer == "mamba":
        t["mamba"] = SSM.mamba_template(cfg)
    elif spec.mixer == "mlstm":
        t["mlstm"] = XL.mlstm_template(cfg)
    elif spec.mixer == "slstm":
        t["slstm"] = XL.slstm_template(cfg)
    else:
        raise ValueError(spec.mixer)
    if cross and spec.mixer in ("attn", "attn_local"):
        t["norm_cross"] = norm_template(d)
        t["cross"] = A.attn_template(cfg)
    if spec.ffn in ("mlp", "moe", "moe_dense"):
        t["norm2"] = norm_template(d)
    if spec.ffn == "mlp":
        t["mlp"] = mlp_template(cfg)
    elif spec.ffn == "moe":
        t["moe"] = M.moe_template(cfg)
    elif spec.ffn == "moe_dense":
        t["moe"] = M.moe_template(cfg)
        t["dense_mlp"] = mlp_template(cfg)
    return t


def model_template(cfg: ModelConfig) -> dict:
    blocks = tuple(
        _block_template(cfg, spec, cross=cfg.is_encdec) for spec in cfg.superblock
    )
    t: dict[str, Any] = {
        "embed": embed_template(cfg),
        "blocks": stack(blocks, cfg.num_superblocks),
        "final_norm": norm_template(cfg.d_model),
    }
    if cfg.is_encdec:
        enc_block = {
            "norm1": norm_template(cfg.d_model),
            "attn": A.attn_template(cfg),
            "norm2": norm_template(cfg.d_model),
            "mlp": mlp_template(cfg),
        }
        t["encoder"] = {
            "blocks": stack((enc_block,), cfg.encoder_layers),
            "final_norm": norm_template(cfg.d_model),
        }
    return t


def cache_template(
    cfg: ModelConfig, batch: int, cache_len: int, *, enc_len: int = 0
) -> tuple:
    """Decode-cache template: tuple over superblock positions, leaves stacked
    over num_superblocks."""
    per_pos = []
    for spec in cfg.superblock:
        c: dict[str, Any] = {}
        if spec.mixer in ("attn", "attn_local"):
            c.update(
                A.kv_cache_template(cfg, batch, cache_len, local=spec.mixer == "attn_local")
            )
            if cfg.is_encdec:
                K, hd = cfg.num_kv_heads, cfg.resolved_head_dim
                shape = (batch, enc_len, K, hd)
                axes = ("cache_batch", "cache_seq", None, None)
                c["cross_k"] = TSpec(shape, axes, init="zeros")
                c["cross_v"] = TSpec(shape, axes, init="zeros")
        elif spec.mixer == "mamba":
            c.update(SSM.mamba_cache_template(cfg, batch))
        elif spec.mixer == "mlstm":
            c.update(XL.mlstm_cache_template(cfg, batch))
        elif spec.mixer == "slstm":
            c.update(XL.slstm_cache_template(cfg, batch))
        per_pos.append(c)
    return stack(tuple(per_pos), cfg.num_superblocks)


def init_model(cfg: ModelConfig, key: jax.Array):
    return init_params(model_template(cfg), key, jnp.dtype(cfg.dtype))


def abstract_model(cfg: ModelConfig):
    return abstract_params(model_template(cfg), jnp.dtype(cfg.dtype))


def model_param_axes(cfg: ModelConfig):
    return param_axes(model_template(cfg))


def num_params(cfg: ModelConfig) -> int:
    return count_params(model_template(cfg))


# ---------------------------------------------------------------------------
# Block application
# ---------------------------------------------------------------------------


def _apply_ffn(spec: LayerSpec, p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if spec.ffn == "none":
        return x
    h = rms_norm(x, p["norm2"], cfg.norm_eps)
    if spec.ffn == "mlp":
        return x + mlp_apply(p["mlp"], h, cfg)
    if spec.ffn == "moe":
        return x + M.moe_apply(p["moe"], h, cfg)
    if spec.ffn == "moe_dense":  # arctic: routed experts + parallel dense MLP
        return x + M.moe_apply(p["moe"], h, cfg) + mlp_apply(p["dense_mlp"], h, cfg)
    raise ValueError(spec.ffn)


def _apply_block(
    spec: LayerSpec,
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    mode: str,
    cache: dict | None,
    pos,
    enc_out,
    causal: bool,
    cross: bool = False,
):
    """Returns (x, new_cache_or_None)."""
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    new_cache: dict[str, Any] = {}
    if spec.mixer in ("attn", "attn_local"):
        local = spec.mixer == "attn_local"
        if mode == "decode":
            y, kv = A.attn_decode(p["attn"], h, {"k": cache["k"], "v": cache["v"]}, pos, cfg, local=local)
            new_cache.update(kv)
        elif mode == "prefill":
            y, (k, v) = A.attn_forward(p["attn"], h, cfg, causal=causal, local=local, return_kv=True)
            new_cache.update(A.prefill_cache_from_kv(k, v, cfg, local=local))
        else:
            y = A.attn_forward(p["attn"], h, cfg, causal=causal, local=local)
        x = x + y
        if cross:
            hc = rms_norm(x, p["norm_cross"], cfg.norm_eps)
            if mode == "decode":
                ck, cv = cache["cross_k"], cache["cross_v"]
            else:
                ck, cv = A.cross_kv(p["cross"], enc_out, cfg)
            x = x + A.cross_attn_forward(p["cross"], hc, ck, cv, cfg)
            if mode in ("prefill", "decode"):
                new_cache["cross_k"], new_cache["cross_v"] = ck, cv
    elif spec.mixer == "mamba":
        if mode == "decode":
            y, c = SSM.mamba_decode(p["mamba"], h, cache, cfg)
            new_cache.update(c)
        elif mode == "prefill":
            y, c = SSM.mamba_forward(p["mamba"], h, cfg, return_cache=True)
            new_cache.update(c)
        else:
            y = SSM.mamba_forward(p["mamba"], h, cfg)
        x = x + y
    elif spec.mixer == "mlstm":
        if mode == "decode":
            y, c = XL.mlstm_decode(p["mlstm"], h, cache, cfg)
            new_cache.update(c)
        elif mode == "prefill":
            y, c = XL.mlstm_forward(p["mlstm"], h, cfg, return_cache=True)
            new_cache.update(c)
        else:
            y = XL.mlstm_forward(p["mlstm"], h, cfg)
        x = x + y
    elif spec.mixer == "slstm":
        if mode == "decode":
            y, c = XL.slstm_decode(p["slstm"], h, cache, cfg)
            new_cache.update(c)
        elif mode == "prefill":
            y, c = XL.slstm_forward(p["slstm"], h, cfg, return_cache=True)
            new_cache.update(c)
        else:
            y = XL.slstm_forward(p["slstm"], h, cfg)
        x = x + y
    else:
        raise ValueError(spec.mixer)

    x = _apply_ffn(spec, p, x, cfg)
    x = hint(x, "batch", "seq", None)
    return x, (new_cache if new_cache else None)


# ---------------------------------------------------------------------------
# Stack runner
# ---------------------------------------------------------------------------


def _run_stack(
    blocks_params,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    mode: str,
    caches=None,
    pos=None,
    enc_out=None,
    causal: bool = True,
    cross: bool = False,
    superblock=None,
    n_superblocks=None,
):
    superblock = superblock or cfg.superblock
    n_sb = n_superblocks or cfg.num_superblocks

    # Remat at PER-LAYER granularity (not per-superblock): jamba's 8-layer
    # superblock would otherwise hold every layer's recompute transients
    # simultaneously during the superblock's backward (measured 75 GiB).
    def layer_fn(spec_idx, lp, x, lc):
        spec = superblock[spec_idx]
        return _apply_block(
            spec, lp, x, cfg, mode=mode, cache=lc, pos=pos,
            enc_out=enc_out, causal=causal, cross=cross,
        )

    if mode != "decode" and cfg.remat == "full":
        layer_fn = jax.checkpoint(layer_fn, static_argnums=(0,))
    elif mode != "decode" and cfg.remat == "dots":
        layer_fn = jax.checkpoint(
            layer_fn,
            static_argnums=(0,),
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
        )

    def body_fn(x, block_params, block_caches):
        new_caches = []
        for i, _spec in enumerate(superblock):
            c = block_caches[i] if block_caches is not None else None
            x, nc = layer_fn(i, block_params[i], x, c)
            new_caches.append(nc)
        return x, tuple(new_caches)

    emit_cache = mode in ("prefill", "decode")
    if cfg.scan_layers:
        xs = (blocks_params, caches) if caches is not None else (blocks_params,)

        def scan_body(carry, xs_t):
            bp = xs_t[0]
            bc = xs_t[1] if len(xs_t) > 1 else None
            y, ncs = body_fn(carry, bp, bc)
            return y, (ncs if emit_cache else None)

        x, new_caches = jax.lax.scan(scan_body, x, xs)
    else:
        new_list = []
        for sb in range(n_sb):
            bp = jax.tree.map(lambda l: l[sb], blocks_params)
            bc = jax.tree.map(lambda l: l[sb], caches) if caches is not None else None
            x, ncs = body_fn(x, bp, bc)
            new_list.append(ncs)
        if emit_cache:
            new_caches = jax.tree.map(lambda *ls: jnp.stack(ls), *new_list)
        else:
            new_caches = None
    return x, new_caches


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------


def _embed(params, tokens, cfg: ModelConfig, prefix_embeds=None):
    emb = params["embed"]["embedding"]
    x = jnp.take(emb, tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    if cfg.tie_embeddings:  # gemma-style input scaling
        x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    return hint(x, "batch", "seq", None)


def _head(params, x, cfg: ModelConfig):
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = x @ params["embed"]["embedding"].T
    else:
        logits = x @ params["embed"]["unembed"]
    logits = softcap(logits, cfg.final_softcap)
    return hint(logits, "batch", "seq_inner", "vocab")


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------


def encode(params, cfg: ModelConfig, enc_embeds: jax.Array) -> jax.Array:
    """Encoder stack over stubbed frontend embeddings (B, Se, d)."""
    enc = params["encoder"]
    x = hint(enc_embeds.astype(jnp.dtype(cfg.dtype)), "batch", "seq", None)
    x, _ = _run_stack(
        enc["blocks"], x, cfg, mode="forward", causal=False,
        superblock=(LayerSpec("attn", "mlp"),), n_superblocks=cfg.encoder_layers,
    )
    return rms_norm(x, enc["final_norm"], cfg.norm_eps)


def forward(params, cfg: ModelConfig, tokens, *, prefix_embeds=None, enc_embeds=None):
    """Full-sequence logits (training path)."""
    enc_out = encode(params, cfg, enc_embeds) if cfg.is_encdec else None
    x = _embed(params, tokens, cfg, prefix_embeds)
    x, _ = _run_stack(params["blocks"], x, cfg, mode="forward", enc_out=enc_out,
                      cross=cfg.is_encdec)
    return _head(params, x, cfg)


def prefill(params, cfg: ModelConfig, tokens, *, prefix_embeds=None, enc_embeds=None):
    """Full-sequence forward that also builds decode caches.
    Returns (last-position logits, caches)."""
    enc_out = encode(params, cfg, enc_embeds) if cfg.is_encdec else None
    x = _embed(params, tokens, cfg, prefix_embeds)
    x, caches = _run_stack(params["blocks"], x, cfg, mode="prefill", enc_out=enc_out,
                           cross=cfg.is_encdec)
    logits = _head(params, x[:, -1:, :], cfg)
    return logits, caches


def decode_step(params, cfg: ModelConfig, token, pos, caches):
    """token: (B, 1) int32; pos: scalar int32 absolute position.
    Returns (logits (B,1,V), new caches)."""
    x = _embed(params, token, cfg)
    x, new_caches = _run_stack(
        params["blocks"], x, cfg, mode="decode", caches=caches, pos=pos,
        cross=cfg.is_encdec,
    )
    return _head(params, x, cfg), new_caches


def loss_fn(params, cfg: ModelConfig, batch: dict):
    """Next-token CE (fp32 softmax) + z-loss; honours batch['loss_mask']."""
    logits = forward(
        params, cfg, batch["tokens"],
        prefix_embeds=batch.get("prefix_embeds"),
        enc_embeds=batch.get("enc_embeds"),
    )
    targets = batch["targets"]
    mask = batch["loss_mask"].astype(jnp.float32)
    # prefix positions carry no targets; logits cover prefix + tokens
    if logits.shape[1] != targets.shape[1]:
        logits = logits[:, logits.shape[1] - targets.shape[1] :]
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, targets[..., None], axis=-1)[..., 0]
    nll = lse - gold
    z_loss = 1e-4 * lse**2
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = jnp.sum((nll + z_loss) * mask) / denom
    return loss, {
        "loss": loss,
        "nll": jnp.sum(nll * mask) / denom,
        "tokens": mask.sum(),
    }
