from . import attention, layers, lm, moe, params, ssm, xlstm
from .lm import (
    abstract_model,
    cache_template,
    decode_step,
    forward,
    init_model,
    loss_fn,
    model_param_axes,
    model_template,
    num_params,
    prefill,
)

__all__ = [
    "attention", "layers", "lm", "moe", "params", "ssm", "xlstm",
    "abstract_model", "cache_template", "decode_step", "forward",
    "init_model", "loss_fn", "model_param_axes", "model_template",
    "num_params", "prefill",
]
