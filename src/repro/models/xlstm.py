"""xLSTM blocks [arXiv:2405.04517]: mLSTM (matrix memory) + sLSTM (scalar).

TPU adaptation (DESIGN.md §5): the mLSTM is evaluated in *chunkwise-parallel*
form — within a chunk the contribution matrix is an attention-like matmul
(MXU-friendly), across chunks a small fp32 state (C, n) is carried by a
``lax.scan``. Stability: sigmoid forget gate (log-space cumsum, decay factors
<= 1) and a capped exponential input gate; the normalizer uses the paper's
max(|q.n|, 1) lower bound, so no stabiliser-max bookkeeping is needed.

The sLSTM has true hidden-to-gate recurrence (R matrices) and is inherently
sequential: a per-timestep scan with the paper's m-stabilised exponential
gating. Both cells expose O(1)-state decode paths (-> long_500k runs).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding.partition import hint

from .layers import rms_norm
from .params import TSpec

__all__ = [
    "mlstm_template",
    "slstm_template",
    "mlstm_cache_template",
    "slstm_cache_template",
    "mlstm_forward",
    "mlstm_decode",
    "slstm_forward",
    "slstm_decode",
]

_ILOG_CAP = 8.0  # cap on the exponential input gate pre-activation
MLSTM_CHUNK = 256


def mlstm_template(cfg: ModelConfig) -> dict:
    d, H = cfg.d_model, cfg.num_heads
    return {
        "wq": TSpec((d, d), ("embed", "qkv"), init="fan_in"),
        "wk": TSpec((d, d), ("embed", "qkv"), init="fan_in"),
        "wv": TSpec((d, d), ("embed", "qkv"), init="fan_in"),
        "w_if": TSpec((d, 2 * H), ("embed", None), init="normal", std=0.01),
        "b_if": TSpec((2 * H,), (None,), init="zeros"),
        "w_og": TSpec((d, d), ("embed", "qkv"), init="fan_in"),
        "headnorm": TSpec((d,), ("embed",), init="zeros"),
        "wo": TSpec((d, d), ("qkv", "embed"), init="fan_in"),
    }


def slstm_template(cfg: ModelConfig) -> dict:
    d, H = cfg.d_model, cfg.num_heads
    hd = d // H
    return {
        "w_in": TSpec((d, 4 * d), ("embed", "qkv"), init="fan_in"),
        "r": TSpec((H, hd, 4 * hd), (None, None, None), init="normal", std=0.01),
        "b": TSpec((4 * d,), (None,), init="zeros"),
        "headnorm": TSpec((d,), ("embed",), init="zeros"),
        "wo": TSpec((d, d), ("qkv", "embed"), init="fan_in"),
    }


def mlstm_cache_template(cfg: ModelConfig, batch: int) -> dict:
    H = cfg.num_heads
    hd = cfg.d_model // H
    return {
        "C": TSpec((batch, H, hd, hd), ("cache_batch", None, "mlstm_dk", None), init="zeros", dtype="float32"),
        "n": TSpec((batch, H, hd), ("cache_batch", None, "mlstm_dk"), init="zeros", dtype="float32"),
    }


def slstm_cache_template(cfg: ModelConfig, batch: int) -> dict:
    d = cfg.d_model
    z = dict(init="zeros", dtype="float32")
    return {
        "c": TSpec((batch, d), ("cache_batch", None), **z),
        "n": TSpec((batch, d), ("cache_batch", None), **z),
        "h": TSpec((batch, d), ("cache_batch", None), **z),
        "m": TSpec((batch, d), ("cache_batch", None), **z),
    }


# ---------------------------------------------------------------------------
# mLSTM — chunkwise parallel
# ---------------------------------------------------------------------------


def _mlstm_qkv_gates(p: dict, x: jax.Array, cfg: ModelConfig):
    B, S, d = x.shape
    H = cfg.num_heads
    hd = d // H
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (x @ p["wk"]).reshape(B, S, H, hd) * (hd**-0.5)
    v = (x @ p["wv"]).reshape(B, S, H, hd)
    gates = x @ p["w_if"] + p["b_if"]  # (B, S, 2H)
    ilog = jnp.minimum(gates[..., : H].astype(jnp.float32), _ILOG_CAP)
    flog = -jax.nn.softplus(-gates[..., H :].astype(jnp.float32))  # log sigmoid
    og = jax.nn.sigmoid(x @ p["w_og"])  # (B, S, d)
    return q, k, v, ilog, flog, og


def _mlstm_finish(p: dict, h: jax.Array, og: jax.Array, cfg: ModelConfig):
    B, S = h.shape[0], h.shape[1]
    d = cfg.d_model
    hn = rms_norm(h.reshape(B, S, d), p["headnorm"], cfg.norm_eps)
    out = (hn * og) @ p["wo"]
    return hint(out, "batch", "seq", None)


def _mlstm_chunk(carry, xs):
    """One chunk of the chunkwise-parallel mLSTM. carry: (C, n) fp32.
    xs: q, k, v (B, L, H, hd); ilog, flog (B, L, H)."""
    C0, n0 = carry
    q, k, v, ilog, flog = xs
    b = jnp.cumsum(flog, axis=1)  # (B, L, H), <= 0, decreasing
    # intra-chunk weights w[t, tau] = exp(b_t - b_tau + ilog_tau), tau <= t
    L = q.shape[1]
    decay = b[:, :, None, :] - b[:, None, :, :] + ilog[:, None, :, :]  # (B, t, tau, H)
    tri = jnp.tril(jnp.ones((L, L), bool))
    w = jnp.where(tri[None, :, :, None], jnp.exp(decay), 0.0)  # (B, t, tau, H)
    scores = jnp.einsum("bthd,bshd->btsh", q.astype(jnp.float32), k.astype(jnp.float32))
    ws = w * scores
    num_intra = jnp.einsum("btsh,bshd->bthd", ws, v.astype(jnp.float32))
    den_intra = jnp.sum(ws, axis=2)  # (B, t, H)
    eb = jnp.exp(b)  # (B, L, H)
    num_inter = jnp.einsum("bthd,bhde->bthe", q.astype(jnp.float32), C0) * eb[..., None]
    den_inter = jnp.einsum("bthd,bhd->bth", q.astype(jnp.float32), n0) * eb
    den = jnp.maximum(jnp.abs(den_intra + den_inter), 1.0)
    h = (num_intra + num_inter) / den[..., None]  # (B, L, H, hd)
    # state to end of chunk
    wL = jnp.exp(b[:, -1:, :] - b + ilog)  # (B, L, H): decay from tau to L
    C1 = jnp.exp(b[:, -1])[:, :, None, None] * C0 + jnp.einsum(
        "blh,blhd,blhe->bhde", wL, k.astype(jnp.float32), v.astype(jnp.float32)
    )
    n1 = jnp.exp(b[:, -1])[..., None] * n0 + jnp.einsum(
        "blh,blhd->bhd", wL, k.astype(jnp.float32)
    )
    return (C1, n1), h


def mlstm_forward(p: dict, x: jax.Array, cfg: ModelConfig, *, return_cache: bool = False):
    B, S, d = x.shape
    H = cfg.num_heads
    hd = d // H
    q, k, v, ilog, flog, og = _mlstm_qkv_gates(p, x, cfg)
    L = min(MLSTM_CHUNK, S)
    while S % L:  # largest divisor <= MLSTM_CHUNK (exact chunking)
        L -= 1
    nc = S // L
    C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    n0 = jnp.zeros((B, H, hd), jnp.float32)

    def chunked(t):  # (B, S, ...) -> (nc, B, L, ...)
        return jnp.swapaxes(t.reshape(B, nc, L, *t.shape[2:]), 0, 1)

    xs = tuple(chunked(t) for t in (q, k, v, ilog, flog))
    chunk_fn = _mlstm_chunk if cfg.remat == "none" else jax.checkpoint(_mlstm_chunk)
    if cfg.unroll_attn_chunks:  # roofline-accounting compiles unroll inner scans
        carry, outs = (C0, n0), []
        for i in range(nc):
            carry, hc = chunk_fn(carry, jax.tree.map(lambda t: t[i], xs))
            outs.append(hc)
        (C1, n1), hs = carry, jnp.stack(outs)
    else:
        (C1, n1), hs = jax.lax.scan(chunk_fn, (C0, n0), xs)
    h = jnp.swapaxes(hs, 0, 1).reshape(B, S, H, hd).astype(x.dtype)
    out = _mlstm_finish(p, h, og, cfg)
    if not return_cache:
        return out
    return out, {"C": C1, "n": n1}


def mlstm_decode(p: dict, x: jax.Array, cache: dict, cfg: ModelConfig):
    """x: (B, 1, d). Linear-space single-step update."""
    B = x.shape[0]
    H = cfg.num_heads
    hd = cfg.d_model // H
    q, k, v, ilog, flog, og = _mlstm_qkv_gates(p, x, cfg)
    i = jnp.exp(ilog[:, 0])  # (B, H)
    f = jnp.exp(flog[:, 0])
    kf = k[:, 0].astype(jnp.float32)
    vf = v[:, 0].astype(jnp.float32)
    C = f[..., None, None] * cache["C"] + i[..., None, None] * (
        kf[..., :, None] * vf[..., None, :]
    )
    n = f[..., None] * cache["n"] + i[..., None] * kf
    qf = q[:, 0].astype(jnp.float32)
    num = jnp.einsum("bhd,bhde->bhe", qf, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n)), 1.0)
    h = (num / den[..., None]).reshape(B, 1, cfg.d_model).astype(x.dtype)
    out = _mlstm_finish(p, h, og, cfg)
    return out, {"C": C, "n": n}


# ---------------------------------------------------------------------------
# sLSTM — sequential with m-stabilised exponential gating
# ---------------------------------------------------------------------------


def _slstm_step(p, cfg, carry, zifo_t):
    """carry: (c, n, h, m) each (B, d) fp32; zifo_t: (B, 4d) input projection."""
    c, n, h, m = carry
    B = c.shape[0]
    d = cfg.d_model
    H = cfg.num_heads
    hd = d // H
    rec = jnp.einsum(
        "bhd,hdf->bhf", h.reshape(B, H, hd).astype(p["r"].dtype), p["r"]
    ).reshape(B, 4 * d)
    g = (zifo_t + rec).astype(jnp.float32)
    zt, it, ft, ot = jnp.split(g, 4, axis=-1)
    z = jnp.tanh(zt)
    m_new = jnp.maximum(ft + m, it)
    i = jnp.exp(it - m_new)
    f = jnp.exp(ft + m - m_new)
    c = f * c + i * z
    n = f * n + i
    h_new = jax.nn.sigmoid(ot) * c / jnp.maximum(n, 1e-6)
    return (c, n, h_new, m_new)


def slstm_forward(p: dict, x: jax.Array, cfg: ModelConfig, *, return_cache: bool = False):
    B, S, d = x.shape
    zifo = x @ p["w_in"] + p["b"]  # (B, S, 4d)
    zifo_tm = jnp.swapaxes(zifo, 0, 1)
    zeros = jnp.zeros((B, d), jnp.float32)
    init = (zeros, zeros, zeros, jnp.full((B, d), -1e30, jnp.float32))

    def step(carry, zt):
        new = _slstm_step(p, cfg, carry, zt)
        return new, new[2]  # emit h

    if cfg.remat != "none":
        # save only the 4 (B,d) carries per step; gate intermediates recompute
        step = jax.checkpoint(step)
    carry, hs = jax.lax.scan(step, init, zifo_tm)
    h = jnp.swapaxes(hs, 0, 1).astype(x.dtype)
    hn = rms_norm(h, p["headnorm"], cfg.norm_eps)
    out = hint(hn @ p["wo"], "batch", "seq", None)
    if not return_cache:
        return out
    c, n, hh, m = carry
    return out, {"c": c, "n": n, "h": hh, "m": m}


def slstm_decode(p: dict, x: jax.Array, cache: dict, cfg: ModelConfig):
    B = x.shape[0]
    zifo = (x @ p["w_in"] + p["b"])[:, 0]  # (B, 4d)
    carry = (cache["c"], cache["n"], cache["h"], cache["m"])
    c, n, h, m = _slstm_step(p, cfg, carry, zifo)
    hn = rms_norm(h[:, None, :].astype(x.dtype), p["headnorm"], cfg.norm_eps)
    out = hn @ p["wo"]
    return out, {"c": c, "n": n, "h": h, "m": m}
