"""Parameter templates: single source of truth for shapes, init, and sharding.

Each model declares its parameters as a nested tree of ``TSpec`` leaves
(shape + logical sharding axes + init rule). From the same template we derive:

  * ``init_params``     — real arrays (deterministic per-path fold_in keys)
  * ``abstract_params`` — ShapeDtypeStructs (dry-run: no allocation)
  * ``param_axes``      — logical axis tree (-> NamedShardings via rules)
  * ``count_params``    — exact parameter count

Stacked (scanned) layers wrap a per-layer template with ``stack`` which
prepends the superblock-count dimension.
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass, replace
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "TSpec",
    "stack",
    "init_params",
    "abstract_params",
    "param_axes",
    "count_params",
    "tree_bytes",
]


@dataclass(frozen=True)
class TSpec:
    """One parameter leaf."""

    shape: tuple[int, ...]
    axes: tuple  # logical axis names (len == ndim), None = replicated
    init: str = "normal"  # "normal" | "zeros" | "ones" | "fan_in"
    std: float = 0.02
    dtype: str | None = None  # override model dtype

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} vs axes {self.axes}")


def stack(template: Any, n: int) -> Any:
    """Prepend a stacked-layer dim of size n to every leaf (scan over layers)."""

    def f(leaf: TSpec) -> TSpec:
        return replace(leaf, shape=(n, *leaf.shape), axes=(None, *leaf.axes))

    return jax.tree.map(f, template, is_leaf=lambda x: isinstance(x, TSpec))


def _is_tspec(x) -> bool:
    return isinstance(x, TSpec)


def _path_key(path) -> int:
    s = jax.tree_util.keystr(path)
    return int.from_bytes(hashlib.sha256(s.encode()).digest()[:4], "little")


def init_params(template: Any, key: jax.Array, dtype: jnp.dtype) -> Any:
    """Materialise arrays. Per-leaf keys are fold_in(key, hash(path)):
    deterministic, order-independent, stable across refactors."""

    def f(path, leaf: TSpec):
        d = jnp.dtype(leaf.dtype) if leaf.dtype else dtype
        k = jax.random.fold_in(key, _path_key(path))
        if leaf.init == "zeros":
            return jnp.zeros(leaf.shape, d)
        if leaf.init == "ones":
            return jnp.ones(leaf.shape, d)
        if leaf.init == "fan_in":
            fan_in = leaf.shape[-2] if len(leaf.shape) >= 2 else leaf.shape[-1]
            std = 1.0 / np.sqrt(fan_in)
            return (jax.random.normal(k, leaf.shape, jnp.float32) * std).astype(d)
        if leaf.init == "normal":
            return (jax.random.normal(k, leaf.shape, jnp.float32) * leaf.std).astype(d)
        raise ValueError(leaf.init)

    return jax.tree_util.tree_map_with_path(f, template, is_leaf=_is_tspec)


def abstract_params(template: Any, dtype: jnp.dtype) -> Any:
    def f(leaf: TSpec):
        d = jnp.dtype(leaf.dtype) if leaf.dtype else dtype
        return jax.ShapeDtypeStruct(leaf.shape, d)

    return jax.tree.map(f, template, is_leaf=_is_tspec)


def param_axes(template: Any) -> Any:
    return jax.tree.map(lambda l: tuple(l.axes), template, is_leaf=_is_tspec)


def is_axes_leaf(x) -> bool:
    """Leaf predicate for logical-axes trees: a tuple of axis names/None.

    Distinguishes axes tuples from structural tuples (e.g. the per-position
    superblock tuple, whose elements are dicts)."""
    return isinstance(x, tuple) and all(e is None or isinstance(e, str) for e in x)


def count_params(template: Any) -> int:
    leaves = jax.tree.leaves(template, is_leaf=_is_tspec)
    return int(sum(np.prod(l.shape) for l in leaves))


def tree_bytes(tree: Any) -> int:
    """Total bytes of a tree of arrays / ShapeDtypeStructs."""
    return int(
        sum(np.prod(x.shape) * jnp.dtype(x.dtype).itemsize for x in jax.tree.leaves(tree))
    )
