"""Target hardware constants (TPU v5e) for roofline analysis.

Values fixed by the assignment: 197 bf16 TFLOP/s per chip, 819 GB/s HBM
bandwidth, ~50 GB/s per ICI link. Aggregate collective bandwidth is modelled
as chips x link_bw (the assignment's "collective term" denominator).
"""

from __future__ import annotations

from dataclasses import dataclass

__all__ = ["HardwareSpec", "TPU_V5E", "DEVICE_TIER_V5E_1", "CLIENT_NPU"]


@dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_flops: float  # bf16 FLOP/s per chip
    hbm_bw: float  # bytes/s per chip
    ici_bw: float  # bytes/s per link
    hbm_bytes: float  # capacity per chip
    dcn_bw: float = 25e9  # bytes/s per host, cross-pod (pod axis)


TPU_V5E = HardwareSpec(
    name="tpu_v5e",
    peak_flops=197e12,
    hbm_bw=819e9,
    ici_bw=50e9,
    hbm_bytes=16 * 1024**3,
)

# Tiers for the paper's device/edge instantiation (DESIGN.md §5):
DEVICE_TIER_V5E_1 = TPU_V5E  # "device" = one v5e chip
CLIENT_NPU = HardwareSpec(  # a phone/laptop-class NPU for benchmarks
    name="client_npu",
    peak_flops=10e12,
    hbm_bw=100e9,
    ici_bw=0.0,
    hbm_bytes=8 * 1024**3,
)
