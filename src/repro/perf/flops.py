"""Analytic FLOP/byte models: MODEL_FLOPS (6ND-style) + recurrence supplements.

Used for (a) the MODEL_FLOPS / HLO_FLOPs "useful compute" ratio in §Roofline,
(b) supplements for work hidden inside non-unrollable while loops (mamba /
sLSTM time scans — XLA cost analysis counts their bodies once), and (c) the
paper-side service-time estimates (core.service_time.from_roofline).
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ModelConfig, ShapeSuite

__all__ = ["CellFlops", "cell_flops", "param_counts"]


@dataclass(frozen=True)
class CellFlops:
    model_flops: float  # canonical 6ND / 2ND (active params)
    attn_flops: float  # quadratic attention term (fwd, incl. bwd factor for train)
    recurrence_flops: float  # mamba/xLSTM scan supplements (hidden in while loops)
    total: float  # model + attn + recurrence
    n_params: int
    n_active: int
    note: str = ""


def param_counts(cfg: ModelConfig) -> tuple[int, int]:
    """(total params, active params) — active discounts non-routed experts."""
    from repro.models.lm import num_params

    total = num_params(cfg)
    if cfg.num_experts == 0:
        return total, total
    moe_layers = sum(1 for s in cfg.superblock if s.ffn in ("moe", "moe_dense"))
    moe_layers *= cfg.num_superblocks
    per_expert = 3 * cfg.d_model * cfg.d_ff  # wi, wg, wo
    inactive = moe_layers * per_expert * (cfg.num_experts - cfg.num_experts_per_tok)
    return total, total - inactive


def _matmul_params(cfg: ModelConfig, n: int) -> int:
    """Params participating in matmuls: drop the input-embedding gather,
    keep the logits matmul (tied models reuse the table there)."""
    emb = cfg.vocab_size * cfg.d_model
    if cfg.tie_embeddings:
        return n  # single table, used as the logits matmul
    return n - emb  # gather excluded; unembed already counted


def _attn_layer_flops(cfg: ModelConfig, B: int, S_q: int, S_kv: int, *, local: bool) -> float:
    """QK^T + PV for one layer, forward. Causal halves the full square;
    local layers touch min(S_kv, W) keys per query."""
    H, hd = cfg.num_heads, cfg.resolved_head_dim
    keys = min(S_kv, cfg.window_size) if local else S_kv
    causal_factor = 0.5 if (S_q == S_kv and not local) else 1.0
    return 2.0 * 2.0 * B * H * hd * S_q * keys * causal_factor


def _recurrence_flops(cfg: ModelConfig, B: int, S: int) -> float:
    """Per-token scan work hidden in while loops (fwd)."""
    total = 0.0
    per_sb = {m: sum(1 for s in cfg.superblock if s.mixer == m) for m in ("mamba", "mlstm", "slstm")}
    n = cfg.num_superblocks
    if per_sb["mamba"]:
        di, ds = cfg.mamba_d_inner, cfg.mamba_d_state
        total += per_sb["mamba"] * n * 6.0 * B * S * di * ds
    if per_sb["mlstm"]:
        H = cfg.num_heads
        hd = cfg.d_model // H
        Lc = 256
        total += per_sb["mlstm"] * n * B * S * H * (4.0 * min(Lc, S) * hd + 6.0 * hd * hd)
    if per_sb["slstm"]:
        hd = cfg.d_model // cfg.num_heads
        total += per_sb["slstm"] * n * 8.0 * B * S * cfg.d_model * hd
    return total


def cell_flops(cfg: ModelConfig, shape: ShapeSuite) -> CellFlops:
    B, S = shape.global_batch, shape.seq_len
    total, active = param_counts(cfg)
    n_mm = _matmul_params(cfg, active)

    attn_positions = [
        (spec.mixer == "attn_local") for spec in cfg.superblock if spec.mixer.startswith("attn")
    ]
    n_sb = cfg.num_superblocks

    if shape.kind == "train":
        tokens = B * S
        model = 6.0 * n_mm * tokens
        attn = 3.0 * sum(
            _attn_layer_flops(cfg, B, S, S, local=loc) for loc in attn_positions
        ) * n_sb
        if cfg.is_encdec:
            attn += 3.0 * cfg.encoder_layers * _attn_layer_flops(cfg, B, S, S, local=False)
            # cross attention: S_q x S_enc full
            attn += 3.0 * len(attn_positions) * n_sb * 2.0 * 2.0 * B * cfg.num_heads * cfg.resolved_head_dim * S * S
        rec = 3.0 * _recurrence_flops(cfg, B, S)
        if cfg.remat == "full":
            model *= 4.0 / 3.0  # extra forward for rematerialisation
            attn *= 4.0 / 3.0
            rec *= 4.0 / 3.0
        note = "train: 6ND(active, matmul params) x remat(4/3)"
    elif shape.kind == "prefill":
        tokens = B * S
        model = 2.0 * n_mm * tokens
        attn = sum(_attn_layer_flops(cfg, B, S, S, local=loc) for loc in attn_positions) * n_sb
        if cfg.is_encdec:
            attn += cfg.encoder_layers * _attn_layer_flops(cfg, B, S, S, local=False)
            attn += len(attn_positions) * n_sb * 2.0 * 2.0 * B * cfg.num_heads * cfg.resolved_head_dim * S * S
        rec = _recurrence_flops(cfg, B, S)
        note = "prefill: 2ND"
    else:  # decode: one token against an S-token cache
        model = 2.0 * n_mm * B
        attn = sum(
            _attn_layer_flops(cfg, B, 1, S, local=loc) for loc in attn_positions
        ) * n_sb
        if cfg.is_encdec:
            attn += len(attn_positions) * n_sb * 2.0 * 2.0 * B * cfg.num_heads * cfg.resolved_head_dim * 1 * S
        rec = _recurrence_flops(cfg, B, 1)
        note = "decode: 2N per token + KV-cache attention"

    return CellFlops(
        model_flops=model,
        attn_flops=attn,
        recurrence_flops=rec,
        total=model + attn + rec,
        n_params=total,
        n_active=active,
        note=note,
    )
