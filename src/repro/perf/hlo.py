"""HLO text analysis: collective-op inventory and byte counts.

``compiled.as_text()`` (post-SPMD-partitioning HLO) is scanned for
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute /
collective-broadcast ops. For each op we record operand bytes, output bytes,
and an estimated *wire* bytes-per-device figure using standard ring-algorithm
cost models:

    all-reduce        2 * (n-1)/n * operand   ~= 2 * operand
    all-gather        (n-1)/n * output        ~= output
    reduce-scatter    (n-1)/n * operand       ~= operand
    all-to-all        (n-1)/n * operand       ~= operand
    collective-permute  operand
    collective-broadcast operand

(n is unknown at parse time; we use the asymptotic factor, which is what the
assignment's "sum operand sizes" convention approximates.)

Caveat recorded in EXPERIMENTS.md: collectives inside while-loop bodies
appear once in the text; scanned-layer totals are therefore extrapolated from
unrolled 1-/2-superblock compiles (see repro.perf.roofline).
"""

from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

__all__ = ["CollectiveStats", "parse_collectives", "DTYPE_BYTES"]

DTYPE_BYTES = {
    "pred": 1,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e4m3b11fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
    "collective-broadcast",
    "ragged-all-to-all",
)

_WIRE_FACTOR = {
    "all-reduce": ("operand", 2.0),
    "all-gather": ("output", 1.0),
    "reduce-scatter": ("operand", 1.0),
    "all-to-all": ("operand", 1.0),
    "collective-permute": ("operand", 1.0),
    "collective-broadcast": ("operand", 1.0),
    "ragged-all-to-all": ("operand", 1.0),
}

# "%name = f32[8,16]{1,0} all-reduce(...)", also tuple outputs
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+"
    r"(" + "|".join(_COLLECTIVES) + r")(?:-start|-done)?\s*\("
)
_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * DTYPE_BYTES.get(dtype, 4)


def _all_shape_bytes(text: str) -> int:
    return sum(_shape_bytes(m.group(1), m.group(2)) for m in _SHAPE_RE.finditer(text))


@dataclass
class CollectiveStats:
    counts: dict[str, int] = field(default_factory=lambda: defaultdict(int))
    operand_bytes: dict[str, int] = field(default_factory=lambda: defaultdict(int))
    output_bytes: dict[str, int] = field(default_factory=lambda: defaultdict(int))

    @property
    def total_operand_bytes(self) -> int:
        return sum(self.operand_bytes.values())

    @property
    def total_output_bytes(self) -> int:
        return sum(self.output_bytes.values())

    @property
    def wire_bytes(self) -> float:
        """Estimated bytes over the wire per device (ring cost model)."""
        total = 0.0
        for kind in self.counts:
            src, factor = _WIRE_FACTOR[kind]
            b = self.operand_bytes[kind] if src == "operand" else self.output_bytes[kind]
            total += factor * b
        return total

    def summary(self) -> dict:
        return {
            "counts": dict(self.counts),
            "operand_bytes": dict(self.operand_bytes),
            "output_bytes": dict(self.output_bytes),
            "wire_bytes": self.wire_bytes,
        }

    def __add__(self, other: "CollectiveStats") -> "CollectiveStats":
        out = CollectiveStats()
        for s in (self, other):
            for k, v in s.counts.items():
                out.counts[k] += v
            for k, v in s.operand_bytes.items():
                out.operand_bytes[k] += v
            for k, v in s.output_bytes.items():
                out.output_bytes[k] += v
        return out

    def scaled(self, factor: float) -> "CollectiveStats":
        out = CollectiveStats()
        for k, v in self.counts.items():
            out.counts[k] = v
        for k, v in self.operand_bytes.items():
            out.operand_bytes[k] = int(v * factor)
        for k, v in self.output_bytes.items():
            out.output_bytes[k] = int(v * factor)
        return out


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Scan post-partitioning HLO for collective ops and sum their sizes.

    Async pairs (-start/-done) are counted once (on -start); -done lines
    repeat the shapes and are skipped.
    """
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        if "-done" in line and any(c in line for c in _COLLECTIVES):
            continue
        m = _OP_RE.search(line)
        if not m:
            continue
        out_shape_text, kind = m.group(1), m.group(2)
        # operands: everything inside the call parens
        call = line[m.end() :]
        depth, end = 1, 0
        for i, ch in enumerate(call):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operands_text = call[:end]
        stats.counts[kind] += 1
        stats.operand_bytes[kind] += _all_shape_bytes(operands_text)
        stats.output_bytes[kind] += _all_shape_bytes(out_shape_text)
    return stats
