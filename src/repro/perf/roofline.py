"""Three-term roofline analysis from compiled dry-run artifacts.

    compute term    = HLO_FLOPs   / (chips x peak FLOP/s)
    memory term     = HLO_bytes   / (chips x HBM bw)
    collective term = wire_bytes  / (chips x ICI link bw)

HLO_FLOPs / bytes come from ``compiled.cost_analysis()``. Two measured facts
shape the pipeline (verified in this container, recorded in EXPERIMENTS.md):

 1. cost_analysis counts while-loop bodies ONCE. Scanned-layer steps therefore
    under-report by ~num_superblocks x. Roofline numbers are taken from
    *unrolled* compiles at 1 and 2 superblocks and extrapolated linearly
    (exact for homogeneous stacks); encoder-decoder models add a third compile
    to separate the encoder slope.
 2. cost_analysis is per-device for SPMD modules; terms below use per-device
    numerator over per-chip denominator, identical to the assignment's
    global/(chips x rate) convention.

Non-unrollable while loops remain (mamba / sLSTM time scans): their FLOPs are
supplemented analytically (repro.perf.flops) and noted per cell.
"""

from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field

from .flops import CellFlops, cell_flops
from .hardware import TPU_V5E, HardwareSpec
from .hlo import CollectiveStats

__all__ = ["RooflineReport", "combine_linear", "report_from_counts"]


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_chips: int
    # per-device counts (HLO)
    hlo_flops_per_dev: float
    hlo_bytes_per_dev: float
    wire_bytes_per_dev: float
    # supplements
    supplement_flops_per_dev: float
    # terms (seconds)
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    # usefulness
    model_flops_global: float
    useful_ratio: float  # MODEL_FLOPS / (HLO_FLOPs x chips)
    collective_counts: dict = field(default_factory=dict)
    memory_analysis: dict = field(default_factory=dict)
    notes: str = ""

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """useful-compute-time / bound-time: how close the step is to the
        compute roofline for its *useful* FLOPs."""
        if self.bound_s <= 0:
            return 0.0
        useful_s = self.model_flops_global / (self.n_chips * TPU_V5E.peak_flops)
        return useful_s / self.bound_s

    def to_json(self) -> str:
        d = asdict(self)
        d["bound_s"] = self.bound_s
        d["roofline_fraction"] = self.roofline_fraction
        return json.dumps(d, indent=2, default=float)


def combine_linear(samples: dict[tuple[int, ...], dict], full: tuple[int, ...]) -> dict:
    """Linear extrapolation over scan-group counts.

    samples: {(1,1): costs, (2,1): costs, (1,2): costs} (second group optional)
    full: e.g. (num_superblocks, encoder_layers). costs are flat dicts of
    numbers. total = base + sum_i (full_i - 1) * slope_i.
    """
    base_key = tuple(1 for _ in full)
    base = samples[base_key]
    out = dict(base)
    for i, n in enumerate(full):
        probe = tuple(2 if j == i else 1 for j in range(len(full)))
        if probe not in samples:
            if n != 1:
                raise KeyError(f"missing probe {probe} for group {i}")
            continue
        slope = {k: samples[probe][k] - base[k] for k in base}
        for k in out:
            out[k] = out[k] + (n - 1) * slope[k]
    return out


def report_from_counts(
    *,
    arch: str,
    shape,
    mesh_name: str,
    n_chips: int,
    flops_per_dev: float,
    bytes_per_dev: float,
    collectives: CollectiveStats | dict,
    cfg=None,
    supplement_flops_global: float = 0.0,
    memory_analysis: dict | None = None,
    hw: HardwareSpec = TPU_V5E,
    notes: str = "",
) -> RooflineReport:
    wire = collectives.wire_bytes if isinstance(collectives, CollectiveStats) else collectives.get("wire_bytes", 0.0)
    counts = collectives.summary()["counts"] if isinstance(collectives, CollectiveStats) else collectives.get("counts", {})
    supp_dev = supplement_flops_global / n_chips
    compute_s = (flops_per_dev + supp_dev) / hw.peak_flops
    memory_s = bytes_per_dev / hw.hbm_bw
    collective_s = wire / hw.ici_bw
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)

    cf: CellFlops | None = cell_flops(cfg, shape) if cfg is not None else None
    model_flops = cf.total if cf else 0.0
    hlo_global = (flops_per_dev + supp_dev) * n_chips
    return RooflineReport(
        arch=arch,
        shape=shape.name if hasattr(shape, "name") else str(shape),
        mesh=mesh_name,
        n_chips=n_chips,
        hlo_flops_per_dev=flops_per_dev,
        hlo_bytes_per_dev=bytes_per_dev,
        wire_bytes_per_dev=wire,
        supplement_flops_per_dev=supp_dev,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        model_flops_global=model_flops,
        useful_ratio=(model_flops / hlo_global) if hlo_global else 0.0,
        collective_counts=dict(counts),
        memory_analysis=memory_analysis or {},
        notes=notes,
    )
