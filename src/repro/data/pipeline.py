"""Deterministic synthetic data pipeline.

Produces next-token-prediction batches (tokens / targets / loss_mask, plus
stub prefix/encoder embeddings for the VLM/audio architectures) from a
seeded, *stateless* sequence generator: batch ``i`` is a pure function of
(seed, i), so a restarted job resumes data exactly where the checkpoint left
off by storing only the step counter — no iterator state to snapshot.

The token stream is a mixture of Zipfian unigrams and short repeated motifs,
giving the model non-trivial structure to fit (smoke-train losses drop well
below the uniform entropy floor).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

__all__ = ["DataConfig", "SyntheticLM", "make_batch"]


@dataclass(frozen=True)
class DataConfig:
    batch: int
    seq_len: int
    seed: int = 0
    zipf_a: float = 1.2
    motif_len: int = 8
    motif_prob: float = 0.5


class SyntheticLM:
    """Stateless batch source: __getitem__(step) -> batch dict."""

    def __init__(self, cfg: ModelConfig, data: DataConfig):
        self.cfg = cfg
        self.data = data
        v = cfg.vocab_size
        rng = np.random.default_rng(data.seed)
        # fixed Zipf unigram table + motif bank (generation-time constants)
        ranks = np.arange(1, v + 1, dtype=np.float64)
        p = ranks ** (-data.zipf_a)
        self._probs = jnp.asarray(p / p.sum(), jnp.float32)
        self._motifs = jnp.asarray(
            rng.integers(0, v, size=(64, data.motif_len)), jnp.int32
        )

    def _tokens(self, key: jax.Array, batch: int, seq: int) -> jax.Array:
        ku, km, kw = jax.random.split(key, 3)
        uni = jax.random.choice(
            ku, self.cfg.vocab_size, shape=(batch, seq), p=self._probs
        )
        # overlay repeated motifs: position t copies motif[t % M] with prob q
        midx = jax.random.randint(km, (batch,), 0, self._motifs.shape[0])
        motif = self._motifs[midx]  # (batch, M)
        tiled = jnp.tile(motif, (1, seq // self.data.motif_len + 1))[:, :seq]
        use = jax.random.bernoulli(kw, self.data.motif_prob, (batch, 1))
        return jnp.where(use, tiled, uni).astype(jnp.int32)

    def __getitem__(self, step: int) -> dict:
        cfg, d = self.cfg, self.data
        key = jax.random.fold_in(jax.random.PRNGKey(d.seed), step)
        S = d.seq_len
        P = 0
        batch: dict = {}
        if cfg.is_encdec:
            ke, kt = jax.random.split(key)
            batch["enc_embeds"] = (
                jax.random.normal(ke, (d.batch, S, cfg.d_model)) * 0.02
            ).astype(jnp.dtype(cfg.dtype))
            key = kt
        elif cfg.prefix_embed:
            P = int(S * cfg.prefix_len_fraction)
            ke, kt = jax.random.split(key)
            batch["prefix_embeds"] = (
                jax.random.normal(ke, (d.batch, P, cfg.d_model)) * 0.02
            ).astype(jnp.dtype(cfg.dtype))
            key = kt
        text = S - P
        tokens = self._tokens(key, d.batch, text)
        batch["tokens"] = tokens
        batch["targets"] = jnp.roll(tokens, -1, axis=1)
        mask = jnp.ones((d.batch, text), jnp.float32).at[:, -1].set(0.0)
        batch["loss_mask"] = mask
        return batch


def make_batch(cfg: ModelConfig, batch: int, seq: int, step: int = 0, seed: int = 0) -> dict:
    """One-shot convenience for tests/examples."""
    return SyntheticLM(cfg, DataConfig(batch=batch, seq_len=seq, seed=seed))[step]
