"""DeepSeek-7B [arXiv:2401.02954; hf]. Llama-architecture dense decoder (MHA)."""
from .base import LayerSpec, ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek_7b",
    family="dense",
    d_model=4096, num_heads=32, num_kv_heads=32, head_dim=128,
    d_ff=11008, vocab_size=102400,
    superblock=(LayerSpec("attn", "mlp"),), num_superblocks=30,
    rope=True,
    service_model="mm1",
    supports_long_context=False,
    notes="30L MHA (kv=32); llama-style SwiGLU MLP.",
))
