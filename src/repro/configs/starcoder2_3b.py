"""StarCoder2-3B [arXiv:2402.19173; hf]. Dense GQA decoder, RoPE."""
from .base import LayerSpec, ModelConfig, register

CONFIG = register(ModelConfig(
    name="starcoder2_3b",
    family="dense",
    d_model=3072, num_heads=24, num_kv_heads=2, head_dim=128,
    d_ff=12288, vocab_size=49152,
    superblock=(LayerSpec("attn", "mlp"),), num_superblocks=30,
    rope=True,
    gated_mlp=False, mlp_act="gelu",
    service_model="mm1",
    supports_long_context=False,
    notes="30L GQA kv=2; full causal attention.",
))
