"""SeamlessM4T-large-v2 backbone [arXiv:2308.11596; hf]. Enc-dec transformer.

The modality frontend (speech feature extractor) is a STUB per the assignment:
input_specs() feeds precomputed frame embeddings of shape (B, S, d_model) to
the encoder; the decoder consumes token ids. 24 encoder + 24 decoder layers.
"""
from .base import LayerSpec, ModelConfig, register

CONFIG = register(ModelConfig(
    name="seamless_m4t_large_v2",
    family="encdec",
    d_model=1024, num_heads=16, num_kv_heads=16, head_dim=64,
    d_ff=8192, vocab_size=256206,
    superblock=(LayerSpec("attn", "mlp"),), num_superblocks=24,  # decoder
    encoder_layers=24,
    prefix_embed=True,  # encoder takes precomputed frame embeddings
    rope=True,
    service_model="mm1",
    supports_long_context=False,
    notes="enc-dec; encoder bidirectional over stubbed audio-frame embeddings.",
))
