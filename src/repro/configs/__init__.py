from .base import (
    ARCH_IDS,
    SHAPES,
    LayerSpec,
    ModelConfig,
    ShapeSuite,
    get_config,
    list_configs,
    register,
    shape_cells,
)

__all__ = [
    "ARCH_IDS", "SHAPES", "LayerSpec", "ModelConfig", "ShapeSuite",
    "get_config", "list_configs", "register", "shape_cells",
]
