"""xLSTM-1.3B [arXiv:2405.04517; unverified]. sLSTM + mLSTM recurrent blocks.

48 blocks as 6 x (1 sLSTM + 7 mLSTM) following the paper's a:b block-ratio
notation; blocks carry their own up/down projections (d_ff=0 -> no separate
FFN). O(1) recurrent state -> long_500k runs.
"""
from .base import LayerSpec, ModelConfig, register

CONFIG = register(ModelConfig(
    name="xlstm_1_3b",
    family="ssm",
    d_model=2048, num_heads=4, num_kv_heads=4, head_dim=512,
    d_ff=0, vocab_size=50304,
    superblock=(
        LayerSpec("slstm", "none"),
        LayerSpec("mlstm", "none"), LayerSpec("mlstm", "none"),
        LayerSpec("mlstm", "none"), LayerSpec("mlstm", "none"),
        LayerSpec("mlstm", "none"), LayerSpec("mlstm", "none"),
        LayerSpec("mlstm", "none"),
    ),
    num_superblocks=6,
    rope=False,
    grad_accum=2,
    service_model="mm1",  # length-dependent recurrence: the paper's RNN case
    supports_long_context=True,
    notes="48 blocks = 6 x (sLSTM + 7 mLSTM); constant-size recurrent state.",
))
