"""Model configuration system.

Every assigned architecture is expressed as a ``ModelConfig`` built from a
*superblock* pattern: the smallest repeating run of layers (1 for homogeneous
stacks, 2 for gemma2's local/global alternation, 8 for jamba's mamba/attn
interleave). The model stack is ``num_superblocks`` repetitions, scanned with
``jax.lax.scan`` so HLO size and compile time are O(superblock), not O(depth).

Layer kinds:
  "attn"        full-causal (or bidirectional for encoders) GQA attention
  "attn_local"  sliding-window causal attention (gemma2)
  "mamba"       selective SSM (S6) token mixer
  "mlstm"       xLSTM matrix-memory cell
  "slstm"       xLSTM scalar-memory cell (recurrent gates)
Mixer is followed by "mlp", "moe", or nothing ("none", for xLSTM blocks that
have no separate FFN).
"""

from __future__ import annotations

import enum
import importlib
from dataclasses import dataclass, field, replace
from typing import Sequence

__all__ = [
    "LayerSpec",
    "ModelConfig",
    "ShapeSuite",
    "SHAPES",
    "register",
    "get_config",
    "list_configs",
    "ARCH_IDS",
]


@dataclass(frozen=True)
class LayerSpec:
    """One layer inside the superblock pattern."""

    mixer: str  # "attn" | "attn_local" | "mamba" | "mlstm" | "slstm"
    ffn: str = "mlp"  # "mlp" | "moe" | "moe_dense" (moe + parallel dense residual) | "none"


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm (doc only)
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    superblock: tuple[LayerSpec, ...]
    num_superblocks: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    # --- attention ---
    rope: bool = True
    rope_theta: float = 10_000.0
    window_size: int = 4096  # for attn_local
    attn_softcap: float = 0.0  # gemma2: 50.0 (0 disables)
    final_softcap: float = 0.0  # gemma2: 30.0
    # --- mlp ---
    gated_mlp: bool = True  # SwiGLU/GeGLU (3 mats) vs plain MLP (2 mats)
    mlp_act: str = "silu"  # "silu" | "gelu"
    # --- moe ---
    num_experts: int = 0
    num_experts_per_tok: int = 0
    capacity_factor: float = 1.25
    moe_group_size: int = 2048  # tokens per dispatch group (GShard G x S split)
    # --- ssm (mamba) ---
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    # --- encoder-decoder (seamless) ---
    encoder_layers: int = 0  # 0 -> decoder-only
    # --- modality frontend stub (vlm / audio) ---
    prefix_embed: bool = False  # model accepts precomputed prefix embeddings
    prefix_len_fraction: float = 0.0  # fraction of seq carried by the stub prefix
    # --- numerics / execution ---
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    remat: str = "full"  # "none" | "full" | "dots"
    scan_layers: bool = True  # False unrolls superblocks (roofline accounting)
    seq_chunk: int = 512  # query-chunk for the XLA flash-style attention
    unroll_attn_chunks: bool = False  # True for roofline-accounting compiles
    attn_impl: str = "xla"  # "xla" | "pallas" (TPU)
    seq_parallel: str = "auto"  # "auto" | "on" | "off" (Megatron-SP residual)
    optimizer: str = "adamw"  # "adamw" | "adafactor" (480B-class memory)
    grad_accum: int = 1  # microbatches per step (activation memory lever)
    grad_dtype: str = "float32"  # gradient accumulation dtype
    # --- paper linkage ---
    service_model: str = "md1"  # queueing formulation (md1 dense | mm1 variable)
    # --- shape policy ---
    supports_long_context: bool = False  # run long_500k?
    notes: str = ""

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 256 so the logits/embedding
        dims shard cleanly over any mesh axis (MaxText-style padding;
        151655 and 256206 are not divisible by 16)."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def num_layers(self) -> int:
        return len(self.superblock) * self.num_superblocks

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.resolved_head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.resolved_head_dim

    @property
    def mamba_d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    def has_mixer(self, kind: str) -> bool:
        return any(l.mixer == kind for l in self.superblock)

    @property
    def attn_layers(self) -> int:
        per = sum(1 for l in self.superblock if l.mixer.startswith("attn"))
        total = per * self.num_superblocks
        if self.is_encdec:
            total += self.encoder_layers  # encoder is all attention
        return total

    def reduced(self, **overrides) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        small = dict(
            d_model=64,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) or 2,
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab_size=257,
            num_superblocks=min(2, self.num_superblocks),
            num_experts=4 if self.num_experts else 0,
            num_experts_per_tok=min(self.num_experts_per_tok, 2),
            # untrained tiny routers are heavily skewed; give smoke tests
            # enough capacity that GShard dropping never fires
            capacity_factor=8.0,
            moe_group_size=32,
            window_size=8,
            mamba_d_state=4,
            mamba_d_conv=4,
            encoder_layers=2 if self.encoder_layers else 0,
            seq_chunk=16,
            grad_accum=1,
            grad_dtype="float32",
            remat="none",
            dtype="float32",
            name=self.name + "-smoke",
        )
        small.update(overrides)
        return replace(self, **small)


@dataclass(frozen=True)
class ShapeSuite:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeSuite] = {
    "train_4k": ShapeSuite("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSuite("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSuite("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSuite("long_500k", 524_288, 1, "decode"),
}

ARCH_IDS = [
    "starcoder2_15b",
    "gemma2_9b",
    "starcoder2_3b",
    "deepseek_7b",
    "seamless_m4t_large_v2",
    "internvl2_1b",
    "arctic_480b",
    "dbrx_132b",
    "xlstm_1_3b",
    "jamba_v0_1_52b",
]

_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    key = cfg.name.replace("-", "_").replace(".", "_")
    _REGISTRY[key] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    key = name.replace("-", "_").replace(".", "_")
    if key not in _REGISTRY:
        if key in ARCH_IDS:
            importlib.import_module(f"repro.configs.{key}")
        else:
            # try importing anyway (user-supplied config module)
            importlib.import_module(f"repro.configs.{key}")
    return _REGISTRY[key]


def list_configs() -> list[str]:
    for arch in ARCH_IDS:
        try:
            importlib.import_module(f"repro.configs.{arch}")
        except ImportError:
            pass
    return sorted(_REGISTRY)


def shape_cells(cfg: ModelConfig) -> list[ShapeSuite]:
    """The shape cells this arch runs (long_500k only for sub-quadratic archs)."""
    cells = [SHAPES["train_4k"], SHAPES["prefill_32k"], SHAPES["decode_32k"]]
    if cfg.supports_long_context:
        cells.append(SHAPES["long_500k"])
    return cells
