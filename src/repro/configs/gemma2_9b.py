"""Gemma2-9B [arXiv:2408.00118; hf]. Local/global alternating attention + softcaps."""
from .base import LayerSpec, ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma2_9b",
    family="dense",
    d_model=3584, num_heads=16, num_kv_heads=8, head_dim=256,
    d_ff=14336, vocab_size=256000,
    # 42 layers = 21 x (local, global)
    superblock=(LayerSpec("attn_local", "mlp"), LayerSpec("attn", "mlp")),
    num_superblocks=21,
    rope=True, window_size=4096,
    mlp_act="gelu",
    attn_softcap=50.0, final_softcap=30.0,
    tie_embeddings=True,
    grad_accum=2,
    service_model="mm1",
    # half the stack is window-4096; global layers keep full KV (DESIGN.md S4)
    supports_long_context=True,
    notes="42L alternating local(4096)/global attention; attn softcap 50, final 30.",
))
