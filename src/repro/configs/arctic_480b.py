"""Snowflake Arctic 480B [hf:Snowflake/snowflake-arctic-base]. Dense-MoE hybrid.

128 experts top-2 with a parallel dense residual MLP on every layer
("moe_dense" ffn kind).
"""
from .base import LayerSpec, ModelConfig, register

CONFIG = register(ModelConfig(
    name="arctic_480b",
    family="moe",
    d_model=7168, num_heads=56, num_kv_heads=8, head_dim=128,
    d_ff=4864, vocab_size=32000,
    superblock=(LayerSpec("attn", "moe_dense"),), num_superblocks=35,
    num_experts=128, num_experts_per_tok=2, capacity_factor=1.25,
    rope=True,
    optimizer="adafactor",  # fp32 AdamW state (5.6 TB) exceeds pod HBM (4 TB)
    grad_accum=4, grad_dtype="bfloat16",  # fp32 grad buffer alone is 7.3 GiB/chip
    service_model="mm1",
    supports_long_context=False,
    notes="35L; MoE-128 top-2 + dense residual MLP in parallel per layer.",
))
