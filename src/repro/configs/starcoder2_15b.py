"""StarCoder2-15B [arXiv:2402.19173; hf]. Dense GQA decoder, RoPE."""
from .base import LayerSpec, ModelConfig, register

CONFIG = register(ModelConfig(
    name="starcoder2_15b",
    family="dense",
    d_model=6144, num_heads=48, num_kv_heads=4, head_dim=128,
    d_ff=24576, vocab_size=49152,
    superblock=(LayerSpec("attn", "mlp"),), num_superblocks=40,
    rope=True,
    grad_accum=2,
    gated_mlp=False, mlp_act="gelu",
    service_model="mm1",  # autoregressive LLM -> Lemma 3.3 formulation
    supports_long_context=False,  # pure full attention -> long_500k skipped
    notes="40L GQA kv=4; full causal attention.",
))
