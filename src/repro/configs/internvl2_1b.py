"""InternVL2-1B backbone [arXiv:2404.16821; hf]. InternLM2 decoder; ViT stub.

The InternViT frontend is a STUB: input_specs() provides precomputed patch
embeddings (B, prefix, d_model) prepended to the token sequence.
"""
from .base import LayerSpec, ModelConfig, register

CONFIG = register(ModelConfig(
    name="internvl2_1b",
    family="vlm",
    d_model=896, num_heads=14, num_kv_heads=2, head_dim=64,
    d_ff=4864, vocab_size=151655,
    superblock=(LayerSpec("attn", "mlp"),), num_superblocks=24,
    prefix_embed=True, prefix_len_fraction=1.0 / 16.0,
    rope=True,
    service_model="mm1",
    supports_long_context=False,
    notes="24L GQA kv=2; 1/16 of seq is stubbed patch-embedding prefix.",
))
