"""DBRX 132B [hf:databricks/dbrx-base; unverified]. Fine-grained MoE 16e top-4."""
from .base import LayerSpec, ModelConfig, register

CONFIG = register(ModelConfig(
    name="dbrx_132b",
    family="moe",
    d_model=6144, num_heads=48, num_kv_heads=8, head_dim=128,
    d_ff=10752, vocab_size=100352,
    superblock=(LayerSpec("attn", "moe"),), num_superblocks=40,
    num_experts=16, num_experts_per_tok=4, capacity_factor=1.25,
    moe_group_size=1024,  # 16e x top-4 makes E*C fat; smaller groups bound the dispatch tensor
    rope=True,
    optimizer="adafactor", grad_accum=4,
    service_model="mm1",
    supports_long_context=False,
    notes="40L; MoE-16 top-4.",
))
