"""Jamba-v0.1 52B [arXiv:2403.19887; hf]. Mamba+attention 1:7 interleave + MoE.

Period-8 superblock with attention at index 4 and MoE on odd layers (16
experts top-2), matching the published Jamba block layout. Attention layers
use no positional embedding (NoPE) as in the paper. KV state exists only on
the 4 attention layers -> long_500k runs.
"""
from .base import LayerSpec, ModelConfig, register

CONFIG = register(ModelConfig(
    name="jamba_v0_1_52b",
    family="hybrid",
    d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=65536,
    superblock=(
        LayerSpec("mamba", "mlp"), LayerSpec("mamba", "moe"),
        LayerSpec("mamba", "mlp"), LayerSpec("mamba", "moe"),
        LayerSpec("attn", "mlp"), LayerSpec("mamba", "moe"),
        LayerSpec("mamba", "mlp"), LayerSpec("mamba", "moe"),
    ),
    num_superblocks=4,
    num_experts=16, num_experts_per_tok=2, capacity_factor=1.25,
    rope=False,  # Jamba uses NoPE on its attention layers
    mamba_d_state=16, mamba_d_conv=4, mamba_expand=2,
    grad_accum=8,  # measured: temp 18.7 GiB at accum 4 -> 13.6 at 8 (fits 16 GiB HBM)
    service_model="mm1",
    supports_long_context=True,
    notes="32L = 4 x 8(1 attn : 7 mamba, MoE every other layer).",
))
