"""repro.obs — observability: tracing, decision audits, metrics, provenance.

  * :mod:`repro.obs.tracer` — deterministic span tracer (JSONL + Perfetto)
  * :mod:`repro.obs.audit` — explainable decision audits with the term
    re-sum invariant
  * :mod:`repro.obs.metrics` — counters / gauges / streaming histograms
  * :mod:`repro.obs.manifest` — timestamp-free run provenance manifests
  * :mod:`repro.obs.report` — markdown/terminal rendering of all of the above
"""

from .audit import AuditLog, DecisionAudit, ResumError, audit_cluster
from .manifest import manifest_delta, run_manifest
from .metrics import Counter, Gauge, Histogram, MetricsRegistry
from .report import explain_flip, format_decision, render_report
from .tracer import Span, Tracer, merge

__all__ = [
    "AuditLog",
    "DecisionAudit",
    "ResumError",
    "audit_cluster",
    "run_manifest",
    "manifest_delta",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "Span",
    "Tracer",
    "merge",
    "format_decision",
    "explain_flip",
    "render_report",
]
