"""Explainable decisions: the full closed-form decomposition behind each one.

Every ``AdaptiveOffloadManager.decide`` (and therefore every gateway /
replay / cluster decision) can record a :class:`DecisionAudit`: the
per-strategy latency totals the argmin ranked, the per-term decomposition of
each strategy's mean latency (the same terms ``Scenario.analytic()`` reports,
same keys, same summation order), the telemetry snapshot the terms were
computed from, the margin over the best alternative, and the hysteresis
state. The core invariant — checked by :meth:`AuditLog.verify` and gated in
CI — is that the logged terms re-sum to the logged totals to <= 1e-9, so an
audit row can never tell a story the decision didn't follow.

In SLO-quantile mode the decision totals are q-quantiles, which do not
decompose as sums; the audit then carries the *mean* decomposition alongside
(``term_totals``), and the invariant binds terms to ``term_totals`` while
``decision_metric`` says what the totals actually are.

The manager talks to :class:`AuditLog` duck-typed through ``record(**row)``
(core must not import obs), so any object with that method — including a
plain test double — can sit in the audit seat.
"""

from __future__ import annotations

import json
import math
from dataclasses import dataclass, field
from pathlib import Path
from typing import Iterator, Mapping

__all__ = [
    "DEVICE_TERMS",
    "EDGE_TERMS",
    "DecisionAudit",
    "AuditLog",
    "ResumError",
    "audit_cluster",
]

# exactly repro.core.latency.LatencyBreakdown's keys, in its summation order
DEVICE_TERMS = ("w_proc_dev", "s_dev")
EDGE_TERMS = ("w_net_dev", "n_req", "w_proc_edge", "s_edge", "w_net_edge", "n_res")


class ResumError(AssertionError):
    """A logged term decomposition does not re-sum to its logged total."""


def _ordered_sum(terms: Mapping[str, float]) -> float:
    keys = DEVICE_TERMS if "w_proc_dev" in terms else EDGE_TERMS
    total = 0.0
    for k in keys:
        total += terms[k]
    return total


def _enc(v):
    """JSON-safe float encoding (inf/nan as strings, canonically)."""
    if isinstance(v, float) and not math.isfinite(v):
        return repr(v)  # "inf" | "-inf" | "nan"
    return v


def _enc_map(d: Mapping) -> dict:
    return {k: _enc_map(v) if isinstance(v, Mapping) else _enc(v)
            for k, v in d.items()}


def _dec(v):
    return float(v) if v in ("inf", "-inf", "nan") else v


def _dec_map(d: dict) -> dict:
    return {k: _dec_map(v) if isinstance(v, dict) else _dec(v)
            for k, v in d.items()}


@dataclass(frozen=True)
class DecisionAudit:
    """One decision, fully explained."""

    epoch: int
    time_s: float
    source: str  # "manager" | "gateway" | "replay" | "cluster[i]" | ...
    chosen: str  # target_name: "on_device" | "edge[j]"
    edge_index: int  # ON_DEVICE (-1) or edge index
    predicted_latency_s: float
    decision_metric: str  # "mean" | "p99" | ... (what `totals` measures)
    totals: dict[str, float]  # strategy -> the latency the argmin ranked
    terms: dict[str, dict[str, float]]  # strategy -> mean decomposition
    term_totals: dict[str, float]  # strategy -> ordered sum of its terms
    snapshot: dict  # the estimator outputs the terms were computed from
    margin_s: float  # best alternative minus chosen (negative under hysteresis)
    hysteresis: dict = field(default_factory=dict)
    slo_quantile: float | None = None

    def max_resum_error(self) -> float:
        """max |sum(terms) - term_totals| over strategies, plus
        |term_totals - totals| in mean mode (saturated inf == inf is exact)."""
        worst = 0.0

        def gap(a: float, b: float) -> float:
            if math.isinf(a) or math.isinf(b):
                return 0.0 if a == b else math.inf
            return abs(a - b)

        for strat, t in self.terms.items():
            worst = max(worst, gap(_ordered_sum(t), self.term_totals[strat]))
            if self.decision_metric == "mean":
                worst = max(worst, gap(self.term_totals[strat], self.totals[strat]))
        return worst

    def to_dict(self) -> dict:
        return {
            "epoch": self.epoch,
            "time_s": self.time_s,
            "source": self.source,
            "chosen": self.chosen,
            "edge_index": self.edge_index,
            "predicted_latency_s": _enc(self.predicted_latency_s),
            "decision_metric": self.decision_metric,
            "totals": _enc_map(self.totals),
            "terms": _enc_map(self.terms),
            "term_totals": _enc_map(self.term_totals),
            "snapshot": _enc_map(self.snapshot),
            "margin_s": _enc(self.margin_s),
            "hysteresis": _enc_map(self.hysteresis),
            "slo_quantile": self.slo_quantile,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "DecisionAudit":
        return cls(
            epoch=int(d["epoch"]),
            time_s=float(d["time_s"]),
            source=str(d["source"]),
            chosen=str(d["chosen"]),
            edge_index=int(d["edge_index"]),
            predicted_latency_s=float(_dec(d["predicted_latency_s"])),
            decision_metric=str(d["decision_metric"]),
            totals=_dec_map(d["totals"]),
            terms=_dec_map(d["terms"]),
            term_totals=_dec_map(d["term_totals"]),
            snapshot=_dec_map(d.get("snapshot", {})),
            margin_s=float(_dec(d["margin_s"])),
            hysteresis=_dec_map(d.get("hysteresis", {})),
            slo_quantile=d.get("slo_quantile"),
        )


class AuditLog:
    """An append-only sequence of :class:`DecisionAudit` rows."""

    def __init__(self):
        self.rows: list[DecisionAudit] = []

    def __len__(self) -> int:
        return len(self.rows)

    def __iter__(self) -> Iterator[DecisionAudit]:
        return iter(self.rows)

    def record(self, **row) -> DecisionAudit:
        a = DecisionAudit(**row)
        self.rows.append(a)
        return a

    def clear(self) -> None:
        self.rows.clear()

    # -- the invariant -------------------------------------------------------
    def max_resum_error(self) -> float:
        return max((a.max_resum_error() for a in self.rows), default=0.0)

    def verify(self, tol: float = 1e-9) -> float:
        """Raise :class:`ResumError` if any row's terms fail to re-sum to its
        totals within ``tol``; returns the worst observed error."""
        worst = 0.0
        for i, a in enumerate(self.rows):
            err = a.max_resum_error()
            if err > tol:
                raise ResumError(
                    f"audit row {i} (source={a.source!r} epoch={a.epoch}): "
                    f"terms re-sum error {err:.3e} > {tol:.0e}")
            worst = max(worst, err)
        return worst

    # -- flips (the report CLI's headline) -----------------------------------
    def flips(self) -> list[tuple[DecisionAudit, DecisionAudit]]:
        """(before, after) pairs where consecutive same-source rows changed
        target — the decisions worth explaining."""
        by_source: dict[str, DecisionAudit] = {}
        out = []
        for a in self.rows:
            prev = by_source.get(a.source)
            if prev is not None and prev.edge_index != a.edge_index:
                out.append((prev, a))
            by_source[a.source] = a
        return out

    # -- serialization -------------------------------------------------------
    def to_jsonl(self) -> str:
        return "".join(
            json.dumps(a.to_dict(), sort_keys=True, separators=(",", ":")) + "\n"
            for a in self.rows
        )

    def write_jsonl(self, path: str | Path) -> Path:
        path = Path(path)
        path.write_text(self.to_jsonl())
        return path

    @classmethod
    def from_jsonl(cls, text: str) -> "AuditLog":
        log = cls()
        log.rows = [DecisionAudit.from_dict(json.loads(line))
                    for line in text.splitlines() if line.strip()]
        return log

    @classmethod
    def read_jsonl(cls, path: str | Path) -> "AuditLog":
        return cls.from_jsonl(Path(path).read_text())


def audit_cluster(result, *, epochs=None, clients=None) -> AuditLog:
    """Reconstruct per-client decision audits from a closed-loop cluster run.

    Re-evaluates the vectorized Algorithm-1 terms from the *estimates the scan
    actually acted on* (``ClusterResult.est_*``), so the audited totals are
    the very numbers ``predict_decisions`` returns on those estimates, and the
    chosen targets are the scan's own. N*T rows get large fast — ``epochs`` /
    ``clients`` subset (sequences of indices) before reconstructing.
    """
    import numpy as np

    from repro.fleet.cluster import predict_terms

    choices = result.policies["adaptive"].choices
    t_n, n = choices.shape
    epochs = range(t_n) if epochs is None else epochs
    clients = range(n) if clients is None else clients
    clients = list(clients)
    dt = float(result.traces.epoch_s)
    log = AuditLog()
    for t in epochs:
        terms = predict_terms(
            result.spec,
            result.est_arrival_rate[t],
            result.est_bandwidth_Bps[t],
            result.est_endo_rate[t],
            result.est_exo_rate[t],
        )
        for i in clients:
            strat_terms = {"on_device": {
                "w_proc_dev": float(terms["w_proc_dev"][i]),
                "s_dev": float(terms["s_dev"][i]),
            }}
            totals = {"on_device": float(terms["t_dev"][i])}
            for j in range(result.spec.n_edges):
                strat_terms[f"edge[{j}]"] = {
                    k: float(terms[k][i, j]) for k in EDGE_TERMS}
                totals[f"edge[{j}]"] = float(terms["t_edge"][i, j])
            choice = int(choices[t, i])
            chosen = "on_device" if choice < 0 else f"edge[{choice}]"
            predicted = totals[chosen]
            alts = [v for k, v in totals.items() if k != chosen]
            margin = (min(alts) - predicted) if alts else math.inf
            log.record(
                epoch=t,
                time_s=t * dt,
                source=f"cluster[{i}]",
                chosen=chosen,
                edge_index=choice,
                predicted_latency_s=predicted,
                decision_metric="mean",
                totals=totals,
                terms=strat_terms,
                term_totals={s: _ordered_sum(v) for s, v in strat_terms.items()},
                snapshot={
                    "lam_dev": float(result.est_arrival_rate[t, i]),
                    "bandwidth_Bps": float(result.est_bandwidth_Bps[t, i]),
                    "endo_rate": [float(x) for x in
                                  np.asarray(result.est_endo_rate[t, i])],
                    "exo_rate": [float(x) for x in
                                 np.asarray(result.est_exo_rate[t])],
                },
                margin_s=margin,
            )
    return log
