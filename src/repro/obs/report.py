"""Render traces + decision audits into human-readable reports.

Two consumers:

  * ``repro.launch.obs_report`` — the CLI that turns a trace JSONL + audit
    JSONL into a markdown/terminal report;
  * ``launch/serve.py`` and ``examples/adaptive_offload.py`` — their
    per-epoch lines come from :func:`format_decision` over the SAME audit
    rows a trace would contain, so printed output and recorded observability
    can never disagree.
"""

from __future__ import annotations

from .audit import AuditLog, DecisionAudit
from .metrics import Histogram, MetricsRegistry
from .tracer import Tracer

__all__ = ["format_decision", "explain_flip", "render_report"]


def _ms(v: float) -> str:
    if v != v:
        return "nan"
    if v == float("inf"):
        return "inf"
    return f"{v * 1e3:.1f} ms"


def format_decision(a: DecisionAudit) -> str:
    """The canonical one-line view of a decision — derived from the audit row,
    not from ad-hoc locals at the call site."""
    bw = a.snapshot.get("bandwidth_Bps")
    bw_s = f"{bw * 8 / 1e6:5.1f} Mbps" if isinstance(bw, (int, float)) else "  n/a    "
    dev = a.totals.get("on_device", float("nan"))
    return (f"[{a.source}] epoch {a.epoch:3d} t={a.time_s:7.1f}s  {bw_s} -> "
            f"{a.chosen:10s} (pred {_ms(a.predicted_latency_s):>9s}; "
            f"device {_ms(dev):>9s}; margin {_ms(a.margin_s):>9s})")


def explain_flip(before: DecisionAudit, after: DecisionAudit) -> str:
    """Term-by-term account of why a decision flipped between two epochs.

    Shows, for the old and new targets, how each closed-form term moved
    between the two audit rows — the 'show your work' view of e.g. a
    bandwidth step pushing w_net_dev past the on-device service time.
    """
    lines = [
        f"flip @ epoch {after.epoch} (t={after.time_s:g}s): "
        f"{before.chosen} -> {after.chosen}  [{after.source}]",
        f"  snapshot: {_fmt_snapshot(before)}  ->  {_fmt_snapshot(after)}",
    ]
    for target in (before.chosen, after.chosen):
        tb, ta = before.terms.get(target), after.terms.get(target)
        if tb is None or ta is None:
            continue
        lines.append(f"  {target}: total {_ms(before.term_totals[target])} -> "
                     f"{_ms(after.term_totals[target])}")
        for k in ta:
            db, da = tb.get(k, 0.0), ta[k]
            marker = "  <-- moved" if abs(da - db) > 0.05 * max(
                abs(da), abs(db), 1e-12) else ""
            lines.append(f"      {k:12s} {_ms(db):>10s} -> {_ms(da):>10s}{marker}")
    if after.hysteresis.get("engaged"):
        lines.append("  (hysteresis engaged: raw argmin differed)")
    return "\n".join(lines)


def _fmt_snapshot(a: DecisionAudit) -> str:
    bits = []
    bw = a.snapshot.get("bandwidth_Bps")
    if isinstance(bw, (int, float)):
        bits.append(f"B={bw * 8 / 1e6:.1f}Mbps")
    lam = a.snapshot.get("lam_dev")
    if isinstance(lam, (int, float)):
        bits.append(f"lam={lam:.2f}/s")
    return " ".join(bits) or "(none)"


def _span_table(tracer: Tracer) -> list[str]:
    cats: dict[str, Histogram] = {}
    for s in tracer.spans:
        cats.setdefault(s.cat, Histogram()).record(s.dur)
    lines = ["| category | spans | total | p50 | p99 |",
             "|---|---:|---:|---:|---:|"]
    for cat in sorted(cats):
        h = cats[cat]
        lines.append(f"| {cat} | {h.count} | {_ms(h.sum)} | {_ms(h.p50)} | "
                     f"{_ms(h.p99)} |")
    return lines


def render_report(
    *,
    tracer: Tracer | None = None,
    audit: AuditLog | None = None,
    metrics: MetricsRegistry | None = None,
    title: str = "Observability report",
) -> str:
    """Markdown report over whatever observability streams exist."""
    out: list[str] = [f"# {title}", ""]
    if tracer is not None and tracer.spans:
        t0 = min(s.t for s in tracer.spans)
        t1 = max(s.t + s.dur for s in tracer.spans)
        out += [f"## Trace — {len(tracer.spans)} spans over "
                f"{t1 - t0:.3f} s on {len(tracer.tracks())} tracks", ""]
        out += _span_table(tracer)
        out.append("")
    if audit is not None and len(audit):
        err = audit.max_resum_error()
        out += [f"## Decisions — {len(audit)} audited "
                f"(max term re-sum error {err:.2e})", ""]
        out += ["```"] + [format_decision(a) for a in audit.rows] + ["```", ""]
        flips = audit.flips()
        if flips:
            out += [f"### {len(flips)} strategy flip(s), explained", ""]
            for before, after in flips:
                out += ["```", explain_flip(before, after), "```", ""]
    if metrics is not None:
        rendered = metrics.render()
        if rendered:
            out += ["## Metrics", "", "```", rendered, "```", ""]
    return "\n".join(out)
