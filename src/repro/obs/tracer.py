"""Span-based request tracing: deterministic, zero-overhead when disabled.

The tracer records the request lifecycle the paper's two-level methodology
implies — decide / transfer / queue / prefill / decode / respond — stamped on
whatever clock the producer runs (the measure harness's simulated clock or the
wall clock). Spans carry no wall-time side channel of their own, so a
simulated-clock run serializes byte-identically across same-seed reruns.

Two export formats:

  * JSONL (:meth:`Tracer.to_jsonl`) — one canonical (sorted-keys) JSON object
    per span, byte-stable per seed; the format :mod:`repro.launch.obs_report`
    reads back.
  * Chrome/Perfetto ``trace_event`` (:meth:`Tracer.to_chrome`) — "X" complete
    events with microsecond ``ts``/``dur``, loadable at https://ui.perfetto.dev.

Hot paths hold a ``tracer`` that is either ``None`` (recommended: guard the
emission site with ``if tracer is not None``) or a :class:`Tracer`; a tracer
constructed with ``enabled=False`` no-ops on every record call, so either
convention keeps the disabled cost to one predicate.
"""

from __future__ import annotations

import json
from dataclasses import dataclass
from pathlib import Path
from typing import Any, Iterable

__all__ = ["Span", "Tracer", "merge"]

# the span categories the repro stack emits (open set — consumers must not
# assume exhaustiveness, the report CLI groups by whatever it finds)
CATEGORIES = ("decide", "transfer", "queue", "prefill", "decode", "respond")


def _scalar(v):
    """Coerce numpy scalars / bools to plain Python so json round-trips are
    canonical and never emit e.g. ``Infinity`` payload variants per dtype."""
    if hasattr(v, "item"):
        v = v.item()
    if isinstance(v, float):
        if v != v:
            return "nan"
        if v == float("inf"):
            return "inf"
        if v == float("-inf"):
            return "-inf"
    return v


@dataclass(frozen=True)
class Span:
    """One timed (or instant, ``dur == 0``) event on a named track."""

    t: float  # start, seconds on the producer's clock
    dur: float  # seconds (0.0 for instants)
    name: str
    cat: str  # lifecycle category ("decide", "prefill", ...)
    track: str  # display lane (Perfetto thread): "engine", "req[3]", ...
    attrs: tuple[tuple[str, Any], ...] = ()

    def to_dict(self) -> dict:
        return {
            "t": self.t,
            "dur": self.dur,
            "name": self.name,
            "cat": self.cat,
            "track": self.track,
            "attrs": dict(self.attrs),
        }

    @classmethod
    def from_dict(cls, d: dict) -> "Span":
        return cls(
            t=float(d["t"]), dur=float(d["dur"]), name=str(d["name"]),
            cat=str(d["cat"]), track=str(d["track"]),
            attrs=tuple(sorted(d.get("attrs", {}).items())),
        )


class Tracer:
    """Collects :class:`Span` records; serializes them deterministically."""

    __slots__ = ("enabled", "spans")

    def __init__(self, *, enabled: bool = True):
        self.enabled = enabled
        self.spans: list[Span] = []

    def __len__(self) -> int:
        return len(self.spans)

    def clear(self) -> None:
        self.spans.clear()

    # -- recording ----------------------------------------------------------
    def span(self, *, t: float, dur: float, name: str, cat: str,
             track: str = "main", **attrs) -> None:
        if not self.enabled:
            return
        self.spans.append(Span(
            t=float(t), dur=float(dur), name=name, cat=cat, track=track,
            attrs=tuple(sorted((k, _scalar(v)) for k, v in attrs.items())),
        ))

    def instant(self, *, t: float, name: str, cat: str,
                track: str = "main", **attrs) -> None:
        self.span(t=t, dur=0.0, name=name, cat=cat, track=track, **attrs)

    # -- JSONL --------------------------------------------------------------
    def to_jsonl(self) -> str:
        """One canonical JSON object per line — byte-stable for identical
        span sequences (same seed + simulated clock => identical bytes)."""
        return "".join(
            json.dumps(s.to_dict(), sort_keys=True, separators=(",", ":")) + "\n"
            for s in self.spans
        )

    def write_jsonl(self, path: str | Path) -> Path:
        path = Path(path)
        path.write_text(self.to_jsonl())
        return path

    @classmethod
    def from_jsonl(cls, text: str) -> "Tracer":
        tr = cls()
        tr.spans = [Span.from_dict(json.loads(line))
                    for line in text.splitlines() if line.strip()]
        return tr

    @classmethod
    def read_jsonl(cls, path: str | Path) -> "Tracer":
        return cls.from_jsonl(Path(path).read_text())

    # -- Chrome/Perfetto trace_event ----------------------------------------
    def to_chrome(self) -> dict:
        """The ``trace_event`` JSON object Perfetto / chrome://tracing load.

        Every span becomes an "X" (complete) event; instants become "i".
        ``ts``/``dur`` are microseconds. Tracks map to tids in order of first
        appearance (deterministic for a deterministic span stream), with
        ``thread_name`` metadata so Perfetto labels the lanes.
        """
        tids: dict[str, int] = {}
        events: list[dict] = []
        for s in self.spans:
            tid = tids.setdefault(s.track, len(tids) + 1)
            ev = {
                "name": s.name,
                "cat": s.cat,
                "ph": "X" if s.dur > 0.0 else "i",
                "ts": s.t * 1e6,
                "pid": 1,
                "tid": tid,
                "args": dict(s.attrs),
            }
            if s.dur > 0.0:
                ev["dur"] = s.dur * 1e6
            else:
                ev["s"] = "t"  # instant scope: thread
            events.append(ev)
        meta = [
            {"name": "thread_name", "ph": "M", "pid": 1, "tid": tid,
             "args": {"name": track}}
            for track, tid in tids.items()
        ]
        return {"traceEvents": meta + events, "displayTimeUnit": "ms"}

    def write_chrome(self, path: str | Path) -> Path:
        path = Path(path)
        path.write_text(json.dumps(self.to_chrome(), sort_keys=True) + "\n")
        return path

    # -- queries (report CLI / tests) ---------------------------------------
    def by_cat(self, cat: str) -> list[Span]:
        return [s for s in self.spans if s.cat == cat]

    def tracks(self) -> list[str]:
        seen: dict[str, None] = {}
        for s in self.spans:
            seen.setdefault(s.track)
        return list(seen)


def merge(tracers: Iterable[Tracer]) -> Tracer:
    """Concatenate several tracers' spans (e.g. engine + gateway) into one
    stream ordered by start time (stable for equal stamps)."""
    out = Tracer()
    spans: list[Span] = []
    for tr in tracers:
        spans.extend(tr.spans)
    out.spans = sorted(spans, key=lambda s: s.t)
    return out
