"""Run provenance: the `manifest.json` attached to every artifact.

The ROADMAP's "experiment manifests" item: BENCH_*.json / VALIDATION.json /
MeasuredProfile artifacts carry no record of what produced them. A manifest
pins the run — seed, a hash of the resolved config, the git commit (+dirty
flag), and the package versions the closed forms ran on — WITHOUT any
timestamp, so artifacts that embed one stay byte-stable across same-seed
reruns on the same checkout.

``manifest_delta`` powers check_regression's informational drift note: when a
committed baseline's manifest differs from the fresh run's, the comparison is
still valid (the gates fire as usual) but the report says what changed.
"""

from __future__ import annotations

import hashlib
import json
import platform
import subprocess
from functools import lru_cache
from pathlib import Path

__all__ = ["run_manifest", "config_hash", "manifest_delta", "MANIFEST_VERSION"]

MANIFEST_VERSION = 1


def config_hash(config) -> str | None:
    """sha256 of the canonical-JSON resolved config (None passes through)."""
    if config is None:
        return None
    blob = json.dumps(config, sort_keys=True, default=str).encode()
    return hashlib.sha256(blob).hexdigest()


@lru_cache(maxsize=1)
def _git_state() -> dict:
    """{"sha", "dirty"} of the checkout this package runs from, or
    {"sha": "unknown", "dirty": None} outside a git repo / without git."""
    cwd = Path(__file__).resolve().parent
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=cwd, capture_output=True,
            text=True, timeout=10, check=True,
        ).stdout.strip()
        porcelain = subprocess.run(
            ["git", "status", "--porcelain"], cwd=cwd, capture_output=True,
            text=True, timeout=10, check=True,
        ).stdout
        return {"sha": sha, "dirty": bool(porcelain.strip())}
    except (OSError, subprocess.SubprocessError):
        return {"sha": "unknown", "dirty": None}


@lru_cache(maxsize=1)
def _environment() -> dict:
    import jax
    import numpy as np

    return {
        "python": platform.python_version(),
        "platform": platform.platform(),
        "packages": {"jax": jax.__version__, "numpy": np.__version__},
    }


def run_manifest(*, seed=None, config=None, extra=None) -> dict:
    """The provenance record for one run. Deliberately timestamp-free."""
    m = {
        "manifest_version": MANIFEST_VERSION,
        "seed": seed,
        "config_sha256": config_hash(config),
        "git": dict(_git_state()),
        **_environment(),
    }
    if extra:
        m["extra"] = dict(extra)
    return m


# keys whose drift is worth reporting (seed/config differences are usually
# the run's *point*, not provenance drift)
_DRIFT_KEYS = ("git", "python", "platform", "packages")


def manifest_delta(a: dict | None, b: dict | None) -> list[str]:
    """Human-readable list of provenance differences between two manifests.

    Empty list => same provenance (or one side has no manifest to compare —
    absence is reported by the caller, not guessed at here). A drift key
    missing entirely from one side is skipped, not drift: committed baselines
    deliberately strip the machine/git-bound fields (see
    ``check_regression --update-baselines``), and a stripped baseline vs a
    full fresh manifest would otherwise report perpetual pseudo-drift.
    """
    if not a or not b:
        return []
    out: list[str] = []
    for key in _DRIFT_KEYS:
        if key not in a or key not in b:
            continue
        va, vb = a.get(key), b.get(key)
        if va == vb:
            continue
        if isinstance(va, dict) and isinstance(vb, dict):
            for sub in sorted(set(va) | set(vb)):
                if va.get(sub) != vb.get(sub):
                    out.append(f"{key}.{sub}: {va.get(sub)!r} -> {vb.get(sub)!r}")
        else:
            out.append(f"{key}: {va!r} -> {vb!r}")
    return out
