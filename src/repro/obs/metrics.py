"""Counters / gauges / streaming histograms + a named registry.

The registry is the single sink the launchers and examples report through
(instead of ad-hoc prints), so rendered output, traces, and audit logs are all
views of the same recorded numbers. Everything is deterministic: histograms
are log-bucketed (no sampling), rendering sorts by metric name, and nothing
reads a clock.
"""

from __future__ import annotations

import math

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry"]


class Counter:
    """Monotone event count."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> None:
        if n < 0:
            raise ValueError("counters only go up")
        self.value += n


class Gauge:
    """Last-write-wins scalar."""

    __slots__ = ("value",)

    def __init__(self):
        self.value: float | None = None

    def set(self, v: float) -> None:
        v = float(v)
        if not math.isfinite(v):
            raise ValueError(f"gauge value must be finite, got {v!r}")
        self.value = v


class Histogram:
    """Streaming log-bucketed histogram with quantile estimates.

    Buckets grow geometrically (``GROWTH`` per bucket, ~7.7% relative width),
    so ``percentile`` is exact to within one bucket's relative width at any
    stream length in O(1) memory. Non-positive values land in a dedicated
    zero bucket (they are valid latencies for instants/zero-byte legs).
    """

    GROWTH = 1.08

    __slots__ = ("count", "sum", "_min", "_max", "_zero", "_buckets", "_log_g")

    def __init__(self):
        self.count = 0
        self.sum = 0.0
        self._min = math.inf
        self._max = -math.inf
        self._zero = 0
        self._buckets: dict[int, int] = {}
        self._log_g = math.log(self.GROWTH)

    def record(self, v: float) -> None:
        v = float(v)
        if not math.isfinite(v):
            raise ValueError(f"histogram observation must be finite, got {v!r}")
        self.count += 1
        self.sum += v
        self._min = min(self._min, v)
        self._max = max(self._max, v)
        if v <= 0.0:
            self._zero += 1
            return
        idx = math.floor(math.log(v) / self._log_g)
        self._buckets[idx] = self._buckets.get(idx, 0) + 1

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    @property
    def min(self) -> float:
        return self._min if self.count else 0.0

    @property
    def max(self) -> float:
        return self._max if self.count else 0.0

    def percentile(self, q: float) -> float:
        """q in (0, 1); returns the geometric midpoint of the bucket holding
        the q-th observation (0.0 for the zero bucket)."""
        if not 0.0 < q < 1.0:
            raise ValueError(f"q must be in (0, 1), got {q}")
        if self.count == 0:
            return 0.0
        rank = q * self.count
        seen = float(self._zero)
        if rank <= seen:
            return 0.0
        for idx in sorted(self._buckets):
            seen += self._buckets[idx]
            if rank <= seen:
                return self.GROWTH ** (idx + 0.5)
        return self._max

    @property
    def p50(self) -> float:
        return self.percentile(0.50)

    @property
    def p99(self) -> float:
        return self.percentile(0.99)


class MetricsRegistry:
    """Get-or-create named metrics; snapshot/render deterministically."""

    def __init__(self):
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        return self._counters.setdefault(name, Counter())

    def gauge(self, name: str) -> Gauge:
        return self._gauges.setdefault(name, Gauge())

    def histogram(self, name: str) -> Histogram:
        return self._histograms.setdefault(name, Histogram())

    def snapshot(self) -> dict:
        """Plain-dict view (JSON-ready), sorted by metric name."""
        out: dict[str, dict] = {"counters": {}, "gauges": {}, "histograms": {}}
        for name in sorted(self._counters):
            out["counters"][name] = self._counters[name].value
        for name in sorted(self._gauges):
            out["gauges"][name] = self._gauges[name].value
        for name in sorted(self._histograms):
            h = self._histograms[name]
            out["histograms"][name] = {
                "count": h.count, "mean": h.mean, "min": h.min, "max": h.max,
                "p50": h.p50, "p99": h.p99,
            }
        return out

    def render(self, prefix: str = "") -> str:
        """Terminal-friendly rendering, one metric per line."""
        lines: list[str] = []
        snap = self.snapshot()
        for name, v in snap["counters"].items():
            lines.append(f"{prefix}{name} = {v}")
        for name, v in snap["gauges"].items():
            lines.append(f"{prefix}{name} = {v:g}" if v is not None
                         else f"{prefix}{name} = (unset)")
        for name, h in snap["histograms"].items():
            lines.append(
                f"{prefix}{name}: n={h['count']} mean={h['mean']:.6g} "
                f"p50={h['p50']:.6g} p99={h['p99']:.6g} max={h['max']:.6g}")
        return "\n".join(lines)
