"""Fault-tolerant checkpointing: atomic msgpack snapshots of pytrees.

Design (DESIGN.md §7):
  * atomic: write to ``<step>.tmp`` then rename — a crash mid-write never
    corrupts the latest checkpoint;
  * self-describing: every leaf stores dtype/shape; the tree structure is
    round-tripped exactly (dicts / lists / tuples / scalars);
  * resumable anywhere: ``restore(..., target=abstract_tree, sharding=...)``
    places leaves directly onto the target mesh — this is what lets a job
    resume on a *different* mesh after elastic re-meshing (the checkpoint is
    mesh-agnostic host bytes; sharding is applied at restore);
  * bounded retention: ``keep`` newest checkpoints are retained.
"""

from __future__ import annotations

import os
import re
import shutil
from pathlib import Path
from typing import Any

import jax
import jax.numpy as jnp
import ml_dtypes  # registers bfloat16/fp8 with numpy dtype lookup
import msgpack
import numpy as np

__all__ = ["Checkpointer", "save_pytree", "load_pytree"]

_LEAF_KEY = "__leaf__"
_TUPLE_KEY = "__tuple__"


def _pack_tree(tree: Any) -> Any:
    if isinstance(tree, dict):
        return {str(k): _pack_tree(v) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        node = {_TUPLE_KEY: isinstance(tree, tuple)}
        node["items"] = [_pack_tree(v) for v in tree]
        return node
    if tree is None:
        return {_LEAF_KEY: "none"}
    arr = np.asarray(tree)
    return {
        _LEAF_KEY: "array",
        "dtype": str(arr.dtype),  # by NAME ("|V2" would lose bfloat16)
        "shape": list(arr.shape),
        "data": arr.tobytes(),
    }


def _unpack_tree(node: Any) -> Any:
    if isinstance(node, dict):
        if node.get(_LEAF_KEY) == "none":
            return None
        if node.get(_LEAF_KEY) == "array":
            arr = np.frombuffer(node["data"], dtype=np.dtype(node["dtype"]))
            return arr.reshape(node["shape"])
        if _TUPLE_KEY in node:
            items = [_unpack_tree(v) for v in node["items"]]
            return tuple(items) if node[_TUPLE_KEY] else items
        return {k: _unpack_tree(v) for k, v in node.items()}
    return node


def save_pytree(path: str | Path, tree: Any) -> None:
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
    payload = msgpack.packb(_pack_tree(host_tree), use_bin_type=True)
    tmp = path.with_suffix(path.suffix + ".tmp")
    tmp.write_bytes(payload)
    os.replace(tmp, path)  # atomic on POSIX


def load_pytree(path: str | Path, *, target: Any = None, shardings: Any = None) -> Any:
    raw = msgpack.unpackb(Path(path).read_bytes(), raw=False)
    tree = _unpack_tree(raw)
    if target is None:
        return tree

    t_leaves, treedef = jax.tree.flatten(target)
    leaves = jax.tree.leaves(tree)
    if len(leaves) != len(t_leaves):
        raise ValueError(
            f"checkpoint has {len(leaves)} leaves, target expects {len(t_leaves)}"
        )
    s_leaves = (
        jax.tree.leaves(shardings) if shardings is not None else [None] * len(leaves)
    )
    out = []
    for val, tgt, shd in zip(leaves, t_leaves, s_leaves):
        val = np.asarray(val)
        if tuple(val.shape) != tuple(tgt.shape):
            raise ValueError(f"shape mismatch: {val.shape} vs {tgt.shape}")
        arr = jnp.asarray(val, dtype=tgt.dtype)
        if shd is not None:
            arr = jax.device_put(arr, shd)
        out.append(arr)
    return jax.tree.unflatten(treedef, out)


class Checkpointer:
    """Step-indexed checkpoint directory with retention + resume."""

    _PAT = re.compile(r"^step_(\d+)\.ckpt$")

    def __init__(self, directory: str | Path, *, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep

    def _path(self, step: int) -> Path:
        return self.dir / f"step_{step:010d}.ckpt"

    def save(self, step: int, tree: Any) -> Path:
        p = self._path(step)
        save_pytree(p, tree)
        self._gc()
        return p

    def steps(self) -> list[int]:
        out = []
        for f in self.dir.iterdir():
            m = self._PAT.match(f.name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, step: int | None = None, *, target: Any = None, shardings: Any = None):
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        return step, load_pytree(self._path(step), target=target, shardings=shardings)

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[: -self.keep]:
            self._path(s).unlink(missing_ok=True)
