"""Execute an :class:`~repro.exp.spec.ExperimentSpec` into ``results/``.

Layout of one run::

    results/<exp-id>/<run-id>/
        manifest.json    run provenance (obs.run_manifest) + the exact spec
        metrics.json     per-seed metric leaves + cross-seed bootstrap CIs
        summary.md       human-readable digest; written LAST -> its presence
                         is the completion marker that enables resume-skip
        seed-<s>/        the artifacts the spec's output contract declares

The run id is deterministic: a hash of the spec, the seed list, and the
machine/git provenance. Re-running the same spec on the same checkout lands
in the same directory and — because ``summary.md`` only appears once a run
finished — is skipped, while any spec/config/seed/toolchain change starts a
fresh directory instead of silently overwriting evidence.

Byte-stability is a contract, not an aspiration: :func:`diff_results`
compares two results trees file-by-file, masking only the dotted JSON paths
each spec declares wall-clock ``volatile``. Everything else must match to
the byte.
"""

from __future__ import annotations

import fnmatch
import hashlib
import importlib
import inspect
import json
import shutil
from dataclasses import dataclass
from numbers import Number
from pathlib import Path
from typing import Callable, Iterable, Mapping, Sequence

from repro.exp.spec import ExperimentError, ExperimentSpec, registry
from repro.obs import run_manifest

__all__ = [
    "RunResult",
    "resolve_payload",
    "call_payload",
    "run_id_for",
    "run_experiment",
    "strip_volatile",
    "diff_results",
]

#: provenance keys that key a run id — a new git sha, interpreter, machine,
#: or dependency set is a different run, not a resume
_PROVENANCE_KEYS = ("git", "python", "platform", "packages")

#: files the runner itself writes at the run root (never part of the
#: payload's output contract, and excluded from the byte-stability diff —
#: metrics.json embeds wall-clock-derived leaves by design)
_RUNNER_FILES = ("manifest.json", "metrics.json", "summary.md")


def _dump(doc: Mapping) -> str:
    return json.dumps(doc, indent=2, sort_keys=True) + "\n"


@dataclass(frozen=True)
class RunResult:
    """Outcome of :func:`run_experiment` for one spec."""

    exp_id: str
    run_id: str
    run_dir: Path
    seeds: tuple[int, ...]
    skipped: bool
    passed: bool
    metrics: dict


def resolve_payload(payload: str) -> Callable:
    """Import the callable behind a ``"module.path:callable"`` reference."""
    mod_name, _, attr = payload.partition(":")
    try:
        mod = importlib.import_module(mod_name)
    except ImportError as e:
        raise ExperimentError(f"payload module {mod_name!r} not importable: {e}")
    fn = getattr(mod, attr, None)
    if not callable(fn):
        raise ExperimentError(f"payload {payload!r} is not a callable")
    return fn


def call_payload(fn: Callable, out_dir: Path, *, seed: int,
                 config: Mapping) -> dict:
    """Call a payload, passing ``seed``/``config`` only if it accepts them.

    Bench families keep their historical ``fn(out_dir) -> report`` shape;
    seed-sensitive payloads take ``fn(out_dir, seed=..., config=...)``. A
    payload returning ``None`` contributes no metrics (roofline).
    """
    params = inspect.signature(fn).parameters
    kwargs = {}
    if "seed" in params:
        kwargs["seed"] = seed
    if "config" in params:
        kwargs["config"] = config
    result = fn(Path(out_dir), **kwargs)
    if result is None:
        return {}
    if not isinstance(result, Mapping):
        raise ExperimentError(
            f"payload {fn.__module__}.{fn.__qualname__} returned "
            f"{type(result).__name__}, expected a metrics mapping")
    return dict(result)


def run_id_for(spec: ExperimentSpec, seeds: Sequence[int]) -> str:
    """Deterministic run id over (spec, seeds, machine/git provenance)."""
    prov = run_manifest()
    key = {
        "spec": spec.to_dict(),
        "seeds": [int(s) for s in seeds],
        "provenance": {k: prov.get(k) for k in _PROVENANCE_KEYS},
    }
    digest = hashlib.sha256(
        json.dumps(key, sort_keys=True).encode()).hexdigest()
    return "run-" + digest[:12]


def _flatten(doc: Mapping, prefix: str = "") -> dict:
    flat: dict = {}
    for k, v in doc.items():
        key = f"{prefix}{k}"
        if isinstance(v, Mapping):
            flat.update(_flatten(v, key + "."))
        else:
            flat[key] = v
    return flat


def _gate_leaves(flat: Mapping) -> dict:
    """The boolean leaves that decide a run's verdict."""
    def is_gate(name: str) -> bool:
        leaf = name.rsplit(".", 1)[-1]
        return leaf == "passed" or leaf.endswith("_passed") \
            or leaf.endswith("_gate_pass")
    return {k: v for k, v in flat.items() if is_gate(k)}


def _aggregate(per_seed: Mapping[int, Mapping]) -> dict:
    """Cross-seed stats per numeric metric leaf.

    With several seeds and genuinely varying values the entry carries a
    bootstrap 95% CI (reusing the validate layer's engine); a leaf identical
    across seeds is flagged ``seed_stable`` instead.
    """
    from repro.validate.metrics import bootstrap_mean_ci

    keys: list[str] = []
    for flat in per_seed.values():
        for k in flat:
            if k not in keys:
                keys.append(k)
    agg: dict = {}
    for k in keys:
        vals = [flat[k] for flat in per_seed.values() if k in flat]
        if not vals or not all(
                isinstance(v, Number) and not isinstance(v, bool)
                for v in vals):
            continue
        vals = [float(v) for v in vals]
        entry: dict = {"n_seeds": len(vals), "mean": sum(vals) / len(vals)}
        if len(vals) > 1 and max(vals) > min(vals):
            ci = bootstrap_mean_ci(vals, seed=0)
            entry.update(ci95_lo=ci.lo, ci95_hi=ci.hi, seed_stable=False)
        else:
            entry["seed_stable"] = True
        agg[k] = entry
    return agg


def _summary_md(spec: ExperimentSpec, run_id: str, seeds: Sequence[int],
                metrics: Mapping) -> str:
    lines = [
        f"# {spec.exp_id}",
        "",
        spec.description or "(no description)",
        "",
        f"- kind: `{spec.kind}`",
        f"- payload: `{spec.payload}`",
        f"- run id: `{run_id}`",
        f"- seeds: {list(seeds)}",
        f"- verdict: **{'PASS' if metrics['passed'] else 'FAIL'}**",
        "",
    ]
    gates = metrics.get("gate_leaves", {})
    if gates:
        lines += ["## Gates", ""]
        for k, v in sorted(gates.items()):
            lines.append(f"- `{k}`: {'PASS' if v else 'FAIL'}")
        lines.append("")
    agg = metrics.get("aggregate", {})
    if agg:
        lines += ["## Metrics", "",
                  "| metric | mean | 95% CI | seeds |", "|---|---|---|---|"]
        for k, e in agg.items():
            ci = (f"[{e['ci95_lo']:.6g}, {e['ci95_hi']:.6g}]"
                  if "ci95_lo" in e else "seed-stable")
            lines.append(f"| `{k}` | {e['mean']:.6g} | {ci} | {e['n_seeds']} |")
        lines.append("")
    outs = metrics.get("outputs", [])
    if outs:
        lines += ["## Artifacts", ""]
        lines += [f"- `{p}`" for p in outs]
        lines.append("")
    return "\n".join(lines)


def _stored_spec(run_dir: Path) -> dict | None:
    try:
        doc = json.loads((run_dir / "manifest.json").read_text())
        return doc["experiment"]["spec"]
    except (OSError, ValueError, KeyError):
        return None


def run_experiment(spec: ExperimentSpec, *,
                   results_root: Path = Path("results"),
                   seeds: Sequence[int] | None = None,
                   force: bool = False) -> RunResult:
    """Run one spec into ``results/<exp-id>/<run-id>/``; see module doc.

    ``seeds`` overrides the spec's seed list only for seed-sensitive
    experiments — bench families pin their own internal seeds and always
    run exactly once.
    """
    if seeds is not None and spec.seed_sensitive:
        run_seeds = tuple(dict.fromkeys(int(s) for s in seeds))
    else:
        run_seeds = spec.seeds
    if not run_seeds:
        raise ExperimentError(f"{spec.exp_id}: empty seed list")

    run_id = run_id_for(spec, run_seeds)
    run_dir = Path(results_root) / spec.exp_id / run_id

    if (run_dir / "summary.md").exists() and not force:
        if _stored_spec(run_dir) == spec.to_dict():
            try:
                metrics = json.loads((run_dir / "metrics.json").read_text())
            except (OSError, ValueError):
                metrics = {}
            return RunResult(spec.exp_id, run_id, run_dir, run_seeds,
                             skipped=True,
                             passed=bool(metrics.get("passed", False)),
                             metrics=metrics)
    if run_dir.exists():
        shutil.rmtree(run_dir)  # partial or forced: start clean
    run_dir.mkdir(parents=True)

    fn = resolve_payload(spec.payload)
    per_seed_flat: dict[int, dict] = {}
    produced: list[str] = []
    for s in run_seeds:
        seed_dir = run_dir / f"seed-{s}"
        seed_dir.mkdir()
        raw = call_payload(fn, seed_dir, seed=s, config=spec.config)
        missing = [f for f in spec.outputs if not (seed_dir / f).exists()]
        if missing:
            raise ExperimentError(
                f"{spec.exp_id} seed {s}: payload did not produce declared "
                f"output(s) {missing}")
        _stamp_outputs(spec, seed_dir, seed=s)
        per_seed_flat[s] = _flatten(raw)
        produced += [f"seed-{s}/{f}" for f in spec.outputs]

    gate_leaves = {f"seed-{s}.{k}": v
                   for s, flat in per_seed_flat.items()
                   for k, v in _gate_leaves(flat).items()}
    passed = all(bool(v) for v in gate_leaves.values()) if gate_leaves \
        else True

    metrics = {
        "exp_id": spec.exp_id,
        "run_id": run_id,
        "seeds": list(run_seeds),
        "passed": passed,
        "gates": dict(spec.gates),
        "gate_leaves": gate_leaves,
        "per_seed": {str(s): flat for s, flat in per_seed_flat.items()},
        "aggregate": _aggregate(per_seed_flat),
        "outputs": produced,
    }

    manifest = run_manifest(seed=run_seeds[0], config=dict(spec.config))
    manifest["experiment"] = {"spec": spec.to_dict(),
                              "seeds": list(run_seeds), "run_id": run_id}
    (run_dir / "manifest.json").write_text(_dump(manifest))
    (run_dir / "metrics.json").write_text(_dump(metrics))
    # completion marker: everything above must already be on disk
    (run_dir / "summary.md").write_text(
        _summary_md(spec, run_id, run_seeds, metrics))
    return RunResult(spec.exp_id, run_id, run_dir, run_seeds,
                     skipped=False, passed=passed, metrics=metrics)


def _stamp_outputs(spec: ExperimentSpec, seed_dir: Path, *, seed: int) -> None:
    """Ensure every declared JSON artifact carries a provenance manifest.

    Payloads that already stamp one (validate, measured, cluster-sim) are
    left alone; bench families historically got theirs from
    ``benchmarks.run.stamp_manifests`` and get the same treatment here.
    """
    for fname in spec.outputs:
        path = seed_dir / fname
        if path.suffix != ".json":
            continue
        try:
            doc = json.loads(path.read_text())
        except ValueError:
            continue
        if not isinstance(doc, dict) or "manifest" in doc:
            continue
        doc["manifest"] = run_manifest(
            seed=seed, config={"exp_id": spec.exp_id, **dict(spec.config)})
        path.write_text(json.dumps(doc, indent=2) + "\n")


def strip_volatile(doc, patterns: Iterable[str]):
    """Deep-copy ``doc`` with every dotted-path pattern removed.

    Each ``.``-separated segment is an fnmatch pattern, so
    ``"*.us_per_call"`` masks that leaf under every top-level key. Matching
    a non-leaf segment removes the whole subtree.
    """
    doc = json.loads(json.dumps(doc))
    for pat in patterns:
        _strip_one(doc, pat.split("."))
    return doc


def _strip_one(node, segs: list[str]) -> None:
    if not isinstance(node, dict) or not segs:
        return
    head, rest = segs[0], segs[1:]
    for key in [k for k in node if fnmatch.fnmatch(str(k), head)]:
        if rest:
            _strip_one(node[key], rest)
        else:
            del node[key]


def _volatile_for(rel: Path, reg: Mapping[str, ExperimentSpec]) -> tuple[str, ...] | None:
    """Declared volatile paths for a results-tree file, else None.

    ``rel`` is relative to a results root: ``<exp-id>/<run-id>/...``.
    Returns ``None`` for files outside any spec's output contract (those
    must be byte-identical), or the masking patterns for declared artifacts.
    """
    if not rel.parts:
        return None
    spec = reg.get(rel.parts[0])
    if spec is None:
        return None
    if rel.name in spec.outputs:
        return tuple(spec.volatile.get(rel.name, ()))
    return None


def diff_results(root_a: Path, root_b: Path,
                 reg: Mapping[str, ExperimentSpec] | None = None) -> list[str]:
    """Byte-stability diff of two results trees; ``[]`` means stable.

    Runner-owned ``metrics.json``/``summary.md`` are excluded (they embed
    wall-clock-derived leaves by design); ``manifest.json`` and every
    payload artifact are compared — JSON artifacts after masking their
    spec-declared volatile paths, everything else byte-for-byte.
    """
    reg = registry() if reg is None else reg
    root_a, root_b = Path(root_a), Path(root_b)

    skip = ("metrics.json", "summary.md", "REPRODUCTION.md")

    def files_of(root: Path) -> dict[Path, Path]:
        return {p.relative_to(root): p for p in sorted(root.rglob("*"))
                if p.is_file() and p.name not in skip}

    a_files, b_files = files_of(root_a), files_of(root_b)
    diffs: list[str] = []
    for rel in sorted(set(a_files) - set(b_files)):
        diffs.append(f"only in {root_a}: {rel}")
    for rel in sorted(set(b_files) - set(a_files)):
        diffs.append(f"only in {root_b}: {rel}")
    for rel in sorted(set(a_files) & set(b_files)):
        raw_a = a_files[rel].read_bytes()
        raw_b = b_files[rel].read_bytes()
        if raw_a == raw_b:
            continue
        vol = _volatile_for(rel, reg)
        if vol is not None and rel.suffix == ".json":
            try:
                doc_a = strip_volatile(json.loads(raw_a), vol)
                doc_b = strip_volatile(json.loads(raw_b), vol)
            except ValueError:
                diffs.append(f"differs (unparseable JSON): {rel}")
                continue
            if _dump(doc_a) == _dump(doc_b):
                continue
            diffs.append(f"differs beyond declared-volatile fields: {rel}")
        else:
            diffs.append(f"differs: {rel}")
    return diffs
