"""Declared experiments: the frozen ``ExperimentSpec`` and the one registry.

The repo's evidence for the paper's headline claim (BENCH_*.json families,
VALIDATION.json, MeasuredProfile artifacts, the paper figures) used to be
produced by a dozen loosely-coordinated CLIs with no declarative record of
what ran. An :class:`ExperimentSpec` turns each artifact-producing entry
point into a *declared* experiment: what runs (a dotted payload reference),
with which seeds and config, which files it must produce (the output
contract), which gate budgets apply, and which JSON fields are wall-clock
volatile (excluded from the byte-stability contract — timings can never be
byte-stable; everything else must be).

:func:`registry` is the single enumeration of every experiment the repo
knows how to run. ``benchmarks.run`` derives its family list from it and
``repro.launch.reproduce`` replays all of it, so a family added here is
automatically benchable, reproducible, and regression-gated — and one added
anywhere else is a test failure (`tests/test_exp.py` checks completeness).

Payloads are dotted ``"module.sub:callable"`` strings resolved lazily by
:mod:`repro.exp.runner`, so this module imports nothing heavy and the
``benchmarks`` package can import it without a cycle.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field, fields
from typing import Mapping

__all__ = [
    "KINDS",
    "ExperimentError",
    "ExperimentSpec",
    "registry",
    "bench_family_specs",
]

#: the experiment taxonomy: how the artifact relates to the paper's evidence
KINDS = (
    "bench-family",      # one benchmarks.run family -> BENCH_<family>.json
    "validate-regime",   # a differential-gate regime -> VALIDATION.json
    "figure",            # the paper-figure suite -> BENCH_paper_figures.json
    "measured-profile",  # hardware-in-the-loop profile + measured gate
    "cluster-sim",       # closed-loop cluster replay -> CLUSTER.json
)

_ID_RE = re.compile(r"^[a-z0-9][a-z0-9_-]*$")
_PAYLOAD_RE = re.compile(r"^[A-Za-z_][\w.]*:[A-Za-z_]\w*$")


class ExperimentError(ValueError):
    """Invalid experiment spec or a spec/run contract violation."""


@dataclass(frozen=True)
class ExperimentSpec:
    """One declared, reproducible experiment.

    ``volatile`` maps a declared output file to dotted key paths whose
    values are wall-clock dependent (timings, throughputs). The runner's
    stability diff masks exactly those paths; every other byte of the
    artifact must be identical across same-seed reruns.
    """

    exp_id: str
    kind: str
    payload: str
    description: str = ""
    seeds: tuple[int, ...] = (0,)
    #: True when the payload consumes the runner's seed (``reproduce
    #: --seeds N`` only widens the seed list of seed-sensitive experiments;
    #: bench families pin their own internal seeds and run once)
    seed_sensitive: bool = False
    config: Mapping[str, object] = field(default_factory=dict)
    gates: Mapping[str, float] = field(default_factory=dict)
    outputs: tuple[str, ...] = ()
    volatile: Mapping[str, tuple[str, ...]] = field(default_factory=dict)

    def __post_init__(self):
        if not _ID_RE.match(self.exp_id):
            raise ExperimentError(
                f"exp_id {self.exp_id!r} must match {_ID_RE.pattern}")
        if self.kind not in KINDS:
            raise ExperimentError(
                f"{self.exp_id}: kind {self.kind!r} not one of {KINDS}")
        if not _PAYLOAD_RE.match(self.payload):
            raise ExperimentError(
                f"{self.exp_id}: payload {self.payload!r} must be "
                "'module.path:callable'")
        object.__setattr__(self, "seeds", tuple(int(s) for s in self.seeds))
        if not self.seeds:
            raise ExperimentError(f"{self.exp_id}: seeds must be non-empty")
        if len(set(self.seeds)) != len(self.seeds):
            raise ExperimentError(f"{self.exp_id}: duplicate seeds {self.seeds}")
        if any(s < 0 for s in self.seeds):
            raise ExperimentError(f"{self.exp_id}: seeds must be >= 0")
        object.__setattr__(self, "config", dict(self.config))
        object.__setattr__(self, "gates",
                           {k: float(v) for k, v in dict(self.gates).items()})
        object.__setattr__(self, "outputs", tuple(self.outputs))
        if len(set(self.outputs)) != len(self.outputs):
            raise ExperimentError(f"{self.exp_id}: duplicate outputs")
        vol = {k: tuple(v) for k, v in dict(self.volatile).items()}
        object.__setattr__(self, "volatile", vol)
        unknown = [a for a in vol if a not in self.outputs]
        if unknown:
            raise ExperimentError(
                f"{self.exp_id}: volatile declares undeclared output(s) "
                f"{unknown} (outputs: {list(self.outputs)})")

    def to_dict(self) -> dict:
        return {
            "exp_id": self.exp_id,
            "kind": self.kind,
            "payload": self.payload,
            "description": self.description,
            "seeds": list(self.seeds),
            "seed_sensitive": self.seed_sensitive,
            "config": dict(self.config),
            "gates": dict(self.gates),
            "outputs": list(self.outputs),
            "volatile": {k: list(v) for k, v in self.volatile.items()},
        }

    @classmethod
    def from_dict(cls, d: Mapping) -> "ExperimentSpec":
        known = {f.name for f in fields(cls)}
        unknown = sorted(set(d) - known)
        if unknown:
            raise ExperimentError(f"unknown ExperimentSpec field(s) {unknown}")
        kw = dict(d)
        for tup_key in ("seeds", "outputs"):
            if tup_key in kw:
                kw[tup_key] = tuple(kw[tup_key])
        if "volatile" in kw:
            kw["volatile"] = {k: tuple(v) for k, v in kw["volatile"].items()}
        return cls(**kw)


def _bench(family: str, payload: str, *, kind: str = "bench-family",
           volatile: tuple[str, ...] = (), description: str = "") -> ExperimentSpec:
    artifact = f"BENCH_{family}.json"
    return ExperimentSpec(
        exp_id=f"bench-{family}" if kind == "bench-family" else "paper-figures",
        kind=kind,
        payload=payload,
        description=description or f"benchmarks.run family '{family}'",
        config={"family": family},
        outputs=(artifact,),
        volatile={artifact: volatile} if volatile else {},
    )


def registry() -> dict[str, ExperimentSpec]:
    """Every experiment the repo knows how to run, keyed by ``exp_id``.

    The order is the execution order of ``reproduce --all``: cheap model
    gates first, then the bench families, then the hardware-in-the-loop and
    closed-loop runs.
    """
    specs = [
        # -- validate regimes (differential fidelity gate) --------------------
        ExperimentSpec(
            exp_id="validate-smoke",
            kind="validate-regime",
            payload="repro.exp.payloads:validate_payload",
            description="tier-1 smoke slice of the differential fidelity "
                        "gate (golden-corpus subset, short simulations)",
            seeds=(0,),
            seed_sensitive=True,
            config={"smoke": True},
            gates={"mape_budget_pct": 5.0, "tail_budget_pct": 10.0},
            outputs=("VALIDATION.json",),
            volatile={"VALIDATION.json": ("corpus.elapsed_s",)},
        ),
        ExperimentSpec(
            exp_id="validate-full",
            kind="validate-regime",
            payload="repro.exp.payloads:validate_payload",
            description="full tier-2 differential gate over the whole "
                        "golden corpus (the paper's 2.2%-MAPE analogue)",
            seeds=(0,),
            seed_sensitive=True,
            config={"smoke": False},
            gates={"mape_budget_pct": 5.0, "tail_budget_pct": 10.0},
            outputs=("VALIDATION.json",),
            volatile={"VALIDATION.json": ("corpus.elapsed_s",)},
        ),
        # -- the paper-figure suite -------------------------------------------
        _bench("paper_figures", "benchmarks.run:run_paper_figures",
               kind="figure",
               description="every paper figure's headline numbers "
                           "(Fig. 2-7 MAPEs, crossovers, adaptation rows)"),
        # -- bench families ---------------------------------------------------
        _bench("fleet", "benchmarks.fleet_bench:fleet_rows", volatile=(
            "analytic.pack_ms", "analytic.vec_scenarios_per_sec",
            "analytic.scalar_scenarios_per_sec", "analytic.speedup",
            "crossover.vec_crossovers_per_sec",
            "crossover.scalar_crossovers_per_sec", "crossover.speedup",
            "simulation.vec_jobs_per_sec", "simulation.scalar_jobs_per_sec",
            "simulation.speedup")),
        _bench("cluster", "benchmarks.cluster_bench:cluster_rows", volatile=(
            "closed_loop.client_epochs_per_sec", "equilibrium.solve_ms")),
        _bench("meanfield", "benchmarks.meanfield_bench:meanfield_rows",
               volatile=("diurnal.wall_s", "diurnal.client_epochs_per_sec",
                         "equilibrium.solve_ms", "cross_check.wall_ms")),
        _bench("validate", "benchmarks.validate_bench:validate_rows",
               volatile=("analytic_vec_us", "analytic_scalar_us",
                         "smoke_gate_s")),
        _bench("tail", "benchmarks.tail_bench:tail_rows", volatile=(
            "scalar_us_per_scenario", "vec_euler_rows_per_sec",
            "euler_vec_rows_per_s", "vec_asym_rows_per_sec",
            "euler_vec_slowdown_vs_asym", "station_pass_speedup")),
        _bench("kernels", "benchmarks.kernel_bench:kernel_rows", volatile=(
            "flash_attention.us_per_call", "decode_attention.us_per_call",
            "ssm_scan.us_per_call", "rmsnorm.us_per_call",
            "lindley_scan.us_per_call", "decision_scan.us_per_call")),
        _bench("measure", "benchmarks.measure_bench:measure_rows", volatile=(
            "engine.tokens_per_sec", "engine.wall_s",
            "harness.requests_per_sec", "harness.wall_s", "fit.wall_ms")),
        _bench("obs", "benchmarks.obs_bench:obs_rows", volatile=(
            "tracer.tokens_per_sec_none", "tracer.tokens_per_sec_disabled",
            "tracer.tokens_per_sec_enabled", "tracer.disabled_overhead_pct",
            "tracer.enabled_overhead_pct", "audit.rows_per_sec")),
        _bench("plan", "benchmarks.plan_bench:plan_rows",
               volatile=("solver.wall_s",)),
        # roofline emits CSV rows from pre-existing dry-run artifacts and
        # writes nothing of its own -> empty output contract
        ExperimentSpec(
            exp_id="bench-roofline",
            kind="bench-family",
            payload="benchmarks.run:run_roofline",
            description="roofline table from experiments/roofline dry-run "
                        "artifacts, when present (no artifact of its own)",
            config={"family": "roofline"},
        ),
        # -- hardware in the loop ---------------------------------------------
        ExperimentSpec(
            exp_id="measured-smoke",
            kind="measured-profile",
            payload="repro.exp.payloads:measured_payload",
            description="simulated-clock smoke profile of the real engine "
                        "+ the analytic-vs-observed measured gate",
            seeds=(0,),
            seed_sensitive=True,
            config={"arch": "starcoder2_3b", "slots": 1, "requests": 240,
                    "target_rho": 0.45},
            gates={"mean_budget_pct": 15.0, "tail_budget_pct": 35.0},
            outputs=("PROFILE_starcoder2_3b.json", "VALIDATION_measured.json"),
        ),
        # -- closed loop ------------------------------------------------------
        ExperimentSpec(
            exp_id="cluster-sim-smoke",
            kind="cluster-sim",
            payload="repro.exp.payloads:cluster_sim_payload",
            description="closed-loop cluster replay (equilibrium + "
                        "bandwidth-step trace, adaptive vs statics)",
            seeds=(0,),
            seed_sensitive=True,
            config={"clients": 24, "duration": 60.0},
            outputs=("CLUSTER.json",),
            volatile={"CLUSTER.json": ("equilibrium.solve_s",
                                       "replay.client_epochs_per_sec",
                                       "cross_check.elapsed_s")},
        ),
    ]
    reg: dict[str, ExperimentSpec] = {}
    for spec in specs:
        if spec.exp_id in reg:
            raise ExperimentError(f"duplicate experiment id {spec.exp_id!r}")
        reg[spec.exp_id] = spec
    return reg


def bench_family_specs() -> dict[str, ExperimentSpec]:
    """``{family name: spec}`` for every benchmarks.run family (the
    bench-family and figure kinds), in registry order."""
    return {str(spec.config["family"]): spec
            for spec in registry().values()
            if spec.kind in ("bench-family", "figure")}
