"""repro.exp — declared experiments and manifest-driven reproduction.

``spec`` holds the frozen :class:`ExperimentSpec` and the single registry of
every experiment the repo knows how to run; ``runner`` executes a spec into
an isolated ``results/<exp-id>/<run-id>/`` directory with provenance,
cross-seed bootstrap CIs, resume-skip semantics, and a byte-stability
contract; ``payloads`` hosts the non-bench payload callables. The
``reproduce`` CLI (:mod:`repro.launch.reproduce`) replays the whole registry.
"""

from repro.exp.spec import (
    KINDS,
    ExperimentError,
    ExperimentSpec,
    bench_family_specs,
    registry,
)
from repro.exp.runner import (
    RunResult,
    diff_results,
    resolve_payload,
    run_experiment,
    run_id_for,
    strip_volatile,
)

__all__ = [
    "KINDS",
    "ExperimentError",
    "ExperimentSpec",
    "RunResult",
    "bench_family_specs",
    "diff_results",
    "registry",
    "resolve_payload",
    "run_experiment",
    "run_id_for",
    "strip_volatile",
]
