"""Experiment payloads that don't live in the ``benchmarks`` package.

Each payload is a plain callable the runner resolves from a spec's dotted
``payload`` string and calls as ``fn(out_dir, seed=..., config=...)``
(kwargs the signature doesn't declare are dropped). A payload writes its
declared artifacts into ``out_dir`` and returns a flat-ish metrics dict;
boolean ``passed`` / ``*_passed`` / ``*_gate_pass`` leaves feed the runner's
gate verdict.

``run_validate`` is also the engine behind ``python -m repro.launch.validate``
— the CLI is a shim over this module so the registry and the historical
entry point can never disagree about what a validate regime runs.
"""

from __future__ import annotations

import json
from pathlib import Path
from time import perf_counter
from typing import Mapping

from repro.obs import run_manifest

__all__ = [
    "run_validate",
    "validate_payload",
    "measured_payload",
    "cluster_sim_payload",
]


def run_validate(
    *,
    seed: int | None = None,
    smoke: bool = False,
    corpus: Path | None = None,
    base_n: int | None = None,
    max_n_factor: float | None = None,
    budget_pct: float | None = None,
    tail_pct: float | None = None,
    tail_budget_pct: float | None = None,
    bootstrap: int = 200,
    simulate: bool = True,
    sim_cross_count: int | None = None,
):
    """Run one differential-validation regime; ``(report, artifact_doc)``.

    The artifact doc is exactly what ``launch.validate`` writes to
    ``VALIDATION.json``: the fidelity report plus corpus metadata and the
    run-provenance manifest.
    """
    from repro.validate import (
        DEFAULT_MAPE_BUDGET_PCT,
        DEFAULT_SEED,
        DEFAULT_TAIL_BUDGET_PCT,
        DEFAULT_TAIL_PCT,
        load_corpus,
        run_differential,
        smoke_subset,
    )

    seed = DEFAULT_SEED if seed is None else int(seed)
    budget_pct = DEFAULT_MAPE_BUDGET_PCT if budget_pct is None else budget_pct
    tail_pct = DEFAULT_TAIL_PCT if tail_pct is None else tail_pct
    tail_budget_pct = DEFAULT_TAIL_BUDGET_PCT if tail_budget_pct is None \
        else tail_budget_pct

    entries, meta = load_corpus(corpus)
    expected = meta.get("expected_totals")
    if smoke:
        entries = smoke_subset(entries)
    base_n = base_n if base_n is not None else (20_000 if smoke else 120_000)
    max_factor = max_n_factor if max_n_factor is not None else \
        (2.0 if smoke else 6.0)
    cross = sim_cross_count if sim_cross_count is not None else \
        (2 if smoke else 3)

    t0 = perf_counter()
    rep = run_differential(
        entries,
        expected_totals=expected,
        base_n=base_n,
        max_n_factor=max_factor,
        seed=seed,
        mape_budget_pct=budget_pct,
        bootstrap=bootstrap,
        simulate=simulate,
        sim_cross_count=cross,
        tail_pct=tail_pct,
        tail_budget_pct=tail_budget_pct,
    )
    elapsed = perf_counter() - t0

    doc = rep.to_dict()
    doc["corpus"] = {"path": meta.get("path"), "seed": meta.get("seed"),
                     "smoke": smoke, "elapsed_s": elapsed}
    doc["manifest"] = run_manifest(seed=seed, config={
        "smoke": smoke, "base_n": base_n, "max_n_factor": max_factor,
        "budget_pct": budget_pct, "tail_pct": tail_pct,
        "tail_budget_pct": tail_budget_pct,
    })
    return rep, doc


def validate_payload(out_dir: Path, seed: int, config: Mapping) -> dict:
    """A validate regime as a declared experiment -> ``VALIDATION.json``."""
    cfg = dict(config)
    cfg.pop("family", None)
    if cfg.pop("no_sim", False):
        cfg["simulate"] = False
    rep, doc = run_validate(seed=seed, **cfg)
    (Path(out_dir) / "VALIDATION.json").write_text(json.dumps(doc, indent=2))
    gate = doc["mape_gate"]
    tail = doc["tail_gate"]
    return {
        "passed": bool(rep.passed),
        "n_entries": doc["config"]["n_entries"],
        "gate_mean_mape_pct": gate["mean_pct"],
        "gate_within_5_frac": gate["within_5_frac"],
        "tail_mean_mape_pct": tail["mean_pct"],
        "elapsed_s": elapsed_of(doc),
    }


def elapsed_of(doc: Mapping) -> float:
    return float(doc["corpus"]["elapsed_s"])


def measured_payload(out_dir: Path, seed: int, config: Mapping) -> dict:
    """Hardware-in-the-loop profile + measured gate as an experiment.

    Writes ``PROFILE_<arch>.json`` (the fitted MeasuredProfile; byte-stable
    per seed on the simulated clock) and ``VALIDATION_measured.json`` (the
    analytic-vs-observed gate report).
    """
    from repro.measure import HarnessConfig, build_profile, run_harness
    from repro.validate.measured import run_measured_gate

    out_dir = Path(out_dir)
    cfg = dict(config)
    hc = HarnessConfig(
        arch=str(cfg.get("arch", "starcoder2_3b")),
        slots=int(cfg.get("slots", 1)),
        reduced=bool(cfg.get("reduced", True)),
        clock=str(cfg.get("clock", "simulated")),
        seed=int(seed),
        n_requests=int(cfg.get("requests", 240)),
        target_rho=float(cfg.get("target_rho", 0.45)),
    )
    trace = run_harness(hc)
    profile = build_profile(trace, seed=int(seed),
                            manifest=run_manifest(seed=int(seed),
                                                  config=hc.to_dict()))
    profile.save(out_dir / f"PROFILE_{profile.arch}.json")

    rep = run_measured_gate(profile,
                            budget_pct=cfg.get("mean_budget_pct"),
                            tail_budget_pct=cfg.get("tail_budget_pct"))
    d = rep.to_dict()
    d["manifest"] = dict(profile.manifest)
    (out_dir / "VALIDATION_measured.json").write_text(
        json.dumps(d, indent=2) + "\n")
    return {
        "passed": bool(rep.passed),
        "mean_mape_pct": d["mean"]["mape_pct"],
        "p99_mape_pct": d["tail"]["mape_pct"],
        "rho": rep.rho,
        "n_requests": rep.n_requests,
    }


def cluster_sim_payload(out_dir: Path, seed: int, config: Mapping) -> dict:
    """Closed-loop cluster replay through the real CLI -> ``CLUSTER.json``.

    Routes through ``repro.launch.cluster_sim.main`` so the experiment
    exercises the same argument parsing, gating, and report assembly users
    get — its exit code is the gate (equilibrium converged AND the adaptive
    fleet beats every static policy).
    """
    from repro.launch.cluster_sim import main as cluster_main

    cfg = dict(config)
    out = Path(out_dir) / "CLUSTER.json"
    argv = ["--clients", str(int(cfg.get("clients", 24))),
            "--duration", str(float(cfg.get("duration", 60.0))),
            "--seed", str(int(seed)),
            "--out", str(out)]
    if cfg.get("meanfield"):
        argv.append("--meanfield")
    if cfg.get("cross_check"):
        argv.append("--cross-check")
    rc = cluster_main(argv)
    metrics = {"passed": rc == 0, "exit_code": rc}
    if out.exists():
        doc = json.loads(out.read_text())
        metrics.update({
            "equilibrium_iterations": doc["equilibrium"]["iterations"],
            "mean_latency_s": doc["equilibrium"]["mean_latency_s"],
            "adaptive_wins": doc.get("adaptive_wins",
                                     doc.get("replay", {}).get("adaptive_wins")),
        })
    return metrics
