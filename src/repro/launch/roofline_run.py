"""Roofline analysis runner (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh) cell:
  1. compile UNROLLED probes at 1 and 2 superblocks (3 probes for enc-dec to
     separate the encoder slope), with inner attention/mLSTM chunk loops
     unrolled and grad-accum collapsed — this sidesteps the measured fact
     that XLA cost analysis counts while-loop bodies once;
  2. extrapolate flops / bytes / collective wire-bytes linearly to full depth
     (exact for homogeneous stacks);
  3. add analytic supplements for the non-unrollable time recurrences
     (mamba / sLSTM: repro.perf.flops.recurrence terms);
  4. combine with the scanned dry-run's memory_analysis into a RooflineReport
     (three terms, dominant bottleneck, MODEL_FLOPS/HLO ratio).

Usage:
  PYTHONPATH=src python -m repro.launch.roofline_run --all --out experiments/roofline
  PYTHONPATH=src python -m repro.launch.roofline_run --arch jamba_v0_1_52b --shape train_4k
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import dataclasses
import json
import traceback
from pathlib import Path

import jax

from repro.configs.base import ARCH_IDS, SHAPES, get_config, shape_cells
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import cell_step_and_specs, shardings_for
from repro.perf.flops import cell_flops
from repro.perf.hlo import parse_collectives
from repro.perf.roofline import combine_linear, report_from_counts
from repro.sharding.partition import rules_for_cell, use_rules

__all__ = ["roofline_cell", "main"]


def _probe_costs(cfg, shape, mesh) -> dict:
    """Compile one unrolled probe; return per-device cost dict."""
    rules = rules_for_cell(cfg, shape, mesh)
    with use_rules(rules):
        cell = cell_step_and_specs(cfg, shape, zero_size=mesh.shape.get("data", 1))
        args = tuple(cell.specs[k] for k in cell.specs)
        in_sh = tuple(shardings_for(cell.axes[k], rules) for k in cell.axes)
        donate = (3,) if cell.kind == "decode" else ()
        jitted = jax.jit(cell.step, in_shardings=in_sh, donate_argnums=donate)
        with mesh:
            compiled = jitted.lower(*args).compile()
        cost = compiled.cost_analysis() or {}
        coll = parse_collectives(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "wire_bytes": float(coll.wire_bytes),
        "_counts": dict(coll.counts),
    }


def roofline_cell(
    arch: str, shape_name: str, *, multi_pod: bool = False, overrides: dict | None = None,
    dryrun_dir: Path | None = None, verbose: bool = True,
):
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "multi" if multi_pod else "single"

    # xLSTM probes keep their mLSTM chunk loops scanned (unrolling 16
    # chunks x 16 layers x fwd+bwd sent the SPMD partitioner into slow-compile
    # territory); the chunk interior is covered by the analytic recurrence
    # supplement instead, and the projection matmuls sit outside the loops.
    unroll_inner = not cfg.has_mixer("mlstm")
    base = dict(scan_layers=False, unroll_attn_chunks=unroll_inner, grad_accum=1)
    groups = [cfg.num_superblocks]
    if cfg.is_encdec:
        groups.append(cfg.encoder_layers)

    samples = {}
    probes = [(1,), (2,)] if not cfg.is_encdec else [(1, 1), (2, 1), (1, 2)]
    for probe in probes:
        ov = dict(base, num_superblocks=probe[0])
        if cfg.is_encdec:
            ov["encoder_layers"] = probe[1]
        cfg_p = dataclasses.replace(cfg, **ov)
        samples[probe] = {
            k: v for k, v in _probe_costs(cfg_p, shape, mesh).items() if not k.startswith("_")
        }
    counts = _probe_costs(
        dataclasses.replace(cfg, **dict(base, num_superblocks=1,
                                        **({"encoder_layers": 1} if cfg.is_encdec else {}))),
        shape, mesh
    )["_counts"] if False else {}

    full = tuple(groups)
    combined = combine_linear(samples, full)

    cf = cell_flops(cfg, shape)
    # collective op-kind census from the scanned dry-run record, if available
    mem, coll_counts = {}, {}
    if dryrun_dir:
        rec_path = dryrun_dir / f"{arch}__{shape_name}__{mesh_name}.json"
        if rec_path.exists():
            rec = json.loads(rec_path.read_text())
            mem = rec.get("memory_analysis", {})
            coll_counts = rec.get("collectives", {}).get("counts", {})

    report = report_from_counts(
        arch=arch,
        shape=shape,
        mesh_name=mesh_name,
        n_chips=int(mesh.size),
        flops_per_dev=combined["flops"],
        bytes_per_dev=combined["bytes"],
        collectives={"wire_bytes": combined["wire_bytes"], "counts": coll_counts},
        cfg=cfg,
        supplement_flops_global=cf.recurrence_flops,
        memory_analysis=mem,
        notes=(
            "unrolled 1/2-superblock extrapolation; mamba/sLSTM time-scan "
            "FLOPs supplemented analytically"
            + ("; recurrence supplement material" if cf.recurrence_flops > 0.05 * cf.total else "")
        ),
    )
    if verbose:
        print(
            f"[roofline] {arch:24s} {shape_name:12s} {mesh_name:6s} "
            f"compute={report.compute_s:.3e}s memory={report.memory_s:.3e}s "
            f"collective={report.collective_s:.3e}s dominant={report.dominant:10s} "
            f"useful={report.useful_ratio:.2f} frac={report.roofline_fraction:.3f}"
        )
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", type=str, default="experiments/roofline")
    ap.add_argument("--dryrun-dir", type=str, default="experiments/dryrun")
    args = ap.parse_args(argv)

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    dr = Path(args.dryrun_dir)
    from repro.obs import run_manifest

    # per-cell reports stay lean; one provenance manifest covers the dir
    # (roofline_report skips it when emitting rows)
    (outdir / "manifest.json").write_text(json.dumps(
        run_manifest(config={"mesh": args.mesh, "all": bool(args.all)}),
        indent=2))

    cells = []
    if args.all:
        for arch in ARCH_IDS:
            for sh in shape_cells(get_config(arch)):
                cells.append((arch, sh.name))
    else:
        cells = [(args.arch, args.shape)]

    failures = []
    for arch, shape_name in cells:
        try:
            rep = roofline_cell(
                arch, shape_name, multi_pod=(args.mesh == "multi"), dryrun_dir=dr
            )
            tag = f"{arch}__{shape_name}__{args.mesh}"
            (outdir / f"{tag}.json").write_text(rep.to_json())
        except Exception as e:
            failures.append((arch, shape_name, repr(e)))
            traceback.print_exc()
    if failures:
        print(f"{len(failures)} roofline failures: {failures}")
        return 1
    print(f"roofline table complete: {len(cells)} cells -> {outdir}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
