"""Closed-loop cluster CLI: N adaptive clients sharing E edge servers.

Runs the §6-style closed-loop questions from one command, in two modes:

  * **exact** (default) — per-client state. Solves the fixed point of the
    decision->load map under nominal conditions (who lands where, per-edge
    utilization, best-response iterations), replays the fleet through a
    bandwidth trace with the estimator-lagged adaptive manager per client
    scored against every all-clients static policy, and with
    ``--cross-check`` validates the closed-loop analytic means against the
    event-driven simulators;
  * **mean-field** (``--meanfield``) — class-aggregated offload fractions,
    O(C * E^2) per epoch regardless of N, for fleets far past the exact
    simulator's reach. Solves the damped Wardrop fixed point, prices every
    all-static fleet at the equilibrium's congestion, replays the fraction
    state through the trace, and with ``--cross-check`` gates the
    mean-field solver against the exact one on a count-scaled copy.

Conditions come from the built-in bandwidth-step walk (``--duration`` /
``--bw-drop``) or from a ``--trace`` JSON spec of step breakpoints; a
malformed trace spec is rejected loudly with exit code 2 before any solve.

Usage:
  PYTHONPATH=src python -m repro.launch.cluster_sim --clients 64 \
      --duration 180 --bw-drop 0.15 --out experiments/CLUSTER.json
  PYTHONPATH=src python -m repro.launch.cluster_sim --cluster spec.json \
      --cross-check
  PYTHONPATH=src python -m repro.launch.cluster_sim --meanfield \
      --clients 100000 --trace trace.json --out experiments/MF.json
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from dataclasses import replace
from pathlib import Path

import numpy as np

from repro.core.latency import NetworkPath, ServiceModel, Tier, Workload
from repro.core.scenario import (
    ClientClass,
    ClusterSpec,
    EdgeSpec,
    MeanFieldSpec,
    Scenario,
)
from repro.fleet import (
    Trace,
    TraceBatch,
    cross_check_equilibrium,
    cross_check_meanfield,
    epoch_times,
    simulate_cluster,
    simulate_meanfield,
    solve_equilibrium,
    solve_meanfield_equilibrium,
    static_fractions,
    step_signal,
)

__all__ = [
    "TraceSpecError",
    "default_cluster",
    "default_meanfield",
    "load_trace_spec",
    "trace_signals",
    "main",
]


class TraceSpecError(ValueError):
    """A ``--trace`` JSON spec that cannot mean anything: the CLI prints the
    message and exits 2 rather than guessing."""


def default_cluster(n_clients: int = 64) -> ClusterSpec:
    """The acceptance-criteria cluster: N Orin-class clients at 2 rps each
    contending for four heterogeneous edge tiers over a 20 Mbit path. Sized
    so no single edge can absorb the whole fleet (every all-on-one-edge
    static saturates) while the equilibrium spreads load at moderate
    utilization."""
    base = Scenario(
        workload=Workload(arrival_rate=2.0, req_bytes=30_000, res_bytes=1_000,
                          name="inceptionv4"),
        device=Tier("orin", 0.045),
        edges=(
            EdgeSpec(Tier("a2", 0.028)),
            EdgeSpec(Tier("a100", 0.008)),
            EdgeSpec(Tier("t4-llm", 0.020, service_model=ServiceModel.EXPONENTIAL)),
            EdgeSpec(Tier("edge-mixed", 0.015, service_model=ServiceModel.GENERAL,
                          service_var=0.25 * 0.015**2)),
        ),
        network=NetworkPath(20e6 / 8),
        name="cluster-default-base",
    )
    return ClusterSpec(base=base, n_clients=n_clients,
                       name=f"cluster-{n_clients}x{len(base.edges)}")


def default_meanfield(n_clients: int = 100_000) -> MeanFieldSpec:
    """The built-in mean-field fleet: three bandwidth/rate classes over
    three pooled accelerator tiers on a 20 Mbit path.

    Results are fire-and-forget (``res_bytes=0``): the model prices the
    return path as one queue at the edge's AGGREGATE rate over the client's
    bandwidth, which caps any pooled edge at bandwidth/res_bytes regardless
    of accelerator count — fire-and-forget is the regime where pooling at
    this scale is meaningful.

    Pool sizes scale with ``n_clients`` (the mean-field limit is scale-free,
    so per-edge utilization at the fixed point is size-invariant above the
    25k-client provisioning floor): the reference point is 128/256/256
    accelerators per pool at 100k clients. A fixed-size fleet under a growing
    population saturates instead — model that by passing an explicit
    ``--cluster`` spec, not by scaling the default."""
    if n_clients < 4:
        raise ValueError(f"need at least 4 clients for the 3-class default "
                         f"mix, got {n_clients}")
    pool = max(n_clients, 25_000) / 100_000.0
    base = Scenario(
        workload=Workload(arrival_rate=0.05, req_bytes=30_000, res_bytes=0,
                          name="mf-cli"),
        device=Tier("orin", 0.045),
        edges=(
            EdgeSpec(Tier("a100-pool", 0.008, parallelism_k=128.0 * pool)),
            EdgeSpec(Tier("a2-pool", 0.028, parallelism_k=256.0 * pool)),
            EdgeSpec(Tier("t4-pool", 0.020, parallelism_k=256.0 * pool,
                          service_model=ServiceModel.EXPONENTIAL)),
        ),
        network=NetworkPath(20e6 / 8),
        name="meanfield-default-base",
    )
    steady, light = n_clients // 2, n_clients // 4
    classes = (
        ClientClass(n_clients=steady, arrival_scale=1.0, name="steady"),
        ClientClass(n_clients=light, arrival_scale=0.5, name="light"),
        ClientClass(n_clients=n_clients - steady - light, arrival_scale=2.0,
                    bandwidth_scale=0.5, name="heavy"),
    )
    return MeanFieldSpec(base=base, classes=classes,
                         name=f"meanfield-{n_clients}x{len(base.edges)}")


# -- trace specs --------------------------------------------------------------

_TRACE_KEYS = ("duration_s", "epoch_s", "bandwidth_Bps", "arrival_rate",
               "edge_bg_rate")


def _breakpoints(field: str, val, *, positive: bool) -> list[tuple[float, float]]:
    if not isinstance(val, list) or not val:
        raise TraceSpecError(
            f"{field} must be a non-empty list of [time, value] breakpoints, "
            f"got {val!r}")
    out = []
    for i, p in enumerate(val):
        ok = (isinstance(p, (list, tuple)) and len(p) == 2 and
              all(isinstance(x, (int, float)) and not isinstance(x, bool)
                  for x in p))
        if not ok:
            raise TraceSpecError(
                f"{field}[{i}] must be a [time, value] number pair, got {p!r}")
        t, v = float(p[0]), float(p[1])
        if t < 0:
            raise TraceSpecError(f"{field}[{i}] time must be non-negative, got {t}")
        if positive and v <= 0:
            raise TraceSpecError(f"{field}[{i}] value must be positive, got {v}")
        if v < 0:
            raise TraceSpecError(f"{field}[{i}] value must be non-negative, got {v}")
        out.append((t, v))
    if any(b[0] < a[0] for a, b in zip(out, out[1:])):
        raise TraceSpecError(f"{field} breakpoints must be sorted by time")
    return out


def load_trace_spec(path: Path) -> dict:
    """Parse and validate a ``--trace`` JSON spec.

    Schema (times in seconds, piecewise-constant step breakpoints)::

        {"duration_s": 180.0, "epoch_s": 1.0,
         "bandwidth_Bps": [[0, 2.5e6], [60, 4e5], [120, 2.5e6]],
         "arrival_rate": [[0, 2.0]],              # optional, default: spec's
         "edge_bg_rate": {"1": [[0, 0], [60, 50]]}}  # optional, per edge

    Every way the spec can be malformed — unknown keys, non-numeric or
    unsorted breakpoints, non-positive bandwidth, bad edge keys — raises
    :class:`TraceSpecError` naming the offending field; nothing is silently
    coerced or defaulted."""
    try:
        doc = json.loads(path.read_text())
    except OSError as err:
        raise TraceSpecError(f"cannot read {path}: {err}") from None
    except json.JSONDecodeError as err:
        raise TraceSpecError(f"{path} is not valid JSON: {err}") from None
    if not isinstance(doc, dict):
        raise TraceSpecError(
            f"trace spec must be a JSON object, got {type(doc).__name__}")
    unknown = sorted(set(doc) - set(_TRACE_KEYS))
    if unknown:
        raise TraceSpecError(
            f"unknown trace spec key(s) {', '.join(map(repr, unknown))} "
            f"(known: {', '.join(_TRACE_KEYS)})")
    for key in ("duration_s", "epoch_s"):
        v = doc.get(key)
        if not isinstance(v, (int, float)) or isinstance(v, bool) or v <= 0:
            raise TraceSpecError(f"{key} must be a positive number, got {v!r}")
    if doc["duration_s"] < 2 * doc["epoch_s"]:
        raise TraceSpecError(
            f"duration_s={doc['duration_s']} must cover at least two "
            f"epoch_s={doc['epoch_s']} epochs")
    if "bandwidth_Bps" not in doc:
        raise TraceSpecError("bandwidth_Bps breakpoints are required")
    spec = {"duration_s": float(doc["duration_s"]),
            "epoch_s": float(doc["epoch_s"]),
            "bandwidth_Bps": _breakpoints("bandwidth_Bps", doc["bandwidth_Bps"],
                                          positive=True)}
    if "arrival_rate" in doc:
        spec["arrival_rate"] = _breakpoints("arrival_rate", doc["arrival_rate"],
                                            positive=True)
    if "edge_bg_rate" in doc:
        bg = doc["edge_bg_rate"]
        if not isinstance(bg, dict):
            raise TraceSpecError(
                f"edge_bg_rate must be an object mapping edge index -> "
                f"breakpoints, got {type(bg).__name__}")
        norm = {}
        for k, pts in bg.items():
            try:
                j = int(k)
            except (TypeError, ValueError):
                raise TraceSpecError(
                    f"edge_bg_rate key {k!r} is not an edge index") from None
            norm[j] = _breakpoints(f"edge_bg_rate[{k}]", pts, positive=False)
        spec["edge_bg_rate"] = norm
    return spec


def trace_signals(
    ts: dict, n_edges: int, default_arrival: float,
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Validated trace spec -> (times, bandwidth, arrival, edge_bg) signals.

    ``bandwidth`` and ``arrival`` are (T,) base signals (mean-field mode
    folds per-class scales in afterwards); ``edge_bg`` is (T, E). An edge
    index outside the spec's pool is a :class:`TraceSpecError` — the check
    needs the scenario, so it lives here rather than in the parser."""
    times = epoch_times(ts["duration_s"], ts["epoch_s"])
    bw = step_signal(times, ts["bandwidth_Bps"])
    lam = step_signal(times, ts.get("arrival_rate",
                                    [(0.0, float(default_arrival))]))
    exo = np.zeros((len(times), n_edges))
    for j, pts in ts.get("edge_bg_rate", {}).items():
        if not 0 <= j < n_edges:
            raise TraceSpecError(
                f"edge_bg_rate index {j} out of range for {n_edges} edges")
        exo[:, j] = step_signal(times, pts)
    return times, bw, lam, exo


def _default_trace_spec(args, bw0: float) -> dict:
    """The built-in §5-style walk: bandwidth drops to ``--bw-drop`` x for
    the middle third of the trace."""
    third = args.duration / 3
    return {"duration_s": args.duration, "epoch_s": args.epoch_s,
            "bandwidth_Bps": [(0.0, bw0), (third, bw0 * args.bw_drop),
                              (2 * third, bw0)]}


def _write_report(out: Path | None, report: dict, args=None) -> None:
    if out:
        if "manifest" not in report:
            from repro.obs import run_manifest

            seed = getattr(args, "seed", None)
            config = None
            if args is not None:
                config = {"mode": report.get("mode"),
                          "clients": getattr(args, "clients", None),
                          "duration": getattr(args, "duration", None)}
            report["manifest"] = run_manifest(seed=seed, config=config)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(report, indent=2))
        print(f"wrote {out}")


# -- exact mode ---------------------------------------------------------------


def _run_exact(args, ts: dict | None) -> int:
    if args.cluster is not None:
        spec = ClusterSpec.from_dict(json.loads(args.cluster.read_text()))
    else:
        spec = default_cluster(args.clients)
    n, e = spec.n_clients, spec.n_edges
    bw0 = float(np.asarray(spec.base.network.bandwidth_Bps))
    if ts is None:
        ts = _default_trace_spec(args, bw0)
    times, bw, lam, exo = trace_signals(ts, e, spec.base.workload.arrival_rate)
    trace = Trace(times=times, bandwidth_Bps=bw, arrival_rate=lam,
                  edge_bg_rate=exo)

    # -- equilibrium under nominal conditions ---------------------------------
    t0 = time.perf_counter()
    eq = solve_equilibrium(spec, max_iter=args.max_iter or 20)
    eq_s = time.perf_counter() - t0
    print(f"{spec.name}: {n} clients x {e} edges")
    print(f"equilibrium: {'converged' if eq.converged else 'NOT CONVERGED'} in "
          f"{eq.iterations} iterations ({eq_s*1e3:.0f} ms"
          f"{', damped after oscillation' if eq.oscillation else ''})")
    for tgt, cnt in eq.counts().items():
        if cnt:
            print(f"  {tgt:12s} {cnt:4d} clients")
    print("  edge rho: " + "  ".join(f"{r:.3f}" for r in eq.rho_edges))
    print(f"  mean latency {eq.mean_latency_s*1e3:.2f} ms")

    # -- closed-loop replay on the trace --------------------------------------
    policies = ("adaptive", "on_device") + tuple(f"edge[{j}]" for j in range(e))
    res = simulate_cluster(spec, trace, policies=policies, seed=args.seed,
                           stagger=args.stagger, hysteresis=args.hysteresis)
    # warm throughput: the scan + scoring are compiled now, time a second pass
    t0 = time.perf_counter()
    simulate_cluster(spec, trace, policies=("adaptive",), seed=args.seed,
                     stagger=args.stagger, hysteresis=args.hysteresis)
    rate = res.client_epochs / (time.perf_counter() - t0)
    print(f"closed loop: {res.client_epochs} client-epochs "
          f"({rate/1e3:.0f}k client-epochs/s warm)")
    for name, p in res.policies.items():
        print(f"  {name:12s} mean {p.mean_latency_s*1e3:9.2f} ms  "
              f"offload {p.offload_frac:5.1%}  saturated {p.saturated_epochs}")
    print(f"adaptive beats every static: {res.adaptive_wins}")

    report = {
        "spec": spec.to_dict(),
        "mode": "exact",
        "equilibrium": {
            "iterations": eq.iterations,
            "converged": eq.converged,
            "oscillation": eq.oscillation,
            "counts": eq.counts(),
            "rho_edges": eq.rho_edges.tolist(),
            "mean_latency_s": eq.mean_latency_s,
            "solve_s": eq_s,
        },
        "replay": {
            "client_epochs": res.client_epochs,
            "client_epochs_per_sec": rate,
            "adaptive_wins": res.adaptive_wins,
            "policies": {
                name: {
                    "mean_latency_s": p.mean_latency_s,
                    "offload_frac": p.offload_frac,
                    "saturated_epochs": p.saturated_epochs,
                    "switches": p.switches,
                }
                for name, p in res.policies.items()
            },
        },
    }

    rc = 0 if (eq.converged and res.adaptive_wins) else 1
    if args.cross_check:
        t0 = time.perf_counter()
        cc = cross_check_equilibrium(spec, eq, n=args.check_n, seed=args.seed)
        cc["elapsed_s"] = time.perf_counter() - t0
        report["cross_check"] = cc
        print(f"cross-check ({cc['elapsed_s']:.1f} s):")
        for g in cc["groups"]:
            print(f"  {g['target']:12s} n={g['n_clients']:3d} rho={g['rho']:.3f} "
                  f"analytic {g['analytic_s']*1e3:7.2f} ms vs sim "
                  f"{g['sim_mean_s']*1e3:7.2f} ms -> {g['mape_pct']:.2f}% MAPE")
        gated_max = cc["gated_max_mape_pct"]
        print(f"  gated max MAPE {gated_max:.2f}%"
              if gated_max is not None else "  no gated groups")
        if gated_max is not None and gated_max > 5.0:
            rc = 1

    _write_report(args.out, report, args)
    return rc


# -- mean-field mode ----------------------------------------------------------


def _gate_sized(spec: MeanFieldSpec, cap: int = 256) -> MeanFieldSpec:
    """Count-scaled copy for the exact cross-check. The exact solver is
    per-client, so solver agreement is checked on at most ``cap`` clients
    with the same class mix; a spec already at or under the cap is used
    as-is."""
    if spec.n_total <= cap:
        return spec
    k = spec.n_total / cap
    classes = tuple(replace(c, n_clients=max(1, round(c.n_clients / k)))
                    for c in spec.classes)
    return MeanFieldSpec(base=spec.base, classes=classes,
                         name=f"{spec.name}-gate{cap}")


def _run_meanfield(args, ts: dict | None) -> int:
    if args.cluster is not None:
        spec = MeanFieldSpec.from_dict(json.loads(args.cluster.read_text()))
    else:
        spec = default_meanfield(args.clients)
    c_n, e_n = spec.n_classes, spec.n_edges
    bw0 = float(np.asarray(spec.base.network.bandwidth_Bps))
    if ts is None:
        ts = _default_trace_spec(args, bw0)
    times, bw, lam, exo = trace_signals(ts, e_n, spec.base.workload.arrival_rate)
    # trace columns are per CLASS: the base signals with each class's
    # bandwidth/arrival scale folded in
    traces = TraceBatch(
        times=times,
        bandwidth_Bps=bw[:, None] * np.array(
            [c.bandwidth_scale for c in spec.classes]),
        arrival_rate=lam[:, None] * np.array(
            [c.arrival_scale for c in spec.classes]),
        edge_bg_rate=exo,
    )

    # -- Wardrop fixed point under nominal conditions -------------------------
    t0 = time.perf_counter()
    eq = solve_meanfield_equilibrium(spec, max_iter=args.max_iter or 500)
    eq_s = time.perf_counter() - t0
    print(f"{spec.name}: {spec.n_total} clients in {c_n} classes x {e_n} "
          f"edges (mean-field)")
    print(f"equilibrium: {'converged' if eq.converged else 'NOT CONVERGED'} in "
          f"{eq.iterations} iterations ({eq_s*1e3:.0f} ms, "
          f"regret {eq.regret_pct:.2f}%)")
    for tgt, cnt in eq.expected_counts().items():
        if cnt > 0.5:
            print(f"  {tgt:12s} {cnt:12.1f} expected clients")
    print("  edge rho: " + "  ".join(f"{r:.3f}" for r in eq.rho_edges))
    print(f"  mean latency {eq.mean_latency_s*1e3:.2f} ms")

    # every all-static fleet priced at the fixed point's congestion: the
    # count-weighted staying cost of the one-hot fraction state. At a Wardrop
    # equilibrium every class sits on its cheapest target, so the adaptive
    # mean must undercut every static price — a self-consistency gate, not a
    # counterfactual replay (a static fleet would also induce different load).
    w = spec.class_counts() / spec.n_total
    prices = {}
    for pname in ("on_device",) + tuple(f"edge[{j}]" for j in range(e_n)):
        f = static_fractions(pname, c_n, e_n)
        prices[pname] = float(np.sum(w * np.sum(f * eq.class_latency_s, axis=1)))
    adaptive_wins = bool(all(eq.mean_latency_s <= p * (1 + 1e-9)
                             for p in prices.values()))
    print("static deviation prices at equilibrium congestion:")
    for pname, p in prices.items():
        print(f"  {pname:12s} {p*1e3:9.2f} ms")
    print(f"adaptive undercuts every static price: {adaptive_wins}")

    # -- mean-field replay on the trace ---------------------------------------
    res = simulate_meanfield(spec, traces,
                             switch_fraction=1.0 / args.stagger)  # compile
    t0 = time.perf_counter()
    res = simulate_meanfield(spec, traces, switch_fraction=1.0 / args.stagger)
    rate = res.client_epochs / (time.perf_counter() - t0)
    off = res.offload_frac
    print(f"mean-field replay: {res.client_epochs} client-epochs "
          f"({rate:.3e} client-epochs/s warm)")
    print(f"  mean latency {res.mean_latency_s*1e3:9.2f} ms  "
          f"offload {off.min():5.1%}..{off.max():5.1%}  "
          f"saturated class-epochs {res.saturated_epochs}")

    report = {
        "spec": spec.to_dict(),
        "mode": "meanfield",
        "equilibrium": {
            "iterations": eq.iterations,
            "converged": eq.converged,
            "regret_pct": eq.regret_pct,
            "expected_counts": eq.expected_counts(),
            "rho_edges": eq.rho_edges.tolist(),
            "mean_latency_s": eq.mean_latency_s,
            "offload_frac": eq.offload_frac,
            "solve_s": eq_s,
        },
        "static_prices_s": prices,
        "adaptive_wins": adaptive_wins,
        "replay": {
            "epochs": res.n_epochs,
            "client_epochs": res.client_epochs,
            "client_epochs_per_sec": rate,
            "mean_latency_s": res.mean_latency_s,
            "offload_frac_min": float(off.min()),
            "offload_frac_max": float(off.max()),
            "saturated_epochs": res.saturated_epochs,
            "peak_rho_edges": res.rho_edges.max(axis=0).tolist(),
        },
    }

    rc = 0 if (eq.converged and adaptive_wins) else 1
    if args.cross_check:
        small = _gate_sized(spec)
        t0 = time.perf_counter()
        cc = cross_check_meanfield(small)
        cc_s = time.perf_counter() - t0
        gated = cc["gated_max_mape_pct"]
        conv = bool(cc["meanfield_converged"] and cc["exact_converged"])
        print(f"cross-check vs exact solver on {small.n_total} clients "
              f"({cc_s:.1f} s): "
              + (f"gated max MAPE {gated:.2f}%" if gated is not None
                 else "no gated rows")
              + ("" if conv else "  [a solver did not converge]"))
        report["cross_check"] = {
            "spec": small.name,
            "n_total": small.n_total,
            "elapsed_s": cc_s,
            "gated_max_mape_pct": gated,
            "gated_mean_mape_pct": cc["gated_mean_mape_pct"],
            "converged": conv,
        }
        if not conv or (gated is not None and gated > 5.0):
            rc = 1

    _write_report(args.out, report, args)
    return rc


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--cluster", type=Path, default=None,
                    help="spec JSON: ClusterSpec.to_dict() (exact mode) or "
                         "MeanFieldSpec.to_dict() (--meanfield); default: "
                         "the built-in fleet sized by --clients")
    ap.add_argument("--meanfield", action="store_true",
                    help="mean-field mode: class-aggregated offload "
                         "fractions, O(classes x edges^2) per epoch "
                         "regardless of fleet size")
    ap.add_argument("--clients", type=int, default=64,
                    help="fleet size for the built-in spec (default 64 "
                         "exact; try 100000..1000000 with --meanfield — the "
                         "built-in pools scale with the population)")
    ap.add_argument("--duration", type=float, default=180.0,
                    help="trace duration in seconds (default 180)")
    ap.add_argument("--epoch-s", type=float, default=1.0,
                    help="decision epoch length (default 1.0)")
    ap.add_argument("--bw-drop", type=float, default=0.15,
                    help="bandwidth multiplier for the middle third of the "
                         "trace (default 0.15; 1.0 = constant conditions)")
    ap.add_argument("--trace", type=Path, default=None,
                    help="JSON trace spec of step breakpoints (see "
                         "load_trace_spec; overrides --duration/--epoch-s/"
                         "--bw-drop); malformed specs exit 2")
    ap.add_argument("--stagger", type=int, default=8,
                    help="decision cohorts (desynchronized control epochs; "
                         "default 8, 1 = fully synchronous; in mean-field "
                         "mode 1/stagger of each class re-decides per epoch)")
    ap.add_argument("--hysteresis", type=float, default=0.0,
                    help="relative-improvement switching threshold "
                         "(default 0; exact mode only)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--max-iter", type=int, default=None,
                    help="equilibrium best-response iteration cap (default "
                         "20 exact; 500 for the mean-field solver's damped "
                         "fixed point, which moves fractional mass per step)")
    ap.add_argument("--cross-check", action="store_true",
                    help="exact mode: validate the equilibrium against the "
                         "event-driven simulators (slower); mean-field "
                         "mode: gate the mean-field solver against the "
                         "exact one on a count-scaled copy")
    ap.add_argument("--check-n", type=int, default=120_000,
                    help="simulated jobs per cross-check group (default "
                         "120000; exact mode only)")
    ap.add_argument("--out", type=Path, default=None,
                    help="write the full report JSON here")
    args = ap.parse_args(argv)

    try:
        ts = load_trace_spec(args.trace) if args.trace is not None else None
        if args.meanfield:
            return _run_meanfield(args, ts)
        return _run_exact(args, ts)
    except TraceSpecError as err:
        print(f"error: bad trace spec: {err}", file=sys.stderr)
        return 2


if __name__ == "__main__":
    raise SystemExit(main())
