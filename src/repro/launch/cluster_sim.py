"""Closed-loop cluster CLI: N adaptive clients sharing E edge servers.

Runs the three §6-style closed-loop questions from one command:

  * **equilibrium** — solve the fixed point of the decision->load map under
    the spec's nominal conditions (who lands where, per-edge utilization,
    how many best-response iterations);
  * **replay** — drive the fleet through a bandwidth-step trace with the
    estimator-lagged adaptive manager per client, scored against every
    all-clients static policy under the true conditions;
  * **cross-check** (``--cross-check``) — validate the closed-loop analytic
    means against the event-driven simulators, the PR 3 differential
    pattern applied to the equilibrium assignment.

Usage:
  PYTHONPATH=src python -m repro.launch.cluster_sim --clients 64 \
      --duration 180 --bw-drop 0.15 --out experiments/CLUSTER.json
  PYTHONPATH=src python -m repro.launch.cluster_sim --cluster spec.json \
      --cross-check
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

from repro.core.latency import NetworkPath, ServiceModel, Tier, Workload
from repro.core.scenario import ClusterSpec, EdgeSpec, Scenario
from repro.fleet import (
    cross_check_equilibrium,
    make_trace,
    simulate_cluster,
    solve_equilibrium,
    step_signal,
)

__all__ = ["default_cluster", "main"]


def default_cluster(n_clients: int = 64) -> ClusterSpec:
    """The acceptance-criteria cluster: N Orin-class clients at 2 rps each
    contending for four heterogeneous edge tiers over a 20 Mbit path. Sized
    so no single edge can absorb the whole fleet (every all-on-one-edge
    static saturates) while the equilibrium spreads load at moderate
    utilization."""
    base = Scenario(
        workload=Workload(arrival_rate=2.0, req_bytes=30_000, res_bytes=1_000,
                          name="inceptionv4"),
        device=Tier("orin", 0.045),
        edges=(
            EdgeSpec(Tier("a2", 0.028)),
            EdgeSpec(Tier("a100", 0.008)),
            EdgeSpec(Tier("t4-llm", 0.020, service_model=ServiceModel.EXPONENTIAL)),
            EdgeSpec(Tier("edge-mixed", 0.015, service_model=ServiceModel.GENERAL,
                          service_var=0.25 * 0.015**2)),
        ),
        network=NetworkPath(20e6 / 8),
        name="cluster-default-base",
    )
    return ClusterSpec(base=base, n_clients=n_clients,
                       name=f"cluster-{n_clients}x{len(base.edges)}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--cluster", type=Path, default=None,
                    help="ClusterSpec.to_dict() JSON (default: built-in 64x4)")
    ap.add_argument("--clients", type=int, default=64,
                    help="fleet size for the built-in spec (default 64)")
    ap.add_argument("--duration", type=float, default=180.0,
                    help="trace duration in seconds (default 180)")
    ap.add_argument("--epoch-s", type=float, default=1.0,
                    help="decision epoch length (default 1.0)")
    ap.add_argument("--bw-drop", type=float, default=0.15,
                    help="bandwidth multiplier for the middle third of the "
                         "trace (default 0.15; 1.0 = constant conditions)")
    ap.add_argument("--stagger", type=int, default=8,
                    help="decision cohorts (desynchronized control epochs; "
                         "default 8, 1 = fully synchronous)")
    ap.add_argument("--hysteresis", type=float, default=0.0,
                    help="relative-improvement switching threshold (default 0)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--max-iter", type=int, default=20,
                    help="equilibrium best-response iteration cap (default 20)")
    ap.add_argument("--cross-check", action="store_true",
                    help="validate the equilibrium against the event-driven "
                         "simulators (slower)")
    ap.add_argument("--check-n", type=int, default=120_000,
                    help="simulated jobs per cross-check group (default 120000)")
    ap.add_argument("--out", type=Path, default=None,
                    help="write the full report JSON here")
    args = ap.parse_args(argv)

    if args.cluster is not None:
        spec = ClusterSpec.from_dict(json.loads(args.cluster.read_text()))
    else:
        spec = default_cluster(args.clients)
    n, e = spec.n_clients, spec.n_edges

    # -- equilibrium under nominal conditions ---------------------------------
    t0 = time.perf_counter()
    eq = solve_equilibrium(spec, max_iter=args.max_iter)
    eq_s = time.perf_counter() - t0
    print(f"{spec.name}: {n} clients x {e} edges")
    print(f"equilibrium: {'converged' if eq.converged else 'NOT CONVERGED'} in "
          f"{eq.iterations} iterations ({eq_s*1e3:.0f} ms"
          f"{', damped after oscillation' if eq.oscillation else ''})")
    for tgt, cnt in eq.counts().items():
        if cnt:
            print(f"  {tgt:12s} {cnt:4d} clients")
    print("  edge rho: " + "  ".join(f"{r:.3f}" for r in eq.rho_edges))
    print(f"  mean latency {eq.mean_latency_s*1e3:.2f} ms")

    # -- closed-loop replay on a bandwidth-step trace --------------------------
    bw0 = float(np.asarray(spec.base.network.bandwidth_Bps))
    third = args.duration / 3
    trace = make_trace(
        args.duration, args.epoch_s,
        bandwidth_Bps=lambda t: step_signal(
            t, [(0, bw0), (third, bw0 * args.bw_drop), (2 * third, bw0)]),
        arrival_rate=spec.base.workload.arrival_rate,
    )
    policies = ("adaptive", "on_device") + tuple(f"edge[{j}]" for j in range(e))
    res = simulate_cluster(spec, trace, policies=policies, seed=args.seed,
                           stagger=args.stagger, hysteresis=args.hysteresis)
    # warm throughput: the scan + scoring are compiled now, time a second pass
    t0 = time.perf_counter()
    simulate_cluster(spec, trace, policies=("adaptive",), seed=args.seed,
                     stagger=args.stagger, hysteresis=args.hysteresis)
    rate = res.client_epochs / (time.perf_counter() - t0)
    print(f"closed loop: {res.client_epochs} client-epochs "
          f"({rate/1e3:.0f}k client-epochs/s warm)")
    for name, p in res.policies.items():
        print(f"  {name:12s} mean {p.mean_latency_s*1e3:9.2f} ms  "
              f"offload {p.offload_frac:5.1%}  saturated {p.saturated_epochs}")
    print(f"adaptive beats every static: {res.adaptive_wins}")

    report = {
        "spec": spec.to_dict(),
        "equilibrium": {
            "iterations": eq.iterations,
            "converged": eq.converged,
            "oscillation": eq.oscillation,
            "counts": eq.counts(),
            "rho_edges": eq.rho_edges.tolist(),
            "mean_latency_s": eq.mean_latency_s,
            "solve_s": eq_s,
        },
        "replay": {
            "client_epochs": res.client_epochs,
            "client_epochs_per_sec": rate,
            "adaptive_wins": res.adaptive_wins,
            "policies": {
                name: {
                    "mean_latency_s": p.mean_latency_s,
                    "offload_frac": p.offload_frac,
                    "saturated_epochs": p.saturated_epochs,
                    "switches": p.switches,
                }
                for name, p in res.policies.items()
            },
        },
    }

    rc = 0 if (eq.converged and res.adaptive_wins) else 1
    if args.cross_check:
        t0 = time.perf_counter()
        cc = cross_check_equilibrium(spec, eq, n=args.check_n, seed=args.seed)
        cc["elapsed_s"] = time.perf_counter() - t0
        report["cross_check"] = cc
        print(f"cross-check ({cc['elapsed_s']:.1f} s):")
        for g in cc["groups"]:
            print(f"  {g['target']:12s} n={g['n_clients']:3d} rho={g['rho']:.3f} "
                  f"analytic {g['analytic_s']*1e3:7.2f} ms vs sim "
                  f"{g['sim_mean_s']*1e3:7.2f} ms -> {g['mape_pct']:.2f}% MAPE")
        gated_max = cc["gated_max_mape_pct"]
        print(f"  gated max MAPE {gated_max:.2f}%"
              if gated_max is not None else "  no gated groups")
        if gated_max is not None and gated_max > 5.0:
            rc = 1

    if args.out:
        args.out.parent.mkdir(parents=True, exist_ok=True)
        args.out.write_text(json.dumps(report, indent=2))
        print(f"wrote {args.out}")
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
