"""Fleet-sizing CLI: minimum deployment meeting a p99 SLO for N clients.

Inverts the closed-loop model: instead of predicting latency for a given
fleet, search the smallest ``(n_edges, accelerator tier, bandwidth)`` whose
decision equilibrium keeps every client's p99 within budget.  Feasibility of
each candidate is one :func:`repro.fleet.solve_equilibrium` with clients
best-responding on exact Euler-inverted quantiles; the search is monotone
bisection per axis (see :mod:`repro.plan.provision`).

Usage:
  PYTHONPATH=src python -m repro.launch.provision --clients 48 --slo-ms 120
  PYTHONPATH=src python -m repro.launch.provision --space space.json \
      --clients 64 --slo-ms 150 --check-minimal --out PLAN.json
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

from repro.core.latency import NetworkPath, Tier, Workload
from repro.core.scenario import EdgeSpec, Scenario
from repro.plan import ProvisionSpace, provision

__all__ = ["default_space", "main"]


def default_space() -> ProvisionSpace:
    """The README's worked example: CPU-bound clients (80 ms on-device, so a
    120 ms p99 budget forces offloading) choosing over a three-rung
    accelerator ladder and a 5..40 Mbit shared uplink."""
    base = Scenario(
        workload=Workload(arrival_rate=4.0, req_bytes=30_000, res_bytes=1_000,
                          name="inceptionv4"),
        device=Tier("cpu-only", 0.08),
        edges=(EdgeSpec(Tier("edge", 0.02)),),
        network=NetworkPath(20e6 / 8),
        name="provision-default-base",
    )
    return ProvisionSpace(
        base=base,
        tiers=(Tier("t4", 0.020), Tier("a2", 0.012), Tier("a100", 0.006)),
        max_edges=8,
        bandwidths_Bps=(5e6 / 8, 10e6 / 8, 20e6 / 8, 40e6 / 8),
        name="provision-default",
    )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--space", type=Path, default=None,
                    help="ProvisionSpace.to_dict() JSON (default: built-in "
                         "3-tier ladder, up to 8 edges, 5..40 Mbit)")
    ap.add_argument("--clients", type=int, default=48,
                    help="fleet size N to provision for (default 48)")
    ap.add_argument("--slo-ms", type=float, default=120.0,
                    help="p-quantile latency budget in ms (default 120)")
    ap.add_argument("--q", type=float, default=0.99,
                    help="SLO quantile (default 0.99)")
    ap.add_argument("--tail-method", default="euler",
                    choices=("euler", "asymptote"),
                    help="quantile engine for feasibility (default euler)")
    ap.add_argument("--max-iter", type=int, default=20,
                    help="equilibrium best-response iteration cap (default 20)")
    ap.add_argument("--check-minimal", action="store_true",
                    help="re-probe the three single-resource decrements and "
                         "assert each violates the SLO (slower)")
    ap.add_argument("--out", type=Path, default=None,
                    help="write the plan JSON here")
    args = ap.parse_args(argv)

    if args.space is not None:
        space = ProvisionSpace.from_dict(json.loads(args.space.read_text()))
    else:
        space = default_space()
    slo_s = args.slo_ms / 1e3

    print(f"{space.name}: N={args.clients} clients, p{args.q * 100:g} <= "
          f"{args.slo_ms:g} ms ({args.tail_method} tails)")
    print(f"  search space: 1..{space.max_edges} edges x "
          f"{len(space.tiers)} tiers ({', '.join(t.name for t in space.tiers)}) x "
          f"{len(space.bandwidths_Bps)} bandwidths "
          f"({', '.join(f'{b * 8 / 1e6:g}' for b in space.bandwidths_Bps)} Mbit)")

    t0 = time.perf_counter()
    plan = provision(space, args.clients, slo_s, q=args.q,
                     tail_method=args.tail_method, max_iter=args.max_iter)
    solve_s = time.perf_counter() - t0

    if plan is None:
        grid = space.max_edges * len(space.tiers) * len(space.bandwidths_Bps)
        print(f"INFEASIBLE: even {space.max_edges}x {space.tiers[-1].name} at "
              f"{space.bandwidths_Bps[-1] * 8 / 1e6:g} Mbit misses the budget "
              f"({solve_s:.1f} s)")
        print(f"  (searched by bisection; exhaustive grid would be {grid} "
              "equilibrium solves)")
        return 1

    print(f"plan ({solve_s:.1f} s, {plan.evaluations} equilibrium solves):")
    print(f"  {plan.n_edges} x {plan.tier.name} "
          f"(s_edge {plan.tier.service_time_s * 1e3:g} ms) @ "
          f"{plan.bandwidth_Bps * 8 / 1e6:g} Mbit")
    print(f"  worst-client p{plan.q * 100:g} {plan.max_latency_s * 1e3:.1f} ms "
          f"(slack {plan.slack_s * 1e3:.1f} ms), "
          f"mean {plan.mean_latency_s * 1e3:.1f} ms")
    for tgt, cnt in plan.counts.items():
        if cnt:
            print(f"  {tgt:12s} {cnt:4d} clients")
    print("  edge rho: " + "  ".join(f"{r:.3f}" for r in plan.rho_edges))

    rc = 0
    if args.check_minimal:
        from repro.fleet import solve_equilibrium

        def infeasible(n_edges, ti, bi, label):
            spec = space.cluster_spec(n_edges, ti, bi, args.clients)
            eq = solve_equilibrium(spec, max_iter=args.max_iter,
                                   slo_quantile=args.q,
                                   tail_method=plan.tail_method)
            ok = not eq.meets_slo(slo_s)
            print(f"  {label:24s} {'violates SLO (minimal)' if ok else 'STILL FEASIBLE'}")
            return ok

        print("minimality probes:")
        probes = []
        if plan.n_edges > 1:
            probes.append(infeasible(plan.n_edges - 1, len(space.tiers) - 1,
                                     len(space.bandwidths_Bps) - 1,
                                     f"{plan.n_edges - 1} edges (best rest)"))
        if plan.tier_index > 0:
            probes.append(infeasible(plan.n_edges, plan.tier_index - 1,
                                     len(space.bandwidths_Bps) - 1,
                                     f"tier {space.tiers[plan.tier_index - 1].name}"))
        if plan.bandwidth_index > 0:
            bw = space.bandwidths_Bps[plan.bandwidth_index - 1]
            probes.append(infeasible(plan.n_edges, plan.tier_index,
                                     plan.bandwidth_index - 1,
                                     f"{bw * 8 / 1e6:g} Mbit"))
        if not probes:
            print("  plan is the cheapest corner of the space; nothing to probe")
        elif not all(probes):
            rc = 1

    if args.out:
        from repro.obs import run_manifest

        args.out.parent.mkdir(parents=True, exist_ok=True)
        report = {"space": space.to_dict(), "plan": plan.to_dict(),
                  "solve_s": solve_s,
                  "manifest": run_manifest(config={
                      "clients": args.clients, "slo_ms": args.slo_ms,
                      "q": args.q})}
        args.out.write_text(json.dumps(report, indent=2))
        print(f"wrote {args.out}")
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
