"""Model-validation CLI: differential fidelity report over the golden corpus.

Pushes every golden-corpus scenario through all four evaluation paths
(scalar/vectorized closed forms, scalar/batched simulators) and writes
``VALIDATION.json`` — the repo's analogue of the paper's observed-vs-predicted
latency table (§4.3: 2.2% mean MAPE, 91.5% within ±5%). Exit status is the
gate: nonzero when any of the five sub-gates fail — scalar-vs-vectorized
agreement (means and tail quantiles), the golden pins, the
analytic-vs-simulated MAPE budget, the tail-percentile budget, or the
mean-field-vs-exact equilibrium solver agreement.

The gate itself lives in ``repro.exp.payloads.run_validate`` — this CLI is a
thin shim over the same engine the experiment registry runs (the
``validate-smoke`` / ``validate-full`` specs), so ``reproduce`` and this
entry point can never disagree. Flags and exit codes are unchanged; the
report lands under the launch-wide ``results/`` convention by default
(explicit ``--out`` paths keep working).

Usage:
  PYTHONPATH=src python -m repro.launch.validate                  # full gate
  PYTHONPATH=src python -m repro.launch.validate --smoke          # tier-1 subset
  PYTHONPATH=src python -m repro.launch.validate --regenerate     # rebuild fixture
  PYTHONPATH=src python -m repro.launch.validate --out results/VALIDATION.json
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.exp.payloads import run_validate
from repro.validate import (
    DEFAULT_MAPE_BUDGET_PCT,
    DEFAULT_SEED,
    DEFAULT_TAIL_BUDGET_PCT,
    DEFAULT_TAIL_PCT,
    default_fixture_path,
    generate_corpus,
    save_corpus,
)

__all__ = ["main"]


def _print_report(rep, elapsed_s: float) -> None:
    d = rep.to_dict()
    vec = d["scalar_vs_vec"]
    gold = d["golden"]
    gate = d["mape_gate"]
    print(f"validated {d['config']['n_entries']} scenarios in {elapsed_s:.1f}s")
    print(f"  scalar vs vectorized analytic: max rel err {vec['max_rel_err']:.2e} "
          f"(tol {vec['tol']:.0e}) -> {'PASS' if vec['passed'] else 'FAIL'}")
    if gold["max_rel_err"] is not None:
        print(f"  golden totals pin:             max rel err {gold['max_rel_err']:.2e} "
              f"(tol {gold['tol']:.0e}) -> {'PASS' if gold['passed'] else 'FAIL'}")
    if gate["n"] == 0:
        print("  analytic vs simulated (gated): not exercised (no simulated "
              "gated entries)")
    else:
        print(f"  analytic vs simulated (gated): mean MAPE {gate['mean_pct']:.2f}% "
              f"over {gate['n']} scenarios (budget {gate['budget_pct']:.1f}%, "
              f"max {gate['max_pct']:.2f}%, {gate['within_5_frac']:.0%} within ±5%) "
              f"-> {'PASS' if gate['passed'] else 'FAIL'}")
    tvec = d["scalar_vs_vec_tail"]
    print(f"  scalar vs vectorized tail:     max rel err {tvec['max_rel_err']:.2e} "
          f"(tol {tvec['tol']:.0e}) -> {'PASS' if tvec['passed'] else 'FAIL'}")
    ev = d["tail_euler_vec"]
    if ev["max_rel_err"] is None:
        print("  batched exact euler inversion: not exercised (no entries at "
              f"rho <= {ev['rho_max']:.2f})")
    else:
        print(f"  batched exact euler inversion: max rel err {ev['max_rel_err']:.2e} "
              f"over {ev['n_entries']} entries at rho <= {ev['rho_max']:.2f} "
              f"(tol {ev['tol']:.0e}) -> {'PASS' if ev['passed'] else 'FAIL'}")
    mf = d["meanfield_gate"]
    if mf is None:
        print("  mean-field vs exact solver:    skipped")
    elif not mf["converged"]:
        print("  mean-field vs exact solver:    FAIL (a solver did not converge)")
    else:
        print(f"  mean-field vs exact solver:    max gated MAPE "
              f"{mf['gated_max_mape_pct']:.2f}% over {mf['n_specs']} fleets "
              f"(budget {mf['budget_pct']:.1f}%) "
              f"-> {'PASS' if mf['passed'] else 'FAIL'}")
    tg = d["tail_gate"]
    if tg["n"] == 0:
        print(f"  analytic p{tg['tail_pct']:.0f} vs simulated:     not exercised "
              "(no tail-gated entries)")
    else:
        print(f"  analytic p{tg['tail_pct']:.0f} vs simulated:     mean MAPE "
              f"{tg['mean_pct']:.2f}% over {tg['n']} scenarios "
              f"(budget {tg['budget_pct']:.1f}%, max {tg['max_pct']:.2f}%) "
              f"-> {'PASS' if tg['passed'] else 'FAIL'}")
    print("  per-band MAPE (all simulated entries):")
    for band, s in d["bands"].items():
        print(f"    {band:8s} n={s['n']:2d} mean {s['mean_pct']:6.2f}%  "
              f"max {s['max_pct']:6.2f}%  ±5% {s['within_5_frac']:.0%}")
    print("  per-regime MAPE:")
    for regime, s in d["regimes"].items():
        print(f"    {regime:22s} n={s['n']:2d} mean {s['mean_pct']:6.2f}%  "
              f"max {s['max_pct']:6.2f}%")
    if d["sim_cross"]:
        print(f"  scalar vs batched simulator:   mean MAPE "
              f"{d['sim_cross']['mean_mape_pct']:.2f}% over "
              f"{int(d['sim_cross']['n_entries'])} entries")
    print(f"overall: {'PASS' if rep.passed else 'FAIL'}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--corpus", type=Path, default=None,
                    help="corpus fixture JSON (default: tests/golden/corpus_v1.json, "
                         "regenerated in-memory when missing)")
    ap.add_argument("--regenerate", action="store_true",
                    help="regenerate the corpus fixture from --seed and exit")
    ap.add_argument("--seed", type=int, default=DEFAULT_SEED,
                    help="corpus generation seed (with --regenerate) and sim seed")
    ap.add_argument("--smoke", action="store_true",
                    help="fast tier-1 subset with short simulations")
    ap.add_argument("--n", type=int, default=None,
                    help="base simulated jobs per scenario (default 120000; 20000 with --smoke)")
    ap.add_argument("--max-n-factor", type=float, default=None,
                    help="cap on the near-saturation n multiplier (default 6; 2 with --smoke)")
    ap.add_argument("--budget", type=float, default=DEFAULT_MAPE_BUDGET_PCT,
                    help="MAPE gate budget in percent (default 5.0)")
    ap.add_argument("--tail-pct", type=float, default=DEFAULT_TAIL_PCT,
                    help="latency percentile for the tail gate (default 99)")
    ap.add_argument("--tail-budget", type=float, default=DEFAULT_TAIL_BUDGET_PCT,
                    help="tail-percentile gate budget in percent (default 10.0)")
    ap.add_argument("--bootstrap", type=int, default=200,
                    help="bootstrap replicates per simulated mean")
    ap.add_argument("--no-sim", action="store_true",
                    help="skip simulation (analytic agreement + golden pins only)")
    ap.add_argument("--out", type=Path, default=Path("results/VALIDATION.json"),
                    help="fidelity report path (default results/VALIDATION.json)")
    args = ap.parse_args(argv)

    fixture = args.corpus if args.corpus is not None else default_fixture_path()
    if args.regenerate:
        entries = generate_corpus(args.seed)
        save_corpus(entries, fixture, seed=args.seed)
        print(f"wrote {len(entries)} corpus entries to {fixture}")
        return 0

    rep, d = run_validate(
        seed=args.seed,
        smoke=args.smoke,
        corpus=args.corpus,
        base_n=args.n,
        max_n_factor=args.max_n_factor,
        budget_pct=args.budget,
        tail_pct=args.tail_pct,
        tail_budget_pct=args.tail_budget,
        bootstrap=args.bootstrap,
        simulate=not args.no_sim,
    )
    args.out.parent.mkdir(parents=True, exist_ok=True)
    args.out.write_text(json.dumps(d, indent=2))
    _print_report(rep, d["corpus"]["elapsed_s"])
    print(f"wrote {args.out}")
    return 0 if rep.passed else 1


if __name__ == "__main__":
    raise SystemExit(main())
