"""Observability report CLI: render traces + decision audits, or demo them.

Two modes:

  # render saved observability streams into a markdown report
  PYTHONPATH=src python -m repro.launch.obs_report \\
      --trace trace.jsonl --audit audit.jsonl --out report.md

  # self-contained worked example: a bandwidth-step gateway scenario plus a
  # simulated-clock engine run, exporting every observability artifact
  PYTHONPATH=src python -m repro.launch.obs_report --demo --out-dir obs_demo

``--demo`` writes into ``--out-dir``:

  * ``trace.jsonl``       — span stream (canonical JSONL, byte-stable per seed)
  * ``trace.chrome.json`` — Chrome trace_event export; load at
    https://ui.perfetto.dev to see the decide/transfer/queue/prefill/decode/
    respond lanes
  * ``audit.jsonl``       — per-decision closed-form term decompositions
  * ``manifest.json``     — run provenance (seed, config hash, git, versions)
  * ``report.md``         — the rendered report, flips explained term-by-term

The demo replays the paper's Fig. 6 arc: bandwidth steps 20 -> 10 -> 2 -> 20
Mbps while the gateway runs Algorithm 1 each epoch, so the audit log contains
real strategy flips for :func:`repro.obs.explain_flip` to decompose.
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.obs import (
    AuditLog,
    MetricsRegistry,
    Tracer,
    merge,
    render_report,
    run_manifest,
)

__all__ = ["main", "run_demo"]

DEMO_SCHEDULE_MBPS = (20.0, 20.0, 10.0, 10.0, 2.0, 2.0, 2.0, 20.0, 20.0)


def _demo_gateway(tracer: Tracer, auditor: AuditLog, metrics: MetricsRegistry,
                  *, rps: float = 10.0) -> None:
    """Bandwidth-step scenario on the deployable gateway (model-only: the
    device tier is a declared profile, no engine needed for the decisions)."""
    from repro.core.latency import ServiceModel, Tier, Workload
    from repro.serving.gateway import EdgeHandle, OffloadGateway

    s_dev = 0.080  # 80 ms on-device service
    req_bytes = int(0.8 * s_dev * 0.625e6)  # bandwidth crossover near 5 Mbps
    gw = OffloadGateway(
        Tier("device", s_dev, service_model=ServiceModel.EXPONENTIAL),
        [EdgeHandle("edge0", service_mean_s=s_dev / 8, parallelism_k=4.0)],
        Workload(rps, req_bytes, max(1, req_bytes // 5)),
        bandwidth_Bps=2.5e6,
        auditor=auditor,
        tracer=tracer,
        metrics=metrics,
    )
    for i, mbps in enumerate(DEMO_SCHEDULE_MBPS):
        for _ in range(3):
            gw.observe_bandwidth(mbps * 1e6 / 8)
        n = max(1, int(rps))
        for k in range(n):
            gw.observe_arrival(i + k / n)
        gw.decide(now=i + 1.0)


def _demo_engine(tracer: Tracer, *, seed: int, n_requests: int) -> None:
    """Simulated-clock engine run: fills the queue/prefill/decode/respond
    lanes with a real request lifecycle (seeded => byte-stable trace)."""
    from repro.measure import HarnessConfig, run_harness

    hc = HarnessConfig(arch="starcoder2_3b", slots=2, seed=seed,
                       n_requests=n_requests, clock="simulated")
    run_harness(hc, tracer=tracer)


def run_demo(out_dir: Path, *, seed: int = 0, n_requests: int = 12,
             engine: bool = True) -> dict:
    """Produce the full demo artifact set; returns {artifact name: path}."""
    out_dir.mkdir(parents=True, exist_ok=True)
    gw_tracer = Tracer()
    auditor = AuditLog()
    metrics = MetricsRegistry()
    _demo_gateway(gw_tracer, auditor, metrics)
    tracers = [gw_tracer]
    if engine:
        eng_tracer = Tracer()
        _demo_engine(eng_tracer, seed=seed, n_requests=n_requests)
        tracers.append(eng_tracer)
    tracer = merge(tracers)
    auditor.verify()

    paths = {
        "trace.jsonl": tracer.write_jsonl(out_dir / "trace.jsonl"),
        "trace.chrome.json": tracer.write_chrome(out_dir / "trace.chrome.json"),
        "audit.jsonl": auditor.write_jsonl(out_dir / "audit.jsonl"),
    }
    manifest = run_manifest(seed=seed, config={
        "demo": True, "schedule_Mbps": list(DEMO_SCHEDULE_MBPS),
        "engine": engine, "n_requests": n_requests,
    })
    mpath = out_dir / "manifest.json"
    mpath.write_text(json.dumps(manifest, indent=2, sort_keys=True) + "\n")
    paths["manifest.json"] = mpath
    report = render_report(tracer=tracer, audit=auditor, metrics=metrics,
                           title="Observability demo (Fig. 6 bandwidth steps)")
    rpath = out_dir / "report.md"
    rpath.write_text(report)
    paths["report.md"] = rpath
    return paths


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--trace", type=Path, default=None,
                    help="span trace JSONL (Tracer.write_jsonl output)")
    ap.add_argument("--audit", type=Path, default=None,
                    help="decision audit JSONL (AuditLog.write_jsonl output)")
    ap.add_argument("--out", type=Path, default=None,
                    help="write the markdown report here (default: stdout)")
    ap.add_argument("--title", default="Observability report")
    ap.add_argument("--demo", action="store_true",
                    help="run the bandwidth-step demo and export all artifacts")
    ap.add_argument("--out-dir", type=Path, default=Path("obs_demo"),
                    help="demo artifact directory (default ./obs_demo)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--requests", type=int, default=12,
                    help="demo engine requests (default 12)")
    ap.add_argument("--no-engine", action="store_true",
                    help="demo: skip the engine run (gateway decisions only)")
    args = ap.parse_args(argv)

    if args.demo:
        paths = run_demo(args.out_dir, seed=args.seed,
                         n_requests=args.requests, engine=not args.no_engine)
        for name, path in paths.items():
            print(f"wrote {path}")
        print(f"load {paths['trace.chrome.json']} at https://ui.perfetto.dev")
        return 0

    if args.trace is None and args.audit is None:
        ap.error("nothing to render: pass --trace and/or --audit, or --demo")
    tracer = Tracer.read_jsonl(args.trace) if args.trace else None
    audit = AuditLog.read_jsonl(args.audit) if args.audit else None
    report = render_report(tracer=tracer, audit=audit, title=args.title)
    if args.out is not None:
        args.out.parent.mkdir(parents=True, exist_ok=True)
        args.out.write_text(report)
        print(f"wrote {args.out}")
    else:
        print(report)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
