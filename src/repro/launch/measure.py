"""Hardware-in-the-loop profiling CLI: profile / fit / validate.

Closes the paper's experimental loop from the command line:

  # run the engine under Poisson load, fit distributions, write a profile
  PYTHONPATH=src python -m repro.launch.measure profile --config starcoder2_3b \\
      --slots 1 --requests 240 --seed 0 --out PROFILE_starcoder2_3b.json

  # refit a saved trace (e.g. after changing fit thresholds)
  PYTHONPATH=src python -m repro.launch.measure fit --trace TRACE.json --out PROFILE.json

  # gate analytic mean/p99 against the observed engine latencies
  PYTHONPATH=src python -m repro.launch.measure validate --profile PROFILE.json

Profiling runs are seeded and (on the default simulated clock) bit-replayable:
the same command produces the same profile JSON. ``--clock wall`` times the
real hardware instead. ``validate`` exits nonzero when the gate fails.
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

from repro.measure import (
    HarnessConfig,
    MeasuredTrace,
    build_profile,
    load_profile,
    run_harness,
)
from repro.obs import run_manifest
from repro.validate.measured import (
    DEFAULT_MEASURED_BUDGET_PCT,
    DEFAULT_MEASURED_TAIL_BUDGET_PCT,
    run_measured_gate,
)

__all__ = ["main"]


def _print_profile(profile) -> None:
    print(f"profiled {profile.arch} ({profile.clock} clock, seed {profile.seed}): "
          f"{profile.n_requests} requests, slots={profile.slots}, "
          f"lambda={profile.arrival_rate:.2f} req/s")
    print(f"  observed: mean latency {profile.observed_stat('latency_mean_s')*1e3:.3f} ms, "
          f"p99 {profile.observed_stat('latency_p99_s')*1e3:.3f} ms, "
          f"rho_hat {profile.observed_stat('rho_hat'):.3f}")
    print("  fits (phase, occupancy): mean / SCV / model")
    for f in profile.fits:
        print(f"    {f.phase:8s} occ={f.occupancy}  n={f.n:4d}  "
              f"{f.mean_s*1e3:9.4f} ms  scv={f.scv:6.3f}  {f.model.value}  "
              f"(CI ±{f.ci_half_width_pct:.1f}%)")


def _print_gate(rep) -> None:
    d = rep.to_dict()
    m, t, v = d["mean"], d["tail"], d["vec"]
    print(f"measured gate: {rep.arch} occ={rep.occupancy} rho={rep.rho:.3f} "
          f"({rep.n_requests} requests, {rep.clock} clock)")
    print(f"  mean:  analytic {m['analytic_s']*1e3:.3f} ms vs observed "
          f"{m['observed_s']*1e3:.3f} ms -> MAPE {m['mape_pct']:.2f}% "
          f"(budget {m['budget_pct']:.1f}%, CI floor ±{m['ci_half_width_pct']:.1f}%) "
          f"-> {'PASS' if m['passed'] else 'FAIL'}")
    print(f"  p{t['pct']:g}:   analytic {t['analytic_s']*1e3:.3f} ms vs observed "
          f"{t['observed_s']*1e3:.3f} ms -> MAPE {t['mape_pct']:.2f}% "
          f"(budget {t['budget_pct']:.1f}%) -> {'PASS' if t['passed'] else 'FAIL'}")
    print(f"  fleet.analytic_vec consistency: rel err {v['rel_err']:.2e} "
          f"(tol {v['tol']:.0e}) -> {'PASS' if v['passed'] else 'FAIL'}")
    print(f"overall: {'PASS' if rep.passed else 'FAIL'}")


def _add_profile_args(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--config", "--arch", dest="arch", default="starcoder2_3b",
                    help="model-zoo config to profile (default starcoder2_3b)")
    ap.add_argument("--slots", type=int, default=1,
                    help="engine decode slots / target batch occupancy (default 1)")
    ap.add_argument("--requests", type=int, default=240,
                    help="recorded requests (default 240)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--clock", choices=("simulated", "wall"), default="simulated",
                    help="simulated = seeded cost-model clock (replayable); "
                         "wall = real hardware timing")
    ap.add_argument("--arrival-rate", type=float, default=None,
                    help="lambda in req/s (default: derived from --target-rho)")
    ap.add_argument("--target-rho", type=float, default=0.45,
                    help="target utilisation when deriving lambda (default 0.45)")
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--prompt-jitter", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=6)
    ap.add_argument("--geometric-p", type=float, default=0.35,
                    help="geometric output-length parameter (0 = fixed length)")
    ap.add_argument("--full-config", action="store_true",
                    help="profile the full-size config (default: reduced CPU proxy)")
    ap.add_argument("--trace-out", type=Path, default=None,
                    help="also save the raw trace JSON")
    ap.add_argument("--out", type=Path, default=None,
                    help="profile path (default results/PROFILE_<arch>.json)")


def _harness_config(args) -> HarnessConfig:
    return HarnessConfig(
        arch=args.arch,
        slots=args.slots,
        reduced=not args.full_config,
        clock=args.clock,
        seed=args.seed,
        n_requests=args.requests,
        arrival_rate=args.arrival_rate,
        target_rho=args.target_rho,
        prompt_len=args.prompt_len,
        prompt_len_jitter=args.prompt_jitter,
        max_new_tokens=args.max_new,
        new_tokens_geometric_p=args.geometric_p,
    )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    sub = ap.add_subparsers(dest="cmd", required=True)

    p_prof = sub.add_parser("profile", help="run the engine and write a MeasuredProfile")
    _add_profile_args(p_prof)

    p_fit = sub.add_parser("fit", help="refit a saved trace into a MeasuredProfile")
    p_fit.add_argument("--trace", type=Path, required=True)
    p_fit.add_argument("--seed", type=int, default=0, help="bootstrap seed")
    p_fit.add_argument("--out", type=Path, default=None,
                       help="profile path (default results/PROFILE_<arch>.json)")

    p_val = sub.add_parser("validate", help="gate analytic vs observed latencies")
    p_val.add_argument("--profile", type=Path, default=None,
                       help="saved MeasuredProfile JSON (default: profile in-process "
                            "with the default smoke harness)")
    _add_profile_args(p_val)
    p_val.add_argument("--occupancy", type=int, default=None,
                       help="request-fit occupancy to gate (default: dominant)")
    p_val.add_argument("--budget", type=float, default=DEFAULT_MEASURED_BUDGET_PCT,
                       help=f"mean MAPE budget %% (default {DEFAULT_MEASURED_BUDGET_PCT})")
    p_val.add_argument("--tail-budget", type=float,
                       default=DEFAULT_MEASURED_TAIL_BUDGET_PCT,
                       help="p99 MAPE budget %% "
                            f"(default {DEFAULT_MEASURED_TAIL_BUDGET_PCT})")
    p_val.add_argument("--report-out", type=Path,
                       default=Path("results/VALIDATION_measured.json"),
                       help="gate report path (default ./VALIDATION_measured.json)")

    args = ap.parse_args(argv)
    t0 = time.perf_counter()

    if args.cmd == "profile":
        hc = _harness_config(args)
        trace = run_harness(hc)
        if args.trace_out is not None:
            trace.save(args.trace_out)
            print(f"wrote {args.trace_out}")
        profile = build_profile(trace, seed=args.seed,
                                manifest=run_manifest(seed=hc.seed,
                                                      config=hc.to_dict()))
        out = args.out or Path(f"results/PROFILE_{profile.arch}.json")
        profile.save(out)
        _print_profile(profile)
        print(f"wrote {out} in {time.perf_counter() - t0:.1f}s")
        return 0

    if args.cmd == "fit":
        trace = MeasuredTrace.load(args.trace)
        profile = build_profile(trace, seed=args.seed,
                                manifest=run_manifest(seed=trace.harness.seed,
                                                      config=trace.harness.to_dict()))
        out = args.out or Path(f"results/PROFILE_{profile.arch}.json")
        profile.save(out)
        _print_profile(profile)
        print(f"wrote {out}")
        return 0

    # validate
    if args.profile is not None:
        profile = load_profile(args.profile)
    else:
        hc = _harness_config(args)
        trace = run_harness(hc)
        profile = build_profile(trace, seed=args.seed,
                                manifest=run_manifest(seed=hc.seed,
                                                      config=hc.to_dict()))
        if args.out is not None:
            profile.save(args.out)
            print(f"wrote {args.out}")
    rep = run_measured_gate(profile, occupancy=args.occupancy,
                            budget_pct=args.budget,
                            tail_budget_pct=args.tail_budget)
    d = rep.to_dict()
    # run provenance rides along with every gate report: the profile's own
    # manifest when it has one (a loaded artifact keeps its origin), else
    # this process's
    d["manifest"] = dict(profile.manifest) if profile.manifest is not None \
        else run_manifest(seed=args.seed)
    args.report_out.parent.mkdir(parents=True, exist_ok=True)
    args.report_out.write_text(json.dumps(d, indent=2) + "\n")
    _print_gate(rep)
    print(f"wrote {args.report_out} in {time.perf_counter() - t0:.1f}s")
    return 0 if rep.passed else 1


if __name__ == "__main__":
    raise SystemExit(main())
