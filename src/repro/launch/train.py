"""Training launcher CLI.

Single-host (CPU-testable) entry point over repro.training.Trainer with
checkpoint/resume and elastic re-mesh hooks. On a real TPU deployment the
same module runs per host under `jax.distributed.initialize()`; the mesh
comes from launch.mesh and the restored checkpoint re-shards automatically
(checkpoint/checkpointer.py is mesh-agnostic).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch starcoder2_3b --tiny \
      --steps 50 --ckpt-dir /tmp/run1
  PYTHONPATH=src python -m repro.launch.train --arch starcoder2_3b --tiny --resume \
      --steps 100 --ckpt-dir /tmp/run1      # continues from the checkpoint
"""

from __future__ import annotations

import argparse

from repro.configs.base import ARCH_IDS, get_config
from repro.training.train_loop import TrainConfig, Trainer

__all__ = ["main"]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--tiny", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", type=str, default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.tiny:
        cfg = cfg.reduced()
    tc = TrainConfig(
        steps=args.steps, batch=args.batch, seq_len=args.seq_len,
        checkpoint_every=args.ckpt_every, checkpoint_dir=args.ckpt_dir,
        lr=args.lr, seed=args.seed,
    )
    trainer = Trainer(cfg, tc)
    if args.resume:
        params, state, step = trainer.resume()
        print(f"[train] resumed {args.arch} at step {step}")
        trainer.run(params, state, start_step=step)
    else:
        trainer.run()
    last = trainer.metrics_log[-1]
    print(f"[train] done: step {last['step']} loss {last['loss']:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
