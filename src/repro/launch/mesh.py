"""Production meshes + elastic re-meshing.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state). Single pod: 16x16 = 256 v5e chips, axes (data, model).
Multi-pod: 2x16x16 = 512 chips, axes (pod, data, model); the "pod" axis is
the DCN dimension — gradient reduction crosses it, everything else stays
within a pod.

``elastic_mesh`` supports the fault-tolerance story (DESIGN.md §7): when
hosts drop, recompute the largest valid mesh from the devices that remain and
resume from checkpoint (training/train_loop.py re-shards the restored state).
"""

from __future__ import annotations

import math

import jax

__all__ = ["make_production_mesh", "elastic_mesh", "data_axis_size", "mesh_axis"]


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def elastic_mesh(n_available: int, *, model_parallel: int = 16) -> jax.sharding.Mesh:
    """Largest (data, model) mesh from n_available devices.

    Keeps model-parallel width fixed (param shardings stay valid) and shrinks
    the data axis to the largest count that fits — dropping to the next power
    of two so batch re-sharding stays divisible. Raises if fewer than one
    model-parallel group survives.
    """
    if n_available < model_parallel:
        raise ValueError(
            f"{n_available} devices cannot host model_parallel={model_parallel}"
        )
    data = 1 << int(math.log2(n_available // model_parallel))
    return jax.make_mesh((data, model_parallel), ("data", "model"))


def data_axis_size(mesh: jax.sharding.Mesh) -> int:
    size = mesh.shape.get("data", 1)
    return int(size)


def mesh_axis(mesh: jax.sharding.Mesh, name: str) -> int:
    return int(mesh.shape.get(name, 1))
