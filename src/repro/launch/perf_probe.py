"""Hillclimb instrumentation: per-layer vs fixed cost breakdown of a cell.

Compiles the unrolled 1- and 2-superblock probes (same machinery as the
roofline runner) and reports base (embedding/head/optimizer/fixed) vs slope
(per-superblock) for flops / bytes / wire-bytes — the napkin-math input for
each hypothesis->change->measure iteration in EXPERIMENTS.md §Perf.

Usage:
  PYTHONPATH=src python -m repro.launch.perf_probe --arch gemma2_9b --shape train_4k \
      [--override seq_chunk=256] [--multi]
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import dataclasses
import json

from repro.configs.base import SHAPES, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline_run import _probe_costs

__all__ = ["breakdown"]


def breakdown(arch: str, shape_name: str, *, multi_pod: bool = False, overrides=None):
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    base_ov = dict(scan_layers=False, unroll_attn_chunks=True, grad_accum=1)
    out = {}
    for n in (1, 2):
        ov = dict(base_ov, num_superblocks=n)
        if cfg.is_encdec:
            ov["encoder_layers"] = 1
        out[n] = _probe_costs(dataclasses.replace(cfg, **ov), shape, mesh)
    n_sb = cfg.num_superblocks
    rows = {}
    for key in ("flops", "bytes", "wire_bytes"):
        slope = out[2][key] - out[1][key]
        base = out[1][key] - slope
        rows[key] = {
            "base": base,
            "per_superblock": slope,
            "total_extrapolated": base + n_sb * slope,
            "base_fraction": base / max(base + n_sb * slope, 1e-30),
        }
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--arch", required=True, help="architecture id (see configs.base.ARCH_IDS)")
    ap.add_argument("--shape", required=True, help="input shape id (e.g. train_4k)")
    ap.add_argument("--multi", action="store_true", help="probe on the 2x16x16 multi-pod mesh")
    ap.add_argument("--override", action="append", default=[],
                    help="config override key=value (repeatable; value parsed as JSON)")
    args = ap.parse_args(argv)
    ov = {}
    for item in args.override:
        k, v = item.split("=", 1)
        try:
            v = json.loads(v)
        except json.JSONDecodeError:
            pass
        ov[k] = v
    rows = breakdown(args.arch, args.shape, multi_pod=args.multi, overrides=ov or None)
    for key, r in rows.items():
        print(
            f"{key:12s} base={r['base']:.3e}  per_sb={r['per_superblock']:.3e}  "
            f"total={r['total_extrapolated']:.3e}  base_frac={r['base_fraction']:.2f}"
        )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
