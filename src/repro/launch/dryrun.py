"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input-shape) cell, ``jax.jit(step).lower(**specs)``
then ``.compile()`` against the production meshes — 16x16 single-pod and
2x16x16 multi-pod. Success proves the sharding annotations, collective
schedule, and per-device memory are consistent; failures here are bugs in the
framework, not in XLA.

The XLA_FLAGS line below MUST precede every other import (jax locks the
device count at first init) — that is why it is the first statement after
this docstring, and why this env var is set nowhere else (smoke tests and
benchmarks see the real single-CPU device).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch starcoder2_15b --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs.base import ARCH_IDS, SHAPES, get_config, shape_cells
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import cell_step_and_specs, shardings_for
from repro.perf.hlo import parse_collectives
from repro.sharding.partition import rules_for_cell, use_rules

__all__ = ["run_cell", "main"]


def _mem_fields(mem) -> dict:
    out = {}
    for f in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "generated_code_size_in_bytes",
        "alias_size_in_bytes",
    ):
        v = getattr(mem, f, None)
        if v is not None:
            out[f] = int(v)
    return out


def run_cell(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool,
    scan_layers: bool = True,
    donate: bool = True,
    overrides: dict | None = None,
    verbose: bool = True,
) -> dict:
    """Lower + compile one cell; return the dry-run record."""
    cfg = get_config(arch)
    if overrides:
        import dataclasses

        cfg = dataclasses.replace(cfg, **overrides)
    if not scan_layers:
        import dataclasses

        cfg = dataclasses.replace(cfg, scan_layers=False, unroll_attn_chunks=True)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "multi" if multi_pod else "single"
    rules = rules_for_cell(cfg, shape, mesh)

    t0 = time.time()
    with use_rules(rules):
        cell = cell_step_and_specs(cfg, shape, zero_size=mesh.shape.get("data", 1))
        arg_names = list(cell.specs.keys())
        args = tuple(cell.specs[k] for k in arg_names)
        in_shardings = tuple(shardings_for(cell.axes[k], rules) for k in arg_names)
        donate_argnums = ()
        if donate:
            if cell.kind == "train":
                donate_argnums = (0, 1)  # params, opt_state
            elif cell.kind == "decode":
                donate_argnums = (3,)  # caches
        jitted = jax.jit(
            cell.step, in_shardings=in_shardings, donate_argnums=donate_argnums
        )
        with mesh:
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        coll = parse_collectives(compiled.as_text())

    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "n_devices": int(mesh.size),
        "kind": cell.kind,
        "scan_layers": cfg.scan_layers,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "flops_per_device": float(cost.get("flops", 0.0)),
        "bytes_accessed_per_device": float(cost.get("bytes accessed", 0.0)),
        "memory_analysis": _mem_fields(mem),
        "collectives": coll.summary(),
    }
    if verbose:
        ma = record["memory_analysis"]
        print(
            f"[dryrun] {arch:24s} {shape_name:12s} {mesh_name:6s} OK  "
            f"compile={record['compile_s']:7.1f}s  "
            f"args={ma.get('argument_size_in_bytes', 0)/2**30:7.2f}GiB  "
            f"temp={ma.get('temp_size_in_bytes', 0)/2**30:7.2f}GiB  "
            f"colls={sum(record['collectives']['counts'].values())}"
        )
        print(f"  memory_analysis: {ma}")
        print(f"  cost_analysis: flops={record['flops_per_device']:.3e} "
              f"bytes={record['bytes_accessed_per_device']:.3e} (per device)")
    return record


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true", help="every (arch x shape) cell")
    ap.add_argument("--out", type=str, default=None, help="directory for JSON records")
    ap.add_argument("--no-scan", action="store_true", help="unrolled (roofline accounting)")
    args = ap.parse_args(argv)

    cells: list[tuple[str, str]] = []
    if args.all:
        for arch in ARCH_IDS:
            cfg = get_config(arch)
            for sh in shape_cells(cfg):
                cells.append((arch, sh.name))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    outdir = Path(args.out) if args.out else None
    if outdir:
        from repro.obs import run_manifest

        outdir.mkdir(parents=True, exist_ok=True)
        # per-cell records stay lean; one provenance manifest covers the dir
        (outdir / "manifest.json").write_text(json.dumps(
            run_manifest(config={"mesh": args.mesh, "cells": len(cells)}),
            indent=2))

    failures = []
    for arch, shape_name in cells:
        for multi in meshes:
            tag = f"{arch}__{shape_name}__{'multi' if multi else 'single'}"
            try:
                rec = run_cell(arch, shape_name, multi_pod=multi, scan_layers=not args.no_scan)
                if outdir:
                    (outdir / f"{tag}.json").write_text(json.dumps(rec, indent=2))
            except Exception as e:
                failures.append((tag, repr(e)))
                print(f"[dryrun] {tag} FAILED: {e}")
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for tag, err in failures:
            print(f"  {tag}: {err}")
        return 1
    print(f"\nall {len(cells) * len(meshes)} dry-run cells compiled OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
