"""Step builders + input specs: the contract between models, launchers,
dry-run, and the serving engine.

``input_specs(cfg, shape)`` returns weak-type-correct ShapeDtypeStructs for
every input of the step that the (arch x shape) cell lowers — no device
allocation, the same pattern the dry-run and the roofline reader consume.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSuite
from repro.models import lm
from repro.models.params import abstract_params, is_axes_leaf, param_axes
from repro.sharding.partition import ShardingRules, current_rules
from repro.training import optimizer as opt

__all__ = [
    "prefix_len",
    "batch_specs",
    "decode_specs",
    "make_train_step",
    "make_prefill_step",
    "make_decode_step",
    "cell_step_and_specs",
    "shardings_for",
]


def prefix_len(cfg: ModelConfig, seq_len: int) -> int:
    if not cfg.prefix_embed or cfg.is_encdec:
        return 0
    return int(seq_len * cfg.prefix_len_fraction)


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins)
# ---------------------------------------------------------------------------


def batch_specs(cfg: ModelConfig, shape: ShapeSuite) -> dict:
    """Training / prefill batch."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    d = jnp.dtype(cfg.dtype)
    if cfg.is_encdec:
        return {
            "enc_embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), d),
            "tokens": jax.ShapeDtypeStruct((B, S), i32),
            "targets": jax.ShapeDtypeStruct((B, S), i32),
            "loss_mask": jax.ShapeDtypeStruct((B, S), jnp.float32),
        }
    P = prefix_len(cfg, S)
    text = S - P
    out = {
        "tokens": jax.ShapeDtypeStruct((B, text), i32),
        "targets": jax.ShapeDtypeStruct((B, text), i32),
        "loss_mask": jax.ShapeDtypeStruct((B, text), jnp.float32),
    }
    if P:
        out["prefix_embeds"] = jax.ShapeDtypeStruct((B, P, cfg.d_model), d)
    return out


def batch_axes(cfg: ModelConfig, shape: ShapeSuite) -> dict:
    ax2 = ("batch", None)
    out = {k: ax2 for k in ("tokens", "targets", "loss_mask")}
    if cfg.is_encdec:
        out["enc_embeds"] = ("batch", None, None)
    elif prefix_len(cfg, shape.seq_len):
        out["prefix_embeds"] = ("batch", None, None)
    return out


def decode_specs(cfg: ModelConfig, shape: ShapeSuite) -> dict:
    """Decode-step inputs: one new token + caches holding ``seq_len`` context."""
    B, S = shape.global_batch, shape.seq_len
    caches = abstract_params(
        lm.cache_template(cfg, B, S, enc_len=S if cfg.is_encdec else 0),
        jnp.dtype(cfg.dtype),
    )
    return {
        "token": jax.ShapeDtypeStruct((B, 1), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
        "caches": caches,
    }


def decode_axes(cfg: ModelConfig, shape: ShapeSuite) -> dict:
    cache_ax = param_axes(lm.cache_template(cfg, shape.global_batch, shape.seq_len,
                                            enc_len=shape.seq_len if cfg.is_encdec else 0))
    return {"token": ("batch", None), "pos": (), "caches": cache_ax}


# ---------------------------------------------------------------------------
# Steps
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, ocfg=None) -> Callable:
    if ocfg is None:
        ocfg = (
            opt.AdafactorConfig() if cfg.optimizer == "adafactor" else opt.AdamWConfig()
        )
    is_adafactor = isinstance(ocfg, opt.AdafactorConfig)

    def compute_grads(params, batch):
        A = max(1, cfg.grad_accum)
        if A == 1:
            (loss, metrics), grads = jax.value_and_grad(lm.loss_fn, has_aux=True)(
                params, cfg, batch
            )
            gd = jnp.dtype(cfg.grad_dtype)
            return loss, metrics, jax.tree.map(lambda g: g.astype(gd), grads)

        # gradient accumulation: scan over A microbatches, fp32 accumulator
        def split(x):
            return x.reshape(A, x.shape[0] // A, *x.shape[1:])

        mbs = jax.tree.map(split, batch)

        def micro(carry, mb):
            loss_sum, tok_sum, acc = carry
            (loss, metrics), g = jax.value_and_grad(lm.loss_fn, has_aux=True)(
                params, cfg, mb
            )
            acc = jax.tree.map(lambda a, b: a + b.astype(a.dtype), acc, g)
            return (loss_sum + loss, tok_sum + metrics["tokens"], acc), None

        gd = jnp.dtype(cfg.grad_dtype)
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, gd), params)
        (loss_sum, tok_sum, grads), _ = jax.lax.scan(
            micro, (jnp.float32(0.0), jnp.float32(0.0), zeros), mbs
        )
        loss = loss_sum / A
        grads = jax.tree.map(lambda g: g / A, grads)
        return loss, {"loss": loss, "nll": loss, "tokens": tok_sum}, grads

    def train_step(params, opt_state, batch):
        loss, metrics, grads = compute_grads(params, batch)
        if not is_adafactor and ocfg.compress:
            q, scales, new_err = opt.compress_grads(grads, opt_state.get("ef"))
            grads = opt.decompress_grads(q, scales)
        if is_adafactor:
            new_params, new_state, om = opt.adafactor_update(ocfg, grads, opt_state, params)
        else:
            new_params, new_state, om = opt.adamw_update(ocfg, grads, opt_state, params)
            if ocfg.compress:
                new_state["ef"] = new_err
        metrics = dict(metrics, **om)
        return new_params, new_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig) -> Callable:
    def prefill_step(params, batch):
        logits, caches = lm.prefill(
            params, cfg, batch["tokens"],
            prefix_embeds=batch.get("prefix_embeds"),
            enc_embeds=batch.get("enc_embeds"),
        )
        return logits, caches

    return prefill_step


def make_decode_step(cfg: ModelConfig) -> Callable:
    def decode_fn(params, token, pos, caches):
        return lm.decode_step(params, cfg, token, pos, caches)

    return decode_fn


# ---------------------------------------------------------------------------
# Cell assembly: (step fn, kwargs-specs, logical-axes) for one (arch x shape)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Cell:
    step: Callable
    specs: dict  # kwargs of ShapeDtypeStructs
    axes: dict  # matching logical axes
    kind: str


def cell_step_and_specs(cfg: ModelConfig, shape: ShapeSuite, *, zero_size: int = 0) -> Cell:
    p_abs = lm.abstract_model(cfg)
    p_axes = lm.model_param_axes(cfg)
    if shape.kind == "train":
        rules = current_rules()

        def _uses_data(v) -> bool:
            return v == "data" or (isinstance(v, tuple) and "data" in v)

        if rules is not None:
            replicated = frozenset(k for k, v in rules.rules.items() if v is None)
            data_resident = frozenset(
                k for k, v in rules.rules.items() if _uses_data(v)
            )
        else:
            replicated = frozenset({"embed"})
            data_resident = frozenset({"expert_ff", "zero"})
        if cfg.optimizer == "adafactor":
            ostate = opt.abstract_adafactor_state(p_abs)
            oaxes = opt.adafactor_axes(p_axes, p_abs)
        else:
            ostate = opt.abstract_adamw_state(p_abs)
            oaxes = opt.opt_axes(
                p_axes, p_abs, zero_size=zero_size,
                replicated_names=replicated, data_resident_names=data_resident,
            )
        return Cell(
            step=make_train_step(cfg),
            specs={"params": p_abs, "opt_state": ostate, "batch": batch_specs(cfg, shape)},
            axes={"params": p_axes, "opt_state": oaxes, "batch": batch_axes(cfg, shape)},
            kind="train",
        )
    if shape.kind == "prefill":
        return Cell(
            step=make_prefill_step(cfg),
            specs={"params": p_abs, "batch": batch_specs(cfg, shape)},
            axes={"params": p_axes, "batch": batch_axes(cfg, shape)},
            kind="prefill",
        )
    if shape.kind == "decode":
        d = decode_specs(cfg, shape)
        da = decode_axes(cfg, shape)
        return Cell(
            step=make_decode_step(cfg),
            specs={"params": p_abs, "token": d["token"], "pos": d["pos"], "caches": d["caches"]},
            axes={"params": p_axes, "token": da["token"], "pos": da["pos"], "caches": da["caches"]},
            kind="decode",
        )
    raise ValueError(shape.kind)


def shardings_for(axes_tree: Any, rules: ShardingRules):
    """Logical axes tree -> NamedSharding tree (leaves matched by is_axes_leaf)."""
    from jax.sharding import NamedSharding

    def f(ax):
        return NamedSharding(rules.mesh, rules.spec(tuple(ax)))

    return jax.tree.map(f, axes_tree, is_leaf=is_axes_leaf)
