"""Serving launcher CLI: engine + Poisson workload + Algorithm-1 gateway.

Serves a (reduced, CPU-runnable) model through the slot-based engine while
the offload gateway replays a bandwidth schedule and reports its decisions —
the deployable shape of the paper's resource manager.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch starcoder2_3b \
      --requests 8 --rps 20 --schedule 20,10,2,20
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs.base import ARCH_IDS, get_config
from repro.core.latency import ServiceModel, Tier, Workload
from repro.models import lm
from repro.obs import AuditLog, MetricsRegistry, format_decision
from repro.serving.engine import Engine, ServeConfig
from repro.serving.gateway import EdgeHandle, OffloadGateway
from repro.serving.workload import PoissonWorkload, WorkloadConfig

__all__ = ["main"]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--arch", choices=ARCH_IDS, default="starcoder2_3b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--rps", type=float, default=20.0)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=4)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--schedule", type=str, default="20,10,2,20",
                    help="bandwidth schedule in Mbps, one epoch each")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch).reduced(seq_chunk=8)
    params = lm.init_model(cfg, jax.random.PRNGKey(0))
    engine = Engine(cfg, params, ServeConfig(slots=args.slots, max_seq=64))

    # warmup first so JIT compilation never pollutes the profiled service
    engine.warmup([args.prompt_len])
    wl_gen = PoissonWorkload(WorkloadConfig(
        arrival_rate=args.rps, prompt_len=args.prompt_len,
        max_new_tokens=args.max_new, vocab=cfg.vocab_size,
    ))
    for r in wl_gen.take(args.requests):
        engine.submit(r)
    engine.drain()
    s_dev, var = engine.observed_service_stats()
    lat = [r.latency_s for r in engine.completed if r.latency_s is not None]
    print(f"[serve] {len(engine.completed)} requests done; "
          f"profiled tick {s_dev*1e3:.1f} ms (var {var:.2e})")

    dev = Tier("device-engine", s_dev, service_model=ServiceModel.EXPONENTIAL)
    # payloads scaled to the profiled service: the schedule's bandwidth
    # crossover lands near 5 Mbps regardless of machine speed
    req_bytes = max(1, int(0.8 * s_dev * 0.625e6))
    # every per-epoch line below is rendered FROM the audit log, so the
    # console report and the machine-readable trail cannot disagree
    auditor = AuditLog()
    metrics = MetricsRegistry()
    gw = OffloadGateway(
        dev,
        [EdgeHandle("edge0", service_mean_s=s_dev / 8, parallelism_k=4.0)],
        Workload(args.rps, req_bytes, max(1, req_bytes // 5)),
        bandwidth_Bps=2.5e6,
        auditor=auditor,
        metrics=metrics,
    )
    for i, mbps in enumerate(float(x) for x in args.schedule.split(",")):
        for _ in range(3):
            gw.observe_bandwidth(mbps * 1e6 / 8)
        for dt in np.arange(0.0, 1.0, 1.0 / max(args.rps, 1.0)):
            gw.observe_arrival(i + dt)
        gw.decide(now=i + 1.0)
        print(format_decision(auditor.rows[-1]))
    auditor.verify()  # terms must re-sum to the decision totals
    print(f"[gateway] switches={gw.switches}")
    for line in metrics.render().splitlines():
        print(f"[metrics] {line}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
