"""Fleet sweep CLI: evaluate a cartesian scenario grid in one jitted call.

Packs a base scenario (built-in paper operating point, or any
``Scenario.to_dict()`` JSON via ``--scenario``) into a
:class:`repro.fleet.ScenarioBatch`, evaluates every grid point with the
vectorized closed forms, and reports strategy shares, latency stats,
throughput (scenarios/sec), and optionally batched crossover points.

Usage:
  PYTHONPATH=src python -m repro.launch.fleet_sweep \
      --axis network.bandwidth_Bps=1e5:1e8:256:geom \
      --axis workload.arrival_rate=0.5:30:128 \
      --crossover bandwidth --out experiments/fleet_sweep.json
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

from repro.core.latency import NetworkPath, Tier, Workload
from repro.core.scenario import EdgeSpec, Scenario
from repro.fleet import ScenarioBatch, fleet_analytic, fleet_crossover
from repro.obs import run_manifest

__all__ = ["default_scenario", "parse_axis", "run_sweep", "main"]


def default_scenario() -> Scenario:
    """The paper's headline operating point: InceptionV4 on a TX2-class
    device vs an A2-class edge at 5 Mbps, 2 rps."""
    return Scenario(
        workload=Workload(arrival_rate=2.0, req_bytes=30_000, res_bytes=1_000,
                          name="inceptionv4"),
        device=Tier("tx2", 0.150),
        edges=(EdgeSpec(Tier("a2", 0.028)),),
        network=NetworkPath(5e6 / 8),
        allow_unstable=True,  # sweep grids deliberately cross saturation
        name="fleet-sweep-default",
    )


def parse_axis(spec: str) -> tuple[str, np.ndarray]:
    """``path=lo:hi:n[:geom|lin]`` -> (path, values)."""
    try:
        path, rng = spec.split("=", 1)
        parts = rng.split(":")
        lo, hi, n = float(parts[0]), float(parts[1]), int(parts[2])
        kind = parts[3] if len(parts) > 3 else "lin"
    except (ValueError, IndexError):
        raise SystemExit(
            f"bad --axis {spec!r}: expected path=lo:hi:n[:geom|lin]") from None
    if kind not in ("geom", "lin"):
        raise SystemExit(f"bad --axis {spec!r}: kind must be geom or lin")
    vals = np.geomspace(lo, hi, n) if kind == "geom" else np.linspace(lo, hi, n)
    return path, vals


def run_sweep(
    base: Scenario,
    axes: dict[str, np.ndarray],
    *,
    crossover_axis: str | None = None,
    repeat: int = 3,
) -> dict:
    t0 = time.perf_counter()
    batch = ScenarioBatch.from_sweep(base, axes)
    pack_s = time.perf_counter() - t0

    fleet_analytic(batch)  # warm: jit compile outside the timed region
    t0 = time.perf_counter()
    for _ in range(repeat):
        pred = fleet_analytic(batch)
    eval_s = (time.perf_counter() - t0) / repeat

    names = pred.strategy_names()
    counts: dict[str, int] = {}
    for n in names:
        counts[n] = counts.get(n, 0) + 1
    best = pred.best_latency
    finite = best[np.isfinite(best)]
    out = {
        "scenario": base.to_dict(),
        "axes": {p: {"n": int(v.size), "lo": float(v.min()), "hi": float(v.max())}
                 for p, v in axes.items()},
        "batch_size": batch.size,
        "timing": {
            "pack_ms": pack_s * 1e3,
            "eval_ms": eval_s * 1e3,
            "scenarios_per_sec": batch.size / eval_s,
        },
        "strategy_counts": counts,
        "best_latency_s": {
            "finite_frac": float(np.isfinite(best).mean()),
            "min": float(finite.min()) if finite.size else None,
            "median": float(np.median(finite)) if finite.size else None,
            "max": float(finite.max()) if finite.size else None,
        },
    }
    if crossover_axis:
        t0 = time.perf_counter()
        cx = fleet_crossover(batch, crossover_axis)
        cx_s = time.perf_counter() - t0
        vals = cx.value[cx.found]
        out["crossover"] = {
            "axis": crossover_axis,
            "solve_ms": cx_s * 1e3,
            "found_frac": float(cx.found.mean()),
            "min": float(vals.min()) if vals.size else None,
            "median": float(np.median(vals)) if vals.size else None,
            "max": float(vals.max()) if vals.size else None,
        }
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--axis", action="append", default=[],
                    help="path=lo:hi:n[:geom|lin]; repeatable")
    ap.add_argument("--scenario", type=Path, default=None,
                    help="Scenario.to_dict() JSON file (default: built-in paper point)")
    ap.add_argument("--crossover", choices=("bandwidth", "arrival_rate"), default=None,
                    help="also solve batched crossovers along this axis")
    ap.add_argument("--repeat", type=int, default=3)
    ap.add_argument("--out", type=Path, default=None, help="write the report JSON here")
    args = ap.parse_args(argv)

    if args.scenario is not None:
        base = Scenario.from_dict(json.loads(args.scenario.read_text()))
    else:
        base = default_scenario()
    if args.axis:
        axes = dict(parse_axis(s) for s in args.axis)
    else:
        axes = {
            "network.bandwidth_Bps": np.geomspace(1e5, 1e8, 256),
            "workload.arrival_rate": np.linspace(0.5, 30.0, 128),
        }

    report = run_sweep(base, axes, crossover_axis=args.crossover, repeat=args.repeat)
    report["manifest"] = run_manifest(config={
        "axes": {path: len(vals) for path, vals in axes.items()},
        "scenario": str(args.scenario) if args.scenario else "builtin",
        "crossover": args.crossover, "repeat": args.repeat,
    })
    t = report["timing"]
    print(f"fleet sweep: {report['batch_size']} scenarios "
          f"(pack {t['pack_ms']:.1f} ms, eval {t['eval_ms']:.2f} ms, "
          f"{t['scenarios_per_sec']/1e6:.2f}M scenarios/s)")
    for name, cnt in sorted(report["strategy_counts"].items()):
        print(f"  {name:12s} wins {cnt:8d} ({cnt/report['batch_size']:6.1%})")
    if args.crossover:
        cx = report["crossover"]
        print(f"  {args.crossover} crossover found for {cx['found_frac']:.1%} "
              f"(median {cx['median']})")
    if args.out:
        args.out.parent.mkdir(parents=True, exist_ok=True)
        args.out.write_text(json.dumps(report, indent=2))
        print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
