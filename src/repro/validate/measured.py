"""Measured regime: gate the closed forms against OBSERVED engine latencies.

PRs 1-5 validated analytic-vs-*simulated*; this module closes the paper's
actual loop (§5: closed forms within 2.2% MAPE of latencies observed on real
accelerators). A :class:`~repro.measure.MeasuredProfile` — fitted from a real
``Engine`` run — becomes an ordinary analytic tier via ``Tier.from_measured``,
the same ``analytic()`` / ``analytic_tail()`` entry points every other regime
uses predict its mean and tail latency, and the gate scores those predictions
against the latencies the engine actually delivered.

Budgets are looser than the simulator gates on purpose: a profiling run is a
finite sample of a stochastic system (the report carries the bootstrap CI
half-width as the statistical resolution floor), and the tail gate scores an
empirical p99 of a few hundred requests.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.latency import NetworkPath, Tier, Workload
from repro.core.scenario import Scenario, analytic, analytic_tail

from .metrics import mape

__all__ = [
    "DEFAULT_MEASURED_BUDGET_PCT",
    "DEFAULT_MEASURED_TAIL_BUDGET_PCT",
    "MEASURED_VEC_TOL",
    "MeasuredGateReport",
    "measured_scenario",
    "run_measured_gate",
]

DEFAULT_MEASURED_BUDGET_PCT = 15.0  # mean-latency MAPE budget (ISSUE acceptance)
DEFAULT_MEASURED_TAIL_BUDGET_PCT = 35.0  # p99 vs an empirical tail is noisier
MEASURED_VEC_TOL = 1e-6  # measured tier through fleet.analytic_vec must agree


def measured_scenario(profile, occupancy: int | None = None, *,
                      name: str | None = None) -> Scenario:
    """An on-device :class:`Scenario` whose device tier is the measured one.

    The workload is the profiling run's own stream (resolved arrival rate,
    payload bytes from the token counts at 4 bytes/token — irrelevant to the
    on-device path but kept honest for anyone adding edges). The returned
    scenario flows through ``analytic``/``analytic_tail``/``fleet`` exactly
    like a hand-specified one; ``allow_unstable=True`` so a saturated
    profiling run yields an inf prediction (and a failed gate) rather than a
    constructor error.
    """
    occ = profile.dominant_occupancy() if occupancy is None else int(occupancy)
    tier = Tier.from_measured(profile, occ)
    wl_meta = dict(profile.workload)
    prompt = wl_meta.get("prompt_len", 64.0)
    newtok = wl_meta.get("max_new_tokens", 16.0)
    return Scenario(
        workload=Workload(
            arrival_rate=profile.arrival_rate,
            req_bytes=4.0 * prompt,
            res_bytes=4.0 * newtok,
            name=f"measured:{profile.arch}",
        ),
        device=tier,
        network=NetworkPath(bandwidth_Bps=1e9),  # no edges: path is unused
        edges=(),
        allow_unstable=True,
        name=name or f"measured:{profile.arch}@occ{occ}",
    )


@dataclass(frozen=True)
class MeasuredGateReport:
    """Analytic-vs-observed scorecard for one measured profile."""

    arch: str
    clock: str
    seed: int
    slots: int
    occupancy: int
    n_requests: int
    rho: float
    observed_mean_s: float
    analytic_mean_s: float
    mean_mape_pct: float
    observed_p99_s: float
    analytic_p99_s: float
    p99_mape_pct: float
    ci_half_width_pct: float  # bootstrap resolution floor on the observed mean
    vec_rel_err: float  # scalar analytic vs fleet.analytic_vec on the same spec
    budget_pct: float
    tail_budget_pct: float
    tail_pct: float

    @property
    def mean_passed(self) -> bool:
        return np.isfinite(self.mean_mape_pct) and self.mean_mape_pct <= self.budget_pct

    @property
    def tail_passed(self) -> bool:
        return (np.isfinite(self.p99_mape_pct)
                and self.p99_mape_pct <= self.tail_budget_pct)

    @property
    def vec_passed(self) -> bool:
        return np.isfinite(self.vec_rel_err) and self.vec_rel_err <= MEASURED_VEC_TOL

    @property
    def passed(self) -> bool:
        return self.mean_passed and self.tail_passed and self.vec_passed

    def to_dict(self) -> dict:
        return {
            "regime": "measured",
            "profile": {
                "arch": self.arch, "clock": self.clock, "seed": self.seed,
                "slots": self.slots, "occupancy": self.occupancy,
                "n_requests": self.n_requests, "rho": self.rho,
            },
            "mean": {
                "observed_s": self.observed_mean_s,
                "analytic_s": self.analytic_mean_s,
                "mape_pct": self.mean_mape_pct,
                "budget_pct": self.budget_pct,
                "ci_half_width_pct": self.ci_half_width_pct,
                "passed": self.mean_passed,
            },
            "tail": {
                "pct": self.tail_pct,
                "observed_s": self.observed_p99_s,
                "analytic_s": self.analytic_p99_s,
                "mape_pct": self.p99_mape_pct,
                "budget_pct": self.tail_budget_pct,
                "passed": self.tail_passed,
            },
            "vec": {"rel_err": self.vec_rel_err, "tol": MEASURED_VEC_TOL,
                    "passed": self.vec_passed},
            "passed": self.passed,
        }


def run_measured_gate(
    profile,
    *,
    occupancy: int | None = None,
    budget_pct: float = DEFAULT_MEASURED_BUDGET_PCT,
    tail_budget_pct: float = DEFAULT_MEASURED_TAIL_BUDGET_PCT,
    tail_pct: float = 99.0,
) -> MeasuredGateReport:
    """Score the closed forms against the profile's observed latencies.

    Three checks: (1) analytic mean latency (Eq. 2 with the measured tier's
    service model) within ``budget_pct`` MAPE of the observed mean; (2)
    analytic ``tail_pct`` sojourn quantile within ``tail_budget_pct`` of the
    empirical one; (3) the measured tier predicts identically through the
    vectorized fleet path — no special-casing anywhere downstream.
    """
    scn = measured_scenario(profile, occupancy)
    occ = int(scn.device.parallelism_k)

    pred = analytic(scn)
    analytic_mean = float(np.asarray(pred["on_device"].total))
    q = tail_pct / 100.0
    analytic_q = float(analytic_tail(scn, q)["on_device"])

    observed_mean = profile.observed_stat("latency_mean_s")
    pkey = f"latency_p{tail_pct:g}_s"
    observed_q = profile.observed_stat(pkey)

    # cross-path consistency: the same spec through fleet.analytic_vec
    from repro.fleet import ScenarioBatch, fleet_analytic

    fp = fleet_analytic(ScenarioBatch.from_scenarios([scn]))
    vec_mean = float(fp.t_dev[0])
    vec_rel = abs(vec_mean - analytic_mean) / max(abs(analytic_mean), 1e-300)

    ci_lo = profile.observed_stat("latency_mean_ci_lo_s")
    ci_hi = profile.observed_stat("latency_mean_ci_hi_s")
    tier = scn.device
    rho = profile.arrival_rate * tier.service_time_s / tier.parallelism_k

    return MeasuredGateReport(
        arch=profile.arch,
        clock=profile.clock,
        seed=profile.seed,
        slots=profile.slots,
        occupancy=occ,
        n_requests=profile.n_requests,
        rho=float(rho),
        observed_mean_s=observed_mean,
        analytic_mean_s=analytic_mean,
        mean_mape_pct=mape(analytic_mean, observed_mean),
        observed_p99_s=observed_q,
        analytic_p99_s=analytic_q,
        p99_mape_pct=mape(analytic_q, observed_q),
        ci_half_width_pct=float(0.5 * (ci_hi - ci_lo) / observed_mean * 100.0),
        vec_rel_err=float(vec_rel),
        budget_pct=float(budget_pct),
        tail_budget_pct=float(tail_budget_pct),
        tail_pct=float(tail_pct),
    )
