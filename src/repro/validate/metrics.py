"""Error metrics for scoring analytic predictions against simulated truth.

The paper's §4.3 fidelity claim is stated in exactly these statistics: mean
absolute percentage error over a scenario set (2.2%), plus the fraction of
scenarios whose prediction lands within ±5% / ±10% of the observation. This
module computes them, groups them into per-regime tables, and quantifies the
*statistical* uncertainty of a simulated mean with a moving-block bootstrap —
queue-latency samples are strongly autocorrelated near saturation, so an
i.i.d. bootstrap would report confidence intervals several times too narrow.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, Mapping, Sequence

import numpy as np

__all__ = [
    "mape",
    "ErrorStats",
    "error_stats",
    "error_table",
    "BootstrapCI",
    "bootstrap_mean_ci",
]


def mape(pred, obs):
    """Absolute percentage error |pred - obs| / |obs| * 100, broadcasting.

    Returns a float for scalar inputs, an ndarray otherwise. ``obs`` must be
    nonzero (latencies are strictly positive); infinities propagate to inf so
    an unstable prediction scored against a finite observation is loud.
    """
    pred = np.asarray(pred, dtype=np.float64)
    obs = np.asarray(obs, dtype=np.float64)
    out = np.abs(pred - obs) / np.abs(obs) * 100.0
    return float(out) if out.ndim == 0 else out


@dataclass(frozen=True)
class ErrorStats:
    """Summary of one group of absolute-percentage errors (paper §4.3 style)."""

    n: int
    mean_pct: float
    median_pct: float
    max_pct: float
    within_5_frac: float
    within_10_frac: float

    def to_dict(self) -> dict:
        return {
            "n": self.n,
            "mean_pct": self.mean_pct,
            "median_pct": self.median_pct,
            "max_pct": self.max_pct,
            "within_5_frac": self.within_5_frac,
            "within_10_frac": self.within_10_frac,
        }


def error_stats(errors_pct: Iterable[float]) -> ErrorStats:
    """Aggregate a list of percentage errors into the paper's summary stats."""
    e = np.asarray(list(errors_pct), dtype=np.float64)
    if e.size == 0:
        return ErrorStats(0, float("nan"), float("nan"), float("nan"),
                          float("nan"), float("nan"))
    return ErrorStats(
        n=int(e.size),
        mean_pct=float(np.mean(e)),
        median_pct=float(np.median(e)),
        max_pct=float(np.max(e)),
        within_5_frac=float(np.mean(e <= 5.0)),
        within_10_frac=float(np.mean(e <= 10.0)),
    )


def error_table(
    keyed_errors: Iterable[tuple[str, float]],
    *,
    order: Sequence[str] | None = None,
) -> Mapping[str, ErrorStats]:
    """Group ``(key, error_pct)`` pairs into per-key :class:`ErrorStats`.

    ``order`` fixes the key order of the returned mapping (unknown keys keep
    insertion order after the ordered ones) — handy for utilization bands,
    which have a natural low->stress reading order.
    """
    groups: dict[str, list[float]] = {}
    for key, err in keyed_errors:
        groups.setdefault(key, []).append(err)
    keys = list(groups)
    if order:
        keys = [k for k in order if k in groups] + [k for k in keys if k not in order]
    return {k: error_stats(groups[k]) for k in keys}


@dataclass(frozen=True)
class BootstrapCI:
    """A bootstrap confidence interval for a simulated steady-state mean."""

    mean: float
    lo: float
    hi: float
    level: float
    n_boot: int
    block_len: int

    @property
    def half_width_pct(self) -> float:
        """CI half-width as a percentage of the mean — the resolution floor
        below which an analytic-vs-simulated MAPE is statistically moot."""
        return float(0.5 * (self.hi - self.lo) / abs(self.mean) * 100.0)

    def to_dict(self) -> dict:
        return {
            "mean": self.mean,
            "lo": self.lo,
            "hi": self.hi,
            "level": self.level,
            "half_width_pct": self.half_width_pct,
        }


def bootstrap_mean_ci(
    samples: np.ndarray,
    *,
    n_boot: int = 200,
    level: float = 0.95,
    block_len: int | None = None,
    seed: int = 0,
) -> BootstrapCI:
    """Moving-block bootstrap CI for the mean of an autocorrelated series.

    Resamples whole contiguous blocks (default length ~sqrt(n), a standard
    rate-optimal choice) so the latency process's serial correlation survives
    into the replicates. Percentile interval at ``level``.
    """
    x = np.asarray(samples, dtype=np.float64).ravel()
    n = x.size
    if n < 2:
        m = float(x.mean()) if n else float("nan")
        return BootstrapCI(m, m, m, level, 0, 1)
    if block_len is None:
        block_len = max(1, int(np.sqrt(n)))
    block_len = min(block_len, n)
    n_blocks = int(np.ceil(n / block_len))
    rng = np.random.default_rng(seed)
    # start indices of sampled blocks, (n_boot, n_blocks)
    starts = rng.integers(0, n - block_len + 1, size=(n_boot, n_blocks))
    idx = starts[:, :, None] + np.arange(block_len)[None, None, :]
    reps = x[idx.reshape(n_boot, -1)[:, :n]].mean(axis=1)
    alpha = 0.5 * (1.0 - level)
    lo, hi = np.quantile(reps, [alpha, 1.0 - alpha])
    return BootstrapCI(
        mean=float(x.mean()),
        lo=float(lo),
        hi=float(hi),
        level=level,
        n_boot=n_boot,
        block_len=block_len,
    )
