"""Seeded golden scenario corpus spanning the paper's evaluation axes.

The paper validates its closed forms over a structured sweep of operating
points (§4.3): accelerator tiers on both sides, bandwidths from cellular to
LAN, arrival rates from idle to near-saturation, and multi-tenant edges. This
module generates the repo's equivalent — a deterministic, seeded corpus of
:class:`repro.core.Scenario` specs, each tagged with

  * the **strategy** whose prediction the scenario exercises
    (``"on_device"`` or ``"edge[0]"``),
  * a **regime** label (which queueing formulation is load-bearing:
    ``device-md1``, ``offload-network-bound``, ``multitenant``, ...),
  * the bottleneck **utilization** rho and its band (``low`` < 0.3 <= ``mid``
    < 0.6 <= ``high`` < 0.8 <= ``peak`` <= 0.9 < ``stress`` <= ~0.95),
  * whether the entry counts toward the **MAPE gate** (``sim_gate``) — the
    aggregation-approximation regimes (k>1 folded into k*mu, paper §3.5) and
    the stress band are reported but not gated, matching how the repo's tests
    have always quantified those approximations separately, and
  * whether it belongs to the fast **smoke** subset run in tier-1.

The corpus is data, not a process: ``generate_corpus(seed)`` is pure, and the
checked-in JSON fixture under ``tests/golden/`` pins both the specs and their
golden scalar-analytic totals, so any future change to the closed forms that
moves a prediction is caught as a diff, not a silent drift.
"""

from __future__ import annotations

import json
from dataclasses import dataclass
from pathlib import Path
from typing import Iterable, Mapping, Sequence

import numpy as np

from repro.core.latency import NetworkPath, ServiceModel, Tier, Workload
from repro.core.multitenant import TenantStream
from repro.core.scenario import (
    EdgeSpec,
    Scenario,
    ScenarioError,
    analytic,
    parse_strategy,
)

__all__ = [
    "CorpusEntry",
    "RHO_BANDS",
    "rho_band",
    "bottleneck_rho",
    "generate_corpus",
    "corpus_to_dict",
    "save_corpus",
    "load_corpus",
    "default_fixture_path",
    "CORPUS_VERSION",
    "DEFAULT_SEED",
]

CORPUS_VERSION = 1
DEFAULT_SEED = 0

# band name -> (lo, hi]; "low" is [0, 0.3) for readability
RHO_BANDS: tuple[tuple[str, float, float], ...] = (
    ("low", 0.0, 0.3),
    ("mid", 0.3, 0.6),
    ("high", 0.6, 0.8),
    ("peak", 0.8, 0.9),
    ("stress", 0.9, 1.0),
)

BAND_ORDER = tuple(name for name, _, _ in RHO_BANDS)


def rho_band(rho: float) -> str:
    """The utilization band a bottleneck rho falls in (upper-inclusive, so a
    rho of exactly 0.9 is still ``peak`` and still gated)."""
    for name, _lo, hi in RHO_BANDS:
        if rho <= hi + 1e-12:
            return name
    return "stress"


def bottleneck_rho(scn: Scenario, strategy: str) -> float:
    """Utilization of the busiest queue on ``strategy``'s path.

    on_device: the device processing queue (lam * s / k). edge[j]: max over
    the device NIC, the edge processing queue at the aggregate load, and the
    return NIC (when results come back) — the same queues stability
    validation checks, so rho < 1 is guaranteed for a validated spec.
    """
    wl = scn.workload
    j = parse_strategy(strategy, len(scn.edges))
    if j < 0:
        return wl.arrival_rate * scn.device.service_time_s / scn.device.parallelism_k
    e = scn.edges[j]
    b = float(np.asarray(scn.network_for(e).bandwidth_Bps))
    agg = e.aggregate(wl)
    rhos = [
        wl.arrival_rate * wl.req_bytes / b,
        agg.arrival_rate * agg.service_mean_s / e.tier.parallelism_k,
    ]
    if scn.return_results and wl.res_bytes > 0:
        rhos.append(agg.arrival_rate * wl.res_bytes / b)
    return float(max(rhos))


@dataclass(frozen=True)
class CorpusEntry:
    """One golden scenario plus the metadata the differential harness needs."""

    scenario: Scenario
    strategy: str  # the evaluation path this entry exercises
    regime: str  # which closed-form regime is load-bearing
    rho: float  # bottleneck utilization on the strategy's path
    sim_gate: bool  # counts toward the analytic-vs-simulated MAPE gate
    smoke: bool  # member of the fast tier-1 subset

    @property
    def name(self) -> str:
        return self.scenario.name

    @property
    def band(self) -> str:
        return rho_band(self.rho)

    def to_dict(self) -> dict:
        return {
            "scenario": self.scenario.to_dict(),
            "strategy": self.strategy,
            "regime": self.regime,
            "rho": self.rho,
            "rho_band": self.band,
            "sim_gate": self.sim_gate,
            "smoke": self.smoke,
            # golden pin: scalar analytic totals at generation time
            "expected_totals": analytic(self.scenario).totals(),
        }

    @classmethod
    def from_dict(cls, d: Mapping) -> "CorpusEntry":
        return cls(
            scenario=Scenario.from_dict(d["scenario"]),
            strategy=d["strategy"],
            regime=d["regime"],
            rho=float(d["rho"]),
            sim_gate=bool(d["sim_gate"]),
            smoke=bool(d["smoke"]),
        )


# ---------------------------------------------------------------------------
# generation
# ---------------------------------------------------------------------------

# (name, service_time_s, ServiceModel, cv^2 for GENERAL) — paper-flavoured
# accelerator tiers; DNNs are deterministic [27], LLM/RNN decode exponential
# (Lemma 3.3), mixed-serving general (Lemma 3.2).
_DEVICE_TIERS = (
    ("tx2-dnn", 0.150, ServiceModel.DETERMINISTIC, 0.0),
    ("orin-dnn", 0.045, ServiceModel.DETERMINISTIC, 0.0),
    ("cpu-rnn", 0.120, ServiceModel.EXPONENTIAL, 1.0),
    ("npu-mixed", 0.060, ServiceModel.GENERAL, 0.25),
)

_EDGE_TIERS = (
    ("a2-dnn", 0.028, ServiceModel.DETERMINISTIC, 0.0),
    ("a100-dnn", 0.008, ServiceModel.DETERMINISTIC, 0.0),
    ("t4-llm", 0.020, ServiceModel.EXPONENTIAL, 1.0),
    ("edge-mixed", 0.015, ServiceModel.GENERAL, 0.25),
)

_BANDWIDTHS_BPS = (5e6 / 8, 20e6 / 8, 100e6 / 8)  # 5 / 20 / 100 Mbit links


def _tier(name: str, s: float, model: ServiceModel, cv2: float, k: float = 1.0) -> Tier:
    return Tier(
        name=name,
        service_time_s=s,
        parallelism_k=k,
        service_model=model,
        service_var=cv2 * s * s if model is ServiceModel.GENERAL else 0.0,
    )


def _jitter(rng: np.random.Generator, value: float, frac: float = 0.1) -> float:
    """Seeded multiplicative jitter so corpus points aren't round numbers."""
    return float(value * rng.uniform(1.0 - frac, 1.0 + frac))


def _device_entry(
    rng: np.random.Generator,
    spec: tuple[str, float, ServiceModel, float],
    target_rho: float,
    *,
    k: float = 1.0,
    regime: str | None = None,
    sim_gate: bool = True,
    smoke: bool = False,
) -> CorpusEntry:
    name, s0, model, cv2 = spec
    s = _jitter(rng, s0)
    lam = target_rho * k / s
    scn = Scenario(
        workload=Workload(arrival_rate=lam, req_bytes=50_000, res_bytes=2_000,
                          name="corpus"),
        device=_tier(name, s, model, cv2, k),
        network=NetworkPath(bandwidth_Bps=_BANDWIDTHS_BPS[-1]),
        edges=(),
        name=f"dev-{name}-rho{target_rho:.2f}" + (f"-k{k:g}" if k != 1.0 else ""),
    )
    return CorpusEntry(
        scenario=scn,
        strategy="on_device",
        regime=regime or f"device-{model.value}",
        rho=bottleneck_rho(scn, "on_device"),
        sim_gate=sim_gate and target_rho <= 0.9,
        smoke=smoke,
    )


def _offload_entry(
    rng: np.random.Generator,
    edge_spec: tuple[str, float, ServiceModel, float],
    target_rho: float,
    *,
    bound: str,  # "compute" | "network"
    k_edge: float = 1.0,
    regime: str | None = None,
    sim_gate: bool = True,
    smoke: bool = False,
) -> CorpusEntry:
    name, s0, model, cv2 = edge_spec
    s = _jitter(rng, s0)
    req = _jitter(rng, 120_000)
    res = _jitter(rng, 4_000)
    if bound == "compute":
        # edge processing is the bottleneck; NICs run at ~40% of target rho
        lam = target_rho * k_edge / s
        bw = lam * req / max(0.05, 0.4 * target_rho)
    else:
        # device NIC is the bottleneck; edge runs at ~35% of target rho
        bw = _jitter(rng, _BANDWIDTHS_BPS[0])
        lam = target_rho * bw / req
        s = max(0.05, 0.35 * target_rho) * k_edge / lam
    # device exists but is off-path: keep its own queue comfortably stable
    dev_k = max(1.0, lam * 0.150 / 0.7)
    scn = Scenario(
        workload=Workload(arrival_rate=lam, req_bytes=req, res_bytes=res,
                          name="corpus"),
        device=Tier("tx2-dnn", 0.150, parallelism_k=dev_k),
        network=NetworkPath(bandwidth_Bps=bw),
        edges=(EdgeSpec(_tier(name, s, model, cv2, k_edge)),),
        name=f"off-{bound}-{name}-rho{target_rho:.2f}"
        + (f"-k{k_edge:g}" if k_edge != 1.0 else ""),
    )
    return CorpusEntry(
        scenario=scn,
        strategy="edge[0]",
        regime=regime or f"offload-{bound}-{model.value}",
        rho=bottleneck_rho(scn, "edge[0]"),
        sim_gate=sim_gate and target_rho <= 0.9,
        smoke=smoke,
    )


def _multitenant_entry(
    rng: np.random.Generator,
    target_rho: float,
    n_tenants: int,
    *,
    hetero: bool = False,
    smoke: bool = False,
    sim_gate: bool = True,
) -> CorpusEntry:
    s_edge = _jitter(rng, 0.020)
    lam_own = _jitter(rng, 2.0)
    # Gated entries use near-homogeneous tenant service means (the paper's
    # §4.8 setup: m copies of the same app). Lemma 3.2 prices every job at the
    # MIXTURE mean, so strongly heterogeneous means are a known, quantified
    # model approximation — generated too (``hetero``), reported, not gated.
    if hetero:
        means = [_jitter(rng, m, 0.2) for m in np.linspace(0.010, 0.045, n_tenants)]
    else:
        means = [_jitter(rng, s_edge) for _ in range(n_tenants)]
    cv2s = [rng.choice([0.0, 0.25, 1.0]) for _ in range(n_tenants)]
    budget = target_rho - lam_own * s_edge  # background's share of utilization
    if budget <= 0:
        raise ValueError("target rho too small for the own stream alone")
    weights = rng.uniform(0.5, 1.5, size=n_tenants)
    weights /= weights.sum()
    tenants = tuple(
        TenantStream(
            arrival_rate=float(w * budget / m),
            service_mean_s=float(m),
            service_var=float(c * m * m),
            name=f"tenant{i}",
        )
        for i, (w, m, c) in enumerate(zip(weights, means, cv2s))
    )
    bw = _BANDWIDTHS_BPS[2]
    scn = Scenario(
        workload=Workload(arrival_rate=lam_own, req_bytes=60_000, res_bytes=3_000,
                          name="corpus"),
        device=Tier("tx2-dnn", 0.150),
        network=NetworkPath(bandwidth_Bps=bw),
        edges=(EdgeSpec(
            _tier("shared-edge", s_edge, ServiceModel.GENERAL, 0.25),
            background=tenants,
        ),),
        name=f"mt-{'het-' if hetero else ''}{n_tenants}tenants-rho{target_rho:.2f}",
    )
    return CorpusEntry(
        scenario=scn,
        strategy="edge[0]",
        regime="multitenant-hetero" if hetero else "multitenant",
        rho=bottleneck_rho(scn, "edge[0]"),
        sim_gate=sim_gate and not hetero and target_rho <= 0.9,
        smoke=smoke,
    )


def _cluster_entry(
    rng: np.random.Generator,
    n_clients: int,
    target_rho: float,
    *,
    sim_gate: bool = True,
    smoke: bool = False,
) -> CorpusEntry:
    """Closed-loop regime: a representative client's induced scenario at the
    solved equilibrium of a small cluster (paper §6).

    The cluster is sized so the fleet's best response concentrates on the
    fast edge at ~``target_rho`` utilization — a slow device keeps everyone
    offloading, and the second edge is bad enough that nobody spills — and
    the representative's view of that fixed point (the other clients as
    per-stream background) is pinned like any other multitenant entry. The
    equilibrium solver is deterministic, so regeneration stays byte-identical."""
    from repro.core.scenario import ClusterSpec
    from repro.fleet.cluster import induced_scenario, solve_equilibrium

    lam = _jitter(rng, 2.0)
    s_fast = _jitter(rng, target_rho / (n_clients * lam), 0.05)
    spec = ClusterSpec(
        base=Scenario(
            workload=Workload(arrival_rate=lam, req_bytes=40_000, res_bytes=2_000,
                              name="corpus"),
            device=Tier("cpu-slow", 0.400),
            network=NetworkPath(bandwidth_Bps=_BANDWIDTHS_BPS[2]),
            edges=(
                EdgeSpec(_tier("cluster-fast", s_fast, ServiceModel.DETERMINISTIC, 0.0)),
                EdgeSpec(_tier("cluster-slow", 6.0 * s_fast,
                               ServiceModel.DETERMINISTIC, 0.0)),
            ),
            name=f"cluster-base-rho{target_rho:.2f}",
        ),
        n_clients=n_clients,
        name=f"cluster-{n_clients}c-rho{target_rho:.2f}",
    )
    eq = solve_equilibrium(spec)
    assert eq.converged, "corpus cluster must reach its fixed point"
    on_edges = eq.choices[eq.choices >= 0]
    assert on_edges.size, "corpus cluster equilibrium must offload"
    j = int(np.argmax(np.bincount(on_edges, minlength=spec.n_edges)))
    rep = int(np.nonzero(eq.choices == j)[0][0])
    scn = induced_scenario(
        spec, eq.choices, rep,
        name=f"cluster-{n_clients}c-rho{target_rho:.2f}",
    )
    strategy = f"edge[{j}]"
    rho = bottleneck_rho(scn, strategy)
    return CorpusEntry(
        scenario=scn,
        strategy=strategy,
        regime="cluster-equilibrium",
        rho=rho,
        sim_gate=sim_gate and rho <= 0.9,
        smoke=smoke,
    )


def _meanfield_entry(
    rng: np.random.Generator,
    target_rho: float,
    *,
    sim_gate: bool = True,
    smoke: bool = False,
) -> CorpusEntry:
    """Mean-field regime: a representative client's induced scenario at the
    integerized mean-field fixed point of a small multi-class fleet (§6 at
    the continuum limit).

    The fleet has three client classes — two well-connected (steady/heavy)
    whose combined rate lands the fast edge near ``target_rho``, and a
    cellular class whose thin uplink keeps it on-device — so the solved
    fractions are class-structured rather than uniform. The continuous
    fractions are integerized per class by largest remainder, the
    representative is the first client on the busiest edge, and its induced
    view of the fixed point is pinned like any other multitenant entry: any
    drift in the mean-field solver moves the induced spec and fails the
    golden pin by name. The solver is deterministic, so regeneration stays
    byte-identical."""
    from repro.core.scenario import ClientClass, MeanFieldSpec
    from repro.fleet.cluster import induced_scenario
    from repro.fleet.meanfield import solve_meanfield_equilibrium

    lam = _jitter(rng, 2.0)
    classes = (
        ClientClass(n_clients=6, arrival_scale=1.0, name="steady"),
        ClientClass(n_clients=3, arrival_scale=2.0, name="heavy"),
        ClientClass(n_clients=3, arrival_scale=0.5, bandwidth_scale=0.08,
                    name="cellular"),
    )
    # the two well-connected classes' combined rate sets the fast edge's rho
    offload_rate = (6 * 1.0 + 3 * 2.0) * lam
    s_fast = _jitter(rng, target_rho / offload_rate, 0.05)
    n_total = sum(c.n_clients for c in classes)
    spec = MeanFieldSpec(
        base=Scenario(
            workload=Workload(arrival_rate=lam, req_bytes=40_000, res_bytes=2_000,
                              name="corpus"),
            device=Tier("tx2-dnn", 0.150),
            network=NetworkPath(bandwidth_Bps=_BANDWIDTHS_BPS[1]),
            edges=(
                EdgeSpec(_tier("mf-fast", s_fast, ServiceModel.DETERMINISTIC, 0.0)),
                EdgeSpec(_tier("mf-slow", 6.0 * s_fast,
                               ServiceModel.DETERMINISTIC, 0.0)),
            ),
            name=f"mf-base-rho{target_rho:.2f}",
        ),
        classes=classes,
        name=f"mf-{n_total}c-rho{target_rho:.2f}",
    )
    mf = solve_meanfield_equilibrium(spec)
    assert mf.converged, "corpus mean-field fleet must reach its fixed point"
    # integerize: per class, largest-remainder apportionment of n_c over targets
    choice_list: list[int] = []
    for c, cl in enumerate(spec.classes):
        exact = cl.n_clients * mf.fractions[c]
        counts = np.floor(exact).astype(np.int64)
        order = np.argsort(-(exact - counts), kind="stable")
        counts[order[: cl.n_clients - int(counts.sum())]] += 1
        for tgt, k in enumerate(counts):
            choice_list.extend([tgt - 1] * int(k))
    choices = np.array(choice_list, dtype=np.int64)
    on_edges = choices[choices >= 0]
    assert on_edges.size, "corpus mean-field fixed point must offload"
    j = int(np.argmax(np.bincount(on_edges, minlength=spec.n_edges)))
    rep = int(np.nonzero(choices == j)[0][0])
    scn = induced_scenario(spec.to_cluster(), choices, rep,
                           name=f"mf-{n_total}c-rho{target_rho:.2f}")
    strategy = f"edge[{j}]"
    rho = bottleneck_rho(scn, strategy)
    return CorpusEntry(
        scenario=scn,
        strategy=strategy,
        regime="meanfield-equilibrium",
        rho=rho,
        sim_gate=sim_gate and rho <= 0.9,
        smoke=smoke,
    )


def generate_corpus(seed: int = DEFAULT_SEED) -> tuple[CorpusEntry, ...]:
    """The golden corpus: deterministic in ``seed``, spanning tiers x
    bandwidth x arrival rate x tenancy x service-model mix x utilization
    bands up to rho ~ 0.95."""
    rng = np.random.default_rng(seed)
    entries: list[CorpusEntry] = []

    # -- on-device: every tier x a rho ladder into the stress band ----------
    for spec in _DEVICE_TIERS:
        for rho in (0.2, 0.5, 0.75, 0.9):
            entries.append(_device_entry(
                rng, spec, rho,
                smoke=(rho == 0.5 and spec[0] in ("tx2-dnn", "cpu-rnn", "npu-mixed")),
            ))
    # stress band: reported, never gated (sim means are noise-dominated there)
    entries.append(_device_entry(rng, _DEVICE_TIERS[0], 0.95))
    entries.append(_device_entry(rng, _DEVICE_TIERS[2], 0.95))
    # k>1 aggregation approximation (paper §3.5): quantified, not gated
    for rho in (0.5, 0.8):
        entries.append(_device_entry(
            rng, _DEVICE_TIERS[0], rho, k=4.0, regime="device-aggregated-k",
            sim_gate=False,
        ))

    # -- dedicated-edge offload: compute-bound and network-bound -------------
    for spec in _EDGE_TIERS:
        for rho in (0.25, 0.55, 0.8):
            entries.append(_offload_entry(
                rng, spec, rho, bound="compute",
                smoke=(rho == 0.55 and spec[0] in ("a2-dnn", "t4-llm")),
            ))
    entries.append(_offload_entry(rng, _EDGE_TIERS[0], 0.9, bound="compute"))
    entries.append(_offload_entry(rng, _EDGE_TIERS[0], 0.93, bound="compute"))
    for rho, smoke in ((0.45, True), (0.75, False), (0.88, False)):
        entries.append(_offload_entry(rng, _EDGE_TIERS[1], rho, bound="network",
                                      smoke=smoke))
    # k>1 edge: aggregation regime again, not gated
    entries.append(_offload_entry(
        rng, _EDGE_TIERS[0], 0.7, bound="compute", k_edge=2.0,
        regime="offload-aggregated-k", sim_gate=False,
    ))

    # -- multi-tenant edges (§3.4): tenancy x utilization --------------------
    entries.append(_multitenant_entry(rng, 0.40, 2, smoke=True))
    entries.append(_multitenant_entry(rng, 0.65, 3))
    entries.append(_multitenant_entry(rng, 0.80, 4))
    entries.append(_multitenant_entry(rng, 0.92, 3, sim_gate=False))
    # heterogeneous mixtures: the Lemma-3.2 mixture-mean approximation,
    # quantified but never gated
    entries.append(_multitenant_entry(rng, 0.45, 2, hetero=True))
    entries.append(_multitenant_entry(rng, 0.75, 3, hetero=True))

    # -- closed-loop cluster equilibria (§6): a representative client's view
    # of the solved fixed point, gated like any multitenant entry ------------
    entries.append(_cluster_entry(rng, 8, 0.55))
    entries.append(_cluster_entry(rng, 8, 0.82))

    # -- tail-percentile regime: entries whose job is exercising the sojourn-
    # QUANTILE layer (analytic p99 vs simulated percentile(99)). Appended
    # last so every earlier entry's rng draws — and therefore the whole
    # pinned fixture prefix — stay byte-identical across regenerations.
    # Exact-transform service models only (det/exp); the gamma-vs-lognormal
    # GENERAL approximation is quantified through the ordinary regimes.
    entries.append(_device_entry(rng, _DEVICE_TIERS[2], 0.6,
                                 regime="tail-percentile", smoke=True))
    entries.append(_device_entry(rng, _DEVICE_TIERS[0], 0.7,
                                 regime="tail-percentile"))
    entries.append(_offload_entry(rng, _EDGE_TIERS[2], 0.6, bound="compute",
                                  regime="tail-percentile"))

    # -- mean-field equilibria (ROADMAP's million-client direction): the
    # integerized fixed point of a class-structured fleet, gated like the
    # cluster regime. Appended last, same prefix-stability discipline as
    # tail-percentile above.
    entries.append(_meanfield_entry(rng, 0.55))
    entries.append(_meanfield_entry(rng, 0.82))

    names = [e.name for e in entries]
    assert len(names) == len(set(names)), "corpus entry names must be unique"
    return tuple(entries)


# ---------------------------------------------------------------------------
# fixture IO
# ---------------------------------------------------------------------------


def default_fixture_path() -> Path:
    """tests/golden/corpus_v1.json at the repo root (source checkouts)."""
    return Path(__file__).resolve().parents[3] / "tests" / "golden" / "corpus_v1.json"


def corpus_to_dict(entries: Iterable[CorpusEntry], *, seed: int) -> dict:
    return {
        "version": CORPUS_VERSION,
        "seed": seed,
        "generator": "repro.validate.corpus:generate_corpus",
        "entries": [e.to_dict() for e in entries],
    }


def save_corpus(entries: Sequence[CorpusEntry], path: Path, *, seed: int) -> None:
    """Write the fixture JSON (stable key order, full float precision, so
    regeneration with the same seed is byte-identical)."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(corpus_to_dict(entries, seed=seed), indent=2,
                               sort_keys=True) + "\n")


def load_corpus(path: Path | None = None) -> tuple[tuple[CorpusEntry, ...], dict]:
    """Load (entries, metadata) from a fixture; falls back to regenerating
    from the default seed when no fixture exists (installed-package use)."""
    path = default_fixture_path() if path is None else Path(path)
    if not path.exists():
        entries = generate_corpus(DEFAULT_SEED)
        return entries, {"version": CORPUS_VERSION, "seed": DEFAULT_SEED,
                         "path": None}
    d = json.loads(path.read_text())
    if d.get("version") != CORPUS_VERSION:
        raise ScenarioError("corpus.version",
                            f"fixture {path} has version {d.get('version')!r}, "
                            f"expected {CORPUS_VERSION}")
    entries = tuple(CorpusEntry.from_dict(ed) for ed in d["entries"])
    meta = {"version": d["version"], "seed": d["seed"], "path": str(path),
            "expected_totals": {ed["scenario"]["name"]: ed["expected_totals"]
                                for ed in d["entries"]}}
    return entries, meta
