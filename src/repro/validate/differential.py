"""Differential validation: every corpus scenario through all four paths.

The repo evaluates one operating point four independent ways:

  1. ``scenario.analytic()``        — scalar closed forms (numpy),
  2. ``fleet.fleet_analytic``       — jitted/vectorized closed forms (JAX),
  3. ``scenario.simulate()``        — scalar discrete-event simulator,
  4. ``fleet.simulate_fleet``       — batched Lindley-recursion simulator.

This module pushes the golden corpus through all of them and scores the
path pairs the paper's fidelity claim rests on:

  * scalar vs vectorized analytic must agree to ``vec_tol`` (default 1e-6
    relative — it actually holds to ~1e-9; any excess is a transcription bug,
    not statistics);
  * recomputed scalar analytic must match the fixture's golden totals
    (``golden_tol``) — drift in the closed forms shows up as a diff here;
  * analytic vs long-run simulation must land within a MAPE budget over the
    gated entries (rho <= 0.9, exact-model regimes), reported per utilization
    band and per regime with block-bootstrap CIs on every simulated mean —
    the repo's analogue of the paper's Table of observed-vs-predicted
    latencies (2.2% mean, 91.5% within ±5%);
  * the two simulators, where both apply, must agree statistically
    (independent RNG streams estimating the same queue).

``run_differential`` is pure given its inputs and seeded throughout, so a
failing report reproduces exactly.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Mapping, Sequence

import numpy as np

from repro.core.latency import NetworkPath, ServiceModel, Tier, Workload
from repro.core.scenario import (
    ClientClass,
    EdgeSpec,
    MeanFieldSpec,
    Scenario,
    analytic_tail,
    parse_strategy,
)
from repro.core.scenario import simulate as scalar_simulate
from repro.core.simulation import steady_slice
from repro.fleet import (
    ScenarioBatch,
    cross_check_meanfield,
    fleet_analytic,
    fleet_tail,
    simulate_fleet,
)

from .corpus import BAND_ORDER, CorpusEntry
from .metrics import BootstrapCI, ErrorStats, bootstrap_mean_ci, error_stats, error_table, mape

__all__ = [
    "EntryReport",
    "ValidationReport",
    "run_differential",
    "run_meanfield_gate",
    "meanfield_gate_specs",
    "smoke_subset",
    "tail_gated",
    "DEFAULT_MAPE_BUDGET_PCT",
    "DEFAULT_MEANFIELD_BUDGET_PCT",
    "DEFAULT_VEC_TOL",
    "DEFAULT_GOLDEN_TOL",
    "DEFAULT_TAIL_BUDGET_PCT",
    "DEFAULT_TAIL_PCT",
    "DEFAULT_EULER_VEC_TOL",
    "EULER_VEC_RHO_MAX",
]

DEFAULT_MAPE_BUDGET_PCT = 5.0
DEFAULT_VEC_TOL = 1e-6
DEFAULT_GOLDEN_TOL = 1e-9
# tail-percentile gate: analytic p99 vs simulated percentile(99). Budget is
# looser than the mean gate because a p99 comparison stacks three error
# sources the mean one does not have: the tandem independence approximation,
# the Euler inversion (~1e-8, negligible), and the much noisier simulated
# percentile estimator.
DEFAULT_TAIL_BUDGET_PCT = 10.0
DEFAULT_TAIL_PCT = 99.0
# tail-euler-vec gate: the batched exact Euler inversion vs the scalar one,
# per corpus entry. Both sides deliberately run the IDENTICAL search
# trajectory (grow/bisect/Newton), so the only divergence left is float
# noise flipping a boolean bisection decision at a razor-edge coincidence —
# observed agreement is ~1e-11; 1e-8 is the contract. Restricted to
# rho <= EULER_VEC_RHO_MAX: deeper into saturation the transform's
# conditioning degrades faster than any scalar/vec comparison can resolve.
DEFAULT_EULER_VEC_TOL = 1e-8
EULER_VEC_RHO_MAX = 0.95
# meanfield gate: the class-aggregated Wardrop fixed point vs the exact
# per-client equilibrium on fixed small fleets (both sides analytic, so the
# block is cheap enough to run on every differential pass including smoke).
# Gated rows are the <= rho_gate per-class latencies and busy-edge
# utilizations cross_check_meanfield reports — same 5% contract as the
# analytic-vs-simulated mean gate.
DEFAULT_MEANFIELD_BUDGET_PCT = 5.0


def tail_gated(e: CorpusEntry) -> bool:
    """Does this entry count toward the tail-percentile gate?

    Mean-gated (rho <= 0.9, exact mean regimes) AND every station on the
    strategy path has an exact service transform (deterministic/exponential).
    GENERAL tiers and multi-tenant mixtures simulate lognormal draws that the
    tail layer's two-moment gamma match only approximates — those are
    reported (quantified), never gated, like every other known model
    approximation in this harness.
    """
    if not e.sim_gate:
        return False
    scn = e.scenario
    j = parse_strategy(e.strategy, len(scn.edges))
    if j < 0:
        return scn.device.service_model is not ServiceModel.GENERAL
    edge = scn.edges[j]
    if edge.background:
        return False
    return edge.tier.service_model is not ServiceModel.GENERAL


def smoke_subset(entries: Sequence[CorpusEntry]) -> list[CorpusEntry]:
    """The fast tier-1 slice of the corpus (entries flagged ``smoke``)."""
    return [e for e in entries if e.smoke]


def meanfield_gate_specs() -> tuple[MeanFieldSpec, ...]:
    """The fixed small fleets the mean-field-vs-exact gate solves.

    Deliberately constant (no seed, no jitter): the gate compares two
    *solvers* on the same spec, so the specs themselves carry no golden
    state to pin — the assertion is agreement, not a frozen value. Two
    shapes: a mixed-rate fleet with a deterministic and an exponential edge
    (the test-suite workhorse), and a heavier two-class fleet whose busy
    edge sits in the high band where the continuum approximation is most
    stressed below the gate's rho ceiling."""
    base = Scenario(
        workload=Workload(2.0, 30_000, 1_000, name="meanfield-gate"),
        device=Tier("orin", 0.045),
        network=NetworkPath(20e6 / 8),
        edges=(
            EdgeSpec(Tier("a2", 0.028)),
            EdgeSpec(Tier("t4", 0.020, service_model=ServiceModel.EXPONENTIAL)),
        ),
        name="mf-gate-base",
    )
    mixed = MeanFieldSpec(
        base=base,
        classes=(
            ClientClass(n_clients=16, arrival_scale=1.0, name="steady"),
            ClientClass(n_clients=16, arrival_scale=0.5, name="light"),
            ClientClass(n_clients=8, arrival_scale=2.0, bandwidth_scale=0.5,
                        name="heavy"),
        ),
        name="mf-gate-mixed",
    )
    heavy = MeanFieldSpec(
        base=Scenario(
            workload=Workload(2.5, 40_000, 2_000, name="meanfield-gate"),
            device=Tier("tx2", 0.150),
            network=NetworkPath(20e6 / 8),
            edges=(EdgeSpec(Tier("a2", 0.014)),
                   EdgeSpec(Tier("a2-far", 0.028))),
            name="mf-gate-heavy-base",
        ),
        classes=(
            ClientClass(n_clients=24, arrival_scale=1.0, name="steady"),
            ClientClass(n_clients=8, arrival_scale=1.5, name="heavy"),
        ),
        name="mf-gate-heavy",
    )
    return (mixed, heavy)


def run_meanfield_gate(
    specs: Sequence[MeanFieldSpec] | None = None,
    *,
    budget_pct: float = DEFAULT_MEANFIELD_BUDGET_PCT,
) -> dict:
    """Cross-check the mean-field solver against the exact one per spec.

    Runs :func:`repro.fleet.cross_check_meanfield` on every spec and folds
    the per-spec gated maxima into one pass/fail block shaped like the other
    ``ValidationReport`` gates. Both solvers are deterministic and analytic,
    so the result is reproducible and cheap (no simulation)."""
    specs = meanfield_gate_specs() if specs is None else list(specs)
    checks = []
    for spec in specs:
        r = cross_check_meanfield(spec)
        checks.append({"spec": spec.name, "n_total": spec.n_total, **r})
    gated_max = [c["gated_max_mape_pct"] for c in checks
                 if c["gated_max_mape_pct"] is not None]
    gated_mean = [c["gated_mean_mape_pct"] for c in checks
                  if c["gated_mean_mape_pct"] is not None]
    converged = all(c["meanfield_converged"] and c["exact_converged"]
                    for c in checks)
    max_pct = float(max(gated_max)) if gated_max else None
    return {
        "budget_pct": float(budget_pct),
        "n_specs": len(checks),
        "converged": converged,
        "gated_max_mape_pct": max_pct,
        "gated_mean_mape_pct": float(np.mean(gated_mean)) if gated_mean else None,
        # a gate nobody exercised stays "pass, n=0" like the other gates, but
        # a non-converged solver is always a loud failure
        "passed": converged and (max_pct is None or max_pct <= budget_pct),
        "specs": checks,
    }


def _rel_err(a: float, b: float) -> float:
    """Symmetric-denominator relative error. Two same-sign infinities agree
    exactly; a one-sided inf or any NaN is an INFINITE error, never a NaN —
    ``max()`` silently drops NaNs, which would let exactly the
    inf-vs-finite transcription bug this check exists to catch slip through."""
    if np.isnan(a) or np.isnan(b):
        return float("inf")
    if np.isinf(a) or np.isinf(b):
        return 0.0 if (np.isinf(a) and np.isinf(b) and (a > 0) == (b > 0)) \
            else float("inf")
    denom = max(abs(a), abs(b), 1e-300)
    return abs(a - b) / denom


@dataclass(frozen=True)
class EntryReport:
    """One corpus scenario's cross-path scores."""

    name: str
    regime: str
    band: str
    rho: float
    strategy: str
    sim_gate: bool
    analytic_scalar_s: float  # scalar closed-form total on the strategy path
    analytic_vec_s: float  # vectorized closed-form total, same path
    vec_rel_err: float  # max over ALL strategies of this scenario
    golden_rel_err: float | None  # vs fixture totals (None without a fixture)
    sim_backend: str | None  # "fleet" | "scalar" | None (not simulated)
    sim_n: int
    sim_mean_s: float | None
    sim_ci: BootstrapCI | None
    sim_mape_pct: float | None
    tail_gate: bool = False  # counts toward the tail-percentile gate
    analytic_tail_s: float | None = None  # scalar q-quantile, strategy path
    sim_tail_s: float | None = None  # simulated percentile(tail_pct)
    tail_mape_pct: float | None = None

    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "regime": self.regime,
            "rho_band": self.band,
            "rho": self.rho,
            "strategy": self.strategy,
            "sim_gate": self.sim_gate,
            "analytic_scalar_s": self.analytic_scalar_s,
            "analytic_vec_s": self.analytic_vec_s,
            "vec_rel_err": self.vec_rel_err,
            "golden_rel_err": self.golden_rel_err,
            "sim_backend": self.sim_backend,
            "sim_n": self.sim_n,
            "sim_mean_s": self.sim_mean_s,
            "sim_ci": None if self.sim_ci is None else self.sim_ci.to_dict(),
            "sim_mape_pct": self.sim_mape_pct,
            "tail_gate": self.tail_gate,
            "analytic_tail_s": self.analytic_tail_s,
            "sim_tail_s": self.sim_tail_s,
            "tail_mape_pct": self.tail_mape_pct,
        }


@dataclass(frozen=True)
class ValidationReport:
    """The full fidelity report ``launch/validate.py`` serialises."""

    entries: tuple[EntryReport, ...]
    vec_max_rel_err: float
    vec_tol: float
    golden_max_rel_err: float | None
    golden_tol: float
    gate: ErrorStats  # over sim-gated entries only
    mape_budget_pct: float
    bands: Mapping[str, ErrorStats]  # ALL simulated entries, by rho band
    regimes: Mapping[str, ErrorStats]
    sim_cross: Mapping[str, float]  # scalar-vs-fleet simulator agreement
    config: Mapping[str, object]
    tail: ErrorStats = error_stats(())  # tail-gated entries only
    tail_budget_pct: float = DEFAULT_TAIL_BUDGET_PCT
    tail_pct: float = DEFAULT_TAIL_PCT
    tail_vec_max_rel_err: float | None = None  # scalar tail vs fleet_tail
    euler_vec_max_rel_err: float | None = None  # batched exact euler vs scalar
    euler_vec_tol: float = DEFAULT_EULER_VEC_TOL
    euler_vec_n: int = 0  # corpus entries inside the rho <= 0.95 gate
    meanfield: Mapping[str, object] | None = None  # run_meanfield_gate block

    @property
    def vec_passed(self) -> bool:
        return self.vec_max_rel_err <= self.vec_tol

    @property
    def golden_passed(self) -> bool:
        return self.golden_max_rel_err is None or \
            self.golden_max_rel_err <= self.golden_tol

    @property
    def gate_passed(self) -> bool:
        # a gate nobody exercised (analytic-only run, or an entry set with no
        # sim-gated members) is consistently "pass, n=0" — the tier-2 test
        # separately asserts the REAL corpus keeps gate.n large
        if self.gate.n == 0:
            return True
        return self.gate.mean_pct <= self.mape_budget_pct

    @property
    def tail_vec_passed(self) -> bool:
        return self.tail_vec_max_rel_err is None or \
            self.tail_vec_max_rel_err <= self.vec_tol

    @property
    def euler_vec_passed(self) -> bool:
        return self.euler_vec_max_rel_err is None or \
            self.euler_vec_max_rel_err <= self.euler_vec_tol

    @property
    def tail_passed(self) -> bool:
        if self.tail.n == 0:
            return True
        return self.tail.mean_pct <= self.tail_budget_pct

    @property
    def meanfield_passed(self) -> bool:
        return self.meanfield is None or bool(self.meanfield["passed"])

    @property
    def passed(self) -> bool:
        return (self.vec_passed and self.golden_passed and self.gate_passed
                and self.tail_vec_passed and self.euler_vec_passed
                and self.tail_passed and self.meanfield_passed)

    def to_dict(self) -> dict:
        return {
            "version": 1,
            "passed": self.passed,
            "config": dict(self.config),
            "scalar_vs_vec": {
                "max_rel_err": self.vec_max_rel_err,
                "tol": self.vec_tol,
                "passed": self.vec_passed,
            },
            "golden": {
                "max_rel_err": self.golden_max_rel_err,
                "tol": self.golden_tol,
                "passed": self.golden_passed,
            },
            "mape_gate": {
                "budget_pct": self.mape_budget_pct,
                "passed": self.gate_passed,
                **self.gate.to_dict(),
            },
            "tail_gate": {
                "tail_pct": self.tail_pct,
                "budget_pct": self.tail_budget_pct,
                "passed": self.tail_passed,
                **self.tail.to_dict(),
            },
            "scalar_vs_vec_tail": {
                "max_rel_err": self.tail_vec_max_rel_err,
                "tol": self.vec_tol,
                "passed": self.tail_vec_passed,
            },
            "tail_euler_vec": {
                "max_rel_err": self.euler_vec_max_rel_err,
                "tol": self.euler_vec_tol,
                "rho_max": EULER_VEC_RHO_MAX,
                "n_entries": self.euler_vec_n,
                "passed": self.euler_vec_passed,
            },
            "meanfield_gate": None if self.meanfield is None
            else dict(self.meanfield),
            "bands": {k: v.to_dict() for k, v in self.bands.items()},
            "regimes": {k: v.to_dict() for k, v in self.regimes.items()},
            "sim_cross": dict(self.sim_cross),
            "entries": [e.to_dict() for e in self.entries],
        }


def _sim_n_for(rho: float, base_n: int, max_factor: float) -> int:
    """Longer runs near saturation (autocorrelation grows sharply as rho -> 1,
    so the mean needs more samples to resolve a 5% comparison at all). The
    factor is quantized to a small tier ladder so batched groups can share a
    launch without low-rho rows inheriting a stress entry's run length."""
    factor = min(max_factor, max(1.0, 0.5 / max(1e-6, 1.0 - rho)))
    for tier in (1.0, 2.0, 4.0):
        if factor <= tier <= max_factor:
            return int(base_n * tier)
    return int(base_n * max_factor)


def _simulate_entries(
    entries: Sequence[CorpusEntry],
    idxs: Sequence[int],
    *,
    base_n: int,
    max_factor: float,
    seed: int,
    bootstrap: int,
    tail_pct: float = DEFAULT_TAIL_PCT,
) -> dict[int, tuple[str, int, float, BootstrapCI, float]]:
    """Simulate every entry, batching where the vectorized simulator applies.

    Returns ``{corpus index: (backend, n, mean, ci, tail_percentile)}``.
    Dedicated-edge and on-device entries run through ``simulate_fleet``
    grouped by their exact strategy string (one device launch per group);
    entries whose target edge hosts background tenants need the
    shared-station scalar simulator.
    """
    out: dict[int, tuple[str, int, float, BootstrapCI, float]] = {}
    # one launch per (strategy, run-length tier): batching is preserved
    # within a tier, and a stress entry's long run never inflates the
    # low-utilization rows that share its strategy
    groups: dict[tuple[str, int], list[int]] = {}
    scalar_idxs: list[int] = []
    for i in idxs:
        e = entries[i]
        j = parse_strategy(e.strategy, len(e.scenario.edges))
        if j >= 0 and e.scenario.edges[j].background:
            scalar_idxs.append(i)
        else:
            n = _sim_n_for(e.rho, base_n, max_factor)
            groups.setdefault((e.strategy, n), []).append(i)

    for (strategy, n), members in groups.items():
        batch = ScenarioBatch.from_scenarios([entries[i].scenario for i in members])
        res = simulate_fleet(batch, strategy, n=n, seed=seed)
        steady = res.latencies[:, steady_slice(n)]
        for row, i in enumerate(members):
            ci = bootstrap_mean_ci(steady[row], n_boot=bootstrap, seed=seed + i)
            out[i] = ("fleet", n, float(steady[row].mean()), ci,
                      float(np.percentile(steady[row], tail_pct)))

    for i in scalar_idxs:
        e = entries[i]
        n = _sim_n_for(e.rho, base_n, max_factor)
        res = scalar_simulate(e.scenario, e.strategy, n=n, seed=seed + i)
        # observed = the scenario's own stream, trimmed with the one shared
        # steady-state window (cf. SimResult.stream_mean)
        sl = steady_slice(len(res.latencies), res.warmup_frac)
        mask = res.stream_ids[sl] == 0
        own = res.latencies[sl][mask]
        ci = bootstrap_mean_ci(own, n_boot=bootstrap, seed=seed + i)
        out[i] = ("scalar", n, float(own.mean()), ci,
                  float(np.percentile(own, tail_pct)))
    return out


def run_differential(
    entries: Sequence[CorpusEntry],
    *,
    expected_totals: Mapping[str, Mapping[str, float]] | None = None,
    base_n: int = 120_000,
    max_n_factor: float = 6.0,
    seed: int = 0,
    mape_budget_pct: float = DEFAULT_MAPE_BUDGET_PCT,
    vec_tol: float = DEFAULT_VEC_TOL,
    golden_tol: float = DEFAULT_GOLDEN_TOL,
    euler_vec_tol: float = DEFAULT_EULER_VEC_TOL,
    bootstrap: int = 200,
    simulate: bool = True,
    sim_cross_count: int = 3,
    tail_pct: float = DEFAULT_TAIL_PCT,
    tail_budget_pct: float = DEFAULT_TAIL_BUDGET_PCT,
    meanfield: bool = True,
    meanfield_budget_pct: float = DEFAULT_MEANFIELD_BUDGET_PCT,
) -> ValidationReport:
    """Cross-check all four evaluation paths over ``entries``.

    ``expected_totals`` (scenario name -> strategy -> golden total) comes from
    the fixture via :func:`repro.validate.corpus.load_corpus`; omit it to skip
    the golden pin (e.g. on a freshly generated in-memory corpus).

    Beyond the mean paths, every entry's strategy is also scored at the
    ``tail_pct`` percentile: scalar ``analytic_tail`` vs ``fleet_tail``
    (agreement gated at ``vec_tol``) and, where simulated, analytic quantile
    vs the observed ``percentile(tail_pct)`` (gated at ``tail_budget_pct``
    over :func:`tail_gated` entries — exact-transform paths at rho <= 0.9).

    ``meanfield`` additionally runs :func:`run_meanfield_gate` — the
    class-aggregated Wardrop solver vs the exact per-client equilibrium on
    the fixed :func:`meanfield_gate_specs` fleets, gated at
    ``meanfield_budget_pct`` — entirely analytic, so it runs even with
    ``simulate=False``.
    """
    entries = list(entries)
    if not entries:
        raise ValueError("need at least one corpus entry")
    q = tail_pct / 100.0

    # -- paths 1+2: scalar and vectorized closed forms ------------------------
    scalar_totals = [e.scenario.analytic().totals() for e in entries]
    batch = ScenarioBatch.from_scenarios([e.scenario for e in entries])
    pred = fleet_analytic(batch)
    scalar_tails = [analytic_tail(e.scenario, q) for e in entries]
    pred_tail = fleet_tail(batch, q)

    vec_errs: list[float] = []
    tail_vec_errs: list[float] = []
    golden_errs: list[float | None] = []
    for i, (e, tot) in enumerate(zip(entries, scalar_totals)):
        vtot = pred.totals(i)
        vec_errs.append(max(_rel_err(v, vtot[k]) for k, v in tot.items()))
        vtail = pred_tail.totals(i)
        tail_vec_errs.append(max(_rel_err(v, vtail[k])
                                 for k, v in scalar_tails[i].items()))
        if expected_totals is not None and e.name in expected_totals:
            exp = expected_totals[e.name]
            golden_errs.append(max(_rel_err(v, float(exp[k]))
                                   for k, v in tot.items()))
        else:
            golden_errs.append(None)

    # -- tail-euler-vec: batched exact inversion vs scalar euler --------------
    # Explicit method="euler" on both sides (immune to default-method drift):
    # the batched kernel must reproduce the scalar Pollaczek-Khinchine
    # inversion to euler_vec_tol on every entry inside the rho gate.
    euler_idx = [i for i, e in enumerate(entries) if e.rho <= EULER_VEC_RHO_MAX]
    euler_vec_max = None
    if euler_idx:
        pred_euler = fleet_tail(batch, q, method="euler")
        euler_errs = []
        for i in euler_idx:
            sc = analytic_tail(entries[i].scenario, q, method="euler")
            vtail = pred_euler.totals(i)
            euler_errs.append(max(_rel_err(v, vtail[k]) for k, v in sc.items()))
        euler_vec_max = float(max(euler_errs))

    # -- paths 3+4: discrete-event simulation ---------------------------------
    sim_results: dict[int, tuple[str, int, float, BootstrapCI, float]] = {}
    if simulate:
        sim_results = _simulate_entries(
            entries, range(len(entries)), base_n=base_n, max_factor=max_n_factor,
            seed=seed, bootstrap=bootstrap, tail_pct=tail_pct,
        )

    reports: list[EntryReport] = []
    for i, e in enumerate(entries):
        pred_s = float(scalar_totals[i][e.strategy])
        pred_q = float(scalar_tails[i][e.strategy])
        backend = n_used = sim_mean = ci = err = None
        sim_q = tail_err = None
        if i in sim_results:
            backend, n_used, sim_mean, ci, sim_q = sim_results[i]
            err = mape(pred_s, sim_mean)
            tail_err = mape(pred_q, sim_q)
        reports.append(EntryReport(
            name=e.name,
            regime=e.regime,
            band=e.band,
            rho=e.rho,
            strategy=e.strategy,
            sim_gate=e.sim_gate,
            analytic_scalar_s=pred_s,
            analytic_vec_s=float(pred.totals(i)[e.strategy]),
            vec_rel_err=vec_errs[i],
            golden_rel_err=golden_errs[i],
            sim_backend=backend,
            sim_n=n_used or 0,
            sim_mean_s=sim_mean,
            sim_ci=ci,
            sim_mape_pct=err,
            tail_gate=tail_gated(e),
            analytic_tail_s=pred_q,
            sim_tail_s=sim_q,
            tail_mape_pct=tail_err,
        ))

    # -- simulator-vs-simulator cross-check (independent RNG streams) ---------
    sim_cross: dict[str, float] = {}
    if simulate and sim_cross_count > 0:
        crossed = []
        for i, e in enumerate(entries):
            if len(crossed) >= sim_cross_count:
                break
            if not e.sim_gate or e.strategy != "on_device":
                continue
            n = _sim_n_for(e.rho, base_n, max_n_factor)
            res = scalar_simulate(e.scenario, "on_device", n=n, seed=seed + 7919)
            fleet_mean = sim_results[i][2]
            crossed.append(mape(res.mean, fleet_mean))
        if crossed:
            sim_cross = {
                "n_entries": float(len(crossed)),
                "mean_mape_pct": float(np.mean(crossed)),
                "max_mape_pct": float(np.max(crossed)),
            }

    gated = [r.sim_mape_pct for r in reports if r.sim_gate and r.sim_mape_pct is not None]
    simulated = [(r.band, r.sim_mape_pct) for r in reports if r.sim_mape_pct is not None]
    by_regime = [(r.regime, r.sim_mape_pct) for r in reports if r.sim_mape_pct is not None]
    tail_gated_errs = [r.tail_mape_pct for r in reports
                       if r.tail_gate and r.tail_mape_pct is not None]

    mf_report = run_meanfield_gate(budget_pct=meanfield_budget_pct) \
        if meanfield else None

    golden_vals = [g for g in golden_errs if g is not None]
    return ValidationReport(
        entries=tuple(reports),
        vec_max_rel_err=float(max(vec_errs)),
        vec_tol=vec_tol,
        golden_max_rel_err=float(max(golden_vals)) if golden_vals else None,
        golden_tol=golden_tol,
        gate=error_stats(gated),
        mape_budget_pct=mape_budget_pct,
        bands=error_table(simulated, order=BAND_ORDER),
        regimes=error_table(by_regime),
        sim_cross=sim_cross,
        config={
            "n_entries": len(entries),
            "base_n": base_n,
            "max_n_factor": max_n_factor,
            "seed": seed,
            "bootstrap": bootstrap,
            "simulate": simulate,
            "tail_pct": tail_pct,
        },
        tail=error_stats(tail_gated_errs),
        tail_budget_pct=tail_budget_pct,
        tail_pct=tail_pct,
        tail_vec_max_rel_err=float(max(tail_vec_errs)),
        euler_vec_max_rel_err=euler_vec_max,
        euler_vec_tol=euler_vec_tol,
        euler_vec_n=len(euler_idx),
        meanfield=mf_report,
    )
