"""Differential model validation: golden corpus, error metrics, MAPE gate.

The standing quality ratchet for the repo's four evaluation paths (scalar
analytic, vectorized analytic, scalar simulation, batched simulation):

  * :mod:`corpus` — seeded golden scenario corpus spanning the paper's axes,
    pinned as a JSON fixture under ``tests/golden/``;
  * :mod:`metrics` — MAPE, per-regime error tables, block-bootstrap CIs;
  * :mod:`differential` — the cross-path runner and fidelity report behind
    ``python -m repro.launch.validate`` (writes ``VALIDATION.json``);
  * :mod:`measured` — the hardware-in-the-loop regime: analytic mean/p99 vs
    latencies *observed* on the real serving engine (paper §5), behind
    ``python -m repro.launch.measure validate``.
"""

from .corpus import (
    BAND_ORDER,
    CORPUS_VERSION,
    DEFAULT_SEED,
    CorpusEntry,
    RHO_BANDS,
    bottleneck_rho,
    corpus_to_dict,
    default_fixture_path,
    generate_corpus,
    load_corpus,
    rho_band,
    save_corpus,
)
from .differential import (
    DEFAULT_EULER_VEC_TOL,
    DEFAULT_GOLDEN_TOL,
    DEFAULT_MAPE_BUDGET_PCT,
    DEFAULT_MEANFIELD_BUDGET_PCT,
    DEFAULT_TAIL_BUDGET_PCT,
    DEFAULT_TAIL_PCT,
    DEFAULT_VEC_TOL,
    EULER_VEC_RHO_MAX,
    EntryReport,
    ValidationReport,
    meanfield_gate_specs,
    run_differential,
    run_meanfield_gate,
    smoke_subset,
    tail_gated,
)
from .measured import (
    DEFAULT_MEASURED_BUDGET_PCT,
    DEFAULT_MEASURED_TAIL_BUDGET_PCT,
    MEASURED_VEC_TOL,
    MeasuredGateReport,
    measured_scenario,
    run_measured_gate,
)
from .metrics import (
    BootstrapCI,
    ErrorStats,
    bootstrap_mean_ci,
    error_stats,
    error_table,
    mape,
)

__all__ = [k for k in dir() if not k.startswith("_")]
