"""GQA decode attention for TPU: split-KV flash-decode.

One new token attends to a long cache (32k-500k). The cache is swept in
``blk_k`` tiles (grid dim innermost, "arbitrary"); the G grouped query heads
of one kv head ride together as the tile's row dim, so the MXU sees
(G x hd) @ (hd x blk_k) — exactly the FlashDecoding split-KV shape
[arXiv:2311.01282], with the cross-device split handled by sequence-sharded
caches (DESIGN.md §6) and the within-device sweep by this kernel. The valid
length ``pos`` arrives via scalar prefetch (it is a traced runtime value).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["decode_attention_kernel", "decode_attention_pallas"]

NEG_INF = -2.0e38


def _compiler_params(grid_len: int):
    cls = getattr(pltpu, "CompilerParams", None) or getattr(pltpu, "TPUCompilerParams")
    sem = ("parallel",) * (grid_len - 1) + ("arbitrary",)
    return cls(dimension_semantics=sem)


def decode_attention_kernel(
    pos_ref,  # scalar prefetch: (1,) int32
    q_ref,  # (1, 1, G, hd)
    k_ref,  # (1, 1, blk_k, hd)
    v_ref,
    o_ref,  # (1, 1, G, hd)
    acc_ref,  # (G, hd) f32
    m_ref,  # (G,) f32
    l_ref,
    *,
    scale: float,
    softcap: float,
    blk_k: int,
    n_k_blocks: int,
):
    ik = pl.program_id(2)
    pos = pos_ref[0]

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    k_start = ik * blk_k
    live = k_start <= pos  # tile entirely past the valid region -> skip

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (G, hd)
        k = k_ref[0, 0].astype(jnp.float32)  # (blk_k, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (G, blk_k)
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(k_pos <= pos, s, NEG_INF)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=1)
        v = v_ref[0, 0].astype(jnp.float32)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_ref[...] = acc_ref[...] * alpha[:, None] + pv
        m_ref[...] = m_new

    @pl.when(ik == n_k_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention_pallas(
    q: jax.Array,  # (B, H, hd)
    k: jax.Array,  # (B, K, S, hd)
    v: jax.Array,
    pos: jax.Array,  # scalar int32
    *,
    softcap: float = 0.0,
    scale: float | None = None,
    blk_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, H, hd = q.shape
    K, S = k.shape[1], k.shape[2]
    G = H // K
    scale = hd**-0.5 if scale is None else scale
    blk_k = min(blk_k, S)
    assert S % blk_k == 0
    nk = S // blk_k
    qr = q.reshape(B, K, G, hd)

    kernel = functools.partial(
        decode_attention_kernel,
        scale=scale,
        softcap=softcap,
        blk_k=blk_k,
        n_k_blocks=nk,
    )
    grid = (B, K, nk)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, kh, ik, pos_ref: (b, kh, 0, 0)),
            pl.BlockSpec((1, 1, blk_k, hd), lambda b, kh, ik, pos_ref: (b, kh, ik, 0)),
            pl.BlockSpec((1, 1, blk_k, hd), lambda b, kh, ik, pos_ref: (b, kh, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, kh, ik, pos_ref: (b, kh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, hd), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, G, hd), q.dtype),
        compiler_params=_compiler_params(len(grid)),
        interpret=interpret,
    )(jnp.asarray(pos, jnp.int32).reshape(1), qr, k, v)
    return out.reshape(B, H, hd)
