"""jit'd wrapper for the decode-attention kernel (model layout adapters)."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .decode_attention import decode_attention_pallas
from .ref import decode_attention_reference

__all__ = ["decode_attention"]


@partial(jax.jit, static_argnames=("softcap", "impl", "blk_k"))
def decode_attention(
    q: jax.Array,  # (B, 1, H, hd) — model layout (single decode token)
    k_cache: jax.Array,  # (B, S, K, hd)
    v_cache: jax.Array,
    pos: jax.Array,
    *,
    softcap: float = 0.0,
    impl: str = "pallas",
    blk_k: int = 512,
) -> jax.Array:
    qt = q[:, 0]  # (B, H, hd)
    kt = jnp.swapaxes(k_cache, 1, 2)  # (B, K, S, hd)
    vt = jnp.swapaxes(v_cache, 1, 2)
    if impl == "xla":
        out = decode_attention_reference(qt, kt, vt, pos, softcap=softcap)
    else:
        out = decode_attention_pallas(
            qt, kt, vt, pos, softcap=softcap, blk_k=blk_k,
            interpret=(impl == "interpret"),
        )
    return out[:, None]  # (B, 1, H, hd)
