"""Pure-jnp oracle for GQA decode attention (one token vs a KV cache)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["decode_attention_reference"]

NEG_INF = -2.0e38


def decode_attention_reference(
    q: jax.Array,  # (B, H, hd) — the new token's queries
    k: jax.Array,  # (B, K, S, hd) — cache (may contain garbage past `pos`)
    v: jax.Array,
    pos: jax.Array | int,  # attend to cache positions <= pos
    *,
    softcap: float = 0.0,
    scale: float | None = None,
) -> jax.Array:
    B, H, hd = q.shape
    K, S = k.shape[1], k.shape[2]
    G = H // K
    scale = hd**-0.5 if scale is None else scale
    qr = q.reshape(B, K, G, hd)
    s = jnp.einsum("bkgh,bksh->bkgs", qr, k, preferred_element_type=jnp.float32) * scale
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    valid = jnp.arange(S) <= pos
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bksh->bkgh", p.astype(v.dtype), v)
    return out.reshape(B, H, hd)
