"""Selective scan (Mamba S6) for TPU: state-resident-in-VMEM recurrence.

The XLA lowering of the scan re-reads/re-writes the (B, D, N) state from HBM
every step (a while-loop over dynamic-update-slices). Here the state lives in
VMEM scratch for the whole sweep — the TPU translation of Mamba's
SRAM-resident CUDA kernel [arXiv:2312.00752] — and only the (blk_t x blk_d)
input/output tiles stream through HBM. Grid = (batch, d-block, t-block) with
time innermost ("arbitrary"): scratch h persists across t-blocks; each grid
cell runs a fori_loop over its blk_t steps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ssm_scan_kernel", "ssm_scan_pallas"]


def _compiler_params(grid_len: int):
    cls = getattr(pltpu, "CompilerParams", None) or getattr(pltpu, "TPUCompilerParams")
    sem = ("parallel",) * (grid_len - 1) + ("arbitrary",)
    return cls(dimension_semantics=sem)


def ssm_scan_kernel(
    dt_ref,  # (1, blk_t, blk_d)
    b_ref,  # (1, blk_t, N)
    c_ref,  # (1, blk_t, N)
    u_ref,  # (1, blk_t, blk_d)
    a_ref,  # (blk_d, N)
    y_ref,  # (1, blk_t, blk_d)
    h_ref,  # scratch (blk_d, N) f32
    *,
    blk_t: int,
):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[...].astype(jnp.float32)  # (blk_d, N)

    def step(t, h):
        dt_t = dt_ref[0, t].astype(jnp.float32)  # (blk_d,)
        u_t = u_ref[0, t].astype(jnp.float32)
        b_t = b_ref[0, t].astype(jnp.float32)  # (N,)
        c_t = c_ref[0, t].astype(jnp.float32)
        decay = jnp.exp(dt_t[:, None] * a)  # (blk_d, N)
        h = decay * h + (dt_t * u_t)[:, None] * b_t[None, :]
        y_t = jnp.sum(h * c_t[None, :], axis=1)  # (blk_d,)
        y_ref[pl.dslice(0, 1), pl.dslice(t, 1), :] = y_t[None, None].astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, blk_t, step, h_ref[...])
    h_ref[...] = h


def ssm_scan_pallas(
    dt: jax.Array,  # (B, T, D)
    Bc: jax.Array,  # (B, T, N)
    Cc: jax.Array,  # (B, T, N)
    u: jax.Array,  # (B, T, D)
    A: jax.Array,  # (D, N)
    *,
    blk_t: int = 256,
    blk_d: int = 512,
    interpret: bool = False,
):
    """Returns y (B, T, D) (final state is recovered by the wrapper when
    needed via a short reference tail — the kernel's contract is the output
    sequence, matching the training hot path)."""
    B, T, D = u.shape
    N = A.shape[1]
    blk_t = min(blk_t, T)
    blk_d = min(blk_d, D)
    assert T % blk_t == 0 and D % blk_d == 0
    nt, nd = T // blk_t, D // blk_d

    kernel = functools.partial(ssm_scan_kernel, blk_t=blk_t)
    grid = (B, nd, nt)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, blk_t, blk_d), lambda b, id_, it: (b, it, id_)),
            pl.BlockSpec((1, blk_t, N), lambda b, id_, it: (b, it, 0)),
            pl.BlockSpec((1, blk_t, N), lambda b, id_, it: (b, it, 0)),
            pl.BlockSpec((1, blk_t, blk_d), lambda b, id_, it: (b, it, id_)),
            pl.BlockSpec((blk_d, N), lambda b, id_, it: (id_, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_t, blk_d), lambda b, id_, it: (b, it, id_)),
        out_shape=jax.ShapeDtypeStruct((B, T, D), u.dtype),
        scratch_shapes=[pltpu.VMEM((blk_d, N), jnp.float32)],
        compiler_params=_compiler_params(len(grid)),
        interpret=interpret,
    )(dt, Bc, Cc, u, A)
