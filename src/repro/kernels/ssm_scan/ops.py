"""jit'd wrapper for the selective-scan kernel."""

from __future__ import annotations

from functools import partial

import jax

from .ref import ssm_scan_reference
from .ssm_scan import ssm_scan_pallas

__all__ = ["ssm_scan"]


@partial(jax.jit, static_argnames=("impl", "blk_t", "blk_d"))
def ssm_scan(dt, Bc, Cc, u, A, *, impl: str = "pallas", blk_t: int = 256, blk_d: int = 512):
    """y = selective_scan(dt, B, C, u; A). Shapes as in ref.py."""
    if impl == "xla":
        y, _ = ssm_scan_reference(dt, Bc, Cc, u, A)
        return y
    return ssm_scan_pallas(
        dt, Bc, Cc, u, A, blk_t=blk_t, blk_d=blk_d, interpret=(impl == "interpret")
    )
