"""Pure-jnp oracle for the selective-scan (Mamba S6) kernel.

h_t = exp(dt_t * A) * h_{t-1} + (dt_t * u_t) * B_t ;  y_t = <h_t, C_t>
per independent channel d with state width n. Matches models/ssm._ssm_core.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["ssm_scan_reference"]


def ssm_scan_reference(
    dt: jax.Array,  # (B, T, D)
    Bc: jax.Array,  # (B, T, N)
    Cc: jax.Array,  # (B, T, N)
    u: jax.Array,  # (B, T, D)
    A: jax.Array,  # (D, N), negative real
    h0: jax.Array | None = None,  # (B, D, N) fp32
):
    """Returns (y (B, T, D) in u.dtype, h_final (B, D, N) fp32)."""
    B, T, D = u.shape
    N = A.shape[1]
    if h0 is None:
        h0 = jnp.zeros((B, D, N), jnp.float32)

    def step(h, xs):
        dt_t, B_t, C_t, u_t = xs
        dtf = dt_t.astype(jnp.float32)
        decay = jnp.exp(dtf[..., None] * A[None].astype(jnp.float32))
        inp = (dtf * u_t.astype(jnp.float32))[..., None] * B_t.astype(jnp.float32)[:, None, :]
        h = decay * h + inp
        y = jnp.einsum("bdn,bn->bd", h, C_t.astype(jnp.float32))
        return h, y.astype(u_t.dtype)

    tm = lambda t: jnp.swapaxes(t, 0, 1)
    h_final, y = jax.lax.scan(step, h0, (tm(dt), tm(Bc), tm(Cc), tm(u)))
    return jnp.swapaxes(y, 0, 1), h_final
