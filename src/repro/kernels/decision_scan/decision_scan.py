"""Staggered-cohort offload decisions over epochs as a Pallas kernel.

The cluster simulator's per-epoch decision step is, per client, an argmin
over the stacked (on-device | edges) cost row with on-device winning ties,
a relative-improvement hysteresis check against the previously chosen
target's CURRENT cost, and a cohort gate (client i re-decides only when
``t % stagger == i % stagger``). Sequential in the epoch axis (the previous
choice is the carry), embarrassingly parallel in the client axis — the same
shape as the Lindley kernel next door, so the same state-resident pattern
applies: each grid cell keeps a (blk_n, 1) block of previous choices in
VMEM scratch for the whole epoch sweep and streams (e1, blk_n, blk_t) cost
tiles through.

Cost tables arrive time-major ``(T, N, E+1)`` (column 0 = on-device, the
cluster convention) and are transposed to target-major ``(E+1, N, T)`` so
the tiled axes are the client/epoch pair and the tiny target axis rides
along whole. Epochs are innermost ("arbitrary") so the choice carry
persists across t-blocks; the client axis is "parallel".
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["decision_scan_kernel", "decision_scan_pallas"]

ON_DEVICE = -1  # target index convention (repro.core.manager.ON_DEVICE)


def _compiler_params(grid_len: int):
    cls = getattr(pltpu, "CompilerParams", None) or getattr(pltpu, "TPUCompilerParams")
    sem = ("parallel",) * (grid_len - 1) + ("arbitrary",)
    return cls(dimension_semantics=sem)


def decision_scan_kernel(
    h_ref,  # (1, 1) SMEM — hysteresis fraction
    costs_ref,  # (e1, blk_n, blk_t) stacked per-target costs, target-major
    cohort_ref,  # (blk_n, 1) int32 — client's decision cohort
    c_ref,  # (blk_n, blk_t) int32 choices out
    prev_ref,  # scratch (blk_n, 1) int32 — previous choice per client row
    *,
    blk_t: int,
    stagger: int,
):
    it = pl.program_id(1)

    @pl.when(it == 0)
    def _init():
        prev_ref[...] = jnp.full_like(prev_ref, ON_DEVICE)

    e1, blk_n, _ = costs_ref.shape
    h = h_ref[0, 0]
    cohort = cohort_ref[...]  # (blk_n, 1)
    tgt_ids = jax.lax.broadcasted_iota(jnp.int32, (e1, blk_n, 1), 0)

    def step(t, prev):
        tg = it * blk_t + t  # global epoch index
        costs_t = costs_ref[:, :, pl.dslice(t, 1)]  # (e1, blk_n, 1)
        # first-argmin: ties go to the lowest target index, i.e. on-device
        choice = jnp.argmin(costs_t, axis=0).astype(jnp.int32) - 1  # (blk_n, 1)
        predicted = jnp.min(costs_t, axis=0)
        # one-hot gather of the previous target's CURRENT cost (the masked
        # where keeps +inf saturated columns from poisoning the sum)
        prev_t = jnp.sum(
            jnp.where(tgt_ids == prev[None, :, :] + 1, costs_t, 0.0), axis=0)
        keep = (
            (tg >= stagger)
            & (h > 0.0)
            & (choice != prev)
            & jnp.isfinite(prev_t)
            & (predicted > (1.0 - h) * prev_t)
        )
        decided = jnp.where(keep, prev, choice)
        new = jnp.where(cohort == tg % stagger, decided, prev).astype(jnp.int32)
        c_ref[:, pl.dslice(t, 1)] = new
        return new

    prev_ref[...] = jax.lax.fori_loop(0, blk_t, step, prev_ref[...])


def decision_scan_pallas(
    costs: jax.Array,  # (T, N, E+1) stacked costs, column 0 = on-device
    cohort: jax.Array,  # (N,) int32
    *,
    hysteresis: float = 0.0,
    stagger: int = 1,
    blk_n: int = 8,
    blk_t: int = 128,
    interpret: bool = False,
):
    """(T, N) int32 choice trajectory (ON_DEVICE or an edge index)."""
    t, n, e1 = costs.shape
    blk_n = min(blk_n, n)
    blk_t = min(blk_t, t)
    pad_n = (-n) % blk_n
    pad_t = (-t) % blk_t
    cm = jnp.transpose(costs, (2, 1, 0))  # (e1, N, T) target-major
    co = cohort.astype(jnp.int32)[:, None]  # (N, 1)
    if pad_n or pad_t:
        # padded epochs run after every real one and padded clients are
        # whole extra rows — both are sliced off below, values irrelevant
        cm = jnp.pad(cm, ((0, 0), (0, pad_n), (0, pad_t)))
        co = jnp.pad(co, ((0, pad_n), (0, 0)))
    _, np_, tp = cm.shape
    grid = (np_ // blk_n, tp // blk_t)
    h = jnp.asarray(hysteresis, cm.dtype).reshape(1, 1)
    out = pl.pallas_call(
        functools.partial(decision_scan_kernel, blk_t=blk_t, stagger=stagger),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((e1, blk_n, blk_t), lambda i, it: (0, i, it)),
            pl.BlockSpec((blk_n, 1), lambda i, it: (i, 0)),
        ],
        out_specs=pl.BlockSpec((blk_n, blk_t), lambda i, it: (i, it)),
        out_shape=jax.ShapeDtypeStruct((np_, tp), jnp.int32),
        scratch_shapes=[pltpu.VMEM((blk_n, 1), jnp.int32)],
        compiler_params=_compiler_params(len(grid)),
        interpret=interpret,
    )(h, cm, co)
    return out[:n, :t].T  # back to time-major (T, N)
