"""Pure-jnp oracle: the staggered decision recurrence as a lax.scan.

Semantically identical to the cluster simulator's per-epoch decide step
(``repro.fleet.cluster._decide_vec`` plus the cohort gate) applied to
precomputed cost tables — the coherence test pins the two decision for
decision, so the kernel can never drift from the production rule.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["decision_scan_reference"]

ON_DEVICE = -1


def decision_scan_reference(
    costs: jax.Array,  # (T, N, E+1) stacked costs, column 0 = on-device
    cohort: jax.Array,  # (N,) int32
    *,
    hysteresis: float = 0.0,
    stagger: int = 1,
) -> jax.Array:
    """(T, N) int32 choice trajectory under first-argmin + hysteresis +
    cohort staggering, from ``prev = ON_DEVICE``."""
    t_n = costs.shape[0]
    cohort = cohort.astype(jnp.int32)

    def step(prev, inp):
        c_t, idx = inp
        choice = jnp.argmin(c_t, axis=1).astype(jnp.int32) - 1
        predicted = jnp.min(c_t, axis=1)
        prev_t = jnp.take_along_axis(c_t, (prev + 1)[:, None], axis=1)[:, 0]
        keep = (
            (idx >= stagger)
            & (hysteresis > 0.0)
            & (choice != prev)
            & jnp.isfinite(prev_t)
            & (predicted > (1.0 - hysteresis) * prev_t)
        )
        decided = jnp.where(keep, prev, choice)
        new = jnp.where(cohort == idx % stagger, decided, prev).astype(jnp.int32)
        return new, new

    init = jnp.full(costs.shape[1], ON_DEVICE, dtype=jnp.int32)
    _, out = jax.lax.scan(step, init, (costs, jnp.arange(t_n)))
    return out
