"""jit'd wrapper for the staggered-decision scan kernel."""

from __future__ import annotations

from functools import partial

import jax

from .decision_scan import decision_scan_pallas
from .ref import decision_scan_reference

__all__ = ["decision_scan"]


@partial(jax.jit,
         static_argnames=("impl", "hysteresis", "stagger", "blk_n", "blk_t"))
def decision_scan(costs, cohort, *, hysteresis: float = 0.0, stagger: int = 1,
                  impl: str = "pallas", blk_n: int = 8, blk_t: int = 128):
    if impl == "xla":
        return decision_scan_reference(
            costs, cohort, hysteresis=hysteresis, stagger=stagger)
    return decision_scan_pallas(
        costs, cohort, hysteresis=hysteresis, stagger=stagger,
        blk_n=blk_n, blk_t=blk_t, interpret=(impl == "interpret"))
