"""jit'd wrapper for the flash-attention Pallas kernel.

``flash_attention(q, k, v, ...)`` accepts the model's (B, S, H, hd) layout,
transposes to the kernel's head-major layout, dispatches to the Pallas kernel
(TPU) or the reference (CPU / interpret validation), and transposes back.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_pallas
from .ref import flash_attention_reference

__all__ = ["flash_attention"]


@partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "impl", "blk_q", "blk_k"),
)
def flash_attention(
    q: jax.Array,  # (B, Sq, H, hd) — model layout
    k: jax.Array,  # (B, Skv, K, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    impl: str = "pallas",  # "pallas" | "interpret" | "xla"
    blk_q: int = 128,
    blk_k: int = 128,
) -> jax.Array:
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    if impl == "xla":
        out = flash_attention_reference(
            qt, kt, vt, causal=causal, window=window, softcap=softcap
        )
    else:
        out = flash_attention_pallas(
            qt, kt, vt,
            causal=causal, window=window, softcap=softcap,
            blk_q=blk_q, blk_k=blk_k,
            interpret=(impl == "interpret"),
        )
    return jnp.swapaxes(out, 1, 2)
