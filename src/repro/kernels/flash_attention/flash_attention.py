"""Flash attention for TPU: VMEM-tiled online-softmax (FlashAttention
[arXiv:2205.14135] reimagined for the TPU memory hierarchy per DESIGN.md §5).

Layout is head-major (B, H, S, hd) so each grid cell streams contiguous
(blk, hd) tiles HBM->VMEM. Grid = (batch, q-head, q-block, kv-block) with the
kv-block dim innermost and sequence-ordered ("arbitrary" semantics): the fp32
accumulator, running max m, and running sum l live in VMEM scratch across the
kv sweep, exactly the role SRAM plays in the CUDA original. GQA is folded
into the k/v index_map (q head h reads kv head h // G). Causal and
sliding-window masks are applied both block-wise (pl.when skips dead tiles'
compute) and element-wise; gemma2 soft-capping runs on the fp32 scores.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_kernel", "flash_attention_pallas"]

NEG_INF = -2.0e38


def _compiler_params(grid_len: int):
    cls = getattr(pltpu, "CompilerParams", None) or getattr(pltpu, "TPUCompilerParams")
    sem = ("parallel",) * (grid_len - 1) + ("arbitrary",)
    return cls(dimension_semantics=sem)


def flash_attention_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    acc_ref,
    m_ref,
    l_ref,
    *,
    scale: float,
    causal: bool,
    window: int,
    softcap: float,
    blk_q: int,
    blk_k: int,
    n_k_blocks: int,
    q_offset: int,
):
    """One (b, h, iq, ik) grid cell. Refs are (blk_q, hd) / (blk_k, hd)."""
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # absolute positions of this tile
    q_start = iq * blk_q + q_offset
    k_start = ik * blk_k
    # block-level liveness: causal kills tiles fully above the diagonal,
    # window kills tiles fully left of the band
    live = jnp.bool_(True)
    if causal:
        live &= k_start <= q_start + blk_q - 1
    if window > 0:
        live &= (k_start + blk_k - 1) > (q_start - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (blk_q, blk_k)
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 1)
        mask = jnp.ones((blk_q, blk_k), jnp.bool_)
        if causal:
            mask &= k_pos <= q_pos
        if window > 0:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l_prev * alpha + jnp.sum(p, axis=1)
        v = v_ref[0, 0].astype(jnp.float32)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_ref[...] = acc_ref[...] * alpha[:, None] + pv
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(ik == n_k_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,  # (B, H, Sq, hd)
    k: jax.Array,  # (B, K, Skv, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    scale: float | None = None,
    blk_q: int = 128,
    blk_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, H, Sq, hd = q.shape
    Bk, K, Skv, _ = k.shape
    G = H // K
    scale = hd**-0.5 if scale is None else scale
    blk_q = min(blk_q, Sq)
    blk_k = min(blk_k, Skv)
    assert Sq % blk_q == 0 and Skv % blk_k == 0, (Sq, blk_q, Skv, blk_k)
    nq, nk = Sq // blk_q, Skv // blk_k
    q_offset = Skv - Sq  # queries are the tail of the kv sequence

    kernel = functools.partial(
        flash_attention_kernel,
        scale=scale,
        causal=causal,
        window=window,
        softcap=softcap,
        blk_q=blk_q,
        blk_k=blk_k,
        n_k_blocks=nk,
        q_offset=q_offset,
    )
    grid = (B, H, nq, nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, blk_q, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, blk_k, hd), lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, blk_k, hd), lambda b, h, iq, ik: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, blk_q, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q, hd), jnp.float32),
            pltpu.VMEM((blk_q,), jnp.float32),
            pltpu.VMEM((blk_q,), jnp.float32),
        ],
        compiler_params=_compiler_params(len(grid)),
        interpret=interpret,
    )(q, k, v)
