"""Pure-jnp oracle for the flash-attention kernel.

Semantics: GQA scaled-dot-product attention over head-major layouts with
optional causal masking, sliding window, and gemma2-style score soft-capping.
Unchunked: materialises the full score matrix (the thing the kernel avoids).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["flash_attention_reference"]

NEG_INF = -2.0e38


def flash_attention_reference(
    q: jax.Array,  # (B, H, Sq, hd)
    k: jax.Array,  # (B, K, Skv, hd)
    v: jax.Array,  # (B, K, Skv, hd)
    *,
    causal: bool = True,
    window: int = 0,  # 0 = unbounded
    softcap: float = 0.0,
    scale: float | None = None,
) -> jax.Array:
    B, H, Sq, hd = q.shape
    K = k.shape[1]
    G = H // K
    scale = hd**-0.5 if scale is None else scale
    qr = q.reshape(B, K, G, Sq, hd)
    scores = jnp.einsum(
        "bkgqh,bksh->bkgqs", qr, k, preferred_element_type=jnp.float32
    ) * scale
    if softcap > 0:
        scores = softcap * jnp.tanh(scores / softcap)
    q_pos = jnp.arange(Sq) + (k.shape[2] - Sq)
    k_pos = jnp.arange(k.shape[2])
    mask = jnp.ones((Sq, k.shape[2]), bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bksh->bkgqh", p.astype(v.dtype), v)
    return out.reshape(B, H, Sq, hd)
