"""Pure-jnp oracle for fused RMSNorm ((1+scale) parameterisation, fp32 core)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["rmsnorm_reference"]


def rmsnorm_reference(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)
