"""jit'd wrapper for fused RMSNorm."""

from __future__ import annotations

from functools import partial

import jax

from .ref import rmsnorm_reference
from .rmsnorm import rmsnorm_pallas

__all__ = ["rmsnorm"]


@partial(jax.jit, static_argnames=("eps", "impl", "blk_rows"))
def rmsnorm(x, scale, *, eps: float = 1e-6, impl: str = "pallas", blk_rows: int = 256):
    if impl == "xla":
        return rmsnorm_reference(x, scale, eps)
    return rmsnorm_pallas(x, scale, eps, blk_rows=blk_rows, interpret=(impl == "interpret"))
