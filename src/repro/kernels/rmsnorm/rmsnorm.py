"""Fused RMSNorm for TPU: one HBM read, fp32 reduction in VMEM, one write.

Rows stream through in (blk_rows, d) tiles; the scale vector is resident.
Fusing the normalise+scale epilogue halves HBM traffic vs. the unfused pair —
the memory-bound term this attacks shows up in every decode-cell roofline.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["rmsnorm_kernel", "rmsnorm_pallas"]


def _compiler_params(grid_len: int):
    cls = getattr(pltpu, "CompilerParams", None) or getattr(pltpu, "TPUCompilerParams")
    return cls(dimension_semantics=("parallel",) * grid_len)


def rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)  # (blk, d)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * (1.0 + s_ref[...].astype(jnp.float32))[None, :]).astype(o_ref.dtype)


def rmsnorm_pallas(x: jax.Array, scale: jax.Array, eps: float = 1e-6, *, blk_rows: int = 256, interpret: bool = False):
    orig_shape = x.shape
    d = x.shape[-1]
    xf = x.reshape(-1, d)
    n = xf.shape[0]
    blk = min(blk_rows, n)
    pad = (-n) % blk
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    grid = (xf.shape[0] // blk,)
    out = pl.pallas_call(
        functools.partial(rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((blk, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xf.shape, x.dtype),
        compiler_params=_compiler_params(len(grid)),
        interpret=interpret,
    )(xf, scale)
    if pad:
        out = out[:n]
    return out.reshape(orig_shape)
