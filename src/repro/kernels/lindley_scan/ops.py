"""jit'd wrapper for the batched Lindley-recursion kernel."""

from __future__ import annotations

from functools import partial

import jax

from .lindley_scan import lindley_scan_pallas
from .ref import lindley_scan_reference

__all__ = ["lindley_scan"]


@partial(jax.jit, static_argnames=("impl", "blk_b", "blk_t"))
def lindley_scan(arrivals, services, *, impl: str = "pallas", blk_b: int = 8, blk_t: int = 512):
    if impl == "xla":
        return lindley_scan_reference(arrivals, services)
    return lindley_scan_pallas(
        arrivals, services, blk_b=blk_b, blk_t=blk_t, interpret=(impl == "interpret")
    )
