"""Pure-jnp oracle: the k=1 Lindley recursion as a lax.scan."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["lindley_scan_reference"]


def lindley_scan_reference(arrivals: jax.Array, services: jax.Array) -> jax.Array:
    """dep_i = max(arr_i, dep_{i-1}) + svc_i, batched over rows."""

    def step(clk, cols):
        a, s = cols
        dep = jnp.maximum(a, clk) + s
        return dep, dep

    init = jnp.full(arrivals.shape[:1], -jnp.inf, dtype=arrivals.dtype)
    _, deps = jax.lax.scan(step, init, (arrivals.T, services.T))
    return deps.T
