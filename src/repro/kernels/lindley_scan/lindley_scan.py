"""Batched Lindley recursion (k=1 FCFS departures) as a Pallas kernel.

The fleet simulator's hot loop is the per-station recurrence
``dep_i = max(arr_i, dep_{i-1}) + svc_i`` — sequential in the job axis,
embarrassingly parallel in the scenario axis. The XLA lowering of the
equivalent ``lax.scan`` re-reads the carry from HBM every step; here each
grid cell holds a (blk_b,) block of scenario clocks in registers/VMEM for the
whole job sweep and streams the (blk_b, T) arrival/service tiles through —
the same state-resident pattern as the ssm_scan kernel next door.

Time is innermost ("arbitrary") so the clock carry persists across t-blocks;
the batch axis is "parallel".
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["lindley_scan_kernel", "lindley_scan_pallas"]


def _compiler_params(grid_len: int):
    cls = getattr(pltpu, "CompilerParams", None) or getattr(pltpu, "TPUCompilerParams")
    sem = ("parallel",) * (grid_len - 1) + ("arbitrary",)
    return cls(dimension_semantics=sem)


def lindley_scan_kernel(
    a_ref,  # (blk_b, blk_t) arrivals
    s_ref,  # (blk_b, blk_t) services
    d_ref,  # (blk_b, blk_t) departures out
    clk_ref,  # scratch (blk_b, 1) f32 — last departure per scenario row
    *,
    blk_t: int,
):
    it = pl.program_id(1)

    @pl.when(it == 0)
    def _init():
        clk_ref[...] = jnp.full_like(clk_ref, -jnp.inf)

    def step(t, clk):
        a_t = a_ref[:, pl.dslice(t, 1)]  # (blk_b, 1)
        s_t = s_ref[:, pl.dslice(t, 1)]
        dep = jnp.maximum(a_t, clk) + s_t
        d_ref[:, pl.dslice(t, 1)] = dep.astype(d_ref.dtype)
        return dep

    clk = jax.lax.fori_loop(0, blk_t, step, clk_ref[...])
    clk_ref[...] = clk


def lindley_scan_pallas(
    arrivals: jax.Array,  # (B, T), non-decreasing along T per row
    services: jax.Array,  # (B, T)
    *,
    blk_b: int = 8,
    blk_t: int = 512,
    interpret: bool = False,
):
    """Departure times of B independent single-server FCFS stations."""
    b, t = arrivals.shape
    blk_b = min(blk_b, b)
    blk_t = min(blk_t, t)
    pad_b = (-b) % blk_b
    pad_t = (-t) % blk_t
    if pad_b or pad_t:
        # padded jobs arrive at +0 service after the real ones; their rows /
        # tail columns are sliced off below, so values are irrelevant
        arrivals = jnp.pad(arrivals, ((0, pad_b), (0, pad_t)))
        services = jnp.pad(services, ((0, pad_b), (0, pad_t)))
    bp, tp = arrivals.shape
    grid = (bp // blk_b, tp // blk_t)
    out = pl.pallas_call(
        functools.partial(lindley_scan_kernel, blk_t=blk_t),
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk_b, blk_t), lambda ib, it: (ib, it)),
            pl.BlockSpec((blk_b, blk_t), lambda ib, it: (ib, it)),
        ],
        out_specs=pl.BlockSpec((blk_b, blk_t), lambda ib, it: (ib, it)),
        out_shape=jax.ShapeDtypeStruct((bp, tp), arrivals.dtype),
        scratch_shapes=[pltpu.VMEM((blk_b, 1), arrivals.dtype)],
        compiler_params=_compiler_params(len(grid)),
        interpret=interpret,
    )(arrivals, services)
    return out[:b, :t]
