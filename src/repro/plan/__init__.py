"""SLO-constrained provisioning: invert the fleet model to size a deployment.

Everything else in the repo predicts latency *given* a deployment; this
package searches deployments — minimum edge count, accelerator tier, and
shared bandwidth meeting a p99 budget for N clients at the decision
equilibrium — by monotone bisection over the batched exact tail.
"""

from .provision import ProvisionPlan, ProvisionSpace, provision

__all__ = [k for k in dir() if not k.startswith("_")]
