"""SLO-constrained fleet provisioning: the paper's question run backwards.

The forward question (``repro.core`` / ``repro.fleet``) is *given* a fleet —
N clients, E edge servers of some accelerator tier, a shared uplink — what
latency does each client see at the decision equilibrium?  The provisioning
question inverts it: given N clients and a p99 budget, what is the **minimum**
deployment that meets it?  Three resources trade off:

  * ``n_edges``   — how many replicas of the edge template to stand up;
  * ``tier``      — which accelerator tier each replica runs (§2's ladder of
    accelerators: the whole point of the paper is that this axis moved);
  * ``bandwidth`` — how fat the shared client uplink is.

Feasibility of one candidate ``(E, tier, bandwidth)`` is *not* a closed form:
it is the fixed point of the decision -> load -> decision map
(:func:`repro.fleet.solve_equilibrium` with ``slo_quantile`` set, so clients
best-respond on exact p99s computed by the batched Euler inversion of the
Pollaczek–Khinchine transform), judged by :meth:`Equilibrium.meets_slo` —
converged, and the *worst* client's q-quantile within budget.

The search exploits monotonicity instead of brute force.  Along each axis,
adding resource can only help: an extra identical edge adds capacity clients
may ignore, a faster tier stochastically dominates a slower one per-request,
and more shared bandwidth shrinks every NIC stage.  (Equilibria of this
congestion game descend a potential, so the Braess-style pathologies of
selfish *routing* with heterogeneous links don't arise for identical
replicas; ``tests/test_plan.py`` cross-checks the solver against exhaustive
grid search anyway.)  Monotone axes mean each minimisation is a
``smallest_true`` bracketed bisection — O(log) equilibrium solves per axis,
the same helper PR 5 introduced for tenancy crossovers.

The minimisation is **lexicographic**: fewest edges first (at the best tier
and fattest pipe), then the slowest tier that still works at that edge
count (at the fattest pipe), then the thinnest pipe that still works.  The
result is component-wise irreducible — decrementing *any* single resource of
the returned plan violates the SLO:

  * ``E-1`` fails at the *best* tier/bandwidth, hence also at the chosen ones;
  * ``tier-1`` fails at the fattest pipe, hence also at the chosen one;
  * ``bandwidth-1`` fails at the chosen ``(E, tier)`` by construction.
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Mapping

import numpy as np

from repro.core.crossover import smallest_true
from repro.core.latency import NetworkPath, ServiceModel, Tier
from repro.core.scenario import ClusterSpec, Scenario, ScenarioError
from repro.core.tail import resolve_tail_method
from repro.fleet.cluster import Equilibrium, solve_equilibrium

__all__ = ["ProvisionPlan", "ProvisionSpace", "provision"]


def _tier_to_dict(t: Tier) -> dict:
    return {
        "name": t.name,
        "service_time_s": t.service_time_s,
        "parallelism_k": t.parallelism_k,
        "service_model": t.service_model.value,
        "service_var": t.service_var,
    }


def _tier_from_dict(d: Mapping, path: str) -> Tier:
    try:
        model = ServiceModel(d.get("service_model", "md1"))
    except ValueError:
        raise ScenarioError(f"{path}.service_model",
                            f"unknown service model {d.get('service_model')!r}") from None
    try:
        return Tier(
            name=d.get("name", "tier"),
            service_time_s=d["service_time_s"],
            parallelism_k=d.get("parallelism_k", 1.0),
            service_model=model,
            service_var=d.get("service_var", 0.0),
        )
    except (KeyError, TypeError):
        raise ScenarioError(f"{path}.service_time_s", "missing required field") from None


@dataclass(frozen=True)
class ProvisionSpace:
    """The candidate deployments the solver searches over.

    ``base`` is a single-edge template scenario: its workload/device describe
    one client, ``edges[0]`` is the edge replica template (background tenants
    and all) whose *tier* the ladder overrides, and its network path is
    replaced by each candidate bandwidth.  ``tiers`` must be ordered
    cheapest-first, i.e. slowest to fastest (strictly decreasing effective
    service time ``s/k``), and ``bandwidths_Bps`` ascending — both orderings
    are what makes per-axis feasibility monotone and the bisection valid.
    """

    base: Scenario
    tiers: tuple[Tier, ...]
    max_edges: int
    bandwidths_Bps: tuple[float, ...]
    name: str = "provision-space"

    def __post_init__(self):
        if not isinstance(self.tiers, tuple):
            object.__setattr__(self, "tiers", tuple(self.tiers))
        if not isinstance(self.bandwidths_Bps, tuple):
            object.__setattr__(self, "bandwidths_Bps",
                               tuple(float(b) for b in self.bandwidths_Bps))
        if not isinstance(self.base, Scenario):
            raise ScenarioError("base",
                                f"expected a Scenario, got {type(self.base).__name__}")
        if len(self.base.edges) != 1:
            raise ScenarioError(
                "base.edges",
                f"template must have exactly one edge (the replica template), "
                f"got {len(self.base.edges)}")
        if not self.tiers:
            raise ScenarioError("tiers", "need at least one accelerator tier")
        eff = [t.service_time_s / t.parallelism_k for t in self.tiers]
        for i in range(1, len(eff)):
            if not eff[i] < eff[i - 1]:
                raise ScenarioError(
                    f"tiers[{i}]",
                    f"tiers must be ordered slowest to fastest: effective "
                    f"service time s/k {eff[i]:.4g} !< {eff[i - 1]:.4g}")
        if self.max_edges < 1:
            raise ScenarioError("max_edges",
                                f"must be at least 1, got {self.max_edges}")
        if not self.bandwidths_Bps:
            raise ScenarioError("bandwidths_Bps", "need at least one bandwidth")
        for i, b in enumerate(self.bandwidths_Bps):
            if not b > 0:
                raise ScenarioError(f"bandwidths_Bps[{i}]",
                                    f"must be positive, got {b!r}")
            if i and not b > self.bandwidths_Bps[i - 1]:
                raise ScenarioError(
                    f"bandwidths_Bps[{i}]",
                    f"bandwidths must be strictly ascending: {b!r} !> "
                    f"{self.bandwidths_Bps[i - 1]!r}")

    def cluster_spec(self, n_edges: int, tier_index: int, bandwidth_index: int,
                     n_clients: int) -> ClusterSpec:
        """The candidate deployment as a solvable :class:`ClusterSpec`.

        Candidates routinely sit past a stability boundary — that is exactly
        what makes them infeasible — so the instantiated scenario carries
        ``allow_unstable=True`` and lets the closed forms report ``inf``.
        """
        template = self.base.edges[0]
        edge = replace(template, tier=self.tiers[tier_index])
        scn = replace(
            self.base,
            edges=(edge,) * n_edges,
            network=NetworkPath(self.bandwidths_Bps[bandwidth_index]),
            allow_unstable=True,
        )
        return ClusterSpec(base=scn, n_clients=n_clients,
                           name=f"{self.name}-{n_clients}x{n_edges}")

    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "base": self.base.to_dict(),
            "tiers": [_tier_to_dict(t) for t in self.tiers],
            "max_edges": self.max_edges,
            "bandwidths_Bps": list(self.bandwidths_Bps),
        }

    @classmethod
    def from_dict(cls, d: Mapping) -> "ProvisionSpace":
        try:
            base_d, tiers_d = d["base"], d["tiers"]
            max_edges, bws = d["max_edges"], d["bandwidths_Bps"]
        except (KeyError, TypeError):
            raise ScenarioError(
                "provision_space",
                "missing required field (need base, tiers, max_edges, "
                "bandwidths_Bps)") from None
        return cls(
            base=Scenario.from_dict(base_d),
            tiers=tuple(_tier_from_dict(td, f"tiers[{i}]")
                        for i, td in enumerate(tiers_d)),
            max_edges=int(max_edges),
            bandwidths_Bps=tuple(float(b) for b in bws),
            name=d.get("name", "provision-space"),
        )


@dataclass(frozen=True)
class ProvisionPlan:
    """The minimal deployment found, plus the equilibrium it was judged at.

    ``tier_index`` / ``bandwidth_index`` index into the space's ladders so
    the minimality claim ("decrement any of these and the SLO breaks") is
    checkable without re-deriving positions from values.  ``evaluations``
    counts distinct equilibrium solves the search spent — the number grid
    search would have multiplied, not added.
    """

    n_clients: int
    slo_s: float
    q: float
    tail_method: str
    n_edges: int
    tier_index: int
    tier: Tier
    bandwidth_index: int
    bandwidth_Bps: float
    max_latency_s: float  # worst-client q-quantile at the chosen equilibrium
    mean_latency_s: float
    counts: dict[str, int]  # clients per target, Equilibrium.counts() style
    rho_edges: tuple[float, ...]
    iterations: int
    evaluations: int

    @property
    def slack_s(self) -> float:
        """Budget left at the worst client; >= 0 for any returned plan."""
        return self.slo_s - self.max_latency_s

    def to_dict(self) -> dict:
        return {
            "n_clients": self.n_clients,
            "slo_s": self.slo_s,
            "q": self.q,
            "tail_method": self.tail_method,
            "n_edges": self.n_edges,
            "tier_index": self.tier_index,
            "tier": _tier_to_dict(self.tier),
            "bandwidth_index": self.bandwidth_index,
            "bandwidth_Bps": self.bandwidth_Bps,
            "max_latency_s": self.max_latency_s,
            "mean_latency_s": self.mean_latency_s,
            "counts": dict(self.counts),
            "rho_edges": list(self.rho_edges),
            "iterations": self.iterations,
            "evaluations": self.evaluations,
        }

    @classmethod
    def from_dict(cls, d: Mapping) -> "ProvisionPlan":
        try:
            return cls(
                n_clients=int(d["n_clients"]),
                slo_s=float(d["slo_s"]),
                q=float(d["q"]),
                tail_method=str(d["tail_method"]),
                n_edges=int(d["n_edges"]),
                tier_index=int(d["tier_index"]),
                tier=_tier_from_dict(d["tier"], "tier"),
                bandwidth_index=int(d["bandwidth_index"]),
                bandwidth_Bps=float(d["bandwidth_Bps"]),
                max_latency_s=float(d["max_latency_s"]),
                mean_latency_s=float(d["mean_latency_s"]),
                counts={str(k): int(v) for k, v in d["counts"].items()},
                rho_edges=tuple(float(r) for r in d["rho_edges"]),
                iterations=int(d["iterations"]),
                evaluations=int(d["evaluations"]),
            )
        except (KeyError, TypeError):
            raise ScenarioError("provision_plan", "missing required field") from None


def provision(
    space: ProvisionSpace,
    n_clients: int,
    slo_s: float,
    *,
    q: float = 0.99,
    tail_method: str = "euler",
    max_iter: int = 20,
) -> ProvisionPlan | None:
    """Smallest ``(n_edges, tier, bandwidth)`` in ``space`` whose equilibrium
    keeps every client's q-quantile within ``slo_s`` — or ``None`` when even
    the maximal deployment misses the budget.

    Lexicographic: minimises edge count first, then tier (slowest feasible),
    then bandwidth (thinnest feasible); see the module docstring for why the
    result is component-wise irreducible.  Each feasibility probe is one
    :func:`solve_equilibrium` with ``slo_quantile=q``; probes are memoised so
    the reported ``evaluations`` counts distinct candidate deployments.
    """
    if n_clients < 1:
        raise ScenarioError("n_clients", f"must be at least 1, got {n_clients}")
    if not slo_s > 0:
        raise ScenarioError("slo_s", f"must be positive, got {slo_s!r}")
    if not 0.0 < q < 1.0:
        raise ScenarioError("q", f"quantile must be in (0, 1), got {q!r}")
    tail_method = resolve_tail_method(q, tail_method)

    cache: dict[tuple[int, int, int], Equilibrium] = {}

    def equilibrium(n_edges: int, ti: int, bi: int) -> Equilibrium:
        key = (n_edges, ti, bi)
        if key not in cache:
            spec = space.cluster_spec(n_edges, ti, bi, n_clients)
            cache[key] = solve_equilibrium(spec, max_iter=max_iter,
                                           slo_quantile=q, tail_method=tail_method)
        return cache[key]

    def feasible(n_edges: int, ti: int, bi: int) -> bool:
        return equilibrium(n_edges, ti, bi).meets_slo(slo_s)

    best_t = len(space.tiers) - 1
    best_b = len(space.bandwidths_Bps) - 1

    n_edges = smallest_true(lambda k: feasible(k, best_t, best_b), space.max_edges)
    if n_edges is None:
        return None
    # Both remaining axes are guaranteed feasible at their top index, so
    # smallest_true cannot return None here.
    ti = smallest_true(lambda k: feasible(n_edges, k - 1, best_b),
                       len(space.tiers)) - 1
    bi = smallest_true(lambda k: feasible(n_edges, ti, k - 1),
                       len(space.bandwidths_Bps)) - 1

    eq = equilibrium(n_edges, ti, bi)
    return ProvisionPlan(
        n_clients=n_clients,
        slo_s=float(slo_s),
        q=float(q),
        tail_method=tail_method,
        n_edges=n_edges,
        tier_index=ti,
        tier=space.tiers[ti],
        bandwidth_index=bi,
        bandwidth_Bps=float(space.bandwidths_Bps[bi]),
        max_latency_s=eq.max_latency_s,
        mean_latency_s=eq.mean_latency_s,
        counts=eq.counts(),
        rho_edges=tuple(float(r) for r in np.asarray(eq.rho_edges)),
        iterations=eq.iterations,
        evaluations=len(cache),
    )
