"""Packed struct-of-arrays representation of a scenario fleet.

A :class:`ScenarioBatch` flattens B validated :class:`repro.core.Scenario`
specs into float64 numpy columns — one array per field, one row per scenario,
edges padded to the widest scenario — so the whole fleet can be handed to the
jitted closed forms in :mod:`repro.fleet.analytic_vec` (and the batched
simulator in :mod:`repro.fleet.sim_vec`) as a single device call.

Two constructors, two scales:

  * :meth:`ScenarioBatch.from_scenarios` packs an explicit list (the output of
    ``Scenario.sweep()`` / ``Scenario.grid()``) — every element was eagerly
    validated at construction, so packing is a plain transcription.
  * :meth:`ScenarioBatch.from_sweep` is the array-native fast path for
    cartesian grids: the base scenario is packed once and swept numeric
    columns are tiled with ``np.meshgrid`` — no per-point Python object is
    ever built, which is what makes million-scenario fleets cheap. Row ``i``
    corresponds exactly to ``base.grid(axes)[i]`` (C order, last axis
    fastest); each axis path is validated once against the base spec so bad
    paths still fail fast with a named-field :class:`ScenarioError`.

Background tenants are stored as the three rate-weighted sums the mixture
moments need (sum lam_i, sum lam_i*s_i, sum lam_i*(var_i + s_i^2)); the
scenario's own stream is folded in at evaluation time from the *current*
arrival-rate column, so sweeping ``workload.arrival_rate`` re-aggregates the
multi-tenant mixture exactly as ``aggregate_streams`` would.
"""

from __future__ import annotations

from dataclasses import dataclass, fields
from dataclasses import replace as _replace
from typing import Iterable, Mapping, Sequence

import numpy as np

from repro.core.latency import ServiceModel
from repro.core.scenario import Scenario, ScenarioError

__all__ = ["ScenarioBatch", "MODEL_CODES", "SWEEPABLE_PATHS"]

# ServiceModel -> integer dispatch code used inside jitted kernels
MODEL_CODES = {
    ServiceModel.DETERMINISTIC: 0,
    ServiceModel.EXPONENTIAL: 1,
    ServiceModel.GENERAL: 2,
}

# field-path -> (attribute, edge-column or None); the numeric leaves
# from_sweep() can tile without materialising Scenario objects
SWEEPABLE_PATHS = {
    "workload.arrival_rate": "lam",
    "workload.req_bytes": "req_bytes",
    "workload.res_bytes": "res_bytes",
    "network.bandwidth_Bps": "bandwidth_Bps",
    "device.service_time_s": "dev_s",
    "device.parallelism_k": "dev_k",
    "device.service_var": "dev_var",
    # per-edge leaves are matched as edges[j].<leaf> via _sweep_slot()
}

_EDGE_LEAVES = {
    "tier.service_time_s": "edge_s",
    "tier.parallelism_k": "edge_k",
    "tier.service_var": "edge_var",
    "bandwidth_Bps": "edge_bw",
}

# domain of each sweepable column, mirroring Scenario's eager validation:
# positivity is NOT a stability concern, so even allow_unstable sweeps must
# fail fast on these (exactly like base.grid(axes) would, row for row)
_POSITIVE_ATTRS = frozenset(
    {"lam", "req_bytes", "bandwidth_Bps", "dev_s", "dev_k",
     "edge_s", "edge_k", "edge_bw"})
_NONNEGATIVE_ATTRS = frozenset({"res_bytes", "dev_var", "edge_var"})


def _validate_axis_domain(path: str, attr: str, values: np.ndarray) -> None:
    """Reject axis values grid() would reject, without building Scenarios."""
    if not np.all(np.isfinite(values)):
        bad = values[~np.isfinite(values)][0]
        raise ScenarioError(path, f"axis values must be finite, got {bad!r}")
    if attr in _POSITIVE_ATTRS and np.any(values <= 0):
        bad = values[values <= 0][0]
        raise ScenarioError(path, f"must be positive, got {bad!r}")
    if attr in _NONNEGATIVE_ATTRS and np.any(values < 0):
        bad = values[values < 0][0]
        raise ScenarioError(path, f"must be non-negative, got {bad!r}")


def _sweep_slot(path: str, n_edges: int) -> tuple[str, int | None]:
    """(attribute, edge column) for a sweepable field path."""
    if path in SWEEPABLE_PATHS:
        return SWEEPABLE_PATHS[path], None
    if path.startswith("edges["):
        close = path.index("]")
        j = int(path[6:close])
        if not 0 <= j < n_edges:
            raise ScenarioError(path, f"edge index {j} out of range (n_edges {n_edges})")
        leaf = path[close + 2 :]  # skip "]."
        if leaf in _EDGE_LEAVES:
            return _EDGE_LEAVES[leaf], j
    known = sorted(SWEEPABLE_PATHS) + [f"edges[j].{leaf}" for leaf in sorted(_EDGE_LEAVES)]
    raise ScenarioError(path, f"not a sweepable numeric field (known: {known})")


@dataclass(frozen=True)
class ScenarioBatch:
    """B scenarios as parallel float64 columns (edges padded to width E)."""

    # workload / network (B,)
    lam: np.ndarray
    req_bytes: np.ndarray
    res_bytes: np.ndarray
    bandwidth_Bps: np.ndarray
    return_results: np.ndarray  # bool
    # device tier (B,)
    dev_s: np.ndarray
    dev_k: np.ndarray
    dev_var: np.ndarray
    dev_model: np.ndarray  # int8 MODEL_CODES
    # edges, padded to (B, E); edge_mask False rows/cols are inert padding
    edge_mask: np.ndarray  # bool
    edge_s: np.ndarray
    edge_k: np.ndarray
    edge_var: np.ndarray
    edge_model: np.ndarray  # int8
    edge_bw: np.ndarray  # nan = "use the shared network path"
    # background tenants, pre-aggregated (B, E): sum lam_i, sum lam_i*s_i,
    # sum lam_i*(var_i + s_i^2) — own stream is folded in at eval time
    bg_lam: np.ndarray
    bg_wsum: np.ndarray
    bg_ssum: np.ndarray

    def __post_init__(self):
        b = self.lam.shape[0]
        for f in fields(self):
            arr = getattr(self, f.name)
            if arr.shape[0] != b:
                raise ValueError(f"{f.name}: leading dim {arr.shape[0]} != batch {b}")
        if self.edge_mask.ndim != 2:
            raise ValueError("edge arrays must be (B, E)")

    @property
    def size(self) -> int:
        return int(self.lam.shape[0])

    def __len__(self) -> int:
        return self.size

    @property
    def max_edges(self) -> int:
        return int(self.edge_mask.shape[1])

    @property
    def n_edges(self) -> np.ndarray:
        """(B,) number of real (non-padding) edges per scenario."""
        return self.edge_mask.sum(axis=1)

    def arrays(self) -> dict[str, np.ndarray]:
        """The columns as a plain dict pytree (the jitted kernels' input)."""
        return {f.name: getattr(self, f.name) for f in fields(self)}

    # -- constructors ---------------------------------------------------------

    @classmethod
    def from_scenarios(cls, scenarios: Sequence[Scenario] | Iterable[Scenario]) -> "ScenarioBatch":
        """Pack an explicit (already-validated) scenario list."""
        scns = list(scenarios)
        if not scns:
            raise ValueError("need at least one scenario")
        b = len(scns)
        e_max = max((len(s.edges) for s in scns), default=0)

        def col(fn, dtype=np.float64):
            return np.asarray([fn(s) for s in scns], dtype=dtype)

        edge_mask = np.zeros((b, e_max), dtype=bool)
        edge_s = np.ones((b, e_max))
        edge_k = np.ones((b, e_max))
        edge_var = np.zeros((b, e_max))
        edge_model = np.zeros((b, e_max), dtype=np.int8)
        edge_bw = np.full((b, e_max), np.nan)
        bg_lam = np.zeros((b, e_max))
        bg_wsum = np.zeros((b, e_max))
        bg_ssum = np.zeros((b, e_max))
        for i, s in enumerate(scns):
            for j, e in enumerate(s.edges):
                edge_mask[i, j] = True
                edge_s[i, j] = e.tier.service_time_s
                edge_k[i, j] = e.tier.parallelism_k
                edge_var[i, j] = e.tier.service_var
                edge_model[i, j] = MODEL_CODES[e.tier.service_model]
                if e.bandwidth_Bps is not None:
                    edge_bw[i, j] = e.bandwidth_Bps
                for t in e.background:
                    bg_lam[i, j] += t.arrival_rate
                    bg_wsum[i, j] += t.arrival_rate * t.service_mean_s
                    bg_ssum[i, j] += t.arrival_rate * (t.service_var + t.service_mean_s**2)

        return cls(
            lam=col(lambda s: s.workload.arrival_rate),
            req_bytes=col(lambda s: s.workload.req_bytes),
            res_bytes=col(lambda s: s.workload.res_bytes),
            bandwidth_Bps=col(lambda s: float(np.asarray(s.network.bandwidth_Bps))),
            return_results=col(lambda s: s.return_results, dtype=bool),
            dev_s=col(lambda s: s.device.service_time_s),
            dev_k=col(lambda s: s.device.parallelism_k),
            dev_var=col(lambda s: s.device.service_var),
            dev_model=col(lambda s: MODEL_CODES[s.device.service_model], dtype=np.int8),
            edge_mask=edge_mask,
            edge_s=edge_s,
            edge_k=edge_k,
            edge_var=edge_var,
            edge_model=edge_model,
            edge_bw=edge_bw,
            bg_lam=bg_lam,
            bg_wsum=bg_wsum,
            bg_ssum=bg_ssum,
        )

    @classmethod
    def from_sweep(cls, base: Scenario, axes: Mapping[str, Iterable]) -> "ScenarioBatch":
        """Cartesian grid over numeric field paths, packed without building
        per-point Scenario objects. Row order matches ``base.grid(axes)``."""
        if not axes:
            return cls.from_scenarios([base])
        paths = list(axes)
        values = [np.asarray(list(axes[p]), dtype=np.float64) for p in paths]
        # sweeps deliberately cross stability boundaries, exactly as
        # grid()/sweep() permit — probe with allow_unstable so row-for-row
        # equivalence with base.grid(axes) holds regardless of value order
        probe = base if base.allow_unstable else _replace(base, allow_unstable=True)
        for p, v in zip(paths, values):
            if v.ndim != 1 or v.size == 0:
                raise ScenarioError(p, "grid axis must be a non-empty 1-D value list")
            # fail fast on bad paths/values exactly like the object API would
            probe.replaced(p, float(v[0]))
        slots = [_sweep_slot(p, len(base.edges)) for p in paths]
        for p, v, (attr, _j) in zip(paths, values, slots):
            # EVERY value, not just the probe: grid() validates each point
            _validate_axis_domain(p, attr, v)

        packed = cls.from_scenarios([base])
        b = int(np.prod([v.size for v in values]))
        cols = {
            name: np.repeat(arr, b, axis=0).copy() for name, arr in packed.arrays().items()
        }
        mesh = np.meshgrid(*values, indexing="ij")  # C order, last axis fastest
        for (attr, j), grid_vals in zip(slots, mesh):
            flat = grid_vals.reshape(-1)
            if j is None:
                cols[attr][:] = flat
            else:
                cols[attr][:, j] = flat
        return cls(**cols)
