"""jit+vmap sojourn-time quantiles over a :class:`ScenarioBatch`.

Vectorized transcription of exactly the scalar tail layer in
:mod:`repro.core.tail`: the Pollaczek-Khinchine sojourn transform per station
(wait factor on the paper's k*mu aggregation, full service on top), the
Fig. 1 tandem composition under the independence approximation, Abate-Whitt
Euler inversion for the numeric CDF, and the dominant-singularity exponential
asymptote as the cheap method the closed-loop cluster paths use inside
``lax.scan``. One jitted call batches the q-quantile of every scenario —
``fleet_tail(batch, 0.99)`` is to ``Scenario.analytic_tail`` exactly what
``fleet_analytic`` is to ``Scenario.analytic()``, and a validation check pins
the two to <= 1e-6 relative agreement over the full golden corpus.

All math runs in float64 (complex128 contours) inside a scoped
``jax.experimental.enable_x64()`` so the global f32 model/kernel stack is
untouched. Algorithmic constants (Euler A/N/M, bracket/bisection iteration
counts) are imported from the scalar module — the agreement gate depends on
both sides running the identical algorithm.

The exact euler inversion itself lives in :mod:`repro.fleet.euler_vec`
(q-derived growth schedule + safeguarded Newton on the free Abate-Whitt
density, static per-slot service-kind hints), which replays the scalar
search trajectory phase for phase — this module routes ``method="euler"``
there and keeps the asymptote path plus the ScenarioBatch-facing station
builders and the public ``fleet_tail`` entry point.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.experimental
import jax.numpy as jnp
import numpy as np

from repro.core.tail import (
    ETA_BISECT_ITERS,
    ETA_GROW_ITERS,
    GAMMA_DET_CV2,
    KIND_DET,
    KIND_EXP,
    KIND_GAMMA,
    euler_grow_iters,
    resolve_tail_method,
)

from .analytic_vec import _implied_var_vec
from .batch import ScenarioBatch
from .euler_vec import quantile_euler_vec

__all__ = ["FleetTailPrediction", "fleet_tail", "sojourn_quantile_vec"]

_INF = jnp.inf
_TINY = 1e-300


# ---------------------------------------------------------------------------
# station-field containers: a dict of arrays, station axis LAST
# (lam, wkind, wmean, wvar, fkind, fmean, fvar) — repro.core.tail.Station,
# columnar
# ---------------------------------------------------------------------------


def _stack_stations(*stations) -> dict[str, jnp.ndarray]:
    """Stack per-station field dicts along a new trailing station axis."""
    keys = ("lam", "wkind", "wmean", "wvar", "fkind", "fmean", "fvar")
    return {k: jnp.stack([jnp.asarray(s[k]) for s in stations], axis=-1)
            for k in keys}


# ---------------------------------------------------------------------------
# exponential-tail asymptote — the cheap method the cluster scan vectorises
# ---------------------------------------------------------------------------


def _mgf_vec(kind, mean, var, eta):
    """Real M_S(eta); garbage (huge finite) past the divergence point, masked
    by the caller. eta broadcasts against the station fields."""
    det = jnp.exp(jnp.minimum(eta * mean, 700.0))
    exp_ = 1.0 / jnp.maximum(1.0 - eta * mean, _TINY)
    gamma_real = var > GAMMA_DET_CV2 * mean * mean
    safe_mean = jnp.where(mean > 0, mean, 1.0)
    safe_var = jnp.where(gamma_real, var, 1.0)
    shape = safe_mean * safe_mean / safe_var
    scale = safe_var / safe_mean
    gam = jnp.exp(jnp.minimum(-shape * jnp.log(jnp.maximum(1.0 - eta * scale, _TINY)),
                              700.0))
    gam = jnp.where(gamma_real, gam, det)
    out = jnp.where(kind == KIND_DET, det, jnp.where(kind == KIND_EXP, exp_, gam))
    return jnp.where(mean > 0, out, jnp.ones_like(out))


def _mgf_prime_vec(kind, mean, var, eta):
    """M_S'(eta) = E[S e^{eta S}], same conventions as ``_mgf_vec``."""
    det = mean * jnp.exp(jnp.minimum(eta * mean, 700.0))
    exp_ = mean / jnp.maximum(1.0 - eta * mean, _TINY) ** 2
    gamma_real = var > GAMMA_DET_CV2 * mean * mean
    safe_mean = jnp.where(mean > 0, mean, 1.0)
    safe_var = jnp.where(gamma_real, var, 1.0)
    shape = safe_mean * safe_mean / safe_var
    scale = safe_var / safe_mean
    gam = mean * jnp.exp(jnp.minimum(
        -(shape + 1.0) * jnp.log(jnp.maximum(1.0 - eta * scale, _TINY)), 700.0))
    gam = jnp.where(gamma_real, gam, det)
    out = jnp.where(kind == KIND_DET, det, jnp.where(kind == KIND_EXP, exp_, gam))
    return jnp.where(mean > 0, out, jnp.zeros_like(out))


def _wait_pole_vec(st):
    """Per-station Cramer decay rate (inf where the station never queues) —
    the vector twin of ``tail._wait_pole``: exp closed form, otherwise
    geometric growth + fixed-iteration bisection with identical constants."""
    lam, wkind = st["lam"], st["wkind"]
    wmean, wvar = st["wmean"], st["wvar"]
    rho = lam * wmean
    safe_wmean = jnp.where(wmean > 0, wmean, 1.0)
    exp_root = (1.0 - rho) / safe_wmean

    def g(eta):
        return lam * (_mgf_vec(wkind, wmean, wvar, eta) - 1.0) - eta

    # divergence point of the wait-service MGF (det -> inf, capped at 700/m)
    gamma_real = wvar > GAMMA_DET_CV2 * wmean * wmean
    safe_var = jnp.where(gamma_real, wvar, 1.0)
    div = jnp.where(
        wkind == KIND_EXP, 1.0 / safe_wmean,
        jnp.where((wkind == KIND_GAMMA) & gamma_real, wmean / safe_var, _INF))
    cap = jnp.minimum(div * (1.0 - 1e-12), 700.0 / safe_wmean)
    hi0 = jnp.minimum(exp_root, cap)

    def grow(_, hi):
        return jnp.where(g(hi) <= 0.0, jnp.minimum(hi * 2.0, cap), hi)

    hi = jax.lax.fori_loop(0, ETA_GROW_ITERS, grow, hi0)

    def bisect(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        le = g(mid) <= 0.0
        return jnp.where(le, mid, lo), jnp.where(le, hi, mid)

    lo, hi = jax.lax.fori_loop(0, ETA_BISECT_ITERS, bisect,
                               (jnp.zeros_like(hi), hi))
    root = jnp.where(wkind == KIND_EXP, exp_root, 0.5 * (lo + hi))
    return jnp.where((lam > 0) & (rho > 0), root, _INF)


def _quantile_asymptote_vec(st, q):
    lam, wmean = st["lam"], st["wmean"]
    rho = lam * wmean
    eta_w = _wait_pole_vec(st)  # (..., S)
    safe_fmean = jnp.where(st["fmean"] > 0, st["fmean"], 1.0)
    eta_s = jnp.where((st["fkind"] == KIND_EXP) & (st["fmean"] > 0),
                      1.0 / safe_fmean, _INF)
    cands = jnp.concatenate([eta_w, eta_s], axis=-1)  # wait poles first
    idx = jnp.argmin(cands, axis=-1)
    eta = jnp.min(cands, axis=-1)
    no_pole = ~jnp.isfinite(eta)
    eta_b = jnp.where(no_pole, 1.0, eta)[..., None]

    # per-station factors at the global eta (garbage at the dominant pole's
    # own factor — excluded from the products below by construction)
    m_w = _mgf_vec(st["wkind"], wmean, st["wvar"], eta_b)
    m_f = _mgf_vec(st["fkind"], st["fmean"], st["fvar"], eta_b)
    g = lam * (m_w - 1.0) - eta_b
    w_fac = jnp.where(rho > 0, (1.0 - rho) * (-eta_b) / jnp.where(
        jnp.abs(g) > _TINY, g, -_TINY), 1.0)
    t_fac = jnp.abs(w_fac) * m_f
    log_t = jnp.log(jnp.maximum(t_fac, _TINY))
    prod_others = jnp.exp(jnp.sum(log_t, axis=-1, keepdims=True) - log_t)

    mgf_p = _mgf_prime_vec(st["wkind"], wmean, st["wvar"], eta_b)
    res_wait = (1.0 - rho) * eta_b / (lam * mgf_p - 1.0) * m_f * prod_others
    res_serv = (1.0 / safe_fmean) * jnp.abs(w_fac) * prod_others
    r_cands = jnp.concatenate([res_wait, res_serv], axis=-1)
    r = jnp.take_along_axis(r_cands, idx[..., None], axis=-1)[..., 0]

    t_q = jnp.log(jnp.maximum(r, _TINY) / (eta_b[..., 0] * (1.0 - q))) / eta_b[..., 0]
    t_q = jnp.where((r > 0) & jnp.isfinite(r), jnp.maximum(t_q, 0.0), _INF)
    return jnp.where(no_pole, jnp.sum(st["fmean"], axis=-1), t_q)


def sojourn_quantile_vec(st: dict, q, *, method: str = "euler",
                         slot_kinds: tuple | None = None,
                         grow_iters: int | None = None):
    """q-quantile of the composed sojourn for station-field arrays (station
    axis last). Traceable; used inside the jitted fleet/cluster paths.

    ``slot_kinds`` is an optional static tuple of per-slot service-kind hints
    for the euler path (``"exp"``/``"nic"`` = statically exponential,
    ``None`` = runtime dispatch) — see
    :func:`repro.fleet.euler_vec.quantile_euler_vec`. ``grow_iters`` is the
    euler path's static bracket-doubling count (``euler_grow_iters(q)``),
    required when q is a tracer. The asymptote path ignores both."""
    unstable = jnp.any(st["lam"] * st["wmean"] >= 1.0, axis=-1)
    if method == "asymptote":
        val = _quantile_asymptote_vec(st, q)
    elif method == "euler":
        val = quantile_euler_vec(st, q, slot_kinds, grow_iters)
    else:
        raise ValueError(f"unknown method {method!r} (known: euler, asymptote)")
    # exact closed form for a pure single M/M/1 station (both methods), as in
    # the scalar layer: t_q = -ln(1-q)/(mu - lam)
    if st["lam"].shape[-1] == 1:
        lam = st["lam"][..., 0]
        mean = st["fmean"][..., 0]
        is_mm1 = ((st["wkind"][..., 0] == KIND_EXP) & (st["fkind"][..., 0] == KIND_EXP)
                  & (st["wmean"][..., 0] == mean) & (mean > 0))
        safe_mean = jnp.where(mean > 0, mean, 1.0)
        exact = -jnp.log1p(-q) / (1.0 / safe_mean - lam)
        val = jnp.where(is_mm1, exact, val)
    return jnp.where(unstable, _INF, val)


# ---------------------------------------------------------------------------
# ScenarioBatch-column station builders (shared with repro.fleet.cluster)
# ---------------------------------------------------------------------------


def _device_stations(c) -> dict:
    """(B, 1) station fields for the on-device path — Eq. 2's single queue."""
    return _stack_stations({
        "lam": c["lam"],
        "wkind": c["dev_model"].astype(jnp.int8),
        "wmean": c["dev_s"] / c["dev_k"],
        "wvar": c["dev_var"],
        "fkind": c["dev_model"].astype(jnp.int8),
        "fmean": c["dev_s"],
        "fvar": c["dev_var"],
    })


def _edge_stations(c) -> dict:
    """(B, E, 3) station fields for the offload path: device NIC -> edge proc
    (own model, or the §3.4 gamma-matched mixture when background tenants are
    present) -> return NIC. Mirrors ``analytic_vec._edge_latency_vec`` so the
    tail and mean evaluations can never drift on inputs."""
    lam = c["lam"][:, None]
    has_bg = c["bg_lam"] > 0.0

    own_var = _implied_var_vec(c["edge_model"], c["edge_s"], c["edge_var"])
    lam_tot = lam + c["bg_lam"]
    mean_mix = (lam * c["edge_s"] + c["bg_wsum"]) / lam_tot
    second_mix = (lam * (own_var + c["edge_s"] ** 2) + c["bg_ssum"]) / lam_tot
    var_mix = jnp.maximum(0.0, second_mix - mean_mix**2)

    b = jnp.where(jnp.isnan(c["edge_bw"]), c["bandwidth_Bps"][:, None], c["edge_bw"])
    req = c["req_bytes"][:, None]
    res = c["res_bytes"][:, None]
    lam_edge = jnp.where(has_bg, lam_tot, lam * jnp.ones_like(lam_tot))
    ret = c["return_results"][:, None]
    res_mean = jnp.where(ret, res / b, 0.0)

    kexp = jnp.full_like(c["edge_model"], KIND_EXP)
    zero = jnp.zeros_like(c["edge_s"])
    proc_kind = jnp.where(has_bg, KIND_GAMMA, c["edge_model"]).astype(jnp.int8)
    nic_in = {"lam": lam * jnp.ones_like(c["edge_s"]), "wkind": kexp,
              "wmean": req / b, "wvar": zero, "fkind": kexp, "fmean": req / b,
              "fvar": zero}
    proc = {"lam": lam_edge, "wkind": proc_kind,
            "wmean": jnp.where(has_bg, mean_mix, c["edge_s"]) / c["edge_k"],
            "wvar": jnp.where(has_bg, var_mix, c["edge_var"]),
            "fkind": proc_kind,
            "fmean": jnp.where(has_bg, mean_mix, c["edge_s"]),
            "fvar": jnp.where(has_bg, var_mix, c["edge_var"])}
    nic_out = {"lam": lam_edge, "wkind": kexp, "wmean": res_mean, "wvar": zero,
               "fkind": kexp, "fmean": res_mean, "fvar": zero}
    return _stack_stations(nic_in, proc, nic_out)


def _device_tail_vec(c, q, method: str, grow_iters: int | None = None,
                     dev_hint: str | None = None):
    """(B,) on-device q-quantile — the tail twin of ``_device_latency_vec``."""
    return sojourn_quantile_vec(_device_stations(c), q, method=method,
                                slot_kinds=(dev_hint,), grow_iters=grow_iters)


def _edge_tail_vec(c, q, method: str, grow_iters: int | None = None,
                   proc_hint: str | None = None):
    """(B, E) offload q-quantile — the tail twin of ``_edge_latency_vec``.

    The NIC slots of the offload tandem are exponential with ``wmean ==
    fmean`` by construction (``nic_station``), so the euler kernel gets
    static ``"nic"`` hints for slots 0 and 2 — the processing slot gets the
    batch-derived ``proc_hint`` (uniform model column) or runtime dispatch."""
    val = sojourn_quantile_vec(_edge_stations(c), q, method=method,
                               slot_kinds=("nic", proc_hint, "nic"),
                               grow_iters=grow_iters)
    return jnp.where(c["edge_mask"], val, _INF)


@partial(jax.jit, static_argnames=("method", "grow_iters", "dev_hint",
                                   "proc_hint"))
def _fleet_tail_jit(c, q, *, method: str, grow_iters: int | None,
                    dev_hint: str | None, proc_hint: str | None):
    t_dev = _device_tail_vec(c, q, method, grow_iters, dev_hint)
    t_edge = _edge_tail_vec(c, q, method, grow_iters, proc_hint)
    stacked = jnp.concatenate([t_dev[:, None], t_edge], axis=1)
    best = jnp.argmin(stacked, axis=1) - 1
    return t_dev, t_edge, best


def _uniform_kind_hint(kinds: np.ndarray) -> str | None:
    """Static service-kind hint for a concrete model column: ``"det"`` /
    ``"exp"`` when every row dispatches to the same branch (the common case —
    sweeps vary load, not service model), else None (runtime dispatch). The
    hints select formulas, never change them, so this is a pure perf
    derivation — on a uniformly non-gamma batch the euler kernel traces no
    ``log`` at all."""
    k = np.asarray(kinds)
    if k.size and np.all(k == KIND_DET):
        return "det"
    if k.size and np.all(k == KIND_EXP):
        return "exp"
    return None


@dataclass(frozen=True)
class FleetTailPrediction:
    """Per-scenario closed-form q-quantile latencies of one fleet evaluation.

    Mirrors :class:`FleetPrediction` (same ``best_edge`` convention, same
    ``totals`` labelling), but every number is the q-th sojourn quantile
    instead of the mean — the batch form of ``Scenario.analytic_tail``.
    """

    q: float
    t_dev: np.ndarray  # (B,)
    t_edge: np.ndarray  # (B, E)
    best_edge: np.ndarray  # (B,) int

    @property
    def size(self) -> int:
        return int(self.t_dev.shape[0])

    def strategy_names(self) -> list[str]:
        return ["on_device" if j < 0 else f"edge[{j}]"
                for j in self.best_edge.tolist()]

    def totals(self, i: int) -> dict[str, float]:
        out = {"on_device": float(self.t_dev[i])}
        for j in range(self.t_edge.shape[1]):
            out[f"edge[{j}]"] = float(self.t_edge[i, j])
        return out


def fleet_tail(batch: ScenarioBatch, q: float, *, method: str = "euler") -> FleetTailPrediction:
    """q-quantile end-to-end latency of every scenario/strategy, one jitted
    call — matches ``Scenario.analytic_tail(q, method=...)`` per row to
    <= 1e-6 relative (gated by the validation harness)."""
    if not 0.0 < q < 1.0:
        raise ValueError(f"quantile must be in (0, 1), got {q}")
    if method not in ("euler", "asymptote"):
        raise ValueError(f"unknown method {method!r} (known: euler, asymptote)")
    method = resolve_tail_method(q, method)
    grow_iters = euler_grow_iters(q) if method == "euler" else None
    np_arrays = batch.arrays()
    dev_hint = _uniform_kind_hint(np_arrays["dev_model"])
    proc_hint = None
    if not np.any(np.asarray(np_arrays["bg_lam"]) > 0.0):
        proc_hint = _uniform_kind_hint(np_arrays["edge_model"])
    with jax.experimental.enable_x64():
        arrays = {k: jnp.asarray(v) for k, v in np_arrays.items()}
        t_dev, t_edge, best = _fleet_tail_jit(arrays, jnp.float64(q),
                                              method=method,
                                              grow_iters=grow_iters,
                                              dev_hint=dev_hint,
                                              proc_hint=proc_hint)
        return FleetTailPrediction(
            q=q,
            t_dev=np.asarray(t_dev),
            t_edge=np.asarray(t_edge),
            best_edge=np.asarray(best),
        )
