"""jit+vmap closed forms over a :class:`ScenarioBatch`.

Vectorized transcription of exactly the scalar path in
``repro.core.latency`` / ``repro.core.multitenant`` / ``repro.core.scenario``:
M/D/1, M/M/1 and M/G/1 (P-K) waits with the paper's k*mu aggregation, the
Eq. 1/2 end-to-end compositions, the §3.4 multi-tenant mixture (own stream
folded into the stored background sums at evaluation time), and batched
bisection for crossover points. One jitted call evaluates the whole fleet —
millions of scenarios per second on a laptop CPU, every row bit-comparable
(<= 1e-9 relative) to ``scenario.analytic()`` on the same spec.

All math runs in float64 inside a scoped ``jax.experimental.enable_x64()``
context so the closed forms keep numpy-double semantics without flipping the
process-global x64 switch out from under the float32 model/kernel stack.
Unstable operating points yield ``inf``, exactly as the kernel layer does.

An exact Erlang-C M/M/k wait (``mmk_wait_erlang_vec``) rides along as the
vectorized counterpart of ``repro.core.queueing.mmk_wait_erlang`` — the test
oracle the paper's k*mu aggregation is scored against, now batched.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.experimental
import jax.numpy as jnp
import numpy as np

from .batch import ScenarioBatch

__all__ = [
    "FleetPrediction",
    "FleetCrossover",
    "fleet_analytic",
    "fleet_crossover",
    "mm1_wait_vec",
    "md1_wait_vec",
    "mg1_wait_vec",
    "mmk_wait_erlang_vec",
]

_INF = jnp.inf


def _stable_where(lam, effective_mu, value):
    """inf wherever the queue is unstable — mirrors latency._stable_where."""
    ok = (lam < effective_mu) & (effective_mu > 0) & (lam >= 0)
    return jnp.where(ok, value, _INF)


def mm1_wait_vec(lam, mu):
    """Paper Eq. 7: E[w] = 1/(mu - lam) - 1/mu."""
    w = 1.0 / (mu - lam) - 1.0 / mu
    return _stable_where(lam, mu, w)


def md1_wait_vec(lam, mu, k=1.0):
    """Paper Eq. 6: M/D/k via aggregated-rate M/D/1."""
    kmu = mu * k
    w = 0.5 * (1.0 / (kmu - lam) - 1.0 / kmu)
    return _stable_where(lam, kmu, w)


def mg1_wait_vec(lam, mu, var_s, k=1.0):
    """Paper Eq. 11: P-K M/G/1 wait with aggregated service rate k*mu."""
    kmu = mu * k
    rho = lam / kmu
    w = (rho + lam * kmu * var_s) / (2.0 * (kmu - lam))
    return _stable_where(lam, kmu, w)


def mmk_wait_erlang_vec(lam, mu, k, *, max_k: int = 64):
    """Exact M/M/k wait (Erlang C), batched over integer server counts.

    The per-row sum over n < k is evaluated as a masked sum to ``max_k``
    terms, so heterogeneous k across the batch stays one fused kernel.
    Runs in its own scoped float64 context (safe to call from numpy code;
    from inside an already-x64 trace the context is a no-op).
    """
    lam_np = np.asarray(lam, dtype=np.float64)
    mu_np = np.asarray(mu, dtype=np.float64)
    k_np = np.asarray(k, dtype=np.float64)
    if np.max(k_np) > max_k:
        raise ValueError(
            f"k={np.max(k_np)} exceeds max_k={max_k}; raise max_k or the "
            "truncated Erlang-B sum would be silently wrong")
    out_shape = np.broadcast_shapes(lam_np.shape, mu_np.shape, k_np.shape)
    with jax.experimental.enable_x64():
        out = _mmk_wait_erlang_impl(
            jnp.atleast_1d(jnp.asarray(lam_np)),
            jnp.atleast_1d(jnp.asarray(mu_np)),
            jnp.atleast_1d(jnp.asarray(k_np)),
            max_k=max_k,
        )
        return out.reshape(out_shape)


def _mmk_wait_erlang_impl(lam, mu, k, *, max_k: int):
    lam, mu, k = jnp.broadcast_arrays(lam, mu, k)
    a = lam / mu  # offered load in Erlangs
    rho = a / k
    n = jnp.arange(max_k, dtype=lam.dtype)
    log_n = jnp.log(jnp.maximum(n, 1.0))
    log_fact = jnp.cumsum(log_n)  # log(n!) since log(0!) = log(1) = 0
    # sum_{n<k} a^n/n!, a^k/k! — in log space for numeric range
    log_a = jnp.log(a)
    log_terms = n * log_a[..., None] - log_fact[None, :]
    mask = n < k[..., None]
    summation = jnp.sum(jnp.where(mask, jnp.exp(log_terms), 0.0), axis=-1)
    log_fact_km1 = jnp.sum(jnp.where(mask, log_n[None, :], 0.0), axis=-1)  # log((k-1)!)
    last = jnp.exp(k * log_a - (log_fact_km1 + jnp.log(k))) / (1.0 - rho)
    p_wait = last / (summation + last)
    w = jnp.where(lam == 0.0, 0.0, p_wait / (k * mu - lam))
    return _stable_where(lam, k * mu, w)


def _proc_wait_vec(model, lam, s, var, k):
    """Processing-queue wait, dispatching on the MODEL_CODES integer —
    the vectorized twin of ``latency.proc_wait``."""
    mu = 1.0 / s
    w_det = md1_wait_vec(lam, mu, k)
    w_exp = mm1_wait_vec(lam, mu * k)
    w_gen = mg1_wait_vec(lam, mu, var, k)
    return jnp.where(model == 0, w_det, jnp.where(model == 1, w_exp, w_gen))


def _implied_var_vec(model, s, var):
    """Var[s] implied by the service model (scenario.implied_service_var)."""
    return jnp.where(model == 1, s * s, jnp.where(model == 2, var, 0.0))


def _edge_latency_vec(c):
    """(B, E) end-to-end offload latency per edge — Eq. 1, with the §3.4
    mixture re-parameterisation wherever an edge hosts background tenants."""
    lam = c["lam"][:, None]
    has_bg = c["bg_lam"] > 0.0

    # mixture moments of background + the scenario's own stream (exactly
    # aggregate_streams: weighted mean, law-of-total-variance second moment)
    own_var = _implied_var_vec(c["edge_model"], c["edge_s"], c["edge_var"])
    lam_tot = lam + c["bg_lam"]
    mean_mix = (lam * c["edge_s"] + c["bg_wsum"]) / lam_tot
    second_mix = (lam * (own_var + c["edge_s"] ** 2) + c["bg_ssum"]) / lam_tot
    var_mix = jnp.maximum(0.0, second_mix - mean_mix**2)

    # dedicated edge: dispatch on the tier's own model at the own rate;
    # multi-tenant edge: M/G/1 on the aggregate (Lemma 3.2), s_edge = mixture mean
    w_proc_own = _proc_wait_vec(c["edge_model"], lam, c["edge_s"], c["edge_var"], c["edge_k"])
    w_proc_mix = mg1_wait_vec(lam_tot, 1.0 / mean_mix, var_mix, c["edge_k"])
    w_proc = jnp.where(has_bg, w_proc_mix, w_proc_own)
    s_edge = jnp.where(has_bg, mean_mix, c["edge_s"])
    lam_edge = jnp.where(has_bg, lam_tot, lam)

    b = jnp.where(jnp.isnan(c["edge_bw"]), c["bandwidth_Bps"][:, None], c["edge_bw"])
    req = c["req_bytes"][:, None]
    res = c["res_bytes"][:, None]
    w_net_dev = mm1_wait_vec(lam, b / req)  # device NIC sees this stream only
    n_req = req / b
    ret = c["return_results"][:, None]
    w_net_edge = jnp.where(ret, mm1_wait_vec(lam_edge, b / res), 0.0)
    n_res = jnp.where(ret, res / b, 0.0)

    total = w_net_dev + n_req + w_proc + s_edge + w_net_edge + n_res
    return jnp.where(c["edge_mask"], total, _INF)


def _device_latency_vec(c):
    """(B,) on-device latency — Eq. 2."""
    w = _proc_wait_vec(c["dev_model"], c["lam"], c["dev_s"], c["dev_var"], c["dev_k"])
    return w + c["dev_s"]


@jax.jit
def _fleet_analytic_jit(c):
    t_dev = _device_latency_vec(c)
    t_edge = _edge_latency_vec(c)
    stacked = jnp.concatenate([t_dev[:, None], t_edge], axis=1)
    # first argmin => on-device wins ties, matching ScenarioPrediction.best_strategy
    best = jnp.argmin(stacked, axis=1) - 1
    return t_dev, t_edge, best


@dataclass(frozen=True)
class FleetPrediction:
    """Per-scenario closed-form latencies of one fleet evaluation.

    ``best_edge`` follows the manager's convention: -1 means on-device,
    j >= 0 means ``edge[j]`` (padded edges are inf and never win).
    """

    t_dev: np.ndarray  # (B,)
    t_edge: np.ndarray  # (B, E)
    best_edge: np.ndarray  # (B,) int

    @property
    def size(self) -> int:
        return int(self.t_dev.shape[0])

    @property
    def best_latency(self) -> np.ndarray:
        stacked = np.concatenate([self.t_dev[:, None], self.t_edge], axis=1)
        return stacked[np.arange(self.size), self.best_edge + 1]

    def strategy_names(self) -> list[str]:
        """Decision.target_name-style labels per scenario."""
        return [
            "on_device" if j < 0 else f"edge[{j}]" for j in self.best_edge.tolist()
        ]

    def totals(self, i: int) -> dict[str, float]:
        """Scenario i's totals keyed like ScenarioPrediction.totals()
        (padded edge slots report inf)."""
        out = {"on_device": float(self.t_dev[i])}
        for j in range(self.t_edge.shape[1]):
            out[f"edge[{j}]"] = float(self.t_edge[i, j])
        return out


def fleet_analytic(batch: ScenarioBatch) -> FleetPrediction:
    """Closed-form per-strategy latency of every scenario, one jitted call."""
    with jax.experimental.enable_x64():
        arrays = {k: jnp.asarray(v) for k, v in batch.arrays().items()}
        t_dev, t_edge, best = _fleet_analytic_jit(arrays)
        return FleetPrediction(
            t_dev=np.asarray(t_dev),
            t_edge=np.asarray(t_edge),
            best_edge=np.asarray(best),
        )


# ---------------------------------------------------------------------------
# batched crossover solving (bandwidth / arrival_rate axes)
# ---------------------------------------------------------------------------


def _diff_at(c, x, axis_code: int, edge: int):
    """T_edge[edge](x) - T_dev(x) with the axis value substituted per row."""
    if axis_code == 0:  # bandwidth
        c = dict(c, bandwidth_Bps=x)
        # a swept shared path overrides any per-edge bandwidth, matching the
        # scalar solvers which always sweep NetworkPath(b)
        c["edge_bw"] = jnp.full_like(c["edge_bw"], jnp.nan)
    else:  # arrival rate
        c = dict(c, lam=x)
    t_dev = _device_latency_vec(c)
    t_edge = _edge_latency_vec(c)
    return t_edge[:, edge] - t_dev


@partial(jax.jit, static_argnames=("axis_code", "edge", "samples", "iters", "linear"))
def _fleet_crossover_jit(
    c, lo, hi, *, axis_code: int, edge: int, samples: int, iters: int, linear: bool
):
    # per-row grid: geometric when the span exceeds two decades (mirrors
    # solve_crossover), linear otherwise — or forced linear for the arrival
    # axis, matching arrival_rate_crossovers' linspace scan
    t = jnp.linspace(0.0, 1.0, samples)
    geom = lo[:, None] * (hi / lo)[:, None] ** t[None, :]
    lin = lo[:, None] + (hi - lo)[:, None] * t[None, :]
    use_geom = (not linear) & (lo > 0) & (hi / lo > 100)
    xs = jnp.where(use_geom[:, None], geom, lin)

    vals = jax.vmap(
        lambda x: _diff_at(c, x, axis_code, edge), in_axes=1, out_axes=1
    )(xs)

    # scan for the first sign change between grid-ADJACENT finite samples.
    # A non-finite sample resets the pairing: pairing across an instability
    # pocket (a run of inf between opposite-sign finite regions) would send
    # the bisection into the non-finite region and report a bogus crossover
    # at a stability boundary — the same fix as solve_crossover's scan.
    b = lo.shape[0]

    def scan_step(carry, col):
        last_x, last_v, found, blo, bhi, bflo, wins = carry
        x_i, v_i = col
        fin = jnp.isfinite(v_i)
        pair = fin & jnp.isfinite(last_v)
        hit = pair & (((last_v > 0) != (v_i > 0)) | (last_v == 0.0))
        new = hit & ~found
        blo = jnp.where(new, last_x, blo)
        bhi = jnp.where(new, x_i, bhi)
        bflo = jnp.where(new, last_v, bflo)
        wins = jnp.where(new, v_i < 0, wins)
        found = found | hit
        last_x = x_i
        last_v = jnp.where(fin, v_i, jnp.nan)  # non-finite breaks adjacency
        return (last_x, last_v, found, blo, bhi, bflo, wins), None

    init = (
        jnp.zeros(b),
        jnp.full(b, jnp.nan),
        jnp.zeros(b, dtype=bool),
        jnp.zeros(b),
        jnp.zeros(b),
        jnp.zeros(b),
        jnp.zeros(b, dtype=bool),
    )
    (_, _, found, blo, bhi, bflo, wins), _ = jax.lax.scan(
        scan_step, init, (xs.T, vals.T)
    )

    exact = found & (bflo == 0.0)  # grid point landed on the root

    def bisect_step(_, carry):
        lo_b, hi_b, flo = carry
        mid = 0.5 * (lo_b + hi_b)
        fm = _diff_at(c, mid, axis_code, edge)
        same = (fm > 0) == (flo > 0)
        lo_b = jnp.where(same, mid, lo_b)
        flo = jnp.where(same, fm, flo)
        hi_b = jnp.where(same, hi_b, mid)
        return lo_b, hi_b, flo

    lo_b, hi_b, _ = jax.lax.fori_loop(0, iters, bisect_step, (blo, bhi, bflo))
    root = 0.5 * (lo_b + hi_b)
    value = jnp.where(found, jnp.where(exact, blo, root), jnp.nan)
    return value, wins, found


@dataclass(frozen=True)
class FleetCrossover:
    """Batched Crossover: nan value where no sign change exists in [lo, hi]."""

    value: np.ndarray  # (B,)
    offload_wins_above: np.ndarray  # (B,) bool, meaningful where found
    found: np.ndarray  # (B,) bool
    lo: np.ndarray
    hi: np.ndarray


def fleet_crossover(
    batch: ScenarioBatch,
    axis: str,
    *,
    edge: int = 0,
    lo=None,
    hi=None,
    samples: int | None = None,
    iters: int = 200,
) -> FleetCrossover:
    """Where does the preferred strategy flip, for every scenario at once?

    ``axis`` is ``"bandwidth"`` (default range 1e4..1e9 B/s, as
    ``bandwidth_crossover``) or ``"arrival_rate"`` (per-row upper bound just
    inside every queue's stability region, as ``arrival_rate_crossovers``;
    the first crossover is returned). Same grid-scan-then-bisect procedure as
    ``repro.core.crossover.solve_crossover``, batched.
    """
    if batch.max_edges == 0 or not 0 <= edge < batch.max_edges:
        raise ValueError(f"edge index {edge} out of range for batch with "
                         f"{batch.max_edges} edge slots")
    with jax.experimental.enable_x64():
        c = {k: jnp.asarray(v) for k, v in batch.arrays().items()}
        b = batch.size
        if axis == "bandwidth":
            axis_code = 0
            linear = False
            samples = 256 if samples is None else samples
            lo_arr = jnp.full(b, 1e4 if lo is None else lo, dtype=jnp.float64)
            hi_arr = jnp.full(b, 1e9 if hi is None else hi, dtype=jnp.float64)
        elif axis == "arrival_rate":
            axis_code = 1
            linear = True
            samples = 512 if samples is None else samples
            lo_arr = jnp.full(b, 0.01 if lo is None else lo, dtype=jnp.float64)
            if hi is None:
                # stay strictly inside every queue's stability region
                bw = jnp.where(
                    jnp.isnan(c["edge_bw"][:, edge]),
                    c["bandwidth_Bps"],
                    c["edge_bw"][:, edge],
                )
                caps_dev = c["dev_k"] / c["dev_s"]
                caps_req = bw / c["req_bytes"]
                has_bg = c["bg_lam"][:, edge] > 0
                caps_edge = c["edge_k"][:, edge] / c["edge_s"][:, edge]
                caps_res = bw / c["res_bytes"]
                cap_nobg = jnp.minimum(
                    jnp.minimum(caps_dev, caps_edge), jnp.minimum(caps_req, caps_res)
                )
                cap_bg = jnp.minimum(caps_dev, caps_req)
                hi_arr = 0.999 * jnp.where(has_bg, cap_bg, cap_nobg)
            else:
                hi_arr = jnp.full(b, hi, dtype=jnp.float64)
        else:
            raise ValueError(f"unknown axis {axis!r} (known: bandwidth, arrival_rate)")
        value, wins, found = _fleet_crossover_jit(
            c, lo_arr, hi_arr, axis_code=axis_code, edge=edge,
            samples=samples, iters=iters, linear=linear,
        )
        return FleetCrossover(
            value=np.asarray(value),
            offload_wins_above=np.asarray(wins),
            found=np.asarray(found),
            lo=np.asarray(lo_arr),
            hi=np.asarray(hi_arr),
        )
