"""Trace-driven replay of the adaptive manager against static policies (§5).

Reproduces the shape of the paper's evaluation "under variable network
conditions and dynamic multi-tenant edge settings": a :class:`Trace` drives
the true environment epoch by epoch; the adaptive policy sees it only through
the §4.2 telemetry estimators (EWMA bandwidth and edge-load reports, a
sliding-window arrival-rate estimate over sampled request timestamps — never
raw instantaneous values), decides via the *same*
``AdaptiveOffloadManager.step()`` hook the serving gateway uses, and every
policy's chosen strategy is then scored with the closed forms under the TRUE
conditions. Static-device and static-edge baselines bracket it, so

    replay(scn, trace).policies["adaptive"].mean_latency_s

directly answers the paper's §5 question: does model-driven adaptation beat
committing to either side?

Epochs whose chosen strategy is unstable under the true conditions score
``saturation_penalty_s`` instead of ``inf`` — one epoch of saturation accrues
a bounded backlog, and bounded penalties keep policy means comparable.
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Sequence

import numpy as np

from repro.core.manager import AdaptiveOffloadManager, Decision
from repro.core.multitenant import TenantStream
from repro.core.scenario import Scenario, ScenarioError
from repro.core.telemetry import EwmaEstimator, SlidingRateEstimator

from .policy import bg_template, clamp_saturation, parse_policy, true_latency
from .traces import Trace

__all__ = ["PolicyResult", "ReplayResult", "replay"]


@dataclass(frozen=True)
class PolicyResult:
    """One policy's scored trajectory through the trace."""

    name: str
    latencies_s: np.ndarray  # (T,) true-condition latency of the chosen target
    targets: tuple[int, ...]  # per-epoch edge index (ON_DEVICE for local)
    saturated_epochs: int  # epochs that hit the saturation penalty

    @property
    def mean_latency_s(self) -> float:
        return float(np.mean(self.latencies_s))

    @property
    def switches(self) -> int:
        return sum(1 for a, b in zip(self.targets, self.targets[1:]) if a != b)


@dataclass(frozen=True)
class ReplayResult:
    """Replay outcome: per-policy scores plus the estimator trajectories."""

    trace: Trace
    policies: dict[str, PolicyResult]
    est_bandwidth_Bps: np.ndarray  # (T,) EWMA view the manager acted on
    est_arrival_rate: np.ndarray  # (T,) sliding-window view
    est_edge_bg_rate: np.ndarray  # (T, E) EWMA edge-load reports
    decisions: tuple[Decision, ...]  # the adaptive manager's full history

    @property
    def adaptive_wins(self) -> bool:
        """Paper §5 criterion: adaptive mean <= every static policy's mean."""
        a = self.policies["adaptive"].mean_latency_s
        return all(
            a <= p.mean_latency_s for n, p in self.policies.items() if n != "adaptive"
        )


def replay(
    scn: Scenario,
    trace: Trace,
    *,
    policies: Sequence[str] = ("adaptive", "on_device", "edge[0]"),
    seed: int = 0,
    bw_alpha: float = 0.5,
    bg_alpha: float = 0.5,
    rate_window_epochs: int = 5,
    saturation_penalty_s: float = 30.0,
    manager: AdaptiveOffloadManager | None = None,
    slo_quantile: float | None = None,
    tail_method: str = "euler",
    auditor=None,
    tracer=None,
) -> ReplayResult:
    """Drive ``scn`` through ``trace``, scoring adaptive vs static policies.

    The adaptive policy's inputs go through the telemetry layer: bandwidth
    and per-edge load via :class:`EwmaEstimator`, arrival rate via a
    :class:`SlidingRateEstimator` fed seeded Poisson request timestamps —
    so the manager reacts with realistic estimator lag, exactly as the
    gateway would. ``manager`` defaults to ``scn.manager()`` (pass one with
    hysteresis etc. to study the beyond-paper extensions).

    ``slo_quantile`` switches the whole replay to the SLO view: the default
    manager decides on q-quantiles (``scn.manager(slo_quantile=...)``) and
    every policy is scored by the q-quantile of its chosen path under the
    true conditions, so ``adaptive_wins`` answers the §5 question for tail
    latency instead of the mean.
    """
    if trace.n_edges not in (0, len(scn.edges)):
        raise ScenarioError(
            "trace", f"trace has {trace.n_edges} edge columns but the scenario "
            f"has {len(scn.edges)} edges")
    static_targets = {
        name: parse_policy(name, len(scn.edges))
        for name in policies if name != "adaptive"
    }
    run_adaptive = "adaptive" in policies
    templates = [bg_template(scn, j) for j in range(len(scn.edges))]
    # a trace without edge columns means "no churn", not "no tenants": the
    # spec's declared background rates hold for every epoch
    spec_bg = np.array([t[0] for t in templates])

    rng = np.random.default_rng(seed)
    obs_kw = {"auditor": auditor, "tracer": tracer, "audit_source": "replay"}
    if manager is not None:
        mgr = manager
        if auditor is not None:
            mgr.auditor = auditor
        if tracer is not None:
            mgr.tracer = tracer
    elif slo_quantile is not None:
        mgr = scn.manager(slo_quantile=slo_quantile, tail_method=tail_method,
                          **obs_kw)
    else:
        mgr = scn.manager(**obs_kw)
    dt = trace.epoch_s
    bw_est = EwmaEstimator(alpha=bw_alpha)
    lam_est = SlidingRateEstimator(window_s=rate_window_epochs * dt)
    bg_ests = [EwmaEstimator(alpha=bg_alpha) for _ in scn.edges]

    t_n = trace.n_epochs
    est_bw = np.empty(t_n)
    est_lam = np.empty(t_n)
    est_bg = np.zeros((t_n, len(scn.edges)))
    chosen: dict[str, list[int]] = {n: [] for n in (*static_targets, *(
        ("adaptive",) if run_adaptive else ()))}
    decisions: list[Decision] = []

    for i in range(t_n):
        t = float(trace.times[i])
        bw_true = float(trace.bandwidth_Bps[i])
        lam_true = float(trace.arrival_rate[i])
        bg_true = trace.edge_bg_rate[i] if trace.n_edges else spec_bg

        # -- telemetry collection (§4.2): estimators, not raw values --------
        est_bw[i] = bw_est.update(bw_true)
        n_req = int(rng.poisson(lam_true * dt))
        for ts in np.sort(rng.uniform(t, t + dt, size=n_req)):
            lam_est.record(float(ts))
        measured = lam_est.rate(t + dt)
        lam_hat = measured if measured > 0 else scn.workload.arrival_rate
        est_lam[i] = lam_hat
        for j, est in enumerate(bg_ests):
            est_bg[i, j] = est.update(float(bg_true[j]))

        if run_adaptive:
            # estimated edge states: spec edges with the churned background
            # re-aggregated at the EWMA-estimated rate
            wl_hat = replace(scn.workload, arrival_rate=lam_hat)
            states = []
            for j, e in enumerate(scn.edges):
                rate, mean, var = templates[j]
                bg = ((TenantStream(est_bg[i, j], mean, var),)
                      if est_bg[i, j] > 0 else ())
                states.append(replace(e, background=bg).to_state(wl_hat))
            d = mgr.step(t, {
                "workload": scn.workload,
                "lam_dev": lam_hat,
                "bandwidth_Bps": est_bw[i],
                "edges": states,
            })
            decisions.append(d)
            chosen["adaptive"].append(d.edge_index)
        for name, tgt in static_targets.items():
            chosen[name].append(tgt)

    # -- score every policy under the TRUE conditions -------------------------
    results: dict[str, PolicyResult] = {}
    for name, targets in chosen.items():
        lats = np.empty(t_n)
        for i, tgt in enumerate(targets):
            bg_true = trace.edge_bg_rate[i] if trace.n_edges else spec_bg
            lats[i] = true_latency(scn, tgt, float(trace.bandwidth_Bps[i]),
                                   float(trace.arrival_rate[i]), bg_true, templates,
                                   slo_quantile=slo_quantile,
                                   tail_method=tail_method)
        lats, saturated = clamp_saturation(lats, saturation_penalty_s)
        results[name] = PolicyResult(
            name=name, latencies_s=lats, targets=tuple(targets),
            saturated_epochs=saturated,
        )
        if tracer is not None and name == "adaptive":
            # close each epoch's lifecycle: the decide span (emitted by the
            # manager) gets its true-condition outcome stamped as a respond
            for i, tgt in enumerate(targets):
                tracer.instant(
                    t=float(trace.times[i]), name="respond", cat="respond",
                    track="replay", epoch=i, latency_s=float(lats[i]),
                    target="on_device" if tgt < 0 else f"edge[{tgt}]")

    return ReplayResult(
        trace=trace,
        policies=results,
        est_bandwidth_Bps=est_bw,
        est_arrival_rate=est_lam,
        est_edge_bg_rate=est_bg,
        decisions=tuple(decisions),
    )
