"""Fleet-scale scenario evaluation: vectorized sweeps + trace-driven replay.

The scalar layer (`repro.core`) answers "what happens at THIS operating
point"; this package answers it for millions of operating points per second
and for operating points that *move*:

  * :class:`ScenarioBatch` — struct-of-arrays packing of Scenario specs;
  * :func:`fleet_analytic` / :func:`fleet_crossover` — jitted closed forms
    and batched-bisection crossover solving over a whole batch;
  * :func:`simulate_fleet` / :func:`lindley_station` — batched
    Lindley-recursion tandem-queue simulation as one `lax.scan` launch;
  * :func:`fleet_tail` — batched sojourn-time q-quantiles (the SLO view of
    the same closed forms, via :mod:`repro.core.tail`'s transform layer);
  * :mod:`traces` + :func:`replay` — §5-style dynamic conditions scored
    against adaptive vs static offloading policies via the same
    ``AdaptiveOffloadManager.step()`` hook the serving gateway uses;
  * :mod:`cluster` — the closed loop: N clients sharing E edges, endogenous
    edge load, fixed-point equilibria, and an event-driven cross-check.
"""

from .analytic_vec import (
    FleetCrossover,
    FleetPrediction,
    fleet_analytic,
    fleet_crossover,
    md1_wait_vec,
    mg1_wait_vec,
    mm1_wait_vec,
    mmk_wait_erlang_vec,
)
from .batch import MODEL_CODES, SWEEPABLE_PATHS, ScenarioBatch
from .cluster import (
    ClusterPolicyResult,
    ClusterResult,
    Equilibrium,
    cross_check_equilibrium,
    induced_scenario,
    predict_decisions,
    predict_terms,
    simulate_cluster,
    solve_equilibrium,
)
from .meanfield import (
    MeanFieldEquilibrium,
    MeanFieldResult,
    cross_check_meanfield,
    simulate_meanfield,
    solve_meanfield_equilibrium,
)
from .policy import (
    bg_template,
    clamp_saturation,
    parse_policy,
    static_fractions,
    true_latency,
)
from .replay import PolicyResult, ReplayResult, replay
from .sim_vec import FleetSimResult, lindley_station, simulate_fleet
from .tail_vec import FleetTailPrediction, fleet_tail
from .traces import (
    Trace,
    TraceBatch,
    drift_signal,
    epoch_times,
    make_trace,
    mmpp_signal,
    step_signal,
)

__all__ = [k for k in dir() if not k.startswith("_")]
