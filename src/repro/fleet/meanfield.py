"""Mean-field cluster layer: client *classes* instead of clients.

The exact closed loop (:mod:`repro.fleet.cluster`) carries per-client state,
so its cost is linear in N — fine for 64 clients, hopeless for the ROADMAP's
millions. This module evolves the *distribution* of decisions instead: the
fleet is partitioned into C homogeneous classes (:class:`.MeanFieldSpec`'s
(device tier, arrival-rate band, bandwidth band) buckets) and the state is a
(C, E+1) matrix of offload fractions ``f[c, j]`` — the fraction of class c
currently targeting on-device (column 0) or edge j-1. The endogenous edge
load is then a *sum of class rates times offload fractions*,

    L_j = sum_c n_c * f[c, j+1] * lam_c  (+ the exogenous trace background),

and every cost evaluation runs the SAME jitted Algorithm-1 closed forms the
exact cluster uses (``_predict_vec`` / ``_predict_tail_vec``), over one row
per (class, current-target) sub-cohort rather than one row per client. The
marginal decider's own stream is excluded from its current edge's background
(``L_j - lam_c``), mirroring the exact solver's self-exclusion, so the
mean-field fixed point and the exact equilibrium answer the same question
and :func:`cross_check_meanfield` can gate one against the other (<=5% MAPE
on per-class latencies and edge utilizations, same style as
``cross_check_equilibrium``).

Complexity per step is O(C * E^2) — *independent of N* — which is what lets
:func:`simulate_meanfield` push a million-client diurnal day through one
``lax.scan`` in seconds on a CPU host.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.experimental
import jax.numpy as jnp
import numpy as np

from repro.core.scenario import (
    MeanFieldSpec,
    ScenarioError,
    implied_service_var,
)
from repro.core.tail import resolve_tail_method

from .batch import MODEL_CODES
from .cluster import (
    _as_jnp,
    _bg_moments,
    _predict_tail_vec,
    _predict_vec,
    _spec_arrays,
    _tail_grow_iters,
    solve_equilibrium,
)
from .policy import bg_template, clamp_saturation
from .traces import Trace, TraceBatch

__all__ = [
    "MeanFieldEquilibrium",
    "MeanFieldResult",
    "solve_meanfield_equilibrium",
    "simulate_meanfield",
    "cross_check_meanfield",
]


# ---------------------------------------------------------------------------
# static spec arrays: one row per (class, current-target) sub-cohort
# ---------------------------------------------------------------------------


def _mf_arrays(spec: MeanFieldSpec) -> dict[str, np.ndarray]:
    """The ``_spec_arrays``-shaped column dict for the mean-field cost rows.

    Rows are laid out class-major over current targets: row ``c*(E+1) + m``
    is "a class-c client currently at target m" (m=0 on-device, m=j+1 edge
    j). Device columns are per-row (classes may override the device tier);
    edge columns stay (E,) and broadcast, exactly as in the exact cluster.
    """
    base = spec.base
    c_n, e_n = spec.n_classes, spec.n_edges
    devices = [spec.device_tier(c) for c in range(c_n)]
    templates = [bg_template(base, j) for j in range(e_n)]
    edge_s = np.array([e.tier.service_time_s for e in base.edges])

    def per_row(vals, dtype=np.float64):
        return np.repeat(np.asarray(vals, dtype=dtype), e_n + 1)

    return {
        "lam_spec": per_row(spec.arrival_rates()),  # (R,)
        "req_bytes": np.float64(base.workload.req_bytes),
        "res_bytes": np.float64(base.workload.res_bytes),
        "return_results": np.bool_(base.return_results),
        "dev_s": per_row([d.service_time_s for d in devices]),
        "dev_k": per_row([d.parallelism_k for d in devices]),
        "dev_var": per_row([d.service_var for d in devices]),
        "dev_model": per_row([MODEL_CODES[d.service_model] for d in devices],
                             dtype=np.int8),
        "edge_s": edge_s,
        "edge_k": np.array([e.tier.parallelism_k for e in base.edges]),
        "edge_var": np.array([e.tier.service_var for e in base.edges]),
        "edge_model": np.array(
            [MODEL_CODES[e.tier.service_model] for e in base.edges], dtype=np.int8),
        "edge_bw": np.array(
            [np.nan if e.bandwidth_Bps is None else e.bandwidth_Bps
             for e in base.edges]),
        "endo_mean": edge_s,
        "endo_var": np.array([implied_service_var(e.tier) for e in base.edges]),
        "exo_rate": np.array([t[0] for t in templates]),
        "exo_mean": np.array([t[1] for t in templates]),
        "exo_var": np.array([t[2] for t in templates]),
        # self-exclusion mask: row (c, m) excludes ONE own stream from edge
        # j's background iff it currently sits there (m == j+1) — the exact
        # solver's `endo_total - own`, in sub-cohort form
        "self_mask": np.equal.outer(
            np.tile(np.arange(e_n + 1), c_n), np.arange(1, e_n + 1)
        ).astype(np.float64),  # (R, E)
        "counts": spec.class_counts(),  # (C,)
    }


def _mf_loads(f, counts, lam_c):
    """(E,) endogenous edge load: sum of class rates x offload fractions."""
    return jnp.sum((counts * lam_c)[:, None] * f[:, 1:], axis=0)


def _mf_cost(cst, lam_c, bw_c, endo_loads, exo, slo_q, tail_method, grow_iters):
    """(C, E+1, E+1) cost table: ``cost[c, m, j]`` is the Algorithm-1 latency
    a class-c client currently at target m predicts for target j, with its
    own stream excluded from its current edge's background."""
    e1 = cst["self_mask"].shape[1] + 1
    lam_row = jnp.repeat(lam_c, e1)
    bw_row = jnp.repeat(bw_c, e1)
    endo = jnp.maximum(
        endo_loads[None, :] - cst["self_mask"] * lam_row[:, None], 0.0)
    bg_lam, bg_wsum, bg_ssum = _bg_moments(cst, endo, exo[None, :])
    if slo_q is None:
        t_dev, t_edge = _predict_vec(cst, lam_row, bw_row,
                                     bg_lam, bg_wsum, bg_ssum)
    else:
        t_dev, t_edge = _predict_tail_vec(
            cst, lam_row, bw_row, bg_lam, bg_wsum, bg_ssum,
            jnp.float64(slo_q), tail_method, grow_iters)
    stacked = jnp.concatenate([t_dev[:, None], t_edge], axis=1)
    return stacked.reshape(lam_c.shape[0], e1, e1)


def _mf_respond(cost, f):
    """Best response of every sub-cohort: all of class c's mass currently at
    m moves to ``argmin_j cost[c, m, j]`` (first argmin — on-device wins
    ties, then the lowest edge index, the exact solver's tie-break)."""
    e1 = cost.shape[1]
    br = jnp.argmin(cost, axis=2)  # (C, E+1) target in 0..E
    onehot = (br[:, :, None] == jnp.arange(e1)[None, None, :]).astype(f.dtype)
    return jnp.einsum("cm,cmj->cj", f, onehot)


@partial(jax.jit, static_argnames=("slo_q", "tail_method", "grow_iters"))
def _mf_step_jit(cst, f, lam_c, bw_c, exo, eta, *, slo_q=None,
                 tail_method="asymptote", grow_iters=None):
    """One damped best-response step; returns everything the solver and the
    diurnal scan both need: the updated fractions, the per-(c, m) staying
    cost, the per-class expected latency, and the edge loads ``f`` induced."""
    loads = _mf_loads(f, cst["counts"], lam_c)
    cost = _mf_cost(cst, lam_c, bw_c, loads, exo, slo_q, tail_method, grow_iters)
    e1 = cost.shape[1]
    stay = cost[:, jnp.arange(e1), jnp.arange(e1)]  # (C, E+1) cost of staying
    lat_class = jnp.sum(f * stay, axis=1)  # (C,) expected latency per class
    f_br = _mf_respond(cost, f)
    f_new = (1.0 - eta) * f + eta * f_br
    return f_new, f_br, cost, stay, lat_class, loads


# ---------------------------------------------------------------------------
# fixed point: solve_meanfield_equilibrium
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MeanFieldEquilibrium:
    """A fixed point of the fraction -> load -> best-response map.

    The mean-field twin of :class:`repro.fleet.cluster.Equilibrium`: instead
    of one choice per client it carries per-class offload fractions, and the
    per-class latency is the fraction-weighted staying cost at the fixed
    point. ``regret_pct`` is the equilibrium residual — the worst relative
    gap between any occupied sub-cohort's staying cost and its best
    response, 0 at an exact Wardrop equilibrium."""

    fractions: np.ndarray  # (C, E+1) column 0 = on-device
    iterations: int
    converged: bool
    regret_pct: float  # worst occupied-mass relative regret at exit
    latency_s: np.ndarray  # (C,) fraction-weighted per-class latency
    class_latency_s: np.ndarray  # (C, E+1) staying cost per (class, target)
    cost_s: np.ndarray  # (C, E+1, E+1) full move-cost table [class, at, to]
    edge_loads: np.ndarray  # (E,) endogenous offloaded rate per edge
    rho_edges: np.ndarray  # (E,) processing utilization incl. exogenous load
    arrival_rates: np.ndarray  # (C,) per-client class rates solved at
    bandwidth_Bps: np.ndarray  # (C,) per-class bandwidth solved at
    exo_rates: np.ndarray  # (E,) exogenous background rates used
    counts: np.ndarray  # (C,) clients per class

    @property
    def n_total(self) -> int:
        return int(self.counts.sum())

    @property
    def mean_latency_s(self) -> float:
        """Count-weighted fleet mean latency at the fixed point."""
        w = self.counts / self.counts.sum()
        return float(np.sum(w * self.latency_s))

    @property
    def offload_frac(self) -> float:
        w = self.counts / self.counts.sum()
        return float(np.sum(w * self.fractions[:, 1:].sum(axis=1)))

    def expected_counts(self) -> dict[str, float]:
        """Expected clients per target, keyed like ``Equilibrium.counts``."""
        per_target = (self.counts[:, None] * self.fractions).sum(axis=0)
        out = {"on_device": float(per_target[0])}
        for j in range(per_target.shape[0] - 1):
            out[f"edge[{j}]"] = float(per_target[j + 1])
        return out


def _rho_edges(cst, loads, exo) -> np.ndarray:
    """Processing utilization of the realized per-edge aggregate mixture —
    the same mixture fold ``solve_equilibrium`` reports."""
    loads = np.asarray(loads, dtype=np.float64)
    exo = np.asarray(exo, dtype=np.float64)
    lam_tot = loads + exo
    wsum = loads * cst["endo_mean"] + exo * cst["exo_mean"]
    return np.where(lam_tot > 0, wsum / cst["edge_k"], 0.0)


def solve_meanfield_equilibrium(
    spec: MeanFieldSpec,
    *,
    bandwidth_Bps: float | np.ndarray | None = None,
    exo_rates: np.ndarray | None = None,
    damping: float = 0.5,
    max_iter: int = 500,
    tol_pct: float = 1e-3,
    slo_quantile: float | None = None,
    tail_method: str = "asymptote",
) -> MeanFieldEquilibrium:
    """Iterate fractions -> loads -> best responses to a Wardrop fixed point.

    Every sub-cohort (class c currently at target m) best-responds against
    the loads the current fractions induce, with its own marginal stream
    excluded from its current edge; a fraction ``damping`` of each cohort
    actually moves per iteration. Pure best response can cycle (the same
    stampede the exact solver damps with sequential sweeps); damped mass
    movement converges to the mixed (Wardrop) equilibrium instead, where
    every occupied target of a class prices within ``tol_pct`` of that
    class's best option. When the residual stalls, the damping factor is
    halved — the mean-field analog of the exact solver's oscillation
    fallback.

    ``bandwidth_Bps`` overrides the *base* bandwidth (scalar, scaled by each
    class's ``bandwidth_scale``) or gives explicit per-class values ((C,)
    array, used verbatim). ``slo_quantile`` switches costs from means to
    q-quantiles, exactly like the exact solver.
    """
    if not 0.0 < damping <= 1.0:
        raise ValueError(f"damping must be in (0, 1], got {damping}")
    if slo_quantile is not None and not 0.0 < slo_quantile < 1.0:
        raise ValueError(f"slo_quantile must be in (0, 1), got {slo_quantile}")
    if slo_quantile is not None:
        tail_method = resolve_tail_method(slo_quantile, tail_method)
    grow_iters = _tail_grow_iters(slo_quantile, tail_method) \
        if slo_quantile is not None else None

    c_n, e_n = spec.n_classes, spec.n_edges
    cst = _mf_arrays(spec)
    lam_c = spec.arrival_rates()
    if bandwidth_Bps is None or np.ndim(bandwidth_Bps) == 0:
        bw_c = spec.bandwidth_Bps(
            None if bandwidth_Bps is None else float(bandwidth_Bps))
    else:
        bw_c = np.asarray(bandwidth_Bps, dtype=np.float64)
        if bw_c.shape != (c_n,):
            raise ScenarioError(
                "bandwidth_Bps", f"expected shape ({c_n},), got {bw_c.shape}")
    exo = np.asarray(exo_rates, dtype=np.float64) if exo_rates is not None \
        else cst["exo_rate"].copy()
    if exo.shape != (e_n,):
        raise ScenarioError("exo_rates", f"expected shape ({e_n},), got {exo.shape}")

    with jax.experimental.enable_x64():
        cst_j = _as_jnp(cst)
        lam_j, bw_j, exo_j = jnp.asarray(lam_c), jnp.asarray(bw_c), jnp.asarray(exo)
        f = jnp.zeros((c_n, e_n + 1), dtype=jnp.float64).at[:, 0].set(1.0)
        eta = float(damping)
        converged = False
        iterations = 0
        best_regret = np.inf
        stall = 0
        regret = np.inf

        def evaluate(f):
            f_new, _f_br, cost, stay, lat, loads = _mf_step_jit(
                cst_j, f, lam_j, bw_j, exo_j, jnp.float64(eta),
                slo_q=slo_quantile, tail_method=tail_method,
                grow_iters=grow_iters)
            # occupied-mass relative regret: how far above its best option
            # any current sub-cohort is pricing (0 at a Wardrop equilibrium;
            # non-finite best = everything saturated, nowhere better to go)
            best = jnp.min(cost, axis=2)
            gap = jnp.where((f > 1e-9) & jnp.isfinite(best),
                            (stay - best) / best, 0.0)
            return f_new, cost, stay, lat, loads, float(jnp.max(gap)) * 100.0

        while iterations < max_iter:
            iterations += 1
            f_new, cost, stay, lat, loads, regret = evaluate(f)
            if regret <= tol_pct:
                converged = True
                break
            if regret < best_regret * (1 - 1e-9):
                best_regret, stall = regret, 0
            else:
                stall += 1
                if stall >= 20:  # residual stalled: damp harder
                    eta, stall = max(eta / 2.0, 1e-3), 0
            f = f_new
        if not converged:
            # the loop exhausted after updating f: refresh the diagnostics so
            # the reported state is self-consistent with `fractions`
            _f_new, cost, stay, lat, loads, regret = evaluate(f)

        fractions = np.asarray(f)
        class_latency = np.asarray(stay)
        latency = np.asarray(lat)
        loads_np = np.asarray(loads)

    return MeanFieldEquilibrium(
        fractions=fractions,
        iterations=iterations,
        converged=converged,
        regret_pct=regret,
        latency_s=latency,
        class_latency_s=class_latency,
        cost_s=np.asarray(cost),
        edge_loads=loads_np,
        rho_edges=_rho_edges(cst, loads_np, exo),
        arrival_rates=lam_c,
        bandwidth_Bps=bw_c,
        exo_rates=exo,
        counts=cst["counts"],
    )


# ---------------------------------------------------------------------------
# the diurnal day: one lax.scan over epochs, O(C * E^2) per step
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("slo_q", "tail_method", "grow_iters"))
def _mf_scan(cst, lam_ct, bw_ct, exo_t, f0, eta, *, slo_q=None,
             tail_method="asymptote", grow_iters=None):
    """Evolve the fraction state through all T epochs.

    Per epoch, every class re-prices against the loads the *current*
    fractions induce (the mean-field analog of the exact loop's one-epoch
    information lag) and a fraction ``eta`` of each sub-cohort moves to its
    best response — the continuum limit of ``stagger``-cohort
    desynchronization: not everyone re-decides at once, so the herd
    stampedes the exact scan needs staggering for damp out naturally."""

    def step(f, inp):
        lam_c, bw_c, exo = inp
        f_new, _f_br, _cost, _stay, lat, loads = _mf_step_jit.__wrapped__(
            cst, f, lam_c, bw_c, exo, eta, slo_q=slo_q,
            tail_method=tail_method, grow_iters=grow_iters)
        return f_new, (f, loads, lat)

    _, outs = jax.lax.scan(step, f0, (lam_ct, bw_ct, exo_t))
    return outs


@dataclass(frozen=True)
class MeanFieldResult:
    """A mean-field closed-loop trajectory (the million-client replay)."""

    spec: MeanFieldSpec
    times: np.ndarray  # (T,)
    fractions: np.ndarray  # (T, C, E+1) decision-time fraction state
    edge_loads: np.ndarray  # (T, E) endogenous offloaded rate per edge
    rho_edges: np.ndarray  # (T, E) utilization incl. exogenous load
    latency_s: np.ndarray  # (T, C) per-class expected latency (clamped)
    saturated_epochs: int  # class-epochs clamped at the saturation penalty

    @property
    def n_epochs(self) -> int:
        return int(len(self.times))

    @property
    def client_epochs(self) -> int:
        """Clients-modeled x epochs — the throughput numerator (the whole
        point: this is N-independent work pricing an N-client fleet)."""
        return int(self.spec.n_total * self.n_epochs)

    @property
    def mean_latency_s(self) -> float:
        w = self.spec.class_counts() / self.spec.n_total
        return float(np.mean(self.latency_s @ w))

    @property
    def offload_frac(self) -> np.ndarray:
        """(T,) count-weighted offloaded fraction of the fleet per epoch."""
        w = self.spec.class_counts() / self.spec.n_total
        return (self.fractions[:, :, 1:].sum(axis=2) @ w)


def simulate_meanfield(
    spec: MeanFieldSpec,
    traces: TraceBatch | Trace,
    *,
    switch_fraction: float = 0.25,
    saturation_penalty_s: float = 30.0,
    slo_quantile: float | None = None,
    tail_method: str = "asymptote",
) -> MeanFieldResult:
    """Drive the class-fraction state through a per-*class* trace batch.

    ``traces`` columns are per class, not per client (``n_clients`` must
    equal ``spec.n_classes``): column c is the measured bandwidth / churned
    arrival rate every member of class c sees (build it with the class's
    ``bandwidth_scale`` folded in). ``switch_fraction`` is the share of each
    class that re-decides per epoch — the continuum analog of the exact
    scan's ``stagger`` cohorts. Per-class latencies are clamped at
    ``saturation_penalty_s`` exactly like the exact replay scoring."""
    if isinstance(traces, Trace):
        traces = TraceBatch.from_trace(traces, spec.n_classes)
    if traces.n_clients != spec.n_classes:
        raise ScenarioError(
            "traces", f"trace batch has {traces.n_clients} class columns but "
            f"the spec has {spec.n_classes} classes")
    if traces.n_edges not in (0, spec.n_edges):
        raise ScenarioError(
            "traces", f"trace batch has {traces.n_edges} edge columns but the "
            f"spec has {spec.n_edges} edges")
    if not 0.0 < switch_fraction <= 1.0:
        raise ValueError(
            f"switch_fraction must be in (0, 1], got {switch_fraction}")
    if slo_quantile is not None and not 0.0 < slo_quantile < 1.0:
        raise ValueError(f"slo_quantile must be in (0, 1), got {slo_quantile}")
    if slo_quantile is not None:
        tail_method = resolve_tail_method(slo_quantile, tail_method)
    grow_iters = _tail_grow_iters(slo_quantile, tail_method) \
        if slo_quantile is not None else None

    cst = _mf_arrays(spec)
    t_n, e_n = traces.n_epochs, spec.n_edges
    exo_true = traces.edge_bg_rate if traces.n_edges else \
        np.broadcast_to(cst["exo_rate"], (t_n, e_n)).copy()

    with jax.experimental.enable_x64():
        cst_j = _as_jnp(cst)
        f0 = jnp.zeros((spec.n_classes, e_n + 1), dtype=jnp.float64) \
            .at[:, 0].set(1.0)
        fractions, loads, lat = _mf_scan(
            cst_j, jnp.asarray(traces.arrival_rate),
            jnp.asarray(traces.bandwidth_Bps), jnp.asarray(exo_true), f0,
            jnp.float64(switch_fraction), slo_q=slo_quantile,
            tail_method=tail_method, grow_iters=grow_iters)
        fractions = np.asarray(fractions)
        loads = np.asarray(loads)
        lat, saturated = clamp_saturation(np.asarray(lat), saturation_penalty_s)

    return MeanFieldResult(
        spec=spec,
        times=np.asarray(traces.times),
        fractions=fractions,
        edge_loads=loads,
        rho_edges=_rho_edges(cst, loads, exo_true),
        latency_s=lat,
        saturated_epochs=saturated,
    )


# ---------------------------------------------------------------------------
# the gate: mean-field vs the exact small-N solver
# ---------------------------------------------------------------------------


def cross_check_meanfield(
    spec: MeanFieldSpec,
    *,
    bandwidth_Bps: float | None = None,
    exo_rates: np.ndarray | None = None,
    rho_gate: float = 0.9,
    rho_floor: float = 0.02,
    max_iter: int = 50,
    slo_quantile: float | None = None,
    tail_method: str = "asymptote",
) -> dict:
    """Validate the mean-field fixed point against the exact solver.

    Expands ``spec`` to its exact per-client :class:`ClusterSpec`
    (class-major layout, per-class bandwidth honoured as a per-client
    override), solves both equilibria under identical conditions, and
    compares (a) per-class latencies — the exact solver's class-mean vs the
    fraction-weighted mean-field latency — and (b) per-edge processing
    utilizations. Same reporting contract as ``cross_check_equilibrium``:
    rows above ``rho_gate`` are informational (near saturation, latencies
    blow up and integer-client granularity dominates), edge rows below
    ``rho_floor`` are informational too (relative error on a near-idle edge
    is noise), and ``gated_max_mape_pct`` is what the validation harness
    asserts <= 5%."""
    mf = solve_meanfield_equilibrium(
        spec, bandwidth_Bps=bandwidth_Bps, exo_rates=exo_rates,
        slo_quantile=slo_quantile, tail_method=tail_method)
    cluster = spec.to_cluster()
    bw_clients = np.repeat(spec.bandwidth_Bps(bandwidth_Bps),
                           [c.n_clients for c in spec.classes])
    eq = solve_equilibrium(
        cluster, bandwidth_Bps=bw_clients, exo_rates=exo_rates,
        max_iter=max_iter, slo_quantile=slo_quantile, tail_method=tail_method)

    idx = spec.class_index()
    rho_by_class_mf = np.array([
        max([mf.rho_edges[j] for j in range(spec.n_edges)
             if mf.fractions[c, j + 1] > 1e-6], default=0.0)
        for c in range(spec.n_classes)
    ])
    classes = []
    for c, cl in enumerate(spec.classes):
        exact_lat = float(np.mean(eq.latency_s[idx == c]))
        mf_lat = float(mf.latency_s[c])
        err_pct = abs(mf_lat - exact_lat) / exact_lat * 100.0
        classes.append({
            "class": cl.name,
            "n_clients": int(cl.n_clients),
            "arrival_rate": float(mf.arrival_rates[c]),
            "rho": float(rho_by_class_mf[c]),
            "meanfield_s": mf_lat,
            "exact_s": exact_lat,
            "mape_pct": err_pct,
            "gated": bool(rho_by_class_mf[c] <= rho_gate),
        })
    edges = []
    for j in range(spec.n_edges):
        exact_rho = float(eq.rho_edges[j])
        mf_rho = float(mf.rho_edges[j])
        err_pct = abs(mf_rho - exact_rho) / exact_rho * 100.0 \
            if exact_rho > 0 else (0.0 if mf_rho == 0 else np.inf)
        edges.append({
            "edge": j,
            "meanfield_rho": mf_rho,
            "exact_rho": exact_rho,
            "mape_pct": err_pct,
            "gated": bool(rho_floor <= exact_rho <= rho_gate),
        })

    gated = [r["mape_pct"] for r in classes + edges if r["gated"]]
    return {
        "classes": classes,
        "edges": edges,
        "meanfield_converged": bool(mf.converged),
        "exact_converged": bool(eq.converged),
        "gated_mean_mape_pct": float(np.mean(gated)) if gated else None,
        "gated_max_mape_pct": float(np.max(gated)) if gated else None,
        "rho_gate": rho_gate,
        "rho_floor": rho_floor,
        "config": {"max_iter": max_iter, "slo_quantile": slo_quantile},
    }
