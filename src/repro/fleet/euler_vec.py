"""Fast batched exact Pollaczek-Khinchine quantile inversion (the tentpole
behind ``fleet_tail(batch, q, method="euler")`` being *real*).

The first vectorized euler path transcribed the scalar algorithm literally:
64 geometric bracket-growth steps plus 100 bisections, each a full Abate-Whitt
contour evaluation, with every service-distribution branch (det / exp / gamma)
computed for every station before a ``where``-select. That is 164 contour
evaluations x 27 complex-LST products x 3 stations per scenario row — ~170x
slower than the exponential-tail asymptote, which is why every batch consumer
traded correctness for speed. This module gets the exact inversion within an
order of magnitude of the asymptote by attacking both factors:

  * **q-derived growth schedule** — Markov's inequality caps the q-quantile
    at ``mean/(1-q)``, so ``euler_grow_iters(q)`` ~ ``log2(1/(1-q)) + 1``
    doublings from ``2 * mean`` always bracket it. The scalar's 64 blind
    doublings become ~8 for p99 (the schedule is shared — see below).
  * **Safeguarded Newton with a free density** — Abate-Whitt inverts any
    transform on the same contour: the CDF uses ``T*(theta)/theta`` and the
    density uses ``T*(theta)`` bare, so one set of transform evaluations
    yields both F(t) and f(t). After ``EULER_BISECT_ITERS`` bisections have
    isolated the crossing, each Newton iteration takes the step when it lands
    inside the current bracket and the bisection midpoint otherwise. The
    scalar's 100 blind bisections become 12 + 10.
  * **One transcendental pair per service evaluation** — det and gamma LSTs
    are both ``exp(·)`` of a selected exponent (``-theta m`` vs
    ``-shape log(1 + theta scale)``), so selecting the *exponent* and
    exponentiating once replaces two complex ``exp`` + one complex ``log``
    with one of each. Slots whose service is *statically* exponential — the
    NIC stations of every offload tandem — skip the transcendentals entirely
    via the ``slot_kinds`` hints (a pure-rational LST), and the ``"nic"``
    hint additionally reuses the one LST for both the wait and the full
    service factor (NIC stations have ``wmean == fmean`` by construction).

Numerical contract — why the trajectory is shared, not just the CDF: the
Euler-inverted CDF of near-deterministic mixtures (M/D/1-heavy tandems)
carries oscillatory inversion noise of amplitude ~``e^-A`` *relative to the
jump structure*, with wavelength ~``t/(N+M+1)``; near a quantile level that
noise can produce several crossings, and two different-but-correct root
finders will land on different ones (observed: 30% apart on a corpus M/D/1
entry). The <= 1e-8 scalar-vs-vec agreement gate therefore requires both
sides to walk the IDENTICAL evaluation sequence. ``quantile_euler_vec``
replays ``core.tail._quantile_euler`` phase for phase — same start
``max(2 * mean, 1e-12)``, same doubling schedule, same bisection midpoints,
same Newton formula and safeguard — on a CDF that is arithmetically identical
term for term (``exp(where(c, a, b)) == where(c, exp(a), exp(b))``), so the
two sides agree to float-noise (~1e-14), and the differential harness gates
it at <= 1e-8 (``tail-euler-vec`` check).

A Pallas kernel variant was considered and skipped: the inner loop is
dominated by complex ``exp``/``log`` over a (rows, 27)-point contour, which
XLA already fuses into a handful of elementwise kernels; on CPU (interpret
mode) a hand-written kernel only adds overhead, and the transcendental mix
leaves no tiling structure for a TPU kernel to exploit beyond what the fused
elementwise path gets.

Import direction: this module must not import ``tail_vec`` (which routes its
euler method here) — the shared station-dict helpers it needs live locally.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.tail import (
    EULER_A,
    EULER_BISECT_ITERS,
    EULER_M,
    EULER_N,
    EULER_NEWTON_ITERS,
    GAMMA_DET_CV2,
    KIND_EXP,
    KIND_GAMMA,
    _EULER_WEIGHTS,
    euler_grow_iters,
)

__all__ = ["cdf_pdf_vec", "quantile_euler_vec"]

_INF = jnp.inf
_TINY = 1e-300


def _slot_service_lst(kind, mean, var, theta, hint):
    """Complex LST E[e^{-theta S}] for one slot's service distribution.

    ``hint`` is the slot's static service-kind hint: ``"exp"`` / ``"nic"``
    mean every row's ``kind`` is KIND_EXP by construction (NIC slots, or a
    batch whose model column is uniformly exponential), so the LST is the
    pure rational ``1/(1 + theta m)`` — no transcendentals traced at all.
    ``"det"`` means uniformly KIND_DET: one complex ``exp``, no log. ``None``
    keeps the runtime dispatch, restructured as exponent-select + a single
    ``exp``: det and degenerate-gamma use ``-theta m``, real gamma uses
    ``-shape log(1 + theta scale)`` (identical values to the scalar branches,
    one complex exp + one complex log instead of two and one). ``mean == 0``
    is the inert factor 1, as everywhere in the tail layer.
    """
    if hint in ("exp", "nic"):
        out = 1.0 / (1.0 + theta * mean)
        return jnp.where(mean > 0, out, jnp.ones_like(out))
    if hint == "det":
        out = jnp.exp(-theta * mean)
        return jnp.where(mean > 0, out, jnp.ones_like(out))
    exp_ = 1.0 / (1.0 + theta * mean)
    gamma_real = var > GAMMA_DET_CV2 * mean * mean  # tail.GAMMA_DET_CV2 cutoff
    safe_mean = jnp.where(mean > 0, mean, 1.0)
    safe_var = jnp.where(gamma_real, var, 1.0)
    shape = safe_mean * safe_mean / safe_var
    scale = safe_var / safe_mean
    use_gamma = (kind == KIND_GAMMA) & gamma_real
    expo = jnp.where(use_gamma, -shape * jnp.log(1.0 + theta * scale),
                     -theta * mean)
    out = jnp.where(kind == KIND_EXP, exp_, jnp.exp(expo))
    return jnp.where(mean > 0, out, jnp.ones_like(out))


def _total_lst_slots(st, theta, slot_kinds):
    """Product of per-slot sojourn transforms ``W* Sf*`` at ``theta``
    (trailing contour axis K). The slot loop is unrolled in Python — S is 1
    (device) or 3 (offload tandem) — so each slot's static hint can prune its
    traced branches independently. Hint ``"nic"`` additionally asserts
    ``wmean == fmean`` (true for every ``nic_station``), letting the wait
    factor reuse the full-service LST instead of re-evaluating it.
    """
    n_slots = st["lam"].shape[-1]
    if slot_kinds is None:
        slot_kinds = (None,) * n_slots
    out = None
    for s in range(n_slots):
        hint = slot_kinds[s]
        lam = st["lam"][..., s, None]
        wmean = st["wmean"][..., s, None]
        rho = lam * wmean
        f = _slot_service_lst(st["fkind"][..., s, None], st["fmean"][..., s, None],
                              st["fvar"][..., s, None], theta, hint)
        if hint == "nic":
            sw = f
        else:
            sw = _slot_service_lst(st["wkind"][..., s, None], wmean,
                                   st["wvar"][..., s, None], theta, hint)
        w = (1.0 - rho) * theta / (theta - lam * (1.0 - sw))
        w = jnp.where(rho > 0, w, jnp.ones_like(w))
        fac = w * f
        out = fac if out is None else out * fac
    return out


def _implied_var_st(kind, mean, var):
    return jnp.where(kind == KIND_EXP, mean * mean,
                     jnp.where(kind == KIND_GAMMA, var, 0.0))


def _sojourn_mean_vec(st):
    """Per-path mean: sum of P-K waits + full service means (inf past rho=1)."""
    rho = st["lam"] * st["wmean"]
    v = _implied_var_st(st["wkind"], st["wmean"], st["wvar"])
    w = st["lam"] * (st["wmean"] ** 2 + v) / (2.0 * jnp.maximum(1.0 - rho, _TINY))
    w = jnp.where(rho > 0, jnp.where(rho < 1.0, w, _INF), 0.0)
    return jnp.sum(w + st["fmean"], axis=-1)


def cdf_pdf_vec(st, t, slot_kinds=None):
    """(CDF, PDF) of the composed sojourn at ``t``, one contour evaluation.

    Abate-Whitt inversion applies to any transform on the same contour
    ``theta_k = (A + 2 pi i k) / (2t)``: the CDF's transform is
    ``T*(theta)/theta``, the density's is ``T*(theta)`` itself. Sharing the
    ``T*`` evaluations is what makes Newton's derivative free. Arithmetic is
    term-for-term identical to the scalar ``core.tail._cdf_pdf`` on the same
    station fields; the PDF is clipped at 0 (inversion noise can dip slightly
    negative in flat regions — the safeguard treats a zero derivative as
    "fall back to bisection").
    """
    ks = jnp.arange(EULER_N + EULER_M + 1, dtype=jnp.float64)
    theta = (EULER_A + 2j * jnp.pi * ks) / (2.0 * t[..., None])
    vals = _total_lst_slots(st, theta, slot_kinds)
    sign = jnp.where(ks == 0, 0.5, 1.0) * ((-1.0) ** ks)
    weights = jnp.asarray(_EULER_WEIGHTS)
    scale = jnp.exp(EULER_A / 2.0) / t
    cdf_part = jnp.cumsum(sign * (vals / theta).real, axis=-1)
    pdf_part = jnp.cumsum(sign * vals.real, axis=-1)
    window = slice(EULER_N, EULER_N + EULER_M + 1)
    cdf = jnp.clip(scale * (cdf_part[..., window] @ weights), 0.0, 1.0)
    pdf = jnp.maximum(scale * (pdf_part[..., window] @ weights), 0.0)
    return cdf, pdf


def _cdf_vec(st, t, slot_kinds=None):
    """CDF only — skips the density's cumsum/contraction for the grow and
    bisect phases (the expensive part, the ``T*`` products, is shared either
    way, so this changes cost, never values)."""
    ks = jnp.arange(EULER_N + EULER_M + 1, dtype=jnp.float64)
    theta = (EULER_A + 2j * jnp.pi * ks) / (2.0 * t[..., None])
    vals = _total_lst_slots(st, theta, slot_kinds)
    sign = jnp.where(ks == 0, 0.5, 1.0) * ((-1.0) ** ks)
    weights = jnp.asarray(_EULER_WEIGHTS)
    scale = jnp.exp(EULER_A / 2.0) / t
    cdf_part = jnp.cumsum(sign * (vals / theta).real, axis=-1)
    window = slice(EULER_N, EULER_N + EULER_M + 1)
    return jnp.clip(scale * (cdf_part[..., window] @ weights), 0.0, 1.0)


def quantile_euler_vec(st, q, slot_kinds=None, grow_iters=None):
    """q-quantile of the composed sojourn by exact Euler inversion, batched.

    Replays the scalar ``core.tail._quantile_euler`` trajectory phase for
    phase — ``grow_iters`` doublings from ``max(2 * mean, 1e-12)``,
    ``EULER_BISECT_ITERS`` bisections, ``EULER_NEWTON_ITERS`` safeguarded
    Newton steps on the free Abate-Whitt density — so both sides land on the
    same crossing of the same noisy CDF (see module docstring) and agree to
    float-noise, well under the 1e-8 gated tolerance. Unstable rows (infinite
    mean) return inf, matching the scalar layer.

    Traceable; ``slot_kinds`` must be a static tuple of per-slot hints (or
    None) and ``grow_iters`` a static int at trace time. ``grow_iters`` is
    derived from q via ``core.tail.euler_grow_iters`` when q is concrete;
    inside a jit where q is traced it must be passed explicitly.
    """
    if grow_iters is None:
        grow_iters = euler_grow_iters(float(q))  # raises if q is a tracer
    mean = _sojourn_mean_vec(st)
    finite = jnp.isfinite(mean)
    safe_mean = jnp.where(finite, mean, 1.0)
    hi0 = jnp.maximum(2.0 * safe_mean, 1e-12)

    def grow(_, hi):
        return jnp.where(_cdf_vec(st, hi, slot_kinds) < q, hi * 2.0, hi)

    hi = jax.lax.fori_loop(0, grow_iters, grow, hi0)
    # if the bracket grew, the last doubled-from point hi/2 is a known
    # below-q evaluation — one free bisection
    lo = jnp.where(hi > hi0, 0.5 * hi, 0.0)

    def bisect(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        below = _cdf_vec(st, mid, slot_kinds) < q
        return jnp.where(below, mid, lo), jnp.where(below, hi, mid)

    lo, hi = jax.lax.fori_loop(0, EULER_BISECT_ITERS, bisect, (lo, hi))
    t = 0.5 * (lo + hi)

    def newton(_, carry):
        lo, hi, t = carry
        cdf, pdf = cdf_pdf_vec(st, t, slot_kinds)
        below = cdf < q
        lo = jnp.where(below, t, lo)
        hi = jnp.where(below, hi, t)
        step = t - (cdf - q) / jnp.where(pdf > 0.0, pdf, 1.0)
        ok = (pdf > 0.0) & (step > lo) & (step < hi)
        return lo, hi, jnp.where(ok, step, 0.5 * (lo + hi))

    lo, hi, t = jax.lax.fori_loop(0, EULER_NEWTON_ITERS, newton, (lo, hi, t))
    return jnp.where(finite, jnp.clip(t, lo, hi), _INF)
