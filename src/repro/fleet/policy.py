"""Shared decision/scoring core for the trace replays (§5) and the
closed-loop cluster simulator (§6).

Both :mod:`repro.fleet.replay` (one client, exogenous conditions) and
:mod:`repro.fleet.cluster` (N clients, endogenous edge load) answer the same
two questions every epoch:

  * what would each static policy name mean as a target index, and
  * what does a chosen target actually cost under the TRUE conditions?

This module is the single home for those answers — policy-label parsing (via
``scenario.parse_strategy``, the one label parser), the per-edge background
*template* (the service-moment mixture a churned load report is re-expanded
with), the closed-form true-condition scoring of one target, and the bounded
saturation penalty that keeps policy means comparable across epochs that
cross a stability boundary.
"""

from __future__ import annotations

from dataclasses import replace
from typing import Sequence

import numpy as np

from repro.core.latency import NetworkPath, edge_offload_latency, on_device_latency
from repro.core.manager import ON_DEVICE
from repro.core.multitenant import TenantStream, aggregate_streams, multitenant_edge_latency
from repro.core.scenario import (
    Scenario,
    ScenarioError,
    implied_service_var,
    parse_strategy,
    tier_station,
)
from repro.core.tail import mixture_station, offload_stations, sojourn_quantile

__all__ = ["parse_policy", "bg_template", "static_fractions", "true_latency",
           "clamp_saturation"]


def parse_policy(name: str, n_edges: int) -> int:
    """Static policy label -> target index (``ON_DEVICE`` or an edge index).

    Thin wrapper over :func:`repro.core.scenario.parse_strategy` so replay
    and cluster policies fail exactly like every other strategy label, with
    the error renamed to the ``policies`` field the caller passed."""
    try:
        return parse_strategy(name, n_edges)
    except ScenarioError as err:
        raise ScenarioError("policies", str(err)) from None


def static_fractions(name: str, n_classes: int, n_edges: int) -> np.ndarray:
    """(C, E+1) mean-field fraction matrix of an all-clients static policy.

    Column 0 is on-device and column ``j + 1`` is edge ``j`` — the layout
    :mod:`repro.fleet.meanfield` uses for every fraction state. Each class
    puts its whole mass on the parsed target, so the matrix is the state a
    fleet pinned to ``name`` occupies; labels parse (and fail) exactly like
    replay and cluster policies."""
    if n_classes < 1:
        raise ValueError(f"n_classes must be positive, got {n_classes}")
    target = parse_policy(name, n_edges)
    f = np.zeros((n_classes, n_edges + 1), dtype=np.float64)
    f[:, 0 if target == ON_DEVICE else target + 1] = 1.0
    return f


def bg_template(scn: Scenario, j: int) -> tuple[float, float, float]:
    """(rate, mean, var) of edge j's spec background aggregate; tenant churn
    scales the rate while preserving the mixture's service moments. Edges
    declared without background churn homogeneous copies of the edge's own
    service (the paper's §4.8 setup)."""
    e = scn.edges[j]
    if e.background:
        agg = aggregate_streams(e.background)
        return agg.arrival_rate, agg.service_mean_s, agg.service_var
    return 0.0, e.tier.service_time_s, implied_service_var(e.tier)


def true_latency(
    scn: Scenario, target: int, bw: float, lam: float, bg_rates: np.ndarray,
    templates: Sequence[tuple[float, float, float]],
    *,
    slo_quantile: float | None = None,
    tail_method: str = "euler",
) -> float:
    """Closed-form latency of ``target`` under the true epoch conditions.

    With ``slo_quantile`` set, the score is the q-quantile of the path's
    sojourn distribution (:mod:`repro.core.tail`) instead of the mean — the
    same objective an SLO-mode manager optimises, so adaptive-vs-static
    comparisons stay apples to apples under an SLO."""
    wl = replace(scn.workload, arrival_rate=float(lam))
    if slo_quantile is not None:
        return _true_tail_latency(scn, target, bw, wl, bg_rates, templates,
                                  slo_quantile, tail_method)
    if target == ON_DEVICE:
        return float(np.asarray(on_device_latency(wl, scn.device)))
    e = scn.edges[target]
    net = NetworkPath(bw) if e.bandwidth_Bps is None else NetworkPath(e.bandwidth_Bps)
    rate = float(bg_rates[target])
    _, mean, var = templates[target]
    if rate > 0:
        streams = (e.own_stream(wl), TenantStream(rate, mean, var))
        return float(np.asarray(multitenant_edge_latency(
            wl, e.tier, net, streams, return_results=scn.return_results)))
    return float(np.asarray(edge_offload_latency(
        wl, e.tier, net, return_results=scn.return_results)))


def _true_tail_latency(
    scn: Scenario, target: int, bw: float, wl, bg_rates, templates,
    q: float, method: str,
) -> float:
    """The q-quantile twin of the mean scoring above: identical station
    composition to ``scenario.tail_stations`` with the trace-churned
    background re-aggregated at the reported rate."""
    if target == ON_DEVICE:
        return float(sojourn_quantile((tier_station(scn.device, wl.arrival_rate),),
                                      q, method=method))
    e = scn.edges[target]
    b = float(bw if e.bandwidth_Bps is None else e.bandwidth_Bps)
    rate = float(bg_rates[target])
    _, mean, var = templates[target]
    if rate > 0:
        agg = aggregate_streams((e.own_stream(wl), TenantStream(rate, mean, var)))
        proc = mixture_station(agg.arrival_rate, agg.service_mean_s,
                               agg.service_var, e.tier.parallelism_k)
    else:
        proc = tier_station(e.tier, wl.arrival_rate)
    stations = offload_stations(wl.arrival_rate, wl.req_bytes, wl.res_bytes,
                                b, proc, return_results=scn.return_results)
    return float(sojourn_quantile(stations, q, method=method))


def clamp_saturation(latencies: np.ndarray, penalty_s: float) -> tuple[np.ndarray, int]:
    """Replace non-finite / beyond-penalty epoch latencies with the bounded
    saturation penalty. One epoch of saturation accrues a bounded backlog, and
    bounded penalties keep policy means comparable. Returns the clamped array
    and the number of clamped entries."""
    lat = np.asarray(latencies, dtype=np.float64)
    saturated = ~np.isfinite(lat) | (lat > penalty_s)
    return np.where(saturated, penalty_s, lat), int(saturated.sum())
