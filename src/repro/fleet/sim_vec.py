"""Batched discrete-event simulation as a `jax.lax.scan` Lindley recursion.

`repro.core.simulation` simulates one scenario at a time with a Python-loop
Lindley recursion (exact, but ~1e5 interpreter steps per scenario). Here the
same feed-forward tandem FCFS networks run for *thousands of scenarios in one
device launch*: the job axis is a `lax.scan`, the scenario axis is pure
vectorization, and k-server stations keep a (B, k) earliest-free-server state
updated with a masked argmin — the scan translation of the heap in
``simulation.station_pass`` (identical departures; only tie-breaking among
equal-free servers can differ, which cannot change any departure time).

Semantics mirror ``scenario.simulate`` exactly for dedicated-edge and
on-device strategies: Poisson arrivals, per-tier service distributions derived
from the ServiceModel (deterministic / exponential / lognormal-general),
exponential NIC stages with mean D/B, and inter-stage resorting by departure
where k > 1 allows overtaking. Multi-tenant edges need the shared-station
merge and are delegated to the scalar simulator (raised here, not silently
mis-simulated).

A Pallas kernel variant of the k=1 recursion lives in
``repro.kernels.lindley_scan`` (same contract as :func:`lindley_station` with
``k=1``); the scan path is the portable default.
"""

from __future__ import annotations

import re
from dataclasses import dataclass
from functools import partial

import jax
import jax.experimental
import jax.numpy as jnp
import numpy as np

from repro.core.simulation import steady_slice

from .batch import ScenarioBatch

__all__ = ["FleetSimResult", "lindley_station", "simulate_fleet"]


@partial(jax.jit, static_argnames=("k_max",))
def _lindley_station_jit(arrivals, services, k, *, k_max: int):
    b, _n = arrivals.shape
    # per-scenario server pool: slots >= k_i start (and stay) at +inf so the
    # masked argmin never selects them — a padded server is never free first
    slot = jnp.arange(k_max)
    free0 = jnp.where(slot[None, :] < k[:, None], 0.0, jnp.inf)

    def step(free, job):
        arr, svc = job
        idx = jnp.argmin(free, axis=1)
        earliest = jnp.take_along_axis(free, idx[:, None], axis=1)[:, 0]
        start = jnp.maximum(arr, earliest)
        dep = start + svc
        free = free.at[jnp.arange(b), idx].set(dep)
        return free, dep

    _, deps = jax.lax.scan(step, free0, (arrivals.T, services.T))
    return deps.T


def lindley_station(arrivals, services, k=1, *, k_max: int | None = None):
    """FCFS k-server station, batched: departure times for (B, N) arrivals.

    The exact scan counterpart of ``simulation.station_pass`` — jobs start in
    arrival order on the earliest-free server. ``k`` may be an int (shared) or
    a (B,) array of per-scenario server counts; ``k_max`` bounds the packed
    server state (defaults to max(k)).
    """
    k_needed = int(np.max(np.asarray(k)))
    if k_max is None:
        k_max = k_needed
    elif k_max < k_needed:
        raise ValueError(
            f"k_max={k_max} is smaller than the largest server count "
            f"{k_needed}; the station would silently run with fewer servers")
    # float64 throughout: arrival clocks reach ~n/lam, and float32 ulps there
    # would swamp millisecond-scale waits
    with jax.experimental.enable_x64():
        arrivals = jnp.asarray(np.asarray(arrivals, dtype=np.float64))
        services = jnp.asarray(np.asarray(services, dtype=np.float64))
        k_arr = jnp.broadcast_to(jnp.asarray(k, dtype=jnp.int32), arrivals.shape[:1])
        return _lindley_station_jit(arrivals, services, k_arr, k_max=k_max)


def _resort_by_departure(dep, orig_arrival):
    """FCFS order at the next station is by arrival there (= departure here);
    carry each job's original arrival through the permutation."""
    perm = jnp.argsort(dep, axis=1, stable=True)
    return jnp.take_along_axis(dep, perm, axis=1), jnp.take_along_axis(
        orig_arrival, perm, axis=1
    )


def _service_samples(key, model, s, var, shape):
    """(B, N) service draws per scenario row, dispatching on MODEL_CODES:
    deterministic / exponential / lognormal(mean, var) — the same three
    distributions ``scenario._service_dist`` derives."""
    kn, kl = jax.random.split(key)
    s = s[:, None]
    var = var[:, None]
    exp_draw = s * jax.random.exponential(kn, shape)
    # LogNormal(mean, var) moment-matched exactly as simulation.LogNormal
    sigma2 = jnp.log1p(var / (s * s))
    mu = jnp.log(s) - 0.5 * sigma2
    ln_draw = jnp.exp(mu + jnp.sqrt(sigma2) * jax.random.normal(kl, shape))
    ln_draw = jnp.where(var == 0.0, s, ln_draw)  # degenerate general -> constant
    model = model[:, None]
    return jnp.where(model == 0, s, jnp.where(model == 1, exp_draw, ln_draw))


@dataclass(frozen=True)
class FleetSimResult:
    """Observed per-scenario latencies of one batched simulation."""

    latencies: np.ndarray  # (B, N) in original arrival order
    arrivals: np.ndarray  # (B, N)
    warmup_frac: float = 0.1

    def _steady(self) -> np.ndarray:
        return self.latencies[:, steady_slice(self.latencies.shape[1],
                                              self.warmup_frac)]

    @property
    def mean(self) -> np.ndarray:
        """(B,) steady-state mean latency per scenario."""
        return self._steady().mean(axis=1)

    def percentile(self, q: float) -> np.ndarray:
        return np.percentile(self._steady(), q, axis=1)


def simulate_fleet(
    batch: ScenarioBatch,
    strategy: str = "on_device",
    *,
    n: int = 20_000,
    seed: int = 0,
    k_max: int | None = None,
) -> FleetSimResult:
    """Simulate every scenario in the batch under one strategy, one launch.

    ``strategy`` is ``"on_device"`` or ``"edge[j]"`` (dedicated edges only —
    rows whose target edge hosts background tenants raise, because the shared
    multi-tenant station needs the scalar ``scenario.simulate`` path). The
    trim/mean conventions match ``simulation.SimResult`` so per-scenario means
    are directly comparable against ``simulate_tandem`` on the same spec.
    """
    m = re.fullmatch(r"on_device|edge\[(\d+)\]", strategy)
    if not m:
        raise ValueError(f"unknown strategy {strategy!r}")
    edge = None if m.group(1) is None else int(m.group(1))

    with jax.experimental.enable_x64():
        key = jax.random.PRNGKey(seed)
        keys = jax.random.split(key, 4)
        shape = (batch.size, n)

        inter = jax.random.exponential(keys[0], shape) / jnp.asarray(batch.lam)[:, None]
        arrivals = jnp.cumsum(inter, axis=1)

        if edge is None:
            k_dev = np.rint(batch.dev_k).astype(np.int64)
            if not np.all(k_dev == batch.dev_k):
                raise ValueError("fractional device parallelism_k cannot be simulated "
                                 "exactly; round it or compare via fleet_analytic only")
            services = _service_samples(
                keys[1], jnp.asarray(batch.dev_model), jnp.asarray(batch.dev_s),
                jnp.asarray(batch.dev_var), shape,
            )
            dep = lindley_station(arrivals, services, np.maximum(k_dev, 1), k_max=k_max)
            latencies = dep - arrivals
            return FleetSimResult(np.asarray(latencies), np.asarray(arrivals))

        if edge >= batch.max_edges or not bool(np.all(batch.edge_mask[:, edge])):
            raise ValueError(f"strategy {strategy!r}: not every scenario has that edge")
        if np.any(batch.bg_lam[:, edge] > 0):
            raise ValueError(
                f"strategy {strategy!r}: background tenants need the shared-station "
                "simulator — use scenario.simulate for those rows"
            )
        k_edge = np.rint(batch.edge_k[:, edge]).astype(np.int64)
        if not np.all(k_edge == batch.edge_k[:, edge]):
            raise ValueError("fractional edge parallelism_k cannot be simulated "
                             "exactly; round it or compare via fleet_analytic only")

        bw = np.where(np.isnan(batch.edge_bw[:, edge]), batch.bandwidth_Bps,
                      batch.edge_bw[:, edge])
        req_mean = jnp.asarray(batch.req_bytes / bw)[:, None]
        res_mean = jnp.asarray(
            np.where(batch.return_results, batch.res_bytes, 0.0) / bw
        )[:, None]

        # stage 1: device NIC (k=1, exponential mean D_req/B); k=1 departures
        # are already non-decreasing, so no resort is needed before stage 2
        nic_req = req_mean * jax.random.exponential(keys[1], shape)
        t = lindley_station(arrivals, nic_req, 1, k_max=1)
        orig = arrivals

        # stage 2: edge processing (k servers, tier service model)
        services = _service_samples(
            keys[2], jnp.asarray(batch.edge_model[:, edge]),
            jnp.asarray(batch.edge_s[:, edge]), jnp.asarray(batch.edge_var[:, edge]),
            shape,
        )
        dep = lindley_station(t, services, np.maximum(k_edge, 1), k_max=k_max)
        t, orig = _resort_by_departure(dep, orig)  # k>1 can overtake

        # stage 3: edge NIC return path (k=1, exponential mean D_res/B; zero
        # mean collapses to zero service when results are consumed at the edge)
        nic_res = res_mean * jax.random.exponential(keys[3], shape)
        dep = lindley_station(t, nic_res, 1, k_max=1)

        latency = dep - orig
        # report in original arrival order for warmup trimming (cf. SimResult)
        perm = jnp.argsort(orig, axis=1, stable=True)
        latency = jnp.take_along_axis(latency, perm, axis=1)
        orig = jnp.take_along_axis(orig, perm, axis=1)
        return FleetSimResult(np.asarray(latency), np.asarray(orig))
