"""Time-varying condition traces for the §5 adaptive-manager experiments.

A :class:`Trace` is the epoch-sampled environment the paper's resource
manager reacts to: measured network bandwidth, request arrival rate, and
per-edge aggregate background load ("dynamic multi-tenant edge settings").
Generators cover the three shapes the evaluation uses:

  * :func:`step_signal` — piecewise-constant schedules (the Fig. 6
    20 -> 10 -> 2 -> 20 Mbps bandwidth walk, Fig. 7 load phases);
  * :func:`drift_signal` — linear drift with an optional seeded random walk
    (slow diurnal-style change);
  * :func:`mmpp_signal` — a 2-state Markov-modulated level (bursty
    conditions: the process alternates between a low and a high level with
    geometric sojourn times, the discrete-epoch cousin of an MMPP).

All generators are plain numpy and seeded — a trace is data, not a process,
so replays are exactly reproducible and trivially serialisable.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import numpy as np

__all__ = [
    "Trace",
    "TraceBatch",
    "epoch_times",
    "step_signal",
    "drift_signal",
    "mmpp_signal",
    "make_trace",
]


@dataclass(frozen=True)
class Trace:
    """Epoch-sampled environment conditions for a trace-driven replay."""

    times: np.ndarray  # (T,) epoch start times, uniformly spaced
    bandwidth_Bps: np.ndarray  # (T,) measured shared-path bandwidth
    arrival_rate: np.ndarray  # (T,) device request rate lambda
    edge_bg_rate: np.ndarray  # (T, E) aggregate background rate per edge

    def __post_init__(self):
        t = np.asarray(self.times, dtype=np.float64)
        object.__setattr__(self, "times", t)
        object.__setattr__(self, "bandwidth_Bps",
                           np.asarray(self.bandwidth_Bps, dtype=np.float64))
        object.__setattr__(self, "arrival_rate",
                           np.asarray(self.arrival_rate, dtype=np.float64))
        bg = np.asarray(self.edge_bg_rate, dtype=np.float64)
        if bg.ndim == 1:
            bg = bg[:, None]
        object.__setattr__(self, "edge_bg_rate", bg)
        if t.ndim != 1 or len(t) < 2:
            raise ValueError("trace needs at least two epochs")
        dts = np.diff(t)
        if not np.allclose(dts, dts[0]) or dts[0] <= 0:
            raise ValueError("trace epochs must be uniformly spaced and increasing")
        for name in ("bandwidth_Bps", "arrival_rate"):
            arr = getattr(self, name)
            if arr.shape != t.shape:
                raise ValueError(f"{name} must be shape {t.shape}, got {arr.shape}")
        if self.edge_bg_rate.shape[0] != len(t):
            raise ValueError("edge_bg_rate must have one row per epoch")
        if np.any(self.bandwidth_Bps <= 0):
            raise ValueError("bandwidth must be positive everywhere")
        if np.any(self.arrival_rate <= 0):
            raise ValueError("arrival rate must be positive everywhere")
        if np.any(self.edge_bg_rate < 0):
            raise ValueError("background rates must be non-negative")

    @property
    def n_epochs(self) -> int:
        return int(len(self.times))

    @property
    def n_edges(self) -> int:
        return int(self.edge_bg_rate.shape[1])

    @property
    def epoch_s(self) -> float:
        return float(self.times[1] - self.times[0])


@dataclass(frozen=True)
class TraceBatch:
    """Per-client condition traces for a closed-loop cluster replay.

    The N-client generalisation of :class:`Trace`: every client sees its own
    measured bandwidth and arrival rate, while ``edge_bg_rate`` is the
    *exogenous* (non-cluster) background load per shared edge — the
    endogenous part, what the other N-1 clients offload, is produced by the
    closed loop itself (:mod:`repro.fleet.cluster`), never by a trace.
    """

    times: np.ndarray  # (T,) epoch start times, uniformly spaced
    bandwidth_Bps: np.ndarray  # (T, N) per-client measured bandwidth
    arrival_rate: np.ndarray  # (T, N) per-client request rate lambda
    edge_bg_rate: np.ndarray  # (T, E) exogenous background rate per edge

    def __post_init__(self):
        t = np.asarray(self.times, dtype=np.float64)
        object.__setattr__(self, "times", t)
        for name in ("bandwidth_Bps", "arrival_rate", "edge_bg_rate"):
            object.__setattr__(self, name,
                               np.asarray(getattr(self, name), dtype=np.float64))
        if t.ndim != 1 or len(t) < 2:
            raise ValueError("trace batch needs at least two epochs")
        dts = np.diff(t)
        if not np.allclose(dts, dts[0]) or dts[0] <= 0:
            raise ValueError("trace epochs must be uniformly spaced and increasing")
        for name in ("bandwidth_Bps", "arrival_rate", "edge_bg_rate"):
            arr = getattr(self, name)
            if arr.ndim != 2 or arr.shape[0] != len(t):
                raise ValueError(f"{name} must be (n_epochs, ...) 2-D with "
                                 f"{len(t)} rows, got shape {arr.shape}")
        if self.bandwidth_Bps.shape != self.arrival_rate.shape:
            raise ValueError("bandwidth_Bps and arrival_rate must agree on "
                             "(n_epochs, n_clients)")
        if self.n_clients < 1:
            raise ValueError("trace batch needs at least one client column")
        if np.any(self.bandwidth_Bps <= 0):
            raise ValueError("bandwidth must be positive everywhere")
        if np.any(self.arrival_rate <= 0):
            raise ValueError("arrival rate must be positive everywhere")
        if np.any(self.edge_bg_rate < 0):
            raise ValueError("background rates must be non-negative")

    @property
    def n_epochs(self) -> int:
        return int(len(self.times))

    @property
    def n_clients(self) -> int:
        return int(self.bandwidth_Bps.shape[1])

    @property
    def n_edges(self) -> int:
        return int(self.edge_bg_rate.shape[1])

    @property
    def epoch_s(self) -> float:
        return float(self.times[1] - self.times[0])

    @classmethod
    def from_trace(cls, trace: Trace, n_clients: int) -> "TraceBatch":
        """Broadcast one single-client trace over ``n_clients`` identical
        columns (every client measures the same conditions)."""
        if n_clients < 1:
            raise ValueError("n_clients must be positive")
        tile = np.repeat(trace.bandwidth_Bps[:, None], n_clients, axis=1)
        lam = np.repeat(trace.arrival_rate[:, None], n_clients, axis=1)
        return cls(times=trace.times, bandwidth_Bps=tile, arrival_rate=lam,
                   edge_bg_rate=trace.edge_bg_rate)

    @classmethod
    def from_traces(cls, traces: Sequence[Trace]) -> "TraceBatch":
        """Stack N per-client traces column-wise.

        All traces must share the same epoch grid, and — because the
        exogenous edge background is a property of the shared pool, not of
        any one client — identical ``edge_bg_rate`` columns."""
        if not traces:
            raise ValueError("need at least one trace")
        first = traces[0]
        for k, tr in enumerate(traces[1:], start=1):
            if not np.array_equal(tr.times, first.times):
                raise ValueError(f"trace {k} has a different epoch grid")
            if not np.array_equal(tr.edge_bg_rate, first.edge_bg_rate):
                raise ValueError(
                    f"trace {k} disagrees on the exogenous edge background; "
                    "the shared pool has ONE background, per-client bg traces "
                    "are not meaningful")
        return cls(
            times=first.times,
            bandwidth_Bps=np.stack([tr.bandwidth_Bps for tr in traces], axis=1),
            arrival_rate=np.stack([tr.arrival_rate for tr in traces], axis=1),
            edge_bg_rate=first.edge_bg_rate,
        )


def epoch_times(duration_s: float, epoch_s: float) -> np.ndarray:
    """Uniform epoch starts covering [0, duration)."""
    if epoch_s <= 0 or duration_s < 2 * epoch_s:
        raise ValueError("need duration >= 2 epochs of positive length")
    return np.arange(0.0, duration_s, epoch_s)


def step_signal(times: np.ndarray, points: Sequence[tuple[float, float]]) -> np.ndarray:
    """Piecewise-constant schedule from (time, value) breakpoints.

    The value before the first breakpoint is the first value; breakpoints
    must be time-sorted. ``step_signal(t, [(0, 20), (40, 2), (60, 20)])`` is
    the Fig. 6-style walk.
    """
    if not points:
        raise ValueError("need at least one (time, value) breakpoint")
    ts = np.asarray([p[0] for p in points], dtype=np.float64)
    vs = np.asarray([p[1] for p in points], dtype=np.float64)
    if np.any(np.diff(ts) < 0):
        raise ValueError("breakpoints must be sorted by time")
    idx = np.clip(np.searchsorted(ts, times, side="right") - 1, 0, len(vs) - 1)
    return vs[idx]


def drift_signal(
    times: np.ndarray,
    start: float,
    end: float,
    *,
    jitter: float = 0.0,
    seed: int = 0,
    floor: float = 1e-9,
) -> np.ndarray:
    """Linear drift start -> end plus an optional seeded random walk.

    ``jitter`` is the per-epoch random-walk step as a fraction of the mean
    level; the result is floored to keep rates/bandwidths positive.
    """
    base = np.linspace(start, end, len(times))
    if jitter > 0.0:
        rng = np.random.default_rng(seed)
        scale = jitter * 0.5 * (start + end)
        base = base + np.cumsum(rng.normal(0.0, scale, size=len(times)))
    return np.maximum(base, floor)


def mmpp_signal(
    times: np.ndarray,
    low: float,
    high: float,
    *,
    p_up: float = 0.1,
    p_down: float = 0.3,
    seed: int = 0,
) -> np.ndarray:
    """Bursty 2-state Markov-modulated level (epoch-discretised MMPP).

    Each epoch the process jumps low->high w.p. ``p_up`` and high->low w.p.
    ``p_down`` — geometric burst/idle sojourns, mean burst length 1/p_down
    epochs. Used for flash-crowd arrival bursts and fading-link bandwidth.
    """
    if not (0 <= p_up <= 1 and 0 <= p_down <= 1):
        raise ValueError("transition probabilities must be in [0, 1]")
    rng = np.random.default_rng(seed)
    state = np.zeros(len(times), dtype=bool)
    cur = False
    u = rng.random(len(times))
    for i in range(len(times)):
        cur = (not cur and u[i] < p_up) or (cur and u[i] >= p_down)
        state[i] = cur
    return np.where(state, high, low)


def _resolve(spec, times: np.ndarray) -> np.ndarray:
    if callable(spec):
        return np.asarray(spec(times), dtype=np.float64)
    arr = np.asarray(spec, dtype=np.float64)
    if arr.ndim == 0:
        return np.full(len(times), float(arr))
    return arr


def make_trace(
    duration_s: float,
    epoch_s: float,
    *,
    bandwidth_Bps,
    arrival_rate,
    edge_bg_rate: Sequence = (),
) -> Trace:
    """Assemble a Trace from per-field specs (constant, array, or callable).

    ``edge_bg_rate`` is one spec per edge; edges beyond the sequence get a
    constant zero background. Example::

        trace = make_trace(
            120.0, 1.0,
            bandwidth_Bps=lambda t: step_signal(t, [(0, 2.5e6), (40, 2.5e5)]),
            arrival_rate=10.0,
            edge_bg_rate=[lambda t: mmpp_signal(t, 0.0, 30.0, seed=7)],
        )
    """
    times = epoch_times(duration_s, epoch_s)
    bg = [_resolve(spec, times) for spec in edge_bg_rate]
    bg_arr = np.stack(bg, axis=1) if bg else np.zeros((len(times), 0))
    return Trace(
        times=times,
        bandwidth_Bps=_resolve(bandwidth_Bps, times),
        arrival_rate=_resolve(arrival_rate, times),
        edge_bg_rate=bg_arr,
    )
