"""Closed-loop multi-client edge-cluster simulation (the paper's §6 setting
at fleet scale).

``repro.fleet.replay`` scores ONE client against exogenous traces — nothing
that client does changes the load anyone else observes. A real multi-tenant
edge deployment is coupled: when a client offloads, its stream joins the
chosen edge's aggregate, every other client's model of that edge worsens,
and their next decisions shift load elsewhere. This module closes that loop
for N clients sharing E edge servers over T epochs:

  * every epoch, every client decides on-device vs offload(e) with exactly
    the §4.2 estimator path the scalar :class:`AdaptiveOffloadManager.step`
    runs — EWMA bandwidth and edge-load reports, a sliding-window arrival
    estimate over seeded Poisson counts — transcribed to (N,)/(N, E) arrays
    (a coherence test pins the two paths decision-for-decision);
  * the per-edge background load is *endogenous*: the offloaders' arrival
    rates superpose (``multitenant.mixture_moments``, §3.4) on top of any
    exogenous background from the trace, and the resulting loads are what
    next epoch's estimators observe;
  * per-client expected latency under the TRUE conditions is evaluated with
    the jitted ``analytic_vec`` closed forms over (N, E) arrays — the
    decision loop is a single ``lax.scan`` over epochs and the scoring a
    single jitted call over all T*N client-epochs, which is what makes
    >=100k client-epochs/s on CPU routine;
  * :func:`solve_equilibrium` finds the fixed point of the decision->load
    map under constant conditions (synchronous best response, falling back
    to damped one-client-at-a-time switching when an oscillation is
    detected), and :func:`cross_check_equilibrium` validates the closed-loop
    analytic means against the event-driven simulators exactly the way the
    PR 3 differential harness validated the open-loop ones.
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from functools import partial
from typing import Mapping, Sequence

import jax
import jax.experimental
import jax.numpy as jnp
import numpy as np

from repro.core.latency import NetworkPath
from repro.core.manager import ON_DEVICE
from repro.core.multitenant import TenantStream, mixture_moments
from repro.core.scenario import (
    ClusterSpec,
    Scenario,
    ScenarioError,
    analytic as scalar_analytic,
    implied_service_var,
)
from repro.core.simulation import steady_slice
from repro.core.tail import euler_grow_iters, resolve_tail_method

from .analytic_vec import (
    _device_latency_vec,
    _edge_latency_vec,
    _implied_var_vec,
    _proc_wait_vec,
    mg1_wait_vec,
    mm1_wait_vec,
)
from .batch import MODEL_CODES, ScenarioBatch
from .policy import bg_template, clamp_saturation, parse_policy
from .sim_vec import simulate_fleet
from .tail_vec import (
    KIND_EXP,
    KIND_GAMMA,
    _device_tail_vec,
    _edge_tail_vec,
    _stack_stations,
    sojourn_quantile_vec,
)
from .traces import Trace, TraceBatch

__all__ = [
    "ClusterPolicyResult",
    "ClusterResult",
    "Equilibrium",
    "simulate_cluster",
    "solve_equilibrium",
    "induced_scenario",
    "cross_check_equilibrium",
    "predict_decisions",
    "predict_terms",
]


# ---------------------------------------------------------------------------
# static spec arrays
# ---------------------------------------------------------------------------


def _spec_arrays(spec: ClusterSpec) -> dict[str, np.ndarray]:
    """The client-independent columns every cluster evaluation consumes."""
    base = spec.base
    e_n = spec.n_edges
    edge_s = np.array([e.tier.service_time_s for e in base.edges])
    templates = [bg_template(base, j) for j in range(e_n)]
    return {
        "lam_spec": spec.arrival_rates(),  # (N,)
        "req_bytes": np.float64(base.workload.req_bytes),
        "res_bytes": np.float64(base.workload.res_bytes),
        "return_results": np.bool_(base.return_results),
        "dev_s": np.float64(base.device.service_time_s),
        "dev_k": np.float64(base.device.parallelism_k),
        "dev_var": np.float64(base.device.service_var),
        "dev_model": np.int8(MODEL_CODES[base.device.service_model]),
        "edge_s": edge_s,
        "edge_k": np.array([e.tier.parallelism_k for e in base.edges]),
        "edge_var": np.array([e.tier.service_var for e in base.edges]),
        "edge_model": np.array(
            [MODEL_CODES[e.tier.service_model] for e in base.edges], dtype=np.int8),
        "edge_bw": np.array(
            [np.nan if e.bandwidth_Bps is None else e.bandwidth_Bps
             for e in base.edges]),
        # endogenous template: what one unit of *cluster* load looks like on
        # edge j — the shared workload's own service moments there
        "endo_mean": edge_s,
        "endo_var": np.array([implied_service_var(e.tier) for e in base.edges]),
        # exogenous template: the spec's declared background mixture, whose
        # rate the trace churns while the service moments hold (cf. replay)
        "exo_rate": np.array([t[0] for t in templates]),
        "exo_mean": np.array([t[1] for t in templates]),
        "exo_var": np.array([t[2] for t in templates]),
    }


def _as_jnp(cst: Mapping[str, np.ndarray]) -> dict[str, jnp.ndarray]:
    return {k: jnp.asarray(v) for k, v in cst.items()}


# ---------------------------------------------------------------------------
# Algorithm 1 over (N, E) arrays — the manager's prediction path, transcribed
# ---------------------------------------------------------------------------


def _bg_moments(cst, endo, exo):
    """The (bg_lam, bg_wsum, bg_ssum) background columns from endogenous and
    exogenous per-edge rates, each expanded with its own service template —
    THE mixture-moment expansion, shared by the prediction path, the decision
    scan, and the truth-scoring tables so the three can never drift apart.
    ``endo``/``exo`` broadcast against the (E,) templates ((N, E), (T, N, E),
    (1, E), ... all work)."""
    bg_lam = endo + exo
    bg_wsum = endo * cst["endo_mean"] + exo * cst["exo_mean"]
    bg_ssum = endo * (cst["endo_var"] + cst["endo_mean"] ** 2) + exo * (
        cst["exo_var"] + cst["exo_mean"] ** 2)
    return bg_lam, bg_wsum, bg_ssum


def _predict_terms_vec(cst, lam_hat, bw_hat, bg_lam, bg_wsum, bg_ssum):
    """The per-term decomposition behind :func:`_predict_vec`, keyed exactly
    like ``LatencyBreakdown`` (w_proc_dev/s_dev; w_net_dev/n_req/w_proc_edge/
    s_edge/w_net_edge/n_res) — device terms (N,), edge terms (N, E). The
    totals are DERIVED from these by ordered summation, so the cluster's
    decision audits re-sum bit-exactly by construction."""
    shape = jnp.broadcast_shapes(lam_hat.shape + (1,), bg_lam.shape)
    w_proc_dev = _proc_wait_vec(
        cst["dev_model"], lam_hat, cst["dev_s"], cst["dev_var"], cst["dev_k"])
    s_dev = jnp.broadcast_to(cst["dev_s"], lam_hat.shape)

    own_var = _implied_var_vec(cst["edge_model"], cst["edge_s"], cst["edge_var"])
    lam = lam_hat[:, None]
    lam_tot = lam + bg_lam
    mean_mix = (lam * cst["edge_s"] + bg_wsum) / lam_tot
    second = (lam * (own_var + cst["edge_s"] ** 2) + bg_ssum) / lam_tot
    var_mix = jnp.maximum(0.0, second - mean_mix**2)
    w_proc_edge = jnp.broadcast_to(
        mg1_wait_vec(lam_tot, 1.0 / mean_mix, var_mix, cst["edge_k"]), shape)

    b = jnp.where(jnp.isnan(cst["edge_bw"]), bw_hat[:, None], cst["edge_bw"])
    w_net_dev = jnp.broadcast_to(
        mm1_wait_vec(lam, b / cst["req_bytes"]), shape)
    n_req = jnp.broadcast_to(cst["req_bytes"] / b, shape)
    use_res = cst["return_results"] & (cst["res_bytes"] > 0)
    w_net_edge = jnp.where(
        use_res, mm1_wait_vec(lam_tot, b / cst["res_bytes"]), 0.0)
    n_res = jnp.where(use_res, jnp.broadcast_to(cst["res_bytes"] / b, shape), 0.0)
    return {
        "w_proc_dev": w_proc_dev,
        "s_dev": s_dev,
        "w_net_dev": w_net_dev,
        "n_req": n_req,
        "w_proc_edge": w_proc_edge,
        "s_edge": jnp.broadcast_to(cst["edge_s"], shape),
        "w_net_edge": w_net_edge,
        "n_res": n_res,
    }


def _sum_terms(terms):
    """(t_dev, t_edge) from the term dict — LatencyBreakdown's exact
    summation order (matches the scalar manager's ordered sum)."""
    t_dev = terms["w_proc_dev"] + terms["s_dev"]
    t_edge = (terms["w_net_dev"] + terms["n_req"] + terms["w_proc_edge"]
              + terms["s_edge"] + terms["w_net_edge"] + terms["n_res"])
    return t_dev, t_edge


def _predict_vec(cst, lam_hat, bw_hat, bg_lam, bg_wsum, bg_ssum):
    """(N,) t_dev and (N, E) t_edge exactly as ``AdaptiveOffloadManager.step``
    computes them from the same estimates (Alg. 1 lines 1-6): the device via
    its service-model dispatch, each edge as M/G/1 on the aggregate mixture
    (own stream folded in) with the OWN service time on line 6."""
    return _sum_terms(
        _predict_terms_vec(cst, lam_hat, bw_hat, bg_lam, bg_wsum, bg_ssum))


def _predict_tail_vec(cst, lam_hat, bw_hat, bg_lam, bg_wsum, bg_ssum, q,
                      method: str, grow_iters: int | None = None):
    """The q-quantile twin of :func:`_predict_vec`: the same station
    composition an SLO-mode ``AdaptiveOffloadManager`` prices scalar-side
    (device NIC -> aggregate-mixture M/G/1 wait + OWN service -> return NIC),
    vectorized over (N, E). Coherence with ``manager.decide`` under
    ``slo_quantile`` is pinned by tests exactly like the mean path."""
    n = lam_hat.shape[0]
    e_n = cst["edge_s"].shape[0]
    dev_kind = jnp.broadcast_to(cst["dev_model"], (n,)).astype(jnp.int8)
    t_dev = sojourn_quantile_vec(_stack_stations({
        "lam": lam_hat,
        "wkind": dev_kind,
        "wmean": jnp.broadcast_to(cst["dev_s"] / cst["dev_k"], (n,)),
        "wvar": jnp.broadcast_to(cst["dev_var"], (n,)),
        "fkind": dev_kind,
        "fmean": jnp.broadcast_to(cst["dev_s"], (n,)),
        "fvar": jnp.broadcast_to(cst["dev_var"], (n,)),
    }), q, method=method, slot_kinds=(None,), grow_iters=grow_iters)

    own_var = _implied_var_vec(cst["edge_model"], cst["edge_s"], cst["edge_var"])
    lam = lam_hat[:, None]
    lam_tot = lam + bg_lam
    mean_mix = (lam * cst["edge_s"] + bg_wsum) / lam_tot
    second = (lam * (own_var + cst["edge_s"] ** 2) + bg_ssum) / lam_tot
    var_mix = jnp.maximum(0.0, second - mean_mix**2)

    b = jnp.where(jnp.isnan(cst["edge_bw"]), bw_hat[:, None], cst["edge_bw"])
    req_mean = cst["req_bytes"] / b
    use_res = cst["return_results"] & (cst["res_bytes"] > 0)
    res_mean = jnp.where(use_res, cst["res_bytes"] / b, 0.0)
    shape = (n, e_n)
    kexp = jnp.full(shape, KIND_EXP, dtype=jnp.int8)
    kgam = jnp.full(shape, KIND_GAMMA, dtype=jnp.int8)
    zero = jnp.zeros(shape)
    lam_e = jnp.broadcast_to(lam, shape)
    stations = _stack_stations(
        {"lam": lam_e, "wkind": kexp, "wmean": req_mean, "wvar": zero,
         "fkind": kexp, "fmean": req_mean, "fvar": zero},
        {"lam": lam_tot, "wkind": kgam, "wmean": mean_mix / cst["edge_k"],
         "wvar": var_mix, "fkind": kgam,
         "fmean": jnp.broadcast_to(cst["edge_s"], shape), "fvar": var_mix},
        {"lam": lam_tot, "wkind": kexp, "wmean": res_mean, "wvar": zero,
         "fkind": kexp, "fmean": res_mean, "fvar": zero},
    )
    t_edge = sojourn_quantile_vec(stations, q, method=method,
                                  slot_kinds=("nic", None, "nic"),
                                  grow_iters=grow_iters)
    return t_dev, t_edge


def _tail_grow_iters(slo_quantile: float, tail_method: str) -> int | None:
    """Static bracket-doubling count for the euler tail path (None for the
    asymptote) — computed where ``slo_quantile`` is still a Python float so
    the jitted paths can pass it through as a static argument."""
    return euler_grow_iters(slo_quantile) if tail_method == "euler" else None


def _decide_vec(t_dev, t_edge, prev_choice, hysteresis, use_hysteresis):
    """Vectorized ``manager.apply_decision_rule``: first-argmin with
    on-device winning ties, plus the relative-improvement hysteresis."""
    stacked = jnp.concatenate([t_dev[:, None], t_edge], axis=1)
    choice = jnp.argmin(stacked, axis=1) - 1
    predicted = jnp.min(stacked, axis=1)
    prev_t = jnp.take_along_axis(stacked, (prev_choice + 1)[:, None], axis=1)[:, 0]
    keep = (
        use_hysteresis
        & (hysteresis > 0.0)
        & (choice != prev_choice)
        & jnp.isfinite(prev_t)
        & (predicted > (1.0 - hysteresis) * prev_t)
    )
    return jnp.where(keep, prev_choice, choice).astype(jnp.int32)


def predict_decisions(
    spec: ClusterSpec,
    lam_hat,
    bandwidth_hat,
    endo_hat,
    exo_hat,
    *,
    prev_choice=None,
    hysteresis: float = 0.0,
    slo_quantile: float | None = None,
    tail_method: str = "asymptote",
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """One epoch of cluster decisions from explicit estimates.

    ``lam_hat``/``bandwidth_hat`` are (N,) per-client estimates, ``endo_hat``
    the (N, E) estimated *other-client* load per edge, ``exo_hat`` the (E,)
    estimated exogenous background. Returns ``(choices, t_dev, t_edge)`` —
    the same numbers ``AdaptiveOffloadManager.step`` produces client by
    client from identical inputs, which is exactly what the gateway
    multi-edge coherence tests assert. Non-positive arrival estimates fall
    back to the client's spec rate, exactly like the closed-loop scan (an
    idle estimator must not poison the mixture mean with 0/0)."""
    if slo_quantile is not None:
        if not 0.0 < slo_quantile < 1.0:
            raise ValueError(f"slo_quantile must be in (0, 1), got {slo_quantile}")
        tail_method = resolve_tail_method(slo_quantile, tail_method)
    cst = _spec_arrays(spec)
    with jax.experimental.enable_x64():
        c = _as_jnp(cst)
        lam_hat = jnp.atleast_1d(jnp.asarray(lam_hat, dtype=jnp.float64))
        if lam_hat.shape[0] != spec.n_clients:
            raise ScenarioError(
                "n_clients", f"expected {spec.n_clients} per-client estimates, "
                f"got {lam_hat.shape[0]}")
        lam_hat = jnp.where(lam_hat > 0, lam_hat, c["lam_spec"])
        bw_hat = jnp.broadcast_to(
            jnp.asarray(bandwidth_hat, dtype=jnp.float64), lam_hat.shape)
        endo = jnp.asarray(endo_hat, dtype=jnp.float64).reshape(
            lam_hat.shape[0], spec.n_edges)
        exo = jnp.asarray(exo_hat, dtype=jnp.float64).reshape(spec.n_edges)
        bg_lam, bg_wsum, bg_ssum = _bg_moments(c, endo, exo[None, :])
        if slo_quantile is None:
            t_dev, t_edge = _predict_vec(c, lam_hat, bw_hat, bg_lam, bg_wsum, bg_ssum)
        else:
            t_dev, t_edge = _predict_tail_vec(
                c, lam_hat, bw_hat, bg_lam, bg_wsum, bg_ssum,
                jnp.float64(slo_quantile), tail_method,
                _tail_grow_iters(slo_quantile, tail_method))
        if prev_choice is None:
            prev = jnp.full(lam_hat.shape, ON_DEVICE, dtype=jnp.int32)
            use_h = jnp.bool_(False)
        else:
            prev = jnp.asarray(prev_choice, dtype=jnp.int32)
            use_h = jnp.bool_(True)
        choice = _decide_vec(t_dev, t_edge, prev, jnp.float64(hysteresis), use_h)
        return np.asarray(choice), np.asarray(t_dev), np.asarray(t_edge)


def predict_terms(
    spec: ClusterSpec,
    lam_hat,
    bandwidth_hat,
    endo_hat,
    exo_hat,
) -> dict[str, np.ndarray]:
    """The per-term decomposition behind one epoch of (mean-mode) cluster
    decisions — ``predict_decisions``' totals, shown working.

    Same estimate inputs and fallback semantics as :func:`predict_decisions`.
    Returns LatencyBreakdown-keyed arrays — device terms ``w_proc_dev``/
    ``s_dev`` (N,), edge terms ``w_net_dev``/``n_req``/``w_proc_edge``/
    ``s_edge``/``w_net_edge``/``n_res`` (N, E) — plus their ordered sums
    ``t_dev`` (N,) and ``t_edge`` (N, E), which match ``predict_decisions``
    bit-for-bit on identical inputs (both are ``_sum_terms`` over
    ``_predict_terms_vec``). This is what ``repro.obs.audit.audit_cluster``
    reconstructs closed-loop decision audits from.
    """
    cst = _spec_arrays(spec)
    with jax.experimental.enable_x64():
        c = _as_jnp(cst)
        lam_hat = jnp.atleast_1d(jnp.asarray(lam_hat, dtype=jnp.float64))
        if lam_hat.shape[0] != spec.n_clients:
            raise ScenarioError(
                "n_clients", f"expected {spec.n_clients} per-client estimates, "
                f"got {lam_hat.shape[0]}")
        lam_hat = jnp.where(lam_hat > 0, lam_hat, c["lam_spec"])
        bw_hat = jnp.broadcast_to(
            jnp.asarray(bandwidth_hat, dtype=jnp.float64), lam_hat.shape)
        endo = jnp.asarray(endo_hat, dtype=jnp.float64).reshape(
            lam_hat.shape[0], spec.n_edges)
        exo = jnp.asarray(exo_hat, dtype=jnp.float64).reshape(spec.n_edges)
        bg_lam, bg_wsum, bg_ssum = _bg_moments(c, endo, exo[None, :])
        terms = _predict_terms_vec(c, lam_hat, bw_hat, bg_lam, bg_wsum, bg_ssum)
        t_dev, t_edge = _sum_terms(terms)
        out = {k: np.asarray(v) for k, v in terms.items()}
        out["t_dev"] = np.asarray(t_dev)
        out["t_edge"] = np.asarray(t_edge)
        return out


# ---------------------------------------------------------------------------
# the closed decision loop: one lax.scan over epochs
# ---------------------------------------------------------------------------


@jax.jit
def _poisson_counts(seed, lam_true, dt):
    """Per-epoch Poisson arrival counts (T, N), hoisted out of the decision
    scan. Replicates the scan's original in-carry key chain step for step
    (``key, kp = split(key); poisson(kp, lam_t * dt)``) so the draws are
    bitwise identical to what the pre-hoist closed loop sampled — which is
    what lets the sharded scans consume the SAME counts as the flat one and
    stay exact, and lets padding happen after sampling without perturbing the
    real clients' draws."""

    def chain(key, lam_t):
        key, kp = jax.random.split(key)
        return key, jax.random.poisson(kp, lam_t * dt).astype(jnp.float64)

    _, n_req = jax.lax.scan(chain, jax.random.PRNGKey(seed), lam_true)
    return n_req


def _scan_epochs(cst, lam_spec, cohort, bw_true, lam_true, exo_true, n_req_all,
                 *, window: int, stagger: int, dt, bw_alpha, bg_alpha,
                 hysteresis, slo_q: float | None = None,
                 tail_method: str = "asymptote", axis_name: str | None = None):
    """The closed decision loop over THIS shard's clients: one ``lax.scan``
    over epochs.

    Carry: per-client EWMA bandwidth, the sliding-window ring of per-epoch
    Poisson arrival counts (pre-drawn by :func:`_poisson_counts` and fed in
    as scan inputs), per-client EWMA estimates of the *other* clients'
    per-edge load (fed by last epoch's reports — the closed loop's one-epoch
    information lag), the shared EWMA exogenous-load estimate, and the
    previous decision (hysteresis).

    Within an epoch every per-client quantity is elementwise in the client
    axis; the ONLY cross-client coupling is the endogenous-load total, so
    with ``axis_name`` set the same body runs on a block of clients under
    ``shard_map`` (or ``vmap`` on one device) and a single ``lax.psum``
    restores the fleet-wide sum — blocking is exact, not approximate.

    ``stagger`` desynchronizes the control epochs: client i re-decides only
    on epochs where ``t % stagger == i % stagger`` and holds its previous
    target in between. Synchronized fleets sharing identical estimates herd
    — every client stampedes onto the same momentarily-cheapest edge,
    saturates it, and stampedes off again, paying the saturation penalty in
    lockstep. Real per-device managers are not phase-locked; ``stagger=k``
    models k staggered cohorts (1 = fully synchronous, the single-client
    replay semantics).
    """
    t_n, n = lam_true.shape
    e_n = exo_true.shape[1]

    def step(carry, inputs):
        est_bw, counts, est_endo, est_exo, prev_choice = carry
        bw_t, lam_t, exo_t, n_req, idx = inputs
        first = idx == 0

        # -- telemetry (§4.2): estimators, never raw instantaneous values --
        est_bw = jnp.where(first, bw_t, bw_alpha * bw_t + (1 - bw_alpha) * est_bw)
        est_exo = jnp.where(first, exo_t, bg_alpha * exo_t + (1 - bg_alpha) * est_exo)
        counts = jax.lax.dynamic_update_slice(
            counts, n_req[:, None], (0, jnp.mod(idx, window)))
        rate = counts.sum(axis=1) / (window * dt)
        lam_hat = jnp.where(rate > 0, rate, lam_spec)

        # -- Algorithm 1 on the estimated state (mean or SLO-quantile) -----
        bg_lam, bg_wsum, bg_ssum = _bg_moments(cst, est_endo, est_exo[None, :])
        if slo_q is None:
            t_dev, t_edge = _predict_vec(cst, lam_hat, est_bw,
                                         bg_lam, bg_wsum, bg_ssum)
        else:
            t_dev, t_edge = _predict_tail_vec(
                cst, lam_hat, est_bw, bg_lam, bg_wsum, bg_ssum,
                jnp.float64(slo_q), tail_method,
                _tail_grow_iters(slo_q, tail_method))
        # hysteresis compares against a PREVIOUS decision, which exists once
        # every cohort has decided at least once
        decided = _decide_vec(t_dev, t_edge, prev_choice, hysteresis, idx >= stagger)
        decide_now = cohort == jnp.mod(idx, stagger)
        choice = jnp.where(decide_now, decided, prev_choice).astype(jnp.int32)

        # -- the loop closes: decisions become next epoch's edge loads -----
        off = (choice[:, None] == jnp.arange(e_n)[None, :])
        own = jnp.where(off, lam_t[:, None], 0.0)
        local = jnp.sum(own, axis=0)
        endo_total = local if axis_name is None else jax.lax.psum(local, axis_name)
        report = endo_total[None, :] - own
        est_endo_next = jnp.where(
            first, report, bg_alpha * report + (1 - bg_alpha) * est_endo)

        out = (choice, endo_total, est_bw, lam_hat, est_endo, est_exo)
        return (est_bw, counts, est_endo_next, est_exo, choice), out

    init = (
        jnp.zeros(n),
        jnp.zeros((n, window)),
        jnp.zeros((n, e_n)),
        jnp.zeros(e_n),
        jnp.full(n, ON_DEVICE, dtype=jnp.int32),
    )
    inputs = (bw_true, lam_true, exo_true, n_req_all, jnp.arange(t_n))
    _, outs = jax.lax.scan(step, init, inputs)
    return outs


@partial(jax.jit, static_argnames=("window", "stagger", "slo_q", "tail_method"))
def _closed_loop_scan(cst, bw_true, lam_true, exo_true, n_req, *, window: int,
                      stagger: int, dt, bw_alpha, bg_alpha, hysteresis,
                      slo_q: float | None = None, tail_method: str = "asymptote"):
    """Decisions/estimates/loads of the adaptive policy over all T epochs —
    :func:`_scan_epochs` over the whole fleet as one block."""
    n = lam_true.shape[1]
    cohort = jnp.mod(jnp.arange(n), stagger)
    return _scan_epochs(
        cst, cst["lam_spec"], cohort, bw_true, lam_true, exo_true, n_req,
        window=window, stagger=stagger, dt=dt, bw_alpha=bw_alpha,
        bg_alpha=bg_alpha, hysteresis=hysteresis, slo_q=slo_q,
        tail_method=tail_method)


@partial(jax.jit,
         static_argnames=("window", "stagger", "shards", "slo_q", "tail_method"))
def _closed_loop_scan_blocked(cst, bw_true, lam_true, exo_true, n_req, *,
                              window: int, stagger: int, shards: int, dt,
                              bw_alpha, bg_alpha, hysteresis,
                              slo_q: float | None = None,
                              tail_method: str = "asymptote"):
    """Single-host sharded twin of :func:`_closed_loop_scan`: clients split
    into ``shards`` equal blocks, :func:`_scan_epochs` vmapped over the block
    axis with the endogenous total restored by ``psum`` over the vmap axis.
    Numerically identical math, the load sum merely re-associated — this is
    the fallback (and the exactness oracle) for the ``shard_map`` path when
    fewer than ``shards`` devices exist."""
    t_n, n = lam_true.shape
    nb = n // shards

    def blocks(a):  # (T, N, ...) -> (B, T, nb, ...) per-shard leading axis
        return jnp.moveaxis(a.reshape(t_n, shards, nb, *a.shape[2:]), 1, 0)

    cohort = jnp.mod(jnp.arange(n), stagger).reshape(shards, nb)
    lam_spec = cst["lam_spec"].reshape(shards, nb)
    run = partial(_scan_epochs, window=window, stagger=stagger, dt=dt,
                  bw_alpha=bw_alpha, bg_alpha=bg_alpha, hysteresis=hysteresis,
                  slo_q=slo_q, tail_method=tail_method, axis_name="shards")
    choice, endo_total, est_bw, lam_hat, est_endo, est_exo = jax.vmap(
        run, in_axes=(None, 0, 0, 0, 0, None, 0), axis_name="shards")(
        cst, lam_spec, cohort, blocks(bw_true), blocks(lam_true), exo_true,
        blocks(n_req))

    def merge(a):  # (B, T, nb, ...) -> (T, N, ...)
        return jnp.moveaxis(a, 0, 1).reshape(t_n, n, *a.shape[3:])

    # psum makes the shared outputs identical on every shard — keep shard 0
    return (merge(choice), endo_total[0], merge(est_bw), merge(lam_hat),
            merge(est_endo), est_exo[0])


def _closed_loop_scan_shardmap(cst, bw_true, lam_true, exo_true, n_req, *,
                               window: int, stagger: int, shards: int, dt,
                               bw_alpha, bg_alpha, hysteresis,
                               slo_q: float | None = None,
                               tail_method: str = "asymptote"):
    """Multi-device sharded twin of :func:`_closed_loop_scan`: client blocks
    placed one per device via ``shard_map``, with the endogenous-load total
    as the only cross-device collective per epoch. Same math as
    ``_closed_loop_scan_blocked`` (its single-host oracle) — the decision
    loop is embarrassingly parallel in clients given lagged load reports."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P

    n = lam_true.shape[1]
    mesh = Mesh(np.array(jax.devices()[:shards]), ("shards",))
    cohort = jnp.mod(jnp.arange(n), stagger)
    run = partial(_scan_epochs, window=window, stagger=stagger, dt=dt,
                  bw_alpha=bw_alpha, bg_alpha=bg_alpha, hysteresis=hysteresis,
                  slo_q=slo_q, tail_method=tail_method, axis_name="shards")
    cols = P(None, "shards")
    fn = shard_map(
        run, mesh=mesh,
        in_specs=(P(), P("shards"), P("shards"), cols, cols, P(), cols),
        out_specs=(cols, P(), cols, cols, P(None, "shards", None), P()),
        check_rep=False)
    return jax.jit(fn)(cst, cst["lam_spec"], cohort, bw_true, lam_true,
                       exo_true, n_req)


def _pad_clients(cst, bw_true, lam_true, n_req, pad: int):
    """Append ``pad`` inert dummy clients so the client axis splits evenly
    into shards. A dummy has TRUE arrival rate 0 — zero pre-drawn counts and
    zero contribution to every endogenous sum — so its presence is exact, not
    approximate; its spec-rate fallback is a harmless 1 rps (its decisions
    are computed and discarded). Padding happens AFTER Poisson sampling, so
    real clients' draws are untouched."""
    if pad == 0:
        return cst, bw_true, lam_true, n_req
    cst = dict(cst)
    cst["lam_spec"] = jnp.concatenate([cst["lam_spec"], jnp.ones(pad)])

    def padcols(a, fill):
        return jnp.concatenate(
            [a, jnp.full((a.shape[0], pad), fill, dtype=a.dtype)], axis=1)

    return cst, padcols(bw_true, 1.0), padcols(lam_true, 0.0), padcols(n_req, 0.0)


# ---------------------------------------------------------------------------
# true-condition scoring: the analytic_vec closed forms over all T*N epochs
# ---------------------------------------------------------------------------


def _truth_batch(cst, lam_true, bw_true, exo_true, choices):
    """The (T*N)-row ScenarioBatch-style column dict of every client-epoch
    under the TRUE conditions — the single construction both the mean and the
    SLO-quantile scoring tables consume, with the endogenous aggregate minus
    the client's own contribution at its chosen edge as background."""
    t_n, n = lam_true.shape
    e_n = exo_true.shape[1]
    off = (choices[..., None] == jnp.arange(e_n)[None, None, :])
    own = jnp.where(off, lam_true[..., None], 0.0)
    endo_total = jnp.sum(own, axis=1)  # (T, E)
    bg_other = endo_total[:, None, :] - own  # (T, N, E)
    bg_lam, bg_wsum, bg_ssum = _bg_moments(cst, bg_other, exo_true[:, None, :])
    b = t_n * n
    ones = jnp.ones((b, e_n))
    c = {
        "lam": lam_true.reshape(b),
        "req_bytes": jnp.full(b, cst["req_bytes"]),
        "res_bytes": jnp.full(b, cst["res_bytes"]),
        "bandwidth_Bps": bw_true.reshape(b),
        "return_results": jnp.full(b, cst["return_results"], dtype=bool),
        "dev_s": jnp.full(b, cst["dev_s"]),
        "dev_k": jnp.full(b, cst["dev_k"]),
        "dev_var": jnp.full(b, cst["dev_var"]),
        "dev_model": jnp.full(b, cst["dev_model"], dtype=jnp.int8),
        "edge_mask": jnp.ones((b, e_n), dtype=bool),
        "edge_s": ones * cst["edge_s"],
        "edge_k": ones * cst["edge_k"],
        "edge_var": ones * cst["edge_var"],
        "edge_model": (ones * cst["edge_model"]).astype(jnp.int8),
        "edge_bw": ones * cst["edge_bw"],
        "bg_lam": bg_lam.reshape(b, e_n),
        "bg_wsum": bg_wsum.reshape(b, e_n),
        "bg_ssum": bg_ssum.reshape(b, e_n),
    }
    return c, endo_total


@jax.jit
def _latency_tables_jit(cst, lam_true, bw_true, exo_true, choices):
    """(T, N) t_dev and (T, N, E) t_edge expected latency under the TRUE
    conditions — one batched ``_edge_latency_vec`` call over T*N rows."""
    t_n, n = lam_true.shape
    e_n = exo_true.shape[1]
    c, endo_total = _truth_batch(cst, lam_true, bw_true, exo_true, choices)
    t_dev = _device_latency_vec(c).reshape(t_n, n)
    t_edge = _edge_latency_vec(c).reshape(t_n, n, e_n)
    return t_dev, t_edge, endo_total


@partial(jax.jit, static_argnames=("tail_method", "grow_iters"))
def _latency_tables_tail_jit(cst, lam_true, bw_true, exo_true, choices, q,
                             *, tail_method: str, grow_iters: int | None = None):
    """The q-quantile twin of :func:`_latency_tables_jit` (analytic
    semantics: mixture mean as s_edge, exactly like ``_edge_tail_vec``)."""
    t_n, n = lam_true.shape
    e_n = exo_true.shape[1]
    c, endo_total = _truth_batch(cst, lam_true, bw_true, exo_true, choices)
    t_dev = _device_tail_vec(c, q, tail_method, grow_iters).reshape(t_n, n)
    t_edge = _edge_tail_vec(c, q, tail_method, grow_iters).reshape(t_n, n, e_n)
    return t_dev, t_edge, endo_total


def _score_assignment(
    cst_j, lam_true, bw_true, exo_true, choices,
    slo_quantile: float | None = None, tail_method: str = "asymptote",
) -> tuple[np.ndarray, np.ndarray]:
    """True-condition latency (mean, or the q-quantile when ``slo_quantile``
    is set) of every (epoch, client) under ``choices``."""
    args = (cst_j, jnp.asarray(lam_true), jnp.asarray(bw_true),
            jnp.asarray(exo_true), jnp.asarray(choices, dtype=jnp.int32))
    if slo_quantile is None:
        t_dev, t_edge, endo_total = _latency_tables_jit(*args)
    else:
        t_dev, t_edge, endo_total = _latency_tables_tail_jit(
            *args, jnp.float64(slo_quantile), tail_method=tail_method,
            grow_iters=_tail_grow_iters(slo_quantile, tail_method))
    stacked = jnp.concatenate([t_dev[:, :, None], t_edge], axis=2)
    idx = (jnp.asarray(choices, dtype=jnp.int32) + 1)[..., None]
    lat = jnp.take_along_axis(stacked, idx, axis=2)[..., 0]
    return np.asarray(lat), np.asarray(endo_total)


# ---------------------------------------------------------------------------
# results
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ClusterPolicyResult:
    """One policy's scored trajectory through the cluster replay."""

    name: str
    latencies_s: np.ndarray  # (T, N) true-condition latency per client-epoch
    choices: np.ndarray  # (T, N) per-epoch target (ON_DEVICE for local)
    edge_loads: np.ndarray  # (T, E) endogenous offloaded rate per edge
    saturated_epochs: int  # client-epochs clamped at the saturation penalty

    @property
    def mean_latency_s(self) -> float:
        return float(np.mean(self.latencies_s))

    @property
    def per_client_mean_s(self) -> np.ndarray:
        return self.latencies_s.mean(axis=0)

    @property
    def switches(self) -> int:
        """Total decision changes across all clients (flapping metric)."""
        return int(np.sum(self.choices[1:] != self.choices[:-1]))

    @property
    def offload_frac(self) -> float:
        return float(np.mean(self.choices >= 0))


@dataclass(frozen=True)
class ClusterResult:
    """Closed-loop replay outcome: per-policy scores + estimator trajectories."""

    spec: ClusterSpec
    traces: TraceBatch
    policies: dict[str, ClusterPolicyResult]
    est_bandwidth_Bps: np.ndarray  # (T, N) EWMA view the managers acted on
    est_arrival_rate: np.ndarray  # (T, N) sliding-window view
    est_endo_rate: np.ndarray  # (T, N, E) estimated other-client load per edge
    est_exo_rate: np.ndarray  # (T, E) estimated exogenous background

    @property
    def client_epochs(self) -> int:
        return int(self.traces.n_epochs * self.traces.n_clients)

    @property
    def adaptive_wins(self) -> bool:
        """§6 criterion: adaptive mean <= every static policy's mean."""
        a = self.policies["adaptive"].mean_latency_s
        return all(
            a <= p.mean_latency_s for n, p in self.policies.items() if n != "adaptive"
        )


def simulate_cluster(
    spec: ClusterSpec,
    traces: TraceBatch | Trace,
    *,
    policies: Sequence[str] = ("adaptive", "on_device", "edge[0]"),
    seed: int = 0,
    bw_alpha: float = 0.5,
    bg_alpha: float = 0.5,
    rate_window_epochs: int = 5,
    saturation_penalty_s: float = 30.0,
    hysteresis: float = 0.0,
    stagger: int = 1,
    shards: int = 1,
    slo_quantile: float | None = None,
    tail_method: str = "asymptote",
    tracer=None,
) -> ClusterResult:
    """Drive N clients through the trace batch with the loop closed.

    ``slo_quantile`` switches decisions AND true-condition scoring from
    expected latencies to the q-quantile of each path's closed-form sojourn
    distribution (:mod:`repro.fleet.tail_vec`, ``tail_method="asymptote"`` by
    default — the cheap dominant-singularity form that vectorises inside the
    ``lax.scan``).

    The adaptive policy runs the vectorized Algorithm-1 path per client per
    epoch inside one ``lax.scan`` (decisions feed the loads the estimators
    see next epoch); every policy — adaptive and the all-clients statics —
    is then scored under the TRUE conditions with one batched
    ``analytic_vec`` call over all T*N client-epochs, with the same bounded
    saturation penalty the scalar replay applies. ``stagger`` spreads
    clients over k staggered decision cohorts (see ``_scan_epochs``);
    leave it at 1 for fully synchronous control.

    ``shards`` splits the client axis into that many blocks for the decision
    scan — one block per device via ``shard_map`` when enough JAX devices
    exist, otherwise a vmapped single-host blocking. Decisions within an
    epoch depend only on lagged load reports, so the split is EXACT: the
    one cross-client quantity (the endogenous per-edge load total) is
    restored by a per-epoch ``psum``, and Poisson arrival counts are drawn
    once, before blocking, from the same seed-keyed chain the unsharded scan
    uses. Results match ``shards=1`` decision-for-decision (float outputs to
    reduction-reassociation tolerance). Clients are padded with inert
    zero-rate dummies when ``shards`` does not divide N."""
    if isinstance(traces, Trace):
        traces = TraceBatch.from_trace(traces, spec.n_clients)
    if traces.n_clients != spec.n_clients:
        raise ScenarioError(
            "traces", f"trace batch has {traces.n_clients} client columns but "
            f"the cluster has {spec.n_clients} clients")
    if traces.n_edges not in (0, spec.n_edges):
        raise ScenarioError(
            "traces", f"trace batch has {traces.n_edges} edge columns but the "
            f"cluster has {spec.n_edges} edges")
    if rate_window_epochs < 1:
        raise ValueError("rate_window_epochs must be >= 1")
    if not 1 <= stagger <= spec.n_clients:
        raise ValueError(f"stagger must be in [1, n_clients], got {stagger}")
    if not 1 <= shards <= spec.n_clients:
        raise ValueError(f"shards must be in [1, n_clients], got {shards}")
    if slo_quantile is not None and not 0.0 < slo_quantile < 1.0:
        raise ValueError(f"slo_quantile must be in (0, 1), got {slo_quantile}")
    if slo_quantile is not None:
        tail_method = resolve_tail_method(slo_quantile, tail_method)

    cst = _spec_arrays(spec)
    t_n, e_n = traces.n_epochs, spec.n_edges
    # a trace without edge columns means "no churn", not "no tenants" (cf.
    # replay): the spec's declared exogenous rates hold every epoch
    exo_true = traces.edge_bg_rate if traces.n_edges else \
        np.broadcast_to(cst["exo_rate"], (t_n, e_n)).copy()

    static_targets = {
        name: parse_policy(name, e_n) for name in policies if name != "adaptive"
    }

    with jax.experimental.enable_x64():
        cst_j = _as_jnp(cst)
        bw_j = jnp.asarray(traces.bandwidth_Bps)
        lam_j = jnp.asarray(traces.arrival_rate)
        exo_j = jnp.asarray(exo_true)

        results: dict[str, ClusterPolicyResult] = {}
        est_bw = est_lam = est_endo = est_exo = None
        if "adaptive" in policies:
            n_req = _poisson_counts(seed, lam_j, jnp.float64(traces.epoch_s))
            scan_kw = dict(
                window=int(rate_window_epochs),
                stagger=int(stagger),
                dt=jnp.float64(traces.epoch_s),
                bw_alpha=jnp.float64(bw_alpha),
                bg_alpha=jnp.float64(bg_alpha),
                hysteresis=jnp.float64(hysteresis),
                slo_q=slo_quantile,
                tail_method=tail_method,
            )
            if shards == 1:
                outs = _closed_loop_scan(cst_j, bw_j, lam_j, exo_j, n_req,
                                         **scan_kw)
            else:
                pad = (-spec.n_clients) % shards
                cst_p, bw_p, lam_p, nreq_p = _pad_clients(
                    cst_j, bw_j, lam_j, n_req, pad)
                scan = (_closed_loop_scan_shardmap
                        if len(jax.devices()) >= shards
                        else _closed_loop_scan_blocked)
                outs = scan(cst_p, bw_p, lam_p, exo_j, nreq_p,
                            shards=int(shards), **scan_kw)
                if pad:
                    keep = spec.n_clients
                    outs = (outs[0][:, :keep], outs[1], outs[2][:, :keep],
                            outs[3][:, :keep], outs[4][:, :keep], outs[5])
            choice, _loads, bw_e, lam_e, endo_e, exo_e = outs
            choices = np.asarray(choice)
            est_bw, est_lam = np.asarray(bw_e), np.asarray(lam_e)
            est_endo, est_exo = np.asarray(endo_e), np.asarray(exo_e)
            lat, loads = _score_assignment(cst_j, lam_j, bw_j, exo_j, choices,
                                           slo_quantile, tail_method)
            lat, saturated = clamp_saturation(lat, saturation_penalty_s)
            results["adaptive"] = ClusterPolicyResult(
                "adaptive", lat, choices, loads, saturated)
            if tracer is not None:
                # per-epoch fleet-aggregate decide spans (the scan itself is
                # jitted — spans are reconstructed from its outputs, stamped
                # on the trace clock)
                dt = float(traces.epoch_s)
                for t in range(t_n):
                    offloaded = int(np.sum(choices[t] >= 0))
                    tracer.span(
                        t=t * dt, dur=dt, name="decide", cat="decide",
                        track="cluster", epoch=t, offloaded=offloaded,
                        on_device=int(choices.shape[1] - offloaded),
                        mean_latency_s=float(np.mean(lat[t])))

        for name, tgt in static_targets.items():
            choices = np.full((t_n, spec.n_clients), tgt, dtype=np.int32)
            lat, loads = _score_assignment(cst_j, lam_j, bw_j, exo_j, choices,
                                           slo_quantile, tail_method)
            lat, saturated = clamp_saturation(lat, saturation_penalty_s)
            results[name] = ClusterPolicyResult(name, lat, choices, loads, saturated)

    t_shape = (t_n, spec.n_clients)
    return ClusterResult(
        spec=spec,
        traces=traces,
        policies=results,
        est_bandwidth_Bps=est_bw if est_bw is not None else np.zeros(t_shape),
        est_arrival_rate=est_lam if est_lam is not None else np.zeros(t_shape),
        est_endo_rate=est_endo if est_endo is not None else np.zeros((*t_shape, e_n)),
        est_exo_rate=est_exo if est_exo is not None else np.zeros((t_n, e_n)),
    )


# ---------------------------------------------------------------------------
# fixed-point equilibrium under constant conditions
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Equilibrium:
    """A fixed point of the decision -> load -> decision map.

    Carries the operating conditions it was solved under (per-client arrival
    rates and bandwidths, exogenous edge rates) so downstream consumers —
    the event-driven cross-check above all — evaluate exactly the system the
    fixed point belongs to, overrides included."""

    choices: np.ndarray  # (N,) per-client target at the fixed point
    iterations: int  # best-response evaluations performed
    converged: bool
    oscillation: bool  # True when damped switching had to engage
    latency_s: np.ndarray  # (N,) analytic per-client latency at the fixed point
    edge_loads: np.ndarray  # (E,) endogenous offloaded rate per edge
    rho_edges: np.ndarray  # (E,) processing utilization incl. exogenous load
    arrival_rates: np.ndarray  # (N,) the rates the fixed point was solved at
    bandwidth_Bps: np.ndarray  # (N,) per-client shared-path bandwidth used
    exo_rates: np.ndarray  # (E,) exogenous background rates used

    @property
    def mean_latency_s(self) -> float:
        return float(np.mean(self.latency_s))

    @property
    def max_latency_s(self) -> float:
        """Worst per-client latency at the fixed point — the number an SLO
        constrains. With ``slo_quantile`` set at solve time, ``latency_s``
        already holds per-client quantiles, so this is the fleet-wide
        worst-client q-quantile."""
        return float(np.max(self.latency_s))

    def meets_slo(self, slo_s: float) -> bool:
        """Feasibility predicate the provisioning solver bisects over: a
        converged fixed point whose worst client is within the budget.
        Non-convergence counts as infeasible — an oscillating assignment has
        no per-client latency anyone can promise."""
        return bool(self.converged and self.max_latency_s <= slo_s)

    def counts(self) -> dict[str, int]:
        """Clients per target, keyed like ``Decision.target_name``."""
        out = {"on_device": int(np.sum(self.choices == ON_DEVICE))}
        for j in range(len(self.edge_loads)):
            out[f"edge[{j}]"] = int(np.sum(self.choices == j))
        return out


def _equilibrium_tables(cst_j, lam, bw, exo, choices,
                        slo_quantile=None, tail_method="asymptote"):
    args = (cst_j, jnp.asarray(lam[None, :]), jnp.asarray(bw[None, :]),
            jnp.asarray(exo[None, :]), jnp.asarray(choices[None, :], dtype=jnp.int32))
    if slo_quantile is None:
        t_dev, t_edge, endo = _latency_tables_jit(*args)
    else:
        t_dev, t_edge, endo = _latency_tables_tail_jit(
            *args, jnp.float64(slo_quantile), tail_method=tail_method,
            grow_iters=_tail_grow_iters(slo_quantile, tail_method))
    return np.asarray(t_dev)[0], np.asarray(t_edge)[0], np.asarray(endo)[0]


def solve_equilibrium(
    spec: ClusterSpec,
    *,
    bandwidth_Bps: float | np.ndarray | None = None,
    arrival_rates: np.ndarray | None = None,
    exo_rates: np.ndarray | None = None,
    max_iter: int = 20,
    slo_quantile: float | None = None,
    tail_method: str = "asymptote",
) -> Equilibrium:
    """Iterate decisions -> loads to a fixed point under constant conditions.

    With ``slo_quantile`` set, clients best-respond on q-quantiles instead of
    means (an SLO-aware congestion game) and ``latency_s`` reports the
    per-client quantile at the fixed point.

    Clients best-respond synchronously with perfect information (the true
    closed forms, no estimator lag). When the decision vector revisits a
    previous state — the classic cycle where a crowd stampedes onto the
    cheapest edge, saturates it, and stampedes off again — the solver
    switches to *damped* tie-breaking: one sequential best-response sweep
    per iteration (clients move one at a time in index order against the
    live assignment, argmin ties broken deterministically toward on-device /
    the lowest edge index). Each damped move strictly lowers the mover's
    latency given the others, so the dynamics descend a congestion potential
    instead of oscillating; a sweep with no moves is the fixed point."""
    if slo_quantile is not None and not 0.0 < slo_quantile < 1.0:
        raise ValueError(f"slo_quantile must be in (0, 1), got {slo_quantile}")
    if slo_quantile is not None:
        tail_method = resolve_tail_method(slo_quantile, tail_method)
    n, e_n = spec.n_clients, spec.n_edges
    cst = _spec_arrays(spec)
    lam = np.asarray(arrival_rates, dtype=np.float64) if arrival_rates is not None \
        else spec.arrival_rates()
    if lam.shape != (n,):
        raise ScenarioError("arrival_rates", f"expected shape ({n},), got {lam.shape}")
    bw_default = float(np.asarray(spec.base.network.bandwidth_Bps))
    bw = np.broadcast_to(
        np.asarray(bw_default if bandwidth_Bps is None else bandwidth_Bps,
                   dtype=np.float64), (n,)).copy()
    exo = np.asarray(exo_rates, dtype=np.float64) if exo_rates is not None \
        else cst["exo_rate"].copy()
    if exo.shape != (e_n,):
        raise ScenarioError("exo_rates", f"expected shape ({e_n},), got {exo.shape}")

    with jax.experimental.enable_x64():
        cst_j = _as_jnp(cst)
        choices = np.full(n, ON_DEVICE, dtype=np.int32)
        seen = {choices.tobytes()}
        damped = False
        converged = False
        iterations = 0

        def tables(ch):
            t_dev, t_edge, _ = _equilibrium_tables(cst_j, lam, bw, exo, ch,
                                                   slo_quantile, tail_method)
            return np.concatenate([t_dev[:, None], t_edge], axis=1)

        stacked = tables(choices)
        while iterations < max_iter:
            iterations += 1
            if not damped:
                best = (np.argmin(stacked, axis=1) - 1).astype(np.int32)
                if np.array_equal(best, choices):
                    converged = True
                    break
                if best.tobytes() in seen:
                    damped = True  # oscillation: fall back to damped sweeps
                    continue
                seen.add(best.tobytes())
                choices = best
                stacked = tables(choices)
            else:
                # one sequential sweep: each client best-responds against the
                # LIVE assignment, so no two clients can stampede together
                moved = False
                for i in range(n):
                    b_i = int(np.argmin(stacked[i])) - 1
                    if b_i != choices[i]:
                        choices[i] = b_i
                        moved = True
                        stacked = tables(choices)
                if not moved:
                    converged = True
                    break

        # every exit path above leaves `stacked` consistent with `choices`
        latency = stacked[np.arange(n), choices + 1]
        off = choices[:, None] == np.arange(e_n)[None, :]
        endo = np.where(off, lam[:, None], 0.0).sum(axis=0)

        # processing utilization of the realized aggregate mixture per edge
        rates = np.concatenate([np.where(off, lam[:, None], 0.0), exo[None, :]], axis=0)
        means = np.concatenate([
            np.broadcast_to(cst["endo_mean"], (n, e_n)), cst["exo_mean"][None, :]
        ], axis=0)
        variances = np.concatenate([
            np.broadcast_to(cst["endo_var"], (n, e_n)), cst["exo_var"][None, :]
        ], axis=0)
        lam_tot, mean_mix, _ = mixture_moments(rates.T, means.T, variances.T)
        rho = lam_tot * mean_mix / cst["edge_k"]

    return Equilibrium(
        choices=choices,
        iterations=iterations,
        converged=converged,
        oscillation=damped,
        latency_s=latency,
        edge_loads=endo,
        rho_edges=rho,
        arrival_rates=lam,
        bandwidth_Bps=bw,
        exo_rates=exo,
    )


# ---------------------------------------------------------------------------
# event-driven cross-check (the PR 3 differential pattern, closed-loop)
# ---------------------------------------------------------------------------


def induced_scenario(
    spec: ClusterSpec,
    choices: np.ndarray,
    i: int,
    *,
    bandwidth_Bps: float | None = None,
    arrival_rates: np.ndarray | None = None,
    exo_rates: np.ndarray | None = None,
    allow_unstable: bool = False,
    name: str | None = None,
) -> Scenario:
    """Client ``i``'s open-loop equivalent of a cluster assignment.

    The other clients' realized offload streams become explicit background
    ``TenantStream``s on their chosen edges — one stream PER client, not one
    pre-aggregated lump, because each client owns its device NIC: lumping 47
    two-rps uplinks into one 94-rps stream would saturate the simulator's
    single per-stream NIC and silently throttle + smooth the load the edge
    sees (the analytic mixture is identical either way; the event-driven
    arrival process is not). The induced spec then runs through every
    open-loop path unchanged: ``analytic()``, ``simulate()``, the validation
    corpus. This is the bridge the closed-loop cross-check and the corpus's
    cluster regime are built on.

    ``exo_rates`` overrides the exogenous background: the spec's declared
    per-edge streams are replaced by one template stream at the given rate
    (the same re-expansion a churned trace gets). ``None`` keeps the spec's
    streams verbatim — preferable when they apply, because the simulator
    gives every background stream its own device NIC."""
    choices = np.asarray(choices, dtype=np.int64).reshape(spec.n_clients)
    lam = np.asarray(arrival_rates, dtype=np.float64) if arrival_rates is not None \
        else spec.arrival_rates()
    base = spec.base
    cst = _spec_arrays(spec)

    edges = []
    for j, e in enumerate(base.edges):
        if exo_rates is None:
            bg = e.background
        elif exo_rates[j] > 0:
            bg = (TenantStream(
                arrival_rate=float(exo_rates[j]),
                service_mean_s=float(cst["exo_mean"][j]),
                service_var=float(cst["exo_var"][j]),
                name="exogenous",
            ),)
        else:
            bg = ()
        for c in range(spec.n_clients):
            if c != i and choices[c] == j:
                bg = bg + (TenantStream(
                    arrival_rate=float(lam[c]),
                    service_mean_s=float(cst["endo_mean"][j]),
                    service_var=float(cst["endo_var"][j]),
                    name=f"cluster-client[{c}]",
                ),)
        edges.append(replace(e, background=bg))

    scn = Scenario(
        workload=replace(base.workload, arrival_rate=float(lam[i])),
        device=base.device,
        network=base.network if bandwidth_Bps is None
        else NetworkPath(float(bandwidth_Bps)),
        edges=tuple(edges),
        return_results=base.return_results,
        allow_unstable=allow_unstable,
        name=name or f"{spec.name}-client{i}",
    )
    return scn


def cross_check_equilibrium(
    spec: ClusterSpec,
    eq: Equilibrium,
    *,
    n: int = 120_000,
    seed: int = 0,
    rho_gate: float = 0.9,
) -> dict:
    """Validate the closed-loop analytic means against event-driven simulation.

    The operating point — per-client arrival rates and bandwidths, exogenous
    edge rates — comes from the :class:`Equilibrium` itself, so overrides
    passed to :func:`solve_equilibrium` are honoured and the simulated system
    is exactly the one the fixed point belongs to. Clients are grouped by
    (target, arrival rate, bandwidth) — within a group every client is
    statistically identical, so one representative simulation per group
    covers the fleet. On-device groups run through the batched Lindley
    simulator (``simulate_fleet``); offloading groups run the scalar
    shared-station multi-tenant simulator on the representative's *induced*
    scenario (the other offloaders as background streams), observing the
    representative's own stream. Groups whose bottleneck utilization exceeds
    ``rho_gate`` are reported but not gated, exactly like the PR 3 corpus."""
    lam = eq.arrival_rates
    # spec-default exogenous rates keep the spec's own per-stream background
    # (each stream gets its own NIC in the sim); overridden rates are
    # re-expanded through the template
    exo = None if np.array_equal(eq.exo_rates, _spec_arrays(spec)["exo_rate"]) \
        else eq.exo_rates
    choices = eq.choices

    def induced(i: int) -> Scenario:
        return induced_scenario(
            spec, choices, i,
            bandwidth_Bps=float(eq.bandwidth_Bps[i]),
            arrival_rates=lam,
            exo_rates=exo,
            allow_unstable=True,
        )

    groups: dict[tuple[int, float, float], list[int]] = {}
    for i in range(spec.n_clients):
        groups.setdefault(
            (int(choices[i]), float(lam[i]), float(eq.bandwidth_Bps[i])), []
        ).append(i)

    reports = []
    dev_members: list[tuple[tuple[int, float, float], int]] = []
    for key, members in groups.items():
        if key[0] == ON_DEVICE:
            dev_members.append((key, members[0]))

    # -- on-device groups: one batched Lindley launch -------------------------
    dev_means: dict[tuple[int, float, float], float] = {}
    if dev_members:
        scns = [induced(i) for _, i in dev_members]
        batch = ScenarioBatch.from_scenarios(scns)
        res = simulate_fleet(batch, "on_device", n=n, seed=seed)
        steady = res.latencies[:, steady_slice(n)]
        for row, (key, _i) in enumerate(dev_members):
            dev_means[key] = float(steady[row].mean())

    for key, members in sorted(groups.items()):
        tgt, lam_i, _bw_i = key
        rep = members[0]
        scn = induced(rep)
        strategy = "on_device" if tgt == ON_DEVICE else f"edge[{tgt}]"
        pred = float(np.asarray(scalar_analytic(scn).totals()[strategy]))
        if tgt == ON_DEVICE:
            rho = lam_i * scn.device.service_time_s / scn.device.parallelism_k
            sim_mean = dev_means[key]
        else:
            e = scn.edges[tgt]
            b = float(np.asarray(scn.network_for(e).bandwidth_Bps))
            agg = e.aggregate(scn.workload)
            rhos = [lam_i * scn.workload.req_bytes / b,
                    agg.arrival_rate * agg.service_mean_s / e.tier.parallelism_k]
            if scn.return_results and scn.workload.res_bytes > 0:
                rhos.append(agg.arrival_rate * scn.workload.res_bytes / b)
            rho = float(max(rhos))
            res = scn.simulate(strategy, n=n, seed=seed + rep)
            sim_mean = res.stream_mean(0) if res.stream_ids is not None else res.mean
        err_pct = abs(pred - sim_mean) / sim_mean * 100.0
        reports.append({
            "target": strategy,
            "n_clients": len(members),
            "arrival_rate": lam_i,
            "rho": rho,
            "analytic_s": pred,
            "sim_mean_s": sim_mean,
            "mape_pct": err_pct,
            "gated": bool(rho <= rho_gate),
        })

    gated = [r["mape_pct"] for r in reports if r["gated"]]
    return {
        "groups": reports,
        "n_groups": len(reports),
        "gated_mean_mape_pct": float(np.mean(gated)) if gated else None,
        "gated_max_mape_pct": float(np.max(gated)) if gated else None,
        "rho_gate": rho_gate,
        "config": {"n": n, "seed": seed},
    }
