"""Quantitative performance-crossover solvers.

The paper's headline capability: "precise, quantitative performance crossover
predictions". Each solver finds the operating-point value at which
T_edge(x) == T_dev(x) by bisection on the (continuous) latency difference,
returning the crossover plus which side prefers offloading.

These power Fig. 4 (bandwidth crossovers), Fig. 5b (request-rate crossover)
and Fig. 5c (tenancy crossover at m co-located apps).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import Callable, Sequence

import numpy as np

from .latency import NetworkPath, Tier, Workload, edge_offload_latency, on_device_latency
from .multitenant import TenantStream, multitenant_edge_latency

__all__ = [
    "Crossover",
    "solve_crossover",
    "smallest_true",
    "bandwidth_crossover",
    "arrival_rate_crossovers",
    "tenancy_crossover",
    "service_gap_bound",
]


@dataclass(frozen=True)
class Crossover:
    value: float | None  # crossover location, None if no sign change in range
    offload_wins_above: bool | None  # direction of advantage past the crossover
    lo: float
    hi: float


def _bisect(f: Callable[[float], float], lo: float, hi: float, iters: int = 200) -> float:
    flo = f(lo)
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        fm = f(mid)
        if fm == 0.0:
            return mid
        if (fm > 0) == (flo > 0):
            lo, flo = mid, fm
        else:
            hi = mid
    return 0.5 * (lo + hi)


def solve_crossover(
    diff: Callable[[float], float], lo: float, hi: float, *, samples: int = 256
) -> Crossover:
    """Find x in [lo, hi] where diff(x) = T_edge - T_dev changes sign.

    diff > 0 means on-device wins at x. Scans a grid for the first sign
    change (multiple crossovers can exist — Fig. 4b — the first is returned;
    use ``samples`` sweeps for the rest), then bisects. Grids spanning more
    than two decades are sampled geometrically so narrow low-end crossover
    regions (e.g. bandwidth sweeps) are not skipped.
    """
    if lo > 0 and hi / lo > 100:
        xs = np.geomspace(lo, hi, samples)
    else:
        xs = np.linspace(lo, hi, samples)
    vals = [diff(float(x)) for x in xs]
    # Sign changes are only trusted between grid-ADJACENT finite samples.
    # Filtering non-finite samples first and pairing the survivors used to
    # pair points on opposite sides of an instability pocket (a run of
    # inf/NaN between them): a sign flip across the pocket sent _bisect into
    # the non-finite region and reported a bogus "crossover" at a stability
    # boundary. A pocket now yields no pair, exactly like the vectorized
    # fleet_crossover scan.
    for (x0, v0), (x1, v1) in zip(zip(xs, vals), zip(xs[1:], vals[1:])):
        if not (math.isfinite(v0) and math.isfinite(v1)):
            continue
        if v0 == 0.0:
            return Crossover(float(x0), v1 < 0, lo, hi)
        if (v0 > 0) != (v1 > 0):
            x = _bisect(diff, float(x0), float(x1))
            return Crossover(x, v1 < 0, lo, hi)
    return Crossover(None, None, lo, hi)


def bandwidth_crossover(
    wl: Workload,
    dev: Tier,
    edge: Tier,
    *,
    lo_Bps: float = 1e4,
    hi_Bps: float = 1e9,
    **kw,
) -> Crossover:
    """Bandwidth above which offloading wins (Fig. 4). Monotone in B."""

    def diff(b: float) -> float:
        net = NetworkPath(bandwidth_Bps=b)
        te = float(edge_offload_latency(wl, edge, net, **kw))
        td = float(on_device_latency(wl, dev))
        return te - td

    return solve_crossover(diff, lo_Bps, hi_Bps)


def arrival_rate_crossovers(
    wl: Workload,
    dev: Tier,
    edge: Tier,
    net: NetworkPath,
    *,
    lo: float = 0.01,
    hi: float | None = None,
    samples: int = 512,
    **kw,
) -> list[Crossover]:
    """All request-rate crossovers in (lo, hi) — Fig. 5b shows these need not
    be unique (competing lambda effects, §3.3 'Practical takeaways')."""
    # stay strictly inside every queue's stability region
    caps = [
        dev.parallelism_k * dev.service_rate,
        edge.parallelism_k * edge.service_rate,
        float(net.nic_rate(wl.req_bytes)),
        float(net.nic_rate(wl.res_bytes)),
    ]
    hi = hi if hi is not None else 0.999 * min(caps)
    if hi <= lo:
        return []

    def diff(lam: float) -> float:
        w = replace(wl, arrival_rate=lam)
        return float(edge_offload_latency(w, edge, net, **kw)) - float(
            on_device_latency(w, dev)
        )

    out: list[Crossover] = []
    xs = np.linspace(lo, hi, samples)
    vals = [diff(float(x)) for x in xs]
    for (x0, v0), (x1, v1) in zip(zip(xs, vals), zip(xs[1:], vals[1:])):
        if math.isfinite(v0) and math.isfinite(v1) and (v0 > 0) != (v1 > 0):
            x = _bisect(diff, float(x0), float(x1))
            out.append(Crossover(x, v1 < 0, lo, hi))
    return out


def smallest_true(predicate: Callable[[int], bool], max_n: int) -> int | None:
    """Smallest m in [1, max_n] with ``predicate(m)`` True, assuming the
    predicate is monotone (False ... False True ... True).

    Exponential bracketing then integer bisection: O(log max_n) evaluations
    instead of a linear scan — the difference between ~20 and ~1024 closed-
    form evaluations per tenancy query. Returns None when the predicate is
    False everywhere in range.
    """
    if max_n < 1:
        return None
    if predicate(1):
        return 1
    lo = 1  # highest index known False
    hi = 1
    while hi < max_n:
        hi = min(hi * 2, max_n)
        if predicate(hi):
            break
        lo = hi
    else:
        return None
    while hi - lo > 1:
        mid = (lo + hi) // 2
        if predicate(mid):
            hi = mid
        else:
            lo = mid
    return hi


def tenancy_crossover(
    wl: Workload,
    dev: Tier,
    edge: Tier,
    net: NetworkPath,
    tenant_template: TenantStream,
    *,
    max_tenants: int = 1024,
) -> int | None:
    """Smallest number of co-located tenants m at which on-device wins (Fig. 5c).

    Tenants are homogeneous copies of ``tenant_template`` (the paper's §4.8
    setup: m InceptionV4 apps at 2 RPS each), so T_edge(m) is monotone
    increasing in m (more load on a fixed mixture; ``inf`` past saturation)
    and the scan is a bracket-and-bisect on the tenant count — pinned equal
    to the old linear scan by tests. Returns None if offloading wins even at
    ``max_tenants``.
    """
    td = float(on_device_latency(wl, dev))

    def on_device_wins(m: int) -> bool:
        streams: Sequence[TenantStream] = [tenant_template] * m
        return float(multitenant_edge_latency(wl, edge, net, streams)) > td

    return smallest_true(on_device_wins, max_tenants)


def service_gap_bound(kind: str, wl: Workload, dev: Tier, edge: Tier, net: NetworkPath, **kw):
    """The lemma RHS as a *bound on the service-time gap* s_dev - s_edge.

    kind in {"md1" (Lemma 3.1), "mm1" (Lemma 3.3), "mg1" (Lemma 3.2)}.
    On-device wins iff (s_dev - s_edge) < bound.
    """
    from . import latency as L

    if kind == "md1":
        return L.lemma31_rhs(wl, dev, edge, net)
    if kind == "mm1":
        return L.lemma33_rhs(wl, dev, edge, net)
    if kind == "mg1":
        return L.lemma32_rhs(wl, dev, edge, net, **kw)
    raise ValueError(kind)
