"""Runtime metric estimation (paper §4.2, §5.1).

The resource manager "periodically collects runtime metrics including network
bandwidth, edge server load, and request arrival rate". These estimators are
what it collects them with:

  * arrival rate lambda — sliding window over request timestamps (§4.2)
  * bandwidth B         — EWMA over iperf-style measurements (§4.2)
  * service rate mu / utilisation rho — completions per interval (§4.2)
  * service mean/variance — windowed moments (feeds the M/G/1 terms)
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

import numpy as np

__all__ = [
    "SlidingRateEstimator",
    "EwmaEstimator",
    "WindowedMoments",
    "UtilisationEstimator",
    "TelemetrySnapshot",
]


class SlidingRateEstimator:
    """lambda-hat = (#events in window) / window (paper: 'sliding window over
    incoming request timestamps')."""

    def __init__(self, window_s: float = 10.0):
        if window_s <= 0:
            raise ValueError("window must be positive")
        self.window_s = window_s
        self._times: deque[float] = deque()

    def record(self, t: float) -> None:
        t = float(t)
        if not np.isfinite(t):
            # a NaN/inf timestamp would poison every eviction comparison from
            # here on (NaN compares false, so nothing ever evicts) — reject
            # at the boundary instead of propagating a silently-wrong rate
            raise ValueError(f"timestamp must be finite, got {t!r}")
        if self._times and t < self._times[-1]:
            raise ValueError("timestamps must be non-decreasing")
        self._times.append(t)
        self._evict(t)

    def _evict(self, now: float) -> None:
        # strict <: an event exactly window_s old is still IN the window
        while self._times and self._times[0] < now - self.window_s:
            self._times.popleft()

    def rate(self, now: float | None = None) -> float:
        if not self._times:
            return 0.0
        now = self._times[-1] if now is None else float(now)
        if not np.isfinite(now):
            raise ValueError(f"now must be finite, got {now!r}")
        self._evict(now)
        if not self._times:
            return 0.0
        return len(self._times) / self.window_s


class EwmaEstimator:
    """Exponentially-weighted moving average (bandwidth, edge load reports)."""

    def __init__(self, alpha: float = 0.3, initial: float | None = None):
        if not 0 < alpha <= 1:
            raise ValueError("alpha in (0, 1]")
        if initial is not None and not np.isfinite(initial):
            raise ValueError(f"initial must be finite, got {initial!r}")
        self.alpha = alpha
        self._value = initial

    def update(self, x: float) -> float:
        x = float(x)
        if not np.isfinite(x):
            # one NaN observation would stick in the average forever (every
            # later blend stays NaN); an inf decays but lingers for many
            # epochs — a corrupted probe reading must fail at ingest
            raise ValueError(f"observation must be finite, got {x!r}")
        self._value = x if self._value is None else self.alpha * x + (1 - self.alpha) * self._value
        return self._value

    @property
    def value(self) -> float:
        if self._value is None:
            raise RuntimeError("no observations yet")
        return self._value

    @property
    def initialized(self) -> bool:
        return self._value is not None


class WindowedMoments:
    """Rolling mean/variance of the last n observations (service times)."""

    def __init__(self, maxlen: int = 512):
        self._buf: deque[float] = deque(maxlen=maxlen)

    def record(self, x: float) -> None:
        x = float(x)
        if not np.isfinite(x):
            # a single NaN/inf makes mean AND var non-finite for the next
            # maxlen observations — reject loudly at the boundary
            raise ValueError(f"observation must be finite, got {x!r}")
        self._buf.append(x)

    @property
    def count(self) -> int:
        return len(self._buf)

    @property
    def mean(self) -> float:
        if not self._buf:
            raise RuntimeError(
                "WindowedMoments.mean on an empty window — record() at least "
                "one observation (or check .count) before reading the mean")
        return float(np.mean(self._buf))

    @property
    def var(self) -> float:
        # one sample has no spread information: report 0.0 (a deterministic
        # M/G/1 prior) rather than the NaN ddof=1 would produce
        if len(self._buf) < 2:
            return 0.0
        return float(np.var(self._buf, ddof=1))


class UtilisationEstimator:
    """rho-hat = lambda-hat / mu-hat, mu-hat from completions per interval."""

    def __init__(self, window_s: float = 10.0):
        self.arrivals = SlidingRateEstimator(window_s)
        self.completions = SlidingRateEstimator(window_s)
        self.service = WindowedMoments()

    def on_arrival(self, t: float) -> None:
        self.arrivals.record(t)

    def on_completion(self, t: float, service_s: float) -> None:
        self.completions.record(t)
        self.service.record(service_s)

    def utilisation(self, now: float | None = None) -> float:
        lam = self.arrivals.rate(now)
        if self.service.count == 0:
            return 0.0
        return lam * self.service.mean


@dataclass(frozen=True)
class TelemetrySnapshot:
    """One epoch's inputs to Algorithm 1."""

    time_s: float
    lam_dev: float  # device arrival rate
    bandwidth_Bps: float  # measured B
    edge_arrival_rates: tuple[float, ...] = ()  # lambda_edge,E per server
    edge_service_means: tuple[float, ...] = ()  # aggregate s_edge,E
    edge_service_vars: tuple[float, ...] = ()  # Var[s_edge,E]
    extras: dict = field(default_factory=dict, compare=False)
