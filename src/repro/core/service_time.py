"""Service-time derivation (paper §3.2, §4.2).

The models take mean (and variance of) service time per tier as *input*. The
paper's menu: (a) empirical profiling, (b) a learned latency predictor, or —
our TPU adaptation — (c) an analytic roofline estimate from the compiled
step's FLOP/byte counts (DESIGN.md §5). This module implements all three plus
the paper's §4.1 procedure for fitting the effective parallelism k from
observed response-time-vs-rate scaling.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Sequence

import numpy as np

from .latency import ServiceModel, Tier, proc_wait

__all__ = [
    "ServiceEstimate",
    "from_profile",
    "from_roofline",
    "fit_parallelism",
]


@dataclass(frozen=True)
class ServiceEstimate:
    mean_s: float
    var_s: float
    n_samples: int
    source: str  # "profile" | "roofline" | "predictor"

    def as_tier(self, name: str, *, k: float = 1.0, model: ServiceModel = ServiceModel.DETERMINISTIC) -> Tier:
        return Tier(
            name=name,
            service_time_s=self.mean_s,
            parallelism_k=k,
            service_model=model,
            service_var=self.var_s,
        )


def from_profile(samples: Sequence[float]) -> ServiceEstimate:
    """Empirical profiling (paper: nvidia-smi per-process execution times /
    representative-input-set averages). Mean + unbiased variance."""
    arr = np.asarray(list(samples), dtype=np.float64)
    if arr.size == 0:
        raise ValueError("no profile samples")
    var = float(arr.var(ddof=1)) if arr.size > 1 else 0.0
    return ServiceEstimate(float(arr.mean()), var, int(arr.size), "profile")


def from_roofline(
    flops: float,
    hbm_bytes: float,
    *,
    peak_flops: float,
    hbm_bw: float,
    collective_s: float = 0.0,
    efficiency: float = 1.0,
) -> ServiceEstimate:
    """Analytic service time from the 3-term roofline of a compiled step.

    s = max(flops/peak, bytes/bw, collective_s) / efficiency

    This is the TPU-native replacement for GPU profiling: the dry-run's
    ``compiled.cost_analysis()`` supplies flops/bytes and the HLO collective
    parse supplies collective_s (see repro.perf.roofline). ``efficiency``
    discounts peak to a realistic fraction (MFU-style).
    """
    if peak_flops <= 0 or hbm_bw <= 0 or not 0 < efficiency <= 1:
        raise ValueError("invalid hardware constants")
    s = max(flops / peak_flops, hbm_bytes / hbm_bw, collective_s) / efficiency
    return ServiceEstimate(float(s), 0.0, 0, "roofline")


def fit_parallelism(
    lam_grid: Sequence[float],
    observed_mean_latency: Sequence[float],
    service_time_s: float,
    *,
    service_model: ServiceModel = ServiceModel.DETERMINISTIC,
    k_lo: float = 0.5,
    k_hi: float = 64.0,
    iters: int = 80,
) -> float:
    """Fit the effective parallelism k (paper §4.1).

    "We estimate k by empirically measuring how response time varies with
    request rate ... and identify a value of k that best captures the
    observed scaling behavior." Golden-section search over k minimising the
    squared error between the closed-form response time (wait(k) + s) and the
    observed means. k is continuous per §3.5.
    """
    lam = np.asarray(list(lam_grid), dtype=np.float64)
    obs = np.asarray(list(observed_mean_latency), dtype=np.float64)
    if lam.shape != obs.shape or lam.size == 0:
        raise ValueError("lam grid and observations must match and be non-empty")

    def loss(k: float) -> float:
        tier = Tier("fit", service_time_s, parallelism_k=k, service_model=service_model)
        pred = proc_wait(tier, lam) + service_time_s
        finite = np.isfinite(pred)
        if not finite.any():
            return np.inf
        # unstable grid points predicted as inf but observed finite -> big penalty
        penalty = float((~finite).sum()) * 1e6
        return float(np.mean((pred[finite] - obs[finite]) ** 2)) + penalty

    # golden-section search
    gr = (np.sqrt(5.0) - 1.0) / 2.0
    a, b = k_lo, k_hi
    c, d = b - gr * (b - a), a + gr * (b - a)
    fc, fd = loss(c), loss(d)
    for _ in range(iters):
        if fc < fd:
            b, d, fd = d, c, fc
            c = b - gr * (b - a)
            fc = loss(c)
        else:
            a, c, fc = c, d, fd
            d = a + gr * (b - a)
            fd = loss(d)
    return float(0.5 * (a + b))
