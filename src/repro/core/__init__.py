"""The paper's contribution: closed-form queueing latency models + the
model-driven adaptive offloading manager, plus the discrete-event simulator
used as the hardware-free validation testbed.
"""

from .latency import (
    LatencyBreakdown,
    NetworkPath,
    ServiceModel,
    Tier,
    Workload,
    edge_offload_latency,
    lemma31_rhs,
    lemma32_rhs,
    lemma33_rhs,
    offload_wins,
    on_device_latency,
    proc_wait,
)
from .crossover import (
    Crossover,
    arrival_rate_crossovers,
    bandwidth_crossover,
    service_gap_bound,
    solve_crossover,
    tenancy_crossover,
)
from .manager import (
    ON_DEVICE,
    AdaptiveOffloadManager,
    Decision,
    EdgeServerState,
    apply_decision_rule,
)
from .multitenant import (
    AggregateLoad,
    TenantStream,
    aggregate_streams,
    mixture_moments,
    multitenant_edge_latency,
)
from .scenario import (
    ClientClass,
    ClusterSpec,
    EdgeSpec,
    MeanFieldSpec,
    Scenario,
    ScenarioError,
    ScenarioPrediction,
    analytic,
    analytic_tail,
    crossovers,
    parse_strategy,
    simulate,
    tail_stations,
)
from .tail import (
    Station,
    mixture_station,
    mm1_sojourn_quantile,
    nic_station,
    proc_station,
    sojourn_cdf,
    sojourn_mean,
    sojourn_pdf,
    sojourn_quantile,
)
from .queueing import (
    QueueStats,
    gg1_wait_upper_bound,
    md1_wait,
    md1_wait_aggregated,
    mdk_wait_approx,
    mg1_wait,
    mm1_response,
    mm1_wait,
    mm1_wait_aggregated,
    mmk_wait_erlang,
    utilisation,
)
from .service_time import ServiceEstimate, fit_parallelism, from_profile, from_roofline
from .split import LayerProfile, SplitPlan, SplitPlanner, SplitPoint, split_latency
from .telemetry import (
    EwmaEstimator,
    SlidingRateEstimator,
    TelemetrySnapshot,
    UtilisationEstimator,
    WindowedMoments,
)

__all__ = [k for k in dir() if not k.startswith("_")]
