"""Closed-form sojourn-time *distributions* per station (tail-latency layer).

The paper's closed forms predict expected end-to-end latencies, but real
offloading policies are driven by SLO percentiles — "Selective Edge Computing
for Mobile Analytics" and the deadline-constrained offloading literature both
decide under hard per-request latency budgets, not means. This module extends
the repo's Eq. 1/2 decompositions from means to full sojourn distributions:

  * **M/M/1 (exact)** — the sojourn time of a stable M/M/1 queue is
    exponential with rate ``mu - lambda``, so every quantile is closed form:
    ``t_q = -ln(1 - q) / (mu - lambda)``.
  * **M/D/1 and M/G/1 (numeric)** — the waiting-time distribution is known
    only through its Pollaczek-Khinchine Laplace-Stieltjes transform
    ``W*(s) = (1 - rho) s / (s - lam (1 - S*(s)))``; we invert it numerically
    with the Abate-Whitt Euler-summation algorithm (discretisation error
    ~``e^-A`` ~ 1e-8) and find quantiles by bisection on the CDF.
  * **Exponential-tail asymptote (cheap fallback)** — the sojourn tail decays
    as ``P(T > t) ~ C e^{-eta t}`` where ``eta`` is the dominant singularity
    of the transform (the Cramer root ``lam (M_S(eta) - 1) = eta`` for the
    wait factor, the service pole for exponential service); ``C`` follows from
    the residue. Exact for M/M/1, asymptotically exact for high quantiles
    elsewhere, and cheap enough to vectorise inside jitted decision loops
    (:mod:`repro.fleet.tail_vec` is the batched twin).

Tandem composition (the Fig. 1 device NIC -> edge proc -> edge NIC path) uses
the **independence approximation**: the end-to-end sojourn transform is the
product of per-station sojourn transforms. This is exact for tandem ·/M/1
stations with Poisson input (Reich's theorem) and an approximation when an
M/D/1 or M/G/1 station sits in the middle; the validation harness quantifies
the error against the discrete-event simulator (tail-percentile gate:
analytic p99 within 10% of simulated ``percentile(99)`` at rho <= 0.9).

GENERAL service is represented by a two-moment gamma match in the transform
domain (the simulator draws lognormal): the mismatch is a quantified model
approximation, reported but not gated — exactly how the repo treats the
paper's k>1 aggregation.

Plain numpy/math only — this is the kernel layer; it must stay importable
without JAX (the vectorised twin lives in ``repro.fleet.tail_vec``).
"""

from __future__ import annotations

import math
from typing import NamedTuple, Sequence

import numpy as np

__all__ = [
    "KIND_DET",
    "KIND_EXP",
    "KIND_GAMMA",
    "Station",
    "nic_station",
    "proc_station",
    "mixture_station",
    "offload_stations",
    "mm1_sojourn_quantile",
    "resolve_tail_method",
    "euler_grow_iters",
    "sojourn_cdf",
    "sojourn_pdf",
    "sojourn_quantile",
    "sojourn_mean",
]

# service-distribution kind codes — intentionally identical to
# repro.fleet.batch.MODEL_CODES (det=0, exp=1, general/gamma=2) so batched
# columns feed the vectorized twin without remapping
KIND_DET, KIND_EXP, KIND_GAMMA = 0, 1, 2

# Abate-Whitt Euler-summation constants (A controls the discretisation error
# ~e^-A; N+M+1 transform evaluations per CDF point). The vectorized twin in
# repro.fleet.tail_vec MUST use the same constants — the <=1e-6 scalar-vs-vec
# agreement gate depends on both sides running the identical algorithm.
EULER_A = 18.4
EULER_N = 15
EULER_M = 11
_EULER_WEIGHTS = np.array(
    [math.comb(EULER_M, j) * 0.5**EULER_M for j in range(EULER_M + 1)]
)

# fixed iteration counts so scalar and vectorized quantiles are deterministic
# and bit-comparable; the scalar-vs-vec agreement gate (<= 1e-8 on euler
# quantiles) depends on both sides walking the IDENTICAL search trajectory,
# because the Euler-inverted CDF of near-deterministic mixtures carries
# oscillatory inversion noise (~e^-A amplitude, wavelength ~t/(N+M+1)) that
# can cross a quantile level more than once — two different-but-correct root
# finders may land on different crossings. The shared trajectory is:
# geometric bracket growth from 2*mean (doubling count derived from q — see
# ``euler_grow_iters``), EULER_BISECT_ITERS bisections to isolate a bracket
# narrower than the noise wavelength, then EULER_NEWTON_ITERS safeguarded
# Newton steps on the free Abate-Whitt density (midpoint fallback whenever
# the Newton candidate leaves the bracket).
EULER_BISECT_ITERS = 10
EULER_NEWTON_ITERS = 8
ETA_GROW_ITERS = 64
ETA_BISECT_ITERS = 80


def euler_grow_iters(q: float) -> int:
    """Bracket doublings from ``2 * mean`` guaranteed to cover the q-quantile.

    Markov's inequality gives ``P(T > t) <= mean/t``, so ``t_q <=
    mean/(1-q)`` and ``ceil(log2(1/(1-q)))`` doublings of ``2 * mean`` always
    reach past it; one extra doubling of margin keeps the ~e^-A inversion
    noise from faking ``F(hi) < q`` right at the boundary. A pure function of
    q (static at trace time) so the jitted batch path runs the same growth
    schedule as the scalar without data-dependent iteration counts.
    """
    if not 0.0 < q < 1.0:
        raise ValueError(f"quantile must be in (0, 1), got {q}")
    return max(0, math.ceil(math.log2(1.0 / (1.0 - q)))) + 1

# gamma service with cv^2 below this is evaluated as deterministic: the exact
# transform needs shape * log(1 + theta/shape-ish) with shape = 1/cv^2, which
# cancels catastrophically once cv^2 reaches float-residue scale (mixture
# variances of homogeneous streams come out as ~1e-19, not exactly 0)
GAMMA_DET_CV2 = 1e-12

# the Euler-inverted CDF is only accurate to ~e^-A ~ 1e-8 absolute, so
# quantiles with 1-q inside two decades of that noise floor would bisect
# against inversion noise and silently underestimate. Past this q the
# numeric method hands off to the exponential-tail asymptote — which is
# asymptotically EXACT in precisely that q -> 1 regime.
EULER_Q_MAX = 1.0 - 1e-6


def resolve_tail_method(q: float, method: str) -> str:
    """The method actually used for quantile q (euler -> asymptote beyond
    ``EULER_Q_MAX``). Exposed so the jitted batch/cluster paths — where the
    switch must happen before tracing — resolve it identically."""
    if method == "euler" and q > EULER_Q_MAX:
        return "asymptote"
    return method


def _gamma_is_det(mean: float, var: float) -> bool:
    return var <= GAMMA_DET_CV2 * mean * mean


class Station(NamedTuple):
    """One FCFS station of a tandem path, in transform-ready form.

    ``lam`` is the Poisson arrival rate. The *wait* service distribution
    (``wkind``/``wmean``/``wvar``) parameterises the P-K waiting-time
    transform — it carries the paper's k*mu aggregation, i.e. mean ``s/k``
    with the variance kept unscaled, exactly matching ``latency.proc_wait``'s
    mean formulas. The *full* service distribution (``fkind``/``fmean``/
    ``fvar``) is what the job actually experiences after its wait (full
    ``s``), so ``E[sojourn] = E[W_aggregated] + s`` reproduces the repo's
    mean model term for term. A station with ``fmean == 0`` and ``lam*wmean
    == 0`` is inert (transform factor 1) — used for disabled return paths.
    """

    lam: float
    wkind: int
    wmean: float
    wvar: float
    fkind: int
    fmean: float
    fvar: float


# ---------------------------------------------------------------------------
# station constructors (the vocabulary scenario/manager/policy compose with)
# ---------------------------------------------------------------------------


def nic_station(lam: float, payload_bytes: float, bandwidth_Bps: float) -> Station:
    """The paper's M/M/1 NIC: exponential service with mean D/B.

    ``payload_bytes == 0`` (a disabled transfer leg) degenerates to an inert
    station, mirroring how the mean model drops the term.
    """
    mean = payload_bytes / bandwidth_Bps if payload_bytes > 0 else 0.0
    return Station(lam, KIND_EXP, mean, 0.0, KIND_EXP, mean, 0.0)


def proc_station(lam: float, kind: int, service_s: float, service_var: float,
                 k: float = 1.0) -> Station:
    """A processing station dispatched on the tier's service model.

    DETERMINISTIC -> M/D/1 on the aggregated rate; EXPONENTIAL -> M/M/1 on
    k*mu; GENERAL -> M/G/1 via a two-moment gamma match (mean ``s/k``,
    variance kept unscaled — the exact aggregation ``mg1_wait`` uses).
    """
    return Station(lam, kind, service_s / k, service_var, kind, service_s, service_var)


def mixture_station(lam_tot: float, mean_mix: float, var_mix: float,
                    k: float = 1.0) -> Station:
    """The §3.4 multi-tenant aggregate as an M/G/1 station (Lemma 3.2):
    gamma-matched mixture moments for both the wait and the full service —
    the distributional twin of ``multitenant_edge_latency``'s
    re-parameterisation (``s_edge`` = mixture mean)."""
    return Station(lam_tot, KIND_GAMMA, mean_mix / k, var_mix,
                   KIND_GAMMA, mean_mix, var_mix)


def offload_stations(
    lam: float,
    req_bytes: float,
    res_bytes: float,
    bandwidth_Bps: float,
    proc: Station,
    *,
    return_results: bool = True,
) -> tuple[Station, Station, Station]:
    """THE Fig. 1 offload tandem: device NIC -> ``proc`` -> return NIC.

    ``lam`` is the workload's own rate (the device NIC sees only this
    stream); the return NIC carries everything the edge serves, i.e.
    ``proc.lam`` (own rate on a dedicated edge, the aggregate on a shared
    one). Every tail consumer — ``scenario.tail_stations``, the quantile
    crossover solvers, the replay's true-condition scoring — composes through
    here, so the station stack can never drift between them.
    """
    res = res_bytes if return_results else 0.0
    return (
        nic_station(lam, req_bytes, bandwidth_Bps),
        proc,
        nic_station(proc.lam, res, bandwidth_Bps),
    )


# ---------------------------------------------------------------------------
# transform-domain primitives
# ---------------------------------------------------------------------------


def _service_lst(kind: int, mean: float, var: float, theta: np.ndarray) -> np.ndarray:
    """Laplace-Stieltjes transform E[e^{-theta S}] of one service distribution
    (theta may be a complex array). mean == 0 means a degenerate zero service
    (factor 1)."""
    if mean <= 0.0:
        return np.ones_like(theta)
    if kind == KIND_DET:
        return np.exp(-theta * mean)
    if kind == KIND_EXP:
        return 1.0 / (1.0 + theta * mean)
    if _gamma_is_det(mean, var):  # near-zero-variance gamma -> deterministic
        return np.exp(-theta * mean)
    shape = mean * mean / var
    scale = var / mean
    return np.exp(-shape * np.log(1.0 + theta * scale))


def _service_mgf(kind: int, mean: float, var: float, eta: float) -> float:
    """Real moment generating function M_S(eta) = E[e^{eta S}] (eta below the
    distribution's divergence point). Formulas (not the complex LST at -eta)
    so the vectorized twin can reproduce every bit of the asymptote path."""
    if mean <= 0.0:
        return 1.0
    if kind == KIND_DET or (kind == KIND_GAMMA and _gamma_is_det(mean, var)):
        return math.exp(eta * mean)
    if kind == KIND_EXP:
        return 1.0 / (1.0 - eta * mean)
    shape = mean * mean / var
    scale = var / mean
    return math.exp(-shape * math.log(1.0 - eta * scale))


def _service_mgf_prime(kind: int, mean: float, var: float, eta: float) -> float:
    """M_S'(eta) = E[S e^{eta S}]."""
    if mean <= 0.0:
        return 0.0
    if kind == KIND_DET or (kind == KIND_GAMMA and _gamma_is_det(mean, var)):
        return mean * math.exp(eta * mean)
    if kind == KIND_EXP:
        return mean / (1.0 - eta * mean) ** 2
    shape = mean * mean / var
    scale = var / mean
    return mean * (1.0 - eta * scale) ** (-shape - 1.0)


def _service_divergence(kind: int, mean: float, var: float) -> float:
    """The MGF's divergence point (sup of eta with finite M_S(eta))."""
    if mean <= 0.0 or kind == KIND_DET or (kind == KIND_GAMMA and _gamma_is_det(mean, var)):
        return math.inf
    if kind == KIND_EXP:
        return 1.0 / mean
    return mean / var


def _implied_var(kind: int, mean: float, var: float) -> float:
    """Var[S] the kind implies (exp carries mean^2, det zero) — the same
    convention as ``scenario.implied_service_var``."""
    if kind == KIND_EXP:
        return mean * mean
    if kind == KIND_GAMMA:
        return var
    return 0.0


def _station_lst(st: Station, theta: np.ndarray) -> np.ndarray:
    """Sojourn transform of one station: T*(theta) = W*(theta) Sf*(theta),
    with W* the Pollaczek-Khinchine waiting-time transform."""
    rho = st.lam * st.wmean
    f = _service_lst(st.fkind, st.fmean, st.fvar, theta)
    if st.lam <= 0.0 or rho <= 0.0:
        return f
    sw = _service_lst(st.wkind, st.wmean, st.wvar, theta)
    w = (1.0 - rho) * theta / (theta - st.lam * (1.0 - sw))
    return w * f


def _total_lst(stations: Sequence[Station], theta: np.ndarray) -> np.ndarray:
    """End-to-end sojourn transform under the tandem independence
    approximation (exact for ·/M/1 tandems with Poisson input)."""
    out = np.ones_like(theta)
    for st in stations:
        out = out * _station_lst(st, theta)
    return out


def _wait_mean(st: Station) -> float:
    """E[W] of one station via P-K on the aggregated moments (identical to
    ``latency.proc_wait`` / ``queueing.mg1_wait`` on the same inputs)."""
    rho = st.lam * st.wmean
    if st.lam <= 0.0 or rho <= 0.0:
        return 0.0
    if rho >= 1.0:
        return math.inf
    v = _implied_var(st.wkind, st.wmean, st.wvar)
    return st.lam * (st.wmean**2 + v) / (2.0 * (1.0 - rho))


def sojourn_mean(stations: Sequence[Station]) -> float:
    """Sum of per-station E[W] + full service means — equals the repo's
    closed-form mean total on the same path (tested)."""
    return float(sum(_wait_mean(st) + st.fmean for st in stations))


def _unstable(stations: Sequence[Station]) -> bool:
    return any(st.lam * st.wmean >= 1.0 for st in stations)


# ---------------------------------------------------------------------------
# numeric CDF (Abate-Whitt Euler summation) + quantile by bisection
# ---------------------------------------------------------------------------


def _cdf_pdf(stations: Sequence[Station], t_arr: np.ndarray):
    """(F(t), f(t)) of the composed sojourn from ONE set of transform
    evaluations: Abate-Whitt inverts any transform on the same contour
    ``theta_k = (A + 2 pi i k) / (2t)`` — the CDF's transform is
    ``T*(theta)/theta``, the density's is ``T*(theta)`` itself. Sharing the
    ``T*`` products is what makes the quantile search's Newton derivative
    free. The density is clipped at 0 (inversion noise dips slightly negative
    in flat regions; the safeguard treats zero as "fall back to bisection").
    """
    ks = np.arange(EULER_N + EULER_M + 1)
    theta = (EULER_A + 2j * np.pi * ks) / (2.0 * t_arr[..., None])
    vals = _total_lst(stations, theta)
    sign = np.where(ks == 0, 0.5, 1.0) * ((-1.0) ** ks)
    window = slice(EULER_N, EULER_N + EULER_M + 1)
    scale = np.exp(EULER_A / 2.0) / t_arr
    cdf_part = np.cumsum(sign * (vals / theta).real, axis=-1)
    pdf_part = np.cumsum(sign * vals.real, axis=-1)
    cdf = np.clip(scale * (cdf_part[..., window] @ _EULER_WEIGHTS), 0.0, 1.0)
    pdf = np.maximum(scale * (pdf_part[..., window] @ _EULER_WEIGHTS), 0.0)
    return cdf, pdf


def sojourn_cdf(stations: Sequence[Station], t) -> np.ndarray:
    """P(T <= t) of the composed sojourn, by numeric transform inversion.

    Vectorised over ``t`` (> 0). Accuracy ~1e-8 absolute away from atoms of
    the distribution; at an atom (e.g. ``t == s`` for a lightly loaded
    deterministic station) the Euler sum converges to the jump midpoint.
    """
    t_arr = np.atleast_1d(np.asarray(t, dtype=np.float64))
    ks = np.arange(EULER_N + EULER_M + 1)
    theta = (EULER_A + 2j * np.pi * ks) / (2.0 * t_arr[..., None])
    vals = _total_lst(stations, theta) / theta  # transform of the CDF
    terms = np.where(ks == 0, 0.5, 1.0) * ((-1.0) ** ks) * vals.real
    partial = np.cumsum(terms, axis=-1)
    acc = partial[..., EULER_N : EULER_N + EULER_M + 1] @ _EULER_WEIGHTS
    out = np.clip(np.exp(EULER_A / 2.0) / t_arr * acc, 0.0, 1.0)
    return out if np.ndim(t) else out[0]


def sojourn_pdf(stations: Sequence[Station], t) -> np.ndarray:
    """Density f(t) of the composed sojourn by the same Euler inversion
    (transform ``T*(theta)`` bare instead of ``T*(theta)/theta``), clipped at
    0. Smoothed at atoms — an M/D/1 jump shows up as a steep finite peak of
    width ~``t/(N+M+1)``, not a delta.
    """
    t_arr = np.atleast_1d(np.asarray(t, dtype=np.float64))
    pdf = _cdf_pdf(stations, t_arr)[1]
    return pdf if np.ndim(t) else pdf[0]


def mm1_sojourn_quantile(lam: float, mu: float, q: float) -> float:
    """Exact M/M/1 sojourn quantile: t_q = -ln(1 - q) / (mu - lambda).

    The sojourn time of a stable M/M/1 queue is exponential with rate
    ``mu - lambda`` (PASTA + the geometric queue-length distribution), so the
    whole distribution — not just the mean 1/(mu - lambda) — is closed form.
    """
    if not 0.0 < q < 1.0:
        raise ValueError(f"quantile must be in (0, 1), got {q}")
    if mu <= 0 or lam < 0 or lam >= mu:
        return math.inf
    return -math.log1p(-q) / (mu - lam)


def _quantile_euler(stations: Sequence[Station], q: float) -> float:
    """Quantile of the Euler-inverted CDF along the shared search trajectory.

    Three phases, all with iteration counts fixed by module constants so the
    vectorized twin (``repro.fleet.euler_vec``) can replay the identical
    evaluation sequence: (1) geometric growth from ``2 * mean`` — anchors the
    bracket to the *leftmost* octave where the CDF reaches q, which matters
    because the inversion noise of near-deterministic mixtures can cross q
    more than once; (2) ``EULER_BISECT_ITERS`` bisections, shrinking the
    bracket below the noise wavelength ~``t/(N+M+1)`` so exactly one crossing
    remains inside; (3) ``EULER_NEWTON_ITERS`` safeguarded Newton steps using
    the free density from ``_cdf_pdf``, falling back to the midpoint whenever
    the Newton candidate leaves the bracket (so the bracket still halves and
    the worst case stays a bisection).
    """
    mean = sojourn_mean(stations)
    if not math.isfinite(mean):
        return math.inf
    hi0 = np.asarray(max(2.0 * mean, 1e-12))
    hi = hi0
    for _ in range(euler_grow_iters(q)):
        hi = np.where(sojourn_cdf(stations, hi) < q, hi * 2.0, hi)
    # if the bracket grew, the last doubled-from point hi/2 is a known
    # below-q evaluation — one free bisection
    lo = np.where(hi > hi0, 0.5 * hi, 0.0)
    for _ in range(EULER_BISECT_ITERS):
        mid = 0.5 * (lo + hi)
        below = sojourn_cdf(stations, mid) < q
        lo = np.where(below, mid, lo)
        hi = np.where(below, hi, mid)
    t = 0.5 * (lo + hi)
    for _ in range(EULER_NEWTON_ITERS):
        cdf, pdf = _cdf_pdf(stations, np.atleast_1d(t))
        cdf, pdf = cdf[0], pdf[0]
        below = cdf < q
        lo = np.where(below, t, lo)
        hi = np.where(below, hi, t)
        newton = t - (cdf - q) / np.where(pdf > 0.0, pdf, 1.0)
        ok = (pdf > 0.0) & (newton > lo) & (newton < hi)
        t = np.where(ok, newton, 0.5 * (lo + hi))
    return float(np.clip(t, lo, hi))


# ---------------------------------------------------------------------------
# exponential-tail asymptote (dominant-singularity decay rate)
# ---------------------------------------------------------------------------


def _wait_pole(st: Station) -> float:
    """The Cramer decay rate of the waiting-time tail: the unique positive
    root of ``lam (M_Sw(eta) - 1) = eta`` (below the MGF's divergence point).

    Exponential wait-service has the closed-form root ``(1 - rho)/wmean``
    (which is why the asymptote is exact for M/M/1); deterministic and gamma
    roots are found by geometric bracket growth + fixed-iteration bisection —
    the same procedure, with the same constants, as the vectorized twin.
    """
    rho = st.lam * st.wmean
    if st.lam <= 0.0 or rho <= 0.0:
        return math.inf
    if rho >= 1.0:
        return 0.0
    if st.wkind == KIND_EXP:
        return (1.0 - rho) / st.wmean

    def g(eta: float) -> float:
        return st.lam * (_service_mgf(st.wkind, st.wmean, st.wvar, eta) - 1.0) - eta

    div = _service_divergence(st.wkind, st.wmean, st.wvar)
    # the root is at least the exponential-service root whenever the service
    # is NOT more variable than exponential (MGF ordering); grow from there
    hi = (1.0 - rho) / st.wmean
    cap = min(div * (1.0 - 1e-12), 700.0 / st.wmean)
    hi = min(hi, cap)
    for _ in range(ETA_GROW_ITERS):
        hi = min(hi * 2.0, cap) if g(hi) <= 0.0 else hi
    lo = 0.0
    for _ in range(ETA_BISECT_ITERS):
        mid = 0.5 * (lo + hi)
        if g(mid) <= 0.0:
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)


def _wait_mgf(st: Station, eta: float) -> float:
    """E[e^{eta W}] = W*(-eta), finite only below the station's wait pole."""
    rho = st.lam * st.wmean
    if st.lam <= 0.0 or rho <= 0.0:
        return 1.0
    g = st.lam * (_service_mgf(st.wkind, st.wmean, st.wvar, eta) - 1.0) - eta
    return (1.0 - rho) * (-eta) / g


def _station_lst_real(st: Station, eta: float) -> float:
    """T*(-eta) on the real axis (the station's sojourn MGF at eta), finite
    only below the station's own dominant singularity."""
    return _wait_mgf(st, eta) * _service_mgf(st.fkind, st.fmean, st.fvar, eta)


def _quantile_asymptote(stations: Sequence[Station], q: float) -> float:
    """Quantile from ``P(T > t) ~ (r/eta) e^{-eta t}``.

    ``eta`` is the smallest candidate decay rate across every factor of the
    product transform — each station's wait pole plus the service pole of
    exponential full service — and ``r`` is the residue of the product at
    that (simple) pole: the dominant factor's local residue times every other
    factor evaluated at ``-eta``. Exact for a single M/M/1 station;
    increasingly accurate as q -> 1 elsewhere. Known limits: gamma service
    branch points are not simple poles (their tails are lighter than the
    matching wait pole whenever the station queues, so they are excluded),
    and near-coincident poles inflate ``r`` — the numeric Euler method is the
    accuracy-first default.
    """
    # candidate order (all wait poles, then all exp-service poles) matches the
    # vectorized twin's stacking so exact ties break identically
    cands: list[tuple[float, int, bool]] = [
        (_wait_pole(st), i, True) for i, st in enumerate(stations)
    ] + [
        (1.0 / st.fmean if st.fkind == KIND_EXP and st.fmean > 0.0 else math.inf,
         i, False)
        for i, st in enumerate(stations)
    ]
    eta, j, is_wait = min(cands, key=lambda c: c[0])
    if not math.isfinite(eta):  # no queueing anywhere and no exp service
        return sum(st.fmean for st in stations)
    st_j = stations[j]
    if is_wait:
        rho = st_j.lam * st_j.wmean
        denom = st_j.lam * _service_mgf_prime(st_j.wkind, st_j.wmean, st_j.wvar, eta) - 1.0
        r = (1.0 - rho) * eta / denom
        r *= _service_mgf(st_j.fkind, st_j.fmean, st_j.fvar, eta)
    else:
        r = (1.0 / st_j.fmean) * _wait_mgf(st_j, eta)
    for i, st in enumerate(stations):
        if i != j:
            r *= _station_lst_real(st, eta)
    if not (r > 0.0 and math.isfinite(r)):
        return math.inf
    return max(math.log(r / (eta * (1.0 - q))) / eta, 0.0)


# ---------------------------------------------------------------------------
# public entry point
# ---------------------------------------------------------------------------


def sojourn_quantile(
    stations: Sequence[Station], q: float, *, method: str = "euler"
) -> float:
    """The q-quantile (q in (0, 1)) of the composed end-to-end sojourn time.

    ``method="euler"`` (default) inverts the exact product transform with
    Abate-Whitt Euler summation; ``method="asymptote"`` uses the cheap
    dominant-singularity exponential tail (the form the jitted fleet/cluster
    paths vectorise). A single M/M/1 station short-circuits to the exact
    closed form under both methods. Unstable stations (rho >= 1) yield
    ``inf``, exactly as the mean closed forms do.
    """
    if not 0.0 < q < 1.0:
        raise ValueError(f"quantile must be in (0, 1), got {q}")
    if method not in ("euler", "asymptote"):
        raise ValueError(f"unknown method {method!r} (known: euler, asymptote)")
    method = resolve_tail_method(q, method)
    stations = [st for st in stations]
    if not stations:
        raise ValueError("need at least one station")
    if _unstable(stations):
        return math.inf
    if (
        len(stations) == 1
        and stations[0].wkind == KIND_EXP
        and stations[0].fkind == KIND_EXP
        and stations[0].wmean == stations[0].fmean
        and stations[0].fmean > 0.0
    ):
        return mm1_sojourn_quantile(stations[0].lam, 1.0 / stations[0].fmean, q)
    if method == "asymptote":
        return _quantile_asymptote(stations, q)
    return _quantile_euler(stations, q)
