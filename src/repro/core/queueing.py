"""Closed-form queueing primitives used by the paper's latency models.

Every function transcribes an equation from the paper ("To Offload or Not To
Offload", CS.DC 2025) and cites it. All times are in seconds, all rates in
requests/second unless noted. Functions are plain-float (math) so they can be
called from schedulers at request granularity without JAX tracing overhead;
vectorised JAX variants live in :mod:`repro.core.latency` where batch
evaluation matters.

Stability convention: a queue is *stable* iff utilisation rho = lambda/mu < 1.
For unstable inputs the closed forms diverge; we return ``math.inf`` instead
of raising so the adaptive manager (Algorithm 1) can treat saturated options
as infinitely bad and never pick them.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

__all__ = [
    "mm1_wait",
    "mm1_response",
    "md1_wait",
    "md1_wait_aggregated",
    "mm1_wait_aggregated",
    "mg1_wait",
    "gg1_wait_upper_bound",
    "mmk_wait_erlang",
    "mdk_wait_approx",
    "utilisation",
    "QueueStats",
]

_EPS = 1e-12


def utilisation(lam: float, mu: float, k: float = 1.0) -> float:
    """rho = lambda / (k mu). Paper §3.4 ("aggregate utilization")."""
    if mu <= 0 or k <= 0:
        return math.inf
    return lam / (k * mu)


def _unstable(lam: float, effective_mu: float) -> bool:
    return lam < 0 or effective_mu <= 0 or lam >= effective_mu - _EPS


def mm1_wait(lam: float, mu: float) -> float:
    """Expected M/M/1/FCFS queueing delay (paper Eq. 7).

    E[w] = 1/(mu - lambda) - 1/mu

    Used by the paper for network interfaces (single NIC controller) and —
    via the aggregated-rate reduction (Lemma 3.3) — for variable-service
    workloads (RNN / LLM).
    """
    if _unstable(lam, mu):
        return math.inf
    if lam == 0.0:
        return 0.0
    return 1.0 / (mu - lam) - 1.0 / mu


def mm1_response(lam: float, mu: float) -> float:
    """Expected M/M/1 response (sojourn) time = wait + service = 1/(mu-lambda)."""
    if _unstable(lam, mu):
        return math.inf
    return 1.0 / (mu - lam)


def md1_wait(lam: float, mu: float) -> float:
    """Expected M/D/1/FCFS queueing delay via the P-K formula (paper Eq. 6 with k=1).

    E[w] = 1/2 (1/(mu - lambda) - 1/mu)

    Deterministic service — the paper's model for DNN inference on
    accelerators (service time is constant because the op count per request
    is constant; their citation [27]).
    """
    if _unstable(lam, mu):
        return math.inf
    if lam == 0.0:
        return 0.0
    return 0.5 * (1.0 / (mu - lam) - 1.0 / mu)


def md1_wait_aggregated(lam: float, mu: float, k: float) -> float:
    """Paper Eq. 6: M/D/k reduced to M/D/1 with aggregated rate k*mu.

    E[w] = 1/2 (1/(k mu - lambda) - 1/(k mu))

    The paper argues (citing [48, 49]) that accelerators with small, fine-
    grained parallelism k are well-approximated by aggregating the service
    rate; k may be non-integer ("continuous multiplier", §3.5).
    """
    return md1_wait(lam, k * mu)


def mm1_wait_aggregated(lam: float, mu: float, k: float) -> float:
    """Lemma 3.3's building block: M/M/1 wait with aggregated rate k*mu.

    E[w] = 1/(k mu - lambda) - 1/(k mu)
    """
    return mm1_wait(lam, k * mu)


def mg1_wait(lam: float, mu: float, var_s: float) -> float:
    """Expected M/G/1/FCFS queueing delay via the P-K formula (paper Eq. 11).

    E[w] = (rho + lambda * mu * Var[s]) / (2 (mu - lambda))

    with rho = lambda/mu. The paper uses this for the multi-tenant edge where
    the aggregate service-time distribution across co-located applications is
    arbitrary (Lemma 3.2).

    Consistency checks (tested):
      Var[s] = 0        -> reduces to md1_wait           (deterministic)
      Var[s] = 1/mu^2   -> reduces to mm1_wait           (exponential)
    """
    if _unstable(lam, mu):
        return math.inf
    if lam == 0.0:
        return 0.0
    if var_s < 0:
        raise ValueError(f"variance must be >= 0, got {var_s}")
    rho = lam / mu
    return (rho + lam * mu * var_s) / (2.0 * (mu - lam))


def gg1_wait_upper_bound(lam: float, mu: float, var_a: float, var_s: float) -> float:
    """Marshall's G/G/1 upper bound on expected wait (paper Eq. 13, [30]).

    E[w] <= lambda (sigma_a^2 + sigma_s^2) / (2 (1 - rho))

    The paper offers this for bursty (non-Poisson) arrivals.
    """
    if _unstable(lam, mu):
        return math.inf
    if lam == 0.0:
        return 0.0
    if var_a < 0 or var_s < 0:
        raise ValueError("variances must be >= 0")
    rho = lam / mu
    return lam * (var_a + var_s) / (2.0 * (1.0 - rho))


# ---------------------------------------------------------------------------
# Exact / reference alternatives (not used by the paper's closed forms, but
# kept as oracles for tests and for quantifying the paper's M/D/k -> M/D/1
# aggregation error, which we report in benchmarks/model_accuracy.py).
# ---------------------------------------------------------------------------


def mmk_wait_erlang(lam: float, mu: float, k: int) -> float:
    """Exact M/M/k expected wait via the Erlang-C formula.

    The paper deliberately avoids M/M/k (birth-death derivation requires
    integer k, §3.5); we keep the exact form as a test oracle for integer k.
    """
    if k < 1 or int(k) != k:
        raise ValueError("Erlang-C requires integer k >= 1")
    k = int(k)
    if _unstable(lam, k * mu):
        return math.inf
    if lam == 0.0:
        return 0.0
    a = lam / mu  # offered load in Erlangs
    rho = a / k
    # P(wait) — Erlang C
    summation = sum(a**n / math.factorial(n) for n in range(k))
    last = a**k / (math.factorial(k) * (1.0 - rho))
    p_wait = last / (summation + last)
    return p_wait / (k * mu - lam)


def mdk_wait_approx(lam: float, mu: float, k: int) -> float:
    """Crommelin-style approximation for M/D/k expected wait.

    E[w_{M/D/k}] ~= E[w_{M/M/k}] / 2  (deterministic service halves the P-K
    variability term). Used only to quantify the aggregation error of the
    paper's Eq. 6 reduction in benchmarks; not part of the paper's models.
    """
    return 0.5 * mmk_wait_erlang(lam, mu, k)


@dataclass(frozen=True)
class QueueStats:
    """Summary of one queueing station's predicted steady-state behaviour."""

    lam: float
    mu: float
    k: float
    wait: float
    service: float
    utilisation: float

    @property
    def response(self) -> float:
        return self.wait + self.service

    @property
    def stable(self) -> bool:
        return self.utilisation < 1.0
