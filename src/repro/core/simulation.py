"""Discrete-event simulation of the paper's queueing networks (Fig. 1).

This is the validation testbed that stands in for the paper's physical
device/edge/network hardware: it simulates the *exact* queueing systems the
closed forms model — Poisson arrivals, FCFS stations with k parallel servers,
deterministic / exponential / general service draws, and the tandem
device-NIC -> edge-proc -> edge-NIC composition of Fig. 1a — and produces
observed end-to-end latencies against which the analytic predictions are
scored (MAPE, ±5% / ±10% fractions; paper §4.3 reports 2.2% / 91.5% / 100%).

Implementation: feed-forward tandem FCFS networks admit an exact recursive
simulation (Lindley recursion generalised to k servers via an
earliest-free-server heap), which is orders of magnitude faster than a
generic event calendar and bit-reproducible from a seed.
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Sequence

import numpy as np

__all__ = [
    "ServiceDist",
    "Deterministic",
    "Exponential",
    "LogNormal",
    "Mixture",
    "poisson_arrivals",
    "station_pass",
    "steady_slice",
    "SimResult",
    "simulate_tandem",
    "simulate_on_device",
    "simulate_offload",
    "simulate_split",
    "simulate_multitenant_offload",
]


# ---------------------------------------------------------------------------
# Service-time distributions
# ---------------------------------------------------------------------------


class ServiceDist:
    """A service-time distribution with known mean/variance."""

    mean: float
    var: float

    def sample(self, n: int, rng: np.random.Generator) -> np.ndarray:
        raise NotImplementedError


@dataclass(frozen=True)
class Deterministic(ServiceDist):
    """Constant service (the paper's DNN-on-accelerator model [27])."""

    value: float

    @property
    def mean(self) -> float:  # type: ignore[override]
        return self.value

    @property
    def var(self) -> float:  # type: ignore[override]
        return 0.0

    def sample(self, n, rng):
        return np.full(n, self.value, dtype=np.float64)


@dataclass(frozen=True)
class Exponential(ServiceDist):
    """Exponential service (paper's RNN/LLM and NIC model)."""

    mean_s: float

    @property
    def mean(self) -> float:  # type: ignore[override]
        return self.mean_s

    @property
    def var(self) -> float:  # type: ignore[override]
        return self.mean_s**2

    def sample(self, n, rng):
        return rng.exponential(self.mean_s, size=n)


@dataclass(frozen=True)
class LogNormal(ServiceDist):
    """General service with target mean/variance (multi-tenant mixtures)."""

    mean_s: float
    var_s: float

    @property
    def mean(self) -> float:  # type: ignore[override]
        return self.mean_s

    @property
    def var(self) -> float:  # type: ignore[override]
        return self.var_s

    def sample(self, n, rng):
        if self.var_s == 0:
            return np.full(n, self.mean_s)
        sigma2 = np.log(1.0 + self.var_s / self.mean_s**2)
        mu = np.log(self.mean_s) - 0.5 * sigma2
        return rng.lognormal(mu, np.sqrt(sigma2), size=n)


@dataclass(frozen=True)
class Mixture(ServiceDist):
    """Probabilistic mixture — the multi-tenant aggregate service (§3.4)."""

    components: tuple[ServiceDist, ...]
    weights: tuple[float, ...]

    def __post_init__(self):
        if not self.components:
            raise ValueError("Mixture needs at least one component")
        if len(self.weights) != len(self.components):
            raise ValueError(
                f"Mixture has {len(self.components)} components but "
                f"{len(self.weights)} weights")
        if not all(np.isfinite(w) and w >= 0 for w in self.weights):
            # a negative/NaN/inf weight would "normalize" into nonsense
            # sampling probabilities (or blow up inside rng.choice later)
            raise ValueError(
                f"Mixture weights must be finite and >= 0, got {self.weights}")
        total = sum(self.weights)
        if total <= 0:
            raise ValueError("Mixture weights must sum to a positive value")
        if not np.isclose(total, 1.0):
            object.__setattr__(self, "weights", tuple(w / total for w in self.weights))

    @property
    def mean(self) -> float:  # type: ignore[override]
        return float(sum(w * c.mean for w, c in zip(self.weights, self.components)))

    @property
    def var(self) -> float:  # type: ignore[override]
        m = self.mean
        second = sum(w * (c.var + c.mean**2) for w, c in zip(self.weights, self.components))
        return float(second - m**2)

    def sample(self, n, rng):
        idx = rng.choice(len(self.components), size=n, p=np.asarray(self.weights))
        out = np.empty(n, dtype=np.float64)
        for i, comp in enumerate(self.components):
            mask = idx == i
            cnt = int(mask.sum())
            if cnt:
                out[mask] = comp.sample(cnt, rng)
        return out


# ---------------------------------------------------------------------------
# Core mechanics
# ---------------------------------------------------------------------------


def poisson_arrivals(lam: float, n: int, rng: np.random.Generator) -> np.ndarray:
    """n arrival times of a Poisson(lam) process."""
    return np.cumsum(rng.exponential(1.0 / lam, size=n))


def _station_pass_k1_loop(arrivals: np.ndarray, services: np.ndarray) -> np.ndarray:
    """The textbook sequential Lindley recursion — kept as the reference
    oracle the vectorized k=1 path is tested against."""
    n = len(arrivals)
    dep = np.empty(n, dtype=np.float64)
    prev = -np.inf
    for i in range(n):
        start = arrivals[i] if arrivals[i] > prev else prev
        prev = start + services[i]
        dep[i] = prev
    return dep


def station_pass(arrivals: np.ndarray, services: np.ndarray, k: int = 1) -> np.ndarray:
    """FCFS k-server station: departure times for jobs arriving at ``arrivals``.

    Jobs start in arrival order on the earliest-free server (FCFS), so
    start_i = max(arrival_i, min(server_free)). Exact Lindley-style recursion;
    k=1 reduces to departure_i = max(arrival_i, departure_{i-1}) + service_i.

    The k=1 recursion unrolls exactly: with C_i = sum_{j<=i} S_j,

        dep_i = C_i + max_{j <= i} (arr_j - C_{j-1})

    (each job departs at the busy-period start that dominates it plus the
    accumulated service since), so the hot path is a cumsum + running max
    instead of a Python loop — ~100x faster at the simulator's 100k-job runs.
    Agrees with the sequential recursion to float64 roundoff (the two sum the
    same services in different association orders; tested at <=1e-12 relative
    on the departure times).
    """
    n = len(arrivals)
    if k == 1:
        if n == 0:  # the sequential recursion returned an empty array too
            return np.empty(0, dtype=np.float64)
        csum = np.cumsum(services, dtype=np.float64)
        excl = np.empty(n, dtype=np.float64)
        excl[0] = 0.0
        excl[1:] = csum[:-1]
        return csum + np.maximum.accumulate(arrivals - excl)
    free = [0.0] * k
    heapq.heapify(free)
    dep = np.empty(n, dtype=np.float64)
    for i in range(n):
        earliest = heapq.heappop(free)
        start = arrivals[i] if arrivals[i] > earliest else earliest
        d = start + services[i]
        dep[i] = d
        heapq.heappush(free, d)
    return dep


def steady_slice(n: int, warmup_frac: float = 0.1) -> slice:
    """The steady-state window of an n-job run: drop the warmup prefix AND a
    small cooldown tail (boundary effects). THE single definition of the trim
    — SimResult, FleetSimResult, and the validation harness all use it, so
    predicted-vs-observed comparisons can never drift on windowing."""
    n0 = int(n * warmup_frac)
    n1 = n - max(1, int(n * 0.02))
    return slice(n0, n1)


@dataclass
class SimResult:
    """Observed end-to-end latencies of one simulated scenario."""

    latencies: np.ndarray
    arrivals: np.ndarray
    warmup_frac: float = 0.1
    stream_ids: np.ndarray | None = None
    extras: dict = field(default_factory=dict)

    def _steady(self) -> np.ndarray:
        return self.latencies[steady_slice(len(self.latencies), self.warmup_frac)]

    @property
    def mean(self) -> float:
        return float(np.mean(self._steady()))

    def percentile(self, q: float) -> float:
        return float(np.percentile(self._steady(), q))

    def stream_mean(self, sid: int) -> float:
        assert self.stream_ids is not None
        sl = steady_slice(len(self.latencies), self.warmup_frac)
        mask = self.stream_ids[sl] == sid
        return float(np.mean(self.latencies[sl][mask]))


def simulate_tandem(
    arrivals: np.ndarray,
    stages: Sequence[tuple[ServiceDist, int]],
    rng: np.random.Generator,
) -> SimResult:
    """Push one arrival stream through FCFS stations in sequence.

    Each stage is (service distribution, #servers). A job's arrival at stage
    j+1 is its departure from stage j. With k>1, overtaking can occur; we sort
    inter-stage arrival order (FCFS at the next queue is by arrival there)
    while tracking per-job identity for latency accounting.
    """
    n = len(arrivals)
    order = np.arange(n)
    t = arrivals.copy()
    for dist, k in stages:
        services = dist.sample(n, rng)
        dep = station_pass(t, services, k)
        # re-sort by departure: that's the arrival order at the next station
        perm = np.argsort(dep, kind="stable")
        t = dep[perm]
        order = order[perm]
    latency = np.empty(n, dtype=np.float64)
    latency[order] = t - arrivals[order]
    return SimResult(latencies=latency, arrivals=arrivals)


# ---------------------------------------------------------------------------
# Paper-scenario frontends (Fig. 1a / 1b / split / multi-tenant)
# ---------------------------------------------------------------------------


def simulate_on_device(
    lam: float,
    service: ServiceDist,
    k: int = 1,
    *,
    n: int = 100_000,
    seed: int = 0,
) -> SimResult:
    """Fig. 1b: local queue -> k accelerator cores."""
    rng = np.random.default_rng(seed)
    arr = poisson_arrivals(lam, n, rng)
    return simulate_tandem(arr, [(service, k)], rng)


def _nic(mean_s: float, deterministic: bool) -> ServiceDist:
    return Deterministic(mean_s) if deterministic else Exponential(mean_s)


def simulate_offload(
    lam: float,
    edge_service: ServiceDist,
    k_edge: int,
    *,
    bandwidth_Bps: float,
    req_bytes: float,
    res_bytes: float,
    n: int = 100_000,
    seed: int = 0,
    deterministic_nic: bool = False,
) -> SimResult:
    """Fig. 1a: device NIC -> edge processing -> edge NIC (return path).

    NIC service is exponential with mean D/B by default, matching the paper's
    M/M/1 NIC model; ``deterministic_nic=True`` gives constant transmission
    (used to quantify that modelling choice in benchmarks).
    """
    rng = np.random.default_rng(seed)
    arr = poisson_arrivals(lam, n, rng)
    stages = [
        (_nic(req_bytes / bandwidth_Bps, deterministic_nic), 1),
        (edge_service, k_edge),
        (_nic(res_bytes / bandwidth_Bps, deterministic_nic), 1),
    ]
    return simulate_tandem(arr, stages, rng)


def simulate_split(
    lam: float,
    dev_service: ServiceDist,
    edge_service: ServiceDist,
    *,
    k_dev: int = 1,
    k_edge: int = 1,
    bandwidth_Bps: float,
    inter_bytes: float,
    res_bytes: float,
    n: int = 100_000,
    seed: int = 0,
) -> SimResult:
    """Collaborative processing: partial device -> ship D_inter -> edge -> return."""
    rng = np.random.default_rng(seed)
    arr = poisson_arrivals(lam, n, rng)
    stages: list[tuple[ServiceDist, int]] = []
    if dev_service.mean > 0:
        stages.append((dev_service, k_dev))
    if inter_bytes > 0:
        stages.append((Exponential(inter_bytes / bandwidth_Bps), 1))
    if edge_service.mean > 0:
        stages.append((edge_service, k_edge))
        stages.append((Exponential(res_bytes / bandwidth_Bps), 1))
    return simulate_tandem(arr, stages, rng)


def simulate_multitenant_offload(
    streams: Sequence[tuple[float, ServiceDist]],
    k_edge: int,
    *,
    bandwidth_Bps: float,
    req_bytes: float,
    res_bytes: float,
    observe_stream: int = 0,
    n_per_stream: int | Sequence[int] = 20_000,
    seed: int = 0,
) -> SimResult:
    """m devices offloading to one shared edge (paper §3.4 figure).

    Each stream i has its own Poisson(lambda_i) arrivals and its own device
    NIC; the edge processing station is shared (no isolation); the edge NIC
    return path carries all completions. Latencies are reported for
    ``observe_stream`` (plus all streams via stream_ids).

    ``n_per_stream`` may be a per-stream sequence: with heterogeneous rates,
    equal counts give unequal time horizons (fast streams drain early and the
    slow ones' tails see an underloaded edge) — scale counts by rate to keep
    a common horizon.
    """
    rng = np.random.default_rng(seed)
    if isinstance(n_per_stream, int):
        counts = [n_per_stream] * len(streams)
    else:
        counts = list(n_per_stream)
        if len(counts) != len(streams):
            raise ValueError("n_per_stream sequence must match streams length")
    per_stream_after_nic: list[np.ndarray] = []
    arrivals_per_stream: list[np.ndarray] = []
    for (lam, _dist), cnt in zip(streams, counts):
        arr = poisson_arrivals(lam, cnt, rng)
        arrivals_per_stream.append(arr)
        nic = Exponential(req_bytes / bandwidth_Bps)
        dep = station_pass(arr, nic.sample(len(arr), rng), 1)
        per_stream_after_nic.append(dep)

    # merge at the shared edge queue, FCFS by arrival there
    sid = np.concatenate(
        [np.full(len(a), i) for i, a in enumerate(per_stream_after_nic)]
    )
    jid = np.concatenate([np.arange(len(a)) for a in per_stream_after_nic])
    t = np.concatenate(per_stream_after_nic)
    perm = np.argsort(t, kind="stable")
    t, sid, jid = t[perm], sid[perm], jid[perm]

    services = np.empty(len(t), dtype=np.float64)
    for i, (_lam, dist) in enumerate(streams):
        mask = sid == i
        services[mask] = dist.sample(int(mask.sum()), rng)
    dep = station_pass(t, services, k_edge)

    # shared return NIC
    perm2 = np.argsort(dep, kind="stable")
    dep, sid, jid = dep[perm2], sid[perm2], jid[perm2]
    nic_out = Exponential(res_bytes / bandwidth_Bps)
    out = station_pass(dep, nic_out.sample(len(dep), rng), 1)

    starts = np.concatenate(arrivals_per_stream)
    # map (sid, jid) back to original arrival time
    offsets = np.cumsum([0] + [len(a) for a in arrivals_per_stream[:-1]])
    orig_arrival = starts[offsets[sid] + jid]
    latency = out - orig_arrival
    # order results by original arrival time for warmup trimming
    perm3 = np.argsort(orig_arrival, kind="stable")
    return SimResult(
        latencies=latency[perm3],
        arrivals=orig_arrival[perm3],
        stream_ids=sid[perm3],
    )
