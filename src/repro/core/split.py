"""Collaborative (split) processing — tandem queue model (paper §3.3 ext.).

A request is partially processed on the device (service s'_dev), the
intermediate activation of size D_inter crosses the network, and the edge
finishes the remaining computation (service s'_edge). The end-to-end model is
the tandem composition of Fig. 1b then Fig. 1a with the request payload
replaced by D_inter.

The planner enumerates split points of a layered model (s = 0 .. L, where
s = 0 is full offload and s = L is full on-device) using per-layer cost
profiles and picks the argmin — this is what §4.6 evaluates (Fig. 5a).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import numpy as np

from .latency import (
    NetworkPath,
    Tier,
    Workload,
    edge_offload_latency,
    mm1_wait,
    on_device_latency,
    proc_wait,
)

__all__ = ["SplitPoint", "split_latency", "LayerProfile", "SplitPlanner", "SplitPlan"]


@dataclass(frozen=True)
class SplitPoint:
    """A concrete split: device does s'_dev of work, ships D_inter bytes."""

    dev_service_s: float  # s'_dev
    edge_service_s: float  # s'_edge
    inter_bytes: float  # D_inter
    index: int = -1  # split layer index (bookkeeping)


def split_latency(
    wl: Workload,
    dev: Tier,
    edge: Tier,
    net: NetworkPath,
    sp: SplitPoint,
    *,
    edge_arrival_rate=None,
    breakdown: bool = False,
):
    """Tandem-queue end-to-end latency of a split execution.

    T_split = w_dev^proc(s'_dev) + s'_dev                     (partial local)
            + w_dev^net + D_inter/B                           (ship activation)
            + w_edge^proc(s'_edge) + s'_edge                  (finish at edge)
            + w_edge^net + D_res/B                            (return result)

    Degenerate cases reduce exactly to the base models (tested):
      s'_dev = 0, D_inter = D_req  -> edge_offload_latency
      s'_edge = 0, D_inter = 0     -> on_device_latency      (no network legs)
    """
    lam = wl.arrival_rate
    lam_edge = lam if edge_arrival_rate is None else edge_arrival_rate

    terms = {}
    # --- device partial processing (Fig. 1b with service s'_dev) ---
    if sp.dev_service_s > 0:
        terms["w_proc_dev"] = proc_wait(dev, lam, service_time=sp.dev_service_s)
        terms["s_dev_partial"] = sp.dev_service_s
    else:
        terms["w_proc_dev"] = 0.0
        terms["s_dev_partial"] = 0.0

    # --- network leg with the intermediate payload (Fig. 1a forward path) ---
    if sp.inter_bytes > 0:
        mu_net_dev = net.nic_rate(sp.inter_bytes)
        terms["w_net_dev"] = mm1_wait(lam, mu_net_dev)
        terms["n_inter"] = net.transmission(sp.inter_bytes)
    else:
        terms["w_net_dev"] = 0.0
        terms["n_inter"] = 0.0

    # --- edge remainder + return path ---
    if sp.edge_service_s > 0:
        terms["w_proc_edge"] = proc_wait(edge, lam_edge, service_time=sp.edge_service_s)
        terms["s_edge_partial"] = sp.edge_service_s
        mu_net_edge = net.nic_rate(wl.res_bytes)
        terms["w_net_edge"] = mm1_wait(lam_edge, mu_net_edge)
        terms["n_res"] = net.transmission(wl.res_bytes)
    else:
        terms["w_proc_edge"] = 0.0
        terms["s_edge_partial"] = 0.0
        terms["w_net_edge"] = 0.0
        terms["n_res"] = 0.0

    total = sum(terms.values())
    if breakdown:
        from .latency import LatencyBreakdown

        return LatencyBreakdown(total, terms)
    return total


@dataclass(frozen=True)
class LayerProfile:
    """Per-layer cost: service seconds on each tier + output activation bytes."""

    dev_service_s: float
    edge_service_s: float
    out_bytes: float
    name: str = "layer"


@dataclass(frozen=True)
class SplitPlan:
    index: int  # layers [0, index) on device, [index, L) on edge
    latency_s: float
    point: SplitPoint | None  # None for pure strategies
    strategy: str  # "device" | "edge" | "split"


class SplitPlanner:
    """Chooses full-local vs full-offload vs the best split point.

    Mirrors §4.6: later split points ship larger intermediate activations, so
    the tandem model naturally penalises them; the planner just evaluates the
    closed form at every boundary.
    """

    def __init__(self, layers: Sequence[LayerProfile], wl: Workload):
        self.layers = list(layers)
        self.wl = wl

    def candidate(self, index: int) -> SplitPoint:
        if not 0 <= index <= len(self.layers):
            raise IndexError(index)
        dev_s = float(sum(l.dev_service_s for l in self.layers[:index]))
        edge_s = float(sum(l.edge_service_s for l in self.layers[index:]))
        if index == 0:
            inter = self.wl.req_bytes  # full offload ships the raw request
        elif index == len(self.layers):
            inter = 0.0  # nothing crosses the network
        else:
            inter = float(self.layers[index - 1].out_bytes)
        return SplitPoint(dev_s, edge_s, inter, index=index)

    def plan(
        self,
        dev: Tier,
        edge: Tier,
        net: NetworkPath,
        *,
        edge_arrival_rate=None,
    ) -> SplitPlan:
        n = len(self.layers)
        best: SplitPlan | None = None
        for idx in range(n + 1):
            sp = self.candidate(idx)
            lat = float(
                split_latency(
                    self.wl, dev, edge, net, sp, edge_arrival_rate=edge_arrival_rate
                )
            )
            strategy = "edge" if idx == 0 else ("device" if idx == n else "split")
            cand = SplitPlan(idx, lat, sp, strategy)
            if best is None or cand.latency_s < best.latency_s:
                best = cand
        assert best is not None
        return best

    def sweep(self, dev: Tier, edge: Tier, net: NetworkPath, **kw) -> np.ndarray:
        """Latency at every split boundary (for Fig. 5a-style plots)."""
        return np.array(
            [
                split_latency(self.wl, dev, edge, net, self.candidate(i), **kw)
                for i in range(len(self.layers) + 1)
            ]
        )
