"""End-to-end latency models for on-device processing and edge offloading.

Transcribes the paper's Eq. (1)/(2) decompositions and Lemmas 3.1-3.3.
All functions are numpy-broadcasting: pass scalars for a single prediction or
arrays (e.g. a bandwidth sweep) and every term broadcasts. Unstable operating
points yield ``inf`` (the adaptive manager treats them as never-preferable).

Units: seconds, bytes, bytes/second, requests/second.
"""

from __future__ import annotations

import enum
from dataclasses import dataclass, field, replace
from typing import Any

import numpy as np

__all__ = [
    "ServiceModel",
    "Tier",
    "Workload",
    "NetworkPath",
    "mm1_wait",
    "md1_wait",
    "mg1_wait",
    "proc_wait",
    "on_device_latency",
    "edge_offload_latency",
    "lemma31_rhs",
    "lemma33_rhs",
    "lemma32_rhs",
    "offload_wins",
    "LatencyBreakdown",
]

_INF = np.inf


def _stable_where(lam, effective_mu, value):
    """inf wherever the queue is unstable (lam >= effective_mu)."""
    lam = np.asarray(lam, dtype=np.float64)
    effective_mu = np.asarray(effective_mu, dtype=np.float64)
    ok = (lam < effective_mu) & (effective_mu > 0) & (lam >= 0)
    return np.where(ok, value, _INF)


def mm1_wait(lam, mu):
    """Paper Eq. 7 — numpy-broadcasting variant of queueing.mm1_wait."""
    lam = np.asarray(lam, dtype=np.float64)
    mu = np.asarray(mu, dtype=np.float64)
    with np.errstate(divide="ignore", invalid="ignore"):
        w = 1.0 / (mu - lam) - 1.0 / mu
    return _stable_where(lam, mu, w)


def md1_wait(lam, mu, k=1.0):
    """Paper Eq. 6 — M/D/k via aggregated-rate M/D/1: 1/2(1/(k mu - lam) - 1/(k mu))."""
    lam = np.asarray(lam, dtype=np.float64)
    kmu = np.asarray(mu, dtype=np.float64) * np.asarray(k, dtype=np.float64)
    with np.errstate(divide="ignore", invalid="ignore"):
        w = 0.5 * (1.0 / (kmu - lam) - 1.0 / kmu)
    return _stable_where(lam, kmu, w)


def mg1_wait(lam, mu, var_s, k=1.0):
    """Paper Eq. 11 — P-K M/G/1 wait with aggregated service rate k*mu.

    E[w] = (rho + lam * (k mu) * Var[s]) / (2 (k mu - lam)), rho = lam/(k mu).
    Matches the form used in Lemma 3.2's right-hand side.
    """
    lam = np.asarray(lam, dtype=np.float64)
    kmu = np.asarray(mu, dtype=np.float64) * np.asarray(k, dtype=np.float64)
    var_s = np.asarray(var_s, dtype=np.float64)
    with np.errstate(divide="ignore", invalid="ignore"):
        rho = lam / kmu
        w = (rho + lam * kmu * var_s) / (2.0 * (kmu - lam))
    return _stable_where(lam, kmu, w)


class ServiceModel(str, enum.Enum):
    """Which queueing formulation models a tier's service (paper §3.3/§3.5)."""

    DETERMINISTIC = "md1"  # DNN inference: constant op count -> M/D/1 (Lemma 3.1)
    EXPONENTIAL = "mm1"  # RNN/LLM: length-dependent service -> M/M/1 (Lemma 3.3)
    GENERAL = "mg1"  # multi-tenant aggregate -> M/G/1 (Lemma 3.2)


@dataclass(frozen=True)
class Tier:
    """An accelerator tier (client device, edge pod, ...).

    ``service_time_s`` is the paper's s_dev / s_edge (mean). ``parallelism_k``
    is the paper's effective parallelism, folded into the service rate as k*mu
    (their M/D/k -> M/D/1 aggregation; k may be fractional, §3.5).
    """

    name: str
    service_time_s: float
    parallelism_k: float = 1.0
    service_model: ServiceModel = ServiceModel.DETERMINISTIC
    service_var: float = 0.0  # Var[s]; only read for ServiceModel.GENERAL
    meta: dict[str, Any] = field(default_factory=dict, compare=False)

    @property
    def service_rate(self) -> float:
        """mu = 1/s (paper: 'service rate is the inverse of service time')."""
        return 1.0 / self.service_time_s

    def with_service(self, service_time_s: float, service_var: float | None = None) -> "Tier":
        return replace(
            self,
            service_time_s=service_time_s,
            service_var=self.service_var if service_var is None else service_var,
        )

    @classmethod
    def from_measured(cls, profile, occupancy: int = 1, *,
                      name: str | None = None) -> "Tier":
        """Build a tier from a measured service-time profile.

        ``profile`` is duck-typed: anything exposing
        ``service_moments(occupancy) -> (mean_s, var_s, service_model)``
        works — canonically a ``repro.measure.MeasuredProfile`` fitted from a
        real engine run. The measured request-level distribution at the given
        batch occupancy becomes the tier's two service moments, classified
        into the paper's taxonomy (M/D/1, M/M/1, or two-moment M/G/1), and
        ``occupancy`` becomes the effective parallelism k — ``occupancy``
        requests are in service concurrently, so the aggregate rate is
        k*mu exactly as in the paper's M/D/k -> M/D/1 folding (§3.5).

        The result is an ordinary :class:`Tier`: it flows through
        ``analytic()``, ``analytic_tail()``, ``fleet.analytic_vec``,
        crossovers, and the manager with no special-casing.
        """
        if occupancy < 1:
            raise ValueError(f"occupancy must be >= 1, got {occupancy}")
        mean_s, var_s, model = profile.service_moments(occupancy)
        mean_s, var_s = float(mean_s), float(var_s)
        if not mean_s > 0:
            raise ValueError(f"measured mean service must be > 0, got {mean_s}")
        if var_s < 0:
            raise ValueError(f"measured service variance must be >= 0, got {var_s}")
        model = ServiceModel(model)
        meta = {"measured": True, "occupancy": int(occupancy)}
        for attr in ("arch", "clock", "seed", "n_requests"):
            if hasattr(profile, attr):
                meta[attr] = getattr(profile, attr)
        return cls(
            name=name or f"measured:{meta.get('arch', 'profile')}@{occupancy}",
            service_time_s=mean_s,
            parallelism_k=float(occupancy),
            service_model=model,
            # only M/G/1 reads Var[s]; zero it otherwise so equality/
            # serialization of DETERMINISTIC/EXPONENTIAL tiers stays canonical
            service_var=var_s if model is ServiceModel.GENERAL else 0.0,
            meta=meta,
        )


@dataclass(frozen=True)
class Workload:
    """A request stream: Poisson(lam) arrivals with given payload sizes."""

    arrival_rate: float  # lambda (RPS)
    req_bytes: float  # D_req
    res_bytes: float  # D_res
    name: str = "workload"


@dataclass(frozen=True)
class NetworkPath:
    """The device<->edge network path. mu_net = B / D (paper §3.3, Alg. 1)."""

    bandwidth_Bps: float  # B

    def nic_rate(self, payload_bytes) -> np.ndarray:
        return np.asarray(self.bandwidth_Bps, dtype=np.float64) / np.asarray(
            payload_bytes, dtype=np.float64
        )

    def transmission(self, payload_bytes) -> np.ndarray:
        """n = D / B."""
        return np.asarray(payload_bytes, dtype=np.float64) / np.asarray(
            self.bandwidth_Bps, dtype=np.float64
        )


def proc_wait(tier: Tier, lam, *, service_time=None, service_var=None):
    """Processing-queue wait at a tier under arrival rate lam.

    Dispatches on the tier's queueing formulation exactly as the paper does:
    M/D/1 (Eq. 6) for deterministic, M/M/1 (Eq. 7, aggregated) for
    exponential, M/G/1 (Eq. 11) for general service.
    """
    s = np.asarray(tier.service_time_s if service_time is None else service_time)
    v = np.asarray(tier.service_var if service_var is None else service_var)
    with np.errstate(divide="ignore", invalid="ignore"):
        mu = 1.0 / s
    if tier.service_model is ServiceModel.DETERMINISTIC:
        return md1_wait(lam, mu, tier.parallelism_k)
    if tier.service_model is ServiceModel.EXPONENTIAL:
        return mm1_wait(lam, mu * tier.parallelism_k)
    if tier.service_model is ServiceModel.GENERAL:
        return mg1_wait(lam, mu, v, tier.parallelism_k)
    raise ValueError(f"unknown service model {tier.service_model}")


@dataclass(frozen=True)
class LatencyBreakdown:
    """Term-by-term decomposition (mirrors paper Eq. 1/2) for explainability.

    The paper's selling point is *explainable* closed forms — the manager
    logs this breakdown so an operator can see exactly which term drove a
    placement flip.
    """

    total: Any
    terms: dict[str, Any]

    def __getitem__(self, key):
        return self.terms[key]


def on_device_latency(wl: Workload, dev: Tier, *, breakdown: bool = False):
    """Paper Eq. 2: T_dev = w_dev^proc + s_dev."""
    w = proc_wait(dev, wl.arrival_rate)
    total = w + dev.service_time_s
    if not breakdown:
        return total
    return LatencyBreakdown(total, {"w_proc_dev": w, "s_dev": dev.service_time_s})


def edge_offload_latency(
    wl: Workload,
    edge: Tier,
    net: NetworkPath,
    *,
    edge_arrival_rate=None,
    return_results: bool = True,
    breakdown: bool = False,
):
    """Paper Eq. 1: T_edge = w_dev^net + n_req + w_edge^proc + s_edge + w_edge^net + n_res.

    ``edge_arrival_rate`` is the *aggregate* arrival rate at the edge
    (lambda_edge = sum_i lambda_i under multi-tenancy, §3.4); defaults to the
    workload's own rate (dedicated edge). ``return_results=False`` drops the
    reverse network path for results consumed at the edge (paper §3.3: "can be
    generalized ... by omitting this network delay").
    """
    lam = wl.arrival_rate
    lam_edge = lam if edge_arrival_rate is None else edge_arrival_rate

    mu_net_dev = net.nic_rate(wl.req_bytes)
    w_net_dev = mm1_wait(lam, mu_net_dev)  # device NIC sees this stream only
    n_req = net.transmission(wl.req_bytes)

    w_proc_edge = proc_wait(edge, lam_edge)
    s_edge = edge.service_time_s

    if return_results:
        mu_net_edge = net.nic_rate(wl.res_bytes)
        # Edge NIC carries completions of everything the edge serves
        # (throughput = aggregate arrival rate under stability, paper §3.3.1).
        w_net_edge = mm1_wait(lam_edge, mu_net_edge)
        n_res = net.transmission(wl.res_bytes)
    else:
        w_net_edge = np.zeros_like(np.asarray(n_req))
        n_res = np.zeros_like(np.asarray(n_req))

    total = w_net_dev + n_req + w_proc_edge + s_edge + w_net_edge + n_res
    if not breakdown:
        return total
    return LatencyBreakdown(
        total,
        {
            "w_net_dev": w_net_dev,
            "n_req": n_req,
            "w_proc_edge": w_proc_edge,
            "s_edge": s_edge,
            "w_net_edge": w_net_edge,
            "n_res": n_res,
        },
    )


# ---------------------------------------------------------------------------
# Lemma right-hand sides. Each lemma states: edge offloading has HIGHER
# average latency than on-device iff  s_dev - s_edge < RHS.
# ---------------------------------------------------------------------------


def _net_terms(lam_dev, lam_edge, wl: Workload, net: NetworkPath):
    """Common first three RHS terms: the two NIC waits + transmissions."""
    mu_nd = net.nic_rate(wl.req_bytes)
    mu_ne = net.nic_rate(wl.res_bytes)
    with np.errstate(divide="ignore", invalid="ignore"):
        t1 = lam_dev / (mu_nd * (mu_nd - lam_dev))
        t2 = lam_edge / (mu_ne * (mu_ne - lam_edge))
    t1 = _stable_where(lam_dev, mu_nd, t1)
    t2 = _stable_where(lam_edge, mu_ne, t2)
    t3 = (np.asarray(wl.req_bytes) + np.asarray(wl.res_bytes)) / np.asarray(
        net.bandwidth_Bps, dtype=np.float64
    )
    return t1 + t2 + t3


def lemma31_rhs(wl: Workload, dev: Tier, edge: Tier, net: NetworkPath):
    """Lemma 3.1 RHS (Eq. 3): deterministic-service (DNN) crossover bound."""
    lam = np.asarray(wl.arrival_rate, dtype=np.float64)
    rhs = _net_terms(lam, lam, wl, net)
    ke_mu = edge.parallelism_k * edge.service_rate
    kd_mu = dev.parallelism_k * dev.service_rate
    with np.errstate(divide="ignore", invalid="ignore"):
        edge_term = 0.5 * (1.0 / (ke_mu - lam) - 1.0 / ke_mu)
        dev_term = 0.5 * (1.0 / (kd_mu - lam) - 1.0 / kd_mu)
    edge_term = _stable_where(lam, ke_mu, edge_term)
    dev_term = _stable_where(lam, kd_mu, dev_term)
    return rhs + edge_term - dev_term


def lemma33_rhs(wl: Workload, dev: Tier, edge: Tier, net: NetworkPath):
    """Lemma 3.3 RHS (Eq. 12): exponential-service (RNN/LLM) crossover bound."""
    lam = np.asarray(wl.arrival_rate, dtype=np.float64)
    rhs = _net_terms(lam, lam, wl, net)
    ke_mu = edge.parallelism_k * edge.service_rate
    kd_mu = dev.parallelism_k * dev.service_rate
    with np.errstate(divide="ignore", invalid="ignore"):
        edge_term = 1.0 / (ke_mu - lam) - 1.0 / ke_mu
        dev_term = 1.0 / (kd_mu - lam) - 1.0 / kd_mu
    edge_term = _stable_where(lam, ke_mu, edge_term)
    dev_term = _stable_where(lam, kd_mu, dev_term)
    return rhs + edge_term - dev_term


def lemma32_rhs(
    wl: Workload,
    dev: Tier,
    edge: Tier,
    net: NetworkPath,
    *,
    edge_arrival_rate,
    edge_service_var,
):
    """Lemma 3.2 RHS (Eq. 10): multi-tenant edge (M/G/1) crossover bound.

    ``edge_arrival_rate`` = lambda_edge = sum_i lambda_i; ``edge_service_var``
    = Var[s_edge] of the aggregate mixture (see multitenant.aggregate_streams).
    """
    lam_dev = np.asarray(wl.arrival_rate, dtype=np.float64)
    lam_edge = np.asarray(edge_arrival_rate, dtype=np.float64)
    rhs = _net_terms(lam_dev, lam_edge, wl, net)

    ke_mu = edge.parallelism_k * edge.service_rate
    kd_mu = dev.parallelism_k * dev.service_rate
    with np.errstate(divide="ignore", invalid="ignore"):
        rho_edge = lam_edge / ke_mu
        edge_term = (rho_edge + lam_edge * ke_mu * np.asarray(edge_service_var)) / (
            2.0 * (ke_mu - lam_edge)
        )
        dev_term = 0.5 * (1.0 / (kd_mu - lam_dev) - 1.0 / kd_mu)
    edge_term = _stable_where(lam_edge, ke_mu, edge_term)
    dev_term = _stable_where(lam_dev, kd_mu, dev_term)
    return rhs + edge_term - dev_term


def offload_wins(wl: Workload, dev: Tier, edge: Tier, net: NetworkPath, **kw):
    """True where edge offloading has LOWER expected latency (direct Eq.1 vs Eq.2).

    Equivalent to the lemma inequality NOT holding; tested for consistency
    against the lemma RHS forms.
    """
    return np.asarray(
        edge_offload_latency(wl, edge, net, **kw) < on_device_latency(wl, dev)
    )
